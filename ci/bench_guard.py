#!/usr/bin/env python3
"""Bench-regression guard for the flexswap-bench-v1 trajectory.

Compares a freshly generated BENCH_hotpath.json against the committed
baseline and fails (exit 1) when any named series regressed by more
than the threshold on mean ns/iter, or when a baseline series vanished.

Stdlib only — no pip installs in CI.

Usage:
    python3 ci/bench_guard.py <baseline.json> <fresh.json> [--threshold PCT]

States handled:
  * baseline has no results (the pending-measurement placeholder the
    repo shipped before the first toolchain-bearing CI run): the guard
    passes and prints the fresh numbers with a reminder to commit them
    as the first real baseline.
  * baseline entry carries `"provisional": true` (a desk-estimated
    placeholder committed without a local toolchain): the delta is
    printed but never fails — CI's fresh artifact is the source of
    truth to commit over it. A provisional series vanishing still
    fails, so placeholders cannot mask a deleted benchmark.
  * `fleet_scale ...` series are advisory: wall-clock parallel scaling
    depends on the runner's core count, so regressions print a notice
    but never fail. Vanishing still fails.
  * any other series present in both: fail on > threshold% mean_ns
    regression.
  * series only in the baseline: fail (a benchmark silently vanished).
  * series only in the fresh run: informational (new benchmarks are
    committed with the next baseline update).
"""

import argparse
import json
import sys

SCHEMA = "flexswap-bench-v1"


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        sys.exit(f"{path}: unexpected schema {doc.get('schema')!r} (want {SCHEMA!r})")
    return {r["name"]: r for r in doc.get("results", [])}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument(
        "--threshold",
        type=float,
        default=25.0,
        help="max allowed mean_ns regression, percent (default 25)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)

    if not fresh:
        sys.exit(f"{args.fresh}: no results — did the bench run?")

    if not base:
        print("bench guard: baseline is in pending-measurement state; nothing to compare.")
        print("Fresh numbers (commit BENCH_hotpath.json to make them the baseline):")
        for name, r in sorted(fresh.items()):
            print(f"  {name:<44} {r['mean_ns']:>12.1f} ns/iter")
        return

    regressions = []
    missing = []
    advisories = []
    for name, b in sorted(base.items()):
        f = fresh.get(name)
        if f is None:
            missing.append(name)
            continue
        b_ns, f_ns = float(b["mean_ns"]), float(f["mean_ns"])
        delta_pct = (f_ns - b_ns) / b_ns * 100.0 if b_ns > 0 else 0.0
        over = delta_pct > args.threshold
        advisory = bool(b.get("provisional")) or name.startswith("fleet_scale")
        if over and advisory:
            marker = "regression (advisory)"
        elif over:
            marker = "REGRESSION"
        elif b.get("provisional"):
            marker = "ok (provisional baseline)"
        else:
            marker = "ok"
        print(
            f"  {name:<44} {b_ns:>12.1f} -> {f_ns:>12.1f} ns/iter "
            f"({delta_pct:+7.1f}%)  {marker}"
        )
        if over:
            if advisory:
                advisories.append((name, delta_pct))
            else:
                regressions.append((name, delta_pct))

    new = sorted(set(fresh) - set(base))
    for name in new:
        print(f"  {name:<44} {'(new series)':>12} {fresh[name]['mean_ns']:>12.1f} ns/iter")

    if advisories:
        worst = ", ".join(f"{n} ({d:+.1f}%)" for n, d in advisories)
        print(
            "bench guard: advisory (provisional/fleet_scale series over "
            f"threshold, not gating): {worst}"
        )
    if any(b.get("provisional") for b in base.values()):
        print(
            "bench guard: baseline contains provisional (desk-estimated) entries — "
            "commit CI's fresh BENCH_hotpath.json artifact to replace them with "
            "measured numbers."
        )
    if missing:
        print(f"bench guard: series missing from the fresh run: {', '.join(missing)}")
    if regressions:
        worst = ", ".join(f"{n} ({d:+.1f}%)" for n, d in regressions)
        print(f"bench guard: FAIL — >{args.threshold:.0f}% regression in: {worst}")
    if missing or regressions:
        sys.exit(1)
    print(f"bench guard: OK — {len(base)} series within {args.threshold:.0f}% of baseline")


if __name__ == "__main__":
    main()
