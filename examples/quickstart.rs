//! Quickstart: one VM under the flexswap MM with the default
//! dt-reclaimer, running a random-access workload. Shows the core loop:
//! faults -> UFFD -> policy engine -> swapper -> storage, and proactive
//! cold-memory reclamation.
//!
//! Run: `cargo run --release --example quickstart`

use flexswap::config::{HostConfig, MmConfig, VmConfig};
use flexswap::coordinator::Machine;
use flexswap::metrics::{fmt_bytes, fmt_ns};
use flexswap::types::{PageSize, MS};
use flexswap::workloads::PhasedWss;

fn main() {
    let mut machine = Machine::new(HostConfig::default());

    // A 256 MiB strict-2MB VM...
    let vm_cfg = VmConfig {
        frames: 65_536,
        vcpus: 1,
        page_size: PageSize::Huge,
        scramble: 0.0, // pristine boot (tiny demo VM: see DESIGN on scatter vs units)
        guest_thp_coverage: 1.0,
    };
    // ...whose MM scans the EPT every 8ms and reclaims pages the
    // dt-reclaimer predicts won't be needed (target promotion rate 2%).
    let mm_cfg = MmConfig {
        scan_interval: 8 * MS,
        history: 16,
        target_promotion_rate: 0.02,
        ..Default::default()
    };

    // Workload: warms half the guest, then shrinks to a quarter of
    // that — the dt-reclaimer harvests the cold remainder.
    let vm = machine.sys_vm(
        vm_cfg,
        &mm_cfg,
        vec![Box::new(PhasedWss::new(vec![
            (32_768, 300_000),
            (8_192, 900_000),
        ]))],
    );

    let results = machine.run();
    let r = &results[0];

    println!("== quickstart: flexswap MM + dt-reclaimer ==");
    println!("guest size        : {}", fmt_bytes(r.nominal_bytes));
    println!("virtual runtime   : {}", fmt_ns(r.runtime));
    println!("avg resident      : {}", fmt_bytes(r.avg_usage_bytes as u64));
    println!(
        "memory saved      : {:.0}% of guest size",
        (1.0 - r.avg_usage_bytes / r.nominal_bytes as f64) * 100.0
    );
    println!(
        "faults            : {} major / {} minor",
        r.counters.faults_major, r.counters.faults_minor
    );
    println!(
        "fault latency     : mean {} p99 {}",
        fmt_ns(r.fault_hist.mean() as u64),
        fmt_ns(r.fault_hist.quantile(0.99))
    );
    println!(
        "swap traffic      : in {} / out {}",
        fmt_bytes(r.counters.swapin_bytes),
        fmt_bytes(r.counters.swapout_bytes)
    );
    let mm = machine.mm(vm).unwrap();
    println!(
        "dt threshold      : {:.1} scans (wss estimate {} units)",
        mm.core.params.get("dt.threshold").copied().unwrap_or(f64::NAN),
        mm.core.params.get("dt.wss_units").copied().unwrap_or(0.0),
    );
}
