//! Phase-changing workload (graph500-style) under three reclamation
//! set-ups: no reclamation, the default dt-reclaimer, and dt + the
//! SYS-Agg phase detector (paper §6.7 / Fig 12).
//!
//! Run: `cargo run --release --example phase_workload`

use flexswap::config::{HostConfig, MmConfig, VmConfig};
use flexswap::coordinator::{Machine, Mechanism, VmSetup};
use flexswap::metrics::fmt_bytes;
use flexswap::mm::Mm;
use flexswap::policies::{AggressivePolicy, DtReclaimer, LruReclaimer, NativeAnalytics};
use flexswap::types::{PageSize, MS, SEC};
use flexswap::workloads::{cloud_preset, CloudWorkload};

fn run(config: &str) -> (u64, f64, Vec<(u64, f64)>) {
    let spec = cloud_preset("g500", 0.06);
    let frames = spec.pages + spec.pages / 8 + 1024;
    let mut m = Machine::new(HostConfig::default());
    let vm_cfg = VmConfig {
        frames,
        vcpus: 1,
        page_size: PageSize::Huge,
        scramble: 0.05,
        guest_thp_coverage: 1.0,
    };
    let mm_cfg = MmConfig {
        scan_interval: if config == "none" { 3600 * SEC } else { 15 * MS },
        history: 16,
        ..Default::default()
    };
    let mut mm = Mm::new(
        &mm_cfg,
        vm_cfg.units(),
        vm_cfg.page_size.unit_bytes(),
        &m.host.sw,
        m.host.hw.zero_2m_ns,
    );
    if config != "none" {
        mm.add_policy(Box::new(DtReclaimer::new(
            Box::new(NativeAnalytics::new()),
            mm_cfg.history,
            mm_cfg.target_promotion_rate,
        )));
    }
    if config == "sys-agg" {
        mm.add_policy(Box::new(AggressivePolicy::new(15 * MS)));
    }
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    m.add_vm(VmSetup {
        vm_cfg,
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: vec![Box::new(CloudWorkload::new(spec))],
        scan_interval: Some(mm_cfg.scan_interval),
    });
    let res = m.run();
    let r = &res[0];
    (r.runtime, r.avg_usage_bytes, r.usage_series.clone())
}

fn main() {
    println!("== g500 phases: construction -> 2x BFS -> 2x SSSP ==\n");
    let (rt_none, mem_none, _) = run("none");
    let (rt_dt, mem_dt, series_dt) = run("dt");
    let (rt_agg, mem_agg, series_agg) = run("sys-agg");

    for (name, rt, mem) in [
        ("no reclamation", rt_none, mem_none),
        ("dt-reclaimer", rt_dt, mem_dt),
        ("dt + SYS-Agg", rt_agg, mem_agg),
    ] {
        println!(
            "{name:16} runtime {:8.1} ms   avg resident {:>9}  ({:.0}% of peak)",
            rt as f64 / 1e6,
            fmt_bytes(mem as u64),
            mem / mem_none * 100.0
        );
    }

    // ASCII usage-over-time sparkline (20 buckets).
    println!("\nmemory usage over time (each column = 5% of runtime):");
    for (name, series) in [("dt", &series_dt), ("agg", &series_agg)] {
        let peak = series.iter().map(|p| p.1).fold(1.0f64, f64::max);
        let mut line = String::new();
        for i in 0..20 {
            let idx = (i * series.len() / 20).min(series.len().saturating_sub(1));
            let frac = series[idx].1 / peak;
            let glyph = match (frac * 8.0) as u32 {
                0 => ' ',
                1 => '.',
                2 => ':',
                3 => '-',
                4 => '=',
                5 => '+',
                6 => '*',
                7 => '#',
                _ => '@',
            };
            line.push(glyph);
        }
        println!("  {name:>4} |{line}|");
    }
    println!("\nSYS-Agg detects each phase change from the fault-rate uptick and");
    println!("drains the previous phase's working set within seconds (Fig 12).");
}
