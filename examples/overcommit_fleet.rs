//! End-to-end driver (DESIGN.md deliverable): a daemon-managed fleet of
//! four VMs with different SLAs runs real cloud-workload generators on
//! one host, sharing the NVMe swap device. The host is *overcommitted*:
//! the sum of VM memory exceeds a budget, and the control plane uses
//! the daemon's cold-memory reports to place limits — while the MMs
//! keep reclaiming proactively.
//!
//! Reports per-VM throughput (ops/s), fault latency and memory saved —
//! the paper's headline "overcommit without hurting the workloads".
//!
//! Run: `cargo run --release --example overcommit_fleet`

use flexswap::config::HostConfig;
use flexswap::daemon::{Daemon, Sla, VmRegistration};
use flexswap::metrics::{fmt_bytes, fmt_ns};
use flexswap::types::SEC;
use flexswap::workloads::{cloud_preset, CloudWorkload};

fn main() {
    let mut daemon = Daemon::new(HostConfig { seed: 11, ..Default::default() });

    let fleet = [
        ("kafka", Sla::Bronze, 0.08),
        ("redis", Sla::Gold, 0.06),
        ("nginx", Sla::Silver, 0.08),
        ("bert", Sla::Silver, 0.06),
    ];
    let mut nominal_total = 0u64;
    for (name, sla, scale) in fleet {
        let spec = cloud_preset(name, scale);
        nominal_total += (spec.pages + 2048) * 4096;
        daemon.register(VmRegistration {
            name: name.to_string(),
            frames: spec.pages + 2048,
            vcpus: 1,
            sla,
            workloads: vec![Box::new(CloudWorkload::new(spec))],
            initial_limit_bytes: None,
        });
    }

    // Control plane: after 2s, squeeze the bronze VM (kafka) to 40% —
    // its cold log makes that nearly free. The change applies from a
    // control tick inside the event loop (PR 3).
    let kafka_limit = (cloud_preset("kafka", 0.08).pages * 4096) * 2 / 5;
    daemon.schedule_limit(0, 2 * SEC, Some(kafka_limit), false, false);

    let results = daemon.machine.run();

    println!("== overcommit fleet: 4 VMs, one NVMe swap device ==");
    println!("nominal fleet memory: {}\n", fmt_bytes(nominal_total));
    let mut saved_total = 0.0;
    for r in &results {
        let ops_per_s = r.work_ops as f64 / (r.runtime as f64 / 1e9);
        let saved = 1.0 - r.avg_usage_bytes / r.nominal_bytes as f64;
        saved_total += r.nominal_bytes as f64 * saved;
        println!(
            "{:8} | {:>9.0} ops/s | fault p50 {:>8} p99 {:>8} | avg resident {:>9} | saved {:>4.0}%",
            r.label,
            ops_per_s,
            fmt_ns(r.fault_hist.quantile(0.5)),
            fmt_ns(r.fault_hist.quantile(0.99)),
            fmt_bytes(r.avg_usage_bytes as u64),
            saved * 100.0,
        );
    }
    println!(
        "\nfleet memory saved : {} ({:.0}% of nominal)",
        fmt_bytes(saved_total as u64),
        saved_total / nominal_total as f64 * 100.0
    );

    println!("\ncontrol-plane cold-memory report:");
    let reports: Vec<_> = daemon.report().to_vec();
    for rep in reports {
        println!(
            "  {:8} usage {:>9} cold~{:>9} pf={}",
            daemon.vm_name(rep.vm),
            fmt_bytes(rep.usage_bytes),
            fmt_bytes(rep.cold_estimate_bytes),
            rep.pf_count
        );
    }
    println!(
        "\nshared NVMe: {} ops, {:.2} GB transferred",
        daemon.machine.nvme.ops,
        daemon.machine.nvme.bytes as f64 / 1e9
    );
}
