//! Writing a custom policy against the policy API (paper §4.3).
//!
//! This reimplements the paper's example — the application-aware
//! next-page prefetcher — from *outside* the library, in ~40 lines, and
//! races it against the naive physical-neighbour version on an aged VM
//! to show why introspection matters (§6.6).
//!
//! Run: `cargo run --release --example custom_policy`

use flexswap::config::{HostConfig, MmConfig, VmConfig};
use flexswap::coordinator::{Machine, Mechanism, VmSetup};
use flexswap::mm::{Mm, Policy, PolicyApi, PolicyEvent};
use flexswap::policies::LruReclaimer;
use flexswap::types::{PageSize, MS};
use flexswap::workloads::SeqScan;

/// The paper's §4.3 example policy, written verbatim against the API.
struct AppAwareNextPagePf {
    issued: u64,
}

impl Policy for AppAwareNextPagePf {
    fn name(&self) -> &'static str {
        "app-aware-next-page"
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        let PolicyEvent::PageFault { ctx, .. } = ev else { return };
        // if (!cr3 || !gva) return;  -- fault has no context: skip
        let Some(ctx) = ctx else { return };
        // next_gva = gva + page.size();
        let next_gva_page = ctx.gva / 4096 + api.vm.unit_frames();
        // next_hva = SYS.gva_to_hva(next_gva, cr3);  (may fail: skip)
        let Some(next_hva) = api.gva_to_hva(next_gva_page, ctx.cr3) else {
            return;
        };
        // SYS.prefetch(next_hva);
        api.prefetch(api.unit_of_frame(next_hva));
        self.issued += 1;
    }
}

/// Naive contrast: prefetch the physically next page.
struct PhysNextPagePf;

impl Policy for PhysNextPagePf {
    fn name(&self) -> &'static str {
        "phys-next-page"
    }
    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        if let PolicyEvent::PageFault { unit, .. } = ev {
            if unit + 1 < api.units() {
                api.prefetch(unit + 1);
            }
        }
    }
}

fn run(policy: Option<Box<dyn Policy>>) -> (f64, f64) {
    let pages = 16_000u64;
    let mut m = Machine::new(HostConfig::default());
    let vm_cfg = VmConfig {
        frames: pages + 2048,
        vcpus: 1,
        page_size: PageSize::Small,
        scramble: 1.0, // aged guest: GVA->GPA fully scrambled
        guest_thp_coverage: 1.0,
    };
    let mm_cfg = MmConfig {
        scan_interval: 500 * MS,
        memory_limit: Some(pages * 4096 * 3 / 4),
        ..Default::default()
    };
    let mut mm = Mm::new(
        &mm_cfg,
        vm_cfg.units(),
        vm_cfg.page_size.unit_bytes(),
        &m.host.sw,
        m.host.hw.zero_2m_ns,
    );
    if let Some(p) = policy {
        mm.add_policy(p);
    }
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    m.add_vm(VmSetup {
        vm_cfg,
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: vec![Box::new(SeqScan::new(pages, 5, 300_000))],
        scan_interval: Some(500 * MS),
    });
    let res = m.run();
    let r = &res[0];
    let timely = r.counters.prefetch_timely as f64
        / (r.counters.prefetch_timely + r.counters.faults_major).max(1) as f64;
    (r.runtime as f64 / 1e6, timely * 100.0)
}

fn main() {
    println!("== custom policy: the paper's §4.3 example, via the public API ==");
    let (base, _) = run(None);
    let (gva, gva_t) = run(Some(Box::new(AppAwareNextPagePf { issued: 0 })));
    let (hva, hva_t) = run(Some(Box::new(PhysNextPagePf)));
    println!("no prefetcher        : {base:8.1} ms");
    println!(
        "app-aware (GVA)      : {gva:8.1} ms  ({:+.0}% vs base, {gva_t:.0}% timely)",
        (1.0 - gva / base) * 100.0
    );
    println!(
        "physical-next (HVA)  : {hva:8.1} ms  ({:+.0}% vs base, {hva_t:.0}% timely)",
        (1.0 - hva / base) * 100.0
    );
    println!("\nThe aged guest scrambles GVA->GPA, so only the introspecting");
    println!("policy predicts the next page correctly (paper §3.2 / §6.6).");
}
