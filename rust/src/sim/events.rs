//! Generic deterministic event queue for the DES engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::types::Time;

/// A time-ordered event queue with FIFO tie-breaking (events scheduled
/// earlier pop first at equal timestamps), which keeps simulations
/// deterministic regardless of heap internals.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `ev` at absolute time `at`.
    pub fn push(&mut self, at: Time, ev: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { at, seq, ev }));
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|Reverse(e)| (e.at, e.ev))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Remove every pending event matching `pred` and return them
    /// sorted by `(time, scheduling order)` — the exact order they
    /// would have popped in. Kept events retain their original
    /// sequence numbers, so their relative FIFO tie order is
    /// unchanged (the VM state-migration flip moves one VM's events
    /// to another machine without perturbing the rest).
    pub fn extract_if(&mut self, mut pred: impl FnMut(&E) -> bool) -> Vec<(Time, E)> {
        let drained = std::mem::take(&mut self.heap).into_vec();
        let mut out: Vec<Entry<E>> = Vec::new();
        for Reverse(e) in drained {
            if pred(&e.ev) {
                out.push(e);
            } else {
                self.heap.push(Reverse(e));
            }
        }
        out.sort_by_key(|e| (e.at, e.seq));
        out.into_iter().map(|e| (e.at, e.ev)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordered() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn extract_if_pops_matching_in_order_and_keeps_ties() {
        let mut q = EventQueue::new();
        q.push(10, 1);
        q.push(5, 2);
        q.push(5, 3);
        q.push(7, 4);
        q.push(5, 5);
        let odd = q.extract_if(|&e| e % 2 == 1);
        assert_eq!(odd, vec![(5, 3), (5, 5), (10, 1)]);
        // Kept events pop in the original tie order.
        assert_eq!(q.pop(), Some((5, 2)));
        assert_eq!(q.pop(), Some((7, 4)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn peek_and_len() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push(42, ());
        assert_eq!(q.peek_time(), Some(42));
        assert_eq!(q.len(), 1);
    }
}
