//! Discrete-event simulation core: deterministic RNG and event queue.
//!
//! Everything in the substrate runs on a nanosecond-resolution virtual
//! clock driven by a binary-heap event queue with deterministic FIFO
//! tie-breaking, so every experiment is exactly reproducible from its
//! seed.

pub mod events;
pub mod rng;

pub use events::EventQueue;
pub use rng::{Rng, Zipf};
