//! Deterministic PRNG (SplitMix64 core) + distribution helpers.
//!
//! We carry our own tiny generator instead of the `rand` crate so that
//! simulation results are bit-stable across toolchains and the hot path
//! stays allocation- and indirection-free.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixpoint-ish start; mix the seed once.
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple, rare).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a stream for an independent component (stable per label).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0xA24BAED4963EE407))
    }
}

/// Zipf sampler over [0, n) with exponent `s`, using the rejection-
/// inversion method of Hörmann & Derflinger — O(1) per sample, no table.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_lo: f64,
    h_hi: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0);
        // Keep s away from the 1.0 singularity of the inversion formula.
        let s = if (s - 1.0).abs() < 1e-6 { 1.0 + 1e-6 } else { s };
        let hf = |x: f64| x.powf(1.0 - s) / (1.0 - s);
        Zipf { n, s, h_lo: hf(0.5), h_hi: hf(n as f64 + 0.5) }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let hinv = |x: f64| (x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s));
        loop {
            let u = self.h_lo + rng.f64() * (self.h_hi - self.h_lo);
            let x = hinv(u).clamp(0.5, self.n as f64 + 0.5);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            // Accept with probability pmf(k)/envelope(x).
            if rng.f64() < k.powf(-self.s) / x.powf(-self.s) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut rng = Rng::new(3);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[rng.below(10) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(4);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_skewed_head() {
        let mut rng = Rng::new(5);
        let z = Zipf::new(1000, 1.1);
        let mut head = 0;
        const N: usize = 20_000;
        for _ in 0..N {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // Top-10 of a 1000-element zipf(1.1) carries a large share.
        assert!(head > N / 4, "head share too small: {head}/{N}");
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::new(6);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gauss();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
