//! The coordinator: composes VMs, MMs (or the kernel baseline), the
//! shared storage backend and NVMe device into one discrete-event
//! machine and drives the paper's §4.1 workflows end to end.

pub mod machine;

pub use machine::{Machine, Mechanism, RunResult, VmImage, VmSetup};
