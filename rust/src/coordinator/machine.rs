//! The discrete-event machine: one host running N VMs, each with either
//! the flexswap MM stack (paper system) or the in-kernel Linux swap
//! baseline, all sharing one NVMe device and one storage backend —
//! exactly the paper's deployment shape (§4.1 / Fig 4).


use crate::baseline::{EnhancedReclaim, LinuxSwap};
use crate::config::{ControlConfig, HostConfig, LinuxConfig, MmConfig, VmConfig};
use crate::daemon::{ControlPlane, HostView, Sla, VmReport};
use crate::hw::Nvme;
use crate::introspect::FaultCtx;
use crate::metrics::{ControlStats, Counters, LatencyHist, Series};
use crate::mm::{Mm, WorkOutcome};
use crate::scanner::EptScanner;
use crate::sim::{EventQueue, Rng};
use crate::storage::{
    ContentMix, ContentModel, SwapBackend, SwapTier, TierMetrics, TieredBackend,
};
use crate::types::{Bitmap, Time, UnitId, VmId, FRAME_BYTES, MS, SEC};
use crate::vm::{AccessResult, Vm};
use crate::workloads::{Op, Workload};

/// Swap mechanism attached to a VM.
pub enum Mechanism {
    /// The paper's userspace MM.
    Sys(Box<Mm>),
    /// Linux kernel swap (optionally driven by the §6.4 enhanced
    /// reclaimer).
    Kernel(Box<LinuxSwap>, Option<EnhancedReclaim>),
}

/// Everything needed to add one VM to the machine.
pub struct VmSetup {
    pub vm_cfg: VmConfig,
    pub mech: Mechanism,
    pub workloads: Vec<Box<dyn Workload>>, // one per vCPU
    pub scan_interval: Option<Time>,
}

struct VcpuState {
    workload: Box<dyn Workload>,
    blocked: bool,
    done: bool,
    fault_raised_at: Time,
    ops_done: u64,
    finished_at: Time,
    /// Virtual time of the first *completed* guest access (hit cost
    /// paid, or first fault resolved) — the clone storm's
    /// time-to-first-useful-work probe (PR 10). None until then.
    first_work_at: Option<Time>,
}

struct VmSlot {
    vm: Vm,
    mech: Mechanism,
    vcpus: Vec<VcpuState>,
    /// Host-client (OVS/vhost) access bits for the QEMU-PT scan (§5.4).
    qemu_bits: Bitmap,
    scan_interval: Time,
    proc: usize,
    fault_hist: LatencyHist,
    usage_series: Series,
    pf_series: Series,
    last_pf_count: u64,
    /// Deterministic guest-page-content synthesizer (the backend's
    /// compressed tier works on real bytes).
    content: ContentModel,
    /// Reusable page-image buffer for backend reads/writes.
    scratch: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    VcpuRun { vm: usize, vcpu: usize },
    FaultDeliver { vm: usize },
    WorkerMapDone { vm: usize, worker: usize, unit: UnitId, from_disk: bool },
    WorkerIoRead { vm: usize, worker: usize, unit: UnitId },
    WorkerOutDone { vm: usize, worker: usize, unit: UnitId, wrote: bool },
    ScanTick { vm: usize },
    PolicyTimer { vm: usize },
    PoolRefill { vm: usize },
    Metrics { vm: usize },
    /// Control-plane tick: rebuild reports, arbitrate, apply limits.
    /// `periodic` ticks re-arm themselves; one-shot ticks land exactly
    /// on a scheduled limit change.
    ControlTick { periodic: bool },
    /// Kernel-mode fault resolved: unblock the vCPU.
    KernelResume { vm: usize, vcpu: usize },
    /// Staged (prefetched) unit mapped after a minor fault.
    WorkerStagedDone { vm: usize, worker: usize, unit: UnitId },
}

impl Ev {
    /// VM slot this event targets (None for host-wide events). The
    /// state-migration flip uses this to pull one VM's pending events
    /// out of the donor's queue.
    fn vm_of(&self) -> Option<usize> {
        match *self {
            Ev::VcpuRun { vm, .. }
            | Ev::FaultDeliver { vm }
            | Ev::WorkerMapDone { vm, .. }
            | Ev::WorkerIoRead { vm, .. }
            | Ev::WorkerOutDone { vm, .. }
            | Ev::ScanTick { vm }
            | Ev::PolicyTimer { vm }
            | Ev::PoolRefill { vm }
            | Ev::Metrics { vm }
            | Ev::KernelResume { vm, .. }
            | Ev::WorkerStagedDone { vm, .. } => Some(vm),
            Ev::ControlTick { .. } => None,
        }
    }

    /// The same event retargeted at another slot id (implant remap).
    fn with_vm(mut self, new: usize) -> Ev {
        match &mut self {
            Ev::VcpuRun { vm, .. }
            | Ev::FaultDeliver { vm }
            | Ev::WorkerMapDone { vm, .. }
            | Ev::WorkerIoRead { vm, .. }
            | Ev::WorkerOutDone { vm, .. }
            | Ev::ScanTick { vm }
            | Ev::PolicyTimer { vm }
            | Ev::PoolRefill { vm }
            | Ev::Metrics { vm }
            | Ev::KernelResume { vm, .. }
            | Ev::WorkerStagedDone { vm, .. } => *vm = new,
            Ev::ControlTick { .. } => {}
        }
        self
    }
}

/// A whole VM lifted out of one machine for implantation into another
/// (fleet state migration): the slot — engine/MM and policy state, the
/// guest `Vm` with its page tables and EPT, vCPU/workload positions,
/// metric series — plus every event the donor still had queued for it
/// and its control-plane identity. The swap copies travel separately
/// through the [`SwapBackend`] export/import path; together the two
/// make the hand-off atomic: after [`Machine::extract_vm`] the donor
/// holds nothing of the VM, and after [`Machine::implant_vm`] the
/// target holds all of it.
pub struct VmImage {
    slot: VmSlot,
    /// Pending events at their absolute virtual times (all ≥ the flip
    /// time, because flips happen at fleet ticks that precede every
    /// pending event).
    events: Vec<(Time, Ev)>,
    /// Control-plane identity — name, SLA, and the donor's fault-delta
    /// baseline (carried so the target's first tick reports only
    /// post-flip faults). None when the donor never registered the VM:
    /// it stays unmanaged on the target too.
    control: Option<(String, Sla, u64)>,
}

impl VmImage {
    /// Control-plane name (None for an unmanaged VM).
    pub fn name(&self) -> Option<&str> {
        self.control.as_ref().map(|(n, _, _)| n.as_str())
    }

    /// SLA class (None for an unmanaged VM).
    pub fn sla(&self) -> Option<Sla> {
        self.control.as_ref().map(|&(_, s, _)| s)
    }

    /// Nominal guest size (admission bookkeeping moves with the VM).
    pub fn nominal_bytes(&self) -> u64 {
        self.slot.vm.cfg.bytes()
    }
}

/// Result of a completed run for one VM.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub label: String,
    /// Virtual time at which the last vCPU finished.
    pub runtime: Time,
    pub counters: Counters,
    pub fault_hist: LatencyHist,
    /// (t, resident bytes)
    pub usage_series: Vec<(Time, f64)>,
    /// (t, faults/sec)
    pub pf_series: Vec<(Time, f64)>,
    pub nominal_bytes: u64,
    pub avg_usage_bytes: f64,
    pub guest_minor_faults: u64,
    pub thp_coverage: f64,
    pub scan_cpu_ns: Time,
    pub work_ops: u64,
    /// Virtual time of the VM's first completed guest access (min over
    /// vCPUs; 0 when the VM never did useful work) — the clone storm's
    /// boot-latency probe (PR 10).
    pub first_work_ns: Time,
}

pub struct Machine {
    pub host: HostConfig,
    pub clock: Time,
    rng: Rng,
    events: EventQueue<Ev>,
    /// VM slots by id. `None` marks a slot whose VM was extracted by a
    /// state migration (or reserved for one arriving): ids are never
    /// reused, so queued events and control-plane records stay valid.
    slots: Vec<Option<VmSlot>>,
    pub nvme: Nvme,
    pub backend: Box<dyn SwapBackend>,
    scanner: EptScanner,
    /// vCPU batch size (ops per scheduling quantum).
    batch: u32,
    max_time: Time,
    metrics_interval: Time,
    /// Whether `start()` has seeded the initial events (set once; the
    /// fleet scheduler starts machines explicitly and then steps them).
    started: bool,
    /// Events handled so far (the fleet_scale bench's events/sec
    /// numerator; identical between engines for the same seed).
    pub events_handled: u64,
    /// The in-simulation control plane (None until installed: a
    /// machine without one runs no control ticks at all).
    control: Option<ControlPlane>,
}

impl Machine {
    pub fn new(host: HostConfig) -> Self {
        let rng = Rng::new(host.seed);
        Machine {
            nvme: Nvme::new(&host.hw),
            backend: Box::new(TieredBackend::new(&host.tier, &host.sw)),
            scanner: EptScanner::new(&host.hw),
            host,
            clock: 0,
            rng,
            events: EventQueue::new(),
            slots: vec![],
            batch: 64,
            max_time: 600 * SEC,
            metrics_interval: 20 * MS,
            started: false,
            events_handled: 0,
            control: None,
        }
    }

    pub fn set_max_time(&mut self, t: Time) {
        self.max_time = t;
    }

    /// Install the control plane: the daemon's feedback loop becomes a
    /// scheduled `ControlTick` actor inside this machine's event loop.
    /// The pool stays a shared arena until the first SLA registration
    /// partitions it (a machine that only schedules one-shot limit
    /// changes must behave exactly like the old `plan_limit_change`
    /// path, pool included).
    pub fn install_control(&mut self, cfg: ControlConfig) {
        self.control = Some(ControlPlane::new(cfg));
    }

    pub fn control(&self) -> Option<&ControlPlane> {
        self.control.as_ref()
    }

    pub fn control_mut(&mut self) -> Option<&mut ControlPlane> {
        self.control.as_mut()
    }

    /// Host control-plane gauges (None until a control plane is
    /// installed).
    pub fn control_stats(&self) -> Option<&ControlStats> {
        self.control.as_ref().map(|c| &c.stats)
    }

    /// Register a VM with the control plane (daemon boot handshake):
    /// fleet bookkeeping plus the backend's SLA pool-partition class.
    /// The first registration partitions the compressed pool by the
    /// configured per-SLA split (enforced quotas).
    pub fn register_control_vm(&mut self, vm: usize, name: String, sla: Sla) {
        self.enroll_control_vm(vm, sla);
        self.control.as_mut().unwrap().register(vm, name, sla);
    }

    /// Adopt a VM implanted by a state migration: identical to
    /// [`Machine::register_control_vm`] except the fault-delta baseline
    /// carries over from the donor's control plane.
    pub fn adopt_control_vm(&mut self, vm: usize, name: String, sla: Sla, last_pf: u64) {
        self.enroll_control_vm(vm, sla);
        self.control.as_mut().unwrap().adopt(vm, name, sla, last_pf);
    }

    /// Shared enrollment: SLA pool class, control-plane presence, and
    /// the one-shot pool partitioning at the first managed VM.
    fn enroll_control_vm(&mut self, vm: usize, sla: Sla) {
        self.backend.set_vm_class(vm, sla.class_index() as u8);
        if self.control.is_none() {
            self.install_control(ControlConfig::default());
        }
        let cp = self.control.as_mut().unwrap();
        if cp.vms.is_empty() && self.host.tier.pool_enabled() {
            let cap = self.host.tier.pool_capacity_bytes;
            let quotas: Vec<u64> = cp
                .cfg
                .pool_split_pct
                .iter()
                .map(|&p| cap / 100 * p as u64)
                .collect();
            self.backend.set_class_quotas(&quotas);
        }
    }

    /// Reserve a fresh slot id for a VM arriving by state migration.
    /// The slot stays empty (and harmless) until [`Machine::implant_vm`]
    /// fills it — or forever, if the migration aborts; ids are never
    /// reused, so nothing can alias it.
    pub fn reserve_slot(&mut self) -> usize {
        self.slots.push(None);
        self.slots.len() - 1
    }

    /// Pre-flip enrollment for a reserved slot: assign its SLA pool
    /// class *and* partition the pool if this machine never managed a
    /// VM before — pre-copied pool entries must land in (and be
    /// accounted to) the VM's partition from the very first chunk,
    /// even when the migration target is an empty shard whose pool
    /// would otherwise only be partitioned at the flip's adoption.
    pub fn prepare_adoption(&mut self, vm: usize, sla: Sla) {
        self.enroll_control_vm(vm, sla);
    }

    /// Lift a VM out of this machine (the donor half of a
    /// state-migration flip): removes the slot, pulls every pending
    /// event the VM owns out of the queue, deregisters it from the
    /// control plane (dropping its scheduled/staged limit changes) and
    /// forgets its swap copies. Export the backend entries you still
    /// need *before* calling this. Returns None for an already-empty
    /// slot.
    pub fn extract_vm(&mut self, vm: usize) -> Option<VmImage> {
        let slot = self.slots[vm].take()?;
        let events = self.events.extract_if(|e| e.vm_of() == Some(vm));
        let control = self.control.as_mut().and_then(|cp| cp.deregister(vm));
        self.backend.forget_vm(vm);
        Some(VmImage { slot, events, control })
    }

    /// Implant a migrated VM into the reserved slot (the target half of
    /// the flip). Its pending events are re-queued at their original
    /// virtual times shifted by `stop_ns` — the modeled stop-and-copy
    /// pause — and its per-unit tier map is re-synced from this
    /// machine's backend (imported pool copies may have been demoted to
    /// NVMe on arrival). Import the swap copies *before* calling this.
    pub fn implant_vm(&mut self, slot_id: usize, image: VmImage, stop_ns: Time) {
        assert!(
            self.slots[slot_id].is_none(),
            "implant target slot {slot_id} is occupied"
        );
        assert!(
            self.started,
            "implant requires a started machine: the migrated events are \
             the VM's whole schedule, and a later start() would seed a \
             second one"
        );
        let VmImage { mut slot, events, control } = image;
        if let Mechanism::Sys(mm) = &mut slot.mech {
            mm.core
                .resync_backend_tiers(|u| self.backend.tier_of(slot_id, u));
        }
        self.slots[slot_id] = Some(slot);
        for (t, ev) in events {
            self.events.push(t + stop_ns, ev.with_vm(slot_id));
        }
        // A VM the donor never managed stays unmanaged here too.
        if let Some((name, sla, last_pf)) = control {
            self.adopt_control_vm(slot_id, name, sla, last_pf);
        }
    }

    /// Schedule a one-shot control-plane limit change at virtual time
    /// `at` (the migration of the old external `plan_limit_change`
    /// path: the change now applies from a control tick *inside* the
    /// event loop). Installs a static control plane if none is present.
    pub fn schedule_limit(&mut self, vm: usize, at: Time, bytes: Option<u64>) {
        self.schedule_limit_release(vm, at, bytes, false, false);
    }

    /// Scheduled limit change with release semantics: `boost` opens the
    /// prefetchers' recovery window, `staged` spreads the release over
    /// several control ticks instead of one jump.
    pub fn schedule_limit_release(
        &mut self,
        vm: usize,
        at: Time,
        bytes: Option<u64>,
        boost: bool,
        staged: bool,
    ) {
        if self.control.is_none() {
            self.install_control(ControlConfig::default());
        }
        self.control.as_mut().unwrap().schedule(vm, at, bytes, boost, staged);
    }

    /// Σ resident bytes over every VM on the host (the control plane's
    /// physical-memory accounting input).
    pub fn host_resident_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .map(|s| match &s.mech {
                Mechanism::Sys(mm) => mm.core.usage_bytes(),
                Mechanism::Kernel(k, _) => k.usage_bytes(),
            })
            .sum()
    }

    /// Resident bytes of one VM (0 for an empty/reserved slot) — the
    /// fleet scheduler's stop-and-copy sizing probe.
    pub fn vm_resident_bytes(&self, vm: usize) -> u64 {
        self.slots[vm]
            .as_ref()
            .map(|s| match &s.mech {
                Mechanism::Sys(mm) => mm.core.usage_bytes(),
                Mechanism::Kernel(k, _) => k.usage_bytes(),
            })
            .unwrap_or(0)
    }

    /// Σ(resident + compressed-pool + golden-image) bytes — the
    /// occupancy the budget invariant bounds (fleet-scheduler headroom
    /// probe). Image bytes are the *stored* (dedup'd) footprint, so a
    /// host full of clones is charged for the shared image exactly once
    /// (PR 10).
    pub fn host_occupied_bytes(&self) -> u64 {
        self.host_resident_bytes()
            + self.backend.metrics().pool_bytes
            + self.backend.metrics().image_stored_bytes
    }

    /// Crash demotion of one VM's residency (the host under it died):
    /// every resident unit is unmapped and becomes Swapped, and the
    /// engine's clean-on-disk knowledge is dropped — the backend those
    /// bits referred to died with the host. The slot itself stays
    /// intact for [`Machine::extract_vm`]; in-flight transitions settle
    /// via the conflating pickup after the rebuild. Returns the demoted
    /// bytes (what the VM must refault on its new shard). Kernel-swap
    /// VMs are not fleet-managed and are left untouched.
    pub fn crash_demote_residency(&mut self, vm: usize) -> u64 {
        let Some(slot) = self.slots[vm].as_mut() else { return 0 };
        let Mechanism::Sys(mm) = &mut slot.mech else { return 0 };
        let demoted = mm.core.crash_demote_all();
        for unit in 0..mm.core.states.len() as u64 {
            slot.vm.ept.unmap(unit);
        }
        demoted
    }

    /// Mean fault latency over every VM on the host (ns; 0 before the
    /// first fault) — the fleet scheduler's per-shard health gauge
    /// input, fed into its fault-latency EWMA each fleet tick.
    pub fn host_fault_mean_ns(&self) -> u64 {
        let mut sum = 0.0f64;
        let mut count = 0u64;
        for s in self.slots.iter().flatten() {
            let c = s.fault_hist.count();
            sum += s.fault_hist.mean() * c as f64;
            count += c;
        }
        if count == 0 {
            0
        } else {
            (sum / count as f64) as u64
        }
    }

    /// Rebuild the control plane's per-VM reports in place (reused
    /// buffer, borrowed names — nothing allocated per tick).
    #[allow(clippy::needless_range_loop)]
    fn build_reports(&mut self, advance_pf_baseline: bool) {
        let Some(cp) = self.control.as_mut() else { return };
        cp.begin_reports();
        for idx in 0..cp.vms.len() {
            let (vm, sla) = (cp.vms[idx].vm, cp.vms[idx].sla);
            let slot = self.slots[vm].as_ref().expect("managed VM has a live slot");
            let (usage, pf, wss_est, limit, unit_bytes, allowance) = match &slot.mech {
                Mechanism::Sys(mm) => {
                    let wss_units =
                        mm.core.params.get("dt.wss_units").copied().unwrap_or(0.0);
                    (
                        mm.core.usage_bytes(),
                        mm.core.pf_count,
                        (wss_units as u64) * mm.core.unit_bytes,
                        mm.core.limit_units.map(|l| l * mm.core.unit_bytes),
                        mm.core.unit_bytes,
                        mm.swapper.threads() as u64 * mm.core.unit_bytes,
                    )
                }
                Mechanism::Kernel(k, _) => (
                    k.usage_bytes(),
                    k.counters.faults_major + k.counters.faults_minor,
                    k.usage_bytes(),
                    k.limit_frames.map(|f| f * FRAME_BYTES),
                    FRAME_BYTES,
                    0,
                ),
            };
            // No analytics estimate yet: conservatively treat the whole
            // residency as working set (nothing provably cold).
            let wss = if wss_est == 0 { usage } else { wss_est.min(usage) };
            cp.push_report(
                VmReport {
                    vm,
                    sla,
                    usage_bytes: usage,
                    wss_bytes: wss,
                    cold_estimate_bytes: usage - wss,
                    pf_count: pf,
                    pf_delta: 0, // derived by push_report
                    limit_bytes: limit,
                    unit_bytes,
                    inflight_allowance: allowance,
                },
                idx,
                advance_pf_baseline,
            );
        }
    }

    /// Refresh and expose the control-plane reports (daemon/harness
    /// external view; same reused buffer the control ticks use).
    pub fn control_reports(&mut self) -> &[VmReport] {
        // External refresh: leave the pf_delta baseline untouched so
        // the next control tick still sees the full inter-tick delta.
        self.build_reports(false);
        self.control.as_ref().map_or(&[], |c| c.reports.as_slice())
    }

    /// Add a VM (and its MM / kernel swap) to the host. Returns its id.
    pub fn add_vm(&mut self, setup: VmSetup) -> usize {
        let id = self.slots.len();
        let mut vm = Vm::new(&setup.vm_cfg, &self.host.hw, &self.host.sw, &mut self.rng);
        if let Mechanism::Kernel(k, _) = &setup.mech {
            if k.cfg.thp {
                vm.enable_host_thp();
            }
        }
        // Mirror the MM's admission-time granularity regions into the
        // EPT (PR 8): both sides must agree on what is 2MB-backed
        // before the first fault.
        if let Mechanism::Sys(mm) = &setup.mech {
            for r in 0..mm.core.regions() {
                if mm.core.region_huge(r) {
                    vm.ept.set_region_huge(r);
                }
            }
        }
        // One guest process addressing the whole guest memory (workload
        // generators index GVA pages within it).
        let proc = vm.spawn_process(setup.vm_cfg.frames);
        let units = vm.units() as usize;
        let vcpus = setup
            .workloads
            .into_iter()
            .map(|w| VcpuState {
                workload: w,
                blocked: false,
                done: false,
                fault_raised_at: 0,
                ops_done: 0,
                finished_at: 0,
                first_work_at: None,
            })
            .collect();
        let scan_interval = setup.scan_interval.unwrap_or(SEC);
        let content = ContentModel::new(self.content_seed(id), ContentMix::default());
        self.slots.push(Some(VmSlot {
            vm,
            mech: setup.mech,
            vcpus,
            qemu_bits: Bitmap::new(units),
            scan_interval,
            proc,
            fault_hist: LatencyHist::default(),
            usage_series: Series::default(),
            pf_series: Series::default(),
            last_pf_count: 0,
            content,
            scratch: Vec::new(),
        }));
        id
    }

    /// Per-VM content-model seed (shared by `add_vm`/`set_content_mix`
    /// so re-mixing keeps the VM's deterministic content identity).
    fn content_seed(&self, vm: usize) -> u64 {
        self.host.seed ^ (vm as u64).wrapping_mul(0x9E3779B97F4A7C15)
    }

    /// Override a VM's guest-content mix (tests / tier experiments).
    pub fn set_content_mix(&mut self, vm: usize, mix: ContentMix) {
        let seed = self.content_seed(vm);
        let slot = self.slots[vm].as_mut().expect("vm slot");
        slot.content = ContentModel::new(seed, mix);
    }

    /// Aggregate storage-backend counters (per-tier hits, occupancy,
    /// compression ratio, NVMe request counts).
    pub fn backend_metrics(&self) -> &TierMetrics {
        self.backend.metrics()
    }

    fn schedule_initial(&mut self) {
        for (vmid, slot) in self.slots.iter().enumerate() {
            let Some(slot) = slot else { continue };
            for v in 0..slot.vcpus.len() {
                self.events.push(0, Ev::VcpuRun { vm: vmid, vcpu: v });
            }
            self.events.push(slot.scan_interval, Ev::ScanTick { vm: vmid });
            self.events.push(SEC, Ev::PolicyTimer { vm: vmid });
            self.events.push(10 * MS, Ev::PoolRefill { vm: vmid });
            self.events.push(self.metrics_interval, Ev::Metrics { vm: vmid });
        }
        if let Some(cp) = &self.control {
            // One-shot ticks land scheduled changes exactly on time;
            // the periodic chain runs only when it would do work
            // (budget accounting, arbitration or staged releases).
            let mut one_shots: Vec<Time> = cp.scheduled_times().collect();
            one_shots.sort_unstable();
            one_shots.dedup();
            for at in one_shots {
                self.events.push(at, Ev::ControlTick { periodic: false });
            }
            if cp.needs_periodic() {
                let at = cp.cfg.interval;
                self.events.push(at, Ev::ControlTick { periodic: true });
            }
        }
    }

    fn all_done(&self) -> bool {
        self.slots
            .iter()
            .flatten()
            .all(|s| s.vcpus.iter().all(|v| v.done))
    }

    /// Seed the initial events (idempotent). `run()` calls this; the
    /// fleet scheduler calls it directly before interleaved stepping.
    pub fn start(&mut self) {
        if !self.started {
            self.started = true;
            self.schedule_initial();
        }
    }

    /// Virtual time of this machine's earliest pending event — the
    /// fleet scheduler's merge key for deterministic multi-machine
    /// interleave (ties across machines break on shard index).
    pub fn peek_time(&self) -> Option<Time> {
        self.events.peek_time()
    }

    /// Handle exactly one event. Returns false when the queue is empty
    /// or the next event lies beyond `max_time` (same termination rule
    /// as `run()`: the over-horizon event is consumed, not handled).
    pub fn step_one(&mut self) -> bool {
        let Some((t, ev)) = self.events.pop() else { return false };
        if t > self.max_time {
            return false;
        }
        self.clock = t;
        self.handle(ev);
        self.events_handled += 1;
        true
    }

    /// Drain this machine's queue up to virtual-time `bound`
    /// (**exclusive**): handles every pending event with `t < bound`
    /// and `t <= max_time`, stopping early once all vCPUs are done.
    /// Returns the number of events handled.
    ///
    /// This is the fleet scheduler's epoch primitive. Its semantics
    /// deliberately mirror the sequential `(time, shard index)` merge
    /// loop so the parallel engine is byte-identical to it:
    /// * `t < bound` is strict — a fleet tick scheduled *at* `bound`
    ///   fires before any event at that timestamp (the merge loop fires
    ///   ticks `while next_tick <= t`);
    /// * the bound check peeks and never pops, so an over-horizon event
    ///   survives for the next epoch ([`Machine::step_one`] would
    ///   consume it);
    /// * a machine whose vCPUs all finished abandons its still-re-arming
    ///   periodic events (`ScanTick`/`Metrics`/...), exactly as the
    ///   merge loop's `done()` filter does.
    pub fn run_until(&mut self, bound: Time) -> u64 {
        let mut handled = 0u64;
        while !self.done() {
            match self.events.peek_time() {
                Some(t) if t < bound && t <= self.max_time => {
                    self.step_one();
                    handled += 1;
                }
                _ => break,
            }
        }
        handled
    }

    /// All vCPUs of all VMs finished their workloads.
    pub fn done(&self) -> bool {
        self.all_done()
    }

    /// Finalize and collect per-VM results (after stepping manually).
    pub fn finish(&mut self) -> Vec<RunResult> {
        self.collect_results()
    }

    /// Run to completion (all workloads done) or `max_time`.
    pub fn run(&mut self) -> Vec<RunResult> {
        self.start();
        while self.step_one() {
            if self.all_done() {
                break;
            }
        }
        self.collect_results()
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::VcpuRun { vm, vcpu } => self.vcpu_run(vm, vcpu),
            Ev::FaultDeliver { vm } => self.fault_deliver(vm),
            Ev::WorkerMapDone { vm, worker, unit, from_disk } => {
                self.worker_map_done(vm, worker, unit, from_disk)
            }
            Ev::WorkerIoRead { vm, worker, unit } => {
                self.worker_io_read_done(vm, worker, unit)
            }
            Ev::WorkerOutDone { vm, worker, unit, wrote } => {
                self.worker_out_done(vm, worker, unit, wrote)
            }
            Ev::ScanTick { vm } => self.scan_tick(vm),
            Ev::PolicyTimer { vm } => self.policy_timer(vm),
            Ev::PoolRefill { vm } => self.pool_refill(vm),
            Ev::Metrics { vm } => self.metrics_tick(vm),
            Ev::ControlTick { periodic } => self.control_tick(periodic),
            Ev::KernelResume { vm, vcpu } => {
                let now = self.clock;
                if let Some(slot) = self.slots[vm].as_mut() {
                    slot.vcpus[vcpu].blocked = false;
                    if slot.vcpus[vcpu].first_work_at.is_none() {
                        slot.vcpus[vcpu].first_work_at = Some(now);
                    }
                }
                self.vcpu_run(vm, vcpu);
            }
            Ev::WorkerStagedDone { vm, worker, unit } => {
                let now = self.clock;
                let Some(slot) = self.slots[vm].as_mut() else { return };
                if let Mechanism::Sys(mm) = &mut slot.mech {
                    let (cost, wake) = mm.core_map_staged(&mut slot.vm, unit, now);
                    mm.swapper.release(worker);
                    self.wake_vcpus(vm, wake, now + cost);
                    self.dispatch_workers(vm);
                }
            }
        }
    }

    fn vcpu_run(&mut self, vmid: usize, vcpu: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        if slot.vcpus[vcpu].done || slot.vcpus[vcpu].blocked {
            return;
        }
        let mut elapsed: Time = 0;
        for _ in 0..self.batch {
            let op = slot.vcpus[vcpu].workload.next(&mut self.rng);
            match op {
                Op::Done => {
                    slot.vcpus[vcpu].done = true;
                    slot.vcpus[vcpu].finished_at = now + elapsed;
                    break;
                }
                Op::Think(t) => elapsed += t,
                Op::Access { proc, gva_page, write, ip, cost_ns } => {
                    slot.vcpus[vcpu].ops_done += 1;
                    if proc == usize::MAX {
                        // Host-side (OVS/vhost) DMA access: page-locking
                        // protocol + QEMU page-table A-bit.
                        elapsed += cost_ns;
                        Self::host_dma_access(slot, gva_page, write);
                        continue;
                    }
                    let t_access = now + elapsed;
                    match slot.vm.access(
                        vcpu,
                        slot.proc,
                        gva_page,
                        write,
                        ip,
                        t_access,
                        &mut self.rng,
                    ) {
                        AccessResult::Hit { cost } => {
                            elapsed += cost + cost_ns;
                            let v = &mut slot.vcpus[vcpu];
                            if v.first_work_at.is_none() {
                                v.first_work_at = Some(now + elapsed);
                            }
                        }
                        AccessResult::Fault(fault) => {
                            elapsed += fault.pre_cost;
                            let raised = now + elapsed;
                            slot.vcpus[vcpu].blocked = true;
                            slot.vcpus[vcpu].fault_raised_at = raised;
                            match &mut slot.mech {
                                Mechanism::Sys(mm) => {
                                    // KVM pushes VMCS regs into the ring.
                                    mm.ring.push(FaultCtx {
                                        cr3: fault.cr3,
                                        ip: fault.ip,
                                        gva: fault.gva_page
                                            * crate::types::FRAME_BYTES,
                                        gpa_frame: fault.gpa_frame,
                                    });
                                    let deliver =
                                        mm.uffd.raise(fault, raised, &self.host.sw);
                                    self.events
                                        .push(deliver, Ev::FaultDeliver { vm: vmid });
                                }
                                Mechanism::Kernel(k, _) => {
                                    let r = k.fault(
                                        &mut slot.vm,
                                        fault.gpa_frame,
                                        raised,
                                        &mut self.nvme,
                                        &mut self.rng,
                                    );
                                    let lat = r.resume_at - raised;
                                    if r.major {
                                        slot.fault_hist.record(lat);
                                    }
                                    k.counters.stall_ns += lat;
                                    self.events.push(
                                        r.resume_at,
                                        Ev::KernelResume { vm: vmid, vcpu },
                                    );
                                }
                            }
                            // Stop the batch: the vCPU is stalled.
                            break;
                        }
                    }
                }
            }
        }
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        match &mut slot.mech {
            Mechanism::Sys(mm) => mm.core.counters.work_ns += elapsed,
            Mechanism::Kernel(k, _) => k.counters.work_ns += elapsed,
        }
        if !slot.vcpus[vcpu].blocked && !slot.vcpus[vcpu].done {
            self.events
                .push(now + elapsed.max(1), Ev::VcpuRun { vm: vmid, vcpu });
        }
    }

    fn host_dma_access(slot: &mut VmSlot, gva_page: u64, _write: bool) {
        // OVS path: lock the page, touch it (forcing swap-in would go
        // through a fault; for simplicity host touches hit resident pages
        // or are dropped), record in the QEMU-side bitmap, unlock.
        let Some(frame) = slot.vm.processes[slot.proc].pt.walk(gva_page) else {
            return;
        };
        let unit = frame as u64 / slot.vm.unit_frames();
        if let Mechanism::Sys(mm) = &mut slot.mech {
            // Inside a 2MB granularity region the base unit carries the
            // lock and the access bit (canonical-state invariant).
            let unit = mm.core.canonical_unit(unit);
            mm.core.locks.lock(unit);
            slot.qemu_bits.set(unit as usize);
            mm.core.locks.unlock(unit);
        } else {
            slot.qemu_bits.set(unit as usize);
        }
    }

    fn fault_deliver(&mut self, vmid: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        let Mechanism::Sys(mm) = &mut slot.mech else { return };
        while let Some(ev) = mm.uffd.poll(now) {
            mm.on_fault(&slot.vm, &ev, now);
        }
        self.dispatch_workers(vmid);
    }

    /// Hand queued work to idle swapper workers (paper §4.1 step 7-9).
    /// Swap I/O goes through the [`SwapBackend`] trait: reads check the
    /// compressed pool first (no NVMe on a hit), writes carry the
    /// policy's tier hint, and watermark writebacks reported in the
    /// receipt update each MM's tier map.
    fn dispatch_workers(&mut self, vmid: usize) {
        let now = self.clock;
        // Tier-map updates for *other* VMs whose pool entries a
        // writeback drained (applied after the current slot borrow ends).
        let mut cross_vm_writeback: Vec<(VmId, UnitId)> = Vec::new();
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        let Mechanism::Sys(mm) = &mut slot.mech else { return };
        while let Some(worker) = mm.swapper.claim() {
            match mm.pick_work(now) {
                None => {
                    mm.swapper.release(worker);
                    mm.swapper.jobs_done -= 1; // claim/release without job
                    break;
                }
                Some(WorkOutcome::MapZero { unit, cost }) => {
                    self.events.push(
                        now + cost,
                        Ev::WorkerMapDone { vm: vmid, worker, unit, from_disk: false },
                    );
                }
                Some(WorkOutcome::MapStaged { unit, cost }) => {
                    self.events.push(
                        now + cost,
                        Ev::WorkerStagedDone { vm: vmid, worker, unit },
                    );
                }
                Some(WorkOutcome::SwapIn { unit, bytes }) => {
                    let r = self.backend.read(
                        vmid,
                        unit,
                        bytes,
                        &mut slot.scratch,
                        now + self.host.sw.queue_handoff_ns,
                        &mut self.nvme,
                        &mut self.rng,
                    );
                    if r.tier == SwapTier::Pool {
                        mm.core.counters.swapin_pool_hits += 1;
                    } else if r.tier == SwapTier::Remote {
                        mm.core.counters.swapin_remote_hits += 1;
                    }
                    self.events.push(
                        r.completes_at,
                        Ev::WorkerIoRead { vm: vmid, worker, unit },
                    );
                }
                Some(WorkOutcome::SwapOutWrite { unit, bytes, pre_cost, hint }) => {
                    mm.unmap_for_swapout(&mut slot.vm, unit);
                    if self.host.tier.pool_enabled() {
                        slot.content.fill(unit, bytes, &mut slot.scratch);
                    } else if slot.scratch.len() != bytes as usize {
                        // Flat mode never reads content back (PR 1
                        // behavior): skip synthesis, keep an all-zero
                        // page of the right size (stores as a marker,
                        // no bytes retained).
                        slot.scratch.clear();
                        slot.scratch.resize(bytes as usize, 0);
                    }
                    let r = self.backend.write(
                        vmid,
                        unit,
                        &slot.scratch,
                        hint,
                        now + pre_cost,
                        &mut self.nvme,
                        &mut self.rng,
                    );
                    if r.tier == SwapTier::Pool {
                        mm.core.counters.swapout_pool_stores += 1;
                    }
                    mm.core.set_backend_tier(unit, Some(r.tier));
                    for (wvm, wunit) in r.writeback {
                        if wvm == vmid {
                            mm.core.set_backend_tier(wunit, Some(SwapTier::Nvme));
                        } else {
                            cross_vm_writeback.push((wvm, wunit));
                        }
                    }
                    self.events.push(
                        r.completes_at + self.host.sw.punch_hole_ns,
                        Ev::WorkerOutDone { vm: vmid, worker, unit, wrote: true },
                    );
                }
                Some(WorkOutcome::Drop { unit, cost }) => {
                    // The elision was decided from `clean_on_disk`, which
                    // can be stale: if the guest dirtied the unit since
                    // its swap-in, the backend copy is invalid and the
                    // content must be written after all.
                    let was_dirty = slot.vm.ept.dirty(unit);
                    mm.unmap_for_swapout(&mut slot.vm, unit);
                    if was_dirty {
                        let bytes = mm.core.unit_bytes * mm.core.span_units(unit);
                        if self.host.tier.pool_enabled() {
                            slot.content.fill(unit, bytes, &mut slot.scratch);
                        } else if slot.scratch.len() != bytes as usize {
                            slot.scratch.clear();
                            slot.scratch.resize(bytes as usize, 0);
                        }
                        let r = self.backend.write(
                            vmid,
                            unit,
                            &slot.scratch,
                            crate::storage::TierHint::Auto,
                            now + cost,
                            &mut self.nvme,
                            &mut self.rng,
                        );
                        if r.tier == SwapTier::Pool {
                            mm.core.counters.swapout_pool_stores += 1;
                        }
                        mm.core.set_backend_tier(unit, Some(r.tier));
                        for (wvm, wunit) in r.writeback {
                            if wvm == vmid {
                                mm.core.set_backend_tier(wunit, Some(SwapTier::Nvme));
                            } else {
                                cross_vm_writeback.push((wvm, wunit));
                            }
                        }
                        self.events.push(
                            r.completes_at + self.host.sw.punch_hole_ns,
                            Ev::WorkerOutDone { vm: vmid, worker, unit, wrote: true },
                        );
                    } else {
                        self.events.push(
                            now + cost,
                            Ev::WorkerOutDone { vm: vmid, worker, unit, wrote: false },
                        );
                    }
                }
            }
        }
        for (wvm, wunit) in cross_vm_writeback {
            if let Some(s) = self.slots[wvm].as_mut() {
                if let Mechanism::Sys(other) = &mut s.mech {
                    other.core.set_backend_tier(wunit, Some(SwapTier::Nvme));
                }
            }
        }
    }

    fn wake_vcpus(&mut self, vmid: usize, wake: Vec<usize>, at: Time) {
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        for v in wake {
            if v >= slot.vcpus.len() {
                continue;
            }
            slot.vcpus[v].blocked = false;
            // The faulted access op was consumed before the block: its
            // completion (now) is the vCPU's first useful work.
            if slot.vcpus[v].first_work_at.is_none() {
                slot.vcpus[v].first_work_at = Some(at);
            }
            let stall = at.saturating_sub(slot.vcpus[v].fault_raised_at);
            slot.fault_hist.record(stall);
            if let Mechanism::Sys(mm) = &mut slot.mech {
                mm.core.counters.stall_ns += stall;
            }
            self.events.push(at, Ev::VcpuRun { vm: vmid, vcpu: v });
        }
    }

    fn worker_map_done(&mut self, vmid: usize, worker: usize, unit: UnitId, from_disk: bool) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        let Mechanism::Sys(mm) = &mut slot.mech else { return };
        let (cost, wake) = mm.finish_swapin(&mut slot.vm, unit, from_disk, now);
        mm.swapper.release(worker);
        self.wake_vcpus(vmid, wake, now + cost);
        self.dispatch_workers(vmid);
    }

    fn worker_io_read_done(&mut self, vmid: usize, worker: usize, unit: UnitId) {
        self.worker_map_done(vmid, worker, unit, true);
    }

    fn worker_out_done(&mut self, vmid: usize, worker: usize, unit: UnitId, wrote: bool) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        let Mechanism::Sys(mm) = &mut slot.mech else { return };
        mm.finish_swapout(&mut slot.vm, unit, wrote, now);
        mm.swapper.release(worker);
        self.dispatch_workers(vmid);
    }

    fn scan_tick(&mut self, vmid: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        // Borrow the host-client bitmap in place and word-clear it after
        // the scan — no per-tick Bitmap allocation.
        let out = self.scanner.scan(&mut slot.vm, Some(&slot.qemu_bits), now);
        slot.qemu_bits.zero();
        match &mut slot.mech {
            Mechanism::Sys(mm) => {
                mm.core.counters.scan_cpu_ns += out.cpu_ns;
                mm.on_scan(&slot.vm, &out.bitmap, now);
                // Units dirtied since their swap-in have a stale backend
                // copy: drop the clean-elision flag and free the dead
                // pool/NVMe copy so it neither occupies pool capacity
                // nor gets written back as garbage I/O.
                for u in out.bitmap.iter_ones() {
                    let uu = u as UnitId;
                    if slot.vm.ept.dirty(uu)
                        && mm.core.states[u] == crate::types::UnitState::Resident
                    {
                        mm.note_dirty(uu);
                        self.backend.discard(vmid, uu);
                        mm.core.set_backend_tier(uu, None);
                        // One reap per dirtying: clean_on_disk is now
                        // cleared, so the dirty bit has done its job.
                        slot.vm.ept.clear_dirty(uu);
                    }
                }
                // Apply policy-requested granularity changes (PR 8).
                // The engine validates; the EPT mirror and the stale
                // backend receipts move in the same step, so no fault
                // can observe a half-applied split/collapse.
                let (splits, collapses) = mm.drain_region_ops();
                for r in splits {
                    slot.vm.ept.split_region(r);
                    // The 2MB image can't serve per-4k reads.
                    self.backend.discard(vmid, mm.core.region_base(r));
                }
                for r in collapses {
                    slot.vm.ept.set_region_huge(r);
                    // Per-4k copies can't back the 2MB unit.
                    let base = mm.core.region_base(r);
                    for u in base..base + mm.core.region_span(r) {
                        self.backend.discard(vmid, u);
                    }
                }
                // Forward a policy-requested pool-admission retune
                // (PR 8 satellite: histogram-driven admission).
                if let Some(pct) = mm.take_pool_admission() {
                    self.backend.set_pool_admission(pct);
                }
                // Policies may have changed the scan cadence (SYS-Agg).
                if let Some(req) = mm.core.requested_scan_interval.take() {
                    slot.scan_interval = req;
                }
            }
            Mechanism::Kernel(k, enhanced) => {
                k.counters.scan_cpu_ns += out.cpu_ns;
                // Young-page feedback to the kernel LRU.
                for u in out.bitmap.iter_ones() {
                    k.touch(u as u64, now);
                }
                if let Some(e) = enhanced {
                    e.on_scan(k, &out.bitmap, now);
                    k.kswapd_tick(&mut slot.vm, now, &mut self.nvme);
                }
            }
        }
        let interval = slot.scan_interval;
        self.events.push(now + interval, Ev::ScanTick { vm: vmid });
        self.dispatch_workers(vmid);
    }

    fn policy_timer(&mut self, vmid: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        if let Mechanism::Sys(mm) = &mut slot.mech {
            mm.on_timer(&slot.vm, now);
            if let Some(req) = mm.core.requested_scan_interval.take() {
                slot.scan_interval = req;
                self.events.push(now + req, Ev::ScanTick { vm: vmid });
            }
        }
        self.events.push(now + SEC, Ev::PolicyTimer { vm: vmid });
        self.dispatch_workers(vmid);
    }

    fn pool_refill(&mut self, vmid: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        if let Mechanism::Sys(mm) = &mut slot.mech {
            mm.zero_pool.refill(2);
        }
        self.events.push(now + 10 * MS, Ev::PoolRefill { vm: vmid });
    }

    fn metrics_tick(&mut self, vmid: usize) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        let (usage, pf) = match &slot.mech {
            Mechanism::Sys(mm) => (mm.core.usage_bytes(), mm.core.pf_count),
            Mechanism::Kernel(k, _) => {
                (k.usage_bytes(), k.counters.faults_major + k.counters.faults_minor)
            }
        };
        slot.usage_series.push(now, usage as f64);
        let dpf = pf - slot.last_pf_count;
        slot.last_pf_count = pf;
        slot.pf_series.push(
            now,
            dpf as f64 / (self.metrics_interval as f64 / 1e9),
        );
        self.events
            .push(now + self.metrics_interval, Ev::Metrics { vm: vmid });
    }

    /// One control tick (paper §4.1: the daemon's feedback loop, now an
    /// event inside the simulation): rebuild reports, snapshot host
    /// accounting, collect scheduled/staged/arbitrated limit actions
    /// and apply them.
    fn control_tick(&mut self, periodic: bool) {
        let now = self.clock;
        if self.control.is_none() {
            return;
        }
        self.build_reports(true);
        let resident = self.host_resident_bytes();
        let pool_bytes = self.backend.metrics().pool_bytes;
        let pool_by_class = [
            self.backend.class_pool_bytes(0),
            self.backend.class_pool_bytes(1),
            self.backend.class_pool_bytes(2),
        ];
        let cp = self.control.as_mut().unwrap();
        let budget = cp.cfg.host_budget_bytes;
        let host = HostView {
            // The arbiter divides the audited budget minus any
            // outbound migration lease: the squeeze is what frees the
            // leased memory for hand-over. Gauges still audit against
            // the full budget (`stats.budget_bytes`).
            budget_bytes: cp.arbitration_budget().unwrap_or(0),
            resident_bytes: resident,
            pool_bytes,
            // With a budget set, the whole pool capacity is reserved
            // off the top so pool growth between ticks cannot break
            // the budget invariant.
            pool_reserved_bytes: if budget.is_some() {
                self.host.tier.pool_capacity_bytes
            } else {
                0
            },
        };
        let boost_window = cp.cfg.recovery_boost_window;
        let interval = cp.cfg.interval;
        let mut actions = std::mem::take(&mut cp.actions);
        actions.clear();
        cp.collect_actions(now, periodic, host, pool_by_class, &mut actions);
        for a in &actions {
            self.apply_limit(a.vm, a.bytes, if a.boost { boost_window } else { 0 });
        }
        let cp = self.control.as_mut().unwrap();
        cp.actions = actions;
        if periodic {
            self.events.push(now + interval, Ev::ControlTick { periodic: true });
        }
    }

    /// Apply one limit change to a VM's mechanism. `boost_window > 0`
    /// opens the prefetchers' recovery-mode window on a release.
    fn apply_limit(&mut self, vmid: usize, bytes: Option<u64>, boost_window: Time) {
        let now = self.clock;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        match &mut slot.mech {
            Mechanism::Sys(mm) => {
                mm.set_memory_limit_with_boost(&slot.vm, bytes, now, boost_window)
            }
            Mechanism::Kernel(k, _) => {
                k.set_limit(bytes);
                k.kswapd_tick(&mut slot.vm, now, &mut self.nvme);
            }
        }
        self.dispatch_workers(vmid);
    }

    fn collect_results(&mut self) -> Vec<RunResult> {
        let clock = self.clock;
        // Final usage sample so short runs still get a sane average.
        // Slots emptied by a state migration produce no row here — the
        // VM's whole history (counters, series, histogram) moved with
        // it and is reported by the machine that owns it at the end.
        for slot in self.slots.iter_mut().flatten() {
            let usage = match &slot.mech {
                Mechanism::Sys(mm) => mm.core.usage_bytes(),
                Mechanism::Kernel(k, _) => k.usage_bytes(),
            };
            slot.usage_series.push(clock.max(1), usage as f64);
        }
        self.slots
            .iter_mut()
            .flatten()
            .map(|slot| {
                let (counters, tlb) = match &slot.mech {
                    Mechanism::Sys(mm) => (mm.core.counters.clone(), slot.vm.tlb_stats()),
                    Mechanism::Kernel(k, _) => (k.counters.clone(), slot.vm.tlb_stats()),
                };
                let mut counters = counters;
                counters.tlb_hits = tlb.0;
                counters.tlb_misses = tlb.1;
                let runtime = slot
                    .vcpus
                    .iter()
                    .map(|v| if v.done { v.finished_at } else { clock })
                    .max()
                    .unwrap_or(clock);
                let thp = match &slot.mech {
                    Mechanism::Kernel(k, _) => k.thp_coverage(),
                    Mechanism::Sys(_) => 1.0,
                };
                RunResult {
                    label: slot
                        .vcpus
                        .first()
                        .map(|v| v.workload.label().to_string())
                        .unwrap_or_default(),
                    runtime,
                    counters: counters.clone(),
                    fault_hist: slot.fault_hist.clone(),
                    usage_series: slot.usage_series.points.clone(),
                    pf_series: slot.pf_series.downsample(512),
                    nominal_bytes: slot.vm.cfg.bytes(),
                    avg_usage_bytes: slot.usage_series.time_weighted_mean(),
                    guest_minor_faults: slot.vm.guest_minor_faults,
                    thp_coverage: thp,
                    scan_cpu_ns: counters.scan_cpu_ns,
                    work_ops: slot.vcpus.iter().map(|v| v.ops_done).sum(),
                    first_work_ns: slot
                        .vcpus
                        .iter()
                        .filter_map(|v| v.first_work_at)
                        .min()
                        .unwrap_or(0),
                }
            })
            .collect()
    }

    /// Warm-start helper: make gva pages [0, gva_pages) resident and
    /// mapped (guest mapping + EPT leaf + MM/kernel accounting).
    pub fn prime_resident(&mut self, vmid: usize, gva_pages: u64) {
        let slot = self.slots[vmid].as_mut().expect("vm slot");
        let uf = slot.vm.unit_frames();
        for g in 0..gva_pages {
            let Some(frame) = slot.vm.ensure_mapped(slot.proc, g) else { continue };
            let unit = frame as u64 / uf;
            slot.vm.ept.map(unit);
            match &mut slot.mech {
                Mechanism::Sys(mm) => {
                    let cu = mm.core.canonical_unit(unit);
                    let ui = cu as usize;
                    if mm.core.states[ui] != crate::types::UnitState::Resident {
                        mm.core.states[ui] = crate::types::UnitState::Resident;
                        mm.core.usage_units += mm.core.span_units(cu);
                        // Register with the reclaimer's recency structure
                        // at time 0 (coldest, ascending-unit tie order).
                        mm.note_touch(cu, 0);
                    }
                }
                Mechanism::Kernel(k, _) => {
                    let fi = frame as usize;
                    if k.states[fi] != crate::types::UnitState::Resident {
                        k.states[fi] = crate::types::UnitState::Resident;
                        k.usage_frames += 1;
                    }
                }
            }
        }
    }

    /// Warm-start helper: make gva pages [lo, hi) swapped out (content
    /// on the backing store, not mapped).
    pub fn prime_swapped(&mut self, vmid: usize, lo: u64, hi: u64) {
        let slot = self.slots[vmid].as_mut().expect("vm slot");
        let uf = slot.vm.unit_frames();
        for g in lo..hi {
            let Some(frame) = slot.vm.ensure_mapped(slot.proc, g) else { continue };
            let unit = frame as u64 / uf;
            slot.vm.ept.unmap(unit);
            match &mut slot.mech {
                Mechanism::Sys(mm) => {
                    let cu = mm.core.canonical_unit(unit);
                    let ui = cu as usize;
                    if mm.core.states[ui] == crate::types::UnitState::Resident {
                        mm.core.usage_units -= mm.core.span_units(cu);
                    }
                    mm.core.states[ui] = crate::types::UnitState::Swapped;
                }
                Mechanism::Kernel(k, _) => {
                    let fi = frame as usize;
                    if k.states[fi] == crate::types::UnitState::Resident {
                        k.usage_frames -= 1;
                    }
                    k.states[fi] = crate::types::UnitState::Swapped;
                }
            }
        }
    }

    /// Schedule a late-added VM's initial events (clone admission,
    /// PR 10): the machine is already started, so `schedule_initial`
    /// never saw this slot. Mirrors [`Machine::schedule_initial`] with
    /// every cadence anchored at `at` instead of 0 — admission happens
    /// at the fleet-tick barrier, which may sit ahead of an idle
    /// shard's clock.
    pub fn activate_vm(&mut self, vmid: usize, at: Time) {
        assert!(
            self.started,
            "activate_vm requires a started machine: before start(), \
             schedule_initial seeds every slot itself"
        );
        let now = self.clock.max(at);
        let slot = self.slots[vmid].as_ref().expect("vm slot");
        let (vcpus, scan) = (slot.vcpus.len(), slot.scan_interval);
        for v in 0..vcpus {
            self.events.push(now, Ev::VcpuRun { vm: vmid, vcpu: v });
        }
        self.events.push(now + scan, Ev::ScanTick { vm: vmid });
        self.events.push(now + SEC, Ev::PolicyTimer { vm: vmid });
        self.events.push(now + 10 * MS, Ev::PoolRefill { vm: vmid });
        self.events
            .push(now + self.metrics_interval, Ev::Metrics { vm: vmid });
    }

    /// Install the shared golden image on this host's backend
    /// (idempotent per image id): synthesize `units` deterministic page
    /// images from `image_seed` and hand them to the backend's
    /// content-addressed image store. A flat (paper) backend ignores
    /// the install, so this is a no-op there.
    pub fn ensure_golden_image(
        &mut self,
        image: u32,
        image_seed: u64,
        units: u64,
        unit_bytes: u64,
    ) {
        if self.backend.image_units(image) >= units {
            return;
        }
        let content = ContentModel::new(image_seed, ContentMix::default());
        let mut buf = Vec::new();
        for u in 0..units {
            content.fill(u, unit_bytes, &mut buf);
            self.backend.install_image_unit(image, u, &buf);
        }
    }

    /// Wire a freshly added (not yet activated) VM up as a clone of a
    /// golden image (PR 10): the whole guest is swapped out with zero
    /// resident memory, its on-demand faults pull units from the shared
    /// image, the tier map reflects the image's pool-cost residency,
    /// and `LinearPf::boot_stream` streams `depth` units ahead of every
    /// boot fault while the `boost_window` recovery window is open.
    pub fn attach_clone(
        &mut self,
        vmid: usize,
        image: u32,
        depth: u64,
        boost_window: Time,
        at: Time,
    ) {
        use crate::policies::LinearPf;
        let now = self.clock.max(at);
        self.backend.attach_image(vmid, image);
        let pages = self.slots[vmid].as_ref().expect("vm slot").vm.cfg.frames;
        self.prime_swapped(vmid, 0, pages);
        self.resync_vm_tiers(vmid);
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        if let Mechanism::Sys(mm) = &mut slot.mech {
            mm.add_policy(Box::new(LinearPf::boot_stream(crate::policies::PfMode::Hva, depth)));
            mm.core.recovery_until = now + boost_window;
        }
    }

    /// Re-sync one VM's per-unit tier map from this machine's backend
    /// (image attach/detach and crash re-attachment change what a read
    /// would hit without going through the receipt path).
    pub fn resync_vm_tiers(&mut self, vmid: usize) {
        let backend = &self.backend;
        let Some(slot) = self.slots[vmid].as_mut() else { return };
        if let Mechanism::Sys(mm) = &mut slot.mech {
            mm.core
                .resync_backend_tiers(|u| backend.tier_of(vmid, u));
        }
    }

    /// Wire a freshly added VM up as a *cold boot* (the clone storm's
    /// baseline arm): the whole guest is swapped out with zero resident
    /// memory and **no** backing entries, so every boot fault pays the
    /// cold NVMe zero-fill path.
    pub fn prime_cold_boot(&mut self, vmid: usize) {
        let pages = self.slots[vmid].as_ref().expect("vm slot").vm.cfg.frames;
        self.prime_swapped(vmid, 0, pages);
    }

    /// Direct access to a VM's MM (tests / harness; None for kernel
    /// VMs and for slots emptied by a state migration).
    pub fn mm(&self, vm: usize) -> Option<&Mm> {
        match &self.slots[vm].as_ref()?.mech {
            Mechanism::Sys(mm) => Some(mm),
            _ => None,
        }
    }
    pub fn mm_mut(&mut self, vm: usize) -> Option<&mut Mm> {
        match &mut self.slots[vm].as_mut()?.mech {
            Mechanism::Sys(mm) => Some(mm),
            _ => None,
        }
    }
    pub fn vm_ref(&self, vm: usize) -> &Vm {
        &self.slots[vm].as_ref().expect("vm slot").vm
    }
}

/// Convenience builders used by the harness and examples.
impl Machine {
    /// Standard flexswap VM: dt-reclaimer + LRU limit reclaimer.
    pub fn sys_vm(
        &mut self,
        vm_cfg: VmConfig,
        mm_cfg: &MmConfig,
        workloads: Vec<Box<dyn Workload>>,
    ) -> usize {
        use crate::policies::{DtReclaimer, LruReclaimer, NativeAnalytics};
        let units = vm_cfg.units();
        let unit_bytes = vm_cfg.page_size.unit_bytes();
        let mut mm = Mm::new(mm_cfg, units, unit_bytes, &self.host.sw, self.host.hw.zero_2m_ns);
        let backend: Box<dyn crate::policies::ColdAnalytics> = if mm_cfg.use_xla {
            match crate::runtime::XlaAnalytics::from_artifacts("artifacts") {
                Ok(x) => Box::new(x),
                Err(e) => {
                    eprintln!("xla analytics unavailable ({e}); using native");
                    Box::new(NativeAnalytics::new())
                }
            }
        } else {
            Box::new(NativeAnalytics::new())
        };
        mm.add_policy(Box::new(
            DtReclaimer::new(backend, mm_cfg.history, mm_cfg.target_promotion_rate)
                .with_adaptive_admission(mm_cfg.adaptive_pool_admission),
        ));
        mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
        self.add_vm(VmSetup {
            vm_cfg,
            mech: Mechanism::Sys(Box::new(mm)),
            workloads,
            scan_interval: Some(mm_cfg.scan_interval),
        })
    }

    /// Linux-swap baseline VM.
    pub fn kernel_vm(
        &mut self,
        vm_cfg: VmConfig,
        linux: &LinuxConfig,
        workloads: Vec<Box<dyn Workload>>,
        enhanced: Option<EnhancedReclaim>,
        scan_interval: Time,
    ) -> usize {
        let k = LinuxSwap::new(linux, vm_cfg.frames, &self.host.sw);
        self.add_vm(VmSetup {
            vm_cfg,
            mech: Mechanism::Kernel(Box::new(k), enhanced),
            workloads,
            scan_interval: Some(scan_interval),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::PageSize;
    use crate::workloads::UniformRandom;

    fn small_vm_cfg(frames: u64, mode: PageSize) -> VmConfig {
        VmConfig {
            frames,
            vcpus: 1,
            page_size: mode,
            scramble: 0.5,
            guest_thp_coverage: 1.0,
        }
    }

    #[test]
    fn sys_vm_runs_to_completion() {
        let mut m = Machine::new(HostConfig::default());
        let cfg = small_vm_cfg(4096, PageSize::Small);
        let mm_cfg = MmConfig::default();
        m.sys_vm(
            cfg,
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, 2048, 50_000))],
        );
        let res = m.run();
        assert_eq!(res.len(), 1);
        let r = &res[0];
        assert!(r.runtime > 0);
        assert_eq!(r.work_ops, 50_000);
        // All first touches fault through the MM.
        assert!(r.counters.faults_minor > 1000, "{:?}", r.counters);
    }

    #[test]
    fn kernel_vm_runs_to_completion() {
        let mut m = Machine::new(HostConfig::default());
        let cfg = small_vm_cfg(4096, PageSize::Small);
        m.kernel_vm(
            cfg,
            &LinuxConfig::default(),
            vec![Box::new(UniformRandom::new(0, 2048, 50_000))],
            None,
            SEC,
        );
        let res = m.run();
        assert_eq!(res[0].work_ops, 50_000);
        assert_eq!(res[0].thp_coverage, 1.0); // nothing swapped
    }

    #[test]
    fn memory_limit_triggers_swap_traffic() {
        let mut m = Machine::new(HostConfig::default());
        let cfg = small_vm_cfg(8192, PageSize::Small);
        let mm_cfg = MmConfig {
            memory_limit: Some(1024 * 4096), // 1/4 of the working set
            scan_interval: 50 * MS,
            ..Default::default()
        };
        m.sys_vm(
            cfg,
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, 4096, 100_000))],
        );
        let res = m.run();
        let c = &res[0].counters;
        assert!(c.swapout_ops > 100, "swapouts {}", c.swapout_ops);
        assert!(c.faults_major > 100, "majors {}", c.faults_major);
        // Usage must respect the limit (within one in-flight unit).
        let mm = m.mm(0).unwrap();
        assert!(mm.core.usage_units <= 1024 + mm.swapper.threads() as u64);
    }

    #[test]
    fn tiered_backend_absorbs_compressible_reclaim() {
        let run = |host: HostConfig| {
            let mut m = Machine::new(host);
            let cfg = small_vm_cfg(8192, PageSize::Small);
            let mm_cfg = MmConfig {
                memory_limit: Some(1024 * 4096),
                scan_interval: 50 * MS,
                ..Default::default()
            };
            m.sys_vm(
                cfg,
                &mm_cfg,
                vec![Box::new(UniformRandom::new(0, 4096, 100_000))],
            );
            let res = m.run();
            let c = res[0].counters.clone();
            let bm = m.backend_metrics().clone();
            (c, bm)
        };
        let (c, bm) = run(HostConfig::default());
        // The pool absorbed writes and served fault hits without I/O.
        assert!(c.swapout_pool_stores > 0, "{bm:?}");
        assert!(c.swapin_pool_hits > 0, "{bm:?}");
        assert!(bm.pool_stores > 0 && bm.pool_hits > 0);
        assert!(bm.compression_ratio() > 1.0);
        // Same run against the paper's flat backend: every request is
        // NVMe, and it issues strictly more of them.
        let (cf, bf) = run(HostConfig::paper());
        assert_eq!(cf.swapout_pool_stores + cf.swapin_pool_hits, 0);
        assert_eq!(bf.pool_stores, 0);
        assert!(
            bm.nvme_io_reqs() < bf.nvme_io_reqs(),
            "tiered {} vs flat {}",
            bm.nvme_io_reqs(),
            bf.nvme_io_reqs()
        );
    }

    /// A VM lifted out of one machine mid-run and implanted into
    /// another finishes its workload there, with its swap copies moved
    /// through the backend export/import path and the donor left empty.
    #[test]
    fn extract_implant_moves_a_running_vm_between_machines() {
        let mut donor = Machine::new(HostConfig { seed: 11, ..Default::default() });
        let cfg = small_vm_cfg(4096, PageSize::Small);
        let mm_cfg = MmConfig {
            memory_limit: Some(512 * 4096), // force swap traffic
            scan_interval: 50 * MS,
            ..Default::default()
        };
        let ops = 60_000u64;
        let vmid = donor.sys_vm(
            cfg,
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, 2048, ops))],
        );
        donor.register_control_vm(vmid, "mover".into(), crate::daemon::Sla::Bronze);

        // Run the donor partway: plenty of swapped-out state exists.
        donor.start();
        for _ in 0..200_000 {
            if !donor.step_one() {
                break;
            }
            if donor.mm(vmid).is_some_and(|m| m.core.counters.swapout_ops > 50) {
                break;
            }
        }
        let flip_at = donor.peek_time().expect("donor still has events");
        assert!(
            donor.mm(vmid).unwrap().core.counters.swapout_ops > 0,
            "scenario never swapped"
        );

        // Move the swap copies, then the VM itself. The target is
        // started (empty) first, exactly like a fleet shard: implanted
        // events are the VM's only schedule — never double-seeded.
        let mut target = Machine::new(HostConfig { seed: 12, ..Default::default() });
        target.start();
        let new_id = target.reserve_slot();
        for s in donor.backend.list_units(vmid) {
            let u = donor.backend.export_unit(vmid, s.unit).unwrap();
            target.backend.import_unit(new_id, u);
        }
        let done_before = donor.mm(vmid).unwrap().stats().counters;
        let image = donor.extract_vm(vmid).expect("vm extractable");
        assert_eq!(image.name(), Some("mover"));
        assert!(donor.backend.list_units(vmid).is_empty(), "donor kept copies");
        assert!(donor.mm(vmid).is_none(), "donor kept the slot");
        assert!(donor.control().unwrap().vms.is_empty(), "donor kept the record");
        assert!(donor.peek_time().is_none(), "donor kept the VM's events");

        let stop_ns = 500_000;
        target.implant_vm(new_id, image, stop_ns);
        assert_eq!(target.control().unwrap().vm_name(new_id), Some("mover"));
        assert!(target.peek_time().unwrap() >= flip_at + stop_ns);

        // The target finishes the workload; counters continued, not reset.
        let res = target.run();
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].work_ops, ops);
        assert!(res[0].counters.swapout_ops >= done_before.swapout_ops);
        // Donor's result collection reports nothing for the moved VM.
        assert!(donor.finish().is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut m = Machine::new(HostConfig { seed: 42, ..Default::default() });
            let cfg = small_vm_cfg(2048, PageSize::Small);
            m.sys_vm(
                cfg,
                &MmConfig::default(),
                vec![Box::new(UniformRandom::new(0, 1024, 20_000))],
            );
            let r = m.run();
            (r[0].runtime, r[0].counters.faults_minor, r[0].counters.faults_major)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn huge_mode_fewer_faults_than_small() {
        let ops = 60_000;
        let run = |mode| {
            let mut m = Machine::new(HostConfig::default());
            let cfg = small_vm_cfg(16_384, mode);
            m.sys_vm(
                cfg,
                &MmConfig::default(),
                vec![Box::new(UniformRandom::new(0, 8192, ops))],
            );
            let r = m.run();
            r[0].counters.faults_minor + r[0].counters.faults_major
        };
        let f4k = run(PageSize::Small);
        let f2m = run(PageSize::Huge);
        assert!(f2m * 10 < f4k, "4k {f4k} vs 2m {f2m}");
    }

    /// Under `--granularity huge` every swap op moves a whole 2MB
    /// region: one queue entry, one receipt, one latency charge.
    #[test]
    fn granularity_huge_mode_moves_regions_whole() {
        let mut m = Machine::new(HostConfig::default());
        let cfg = small_vm_cfg(16_384, PageSize::Small);
        let mm_cfg = MmConfig {
            memory_limit: Some(4096 * 4096),
            scan_interval: 50 * MS,
            granularity: crate::types::GranularityMode::Huge,
            ..Default::default()
        };
        m.sys_vm(
            cfg,
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, 8192, 60_000))],
        );
        let res = m.run();
        let c = &res[0].counters;
        assert_eq!(res[0].work_ops, 60_000);
        assert!(c.swapout_ops > 0, "{c:?}");
        // All regions are huge, so every swap-in/out is a region op.
        assert_eq!(c.huge_swapins, c.swapin_ops, "{c:?}");
        assert_eq!(c.huge_swapouts, c.swapout_ops, "{c:?}");
        let mm = m.mm(0).unwrap();
        assert!(mm.core.usage_units <= 4096 + 512 * mm.swapper.threads() as u64);
    }

    /// The split-always oracle is *byte-identical* to the flat 4k
    /// baseline: admitting huge and immediately splitting every region
    /// must leave no structural trace in the run.
    #[test]
    fn granularity_split_all_matches_fixed_exactly() {
        use crate::types::GranularityMode;
        let run = |g: GranularityMode| {
            let mut m = Machine::new(HostConfig { seed: 7, ..Default::default() });
            let cfg = small_vm_cfg(8192, PageSize::Small);
            let mm_cfg = MmConfig {
                memory_limit: Some(1024 * 4096),
                scan_interval: 50 * MS,
                granularity: g,
                ..Default::default()
            };
            m.sys_vm(
                cfg,
                &mm_cfg,
                vec![Box::new(UniformRandom::new(0, 4096, 60_000))],
            );
            let res = m.run();
            let bm = format!("{:?}", m.backend_metrics());
            (res[0].runtime, res[0].counters.clone(), bm)
        };
        let norm = |mut c: Counters| {
            c.region_splits = 0; // the only legal difference
            format!("{c:?}")
        };
        let (rt_f, cf, bf) = run(GranularityMode::Fixed);
        let (rt_s, cs, bs) = run(GranularityMode::SplitAll);
        assert_eq!(cs.region_splits, 16); // 8192 units / 512
        assert_eq!(rt_f, rt_s);
        assert_eq!(norm(cf), norm(cs));
        assert_eq!(bf, bs);
    }

    /// `run_until` sliced at arbitrary epoch bounds is the same
    /// computation as `run()`: identical per-VM results and identical
    /// event count. The slicing grid (3ms) is deliberately off every
    /// periodic cadence in the machine so bounds land mid-stream.
    #[test]
    fn run_until_slices_match_run() {
        let build = || {
            let mut m = Machine::new(HostConfig { seed: 21, ..Default::default() });
            let cfg = small_vm_cfg(2048, PageSize::Small);
            m.sys_vm(
                cfg,
                &MmConfig {
                    memory_limit: Some(512 * 4096),
                    ..Default::default()
                },
                vec![Box::new(UniformRandom::new(0, 1024, 15_000))],
            );
            m
        };
        let mut a = build();
        let ra = a.run();

        let mut b = build();
        b.start();
        let mut bound = 0;
        while !b.done() && bound <= 600 * SEC {
            bound += 3 * MS;
            b.run_until(bound);
        }
        let rb = b.finish();
        assert_eq!(a.events_handled, b.events_handled, "event counts diverged");
        assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "results diverged");
    }
}
