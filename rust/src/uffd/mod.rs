//! userfaultfd model: the kernel mechanism that routes EPT violations on
//! missing pages to the userspace Memory Manager (paper §4.1 steps 3-5).
//!
//! The real path is: EPT violation -> KVM -> Linux MM -> uffd event ->
//! MM's UFFD poller. We model its *cost* (the paper's 22µs VMEXIT for
//! userspace faults vs 6µs in-kernel) and its *semantics*: events are
//! delivered in order, carry the faulting address, and the fault stays
//! outstanding until `UFFDIO_CONTINUE` maps the page.

use std::collections::VecDeque;

use crate::config::SwCost;
use crate::types::{Time, UnitId};
use crate::vm::FaultInfo;

/// One delivered userfault event.
#[derive(Debug, Clone)]
pub struct UffdEvent {
    pub fault: FaultInfo,
    /// When the guest instruction faulted.
    pub raised_at: Time,
    /// When the MM poller sees the event.
    pub delivered_at: Time,
}

/// The uffd channel between a VM's faults and its MM poller.
#[derive(Debug, Default)]
pub struct Uffd {
    queue: VecDeque<UffdEvent>,
    pub events_raised: u64,
    pub events_delivered: u64,
}

impl Uffd {
    pub fn new() -> Self {
        Self::default()
    }

    /// Kernel side: an EPT violation on a uffd-registered range. Returns
    /// the delivery time at which the MM poller wakes with the event.
    pub fn raise(&mut self, fault: FaultInfo, now: Time, sw: &SwCost) -> Time {
        let delivered_at = now + sw.vmexit_uffd_ns;
        self.events_raised += 1;
        self.queue.push_back(UffdEvent { fault, raised_at: now, delivered_at });
        delivered_at
    }

    /// MM side: poll the next event that is visible at `now`.
    pub fn poll(&mut self, now: Time) -> Option<UffdEvent> {
        if self.queue.front().is_some_and(|e| e.delivered_at <= now) {
            self.events_delivered += 1;
            self.queue.pop_front()
        } else {
            None
        }
    }

    /// Outstanding (raised, not yet polled) events.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Cost of resolving a fault: UFFDIO_CONTINUE ioctl + vCPU wake.
    pub fn continue_cost(sw: &SwCost, huge: bool) -> Time {
        sw.uffd_continue_ns + if huge { sw.map_2m_extra_ns } else { 0 }
    }

    /// Units currently queued (for conflation checks in tests).
    pub fn queued_units(&self) -> impl Iterator<Item = UnitId> + '_ {
        self.queue.iter().map(|e| e.fault.unit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fault(unit: UnitId) -> FaultInfo {
        FaultInfo {
            unit,
            gpa_frame: unit,
            gva_page: unit,
            cr3: 0x1000,
            ip: 0x400000,
            write: false,
            vcpu: 0,
            pre_cost: 0,
        }
    }

    #[test]
    fn delivery_is_delayed_by_vmexit_cost() {
        let sw = SwCost::default();
        let mut u = Uffd::new();
        let at = u.raise(fault(1), 100, &sw);
        assert_eq!(at, 100 + 22_000);
        assert!(u.poll(at - 1).is_none());
        let ev = u.poll(at).unwrap();
        assert_eq!(ev.fault.unit, 1);
        assert_eq!(ev.raised_at, 100);
    }

    #[test]
    fn fifo_order() {
        let sw = SwCost::default();
        let mut u = Uffd::new();
        u.raise(fault(1), 0, &sw);
        u.raise(fault(2), 0, &sw);
        let t = 1_000_000;
        assert_eq!(u.poll(t).unwrap().fault.unit, 1);
        assert_eq!(u.poll(t).unwrap().fault.unit, 2);
        assert_eq!(u.backlog(), 0);
    }

    #[test]
    fn continue_cost_huge_is_bigger() {
        let sw = SwCost::default();
        assert!(Uffd::continue_cost(&sw, true) > Uffd::continue_cost(&sw, false));
    }
}
