//! Core shared types: virtual time, page/unit identifiers, bitmaps.

/// Virtual time in nanoseconds since simulation start.
pub type Time = u64;

/// Time unit helpers.
pub const NS: Time = 1;
pub const US: Time = 1_000;
pub const MS: Time = 1_000_000;
pub const SEC: Time = 1_000_000_000;

/// 4kB frames per 2MB hugepage.
pub const HUGE_FRAMES: u64 = 512;
/// Bytes per 4kB frame.
pub const FRAME_BYTES: u64 = 4096;
/// Bytes per 2MB hugepage.
pub const HUGE_BYTES: u64 = FRAME_BYTES * HUGE_FRAMES;

/// Identifier of a VM on the host.
pub type VmId = usize;

/// A *swap unit*: the granularity at which the MM swaps. In strict-4kB
/// mode a unit is one 4kB frame; in strict-2MB mode it is a 512-frame
/// aligned hugepage. Units index the VM's guest-physical space:
/// `gpa_frame / unit_frames`.
pub type UnitId = u64;

/// Page size mode of a VM's backing memory (strict, per the paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PageSize {
    /// strict-4kB: memory and backing store use 4kB pages.
    Small,
    /// strict-2MB: memory and backing store use 2MB pages (HugeTLB-like;
    /// never split — the paper's headline mode).
    Huge,
}

impl PageSize {
    /// 4kB frames per swap unit.
    pub fn unit_frames(self) -> u64 {
        match self {
            PageSize::Small => 1,
            PageSize::Huge => HUGE_FRAMES,
        }
    }
    /// Bytes per swap unit.
    pub fn unit_bytes(self) -> u64 {
        self.unit_frames() * FRAME_BYTES
    }
    pub fn label(self) -> &'static str {
        match self {
            PageSize::Small => "4k",
            PageSize::Huge => "2M",
        }
    }
}

/// Dense bitmap over swap units (the EPT scanner's output format).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn new(len: usize) -> Self {
        Bitmap { words: vec![0; len.div_ceil(64)], len }
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1 << (i % 64);
    }
    #[inline]
    pub fn clear(&mut self, i: usize) {
        self.words[i / 64] &= !(1 << (i % 64));
    }
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }
    pub fn zero(&mut self) {
        self.words.fill(0);
    }
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(move |(wi, &w)| {
            let mut w = w;
            let mut out = Vec::with_capacity(w.count_ones() as usize);
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                out.push(wi * 64 + b);
                w &= w - 1;
            }
            out
        })
    }
    /// OR another bitmap into this one (same length).
    pub fn or_assign(&mut self, other: &Bitmap) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }
}

/// Per-unit swap state machine (paper §4.2 "Swapper will determine the
/// necessary state of the page and perform the required actions").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitState {
    /// Never touched: no backing store content, faults map a zero page.
    Untouched,
    /// Mapped into all clients, content in DRAM.
    Resident,
    /// Content only on the backing store.
    Swapped,
    /// Prefetched: content staged in DRAM but not mapped — the next
    /// fault is minor (no I/O), matching the paper's "prefetching does
    /// not map the page, it removes I/O from the fault path".
    Staged,
    /// Swap-in I/O in flight.
    SwappingIn,
    /// Unmapped, swap-out I/O in flight.
    SwappingOut,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_units() {
        assert_eq!(PageSize::Small.unit_frames(), 1);
        assert_eq!(PageSize::Huge.unit_frames(), 512);
        assert_eq!(PageSize::Huge.unit_bytes(), 2 * 1024 * 1024);
    }

    #[test]
    fn bitmap_set_get_count() {
        let mut b = Bitmap::new(130);
        assert_eq!(b.count_ones(), 0);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1));
        assert_eq!(b.count_ones(), 3);
        let ones: Vec<_> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 64, 129]);
        b.clear(64);
        assert_eq!(b.count_ones(), 2);
        b.zero();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn bitmap_or() {
        let mut a = Bitmap::new(10);
        let mut b = Bitmap::new(10);
        a.set(1);
        b.set(2);
        a.or_assign(&b);
        assert!(a.get(1) && a.get(2));
    }
}
