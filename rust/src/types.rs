//! Core shared types: virtual time, page/unit identifiers, bitmaps.

/// Virtual time in nanoseconds since simulation start.
pub type Time = u64;

/// Time unit helpers.
pub const NS: Time = 1;
pub const US: Time = 1_000;
pub const MS: Time = 1_000_000;
pub const SEC: Time = 1_000_000_000;

/// 4kB frames per 2MB hugepage.
pub const HUGE_FRAMES: u64 = 512;
/// Bytes per 4kB frame.
pub const FRAME_BYTES: u64 = 4096;
/// Bytes per 2MB hugepage.
pub const HUGE_BYTES: u64 = FRAME_BYTES * HUGE_FRAMES;
/// 4kB swap units per 2MB-backed region (granularity regions only exist
/// on VMs whose unit is 4kB; strict-2MB VMs already swap whole 2M units).
pub const REGION_UNITS: u64 = HUGE_FRAMES;

/// Identifier of a VM on the host.
pub type VmId = usize;

/// A *swap unit*: the granularity at which the MM swaps. In strict-4kB
/// mode a unit is one 4kB frame; in strict-2MB mode it is a 512-frame
/// aligned hugepage. Units index the VM's guest-physical space:
/// `gpa_frame / unit_frames`.
pub type UnitId = u64;

/// Page size mode of a VM's backing memory (strict, per the paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PageSize {
    /// strict-4kB: memory and backing store use 4kB pages.
    Small,
    /// strict-2MB: memory and backing store use 2MB pages (HugeTLB-like;
    /// never split — the paper's headline mode).
    Huge,
}

impl PageSize {
    /// 4kB frames per swap unit.
    pub fn unit_frames(self) -> u64 {
        match self {
            PageSize::Small => 1,
            PageSize::Huge => HUGE_FRAMES,
        }
    }
    /// Bytes per swap unit.
    pub fn unit_bytes(self) -> u64 {
        self.unit_frames() * FRAME_BYTES
    }
    pub fn label(self) -> &'static str {
        match self {
            PageSize::Small => "4k",
            PageSize::Huge => "2M",
        }
    }
}

/// Swap-granularity mode of a 4kB-unit VM (PR 8). Unlike
/// [`PageSize::Huge`] (whole-VM strict 2MB units, never split), these
/// modes keep the unit 4kB and overlay 2MB-backed *regions* of 512
/// units that can split back to per-4k tracking and collapse again at
/// runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum GranularityMode {
    /// Flat 4k: no regions, byte-identical to the pre-PR-8 behaviour.
    #[default]
    Fixed,
    /// Every region 2MB-backed at admission; no runtime split/collapse.
    Huge,
    /// Every region 2MB-backed at admission; the dt-reclaimer splits
    /// refault-churning regions and collapses uniform ranges back.
    Auto,
    /// Oracle: admit huge, then immediately split every region. Must be
    /// byte-identical to `Fixed` (the split-always acceptance test).
    SplitAll,
}

impl GranularityMode {
    pub fn label(self) -> &'static str {
        match self {
            GranularityMode::Fixed => "4k",
            GranularityMode::Huge => "huge",
            GranularityMode::Auto => "auto",
            GranularityMode::SplitAll => "split-all",
        }
    }
}

/// Granularity tag of one swap operation: whether a fault/reclaim on a
/// unit moves one 4kB page or one whole 2MB-backed region in a single
/// O(1) queue entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// One 4kB unit.
    Page,
    /// One 2MB-backed region (512 units, canonicalized to its base).
    Region,
}

/// Dense bitmap over swap units (the EPT scanner's output format).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn new(len: usize) -> Self {
        Bitmap { words: vec![0; len.div_ceil(64)], len }
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1 << (i % 64);
    }
    #[inline]
    pub fn clear(&mut self, i: usize) {
        self.words[i / 64] &= !(1 << (i % 64));
    }
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }
    pub fn zero(&mut self) {
        self.words.fill(0);
    }
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
    /// Allocation-free iterator over set bit indices, ascending. Sits on
    /// the EPT-scan and policy paths, so it must not heap-allocate.
    pub fn iter_ones(&self) -> OnesIter<'_> {
        OnesIter { words: &self.words, cur: 0, wi: 0, base: 0 }
    }
    /// OR another bitmap into this one (same length).
    pub fn or_assign(&mut self, other: &Bitmap) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }
    /// Clear every bit that is set in `other` (word-parallel `self &= !other`).
    pub fn and_not_assign(&mut self, other: &Bitmap) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }
    /// Clear bits in `[lo, hi)`, 64 at a time for interior words.
    pub fn clear_range(&mut self, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        assert!(hi <= self.len);
        let lw = lo / 64;
        let hw = (hi - 1) / 64;
        let lo_mask = !0u64 << (lo % 64);
        let hi_mask = !0u64 >> (63 - ((hi - 1) % 64));
        if lw == hw {
            self.words[lw] &= !(lo_mask & hi_mask);
        } else {
            self.words[lw] &= !lo_mask;
            for w in &mut self.words[lw + 1..hw] {
                *w = 0;
            }
            self.words[hw] &= !hi_mask;
        }
    }
    /// Set bits in `[lo, hi)`, 64 at a time for interior words (the
    /// mirror of [`Bitmap::clear_range`]; region split fan-out path).
    pub fn set_range(&mut self, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        assert!(hi <= self.len);
        let lw = lo / 64;
        let hw = (hi - 1) / 64;
        let lo_mask = !0u64 << (lo % 64);
        let hi_mask = !0u64 >> (63 - ((hi - 1) % 64));
        if lw == hw {
            self.words[lw] |= lo_mask & hi_mask;
        } else {
            self.words[lw] |= lo_mask;
            for w in &mut self.words[lw + 1..hw] {
                *w = !0;
            }
            self.words[hw] |= hi_mask;
        }
    }
    /// Any bit set in `[lo, hi)`?
    pub fn any_in_range(&self, lo: usize, hi: usize) -> bool {
        if lo >= hi {
            return false;
        }
        assert!(hi <= self.len);
        let lw = lo / 64;
        let hw = (hi - 1) / 64;
        let lo_mask = !0u64 << (lo % 64);
        let hi_mask = !0u64 >> (63 - ((hi - 1) % 64));
        if lw == hw {
            return self.words[lw] & lo_mask & hi_mask != 0;
        }
        if self.words[lw] & lo_mask != 0 || self.words[hw] & hi_mask != 0 {
            return true;
        }
        self.words[lw + 1..hw].iter().any(|&w| w != 0)
    }
    /// Raw 64-bit words (bit `i` of word `w` is unit `w*64 + i`). Bits at
    /// or beyond `len()` are always zero.
    #[inline]
    pub fn as_words(&self) -> &[u64] {
        &self.words
    }
    /// Mutable raw words. Callers must keep bits `>= len()` zero — the
    /// word-parallel EPT scan relies on this invariant.
    #[inline]
    pub fn as_words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }
}

/// Iterator state for [`Bitmap::iter_ones`]: one word cursor, no heap.
pub struct OnesIter<'a> {
    words: &'a [u64],
    cur: u64,
    wi: usize,
    base: usize,
}

impl Iterator for OnesIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            if self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi];
            self.base = self.wi * 64;
            self.wi += 1;
        }
        let b = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some(self.base + b)
    }
}

/// Per-unit swap state machine (paper §4.2 "Swapper will determine the
/// necessary state of the page and perform the required actions").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitState {
    /// Never touched: no backing store content, faults map a zero page.
    Untouched,
    /// Mapped into all clients, content in DRAM.
    Resident,
    /// Content only on the backing store.
    Swapped,
    /// Prefetched: content staged in DRAM but not mapped — the next
    /// fault is minor (no I/O), matching the paper's "prefetching does
    /// not map the page, it removes I/O from the fault path".
    Staged,
    /// Swap-in I/O in flight.
    SwappingIn,
    /// Unmapped, swap-out I/O in flight.
    SwappingOut,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_units() {
        assert_eq!(PageSize::Small.unit_frames(), 1);
        assert_eq!(PageSize::Huge.unit_frames(), 512);
        assert_eq!(PageSize::Huge.unit_bytes(), 2 * 1024 * 1024);
    }

    #[test]
    fn bitmap_set_get_count() {
        let mut b = Bitmap::new(130);
        assert_eq!(b.count_ones(), 0);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1));
        assert_eq!(b.count_ones(), 3);
        let ones: Vec<_> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 64, 129]);
        b.clear(64);
        assert_eq!(b.count_ones(), 2);
        b.zero();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn bitmap_or() {
        let mut a = Bitmap::new(10);
        let mut b = Bitmap::new(10);
        a.set(1);
        b.set(2);
        a.or_assign(&b);
        assert!(a.get(1) && a.get(2));
    }

    #[test]
    fn bitmap_and_not() {
        let mut a = Bitmap::new(130);
        let mut b = Bitmap::new(130);
        for i in [0, 63, 64, 129] {
            a.set(i);
        }
        b.set(63);
        b.set(129);
        a.and_not_assign(&b);
        let ones: Vec<_> = a.iter_ones().collect();
        assert_eq!(ones, vec![0, 64]);
    }

    #[test]
    fn bitmap_clear_range() {
        // Spans three words; check sub-word, word-boundary and interior.
        let mut a = Bitmap::new(200);
        for i in 0..200 {
            a.set(i);
        }
        a.clear_range(10, 10); // empty range: no-op
        assert_eq!(a.count_ones(), 200);
        a.clear_range(60, 140);
        for i in 0..200 {
            assert_eq!(a.get(i), !(60..140).contains(&i), "bit {i}");
        }
        a.clear_range(0, 200);
        assert_eq!(a.count_ones(), 0);
        // Single-word interior range.
        let mut b = Bitmap::new(64);
        for i in 0..64 {
            b.set(i);
        }
        b.clear_range(3, 7);
        assert_eq!(b.count_ones(), 60);
        assert!(b.get(2) && !b.get(3) && !b.get(6) && b.get(7));
    }

    #[test]
    fn granularity_set_range_mirrors_clear_range() {
        let mut a = Bitmap::new(200);
        a.set_range(10, 10); // empty range: no-op
        assert_eq!(a.count_ones(), 0);
        a.set_range(60, 140);
        for i in 0..200 {
            assert_eq!(a.get(i), (60..140).contains(&i), "bit {i}");
        }
        a.set_range(0, 200);
        assert_eq!(a.count_ones(), 200);
        // Single-word interior range.
        let mut b = Bitmap::new(64);
        b.set_range(3, 7);
        assert_eq!(b.count_ones(), 4);
        assert!(!b.get(2) && b.get(3) && b.get(6) && !b.get(7));
    }

    #[test]
    fn granularity_any_in_range() {
        let mut a = Bitmap::new(300);
        assert!(!a.any_in_range(0, 300));
        a.set(128);
        assert!(a.any_in_range(0, 300));
        assert!(a.any_in_range(128, 129));
        assert!(a.any_in_range(64, 192)); // interior full word
        assert!(!a.any_in_range(0, 128));
        assert!(!a.any_in_range(129, 300));
        assert!(!a.any_in_range(10, 10));
    }

    #[test]
    fn granularity_mode_labels_and_default() {
        assert_eq!(GranularityMode::default(), GranularityMode::Fixed);
        assert_eq!(GranularityMode::Fixed.label(), "4k");
        assert_eq!(GranularityMode::Huge.label(), "huge");
        assert_eq!(GranularityMode::Auto.label(), "auto");
        assert_eq!(GranularityMode::SplitAll.label(), "split-all");
        assert_eq!(REGION_UNITS, HUGE_FRAMES);
        assert_ne!(Granularity::Page, Granularity::Region);
    }

    #[test]
    fn iter_ones_across_words_and_tails() {
        let mut a = Bitmap::new(300);
        let want = vec![0usize, 1, 63, 64, 127, 128, 255, 299];
        for &i in &want {
            a.set(i);
        }
        assert_eq!(a.iter_ones().collect::<Vec<_>>(), want);
        assert_eq!(Bitmap::new(0).iter_ones().count(), 0);
        assert_eq!(Bitmap::new(64).iter_ones().count(), 0);
    }

    #[test]
    fn word_accessors_round_trip() {
        let mut a = Bitmap::new(130);
        a.set(64);
        assert_eq!(a.as_words()[1], 1);
        a.as_words_mut()[0] = 0b101;
        assert!(a.get(0) && a.get(2) && !a.get(1));
        assert_eq!(a.count_ones(), 3);
    }
}
