//! Typed configuration for the whole stack: hardware model constants,
//! VM shapes, MM / policy settings and experiment parameters.
//!
//! Every latency constant is calibrated against a number the paper
//! reports (Fig 1, Fig 3, Fig 6, §5.1, §6 machine setup); see DESIGN.md
//! §2 for the calibration table.



use crate::types::{GranularityMode, PageSize, Time, MS, NS, SEC, US};

/// Hardware model constants (Intel Xeon Gold 6226 + Intel D7-P5510 over
/// PCIe3 x4, per the paper's machine setup).
#[derive(Debug, Clone)]
pub struct HwConfig {
    /// DRAM access on a TLB hit.
    pub mem_ns: Time,
    /// Full nested page walk, 4kB leaf (guest 4-level x EPT 4-level).
    pub walk_4k_ns: Time,
    /// Full nested page walk, 2MB leaf (one level shorter on both sides).
    pub walk_2m_ns: Time,
    /// Extra walk cost while partial-walk caches are cold after an EPT
    /// access-bit clear (paper §3.3 "indirect cost").
    pub pwc_penalty_ns: Time,
    /// How long the PWC penalty persists after a scan clears A-bits.
    pub pwc_penalty_window: Time,
    /// TLB entries (single-level model, per vCPU).
    pub tlb_entries_4k: usize,
    pub tlb_entries_2m: usize,
    /// Per-PTE cost of scanning + clearing EPT access bits.
    pub scan_pte_ns: Time,
    /// NVMe: flash read/write base latency for a 4kB op.
    pub nvme_lat_4k_ns: Time,
    /// NVMe: additional fixed overhead for a 2MB op (command + flash).
    pub nvme_lat_2m_extra_ns: Time,
    /// PCIe v3 x4 effective bus bandwidth (bytes/sec) — the paper measures
    /// ~2.6 GB/s with fio.
    pub nvme_bus_bytes_per_sec: u64,
    /// NVMe queue parallelism (independent flash channels).
    pub nvme_channels: usize,
    /// Zeroing a 2MB page (paper §5.1: ~100us, hidden by the zero pool).
    pub zero_2m_ns: Time,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            mem_ns: 80 * NS,
            walk_4k_ns: 120 * NS,
            walk_2m_ns: 30 * NS,
            pwc_penalty_ns: 60 * NS,
            pwc_penalty_window: 2 * MS,
            tlb_entries_4k: 1536,
            tlb_entries_2m: 1024,
            scan_pte_ns: 5 * NS,
            nvme_lat_4k_ns: 75 * US,
            nvme_lat_2m_extra_ns: 120 * US,
            nvme_bus_bytes_per_sec: 2_600_000_000,
            nvme_channels: 32,
            zero_2m_ns: 100 * US,
        }
    }
}

/// Software-path cost constants (paper Fig 6 breakdown).
#[derive(Debug, Clone)]
pub struct SwCost {
    /// VM exit + kernel fixups for an in-kernel (Linux swap) fault.
    pub vmexit_kernel_ns: Time,
    /// VM exit + UFFD delivery + MM wakeups for a userspace fault
    /// (the paper measures 22us vs 6us in-kernel).
    pub vmexit_uffd_ns: Time,
    /// UFFDIO_CONTINUE + wake of the faulting vCPU.
    pub uffd_continue_ns: Time,
    /// Extra mapping work for a 2MB unit (EPT leaf install, pool book-
    /// keeping) — tuned so the 2M VMEXIT share lands near the paper's 4.2%.
    pub map_2m_extra_ns: Time,
    /// process_madvise(MADV_DONTNEED) per client on swap-out.
    pub madvise_ns: Time,
    /// FALLOC_FL_PUNCH_HOLE on the backing file.
    pub punch_hole_ns: Time,
    /// Storage-backend polling interval (request pickup jitter bound).
    pub backend_poll_ns: Time,
    /// Bounce-buffer copy per 4kB (SPDK cannot DMA 4k zero-copy, §5.3).
    pub bounce_copy_4k_ns: Time,
    /// Swapper queue handoff + semaphore wake.
    pub queue_handoff_ns: Time,
    /// In-kernel swap software path (swap cache, readahead setup).
    pub kernel_swap_sw_ns: Time,
    /// Guest-side cost of a first-touch minor fault (guest allocator).
    pub guest_alloc_ns: Time,
    /// Cost of one GVA->HVA guest page-table walk in the QEMU helper.
    pub gva_walk_ns: Time,
    /// Compressing one 4kB page into the compressed swap pool (LZO-class
    /// software codec; scaled linearly for 2MB units).
    pub compress_4k_ns: Time,
    /// Decompressing one 4kB page on a compressed-pool fault hit.
    pub decompress_4k_ns: Time,
}

impl Default for SwCost {
    fn default() -> Self {
        SwCost {
            vmexit_kernel_ns: 6 * US,
            vmexit_uffd_ns: 22 * US,
            uffd_continue_ns: 3 * US,
            map_2m_extra_ns: 18 * US,
            madvise_ns: 2 * US,
            punch_hole_ns: 2 * US,
            backend_poll_ns: 2 * US,
            bounce_copy_4k_ns: 600 * NS,
            queue_handoff_ns: 1 * US,
            kernel_swap_sw_ns: 4 * US,
            guest_alloc_ns: 800 * NS,
            gva_walk_ns: 2 * US,
            compress_4k_ns: 2 * US,
            decompress_4k_ns: 1 * US,
        }
    }
}

/// Tiered storage-backend configuration (compressed pool + NVMe
/// writeback; see [`crate::storage::TieredBackend`]).
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Compressed-pool capacity in bytes of *compressed* data. 0
    /// disables the pool entirely: every write goes straight to NVMe
    /// (the flat backend the paper's testbed uses).
    pub pool_capacity_bytes: u64,
    /// Start background writeback when compressed-pool occupancy
    /// exceeds this percentage of capacity.
    pub high_watermark_pct: u8,
    /// Writeback drains the pool down to this percentage of capacity.
    pub low_watermark_pct: u8,
    /// Maximum pool entries drained per writeback round.
    pub writeback_batch: usize,
    /// Adjacent-unit writeback requests are coalesced into a single
    /// NVMe I/O of up to this many units.
    pub max_coalesce_units: u64,
    /// Reject pool admission when the compressed image is at least this
    /// percentage of the raw size (incompressible page; zswap's
    /// same-filled/reject heuristic).
    pub reject_pct: u8,
    /// Network round trip for fetching one 4kB of compressed data from a
    /// remote-memory lease (RDMA-class fabric; Memtrade measures remote
    /// hits an order of magnitude faster than flash but slower than
    /// local DRAM). Scaled linearly with raw unit size, like the codec
    /// costs, and sits between a pool hit (~decompress only) and the
    /// 75us NVMe flash read.
    pub remote_lat_4k_ns: Time,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            pool_capacity_bytes: 256 * 1024 * 1024,
            high_watermark_pct: 90,
            low_watermark_pct: 70,
            writeback_batch: 64,
            max_coalesce_units: 8,
            reject_pct: 90,
            remote_lat_4k_ns: 20 * US,
        }
    }
}

impl TierConfig {
    /// Flat single-tier backend: no compressed pool, every swap write
    /// is an NVMe I/O (the paper's §6 testbed).
    pub fn flat() -> Self {
        TierConfig { pool_capacity_bytes: 0, ..Default::default() }
    }

    /// True when the compressed pool is enabled.
    pub fn pool_enabled(&self) -> bool {
        self.pool_capacity_bytes > 0
    }

    pub fn high_watermark_bytes(&self) -> u64 {
        self.pool_capacity_bytes / 100 * self.high_watermark_pct as u64
    }

    pub fn low_watermark_bytes(&self) -> u64 {
        self.pool_capacity_bytes / 100 * self.low_watermark_pct as u64
    }
}

/// Which arbitration policy the control-plane daemon runs each tick
/// (see [`crate::daemon::Arbiter`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArbiterKind {
    /// No closed-loop arbitration: limits stay as registered; only
    /// scheduled one-shot changes are applied.
    #[default]
    Static,
    /// Every tick, re-divide the host budget by SLA weight with
    /// per-VM WSS floors (Gold squeezed below WSS only after Bronze
    /// and Silver slack is exhausted).
    ProportionalShare,
    /// Act only on watermark crossings: squeeze to proportional
    /// targets above the high watermark, release in stages (with the
    /// recovery boost) below the low one.
    Watermark,
}

/// Control-plane configuration: the daemon's in-simulation feedback
/// loop ([`crate::daemon::ControlPlane`], scheduled as a `ControlTick`
/// actor inside [`crate::coordinator::Machine`]).
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Control-tick cadence.
    pub interval: Time,
    /// Host physical-memory budget: Σ(resident + compressed-pool)
    /// bytes the fleet may occupy. None = accounting only (no
    /// arbitration pressure).
    pub host_budget_bytes: Option<u64>,
    pub kind: ArbiterKind,
    /// How long [`crate::mm::PolicyApi::recovery_mode`] stays raised
    /// after a boost-flagged hard-limit release (0 disables the hint).
    pub recovery_boost_window: Time,
    /// A staged hard-limit release doubles the limit per tick, reaching
    /// the target in at most this many steps.
    pub release_stages: u32,
    /// Share of the compressed pool reserved per SLA class
    /// (Gold/Silver/Bronze, percent; applied when the pool is enabled).
    pub pool_split_pct: [u8; 3],
    /// Watermark-arbiter trigger points, percent of the host budget.
    pub high_watermark_pct: u8,
    pub low_watermark_pct: u8,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            interval: 100 * MS,
            host_budget_bytes: None,
            kind: ArbiterKind::Static,
            recovery_boost_window: 400 * MS,
            release_stages: 4,
            pool_split_pct: [20, 30, 50],
            high_watermark_pct: 90,
            low_watermark_pct: 75,
        }
    }
}

/// How the fleet scheduler picks a host shard for a newly admitted VM
/// (see [`crate::daemon::FleetScheduler`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Fill shards in order: a VM lands on the first shard whose
    /// SLA-weighted committed demand still fits under the shard budget
    /// times [`FleetConfig::fit_overcommit_pct`]; falls back to the
    /// least-committed shard when nothing fits.
    #[default]
    FirstFitBySla,
    /// Place on the shard with the lowest projected fault pressure:
    /// committed bytes scaled up for low-weight SLAs (a Bronze byte
    /// attracts more squeeze — and therefore more faults — than a Gold
    /// byte under pressure).
    SpreadByFaultRate,
}

/// What happens to a host shard when a [`HostFault`] fires
/// (see [`crate::daemon::FleetScheduler`]'s failure model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostFaultKind {
    /// The host dies instantly: resident memory and the compressed pool
    /// are gone; only NVMe receipts survive. Every VM on the shard is
    /// rebuilt on a surviving shard from those receipts, and the Σ-budget
    /// baseline shrinks by exactly the dead host's audited budget.
    Crash,
    /// The host's NVMe device degrades: flash latency inflates by
    /// [`FleetConfig::nvme_degrade_factor`]. The scheduler reacts with a
    /// graceful drain — mass VM state migration off the shard under
    /// [`FleetConfig::drain_deadline_ticks`]; VMs that miss the deadline
    /// fall back to the lease-only rebalancer.
    DegradedNvme,
    /// The platform revokes [`FleetConfig::revoke_pct`] percent of the
    /// host's budget (Memtrade-style producer reclaim). The shard sheds
    /// occupancy lease-style — chunked against measured headroom — and
    /// the Σ-budget baseline shrinks by the revoked bytes as they land.
    BudgetRevoke,
}

/// One deterministic failure event, injected at the first fleet tick at
/// or after `at` (fault schedules are part of [`FleetConfig`], so
/// same-seed runs replay faults identically at any worker count).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostFault {
    /// Virtual time at (or after) which the fault fires.
    pub at: Time,
    /// Target host shard index.
    pub host: usize,
    pub kind: HostFaultKind,
}

/// Remote-memory marketplace configuration (Memtrade-style, PR 9):
/// shards with pool slack post offers at fleet ticks, demand-infeasible
/// shards bid, and a matched pair moves the consumer's coldest pool
/// entries onto donor DRAM under a lease escrow. All matching, staging
/// and revocation run single-threaded at the fleet-tick barrier.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// Arm the marketplace. Off by default: every pre-remote scenario
    /// replays unchanged.
    pub enabled: bool,
    /// Smallest lease worth granting — offers and bids below this are
    /// ignored (matching overhead would dominate the benefit).
    pub min_lease_bytes: u64,
    /// Largest single lease; also caps one donor's total exposure,
    /// since a donor holds at most one lease at a time.
    pub max_lease_bytes: u64,
    /// Consumer-side staging pace: at most this many compressed pool
    /// bytes retag to the remote tier per fleet tick, and never more
    /// than the donor's measured headroom minus the margin.
    pub stage_chunk_bytes: u64,
    /// Revocation pace: at most this many remote bytes written back to
    /// the consumer's NVMe per fleet tick while a lease is revoking.
    pub recall_chunk_bytes: u64,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            enabled: false,
            min_lease_bytes: 1024 * 1024,
            max_lease_bytes: 16 * 1024 * 1024,
            stage_chunk_bytes: 1024 * 1024,
            recall_chunk_bytes: 2 * 1024 * 1024,
        }
    }
}

/// Clone-from-image admission (PR 10): a newly admitted VM implants
/// with *zero* resident memory, backed by a shared read-only
/// content-addressed golden image held once per host in the compressed
/// pool. Faults decompress units out of the image at pool latency
/// (instead of the per-VM NVMe boot-image read a cold boot pays), a
/// write breaks CoW into a private shadow entry, and the image itself
/// is refcounted — dropped only when the last clone on the host is
/// forgotten. All clone admissions happen at the fleet-tick barrier,
/// so seq/par byte-identity and the Σ-budget audit hold with storms
/// armed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CloneConfig {
    /// Arm clone-from-image admission. Off by default: every
    /// pre-clone scenario (and `HostConfig::paper()` figure) replays
    /// unchanged.
    pub enabled: bool,
    /// Golden-image size in swap units (the clone's boot working set).
    pub image_units: u64,
    /// Content-synthesis seed for the golden image. All clones of one
    /// image share it — that determinism is what makes the dedup ratio
    /// measurable.
    pub image_seed: u64,
    /// Admission pacing: at most this many queued clones implant per
    /// fleet tick.
    pub clones_per_tick: usize,
    /// Placement for image-sharing clones: `true` packs them onto
    /// hosts that already hold the image (the stored image bytes are
    /// charged once per host, so packing amortizes them); `false`
    /// spreads by committed pressure like any other admission.
    pub pack: bool,
    /// `LinearPf` boot-stream lookahead: while the clone's recovery
    /// boost window is raised, each fault streams this many successor
    /// units ahead out of the image.
    pub boot_stream_depth: u64,
    /// How long the clone's recovery boost stays raised after implant
    /// (the boot window the prefetcher streams inside).
    pub boost_window: Time,
}

impl Default for CloneConfig {
    fn default() -> Self {
        CloneConfig {
            enabled: false,
            image_units: 1024,
            image_seed: 0xB007_1A6E,
            clones_per_tick: 4,
            pack: false,
            boot_stream_depth: 8,
            boost_window: 500 * MS,
        }
    }
}

/// Fleet-scheduler configuration: how many host shards, their budgets,
/// VM placement, and the fault-rate-delta migration thresholds
/// ([`crate::daemon::FleetScheduler`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of host shards (one arbiter + control plane + tiered
    /// backend each).
    pub hosts: usize,
    /// Per-host physical-memory budgets; entry `i % len` applies to
    /// host `i`, so a single entry means a homogeneous fleet.
    pub host_budgets: Vec<u64>,
    pub placement: PlacementPolicy,
    /// Fleet-tick cadence: migration decisions and staged-lease chunk
    /// transfers happen at multiples of this virtual time.
    pub interval: Time,
    /// Enable the fault-rate-delta rebalancer (off = static placement:
    /// admission-time shard choice is final, no cross-host migration).
    pub migration: bool,
    /// A VM is migration-eligible only when its `pf_delta` (faults
    /// since the shard's previous control tick) reaches this.
    pub migrate_pf_delta_min: u64,
    /// A shard counts as pressured when Σ demand exceeds this percent
    /// of its usable budget (demand = WSS + fault headroom, the
    /// arbiter's own infeasibility criterion).
    pub pressure_demand_pct: u32,
    /// A shard may donate only while Σ demand stays below this percent
    /// of its usable budget — donors never become infeasible.
    pub donor_demand_pct: u32,
    /// Per-migration total size cap.
    pub migration_max_bytes: u64,
    /// Chunks and migrations smaller than this are not worth moving.
    pub migration_min_chunk: u64,
    /// Headroom the donor keeps on every chunk transfer (absorbs
    /// between-tick drift so the audited budget is never overshot).
    pub migration_margin_bytes: u64,
    /// Abort a migration that moved nothing for this many fleet ticks.
    pub migration_stall_ticks: u32,
    /// Concurrent in-flight migrations across the whole fleet.
    pub max_active_migrations: usize,
    /// Enable full **VM state migration**: when a feasible target shard
    /// exists, the rebalancer moves the pressured VM itself (engine/MM
    /// state, tier map, pool entries, NVMe receipts) instead of leasing
    /// budget toward it. Falls back to the budget lease when no shard
    /// can absorb the whole VM. Requires `migration`.
    pub state_migration: bool,
    /// Cold-phase (pre-copy) transfer cap per fleet tick: at most this
    /// many raw bytes of pool entries + NVMe receipts are staged to the
    /// target while the VM keeps running on the donor.
    pub state_chunk_bytes: u64,
    /// Attempt the stop-and-copy flip once the not-yet-copied swapped
    /// bytes drop to this threshold (re-dirtied entries count again).
    pub state_flip_threshold_bytes: u64,
    /// Force a flip attempt after this many pre-copy fleet ticks even
    /// if the threshold was never reached (churny VMs converge here).
    pub state_max_precopy_ticks: u32,
    /// Modeled transfer bandwidth for the stop-and-copy bytes (the
    /// brief pause the migrated VM observes at the flip).
    pub state_stop_bytes_per_sec: u64,
    /// Fixed stop-and-copy overhead (hand-off, EPT rebuild, adopt).
    pub state_stop_fixed_ns: Time,
    /// First-fit admission: committed demand may exceed the shard
    /// budget by this percentage before the shard counts as full.
    pub fit_overcommit_pct: u32,
    /// Per-shard control-plane template; `host_budget_bytes` is
    /// overwritten with the shard's entry from `host_budgets`.
    pub control: ControlConfig,
    /// Virtual-time horizon for [`crate::daemon::FleetScheduler::run`].
    pub max_time: Time,
    /// Parallel epoch engine (default): between consecutive fleet
    /// ticks every live shard drains its queue on a worker thread,
    /// joining at the tick barrier. `false` runs the sequential
    /// `(time, shard index)` merge — the correctness oracle the
    /// equivalence suite compares against (`--sequential` on the CLI).
    /// Output is byte-identical either way.
    pub parallel: bool,
    /// Worker-thread cap for the parallel engine; `None` uses
    /// `std::thread::available_parallelism`. Any value yields the same
    /// output (thread-count independence is a gated test).
    pub workers: Option<usize>,
    /// Deterministic fault schedule: each entry fires at the first
    /// fleet tick at or after its `at` time, in `(at, host)` order.
    /// Empty (the default) preserves pre-fault behaviour exactly.
    pub faults: Vec<HostFault>,
    /// Graceful drain: a degraded shard has this many fleet ticks to
    /// evacuate its VMs via state migration before the remaining ones
    /// fall back to the lease-only rebalancer.
    pub drain_deadline_ticks: u32,
    /// [`HostFaultKind::DegradedNvme`] multiplies the shard's NVMe
    /// flash latency by this factor.
    pub nvme_degrade_factor: u32,
    /// [`HostFaultKind::BudgetRevoke`] takes back this percentage of
    /// the shard's current audited budget.
    pub revoke_pct: u32,
    /// Modeled outage a crash-rebuilt VM observes before resuming on
    /// its new shard (detection + re-admission; receipts re-attach but
    /// all resident state refaults from the backend).
    pub crash_rebuild_stop_ns: Time,
    /// Remote-memory marketplace (PR 9); disabled by default.
    pub remote: RemoteConfig,
    /// Clone-from-image admission (PR 10); disabled by default.
    pub clone: CloneConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            hosts: 4,
            host_budgets: vec![512 * 1024 * 1024],
            placement: PlacementPolicy::default(),
            interval: 100 * MS,
            migration: true,
            migrate_pf_delta_min: 16,
            pressure_demand_pct: 104,
            donor_demand_pct: 90,
            migration_max_bytes: 64 * 1024 * 1024,
            migration_min_chunk: 512 * 1024,
            migration_margin_bytes: 256 * 1024,
            migration_stall_ticks: 8,
            max_active_migrations: 1,
            state_migration: false,
            state_chunk_bytes: 8 * 1024 * 1024,
            state_flip_threshold_bytes: 2 * 1024 * 1024,
            state_max_precopy_ticks: 16,
            state_stop_bytes_per_sec: 10_000_000_000,
            state_stop_fixed_ns: 200 * US,
            fit_overcommit_pct: 140,
            control: ControlConfig::default(),
            max_time: 600 * SEC,
            parallel: true,
            workers: None,
            faults: Vec::new(),
            drain_deadline_ticks: 32,
            nvme_degrade_factor: 8,
            revoke_pct: 25,
            crash_rebuild_stop_ns: 5 * MS,
            remote: RemoteConfig::default(),
            clone: CloneConfig::default(),
        }
    }
}

impl FleetConfig {
    /// Budget of host shard `i` (budgets cycle when fewer are given).
    pub fn budget_of(&self, i: usize) -> u64 {
        self.host_budgets[i % self.host_budgets.len()]
    }
}

/// Shape and behaviour of one simulated VM.
#[derive(Debug, Clone)]
pub struct VmConfig {
    /// Guest-physical memory in 4kB frames.
    pub frames: u64,
    pub vcpus: usize,
    /// Strict page-size mode of the backing memory (paper §3.1).
    pub page_size: PageSize,
    /// Fraction of the guest allocator churned before the workload starts
    /// (the §3.2 "aging"; 0.0 = identity GVA->GPA, 1.0 = fully scrambled).
    pub scramble: f64,
    /// Fraction of guest memory the guest OS backs with THP (affects the
    /// effective TLB reach in Huge mode).
    pub guest_thp_coverage: f64,
}

impl Default for VmConfig {
    fn default() -> Self {
        VmConfig {
            frames: 262_144, // 1 GiB guest
            vcpus: 1,
            page_size: PageSize::Huge,
            scramble: 0.9,
            guest_thp_coverage: 0.95,
        }
    }
}

impl VmConfig {
    pub fn units(&self) -> u64 {
        self.frames.div_ceil(self.page_size.unit_frames())
    }
    pub fn bytes(&self) -> u64 {
        self.frames * crate::types::FRAME_BYTES
    }
}

/// Memory-manager configuration (one MM per VM, paper §4.2).
#[derive(Debug, Clone)]
pub struct MmConfig {
    /// Number of Swapper worker threads.
    pub swapper_threads: usize,
    /// Memory limit in bytes (None = best-effort reclamation only).
    pub memory_limit: Option<u64>,
    /// EPT scan interval for the proactive reclaimer.
    pub scan_interval: Time,
    /// dt-reclaimer history window (must match the AOT artifact's H).
    pub history: usize,
    /// dt-reclaimer target promotion rate (paper default 2%).
    pub target_promotion_rate: f64,
    /// Zero-page pool capacity (2MB pages).
    pub zero_pool: usize,
    /// VMCS introspection ring capacity (fault contexts).
    pub vmcs_ring: usize,
    /// Use the AOT-compiled XLA artifacts for the reclaimer analytics
    /// (true) or the native Rust fallback (false; used for ablation).
    pub use_xla: bool,
    /// Swap-granularity mode for 4kB-unit VMs (PR 8): overlay 2MB-backed
    /// regions on the flat unit space. Ignored (forced to `Fixed`) on
    /// strict-2MB VMs, whose unit is already 2MB.
    pub granularity: GranularityMode,
    /// Drive the tiered backend's pool-admission threshold from the
    /// dt-reclaimer's age histogram instead of the static
    /// `TierConfig::reject_pct` (off by default: determinism baseline).
    pub adaptive_pool_admission: bool,
}

impl Default for MmConfig {
    fn default() -> Self {
        MmConfig {
            swapper_threads: 4,
            memory_limit: None,
            scan_interval: 1 * SEC,
            history: 32,
            target_promotion_rate: 0.02,
            zero_pool: 64,
            vmcs_ring: 512,
            use_xla: false,
            granularity: GranularityMode::Fixed,
            adaptive_pool_admission: false,
        }
    }
}

/// Linux-baseline knobs (paper §6 benchmark setup).
#[derive(Debug, Clone)]
pub struct LinuxConfig {
    /// vm.page-cluster: readahead of 2^k pages around a fault (default 3).
    pub page_cluster: u32,
    /// Transparent Huge Pages enabled (split on swap-out).
    pub thp: bool,
    /// cgroup memory limit in bytes.
    pub memory_limit: Option<u64>,
    /// Async page faults (KVM) enabled.
    pub async_pf: bool,
}

impl Default for LinuxConfig {
    fn default() -> Self {
        LinuxConfig { page_cluster: 3, thp: true, memory_limit: None, async_pf: true }
    }
}

/// Top-level experiment config: one host, N VMs, a mechanism choice.
#[derive(Debug, Clone, Default)]
pub struct HostConfig {
    pub hw: HwConfig,
    pub sw: SwCost,
    /// Storage-backend tiering (default: compressed pool enabled).
    pub tier: TierConfig,
    pub seed: u64,
}

impl HostConfig {
    /// The paper's §6 testbed: a flat NVMe swap backend with no
    /// compressed tier. The figure-reproduction experiments use this so
    /// their calibrated latency shapes match the paper's hardware.
    pub fn paper() -> Self {
        HostConfig { tier: TierConfig::flat(), ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let sw = SwCost::default();
        assert_eq!(sw.vmexit_kernel_ns, 6_000);
        assert_eq!(sw.vmexit_uffd_ns, 22_000);
        let hw = HwConfig::default();
        assert_eq!(hw.zero_2m_ns, 100_000);
        assert_eq!(hw.nvme_bus_bytes_per_sec, 2_600_000_000);
    }

    #[test]
    fn tier_config_watermarks_and_flat() {
        let t = TierConfig::default();
        assert!(t.pool_enabled());
        assert!(t.high_watermark_bytes() > t.low_watermark_bytes());
        assert!(t.high_watermark_bytes() < t.pool_capacity_bytes);
        let f = TierConfig::flat();
        assert!(!f.pool_enabled());
        assert_eq!(f.high_watermark_bytes(), 0);
        assert!(!HostConfig::paper().tier.pool_enabled());
        assert!(HostConfig::default().tier.pool_enabled());
    }

    #[test]
    fn vm_units_by_mode() {
        let mut vm = VmConfig { frames: 1024, ..Default::default() };
        vm.page_size = PageSize::Small;
        assert_eq!(vm.units(), 1024);
        vm.page_size = PageSize::Huge;
        assert_eq!(vm.units(), 2);
    }

    #[test]
    fn fleet_config_budget_cycles() {
        let f = FleetConfig {
            hosts: 4,
            host_budgets: vec![100, 200],
            ..Default::default()
        };
        assert_eq!(f.budget_of(0), 100);
        assert_eq!(f.budget_of(1), 200);
        assert_eq!(f.budget_of(2), 100);
        assert_eq!(f.budget_of(3), 200);
        // Donors must be strictly stricter than the pressure trigger,
        // or one shard could count as both at once.
        let d = FleetConfig::default();
        assert!(d.donor_demand_pct < d.pressure_demand_pct);
        assert!(d.migration_min_chunk > d.migration_margin_bytes);
        // No faults by default: arming the failure model is opt-in, so
        // every pre-fault scenario replays unchanged.
        assert!(d.faults.is_empty());
        assert!(d.nvme_degrade_factor > 1, "degrade must inflate latency");
        assert!(d.revoke_pct < 100, "revocation must leave a live budget");
        assert!(d.drain_deadline_ticks > 0);
    }

    #[test]
    fn remote_defaults_are_opt_in_and_latency_ordered() {
        let d = FleetConfig::default();
        assert!(!d.remote.enabled, "marketplace must be opt-in");
        assert!(d.remote.min_lease_bytes <= d.remote.max_lease_bytes);
        assert!(d.remote.stage_chunk_bytes > 0);
        assert!(d.remote.recall_chunk_bytes > 0);
        // Fault-path ordering the walkthrough promises: a remote hit is
        // slower than a pool decompress, faster than an NVMe flash read.
        let t = TierConfig::default();
        assert!(t.remote_lat_4k_ns > SwCost::default().decompress_4k_ns);
        assert!(t.remote_lat_4k_ns < HwConfig::default().nvme_lat_4k_ns);
    }

    #[test]
    fn clone_defaults_are_opt_in_and_paper_mode_is_clean() {
        let d = FleetConfig::default();
        assert!(!d.clone.enabled, "clone admission must be opt-in");
        assert!(d.clone.image_units > 0);
        assert!(d.clone.clones_per_tick > 0);
        assert!(
            d.clone.boot_stream_depth >= 2,
            "must stream at least as far as the stock LinearPf"
        );
        assert!(d.clone.boost_window > 0);
        // Paper-mode divergence audit: the calibrated figure host has no
        // compressed pool, so a golden image could never live there —
        // and nothing in `HostConfig` grows clone state. Pin both so
        // figure shapes stay byte-identical with PR 10 merged.
        let paper = HostConfig::paper();
        assert!(
            !paper.tier.pool_enabled(),
            "paper host must stay flat (image tier needs the pool)"
        );
        assert_eq!(
            format!("{:?}", paper.tier),
            format!("{:?}", TierConfig::flat()),
            "paper tier config must not drift from flat()"
        );
    }

    #[test]
    fn fig1_breakeven_predicted_near_paper() {
        // Analytic crossover r* = (walk4k - walk2m) / (fault2m - fault4k)
        // should land near the paper's 0.01%.
        let hw = HwConfig::default();
        let sw = SwCost::default();
        let fault_4k =
            sw.vmexit_uffd_ns + hw.nvme_lat_4k_ns + sw.uffd_continue_ns;
        let fault_2m = sw.vmexit_uffd_ns
            + hw.nvme_lat_2m_extra_ns
            + (2 * 1024 * 1024u64) * 1_000_000_000 / hw.nvme_bus_bytes_per_sec
            + sw.uffd_continue_ns;
        let r = (hw.walk_4k_ns - hw.walk_2m_ns) as f64
            / (fault_2m - fault_4k) as f64;
        assert!(r > 0.3e-4 && r < 3.0e-4, "breakeven {r}");
    }
}
