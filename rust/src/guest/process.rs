//! A guest process: an address space (page table) identified by its CR3.
//!
//! The hypervisor sees CR3/PDBP values in the VMCS at fault time (§5.2)
//! and can use them to distinguish guest applications without guest
//! cooperation.

use super::pagetable::GuestPageTable;

#[derive(Debug, Clone)]
pub struct GuestProcess {
    /// Page-directory base pointer — the opaque per-process token the
    /// introspection ring exposes to policies.
    pub cr3: u64,
    /// Hardware ASID used for TLB tagging.
    pub asid: u16,
    pub pt: GuestPageTable,
}

impl GuestProcess {
    pub fn new(idx: usize, gva_pages: u64) -> Self {
        GuestProcess {
            // Realistic-looking kernel pointer for the CR3 value.
            cr3: 0xFFFF_8000_0000_0000 | ((idx as u64 + 1) << 12),
            asid: idx as u16 + 1,
            pt: GuestPageTable::new(gva_pages),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_cr3_and_asid() {
        let a = GuestProcess::new(0, 4);
        let b = GuestProcess::new(1, 4);
        assert_ne!(a.cr3, b.cr3);
        assert_ne!(a.asid, b.asid);
    }
}
