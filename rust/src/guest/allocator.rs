//! Guest physical-frame allocator.
//!
//! A freshly booted guest hands out frames roughly sequentially, so
//! GVA-contiguous buffers are GPA-contiguous too. After the system "ages"
//! (allocations and frees churn the free list), contiguity is destroyed —
//! this is exactly the §3.2 observation that spatial patterns visible in
//! GVA space scramble in GPA space. `age()` reproduces the paper's
//! warm-up ("running a random memory access process for 1 second").

use crate::sim::Rng;

/// 4kB guest-physical frame number.
pub type Frame = u32;

#[derive(Debug, Clone)]
pub struct GuestAllocator {
    /// LIFO free list; boot state is descending so pops are sequential.
    free: Vec<Frame>,
    total: u64,
}

impl GuestAllocator {
    pub fn new(frames: u64) -> Self {
        // Reverse order: pop() yields frame 0, 1, 2, ... at boot.
        let free = (0..frames as Frame).rev().collect();
        GuestAllocator { free, total: frames }
    }

    /// Churn the free list, destroying sequential order for a `fraction`
    /// of entries (0.0 = pristine boot, 1.0 = fully scrambled).
    pub fn age(&mut self, fraction: f64, rng: &mut Rng) {
        let n = self.free.len();
        if n < 2 || fraction <= 0.0 {
            return;
        }
        let swaps = (n as f64 * fraction.clamp(0.0, 1.0)) as usize;
        for _ in 0..swaps {
            let i = rng.below(n as u64) as usize;
            let j = rng.below(n as u64) as usize;
            self.free.swap(i, j);
        }
    }

    pub fn alloc(&mut self) -> Option<Frame> {
        self.free.pop()
    }

    pub fn free_frame(&mut self, f: Frame) {
        self.free.push(f);
    }

    pub fn available(&self) -> u64 {
        self.free.len() as u64
    }

    pub fn total(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_allocation_is_sequential() {
        let mut a = GuestAllocator::new(16);
        let frames: Vec<_> = (0..16).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(frames, (0..16).collect::<Vec<_>>());
        assert_eq!(a.alloc(), None);
    }

    #[test]
    fn aged_allocation_is_scrambled() {
        let mut a = GuestAllocator::new(4096);
        a.age(1.0, &mut Rng::new(9));
        let frames: Vec<_> = (0..4096).map(|_| a.alloc().unwrap()).collect();
        // Count adjacent pairs that are still sequential.
        let seq = frames.windows(2).filter(|w| w[1] == w[0] + 1).count();
        assert!(seq < 200, "still too sequential: {seq}");
        // Still a permutation.
        let mut sorted = frames.clone();
        sorted.sort();
        assert_eq!(sorted, (0..4096).collect::<Vec<_>>());
    }

    #[test]
    fn free_recycles() {
        let mut a = GuestAllocator::new(2);
        let f0 = a.alloc().unwrap();
        a.alloc().unwrap();
        assert_eq!(a.available(), 0);
        a.free_frame(f0);
        assert_eq!(a.alloc(), Some(f0));
    }
}
