//! Guest-side models: the guest OS physical-page allocator (whose aging
//! produces the §3.2 GVA->GPA scrambling), per-process guest page tables
//! and guest processes.

pub mod allocator;
pub mod pagetable;
pub mod process;

pub use allocator::GuestAllocator;
pub use pagetable::GuestPageTable;
pub use process::GuestProcess;
