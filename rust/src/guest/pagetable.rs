//! Per-process guest page table (GVA -> GPA at 4kB granularity), with
//! guest-side access bits (what an in-guest profiler would see — the
//! "direct" measurement of Fig 2).

use super::allocator::{Frame, GuestAllocator};
use crate::types::Bitmap;

pub const UNMAPPED: Frame = Frame::MAX;

#[derive(Debug, Clone)]
pub struct GuestPageTable {
    /// gva_page -> guest frame.
    map: Vec<Frame>,
    /// Guest-side access bits, GVA-indexed.
    accessed: Bitmap,
}

impl GuestPageTable {
    pub fn new(gva_pages: u64) -> Self {
        GuestPageTable {
            map: vec![UNMAPPED; gva_pages as usize],
            accessed: Bitmap::new(gva_pages as usize),
        }
    }

    pub fn gva_pages(&self) -> u64 {
        self.map.len() as u64
    }

    /// Translate; `None` = guest minor fault (demand-zero page).
    #[inline]
    pub fn walk(&self, gva_page: u64) -> Option<Frame> {
        match self.map.get(gva_page as usize) {
            Some(&f) if f != UNMAPPED => Some(f),
            _ => None,
        }
    }

    /// Handle the guest's own demand-paging fault: allocate a frame.
    pub fn map_on_fault(
        &mut self,
        gva_page: u64,
        alloc: &mut GuestAllocator,
    ) -> Option<Frame> {
        debug_assert!(self.walk(gva_page).is_none());
        let f = alloc.alloc()?;
        self.map[gva_page as usize] = f;
        Some(f)
    }

    /// Record a guest-visible access (guest PTE A-bit).
    #[inline]
    pub fn touch(&mut self, gva_page: u64) {
        self.accessed.set(gva_page as usize);
    }

    /// Read + clear guest A-bits (in-guest scan, GVA order).
    pub fn scan_and_clear(&mut self) -> Bitmap {
        let out = self.accessed.clone();
        self.accessed.zero();
        out
    }

    /// Iterate mapped (gva_page, frame) pairs.
    pub fn mappings(&self) -> impl Iterator<Item = (u64, Frame)> + '_ {
        self.map
            .iter()
            .enumerate()
            .filter(|(_, &f)| f != UNMAPPED)
            .map(|(g, &f)| (g as u64, f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demand_paging() {
        let mut alloc = GuestAllocator::new(8);
        let mut pt = GuestPageTable::new(4);
        assert_eq!(pt.walk(1), None);
        let f = pt.map_on_fault(1, &mut alloc).unwrap();
        assert_eq!(pt.walk(1), Some(f));
    }

    #[test]
    fn abit_scan_clears() {
        let mut pt = GuestPageTable::new(4);
        pt.touch(2);
        let bm = pt.scan_and_clear();
        assert!(bm.get(2));
        assert_eq!(pt.scan_and_clear().count_ones(), 0);
    }

    #[test]
    fn oom_returns_none() {
        let mut alloc = GuestAllocator::new(1);
        let mut pt = GuestPageTable::new(2);
        pt.map_on_fault(0, &mut alloc).unwrap();
        assert!(pt.map_on_fault(1, &mut alloc).is_none());
    }
}
