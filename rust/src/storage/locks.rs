//! Page locking for zero-copy I/O virtualization (paper §5.5).
//!
//! Clients like OVS share a page-lock bitmap with the MM. Locking is a
//! two-step protocol: (1) atomically set the lock bit, (2) read the page
//! to force a swap-in if it was out. The MM never swaps out a locked
//! unit; clients clear the bit when the DMA finishes.

use crate::types::{Bitmap, UnitId};

#[derive(Debug)]
pub struct LockBitmap {
    bits: Bitmap,
    pub lock_ops: u64,
    pub unlock_ops: u64,
    /// Swap-outs the MM skipped because the unit was locked.
    pub denied_swapouts: u64,
}

impl LockBitmap {
    pub fn new(units: u64) -> Self {
        LockBitmap {
            bits: Bitmap::new(units as usize),
            lock_ops: 0,
            unlock_ops: 0,
            denied_swapouts: 0,
        }
    }

    /// Client step 1: set the lock bit. The caller must then touch the
    /// page (which faults it in if swapped) before starting DMA.
    pub fn lock(&mut self, unit: UnitId) {
        self.bits.set(unit as usize);
        self.lock_ops += 1;
    }

    pub fn unlock(&mut self, unit: UnitId) {
        self.bits.clear(unit as usize);
        self.unlock_ops += 1;
    }

    #[inline]
    pub fn is_locked(&self, unit: UnitId) -> bool {
        self.bits.get(unit as usize)
    }

    /// MM side: check-and-account on the swap-out path.
    pub fn deny_if_locked(&mut self, unit: UnitId) -> bool {
        if self.is_locked(unit) {
            self.denied_swapouts += 1;
            true
        } else {
            false
        }
    }

    pub fn locked_count(&self) -> usize {
        self.bits.count_ones()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_prevents_swapout() {
        let mut l = LockBitmap::new(8);
        l.lock(3);
        assert!(l.deny_if_locked(3));
        assert!(!l.deny_if_locked(4));
        assert_eq!(l.denied_swapouts, 1);
        l.unlock(3);
        assert!(!l.deny_if_locked(3));
    }

    #[test]
    fn counts() {
        let mut l = LockBitmap::new(4);
        l.lock(0);
        l.lock(1);
        assert_eq!(l.locked_count(), 2);
        l.unlock(0);
        assert_eq!(l.locked_count(), 1);
        assert_eq!(l.lock_ops, 2);
        assert_eq!(l.unlock_ops, 1);
    }
}
