//! Page codec for the compressed swap pool: zero-page detection plus a
//! byte-run-length encoder with a verbatim fallback for incompressible
//! data.
//!
//! The codec is deliberately simple — the pool's value comes from the
//! *tiering* (absorbing reclaim writes in DRAM instead of NVMe), not
//! from squeezing the last percent of ratio — but it is a real codec
//! over real bytes: `decompress(compress(p)) == p` for every input, a
//! property the round-trip tests drive with random and zero-heavy
//! pages. Zero detection mirrors the zero-page special-casing the MM
//! already does for first-touch faults ([`crate::mm::ZeroPool`]): an
//! all-zero page stores no payload at all, like zswap's same-filled
//! page path.
//!
//! Encoding format (`Compressed::Rle`): a sequence of `(run_len, byte)`
//! pairs, `run_len` in `1..=255`. Runs longer than 255 split into
//! multiple pairs. If the encoded stream would reach the input length,
//! [`compress`] returns `Compressed::Raw` instead (never larger than
//! the input plus the enum tag).

/// A compressed page image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Compressed {
    /// All-zero page of `len` bytes: no payload stored.
    Zero { len: u32 },
    /// Run-length-encoded payload (strictly smaller than the input).
    Rle { len: u32, data: Vec<u8> },
    /// Incompressible page stored verbatim.
    Raw(Vec<u8>),
}

impl Compressed {
    /// Bytes of pool memory this image occupies (payload only; the
    /// per-entry bookkeeping overhead is accounted by the pool).
    pub fn stored_bytes(&self) -> u64 {
        match self {
            Compressed::Zero { .. } => 0,
            Compressed::Rle { data, .. } => data.len() as u64,
            Compressed::Raw(data) => data.len() as u64,
        }
    }

    /// Length of the original (decompressed) page.
    pub fn raw_len(&self) -> usize {
        match self {
            Compressed::Zero { len } => *len as usize,
            Compressed::Rle { len, .. } => *len as usize,
            Compressed::Raw(data) => data.len(),
        }
    }
}

/// True if every byte of `data` is zero (word-at-a-time scan).
pub fn is_zero_page(data: &[u8]) -> bool {
    let mut chunks = data.chunks_exact(8);
    if !chunks.all(|c| u64::from_ne_bytes(c.try_into().unwrap()) == 0) {
        return false;
    }
    data.chunks_exact(8).remainder().iter().all(|&b| b == 0)
}

/// Compress a page. Zero pages store nothing; pages whose RLE stream
/// does not shrink are stored raw.
pub fn compress(data: &[u8]) -> Compressed {
    if is_zero_page(data) {
        return Compressed::Zero { len: data.len() as u32 };
    }
    let mut out = Vec::with_capacity(data.len() / 4);
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < 255 {
            run += 1;
        }
        out.push(run as u8);
        out.push(b);
        i += run;
        if out.len() >= data.len() {
            // Not shrinking: bail out to the verbatim representation.
            return Compressed::Raw(data.to_vec());
        }
    }
    Compressed::Rle { len: data.len() as u32, data: out }
}

/// Decompress into `out` (cleared and refilled; capacity is reused).
pub fn decompress(img: &Compressed, out: &mut Vec<u8>) {
    out.clear();
    match img {
        Compressed::Zero { len } => out.resize(*len as usize, 0),
        Compressed::Raw(data) => out.extend_from_slice(data),
        Compressed::Rle { len, data } => {
            out.reserve(*len as usize);
            for pair in data.chunks_exact(2) {
                let (run, b) = (pair[0] as usize, pair[1]);
                let start = out.len();
                out.resize(start + run, b);
            }
            debug_assert_eq!(out.len(), *len as usize);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    fn roundtrip(data: &[u8]) -> Compressed {
        let img = compress(data);
        let mut out = Vec::new();
        decompress(&img, &mut out);
        assert_eq!(out.as_slice(), data, "roundtrip mismatch ({} bytes)", data.len());
        img
    }

    #[test]
    fn zero_page_stores_nothing() {
        let img = roundtrip(&[0u8; 4096]);
        assert_eq!(img, Compressed::Zero { len: 4096 });
        assert_eq!(img.stored_bytes(), 0);
        assert_eq!(img.raw_len(), 4096);
    }

    #[test]
    fn pattern_page_shrinks() {
        let mut page = vec![0xABu8; 4096];
        page[100] = 1;
        page[3000] = 2;
        let img = roundtrip(&page);
        assert!(img.stored_bytes() < 200, "stored {}", img.stored_bytes());
    }

    #[test]
    fn random_page_falls_back_to_raw() {
        let mut rng = Rng::new(5);
        let page: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
        let img = roundtrip(&page);
        assert!(matches!(img, Compressed::Raw(_)));
        assert_eq!(img.stored_bytes(), 4096);
    }

    #[test]
    fn run_length_boundaries() {
        // Runs of exactly 255, 256 and 510 bytes cross the u8 limit.
        for n in [1usize, 2, 254, 255, 256, 510, 511, 1024] {
            let mut page = vec![7u8; n];
            if n > 2 {
                page[n / 2] = 9; // break the run mid-way too
            }
            roundtrip(&page);
        }
        roundtrip(&[]);
        roundtrip(&[0]);
        roundtrip(&[1]);
    }

    /// Property: compress/decompress identity over randomized pages —
    /// random, zero-heavy, and run-structured — across many seeds.
    #[test]
    fn prop_roundtrip_identity() {
        let mut rng = Rng::new(42);
        for case in 0..200u64 {
            let len = match case % 4 {
                0 => 4096,
                1 => 1 + rng.below(4096) as usize,
                2 => 2 * 1024 * 1024 / 64, // 2M unit sampled at /64 for speed
                _ => 64 + rng.below(512) as usize,
            };
            let mut page = vec![0u8; len];
            match case % 3 {
                0 => {
                    // Zero-heavy: a few random dirty islands.
                    for _ in 0..rng.below(8) {
                        let at = rng.below(len as u64) as usize;
                        let span = (rng.below(64) as usize + 1).min(len - at);
                        for b in &mut page[at..at + span] {
                            *b = rng.below(256) as u8;
                        }
                    }
                }
                1 => {
                    // Fully random (incompressible).
                    for b in page.iter_mut() {
                        *b = rng.below(256) as u8;
                    }
                }
                _ => {
                    // Run-structured: random-length constant runs.
                    let mut i = 0;
                    while i < len {
                        let run = (1 + rng.below(400) as usize).min(len - i);
                        let v = rng.below(256) as u8;
                        for b in &mut page[i..i + run] {
                            *b = v;
                        }
                        i += run;
                    }
                }
            }
            let img = compress(&page);
            let mut out = Vec::new();
            decompress(&img, &mut out);
            assert_eq!(out, page, "case {case} len {len}");
            // Compressed never exceeds raw (Raw fallback guarantees it).
            assert!(img.stored_bytes() <= len as u64, "case {case}");
        }
    }
}
