//! Storage Backend (paper §4.4, §5.3): a single SPDK-like polling
//! process serving swap I/O for all MMs, plus the page-locking protocol
//! that lets zero-copy I/O clients (OVS/vhost) pin pages against
//! swap-out.

pub mod backend;
pub mod locks;

pub use backend::{IoToken, StorageBackend};
pub use locks::LockBitmap;
