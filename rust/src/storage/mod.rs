//! Storage backend (paper §4.4, §5.3) — now tiered.
//!
//! One backend process serves swap I/O for all MMs on the host. PR 2
//! replaced the flat SPDK/NVMe path with the [`SwapBackend`] trait and
//! a tiered implementation, [`TieredBackend`] — three tiers since
//! PR 9:
//!
//! * **Tier 0 — compressed pool** ([`codec`]): a zswap-style in-memory
//!   pool that absorbs reclaim writes. Zero pages (detected with the
//!   same all-zero scan idea as the MM's [`crate::mm::ZeroPool`]) store
//!   no payload; run-length-compressible pages store their encoded
//!   form; incompressible pages are rejected to NVMe.
//! * **Tier 0.5 — leased remote memory** (PR 9): when the fleet's
//!   marketplace matches this host with a donor, `remote_stage` moves
//!   the coldest pool entries into the donor's DRAM — a fault hit
//!   there costs one modeled network round trip plus decompression,
//!   strictly between a pool hit and an NVMe read, with no local
//!   NVMe I/O. Revocation (`remote_recall`) writes entries back to
//!   local NVMe oldest-first; a donor crash (`remote_drop`) loses
//!   them, and later faults re-fault as cold NVMe misses.
//! * **Tier 1 — NVMe writeback** ([`crate::hw::Nvme`]): when the pool
//!   crosses its high watermark, the oldest entries are drained in
//!   batches of sorted, adjacent-unit-coalesced I/O requests down to
//!   the low watermark.
//!
//! Faults check the pool first (decompress-on-hit, **no** NVMe I/O) and
//! fall through to the device; see [`backend`] for the full trait
//! contract (write idempotence, non-destructive reads, writeback
//! ordering, the fault-during-writeback rule). [`locks`] carries the
//! page-locking protocol that lets zero-copy I/O clients (OVS/vhost)
//! pin pages against swap-out, unchanged from PR 1.
//!
//! # Example
//!
//! A zero page is absorbed by the pool and faults back without device
//! I/O; an incompressible page falls through to NVMe:
//!
//! ```
//! use flexswap::config::{HwConfig, SwCost, TierConfig};
//! use flexswap::hw::Nvme;
//! use flexswap::sim::Rng;
//! use flexswap::storage::{SwapBackend, SwapTier, TierHint, TieredBackend};
//!
//! let mut backend = TieredBackend::new(&TierConfig::default(), &SwCost::default());
//! let mut nvme = Nvme::new(&HwConfig::default());
//! let mut rng = Rng::new(1);
//!
//! // Reclaim write of a zero page: pool tier, no NVMe request.
//! let zero = vec![0u8; 4096];
//! let w = backend.write(0, 7, &zero, TierHint::Auto, 0, &mut nvme, &mut rng);
//! assert_eq!(w.tier, SwapTier::Pool);
//! assert_eq!(backend.metrics().nvme_write_reqs, 0);
//!
//! // Fault hit on the compressed pool: decompress only, content intact.
//! let mut page = Vec::new();
//! let r = backend.read(0, 7, 4096, &mut page, w.completes_at, &mut nvme, &mut rng);
//! assert_eq!(r.tier, SwapTier::Pool);
//! assert_eq!(page, zero);
//! assert_eq!(backend.metrics().nvme_reads, 0);
//!
//! // A policy can route a cold unit straight to NVMe.
//! let w2 = backend.write(0, 8, &zero, TierHint::Nvme, 0, &mut nvme, &mut rng);
//! assert_eq!(w2.tier, SwapTier::Nvme);
//! assert_eq!(backend.metrics().nvme_write_reqs, 1);
//! ```

pub mod backend;
pub mod codec;
pub mod content;
pub mod locks;
pub mod tiered;

pub use backend::{
    CrashSalvage, IoReceipt, IoToken, PortableUnit, SwapBackend, SwapTier, TierHint, TierMetrics,
    UnitSummary,
};
pub use codec::{compress, decompress, is_zero_page, Compressed};
pub use content::{ContentClass, ContentMix, ContentModel};
pub use locks::LockBitmap;
pub use tiered::TieredBackend;
