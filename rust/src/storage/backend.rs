//! The [`SwapBackend`] trait: the contract between the MM/Swapper layer
//! and swap storage, plus the receipt and metrics types every backend
//! implementation shares.
//!
//! PR 1's `StorageBackend` was a single flat SPDK-like NVMe path; this
//! trait replaces it so the machine can route swap I/O through a tiered
//! implementation ([`crate::storage::TieredBackend`]: compressed
//! in-memory pool + batched NVMe writeback) while policies target tiers
//! explicitly via [`TierHint`].
//!
//! # Contract
//!
//! * **Idempotence / replacement** — [`SwapBackend::write`] for a
//!   `(vm, unit)` that already has a stored copy *replaces* it (the old
//!   copy's pool bytes are released). [`SwapBackend::discard`] of an
//!   absent unit is a no-op. [`SwapBackend::read`] is non-destructive:
//!   the stored copy survives, which is what lets the engine's
//!   `clean_on_disk` write-back elision (`WorkOutcome::Drop`) stay
//!   correct — a clean reclaim never re-writes, so the backend copy
//!   must remain valid.
//! * **Tier fallthrough** — reads check the compressed pool first
//!   (decompress on hit, no NVMe I/O), then NVMe. A unit that was never
//!   written (e.g. a warm-start `prime_swapped` VM) models pre-existing
//!   cold swap-file content: the read is a full NVMe I/O returning a
//!   zero-filled page. A pool-disabled (flat) backend is
//!   accounting-only: timing and counters are exact, but no content is
//!   retained and `read` leaves `out` untouched (PR 1 parity).
//! * **Writeback ordering** — when pool occupancy crosses the
//!   configured high watermark, the backend drains oldest-admitted
//!   entries in batches, *sorted ascending by `(vm, unit)`*, and
//!   coalesces runs of adjacent units into single NVMe requests. The
//!   drained units are reported in [`IoReceipt::writeback`] so the
//!   machine can update per-MM tier maps.
//! * **Fault-during-writeback** — a read of a unit whose writeback I/O
//!   is still in flight must not complete before that writeback does
//!   (the data is not on the device yet); implementations serialize the
//!   read behind the writeback's completion time.
//!
//! Completion is returned as a virtual-time stamp ([`IoReceipt::completes_at`])
//! rather than a callback: the discrete-event machine schedules the
//! wake-up event itself, exactly as it did against the flat backend.

use crate::hw::Nvme;
use crate::sim::Rng;
use crate::storage::codec::Compressed;
use crate::types::{Time, UnitId, VmId};

/// Token identifying an in-flight I/O (paired with its completion event).
pub type IoToken = u64;

/// Which storage tier currently holds (or served) a unit's swap copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SwapTier {
    /// Compressed in-memory pool (zswap-style): no device I/O to hit.
    Pool,
    /// NVMe device (flat tier / writeback target).
    Nvme,
    /// Remote memory leased from another host (PR 9, Memtrade-style):
    /// the compressed image lives in a donor shard's DRAM, so a hit
    /// pays a modeled network round trip — between a pool hit and an
    /// NVMe read. Entries reach this tier only via
    /// [`SwapBackend::remote_stage`] under a fleet-scheduler lease, and
    /// leave it via [`SwapBackend::remote_recall`] (revocation, back to
    /// NVMe) or [`SwapBackend::remote_drop`] (donor crash: content is
    /// gone and the next read re-faults as cold).
    Remote,
}

/// Policy-provided routing hint for a swap-out write.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TierHint {
    /// Backend decides (pool if compressible and within capacity).
    #[default]
    Auto,
    /// Prefer the compressed pool even for poorly-compressing data
    /// (admit unless it alone exceeds pool capacity).
    Pool,
    /// Bypass the pool: write straight to NVMe. Policies use this for
    /// units predicted never to fault again (e.g. the dt-reclaimer's
    /// maximally-cold class) so they don't churn pool capacity.
    Nvme,
}

/// Result of a [`SwapBackend`] operation: where the data landed / came
/// from and when the operation completes in virtual time.
#[derive(Debug, Clone)]
pub struct IoReceipt {
    pub token: IoToken,
    pub completes_at: Time,
    /// Tier that absorbed the write or served the read.
    pub tier: SwapTier,
    /// Units this operation's watermark writeback drained from the pool
    /// to NVMe (sorted ascending by `(vm, unit)`; usually empty).
    pub writeback: Vec<(VmId, UnitId)>,
}

/// Aggregate backend counters (per-host; per-VM splits live in
/// [`crate::metrics::Counters`]).
#[derive(Debug, Clone, Default)]
pub struct TierMetrics {
    /// Writes absorbed by the compressed pool.
    pub pool_stores: u64,
    /// Pool admissions denied (incompressible page -> straight to NVMe).
    pub pool_rejects: u64,
    /// Stored pages that were all-zero (no payload at all).
    pub pool_zero_pages: u64,
    /// Reads served by pool decompression (no NVMe I/O).
    pub pool_hits: u64,
    /// Reads that fell through the pool to NVMe (incl. cold content).
    pub pool_fallthrough: u64,
    /// Current compressed-pool occupancy in bytes.
    pub pool_bytes: u64,
    pub pool_peak_bytes: u64,
    /// Raw vs compressed size of everything admitted to the pool.
    pub raw_bytes_stored: u64,
    pub compressed_bytes_stored: u64,
    /// Watermark writeback activity.
    pub writeback_batches: u64,
    pub writeback_units: u64,
    /// NVMe request counts *after* coalescing (direct writes + writeback
    /// + reads). The tiering win is measured here.
    pub nvme_write_reqs: u64,
    /// Subset of `nvme_write_reqs` larger than one 4kB frame (huge-unit
    /// direct writes and coalesced writeback runs).
    pub nvme_huge_write_reqs: u64,
    pub nvme_reads: u64,
    pub nvme_bytes_read: u64,
    pub nvme_bytes_written: u64,
    /// SPDK DMA modeling (§5.3): 2MB ops are zero-copy, 4kB bounce.
    pub zero_copy_ops: u64,
    pub bounced_ops: u64,
    pub discards: u64,
    /// Remote tier (PR 9): entries staged out of the pool into leased
    /// remote memory, and the reads they served at network cost.
    pub remote_stages: u64,
    pub remote_hits: u64,
    /// Current stored (compressed) bytes held in the remote tier.
    pub remote_bytes: u64,
    pub remote_peak_bytes: u64,
    /// Revocation recalls (remote -> local NVMe) in units / stored bytes.
    pub remote_recalls: u64,
    pub remote_recalled_bytes: u64,
    /// Entries dropped because the donor died mid-lease: the content is
    /// gone and the next read of each re-faults as a cold NVMe miss.
    pub remote_dropped_units: u64,
    pub remote_dropped_bytes: u64,
    /// Golden-image tier (PR 10): compressed bytes the host actually
    /// holds for shared read-only clone images (dedup'd blobs, charged
    /// once per host no matter how many clones attach).
    pub image_stored_bytes: u64,
    /// Σ raw image bytes across *attached clones* — what the same data
    /// would cost if each clone carried a private copy. The dedup ratio
    /// is `image_logical_bytes / image_stored_bytes`.
    pub image_logical_bytes: u64,
    /// Reads served by decompressing a shared image blob (no NVMe I/O,
    /// no per-VM pool entry).
    pub image_hits: u64,
    pub image_hit_bytes: u64,
    /// First writes to image-backed units that broke CoW into a private
    /// shadow entry.
    pub image_cow_breaks: u64,
    /// Clones attached to a golden image on this host (lifetime count).
    pub image_attaches: u64,
}

impl TierMetrics {
    /// Raw/compressed ratio of pool-admitted data (1.0 when nothing
    /// was admitted).
    pub fn compression_ratio(&self) -> f64 {
        if self.compressed_bytes_stored == 0 {
            if self.raw_bytes_stored > 0 {
                f64::INFINITY // everything stored was zero-filled
            } else {
                1.0
            }
        } else {
            self.raw_bytes_stored as f64 / self.compressed_bytes_stored as f64
        }
    }

    /// Total NVMe requests issued (reads + coalesced writes).
    pub fn nvme_io_reqs(&self) -> u64 {
        self.nvme_reads + self.nvme_write_reqs
    }

    /// Fraction of backend reads served without NVMe I/O.
    pub fn pool_hit_rate(&self) -> f64 {
        let total = self.pool_hits + self.pool_fallthrough;
        if total == 0 {
            0.0
        } else {
            self.pool_hits as f64 / total as f64
        }
    }

    /// Golden-image dedup ratio: logical (per-clone) bytes over the
    /// bytes the host actually stores. 1.0 when no image is held; > 1.0
    /// as soon as two clones share one image.
    pub fn image_dedup_ratio(&self) -> f64 {
        if self.image_stored_bytes == 0 {
            1.0
        } else {
            self.image_logical_bytes as f64 / self.image_stored_bytes as f64
        }
    }
}

/// Lightweight listing of one stored unit (no payload): what the fleet
/// scheduler's VM state migration iterates when staging cold transfers.
/// The `stamp` is the backend's per-entry replacement generation — a
/// pre-copied unit whose stamp no longer matches was rewritten on the
/// donor and must be re-copied at the stop-and-copy flip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnitSummary {
    pub unit: UnitId,
    pub stamp: u32,
    pub tier: SwapTier,
    /// Raw (uncompressed) length — the bytes a migration transfers.
    pub raw_bytes: u64,
    /// Pool bytes the stored image occupies (0 on the NVMe tier).
    pub stored_bytes: u64,
}

/// A self-contained exported swap copy of one unit, portable between
/// backends (VM state migration). Carries the actual page image so the
/// hand-off is content-preserving, plus the donor-side stamp for the
/// pre-copy invalidation check.
#[derive(Debug, Clone)]
pub struct PortableUnit {
    pub unit: UnitId,
    pub stamp: u32,
    pub tier: SwapTier,
    pub img: Compressed,
}

/// Swap storage behind the Swapper workers. See the module docs for the
/// ordering / idempotence / fallthrough contract. `Send` because each
/// backend belongs to one machine and the fleet scheduler runs machines
/// on worker threads between fleet ticks.
pub trait SwapBackend: Send {
    /// Store `data` as the swap copy of `(vm, unit)`, replacing any
    /// previous copy. `hint` routes between tiers; the returned receipt
    /// says where the data landed and when the store completes.
    #[allow(clippy::too_many_arguments)]
    fn write(
        &mut self,
        vm: VmId,
        unit: UnitId,
        data: &[u8],
        hint: TierHint,
        now: Time,
        nvme: &mut Nvme,
        rng: &mut Rng,
    ) -> IoReceipt;

    /// Fetch the swap copy of `(vm, unit)` into `out` (resized to the
    /// unit's length). `bytes` is the expected unit size, used to model
    /// cold (never-written) content. Non-destructive.
    #[allow(clippy::too_many_arguments)]
    fn read(
        &mut self,
        vm: VmId,
        unit: UnitId,
        bytes: u64,
        out: &mut Vec<u8>,
        now: Time,
        nvme: &mut Nvme,
        rng: &mut Rng,
    ) -> IoReceipt;

    /// Drop the stored copy, releasing pool space. No-op if absent.
    fn discard(&mut self, vm: VmId, unit: UnitId);

    /// Tier currently holding the unit's copy (None if never written or
    /// discarded).
    fn tier_of(&self, vm: VmId, unit: UnitId) -> Option<SwapTier>;

    /// Aggregate counters.
    fn metrics(&self) -> &TierMetrics;

    /// Assign a VM to a pool-partition class (SLA-driven; see
    /// [`SwapBackend::set_class_quotas`]). Default: ignored — backends
    /// without partitions treat the pool as one shared arena.
    fn set_vm_class(&mut self, _vm: VmId, _class: u8) {}

    /// Partition the compressed pool: `quotas[c]` bytes reserved for
    /// class `c`. Admission and watermark writeback are then enforced
    /// per class, so one SLA class can never evict another's pool
    /// residency. An empty slice restores the shared arena.
    fn set_class_quotas(&mut self, _quotas: &[u64]) {}

    /// Compressed-pool bytes currently held by a partition class
    /// (0 for backends without partitions).
    fn class_pool_bytes(&self, _class: u8) -> u64 {
        0
    }

    /// Retune pool admission at runtime (PR 8 satellite): admit a page
    /// only while its compressed size is below `reject_pct`% of raw.
    /// Driven by the dt-reclaimer's age histogram when
    /// `adaptive_pool_admission` is on. Default: ignored — backends
    /// without a compressed pool have no admission decision.
    fn set_pool_admission(&mut self, _reject_pct: u8) {}

    // ---- VM state migration (fleet scheduler hand-off) ----
    //
    // Contract: `list_units` is a cheap, payload-free snapshot in
    // ascending unit order; `export_unit` clones one unit's copy
    // (non-destructive — the donor keeps serving faults until the
    // flip); `import_unit` places an exported copy under the target's
    // VM id, demoting a pool-tier image to NVMe when the target pool /
    // class quota cannot absorb it (returns where it landed);
    // `forget_vm` drops every copy a VM left behind, releasing pool
    // space (the donor side of the atomic hand-off). Imported entries
    // are immediately readable (any writeback serialization was the
    // donor's; the transfer itself is accounted by the migration
    // ledger, not by backend timing).

    /// Snapshot of every stored unit of a VM, ascending by unit id.
    fn list_units(&self, _vm: VmId) -> Vec<UnitSummary> {
        Vec::new()
    }

    /// Clone one unit's stored copy for transfer (None if absent).
    fn export_unit(&self, _vm: VmId, _unit: UnitId) -> Option<PortableUnit> {
        None
    }

    /// Place an exported copy under `vm`, replacing any previous copy.
    /// Returns the tier that actually absorbed it. Backends that can
    /// receive migrations MUST override this: the default refuses
    /// (panics) rather than silently dropping a migrated VM's swap
    /// copy and reporting success.
    fn import_unit(&mut self, _vm: VmId, u: PortableUnit) -> SwapTier {
        panic!(
            "SwapBackend::import_unit not implemented by this backend; \
             refusing to drop the migrated copy of unit {}",
            u.unit
        );
    }

    /// Drop every stored copy of `vm` (releasing pool space). Returns
    /// how many entries were dropped.
    fn forget_vm(&mut self, _vm: VmId) -> usize {
        0
    }

    /// Crash salvage: what survives of a VM's swap state when this
    /// backend's host dies. NVMe receipts are durable — they are
    /// exported for re-import on the rebuild shard. Pool-resident
    /// copies lived in the dead host's DRAM and are genuinely lost:
    /// they are only *counted* (units, raw bytes); the rebuilt VM
    /// re-synthesizes their content as cold faults on first touch
    /// (the never-written-unit fallthrough in the read contract).
    /// The VM's entries are dropped either way — the backend belongs
    /// to a machine that no longer exists.
    fn salvage_vm(&mut self, vm: VmId) -> CrashSalvage {
        let mut s = CrashSalvage::default();
        for u in self.list_units(vm) {
            match u.tier {
                SwapTier::Nvme => {
                    if let Some(p) = self.export_unit(vm, u.unit) {
                        s.salvaged_bytes += u.raw_bytes;
                        s.units.push(p);
                    }
                }
                // Pool copies lived in this host's DRAM; remote copies
                // lived in a donor's DRAM under a lease that dies with
                // this host. Both are genuinely lost.
                SwapTier::Pool | SwapTier::Remote => {
                    s.lost_units += 1;
                    s.lost_bytes += u.raw_bytes;
                }
            }
        }
        self.forget_vm(vm);
        s
    }

    // ---- Remote marketplace tier (PR 9) ----
    //
    // Contract: the fleet scheduler drives all three calls at the
    // single-threaded fleet-tick barrier, never mid-epoch. `remote_stage`
    // retags the coldest pool entries (oldest-admitted first, exactly
    // the watermark drain's victim order) as `SwapTier::Remote` until
    // `max_bytes` of stored bytes moved — pool occupancy drops by what
    // was staged, so staging extends effective pool capacity instead of
    // spilling to NVMe. `remote_recall` moves the oldest-staged entries
    // back as paced NVMe writes (revocation). `remote_drop` loses every
    // remote entry's content (donor crash): subsequent reads take the
    // never-written cold-miss path. Defaults are no-ops so accounting-
    // only backends stay remote-free.

    /// Retag up to `max_bytes` stored bytes of the coldest pool entries
    /// as remote. Returns the stored bytes actually staged.
    fn remote_stage(&mut self, _max_bytes: u64) -> u64 {
        0
    }

    /// Recall up to `max_bytes` stored bytes of remote entries back to
    /// local NVMe (oldest-staged first), issuing the writeback I/O.
    /// Returns the stored bytes actually recalled.
    fn remote_recall(&mut self, _max_bytes: u64, _now: Time, _nvme: &mut Nvme) -> u64 {
        0
    }

    /// Drop every remote entry (the donor holding them crashed).
    /// Returns `(units, stored_bytes)` dropped.
    fn remote_drop(&mut self) -> (u64, u64) {
        (0, 0)
    }

    /// Stored bytes currently held in the remote tier.
    fn remote_bytes(&self) -> u64 {
        0
    }

    // ---- Golden-image tier (PR 10, clone-from-image admission) ----
    //
    // Contract: a golden image is *host-shared read-only* state keyed
    // by image id, not per-VM state — `list_units`, `export_unit`,
    // `salvage_vm` and migration never see it, so a clone's crash or
    // migration cannot damage the image other clones read from.
    // `install_image_unit` stores one unit's content into the image,
    // content-addressed: byte-identical compressed blobs across units
    // (and across images) are stored once and refcounted, which is
    // what makes the dedup ratio measurable. `attach_image` binds a VM
    // to an image and bumps its refcount; detach happens inside
    // `forget_vm` (migration, crash rebuild, or teardown), and the
    // image's storage is released only when the last attached clone on
    // the host is forgotten. Reads of an attached VM's units that have
    // no private copy fall through to the image (decompress at pool
    // cost); the first *write* to such a unit breaks CoW by creating
    // an ordinary private entry that shadows the image from then on.
    // Defaults are no-ops so accounting-only backends stay image-free.

    /// Store one unit's content into golden image `image` (dedup'd,
    /// content-addressed). Installing the same unit twice replaces the
    /// mapping. No-op on backends without an image tier.
    fn install_image_unit(&mut self, _image: u32, _unit: UnitId, _data: &[u8]) {}

    /// Attach `vm` to `image`: reads of units the image covers fall
    /// through to it until a private write shadows them. Bumps the
    /// image refcount.
    fn attach_image(&mut self, _vm: VmId, _image: u32) {}

    /// Image the VM is attached to, if any.
    fn image_of(&self, _vm: VmId) -> Option<u32> {
        None
    }

    /// Units mapped by golden image `image` (0 = not installed here).
    fn image_units(&self, _image: u32) -> u64 {
        0
    }
}

/// What [`SwapBackend::salvage_vm`] recovered from a dead host: the
/// durable NVMe copies, plus the tally of pool-resident state that died
/// with the host's DRAM.
#[derive(Debug, Clone, Default)]
pub struct CrashSalvage {
    /// Durable NVMe copies, ascending by unit id, ready to re-import.
    pub units: Vec<PortableUnit>,
    /// Raw bytes of the salvaged NVMe copies.
    pub salvaged_bytes: u64,
    /// Pool-resident-only units lost with the host.
    pub lost_units: u64,
    /// Raw bytes of the lost pool copies.
    pub lost_bytes: u64,
}
