//! SPDK-like storage backend: one polling core, a lock-free request
//! queue per MM, zero-copy DMA for 2MB pages and bounce buffers for 4kB
//! (SPDK cannot DMA unaligned 4k directly, §5.3).
//!
//! Swapper worker threads enqueue a request and sleep on a semaphore;
//! the backend polls, programs the NVMe DMA engine, and wakes the worker
//! on completion. We model the poll pickup as a uniformly distributed
//! delay in [0, poll_interval), the DMA via [`crate::hw::Nvme`], and the
//! 4kB bounce copy as a fixed per-op cost.

use crate::config::SwCost;
use crate::hw::{IoKind, Nvme};
use crate::sim::Rng;
use crate::types::{Time, UnitId, VmId, FRAME_BYTES};

/// Token identifying an in-flight I/O (paired with its completion event).
pub type IoToken = u64;

#[derive(Debug, Clone)]
pub struct IoRequest {
    pub token: IoToken,
    pub vm: VmId,
    pub unit: UnitId,
    pub bytes: u64,
    pub kind: IoKind,
    pub submitted_at: Time,
    pub completes_at: Time,
}

#[derive(Debug)]
pub struct StorageBackend {
    next_token: IoToken,
    poll_ns: Time,
    bounce_copy_4k_ns: Time,
    pub inflight: u64,
    pub completed: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Zero-copy ops (2MB DMA straight into VM memory).
    pub zero_copy_ops: u64,
    /// Bounce-buffered ops (4kB).
    pub bounced_ops: u64,
}

impl StorageBackend {
    pub fn new(sw: &SwCost) -> Self {
        StorageBackend {
            next_token: 0,
            poll_ns: sw.backend_poll_ns,
            bounce_copy_4k_ns: sw.bounce_copy_4k_ns,
            inflight: 0,
            completed: 0,
            bytes_read: 0,
            bytes_written: 0,
            zero_copy_ops: 0,
            bounced_ops: 0,
        }
    }

    /// Submit a swap I/O at `now`; returns the request with its
    /// completion time (the machine schedules the IoDone event).
    pub fn submit(
        &mut self,
        vm: VmId,
        unit: UnitId,
        bytes: u64,
        kind: IoKind,
        now: Time,
        nvme: &mut Nvme,
        rng: &mut Rng,
    ) -> IoRequest {
        let token = self.next_token;
        self.next_token += 1;
        self.inflight += 1;

        // Poll-loop pickup jitter.
        let pickup = now + rng.below(self.poll_ns.max(1));

        // 2MB: program the DMA engine against VM memory directly
        // (zero-copy). 4kB: DMA into a bounce buffer, then copy.
        let extra = if bytes > FRAME_BYTES {
            self.zero_copy_ops += 1;
            0
        } else {
            self.bounced_ops += 1;
            self.bounce_copy_4k_ns
        };

        match kind {
            IoKind::Read => self.bytes_read += bytes,
            IoKind::Write => self.bytes_written += bytes,
        }

        let done = nvme.submit(pickup, bytes, kind) + extra;
        IoRequest { token, vm, unit, bytes, kind, submitted_at: now, completes_at: done }
    }

    /// Mark an I/O completed (wake the waiting swapper thread).
    pub fn complete(&mut self, _req: &IoRequest) {
        self.inflight -= 1;
        self.completed += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::types::HUGE_BYTES;

    fn setup() -> (StorageBackend, Nvme, Rng) {
        (
            StorageBackend::new(&SwCost::default()),
            Nvme::new(&HwConfig::default()),
            Rng::new(3),
        )
    }

    #[test]
    fn huge_is_zero_copy_small_is_bounced() {
        let (mut b, mut n, mut rng) = setup();
        b.submit(0, 1, HUGE_BYTES, IoKind::Read, 0, &mut n, &mut rng);
        b.submit(0, 2, FRAME_BYTES, IoKind::Read, 0, &mut n, &mut rng);
        assert_eq!(b.zero_copy_ops, 1);
        assert_eq!(b.bounced_ops, 1);
        assert_eq!(b.inflight, 2);
    }

    #[test]
    fn completion_accounting() {
        let (mut b, mut n, mut rng) = setup();
        let r = b.submit(0, 1, FRAME_BYTES, IoKind::Write, 100, &mut n, &mut rng);
        assert!(r.completes_at > 100);
        b.complete(&r);
        assert_eq!(b.inflight, 0);
        assert_eq!(b.completed, 1);
        assert_eq!(b.bytes_written, FRAME_BYTES);
    }

    #[test]
    fn tokens_unique() {
        let (mut b, mut n, mut rng) = setup();
        let a = b.submit(0, 1, FRAME_BYTES, IoKind::Read, 0, &mut n, &mut rng);
        let c = b.submit(0, 1, FRAME_BYTES, IoKind::Read, 0, &mut n, &mut rng);
        assert_ne!(a.token, c.token);
    }
}
