//! Deterministic guest-page-content model.
//!
//! The substrate is a discrete-event simulation — there is no real
//! guest RAM to hand the storage backend — so the machine synthesizes
//! each unit's content deterministically from `(seed, unit)` when a
//! swap-out needs bytes. The mix mirrors what cloud-VM memory studies
//! (zswap/Memtrade) report: a large zero/low-entropy fraction plus an
//! incompressible remainder. The same unit always regenerates the same
//! bytes, so backend read-backs can be checked for integrity in tests.

use crate::sim::Rng;
use crate::types::UnitId;

/// Fractions of the unit population per content class (must sum ≤ 1;
/// the remainder is incompressible random data).
#[derive(Debug, Clone)]
pub struct ContentMix {
    /// All-zero units (untouched allocator slack, zeroed buffers).
    pub zero: f64,
    /// Low-entropy units: long constant runs (heap metadata, caches).
    pub pattern: f64,
}

impl Default for ContentMix {
    fn default() -> Self {
        ContentMix { zero: 0.30, pattern: 0.40 }
    }
}

impl ContentMix {
    /// Everything compressible goes through the pool for free/cheap.
    pub fn all_random() -> Self {
        ContentMix { zero: 0.0, pattern: 0.0 }
    }
    pub fn all_zero() -> Self {
        ContentMix { zero: 1.0, pattern: 0.0 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContentClass {
    Zero,
    Pattern,
    Random,
}

/// Per-VM content generator. Class assignment and bytes are pure
/// functions of `(seed, unit)` — regenerating a unit always yields
/// identical content.
#[derive(Debug, Clone)]
pub struct ContentModel {
    seed: u64,
    mix: ContentMix,
}

impl ContentModel {
    pub fn new(seed: u64, mix: ContentMix) -> Self {
        ContentModel { seed, mix }
    }

    fn unit_rng(&self, unit: UnitId) -> Rng {
        Rng::new(self.seed ^ unit.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Content class of a unit.
    pub fn class_of(&self, unit: UnitId) -> ContentClass {
        let mut rng = self.unit_rng(unit);
        let x = rng.f64();
        if x < self.mix.zero {
            ContentClass::Zero
        } else if x < self.mix.zero + self.mix.pattern {
            ContentClass::Pattern
        } else {
            ContentClass::Random
        }
    }

    /// Synthesize the unit's page image into `buf` (resized to
    /// `unit_bytes`; capacity is reused across calls).
    pub fn fill(&self, unit: UnitId, unit_bytes: u64, buf: &mut Vec<u8>) {
        let n = unit_bytes as usize;
        buf.clear();
        match self.class_of(unit) {
            ContentClass::Zero => buf.resize(n, 0),
            ContentClass::Pattern => {
                // A handful of long constant runs.
                let mut rng = self.unit_rng(unit ^ 0xF00D);
                while buf.len() < n {
                    let run = (256 + rng.below(4096) as usize).min(n - buf.len());
                    let v = rng.below(256) as u8;
                    let start = buf.len();
                    buf.resize(start + run, v);
                }
            }
            ContentClass::Random => {
                let mut rng = self.unit_rng(unit ^ 0xBEEF);
                buf.resize(n, 0);
                for chunk in buf.chunks_exact_mut(8) {
                    chunk.copy_from_slice(&rng.next_u64().to_ne_bytes());
                }
                let tail = buf.len() - buf.len() % 8;
                let last = rng.next_u64().to_ne_bytes();
                let rest = buf.len() - tail;
                buf[tail..].copy_from_slice(&last[..rest]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_unit() {
        let m = ContentModel::new(7, ContentMix::default());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        m.fill(42, 4096, &mut a);
        m.fill(42, 4096, &mut b);
        assert_eq!(a, b);
        m.fill(43, 4096, &mut b);
        // Different units differ unless both are zero-class.
        if m.class_of(42) != ContentClass::Zero || m.class_of(43) != ContentClass::Zero {
            assert_ne!(a, b);
        }
    }

    #[test]
    fn mix_fractions_roughly_hold() {
        let m = ContentModel::new(3, ContentMix::default());
        let mut counts = [0u64; 3];
        for u in 0..4000u64 {
            match m.class_of(u) {
                ContentClass::Zero => counts[0] += 1,
                ContentClass::Pattern => counts[1] += 1,
                ContentClass::Random => counts[2] += 1,
            }
        }
        let frac = |c: u64| c as f64 / 4000.0;
        assert!((frac(counts[0]) - 0.30).abs() < 0.05, "{counts:?}");
        assert!((frac(counts[1]) - 0.40).abs() < 0.05, "{counts:?}");
        assert!((frac(counts[2]) - 0.30).abs() < 0.05, "{counts:?}");
    }

    #[test]
    fn classes_compress_as_expected() {
        use crate::storage::codec;
        let m = ContentModel::new(9, ContentMix::default());
        let mut buf = Vec::new();
        let (mut saw_zero, mut saw_pattern, mut saw_random) = (false, false, false);
        for u in 0..200u64 {
            m.fill(u, 4096, &mut buf);
            let img = codec::compress(&buf);
            match m.class_of(u) {
                ContentClass::Zero => {
                    assert_eq!(img.stored_bytes(), 0);
                    saw_zero = true;
                }
                ContentClass::Pattern => {
                    let stored = img.stored_bytes();
                    assert!(stored < 2048, "pattern unit {u} stored {stored}");
                    saw_pattern = true;
                }
                ContentClass::Random => {
                    assert!(img.stored_bytes() >= 4096 * 9 / 10);
                    saw_random = true;
                }
            }
        }
        assert!(saw_zero && saw_pattern && saw_random);
    }
}
