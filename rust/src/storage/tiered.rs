//! [`TieredBackend`]: the two-tier swap store — a zswap-style
//! compressed in-memory pool in front of the SPDK/NVMe path — behind
//! the [`SwapBackend`] trait.
//!
//! Write path: poll-loop pickup (same jitter model as the flat PR 1
//! backend), then a compression attempt. Compressible pages are
//! admitted to the pool (zero pages store no payload at all);
//! incompressible pages and [`TierHint::Nvme`]-routed pages go straight
//! to the device with the §5.3 DMA model (2MB zero-copy, 4kB bounce
//! buffer). When pool occupancy crosses the high watermark, the
//! oldest-admitted entries are drained to NVMe in batches: victims are
//! sorted by `(vm, unit)` and runs of adjacent units are coalesced into
//! single large sequential I/O requests — the request-count win the
//! `storage_tiers` bench series and the acceptance tests measure.
//!
//! Read path: pool hit = decompress only (no NVMe I/O); NVMe-tier reads
//! serialize behind any still-in-flight writeback of the same unit
//! (fault-during-writeback race); never-written units model cold
//! pre-existing swap-file content (zero-filled, full NVMe read) so
//! warm-start (`prime_swapped`) experiments keep the flat backend's
//! exact timing.
//!
//! With `TierConfig::flat()` (pool capacity 0) the backend is
//! *accounting-only*, exactly like the PR 1 flat backend: no codec
//! work, no content retained, `read` leaves `out` untouched, and every
//! op reproduces the PR 1 cost structure — the paper-figure
//! experiments run in that mode.

use std::collections::{BTreeMap, VecDeque};

use crate::config::{SwCost, TierConfig};
use crate::hw::{IoKind, Nvme};
use crate::sim::Rng;
use crate::storage::backend::{
    IoReceipt, IoToken, PortableUnit, SwapBackend, SwapTier, TierHint, TierMetrics, UnitSummary,
};
use crate::storage::codec::{self, Compressed};
use crate::types::{Time, UnitId, VmId, FRAME_BYTES};

#[derive(Debug)]
struct Entry {
    img: Compressed,
    tier: SwapTier,
    /// Generation stamp; a drain-FIFO reference is live iff it matches.
    stamp: u32,
    /// Completion time of the writeback (or direct write) that put the
    /// copy on NVMe; reads of this unit cannot start earlier.
    nvme_ready_at: Time,
    /// Pool-partition class the entry's bytes are accounted to.
    class: u8,
}

/// One shared read-only golden image (PR 10): content-addressed
/// compressed blobs with a unit → blob mapping, refcounted across
/// attached clones. Byte-identical compressed page images collapse to
/// one stored blob — the dedup the clone-storm experiment measures.
/// Image state is keyed by image id, not VM id, so per-VM salvage /
/// export / migration never touches it.
#[derive(Debug, Default)]
struct GoldenImage {
    blobs: Vec<Compressed>,
    /// Content-address index: serialized blob bytes → blob slot.
    dedup: BTreeMap<Vec<u8>, u32>,
    /// Unit → blob slot.
    map: BTreeMap<UnitId, u32>,
    /// Σ raw bytes of the mapped units (what one clone's private copy
    /// of the image would occupy uncompressed).
    raw_bytes: u64,
    /// Σ stored bytes of the dedup'd blobs (what the host actually
    /// holds, once, for every attached clone).
    stored_bytes: u64,
    /// Attached clones on this host; the image is dropped at zero.
    refs: u32,
}

/// Content-address key of a compressed blob (discriminant + raw length
/// + payload): byte-identical page images — the common case across
/// units synthesized from one deterministic content seed — collapse to
/// a single stored blob.
fn blob_key(img: &Compressed) -> Vec<u8> {
    let mut k = Vec::with_capacity(5 + img.stored_bytes() as usize);
    match img {
        Compressed::Zero { len } => {
            k.push(0);
            k.extend_from_slice(&len.to_le_bytes());
        }
        Compressed::Rle { len, data } => {
            k.push(1);
            k.extend_from_slice(&len.to_le_bytes());
            k.extend_from_slice(data);
        }
        Compressed::Raw(data) => {
            k.push(2);
            k.extend_from_slice(data);
        }
    }
    k
}

/// Two-tier swap store: compressed pool + NVMe writeback.
#[derive(Debug)]
pub struct TieredBackend {
    cfg: TierConfig,
    poll_ns: Time,
    bounce_copy_4k_ns: Time,
    compress_4k_ns: Time,
    decompress_4k_ns: Time,
    /// Per-VM unit stores, grown lazily.
    stores: Vec<Vec<Option<Entry>>>,
    /// Pool admission order per partition class: `(vm, unit, stamp)`,
    /// lazily invalidated (same tombstone idiom as the Swapper queue).
    /// Index 0 is the shared arena when no quotas are configured.
    drain_fifo: Vec<VecDeque<(VmId, UnitId, u32)>>,
    /// Remote-tier staging order (oldest staged first), same
    /// stamp-tombstone idiom: revocation recalls pop from the front.
    remote_fifo: VecDeque<(VmId, UnitId, u32)>,
    /// SLA pool partitions: `class_quota[c]` bytes reserved for class
    /// `c` (empty = one shared arena); `class_bytes[c]` tracks
    /// occupancy; `vm_class` maps VMs to classes.
    class_quota: Vec<u64>,
    class_bytes: Vec<u64>,
    vm_class: Vec<u8>,
    /// Globally monotonic entry stamp: a replaced entry always gets a
    /// fresh stamp, so stale FIFO references can never match it.
    next_stamp: u32,
    next_token: IoToken,
    /// Pool reject threshold pushed by the dt-reclaimer's adaptive
    /// admission (overrides `cfg.reject_pct` when set).
    admission_override: Option<u8>,
    /// Golden images held by this host (PR 10), and which image each
    /// attached clone reads through.
    images: BTreeMap<u32, GoldenImage>,
    vm_image: BTreeMap<VmId, u32>,
    metrics: TierMetrics,
}

impl TieredBackend {
    pub fn new(cfg: &TierConfig, sw: &SwCost) -> Self {
        TieredBackend {
            cfg: cfg.clone(),
            poll_ns: sw.backend_poll_ns,
            bounce_copy_4k_ns: sw.bounce_copy_4k_ns,
            compress_4k_ns: sw.compress_4k_ns,
            decompress_4k_ns: sw.decompress_4k_ns,
            stores: vec![],
            drain_fifo: vec![VecDeque::new()],
            remote_fifo: VecDeque::new(),
            class_quota: vec![],
            class_bytes: vec![0],
            vm_class: vec![],
            next_stamp: 1,
            next_token: 0,
            admission_override: None,
            images: BTreeMap::new(),
            vm_image: BTreeMap::new(),
            metrics: TierMetrics::default(),
        }
    }

    /// Flat single-tier backend (the paper's testbed shape).
    pub fn flat(sw: &SwCost) -> Self {
        Self::new(&TierConfig::flat(), sw)
    }

    fn slot_mut(&mut self, vm: VmId, unit: UnitId) -> &mut Option<Entry> {
        if self.stores.len() <= vm {
            self.stores.resize_with(vm + 1, Vec::new);
        }
        let store = &mut self.stores[vm];
        if store.len() <= unit as usize {
            store.resize_with(unit as usize + 1, || None);
        }
        &mut store[unit as usize]
    }

    fn entry(&self, vm: VmId, unit: UnitId) -> Option<&Entry> {
        self.stores.get(vm)?.get(unit as usize)?.as_ref()
    }

    /// Per-op CPU cost of the codec, scaled from the 4kB calibration.
    fn scaled(&self, per_4k: Time, bytes: u64) -> Time {
        per_4k * bytes.div_ceil(FRAME_BYTES)
    }

    /// Partition class of a VM (always 0 in the shared arena).
    fn class_of(&self, vm: VmId) -> usize {
        if self.class_quota.is_empty() {
            return 0;
        }
        let c = self.vm_class.get(vm).copied().unwrap_or(0) as usize;
        c.min(self.class_quota.len() - 1)
    }

    /// (quota, high watermark, low watermark) bytes of a class — the
    /// whole-pool figures when unpartitioned.
    fn class_limits(&self, class: usize) -> (u64, u64, u64) {
        if self.class_quota.is_empty() {
            (
                self.cfg.pool_capacity_bytes,
                self.cfg.high_watermark_bytes(),
                self.cfg.low_watermark_bytes(),
            )
        } else {
            let q = self.class_quota[class];
            (
                q,
                q / 100 * self.cfg.high_watermark_pct as u64,
                q / 100 * self.cfg.low_watermark_pct as u64,
            )
        }
    }

    /// Release a unit's previous copy (write replacement / discard).
    fn remove_entry(&mut self, vm: VmId, unit: UnitId) -> bool {
        let slot = self.slot_mut(vm, unit);
        match slot.take() {
            Some(e) => {
                match e.tier {
                    SwapTier::Pool => {
                        self.metrics.pool_bytes -= e.img.stored_bytes();
                        self.class_bytes[e.class as usize] -= e.img.stored_bytes();
                    }
                    // Stale remote-FIFO references tombstone via stamp.
                    SwapTier::Remote => self.metrics.remote_bytes -= e.img.stored_bytes(),
                    SwapTier::Nvme => {}
                }
                true
            }
            None => false,
        }
    }

    /// Shared-image blob covering `(vm, unit)`, if the VM is an
    /// attached clone and no private copy shadows the image.
    fn image_blob(&self, vm: VmId, unit: UnitId) -> Option<&Compressed> {
        let gi = self.images.get(self.vm_image.get(&vm)?)?;
        gi.map.get(&unit).map(|&b| &gi.blobs[b as usize])
    }

    /// Detach a clone from its golden image (refcount down; the image's
    /// stored bytes are released only when the last clone detaches).
    fn detach_image(&mut self, vm: VmId) {
        let Some(img_id) = self.vm_image.remove(&vm) else { return };
        let Some(gi) = self.images.get_mut(&img_id) else { return };
        gi.refs -= 1;
        self.metrics.image_logical_bytes -= gi.raw_bytes;
        if gi.refs == 0 {
            let stored = gi.stored_bytes;
            self.images.remove(&img_id);
            self.metrics.image_stored_bytes -= stored;
        }
    }

    /// NVMe DMA submission with the §5.3 bounce/zero-copy model.
    fn nvme_op(&mut self, start: Time, bytes: u64, kind: IoKind, nvme: &mut Nvme) -> Time {
        let extra = if bytes > FRAME_BYTES {
            self.metrics.zero_copy_ops += 1;
            0
        } else {
            self.metrics.bounced_ops += 1;
            self.bounce_copy_4k_ns
        };
        match kind {
            IoKind::Read => {
                self.metrics.nvme_reads += 1;
                self.metrics.nvme_bytes_read += bytes;
            }
            IoKind::Write => {
                self.metrics.nvme_write_reqs += 1;
                if bytes > FRAME_BYTES {
                    self.metrics.nvme_huge_write_reqs += 1;
                }
                self.metrics.nvme_bytes_written += bytes;
            }
        }
        nvme.submit(start, bytes, kind) + extra
    }

    /// Drain one partition class down to its low watermark:
    /// oldest-admitted first, sorted by `(vm, unit)` per batch,
    /// adjacent units coalesced into single NVMe requests. Returns the
    /// drained units in sorted order. In the shared arena, class 0
    /// covers the whole pool — identical to the pre-partition behavior.
    fn drain(&mut self, class: usize, now: Time, nvme: &mut Nvme) -> Vec<(VmId, UnitId)> {
        let (_, _, low) = self.class_limits(class);
        let mut all_drained = Vec::new();
        while self.class_bytes[class] > low {
            // Collect one batch of live FIFO entries.
            let mut victims: Vec<(VmId, UnitId)> = Vec::new();
            let mut freed = 0u64;
            while victims.len() < self.cfg.writeback_batch {
                if self.class_bytes[class] - freed <= low {
                    break;
                }
                let Some((vm, unit, stamp)) = self.drain_fifo[class].pop_front() else { break };
                let Some(e) = self.entry(vm, unit) else { continue };
                if e.stamp != stamp || e.tier != SwapTier::Pool {
                    continue; // stale reference (replaced or already drained)
                }
                freed += e.img.stored_bytes();
                victims.push((vm, unit));
            }
            if victims.is_empty() {
                break; // only zero pages (never queued) remain
            }
            victims.sort_unstable();
            self.metrics.writeback_batches += 1;
            self.metrics.writeback_units += victims.len() as u64;

            // Coalesce runs of adjacent units into single sequential I/Os.
            let mut i = 0;
            while i < victims.len() {
                let (vm0, _) = victims[i];
                let mut j = i + 1;
                while j < victims.len()
                    && victims[j].0 == vm0
                    && victims[j].1 == victims[j - 1].1 + 1
                    && (j - i) < self.cfg.max_coalesce_units as usize
                {
                    j += 1;
                }
                let bytes: u64 = victims[i..j]
                    .iter()
                    .map(|&(vm, u)| {
                        self.entry(vm, u).map(|e| e.img.raw_len() as u64).unwrap_or(0)
                    })
                    .sum();
                let done = self.nvme_op(now, bytes, IoKind::Write, nvme);
                for &(vm, u) in &victims[i..j] {
                    let mut freed_now = 0;
                    let mut freed_class = 0;
                    if let Some(e) = self.slot_mut(vm, u).as_mut() {
                        freed_now = e.img.stored_bytes();
                        freed_class = e.class as usize;
                        e.tier = SwapTier::Nvme;
                        e.nvme_ready_at = done;
                    }
                    self.metrics.pool_bytes -= freed_now;
                    self.class_bytes[freed_class] -= freed_now;
                }
                i = j;
            }
            all_drained.extend_from_slice(&victims);
        }
        all_drained
    }
}

impl SwapBackend for TieredBackend {
    #[allow(clippy::too_many_arguments)]
    fn write(
        &mut self,
        vm: VmId,
        unit: UnitId,
        data: &[u8],
        hint: TierHint,
        now: Time,
        nvme: &mut Nvme,
        rng: &mut Rng,
    ) -> IoReceipt {
        let token = self.next_token;
        self.next_token += 1;
        let raw = data.len() as u64;
        // Poll-loop pickup jitter (one draw, flat-backend compatible).
        let pickup = now + rng.below(self.poll_ns.max(1));
        let had_private = self.remove_entry(vm, unit);
        // First write to an image-backed unit with no private copy yet:
        // CoW break. The private entry stored below permanently shadows
        // the read-only image for this unit; the image itself is
        // untouched (other clones keep reading it).
        if !had_private && self.image_blob(vm, unit).is_some() {
            self.metrics.image_cow_breaks += 1;
        }

        let mut cpu = 0;
        let mut writeback = Vec::new();
        let mut nvme_img = None;
        if self.cfg.pool_enabled() && hint != TierHint::Nvme {
            let class = self.class_of(vm);
            let (quota, high, _) = self.class_limits(class);
            cpu = self.scaled(self.compress_4k_ns, raw);
            let img = codec::compress(data);
            let stored = img.stored_bytes();
            let reject_pct = self.admission_override.unwrap_or(self.cfg.reject_pct);
            let admit = hint == TierHint::Pool || stored * 100 < raw * reject_pct as u64;
            if admit
                && (self.class_bytes[class] + stored > high
                    || self.metrics.pool_bytes + stored > self.cfg.high_watermark_bytes())
            {
                // Make room before inserting — draining only this
                // class's entries (quota enforcement: one SLA class
                // never evicts another's pool residency).
                writeback = self.drain(class, now, nvme);
            }
            // Admission must never push occupancy past the class quota
            // or pool capacity — an image that still doesn't fit after
            // draining (e.g. a raw 2MB unit in a tiny partition) falls
            // through to NVMe.
            if admit
                && self.metrics.pool_bytes + stored <= self.cfg.pool_capacity_bytes
                && self.class_bytes[class] + stored <= quota
            {
                let is_zero = matches!(img, Compressed::Zero { .. });
                let stamp = self.next_stamp;
                self.next_stamp = self.next_stamp.wrapping_add(1);
                *self.slot_mut(vm, unit) = Some(Entry {
                    img,
                    tier: SwapTier::Pool,
                    stamp,
                    nvme_ready_at: 0,
                    class: class as u8,
                });
                if !is_zero {
                    // Zero pages occupy no bytes: nothing to ever drain.
                    self.drain_fifo[class].push_back((vm, unit, stamp));
                } else {
                    self.metrics.pool_zero_pages += 1;
                }
                self.metrics.pool_stores += 1;
                self.metrics.pool_bytes += stored;
                self.class_bytes[class] += stored;
                self.metrics.pool_peak_bytes =
                    self.metrics.pool_peak_bytes.max(self.metrics.pool_bytes);
                self.metrics.raw_bytes_stored += raw;
                self.metrics.compressed_bytes_stored += stored;
                return IoReceipt {
                    token,
                    completes_at: pickup + cpu,
                    tier: SwapTier::Pool,
                    writeback,
                };
            }
            self.metrics.pool_rejects += 1;
            // Keep the compressed image: NVMe-tier entries in a
            // pool-enabled backend store their content compressed
            // (simulation fidelity, not timing).
            nvme_img = Some(img);
        }

        // NVMe path (flat mode, explicit routing, or pool reject):
        // identical cost structure to the PR 1 backend (pickup + device
        // + bounce). Flat mode is accounting-only — no content kept.
        let done = self.nvme_op(pickup + cpu, raw, IoKind::Write, nvme);
        let img = nvme_img.unwrap_or_else(|| {
            if self.cfg.pool_enabled() {
                codec::compress(data)
            } else {
                Compressed::Zero { len: raw as u32 }
            }
        });
        let stamp = self.next_stamp;
        self.next_stamp = self.next_stamp.wrapping_add(1);
        let class = self.class_of(vm) as u8;
        *self.slot_mut(vm, unit) = Some(Entry {
            img,
            tier: SwapTier::Nvme,
            stamp,
            nvme_ready_at: done,
            class,
        });
        IoReceipt { token, completes_at: done, tier: SwapTier::Nvme, writeback }
    }

    #[allow(clippy::too_many_arguments)]
    fn read(
        &mut self,
        vm: VmId,
        unit: UnitId,
        bytes: u64,
        out: &mut Vec<u8>,
        now: Time,
        nvme: &mut Nvme,
        rng: &mut Rng,
    ) -> IoReceipt {
        let token = self.next_token;
        self.next_token += 1;
        let pickup = now + rng.below(self.poll_ns.max(1));
        match self.entry(vm, unit) {
            Some(e) if e.tier == SwapTier::Pool => {
                codec::decompress(&e.img, out);
                let cpu = self.scaled(self.decompress_4k_ns, e.img.raw_len() as u64);
                self.metrics.pool_hits += 1;
                IoReceipt {
                    token,
                    completes_at: pickup + cpu,
                    tier: SwapTier::Pool,
                    writeback: vec![],
                }
            }
            Some(e) if e.tier == SwapTier::Remote => {
                // Leased remote memory: one modeled network round trip
                // fetches the compressed image from the donor's DRAM,
                // then local decompression — strictly between a pool
                // hit and an NVMe read, and no NVMe I/O at all.
                codec::decompress(&e.img, out);
                let raw = e.img.raw_len() as u64;
                let net = self.scaled(self.cfg.remote_lat_4k_ns, raw);
                let cpu = self.scaled(self.decompress_4k_ns, raw);
                self.metrics.remote_hits += 1;
                IoReceipt {
                    token,
                    completes_at: pickup + net + cpu,
                    tier: SwapTier::Remote,
                    writeback: vec![],
                }
            }
            Some(e) => {
                // NVMe tier: wait out any in-flight writeback of this
                // unit — the data is not on the device before then.
                let ready = e.nvme_ready_at;
                let len = e.img.raw_len() as u64;
                debug_assert_eq!(len, bytes, "unit {unit} stored {len} read {bytes}");
                if self.cfg.pool_enabled() {
                    codec::decompress(&e.img, out);
                    self.metrics.pool_fallthrough += 1;
                }
                let done = self.nvme_op(pickup.max(ready), len, IoKind::Read, nvme);
                IoReceipt { token, completes_at: done, tier: SwapTier::Nvme, writeback: vec![] }
            }
            None => {
                // Attached clone, no private copy: serve the unit out
                // of the shared golden image — decompress at pool cost,
                // no NVMe I/O, no per-VM entry (the read-only CoW path,
                // PR 10).
                if let Some(blob) = self.image_blob(vm, unit) {
                    let raw = blob.raw_len() as u64;
                    codec::decompress(blob, out);
                    let cpu = self.scaled(self.decompress_4k_ns, raw);
                    self.metrics.image_hits += 1;
                    self.metrics.image_hit_bytes += raw;
                    return IoReceipt {
                        token,
                        completes_at: pickup + cpu,
                        tier: SwapTier::Pool,
                        writeback: vec![],
                    };
                }
                // Never written: cold pre-existing swap-file content
                // (zero-filled). Flat mode is accounting-only and leaves
                // `out` untouched.
                if self.cfg.pool_enabled() {
                    out.clear();
                    out.resize(bytes as usize, 0);
                    self.metrics.pool_fallthrough += 1;
                }
                let done = self.nvme_op(pickup, bytes, IoKind::Read, nvme);
                IoReceipt { token, completes_at: done, tier: SwapTier::Nvme, writeback: vec![] }
            }
        }
    }

    fn discard(&mut self, vm: VmId, unit: UnitId) {
        // Only a private copy can be discarded: the shared image is
        // read-only and refcounted, so an image-backed unit with no
        // private shadow is immune (other clones still read it).
        if self.remove_entry(vm, unit) {
            self.metrics.discards += 1;
        }
    }

    fn tier_of(&self, vm: VmId, unit: UnitId) -> Option<SwapTier> {
        // Image-backed units with no private copy report Pool: a fault
        // there decompresses out of the host-resident image, exactly
        // like a pool hit and with the same cost model.
        self.entry(vm, unit)
            .map(|e| e.tier)
            .or_else(|| self.image_blob(vm, unit).map(|_| SwapTier::Pool))
    }

    fn metrics(&self) -> &TierMetrics {
        &self.metrics
    }

    fn set_vm_class(&mut self, vm: VmId, class: u8) {
        if self.vm_class.len() <= vm {
            self.vm_class.resize(vm + 1, 0);
        }
        self.vm_class[vm] = class;
    }

    /// Configure partitions *before* traffic: existing occupancy stays
    /// accounted to the classes it was admitted under.
    fn set_class_quotas(&mut self, quotas: &[u64]) {
        self.class_quota = quotas.to_vec();
        let n = quotas.len().max(1);
        self.class_bytes.resize(n, 0);
        self.drain_fifo.resize_with(n, VecDeque::new);
    }

    fn class_pool_bytes(&self, class: u8) -> u64 {
        self.class_bytes.get(class as usize).copied().unwrap_or(0)
    }

    fn set_pool_admission(&mut self, reject_pct: u8) {
        self.admission_override = Some(reject_pct.min(100));
    }

    fn list_units(&self, vm: VmId) -> Vec<UnitSummary> {
        let Some(store) = self.stores.get(vm) else { return Vec::new() };
        store
            .iter()
            .enumerate()
            .filter_map(|(u, e)| {
                e.as_ref().map(|e| UnitSummary {
                    unit: u as UnitId,
                    stamp: e.stamp,
                    tier: e.tier,
                    raw_bytes: e.img.raw_len() as u64,
                    stored_bytes: match e.tier {
                        SwapTier::Pool | SwapTier::Remote => e.img.stored_bytes(),
                        SwapTier::Nvme => 0,
                    },
                })
            })
            .collect()
    }

    fn export_unit(&self, vm: VmId, unit: UnitId) -> Option<PortableUnit> {
        self.entry(vm, unit).map(|e| PortableUnit {
            unit,
            stamp: e.stamp,
            tier: e.tier,
            img: e.img.clone(),
        })
    }

    fn import_unit(&mut self, vm: VmId, u: PortableUnit) -> SwapTier {
        self.remove_entry(vm, u.unit);
        let stored = u.img.stored_bytes();
        let class = self.class_of(vm);
        let (quota, _, _) = self.class_limits(class);
        // Pool copies stay pooled only while the target has room;
        // otherwise they land on NVMe (the migration modeled the
        // arrival as a writeback — no drain is triggered here, so one
        // import can never evict a resident class's entries). A
        // remote-tier copy always demotes to NVMe: the target holds no
        // lease covering it.
        let tier = if u.tier == SwapTier::Pool
            && self.cfg.pool_enabled()
            && self.metrics.pool_bytes + stored <= self.cfg.pool_capacity_bytes
            && self.class_bytes[class] + stored <= quota
        {
            SwapTier::Pool
        } else {
            SwapTier::Nvme
        };
        let stamp = self.next_stamp;
        self.next_stamp = self.next_stamp.wrapping_add(1);
        let is_zero = matches!(u.img, Compressed::Zero { .. });
        *self.slot_mut(vm, u.unit) = Some(Entry {
            img: u.img,
            tier,
            stamp,
            nvme_ready_at: 0,
            class: class as u8,
        });
        if tier == SwapTier::Pool {
            self.metrics.pool_bytes += stored;
            self.class_bytes[class] += stored;
            self.metrics.pool_peak_bytes =
                self.metrics.pool_peak_bytes.max(self.metrics.pool_bytes);
            if !is_zero {
                self.drain_fifo[class].push_back((vm, u.unit, stamp));
            }
        }
        tier
    }

    fn forget_vm(&mut self, vm: VmId) -> usize {
        // Detach from any golden image first: a clone may hold zero
        // private entries (its store was never even grown), but the
        // image refcount must still step down.
        self.detach_image(vm);
        let Some(store) = self.stores.get(vm) else { return 0 };
        let units: Vec<UnitId> = (0..store.len() as UnitId)
            .filter(|&u| store[u as usize].is_some())
            .collect();
        for &u in &units {
            self.remove_entry(vm, u);
        }
        units.len()
    }

    // ---- Remote marketplace tier (PR 9) ----

    /// Retag the coldest pool entries (oldest-admitted first, per
    /// partition class in class order — the watermark drain's own
    /// victim order) as remote, never exceeding `max_bytes` of stored
    /// bytes: the cap is the donor's proven headroom, so overshooting
    /// would break the donor's budget reasoning.
    fn remote_stage(&mut self, max_bytes: u64) -> u64 {
        if !self.cfg.pool_enabled() {
            return 0;
        }
        let mut staged = 0u64;
        for class in 0..self.drain_fifo.len() {
            loop {
                let Some(&(vm, unit, stamp)) = self.drain_fifo[class].front() else { break };
                let stored = match self.entry(vm, unit) {
                    Some(e) if e.stamp == stamp && e.tier == SwapTier::Pool => {
                        e.img.stored_bytes()
                    }
                    _ => {
                        // Stale reference (replaced or already drained).
                        self.drain_fifo[class].pop_front();
                        continue;
                    }
                };
                if staged + stored > max_bytes {
                    break;
                }
                self.drain_fifo[class].pop_front();
                let mut entry_class = class;
                if let Some(e) = self.slot_mut(vm, unit).as_mut() {
                    entry_class = e.class as usize;
                    e.tier = SwapTier::Remote;
                }
                self.metrics.pool_bytes -= stored;
                self.class_bytes[entry_class] -= stored;
                self.metrics.remote_bytes += stored;
                self.metrics.remote_peak_bytes =
                    self.metrics.remote_peak_bytes.max(self.metrics.remote_bytes);
                self.metrics.remote_stages += 1;
                staged += stored;
                self.remote_fifo.push_back((vm, unit, stamp));
            }
        }
        staged
    }

    /// Revocation: move the oldest-staged remote entries back to local
    /// NVMe with real writeback I/O. Always makes progress — a single
    /// entry larger than `max_bytes` is still recalled (recalling only
    /// *frees* donor memory, so overshoot is safe on this side).
    fn remote_recall(&mut self, max_bytes: u64, now: Time, nvme: &mut Nvme) -> u64 {
        if max_bytes == 0 {
            return 0;
        }
        let mut recalled = 0u64;
        while let Some(&(vm, unit, stamp)) = self.remote_fifo.front() {
            let (stored, raw) = match self.entry(vm, unit) {
                Some(e) if e.stamp == stamp && e.tier == SwapTier::Remote => {
                    (e.img.stored_bytes(), e.img.raw_len() as u64)
                }
                _ => {
                    self.remote_fifo.pop_front();
                    continue;
                }
            };
            if recalled > 0 && recalled + stored > max_bytes {
                break;
            }
            self.remote_fifo.pop_front();
            let done = self.nvme_op(now, raw, IoKind::Write, nvme);
            if let Some(e) = self.slot_mut(vm, unit).as_mut() {
                e.tier = SwapTier::Nvme;
                e.nvme_ready_at = done;
            }
            self.metrics.remote_bytes -= stored;
            self.metrics.remote_recalls += 1;
            self.metrics.remote_recalled_bytes += stored;
            recalled += stored;
        }
        recalled
    }

    /// Donor crash: every remote entry's content lived in the dead
    /// donor's DRAM. Drop them outright — the next read of each takes
    /// the never-written cold-miss path (zero-fill NVMe read), so the
    /// loss is re-synthesized as measured faults, not waved away.
    fn remote_drop(&mut self) -> (u64, u64) {
        let mut units = 0u64;
        let mut bytes = 0u64;
        while let Some((vm, unit, stamp)) = self.remote_fifo.pop_front() {
            let stored = match self.entry(vm, unit) {
                Some(e) if e.stamp == stamp && e.tier == SwapTier::Remote => {
                    e.img.stored_bytes()
                }
                _ => continue,
            };
            *self.slot_mut(vm, unit) = None;
            self.metrics.remote_bytes -= stored;
            self.metrics.remote_dropped_units += 1;
            self.metrics.remote_dropped_bytes += stored;
            units += 1;
            bytes += stored;
        }
        (units, bytes)
    }

    fn remote_bytes(&self) -> u64 {
        self.metrics.remote_bytes
    }

    // ---- Golden-image tier (PR 10) ----

    /// Store one unit's content into a golden image, content-addressed:
    /// byte-identical compressed blobs are stored once and shared by
    /// every unit (and clone) that maps them. Gated on the pool being
    /// enabled — the flat (paper) backend retains no content, so it
    /// can hold no image either.
    fn install_image_unit(&mut self, image: u32, unit: UnitId, data: &[u8]) {
        if !self.cfg.pool_enabled() {
            return;
        }
        let img = codec::compress(data);
        let raw = data.len() as u64;
        let stored = img.stored_bytes();
        let key = blob_key(&img);
        let gi = self.images.entry(image).or_default();
        let blob = match gi.dedup.get(&key) {
            Some(&b) => b,
            None => {
                let b = gi.blobs.len() as u32;
                gi.dedup.insert(key, b);
                gi.blobs.push(img);
                gi.stored_bytes += stored;
                self.metrics.image_stored_bytes += stored;
                b
            }
        };
        if gi.map.insert(unit, blob).is_none() {
            gi.raw_bytes += raw;
        }
    }

    /// Attach a clone to an installed image (refcount up). Attaching to
    /// an image this host does not hold is a no-op: the clone simply
    /// faults cold, it never reads through a phantom image.
    fn attach_image(&mut self, vm: VmId, image: u32) {
        let Some(gi) = self.images.get_mut(&image) else { return };
        gi.refs += 1;
        self.vm_image.insert(vm, image);
        self.metrics.image_attaches += 1;
        self.metrics.image_logical_bytes += gi.raw_bytes;
    }

    fn image_of(&self, vm: VmId) -> Option<u32> {
        self.vm_image.get(&vm).copied()
    }

    fn image_units(&self, image: u32) -> u64 {
        self.images.get(&image).map(|g| g.map.len() as u64).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::types::HUGE_BYTES;

    fn setup(cfg: TierConfig) -> (TieredBackend, Nvme, Rng) {
        (
            TieredBackend::new(&cfg, &SwCost::default()),
            Nvme::new(&HwConfig::default()),
            Rng::new(3),
        )
    }

    fn pattern_page(n: usize, v: u8) -> Vec<u8> {
        vec![v; n]
    }

    fn random_page(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    // ---- flat-mode behavior (PR 1 backend parity) ----

    #[test]
    fn flat_huge_is_zero_copy_small_is_bounced() {
        let (mut b, mut n, mut rng) = setup(TierConfig::flat());
        b.write(0, 1, &random_page(HUGE_BYTES as usize, 1), TierHint::Auto, 0, &mut n, &mut rng);
        b.write(0, 2, &random_page(FRAME_BYTES as usize, 2), TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(b.metrics().zero_copy_ops, 1);
        assert_eq!(b.metrics().bounced_ops, 1);
        assert_eq!(b.metrics().nvme_write_reqs, 2);
        assert_eq!(b.metrics().pool_stores, 0);
    }

    #[test]
    fn granularity_huge_write_counts_one_2m_request() {
        let (mut b, mut n, mut rng) = setup(TierConfig::flat());
        b.write(0, 1, &random_page(HUGE_BYTES as usize, 1), TierHint::Auto, 0, &mut n, &mut rng);
        b.write(0, 2, &random_page(FRAME_BYTES as usize, 2), TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(b.metrics().nvme_huge_write_reqs, 1);
        assert_eq!(b.metrics().nvme_write_reqs, 2);
        assert_eq!(b.metrics().nvme_bytes_written, HUGE_BYTES + FRAME_BYTES);
    }

    #[test]
    fn granularity_huge_unit_roundtrips_through_pool_backend() {
        // A 2MB unit written through the pool-enabled backend must read
        // back byte-identical, whichever tier it landed on — and a
        // never-written 2MB unit reads back as cold zero-fill.
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = pattern_page(HUGE_BYTES as usize, 7);
        let w = b.write(0, 1, &page, TierHint::Auto, 0, &mut n, &mut rng);
        let mut out = Vec::new();
        b.read(0, 1, HUGE_BYTES, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(out, page);
        let mut cold = Vec::new();
        b.read(0, 2, HUGE_BYTES, &mut cold, w.completes_at, &mut n, &mut rng);
        assert_eq!(cold, vec![0u8; HUGE_BYTES as usize]);
    }

    #[test]
    fn granularity_admission_override_replaces_config_threshold() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = pattern_page(FRAME_BYTES as usize, 3);
        let w = b.write(0, 1, &page, TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Pool); // compressible -> admitted
        b.set_pool_admission(0); // adaptive policy: reject everything
        let w2 = b.write(0, 2, &page, TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(w2.tier, SwapTier::Nvme);
        // Explicit Pool routing bypasses the threshold either way.
        let w3 = b.write(0, 3, &page, TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w3.tier, SwapTier::Pool);
        b.set_pool_admission(100); // back to permissive
        let w4 = b.write(0, 4, &page, TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(w4.tier, SwapTier::Pool);
    }

    #[test]
    fn flat_write_read_accounting_only() {
        let (mut b, mut n, mut rng) = setup(TierConfig::flat());
        let page = random_page(FRAME_BYTES as usize, 9);
        let w = b.write(0, 1, &page, TierHint::Auto, 100, &mut n, &mut rng);
        assert!(w.completes_at > 100);
        assert_eq!(w.tier, SwapTier::Nvme);
        let mut out = Vec::new();
        let r = b.read(0, 1, FRAME_BYTES, &mut out, w.completes_at, &mut n, &mut rng);
        // Flat mode (PR 1 parity) keeps no content and leaves `out`
        // untouched — accounting and timing only.
        assert!(out.is_empty());
        assert_eq!(r.tier, SwapTier::Nvme);
        assert_eq!(b.metrics().nvme_bytes_written, FRAME_BYTES);
        assert_eq!(b.metrics().nvme_bytes_read, FRAME_BYTES);
    }

    #[test]
    fn pool_enabled_nvme_reject_still_roundtrips_content() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = random_page(FRAME_BYTES as usize, 9);
        let w = b.write(0, 1, &page, TierHint::Auto, 100, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Nvme); // incompressible -> rejected
        let mut out = Vec::new();
        let r = b.read(0, 1, FRAME_BYTES, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(out, page);
        assert_eq!(r.tier, SwapTier::Nvme);
    }

    #[test]
    fn oversized_image_falls_through_to_nvme_even_with_pool_hint() {
        // Pool smaller than a single raw page: admission must not
        // overshoot capacity — the write lands on NVMe instead.
        let cfg = TierConfig {
            pool_capacity_bytes: 1024,
            ..TierConfig::default()
        };
        let (mut b, mut n, mut rng) = setup(cfg);
        let w = b.write(0, 1, &random_page(4096, 3), TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Nvme);
        assert_eq!(b.metrics().pool_bytes, 0);
        assert_eq!(b.metrics().pool_rejects, 1);
    }

    #[test]
    fn tokens_unique() {
        let (mut b, mut n, mut rng) = setup(TierConfig::flat());
        let p = random_page(FRAME_BYTES as usize, 4);
        let a = b.write(0, 1, &p, TierHint::Auto, 0, &mut n, &mut rng);
        let mut out = Vec::new();
        let c = b.read(0, 1, FRAME_BYTES, &mut out, 0, &mut n, &mut rng);
        assert_ne!(a.token, c.token);
    }

    #[test]
    fn cold_read_of_unwritten_unit_is_nvme_zero_fill() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let mut out = Vec::new();
        let r = b.read(0, 77, FRAME_BYTES, &mut out, 0, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Nvme);
        assert_eq!(out, vec![0u8; FRAME_BYTES as usize]);
        assert_eq!(b.metrics().nvme_reads, 1);
        assert_eq!(b.tier_of(0, 77), None);
    }

    // ---- pool behavior ----

    #[test]
    fn compressible_write_absorbed_by_pool_no_nvme() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let w = b.write(0, 5, &pattern_page(4096, 0xAA), TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Pool);
        assert_eq!(b.metrics().nvme_write_reqs, 0);
        assert_eq!(b.tier_of(0, 5), Some(SwapTier::Pool));

        // Hit: decompress only, no NVMe I/O, content intact.
        let mut out = Vec::new();
        let r = b.read(0, 5, 4096, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Pool);
        assert_eq!(out, pattern_page(4096, 0xAA));
        assert_eq!(b.metrics().nvme_reads, 0);
        assert_eq!(b.metrics().pool_hits, 1);
        // Non-destructive: copy survives the read.
        assert_eq!(b.tier_of(0, 5), Some(SwapTier::Pool));
    }

    #[test]
    fn zero_page_stores_zero_bytes() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 1, &[0u8; 4096], TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(b.metrics().pool_zero_pages, 1);
        assert_eq!(b.metrics().pool_bytes, 0);
        let mut out = Vec::new();
        let r = b.read(0, 1, 4096, &mut out, 0, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Pool);
        assert!(out.iter().all(|&x| x == 0));
    }

    #[test]
    fn incompressible_write_rejected_to_nvme() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = random_page(4096, 11);
        let w = b.write(0, 2, &page, TierHint::Auto, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Nvme);
        assert_eq!(b.metrics().pool_rejects, 1);
        assert_eq!(b.metrics().nvme_write_reqs, 1);
        // Content still readable.
        let mut out = Vec::new();
        b.read(0, 2, 4096, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(out, page);
    }

    #[test]
    fn explicit_nvme_hint_bypasses_pool() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let w = b.write(0, 3, &pattern_page(4096, 1), TierHint::Nvme, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Nvme);
        assert_eq!(b.metrics().pool_stores, 0);
    }

    #[test]
    fn pool_hint_admits_incompressible() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let w = b.write(0, 3, &random_page(4096, 5), TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Pool);
        assert_eq!(b.metrics().pool_bytes, 4096);
    }

    #[test]
    fn rewrite_replaces_pool_copy() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 4, &pattern_page(4096, 1), TierHint::Auto, 0, &mut n, &mut rng);
        let bytes1 = b.metrics().pool_bytes;
        b.write(0, 4, &pattern_page(4096, 2), TierHint::Auto, 10, &mut n, &mut rng);
        // Replacement: occupancy does not double.
        assert_eq!(b.metrics().pool_bytes, bytes1);
        let mut out = Vec::new();
        b.read(0, 4, 4096, &mut out, 20, &mut n, &mut rng);
        assert_eq!(out, pattern_page(4096, 2));
    }

    #[test]
    fn discard_releases_pool_space_and_is_idempotent() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 4, &pattern_page(4096, 3), TierHint::Auto, 0, &mut n, &mut rng);
        assert!(b.metrics().pool_bytes > 0);
        b.discard(0, 4);
        assert_eq!(b.metrics().pool_bytes, 0);
        assert_eq!(b.tier_of(0, 4), None);
        b.discard(0, 4); // no-op
        assert_eq!(b.metrics().discards, 1);
    }

    // ---- watermark writeback ----

    /// Small pool that admits raw (hint Pool) 4k pages: capacity 100
    /// pages with exact page-sized watermarks — high at 8 pages (8%),
    /// low at 4 pages (4%). The write that would push occupancy past 8
    /// pages (the 9th) triggers a drain of the 4 oldest entries.
    fn small_pool() -> TierConfig {
        TierConfig {
            pool_capacity_bytes: 100 * 4096,
            high_watermark_pct: 8,
            low_watermark_pct: 4,
            writeback_batch: 64,
            max_coalesce_units: 4,
            reject_pct: 101, // admit everything compressible-or-not
            ..TierConfig::default()
        }
    }

    #[test]
    fn watermark_drain_is_sorted_batched_and_coalesced() {
        let (mut b, mut n, mut rng) = setup(small_pool());
        // Write 9 raw pages in shuffled unit order; the 9th write
        // crosses the 8-page high watermark and drains the 4
        // oldest-admitted entries (down to the 4-page low watermark).
        let order = [3u64, 2, 9, 4, 1, 8, 7, 6, 5];
        let mut last =
            IoReceipt { token: 0, completes_at: 0, tier: SwapTier::Pool, writeback: vec![] };
        for (i, &u) in order.iter().enumerate() {
            let at = i as u64 * 1000;
            last = b.write(0, u, &random_page(4096, u), TierHint::Pool, at, &mut n, &mut rng);
        }
        let wb = &last.writeback;
        assert!(!wb.is_empty(), "drain did not trigger");
        // 4 drained + (8 - 4 + 1 new) admitted = 5 pages resident.
        assert_eq!(b.metrics().pool_bytes, 5 * 4096);
        // Sorted ascending by (vm, unit).
        let mut sorted = wb.clone();
        sorted.sort_unstable();
        assert_eq!(*wb, sorted, "writeback not sorted");
        // Oldest-admitted entries went out (first 4 of the write order,
        // as units): {3,2,9,4} sorted = [2,3,4,9].
        assert_eq!(wb, &[(0, 2), (0, 3), (0, 4), (0, 9)]);
        // Coalescing: run [2,3,4] is one request; 9 stands alone ->
        // 2 NVMe write requests for 4 units.
        assert_eq!(b.metrics().nvme_write_reqs, 2);
        assert_eq!(b.metrics().writeback_units, 4);
        assert_eq!(b.metrics().writeback_batches, 1);
        assert_eq!(b.metrics().nvme_bytes_written, 4 * 4096);
        // Drained units now read from NVMe; undrained stay pooled.
        assert_eq!(b.tier_of(0, 2), Some(SwapTier::Nvme));
        assert_eq!(b.tier_of(0, 5), Some(SwapTier::Pool));
    }

    #[test]
    fn coalesce_cap_splits_long_runs() {
        let cfg = TierConfig { max_coalesce_units: 2, ..small_pool() };
        let (mut b, mut n, mut rng) = setup(cfg);
        let mut last_wb = vec![];
        for u in 0..9u64 {
            let page = random_page(4096, u);
            let r = b.write(0, u, &page, TierHint::Pool, u * 1000, &mut n, &mut rng);
            if !r.writeback.is_empty() {
                last_wb = r.writeback;
            }
        }
        // Units 0..4 drained as a contiguous run, split at the cap:
        // [0,1] [2,3] = 2 requests for 4 units.
        assert_eq!(last_wb, vec![(0, 0), (0, 1), (0, 2), (0, 3)]);
        assert_eq!(b.metrics().nvme_write_reqs, 2);
    }

    /// Regression: a fault hitting a unit whose writeback is in flight
    /// must serialize behind the writeback and return intact content.
    #[test]
    fn fault_during_writeback_race() {
        let (mut b, mut n, mut rng) = setup(small_pool());
        let page0 = random_page(4096, 0);
        b.write(0, 0, &page0, TierHint::Pool, 0, &mut n, &mut rng);
        // Fill until unit 0 is drained.
        let mut drained_at = 0;
        for u in 1..9u64 {
            let r = b.write(0, u, &random_page(4096, u), TierHint::Pool, 100, &mut n, &mut rng);
            if r.writeback.contains(&(0, 0)) {
                drained_at = r.completes_at;
            }
        }
        assert_eq!(b.tier_of(0, 0), Some(SwapTier::Nvme), "unit 0 not drained");
        let ready = b.entry(0, 0).unwrap().nvme_ready_at;
        assert!(ready > 0);
        // Read immediately (virtual now=100, writeback still in flight).
        let mut out = Vec::new();
        let r = b.read(0, 0, 4096, &mut out, 100, &mut n, &mut rng);
        assert_eq!(out, page0, "content corrupted across writeback");
        assert!(
            r.completes_at >= ready,
            "read completed at {} before writeback at {ready}",
            r.completes_at
        );
        let _ = drained_at;
    }

    // ---- acceptance: tiering strictly reduces NVMe requests ----

    /// Reclaiming a zero/compressible-heavy working set through the
    /// tiered backend issues strictly fewer NVMe I/O requests than the
    /// flat backend, and compressed-pool fault hits perform no NVMe I/O.
    #[test]
    fn compressible_reclaim_beats_flat_on_nvme_requests() {
        let run = |cfg: TierConfig| {
            let (mut b, mut n, mut rng) = setup(cfg);
            // 64-unit working set: half zero, rest constant-pattern.
            for u in 0..64u64 {
                let page = if u % 2 == 0 {
                    vec![0u8; 4096]
                } else {
                    pattern_page(4096, u as u8)
                };
                b.write(0, u, &page, TierHint::Auto, u * 10_000, &mut n, &mut rng);
            }
            // Fault half of them back in.
            let mut out = Vec::new();
            for u in 0..32u64 {
                b.read(0, u, 4096, &mut out, 1_000_000 + u * 10_000, &mut n, &mut rng);
            }
            (b.metrics().nvme_io_reqs(), b.metrics().pool_hits, b.metrics().nvme_reads)
        };
        let (flat_reqs, flat_hits, _) = run(TierConfig::flat());
        let (tier_reqs, tier_hits, tier_nvme_reads) = run(TierConfig::default());
        assert_eq!(flat_hits, 0);
        assert_eq!(flat_reqs, 64 + 32);
        // Strictly fewer NVMe requests end to end.
        assert!(
            tier_reqs < flat_reqs,
            "tiered {tier_reqs} not < flat {flat_reqs}"
        );
        // Everything compressible stayed in the pool: all 32 faults were
        // pool hits and no NVMe read happened at all.
        assert_eq!(tier_hits, 32);
        assert_eq!(tier_nvme_reads, 0);
    }

    // ---- per-SLA pool partitions ----

    /// Two classes with page-sized quotas: class 1's overflow drains
    /// only class-1 entries; class 0's residency is untouched, and
    /// neither class ever exceeds its quota.
    #[test]
    fn class_quotas_enforced_and_drains_stay_in_class() {
        let cfg = TierConfig {
            pool_capacity_bytes: 100 * 4096,
            high_watermark_pct: 50,
            low_watermark_pct: 25,
            writeback_batch: 64,
            max_coalesce_units: 4,
            reject_pct: 101, // admit everything
            ..TierConfig::default()
        };
        let (mut b, mut n, mut rng) = setup(cfg);
        // Quotas: class 0 = 16 pages, class 1 = 8 pages. Watermarks per
        // class: high 50%, low 25% of the quota.
        b.set_class_quotas(&[16 * 4096, 8 * 4096]);
        b.set_vm_class(0, 0);
        b.set_vm_class(1, 1);
        // Class 0: 6 pages — under its 8-page high watermark, no drain.
        for u in 0..6u64 {
            b.write(0, u, &random_page(4096, u), TierHint::Pool, u * 1000, &mut n, &mut rng);
        }
        assert_eq!(b.class_pool_bytes(0), 6 * 4096);
        // Class 1: its high watermark is 4 pages; the 5th write drains
        // class 1 down to 2 pages (25% of 8) before inserting.
        let mut wb = vec![];
        for u in 0..5u64 {
            let page = random_page(4096, 100 + u);
            let r = b.write(1, u, &page, TierHint::Pool, u * 1000, &mut n, &mut rng);
            if !r.writeback.is_empty() {
                wb = r.writeback;
            }
        }
        assert!(!wb.is_empty(), "class-1 drain did not trigger");
        assert!(wb.iter().all(|&(vm, _)| vm == 1), "drained foreign class: {wb:?}");
        // Class 0 untouched by class 1's pressure.
        assert_eq!(b.class_pool_bytes(0), 6 * 4096);
        assert!(b.class_pool_bytes(1) <= 8 * 4096, "quota exceeded");
        for u in 0..6u64 {
            assert_eq!(b.tier_of(0, u), Some(SwapTier::Pool), "class-0 unit {u} evicted");
        }
    }

    /// An image that cannot fit its class quota falls through to NVMe
    /// even when another class has room.
    #[test]
    fn quota_overflow_falls_through_to_nvme() {
        let (mut b, mut n, mut rng) = setup(TierConfig {
            pool_capacity_bytes: 100 * 4096,
            reject_pct: 101,
            ..TierConfig::default()
        });
        b.set_class_quotas(&[50 * 4096, 2048]); // class 1: half a page
        b.set_vm_class(0, 1);
        let w = b.write(0, 1, &random_page(4096, 9), TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Nvme);
        assert_eq!(b.class_pool_bytes(1), 0);
        // Class 0 admission unaffected.
        b.set_vm_class(1, 0);
        let w2 = b.write(1, 1, &random_page(4096, 10), TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w2.tier, SwapTier::Pool);
        assert_eq!(b.class_pool_bytes(0), 4096);
    }

    #[test]
    fn shared_arena_reports_all_bytes_as_class_zero() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(3, 1, &random_page(4096, 1), TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(b.class_pool_bytes(0), b.metrics().pool_bytes);
        assert_eq!(b.class_pool_bytes(2), 0);
    }

    // ---- VM state migration: export / import / forget ----

    /// Export from one backend, import into another: content survives
    /// the hand-off, the donor's copies are released by `forget_vm`,
    /// and pool occupancy accounting follows the entries.
    #[test]
    fn export_import_roundtrips_content_across_backends() {
        let (mut donor, mut n, mut rng) = setup(TierConfig::default());
        let zero = vec![0u8; 4096];
        let patt = pattern_page(4096, 0x5A);
        let rand = random_page(4096, 77);
        donor.write(0, 1, &zero, TierHint::Auto, 0, &mut n, &mut rng);
        donor.write(0, 2, &patt, TierHint::Auto, 0, &mut n, &mut rng);
        donor.write(0, 3, &rand, TierHint::Auto, 0, &mut n, &mut rng); // NVMe reject
        let listing = donor.list_units(0);
        assert_eq!(listing.len(), 3);
        assert!(listing.windows(2).all(|w| w[0].unit < w[1].unit));

        let (mut target, mut n2, mut rng2) = setup(TierConfig::default());
        for s in &listing {
            let u = donor.export_unit(0, s.unit).expect("listed unit exports");
            assert_eq!(u.stamp, s.stamp);
            let tier = target.import_unit(5, u);
            assert_eq!(tier, s.tier, "tier preserved when the pool has room");
        }
        assert_eq!(donor.forget_vm(0), 3);
        assert_eq!(donor.metrics().pool_bytes, 0);
        assert!(donor.list_units(0).is_empty());

        let mut out = Vec::new();
        target.read(5, 2, 4096, &mut out, 100, &mut n2, &mut rng2);
        assert_eq!(out, patt);
        target.read(5, 3, 4096, &mut out, 200, &mut n2, &mut rng2);
        assert_eq!(out, rand);
        target.read(5, 1, 4096, &mut out, 300, &mut n2, &mut rng2);
        assert_eq!(out, zero);
    }

    /// A pool-tier import that does not fit the target's quota is
    /// demoted to NVMe instead of evicting resident entries.
    #[test]
    fn import_demotes_to_nvme_when_pool_has_no_room() {
        let (mut donor, mut n, mut rng) = setup(TierConfig::default());
        donor.write(0, 1, &pattern_page(4096, 1), TierHint::Pool, 0, &mut n, &mut rng);
        let u = donor.export_unit(0, 1).unwrap();
        let (mut target, mut n2, mut rng2) = setup(TierConfig {
            pool_capacity_bytes: 2, // nothing fits
            ..TierConfig::default()
        });
        assert_eq!(target.import_unit(0, u), SwapTier::Nvme);
        assert_eq!(target.metrics().pool_bytes, 0);
        let mut out = Vec::new();
        target.read(0, 1, 4096, &mut out, 0, &mut n2, &mut rng2);
        assert_eq!(out, pattern_page(4096, 1));
    }

    /// A rewrite after export changes the stamp — the pre-copy
    /// invalidation signal the migration flip keys on.
    #[test]
    fn rewrite_invalidates_exported_stamp() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 1, &pattern_page(4096, 1), TierHint::Pool, 0, &mut n, &mut rng);
        let before = b.export_unit(0, 1).unwrap().stamp;
        b.write(0, 1, &pattern_page(4096, 2), TierHint::Pool, 10, &mut n, &mut rng);
        let after = b.list_units(0)[0].stamp;
        assert_ne!(before, after);
    }

    // ---- Remote marketplace tier (PR 9) ----

    /// Staging retags the coldest (oldest-admitted) pool entries as
    /// remote: pool occupancy drops by exactly the staged stored bytes,
    /// the stored bytes move to the remote gauge, and the cap is never
    /// overshot.
    #[test]
    fn remote_stage_moves_coldest_pool_entries_and_frees_pool() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        for u in 0..4u64 {
            b.write(0, u, &pattern_page(4096, 1 + u as u8), TierHint::Pool, u * 100, &mut n, &mut rng);
        }
        let listing = b.list_units(0);
        let per = listing[0].stored_bytes;
        assert!(per > 0);
        let pool_before = b.metrics().pool_bytes;
        // Budget for one and a half entries: exactly one stages.
        let staged = b.remote_stage(per + per / 2);
        assert_eq!(staged, per, "cap overshot or nothing staged");
        assert_eq!(b.metrics().pool_bytes, pool_before - per);
        assert_eq!(b.remote_bytes(), per);
        assert_eq!(b.metrics().remote_stages, 1);
        // Oldest-admitted entry (unit 0) went remote; the rest stayed.
        assert_eq!(b.tier_of(0, 0), Some(SwapTier::Remote));
        for u in 1..4u64 {
            assert_eq!(b.tier_of(0, u), Some(SwapTier::Pool));
        }
    }

    /// A remote hit decompresses intact content with NO NVMe I/O, and
    /// its completion sits strictly between a pool hit and an NVMe
    /// round trip.
    #[test]
    fn remote_hit_latency_sits_between_pool_and_nvme() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = pattern_page(4096, 9);
        b.write(0, 0, &page, TierHint::Pool, 0, &mut n, &mut rng);
        b.write(0, 1, &page, TierHint::Pool, 10, &mut n, &mut rng);
        let per = b.list_units(0)[0].stored_bytes;
        assert_eq!(b.remote_stage(per), per); // unit 0 only
        let now = 1_000_000;
        let mut out = Vec::new();
        let rp = b.read(0, 1, 4096, &mut out, now, &mut n, &mut rng);
        assert_eq!(rp.tier, SwapTier::Pool);
        let rr = b.read(0, 0, 4096, &mut out, now, &mut n, &mut rng);
        assert_eq!(rr.tier, SwapTier::Remote);
        assert_eq!(out, page, "remote content corrupted");
        assert_eq!(b.metrics().remote_hits, 1);
        assert_eq!(b.metrics().nvme_reads, 0, "remote hit did NVMe I/O");
        // Pool ~1us + jitter; remote adds a ~20us network round trip;
        // NVMe would be ~75us + queueing.
        assert!(rr.completes_at > rp.completes_at + 15_000, "remote not slower than pool");
        assert!(rr.completes_at < now + 75_000, "remote not faster than NVMe");
    }

    /// Revocation recalls oldest-staged entries to local NVMe with real
    /// writeback I/O; content survives and later reads are NVMe-tier.
    #[test]
    fn remote_recall_writes_back_to_nvme_oldest_first() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        let page = pattern_page(4096, 5);
        for u in 0..3u64 {
            b.write(0, u, &page, TierHint::Pool, u * 100, &mut n, &mut rng);
        }
        let per = b.list_units(0)[0].stored_bytes;
        assert_eq!(b.remote_stage(3 * per), 3 * per);
        let writes_before = b.metrics().nvme_write_reqs;
        // Budget for one entry: the oldest-staged (unit 0) recalls.
        let recalled = b.remote_recall(per, 1_000, &mut n);
        assert_eq!(recalled, per);
        assert_eq!(b.tier_of(0, 0), Some(SwapTier::Nvme));
        assert_eq!(b.tier_of(0, 1), Some(SwapTier::Remote));
        assert_eq!(b.metrics().nvme_write_reqs, writes_before + 1);
        assert_eq!(b.remote_bytes(), 2 * per);
        assert_eq!(b.metrics().remote_recalled_bytes, per);
        let mut out = Vec::new();
        let r = b.read(0, 0, 4096, &mut out, 2_000_000, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Nvme);
        assert_eq!(out, page);
    }

    /// Donor crash: dropped remote entries are genuinely lost — the
    /// next read takes the never-written cold-miss path (zero fill,
    /// full NVMe read).
    #[test]
    fn remote_drop_refaults_as_cold_miss() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 7, &pattern_page(4096, 3), TierHint::Pool, 0, &mut n, &mut rng);
        let per = b.list_units(0)[0].stored_bytes;
        assert_eq!(b.remote_stage(per), per);
        let (units, bytes) = b.remote_drop();
        assert_eq!((units, bytes), (1, per));
        assert_eq!(b.remote_bytes(), 0);
        assert_eq!(b.tier_of(0, 7), None);
        let mut out = Vec::new();
        let r = b.read(0, 7, 4096, &mut out, 1_000, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Nvme);
        assert_eq!(out, vec![0u8; 4096]);
        assert_eq!(b.metrics().remote_dropped_units, 1);
    }

    /// A rewrite of a remote unit replaces the copy (fresh pool entry)
    /// and tombstones the stale remote-FIFO reference: a later recall
    /// must not touch the new copy.
    #[test]
    fn remote_rewrite_tombstones_fifo_reference() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 2, &pattern_page(4096, 1), TierHint::Pool, 0, &mut n, &mut rng);
        let per = b.list_units(0)[0].stored_bytes;
        assert_eq!(b.remote_stage(per), per);
        b.write(0, 2, &pattern_page(4096, 2), TierHint::Pool, 100, &mut n, &mut rng);
        assert_eq!(b.remote_bytes(), 0, "replaced remote copy still accounted");
        assert_eq!(b.tier_of(0, 2), Some(SwapTier::Pool));
        assert_eq!(b.remote_recall(u64::MAX / 2, 200, &mut n), 0);
        assert_eq!(b.tier_of(0, 2), Some(SwapTier::Pool), "recall touched the fresh copy");
    }

    // ---- Golden-image tier (PR 10, clone-from-image) ----

    /// Image content with deliberately few distinct pages, so the
    /// content-addressed store collapses them.
    fn image_page(u: u64) -> Vec<u8> {
        pattern_page(4096, (u % 2) as u8 + 1)
    }

    fn install_image(b: &mut TieredBackend, image: u32, units: u64) {
        for u in 0..units {
            b.install_image_unit(image, u, &image_page(u));
        }
    }

    #[test]
    fn image_install_dedups_content_addressed_blobs() {
        let (mut b, _, _) = setup(TierConfig::default());
        install_image(&mut b, 1, 8);
        assert_eq!(b.image_units(1), 8);
        // 8 units, 2 distinct contents: exactly 2 blobs stored.
        let one = codec::compress(&image_page(0)).stored_bytes();
        let two = codec::compress(&image_page(1)).stored_bytes();
        assert!(one > 0 && two > 0);
        assert_eq!(b.metrics().image_stored_bytes, one + two);
        // Re-installing a unit replaces the mapping, no double count.
        b.install_image_unit(1, 3, &image_page(3));
        assert_eq!(b.image_units(1), 8);
        assert_eq!(b.metrics().image_stored_bytes, one + two);
    }

    #[test]
    fn attached_clone_reads_units_out_of_image_at_pool_cost() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        install_image(&mut b, 1, 8);
        b.attach_image(0, 1);
        assert_eq!(b.image_of(0), Some(1));
        assert_eq!(b.tier_of(0, 5), Some(SwapTier::Pool));
        let mut out = Vec::new();
        let r = b.read(0, 5, 4096, &mut out, 0, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Pool);
        assert_eq!(out, image_page(5));
        assert_eq!(b.metrics().nvme_reads, 0, "image hit did NVMe I/O");
        assert_eq!(b.metrics().image_hits, 1);
        assert_eq!(b.metrics().image_hit_bytes, 4096);
        // An unattached VM reading the same unit misses cold.
        let r2 = b.read(7, 5, 4096, &mut out, 0, &mut n, &mut rng);
        assert_eq!(r2.tier, SwapTier::Nvme);
        assert_eq!(out, vec![0u8; 4096]);
        assert_eq!(b.tier_of(7, 5), None);
    }

    #[test]
    fn image_write_breaks_cow_into_private_shadow() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        install_image(&mut b, 1, 8);
        b.attach_image(0, 1);
        b.attach_image(1, 1);
        // First write from clone 0 breaks CoW: a private entry shadows
        // the image for (vm 0, unit 3) from now on.
        let upd = pattern_page(4096, 0x77);
        let w = b.write(0, 3, &upd, TierHint::Pool, 0, &mut n, &mut rng);
        assert_eq!(w.tier, SwapTier::Pool);
        assert_eq!(b.metrics().image_cow_breaks, 1);
        let mut out = Vec::new();
        b.read(0, 3, 4096, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(out, upd, "private shadow not served");
        // Clone 1 still reads the pristine image content.
        b.read(1, 3, 4096, &mut out, w.completes_at, &mut n, &mut rng);
        assert_eq!(out, image_page(3), "image damaged by clone 0's write");
        // Rewrite of the already-broken unit is not another CoW break.
        b.write(0, 3, &upd, TierHint::Pool, 100, &mut n, &mut rng);
        assert_eq!(b.metrics().image_cow_breaks, 1);
    }

    #[test]
    fn image_discard_is_noop_without_private_copy() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        install_image(&mut b, 1, 4);
        b.attach_image(0, 1);
        b.discard(0, 2);
        assert_eq!(b.metrics().discards, 0, "discard touched the shared image");
        assert_eq!(b.tier_of(0, 2), Some(SwapTier::Pool));
        let mut out = Vec::new();
        b.read(0, 2, 4096, &mut out, 0, &mut n, &mut rng);
        assert_eq!(out, image_page(2));
        // A private shadow IS discardable — and the unit falls back to
        // the image afterwards, not to a cold miss.
        b.write(0, 2, &pattern_page(4096, 9), TierHint::Pool, 10, &mut n, &mut rng);
        b.discard(0, 2);
        assert_eq!(b.metrics().discards, 1);
        b.read(0, 2, 4096, &mut out, 20, &mut n, &mut rng);
        assert_eq!(out, image_page(2));
    }

    #[test]
    fn image_released_only_at_refcount_zero() {
        let (mut b, _, _) = setup(TierConfig::default());
        install_image(&mut b, 1, 8);
        let stored = b.metrics().image_stored_bytes;
        assert!(stored > 0);
        b.attach_image(0, 1);
        b.attach_image(1, 1);
        assert_eq!(b.metrics().image_attaches, 2);
        // Logical bytes count per clone; stored bytes are charged once
        // — the dedup ratio the storm experiment reports.
        assert_eq!(b.metrics().image_logical_bytes, 2 * 8 * 4096);
        assert!(b.metrics().image_dedup_ratio() > 1.0);
        b.forget_vm(0);
        assert_eq!(b.image_of(0), None);
        assert_eq!(b.image_units(1), 8, "image dropped while clone 1 still attached");
        assert_eq!(b.metrics().image_stored_bytes, stored);
        assert_eq!(b.metrics().image_logical_bytes, 8 * 4096);
        b.forget_vm(1);
        assert_eq!(b.image_units(1), 0, "image must drop at refcount zero");
        assert_eq!(b.metrics().image_stored_bytes, 0);
        assert_eq!(b.metrics().image_logical_bytes, 0);
    }

    #[test]
    fn crash_salvage_of_clone_leaves_shared_image_intact() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        install_image(&mut b, 1, 8);
        b.attach_image(0, 1);
        b.attach_image(1, 1);
        // Clone 0 breaks CoW on two units: one stays pooled, one is
        // routed to NVMe.
        b.write(0, 1, &pattern_page(4096, 0x11), TierHint::Pool, 0, &mut n, &mut rng);
        b.write(0, 2, &pattern_page(4096, 0x22), TierHint::Nvme, 0, &mut n, &mut rng);
        let s = b.salvage_vm(0);
        // Salvage saw only the private copies, never the image blobs.
        assert_eq!(s.units.len(), 1, "exactly the NVMe shadow salvages");
        assert_eq!(s.lost_units, 1, "exactly the pool shadow is lost");
        assert_eq!(b.image_of(0), None, "salvage must detach the clone");
        // The surviving clone keeps reading every image unit.
        assert_eq!(b.image_units(1), 8);
        let mut out = Vec::new();
        for u in 0..8u64 {
            b.read(1, u, 4096, &mut out, 1_000, &mut n, &mut rng);
            assert_eq!(out, image_page(u), "survivor lost image unit {u}");
        }
    }

    #[test]
    fn flat_backend_holds_no_image() {
        let (mut b, mut n, mut rng) = setup(TierConfig::flat());
        install_image(&mut b, 1, 4);
        assert_eq!(b.image_units(1), 0, "flat (paper) backend grew image state");
        b.attach_image(0, 1);
        assert_eq!(b.image_of(0), None);
        let mut out = Vec::new();
        let r = b.read(0, 2, 4096, &mut out, 0, &mut n, &mut rng);
        assert_eq!(r.tier, SwapTier::Nvme);
        assert!(out.is_empty(), "flat mode stayed accounting-only");
        assert_eq!(b.metrics().image_stored_bytes, 0);
    }

    #[test]
    fn compression_ratio_reported() {
        let (mut b, mut n, mut rng) = setup(TierConfig::default());
        b.write(0, 0, &pattern_page(4096, 7), TierHint::Auto, 0, &mut n, &mut rng);
        assert!(b.metrics().compression_ratio() > 10.0);
        assert!(b.metrics().pool_hit_rate() == 0.0);
        let mut out = Vec::new();
        b.read(0, 0, 4096, &mut out, 10, &mut n, &mut rng);
        assert_eq!(b.metrics().pool_hit_rate(), 1.0);
    }
}
