//! # FlexSwap — Flexible Swapping for the Cloud (reproduction)
//!
//! A userspace memory-overcommit / swapping framework for opaque VMs,
//! reproducing Pandurov et al., "Flexible Swapping for the Cloud" (2024).
//!
//! The system under test — Memory Manager, Policy Engine, Swapper queues,
//! storage backend, VM introspection and the full policy zoo — is
//! implemented as designed in the paper. Because the paper's substrate
//! (KVM/EPT, userfaultfd, a dedicated NVMe SSD and multi-hundred-GB cloud
//! workloads) is hardware we do not have, the substrate is a
//! discrete-event simulation calibrated with the paper's own measured
//! constants (see `DESIGN.md` §2 for the substitution map).
//!
//! Layer map (three-layer Rust + JAX + Pallas architecture):
//! * **L3** — this crate: coordinator, policies, substrate, experiment
//!   harness (`harness`), CLI (`main.rs`).
//! * **L2/L1** — `python/compile/`: the dt-reclaimer analytics pipeline
//!   (JAX) with its Pallas `coldstats` hot loop, AOT-lowered to HLO text
//!   in `artifacts/` and executed from [`runtime`] via PJRT, always off
//!   the page-fault critical path.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod guest;
pub mod harness;
pub mod hw;
pub mod introspect;
pub mod metrics;
pub mod mm;
pub mod policies;
pub mod runtime;
pub mod scanner;
pub mod sim;
pub mod storage;
pub mod types;
pub mod uffd;
pub mod vm;
pub mod workloads;

pub use types::{PageSize, Time, UnitId, VmId};
