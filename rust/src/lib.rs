//! # FlexSwap — Flexible Swapping for the Cloud (reproduction)
//!
//! A userspace memory-overcommit / swapping framework for opaque VMs,
//! reproducing Pandurov et al., "Flexible Swapping for the Cloud" (2024).
//!
//! The system under test — Memory Manager, Policy Engine, Swapper queues,
//! storage backend, VM introspection and the full policy zoo — is
//! implemented as designed in the paper. Because the paper's substrate
//! (KVM/EPT, userfaultfd, a dedicated NVMe SSD and multi-hundred-GB cloud
//! workloads) is hardware we do not have, the substrate is a
//! discrete-event simulation calibrated with the paper's own measured
//! constants (see `DESIGN.md` §2 for the substitution map).
//!
//! Layer map (three-layer Rust + JAX + Pallas architecture):
//! * **L3** — this crate: coordinator, policies, substrate, experiment
//!   harness (`harness`), CLI (`main.rs`).
//! * **L2/L1** — `python/compile/`: the dt-reclaimer analytics pipeline
//!   (JAX) with its Pallas `coldstats` hot loop, AOT-lowered to HLO text
//!   in `artifacts/` and executed from [`runtime`] via PJRT, always off
//!   the page-fault critical path.
//!
//! Beyond the paper, swap storage is tiered (PR 2): the [`storage`]
//! module defines the [`storage::SwapBackend`] trait and a two-tier
//! implementation — a zswap-style compressed in-memory pool that
//! absorbs reclaim writes (zero-page/run-length codec) in front of the
//! NVMe device, drained by watermark-triggered batched+sorted
//! writeback. Policies target tiers through
//! [`mm::PolicyApi::reclaim_to`] / [`mm::PolicyApi::swap_tier`].
//!
//! `ARCHITECTURE.md` at the repo root carries the full module map, a
//! narrated end-to-end page-fault walkthrough, and the fault-path
//! complexity tables; `README.md` has the build/test/bench quickstart.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod guest;
pub mod harness;
pub mod hw;
pub mod introspect;
pub mod metrics;
pub mod mm;
pub mod policies;
pub mod runtime;
pub mod scanner;
pub mod sim;
pub mod storage;
pub mod types;
pub mod uffd;
pub mod vm;
pub mod workloads;

pub use types::{PageSize, Time, UnitId, VmId};
