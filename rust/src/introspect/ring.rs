//! The KVM->MM fault-context ring buffer.
//!
//! Bounded like the real shared-memory ring; on overflow the oldest
//! context is dropped and the corresponding fault is simply delivered
//! without guest context (policies must tolerate `None` — the paper's
//! example prefetcher does exactly that).

use std::collections::VecDeque;

/// Guest registers captured from the VMCS at EPT-violation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultCtx {
    /// Page-directory base pointer (CR3) of the faulting guest context.
    pub cr3: u64,
    /// Guest instruction pointer.
    pub ip: u64,
    /// Guest linear (virtual) address of the access.
    pub gva: u64,
    /// Host-side key used to pair ring entries with UFFD events.
    pub gpa_frame: u64,
}

#[derive(Debug)]
pub struct VmcsRing {
    buf: VecDeque<FaultCtx>,
    cap: usize,
    pub pushed: u64,
    pub dropped: u64,
}

impl VmcsRing {
    pub fn new(cap: usize) -> Self {
        VmcsRing { buf: VecDeque::with_capacity(cap), cap, pushed: 0, dropped: 0 }
    }

    /// KVM side: record fault context (drops oldest on overflow).
    pub fn push(&mut self, ctx: FaultCtx) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ctx);
        self.pushed += 1;
    }

    /// MM side: find and remove the context for a delivered fault.
    pub fn take(&mut self, gpa_frame: u64) -> Option<FaultCtx> {
        let idx = self.buf.iter().position(|c| c.gpa_frame == gpa_frame)?;
        self.buf.remove(idx)
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(gpa: u64) -> FaultCtx {
        FaultCtx { cr3: 0x1000, ip: 0x400000 + gpa, gva: gpa * 2, gpa_frame: gpa }
    }

    #[test]
    fn push_take_pairs_by_gpa() {
        let mut r = VmcsRing::new(4);
        r.push(ctx(10));
        r.push(ctx(11));
        let c = r.take(10).unwrap();
        assert_eq!(c.ip, 0x400000 + 10);
        assert!(r.take(10).is_none());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn overflow_drops_oldest() {
        let mut r = VmcsRing::new(2);
        r.push(ctx(1));
        r.push(ctx(2));
        r.push(ctx(3));
        assert_eq!(r.dropped, 1);
        assert!(r.take(1).is_none()); // oldest lost
        assert!(r.take(3).is_some());
    }
}
