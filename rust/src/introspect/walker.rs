//! GVA -> HVA translation by walking the guest page tables (paper §5.2).
//!
//! The real system forwards the request to a QEMU helper thread that
//! walks the guest tables for a given PDBP; translations can fail when
//! the guest mapping does not exist yet (the paper observes a small,
//! ignorable failure fraction). The MM and hypervisor only understand
//! HVAs, so policies predicting in GVA space must round-trip through
//! this walker. Host mapping is linear, so HVA == GPA offset here.

use crate::config::SwCost;
use crate::types::Time;
use crate::vm::Vm;

#[derive(Debug, Default)]
pub struct GvaWalker {
    pub translations: u64,
    pub failures: u64,
}

impl GvaWalker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Translate `gva_page` under `cr3`. Returns the host frame number
    /// (HVA page) or None if the guest has no mapping yet. `cost` models
    /// the QEMU helper-thread walk.
    pub fn gva_to_hva(
        &mut self,
        vm: &Vm,
        cr3: u64,
        gva_page: u64,
    ) -> Option<u64> {
        self.translations += 1;
        let proc = vm.processes.iter().find(|p| p.cr3 == cr3);
        let frame = proc.and_then(|p| p.pt.walk(gva_page));
        if frame.is_none() {
            self.failures += 1;
        }
        frame.map(|f| f as u64)
    }

    pub fn walk_cost(sw: &SwCost) -> Time {
        sw.gva_walk_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, VmConfig};
    use crate::sim::Rng;
    use crate::types::PageSize;
    use crate::vm::AccessResult;

    #[test]
    fn translates_mapped_and_fails_unmapped() {
        let cfg = VmConfig {
            frames: 512,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 1.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(11);
        let mut vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        let p = vm.spawn_process(512);
        // Touch gva 7 so the guest maps it.
        let fault = match vm.access(0, p, 7, false, 0, 0, &mut rng) {
            AccessResult::Fault(f) => f,
            _ => panic!(),
        };
        let cr3 = vm.processes[p].cr3;
        let mut w = GvaWalker::new();
        let hva = w.gva_to_hva(&vm, cr3, 7).unwrap();
        assert_eq!(hva, fault.gpa_frame);
        assert!(w.gva_to_hva(&vm, cr3, 8).is_none()); // untouched gva
        assert!(w.gva_to_hva(&vm, 0xdead, 7).is_none()); // unknown cr3
        assert_eq!(w.failures, 2);
    }
}
