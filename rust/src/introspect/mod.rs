//! Lightweight VM introspection (paper §5.2): the KVM->MM VMCS register
//! ring buffer and the GVA->HVA guest-page-table walker.
//!
//! At EPT-violation time, a (modified) KVM copies PDBP/CR3, IP and the
//! guest linear address into a ring shared with the MM; the MM attaches
//! that context to the matching UFFD event so policies can reason in the
//! guest application's address space without guest cooperation.

pub mod ring;
pub mod walker;

pub use ring::{FaultCtx, VmcsRing};
pub use walker::GvaWalker;
