//! Experiment harness: one driver per paper figure (DESIGN.md §5 maps
//! each to its modules). Every driver returns [`crate::metrics::Table`]s
//! whose rows regenerate the paper's series; `flexswap fig<N>` prints
//! them and writes CSV into `results/`.

pub mod analysis;
pub mod eval;
pub mod fleet;
pub mod granularity;

use crate::metrics::Table;

/// A registered experiment.
pub struct Experiment {
    pub id: &'static str,
    pub title: &'static str,
    /// The paper's qualitative expectation (what "shape holds" means).
    pub expectation: &'static str,
    pub run: fn(Scale) -> Vec<Table>,
}

/// Experiment scale knob: `quick` for CI, `full` for EXPERIMENTS.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn f(self, quick: f64, full: f64) -> f64 {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
    pub fn u(self, quick: u64, full: u64) -> u64 {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "fig1",
            title: "Fig 1: access latency vs cold-page access ratio (strict-4k vs strict-2M)",
            expectation: "2M faster below ~0.01% cold ratio; 4k faster above; crossover near 1e-4",
            run: analysis::fig1,
        },
        Experiment {
            id: "fig2",
            title: "Fig 2: access pattern, guest-virtual vs guest-physical view",
            expectation: "clean two-phase pattern in GVA; scrambled in GPA after aging",
            run: analysis::fig2,
        },
        Experiment {
            id: "fig3",
            title: "Fig 3: EPT scan frequency: direct %CPU and indirect runtime cost",
            expectation: "both costs grow as interval shrinks; 2M dramatically cheaper than 4k",
            run: analysis::fig3,
        },
        Experiment {
            id: "fig6",
            title: "Fig 6: page fault latency breakdown (VMEXIT vs I/O)",
            expectation: "sys-4k VMEXIT ~22us vs kernel 6us, total +~13%; 2M ~11x kernel-4k total, VMEXIT share ~4%",
            run: eval::fig6,
        },
        Experiment {
            id: "fig7",
            title: "Fig 7: swap I/O throughput vs parallelism",
            expectation: "2M saturates ~2.6GB/s with 2 swapper threads; 4k sys ~ kernel",
            run: eval::fig7,
        },
        Experiment {
            id: "fig8",
            title: "Fig 8: WSS estimation tracks a varying working set",
            expectation: "reported WSS/memory usage tracks ground truth; PF spikes at phase shifts",
            run: eval::fig8,
        },
        Experiment {
            id: "fig9",
            title: "Fig 9: cloud workloads: performance + memory saved (2M vs 4k vs none)",
            expectation: "2M ~ baseline perf with big savings (kafka ~70%); 4k slower; redis ~no reclaim",
            run: eval::fig9,
        },
        Experiment {
            id: "fig10",
            title: "Fig 10: g500 vs enhanced-Linux reclaim under aggressivity sweep",
            expectation: "baseline saves more but always slower; SYS-Agg saves most at small cost",
            run: eval::fig10,
        },
        Experiment {
            id: "fig11",
            title: "Fig 11: runtime under 80% memory limit (redis vs matmul; SYS-R)",
            expectation: "redis better on 4k, matmul better on 2M; SYS-R ~-30% runtime vs kernel on matmul",
            run: eval::fig11,
        },
        Experiment {
            id: "figpf",
            title: "§6.6: LinearPF prefetcher, GVA vs HVA",
            expectation: "GVA version -30% runtime, >90% timely; HVA version no help, <2% timely",
            run: eval::fig_pf,
        },
        Experiment {
            id: "tiers",
            title: "Storage tiers: compressed pool + NVMe writeback vs flat backend (PR 2 extension)",
            expectation: "tiered run issues fewer NVMe requests; compressible fault hits served from the pool with no I/O",
            run: eval::fig_tiers,
        },
        Experiment {
            id: "fleet",
            title: "Fleet control plane: mixed-SLA VMs under closed-loop limits, plus a 4-host sharded fleet with budget leases, live VM state migration, host failure injection, and a remote-memory marketplace (PR 3/4/5/7/9 extension)",
            expectation: "per-host budget never exceeded at any control tick — mid-migration included — and Σ budgets conserved (less exactly the retired budget of dead hosts); closed-loop beats static limits on memory saved and/or p99 stall; the lease rebalancer cuts total major faults on the pressure-skewed 4-host fleet without losing Σ saved memory; full VM state migration beats lease-only on majors or occupancy, with atomic hand-off at every flip; graceful drain beats hard crash on recovered-VM p99 fault stall and SLA violations; the remote marketplace strictly beats NVMe-only on the pressured host's p99 fault stall with Σ budgets exactly conserved",
            run: fleet::fleet,
        },
        Experiment {
            id: "clone_storm",
            title: "Boot-storm autoscaling: clone-from-image admission with streamed memory on an 8-host fleet (PR 10 extension)",
            expectation: "image-backed clones implant with zero resident memory and strictly beat cold boots on time-to-first-useful-work p99 (boot faults decompress shared pool entries and the boot stream runs ahead, vs full NVMe zero-fill per cold fault); golden-image dedup ratio > 1 with clones sharing one image; packing holds the image on fewer hosts and stores fewer image bytes than spreading; Σ budgets exactly conserved and summaries byte-identical across engines and worker counts with the storm armed",
            run: fleet::clone_storm,
        },
        Experiment {
            id: "granularity",
            title: "Swap granularity: strict-4k vs huge vs auto on a uniform-cold sweep (PR 8 extension)",
            expectation: "huge moves whole 2MB regions: strictly fewer major faults per GB reclaimed and strictly fewer NVMe requests than strict-4k; region-level scan burns far less CPU; auto splits only refault-heavy regions",
            run: granularity::granularity,
        },
        Experiment {
            id: "fig12",
            title: "Fig 12: g500 memory usage over time (SYS-Agg vs default)",
            expectation: "aggressive policy reclaims phase memory much faster",
            run: eval::fig12,
        },
        Experiment {
            id: "fig13",
            title: "Fig 13: recovery after memory limit lift (redis/memtier)",
            expectation: "2M recovers fastest; kernel ~ 4k-WSR; plain 4k slowest",
            run: eval::fig13,
        },
    ]
}

/// Render tables as markdown under a header and persist each as
/// `results/<id>_<slug>.csv` (shared by `run_by_id` and the CLI's
/// parameterized runs like `fleet --hosts N`).
pub fn emit_tables(id: &str, header: String, tables: &[Table]) -> String {
    emit_tables_in("results", id, header, tables)
}

/// [`emit_tables`] with an explicit output directory — the `--out-dir`
/// CLI path. Nightly soak arms write to distinct directories so their
/// per-arm CSVs don't clobber each other under the shared
/// `fleet_soak_*` names.
pub fn emit_tables_in(dir: &str, id: &str, header: String, tables: &[Table]) -> String {
    let mut out = header;
    for t in tables {
        out.push_str(&t.markdown());
        out.push('\n');
        // Also persist CSV for plotting.
        let _ = std::fs::create_dir_all(dir);
        let file = format!(
            "{}/{}_{}.csv",
            dir,
            id,
            t.title
                .to_lowercase()
                .chars()
                .map(|c| if c.is_alphanumeric() { c } else { '_' })
                .collect::<String>()
        );
        let _ = std::fs::write(file, t.csv());
    }
    out
}

/// Run one experiment by id and render its tables as markdown.
pub fn run_by_id(id: &str, scale: Scale) -> Option<String> {
    let exp = registry().into_iter().find(|e| e.id == id)?;
    let tables = (exp.run)(scale);
    let header =
        format!("## {}\n\n*Paper expectation:* {}\n\n", exp.title, exp.expectation);
    Some(emit_tables(exp.id, header, &tables))
}

/// The `fleet` experiment with an explicit shard count (the
/// `flexswap fleet --hosts N` CLI path; tables land in the same
/// `results/fleet_*.csv` files as the registered run). `opts` carries
/// the execution-engine knobs: `--sequential` (merge-loop oracle
/// instead of the parallel epoch engine), `--workers N`, `--vms N`
/// (total population, split evenly across hosts), `--fault-plan`
/// (arm randomized host faults in the soak), `--remote` (arm the
/// remote-memory marketplace in the soak), and `--clone-storm`
/// (append the PR 10 boot-storm tables).
pub fn run_fleet_with_hosts(scale: Scale, hosts: usize, opts: fleet::FleetRunOpts) -> String {
    let engine = if opts.sequential { "sequential merge" } else { "parallel epochs" };
    let tables = fleet::fleet_with_hosts(scale, hosts, opts);
    let header = format!(
        "## Fleet control plane ({hosts} host shards, {engine})\n\n*Expectation:* \
         per-host budget held at every tick (mid-migration included), \
         Σ budgets conserved less retired dead-host budget, rebalancer \
         cuts major faults on the pressured host, full VM migration \
         beats lease-only, graceful drain beats hard crash on \
         recovered-VM tail latency, remote marketplace beats NVMe-only \
         on pressured-host tail latency\n\n"
    );
    emit_tables("fleet", header, &tables)
}

/// The nightly fleet soak (`flexswap fleet --hosts N --seeds K`): the
/// sharded comparison swept over `seeds` seeds, CSV per seed under
/// `<out_dir>/fleet_soak_*.csv` (`--out-dir`; the default `results`
/// matches the PR-gating path, nightly arms pass distinct dirs). With
/// `--fault-plan random` each seed also carries a seed-derived
/// host-fault schedule (chaos soak); with `--remote` the marketplace
/// is armed. Scheduled CI runs this off the PR-gating path.
pub fn run_fleet_soak(
    scale: Scale,
    hosts: usize,
    seeds: u64,
    opts: fleet::FleetRunOpts,
    out_dir: &str,
) -> String {
    let chaos = if opts.fault_plan == fleet::FaultPlan::Random { ", random faults" } else { "" };
    let remote = if opts.remote { ", remote marketplace" } else { "" };
    let tables = fleet::fleet_soak(scale, hosts, seeds, opts);
    let header = format!(
        "## Fleet soak ({hosts} host shards × {seeds} seeds{chaos}{remote})\n\n*Expectation:* \
         every seed holds the budget / conservation / atomic-hand-off \
         invariants (Σ budgets stepping down by exactly each dead \
         host's budget); migration, recovery, and remote-lease activity \
         is reported per seed\n\n"
    );
    emit_tables_in(out_dir, "fleet_soak", header, &tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_figures() {
        let ids: Vec<_> = registry().iter().map(|e| e.id).collect();
        for want in [
            "fig1", "fig2", "fig3", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "figpf",
            "tiers", "fleet", "clone_storm", "granularity", "fig12", "fig13",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run_by_id("fig99", Scale::Quick).is_none());
    }
}
