//! §6 evaluation experiments (Fig 6-13 and §6.6).

use crate::baseline::EnhancedReclaim;
use crate::config::{HostConfig, LinuxConfig, MmConfig, VmConfig};
use crate::coordinator::{Machine, Mechanism, VmSetup};
use crate::metrics::{fmt_bytes, Table};
use crate::mm::Mm;
use crate::policies::{
    AggressivePolicy, DtReclaimer, LinearPf, LruReclaimer, NativeAnalytics, PfMode,
    ReuseDistReclaimer, WsrPolicy,
};
use crate::storage::TierMetrics;
use crate::types::{PageSize, Time, MS, SEC};
use crate::workloads::{
    cloud_preset, CloudWorkload, PhasedWss, SeqScan, UniformRandom, Workload,
};

use super::Scale;

fn no_reclaim_mm(page_size: PageSize) -> MmConfig {
    MmConfig {
        scan_interval: 3600 * SEC,
        swapper_threads: 4,
        ..Default::default()
    }
    .tap(|c| {
        let _ = page_size;
        c
    })
}

trait Tap: Sized {
    fn tap<F: FnOnce(Self) -> Self>(self, f: F) -> Self {
        f(self)
    }
}
impl Tap for MmConfig {}

fn vm_cfg(frames: u64, mode: PageSize, vcpus: usize) -> VmConfig {
    VmConfig {
        frames,
        vcpus,
        page_size: mode,
        // Freshly-booted guests (the paper's §6.3 setup) allocate large
        // buffers nearly contiguously; only the §3.2/§6.6 experiments
        // age the allocator first.
        scramble: 0.05,
        guest_thp_coverage: 1.0,
    }
}

/// Fig 6: fault latency breakdown: software (VMEXIT path) vs I/O.
pub fn fig6(scale: Scale) -> Vec<Table> {
    let ops = scale.u(3_000, 12_000);
    let mut t = Table::new(
        "page fault cost breakdown",
        &["config", "sw_us", "total_us", "sw_share_pct", "vs_kernel4k"],
    );
    let mut kernel4k_total = 0.0;
    for config in ["kernel-4k", "sys-4k", "sys-2M"] {
        let (sw_us, total_us) = fig6_one(config, ops);
        if config == "kernel-4k" {
            kernel4k_total = total_us;
        }
        t.row(vec![
            config.into(),
            format!("{sw_us:.1}"),
            format!("{total_us:.1}"),
            format!("{:.1}", sw_us / total_us * 100.0),
            format!("{:.1}x", total_us / kernel4k_total),
        ]);
    }
    vec![t]
}

fn fig6_one(config: &str, ops: u64) -> (f64, f64) {
    let host = HostConfig::paper();
    let mut m = Machine::new(host.clone());
    let frames = 48_000u64;
    let pages = 40_960u64;
    let (mode, kernel) = match config {
        "kernel-4k" => (PageSize::Small, true),
        "sys-4k" => (PageSize::Small, false),
        "sys-2M" => (PageSize::Huge, false),
        _ => unreachable!(),
    };
    let w: Vec<Box<dyn Workload>> = vec![Box::new(UniformRandom::new(0, pages, ops))];
    let vmid = if kernel {
        // Paper disables readahead + async PF for this experiment.
        let lx = LinuxConfig { page_cluster: 0, thp: false, memory_limit: None, async_pf: false };
        m.kernel_vm(vm_cfg(frames, mode, 1), &lx, w, None, 3600 * SEC)
    } else {
        m.sys_vm(vm_cfg(frames, mode, 1), &no_reclaim_mm(mode), w)
    };
    // Entire region swapped out: every access is a major fault.
    m.prime_swapped(vmid, 0, pages);
    let res = m.run();
    let total_us = res[0].fault_hist.mean() / 1e3;
    let sw_us = if kernel {
        host.sw.vmexit_kernel_ns as f64 / 1e3 + host.sw.kernel_swap_sw_ns as f64 / 1e3
    } else {
        host.sw.vmexit_uffd_ns as f64 / 1e3
            + host.sw.uffd_continue_ns as f64 / 1e3
            + if mode == PageSize::Huge { host.sw.map_2m_extra_ns as f64 / 1e3 } else { 0.0 }
            + host.sw.queue_handoff_ns as f64 / 1e3
    };
    (sw_us, total_us)
}

/// Fig 7: swap-in throughput as parallelism grows.
pub fn fig7(scale: Scale) -> Vec<Table> {
    let ops_per_vcpu = scale.u(2_000, 8_000);
    let mut t = Table::new(
        "swap I/O throughput (GB/s) vs parallelism",
        &["vcpus", "kernel_4k", "sys_4k", "sys_2M"],
    );
    for vcpus in [1usize, 2, 4, 8] {
        let mut row = vec![vcpus.to_string()];
        for config in ["kernel-4k", "sys-4k", "sys-2M"] {
            row.push(format!("{:.2}", fig7_one(config, vcpus, ops_per_vcpu)));
        }
        t.row(row);
    }
    vec![t]
}

fn fig7_one(config: &str, vcpus: usize, ops_per_vcpu: u64) -> f64 {
    let mut m = Machine::new(HostConfig::paper());
    let frames = 200_000u64;
    let pages = 180_000u64;
    let (mode, kernel) = match config {
        "kernel-4k" => (PageSize::Small, true),
        "sys-4k" => (PageSize::Small, false),
        "sys-2M" => (PageSize::Huge, false),
        _ => unreachable!(),
    };
    let span = pages / vcpus as u64;
    let ws: Vec<Box<dyn Workload>> = (0..vcpus)
        .map(|v| {
            Box::new(UniformRandom::new(v as u64 * span, span, ops_per_vcpu))
                as Box<dyn Workload>
        })
        .collect();
    let vmid = if kernel {
        let lx = LinuxConfig { page_cluster: 0, thp: false, memory_limit: None, async_pf: true };
        m.kernel_vm(vm_cfg(frames, mode, vcpus), &lx, ws, None, 3600 * SEC)
    } else {
        let mm = MmConfig {
            scan_interval: 3600 * SEC,
            swapper_threads: vcpus,
            ..Default::default()
        };
        m.sys_vm(vm_cfg(frames, mode, vcpus), &mm, ws)
    };
    m.prime_swapped(vmid, 0, pages);
    let res = m.run();
    let bytes = res[0].counters.swapin_bytes;
    bytes as f64 / (res[0].runtime as f64 / 1e9) / 1e9
}

/// Fig 8: WSS estimation tracking a varying working set.
pub fn fig8(scale: Scale) -> Vec<Table> {
    let unit = scale.u(6_000, 24_000);
    let per_phase = scale.u(400_000, 1_600_000);
    let phases = vec![
        (unit * 2, per_phase),
        (unit * 4, per_phase),
        (unit, per_phase),
        (unit * 3, per_phase),
    ];
    let w = PhasedWss::new(phases.clone());
    let mut m = Machine::new(HostConfig::paper());
    let mm = MmConfig { scan_interval: 8 * MS, history: 16, ..Default::default() };
    let frames = unit * 5;
    let vmid = m.sys_vm(vm_cfg(frames, PageSize::Small, 1), &mm, vec![Box::new(w)]);
    let _ = vmid;
    let res = m.run();
    let r = &res[0];

    let mut t = Table::new(
        "WSS estimate vs ground truth over time",
        &["t_ms", "true_wss_mb", "mem_usage_mb", "pf_per_s"],
    );
    let runtime = r.runtime.max(1);
    let total_ops: u64 = phases.iter().map(|p| p.1).sum();
    let ground = PhasedWss::new(phases);
    let usage_ds = {
        let mut s = crate::metrics::Series::default();
        s.points = r.usage_series.clone();
        s.downsample(24)
    };
    for (i, (tt, usage)) in usage_ds.iter().enumerate() {
        // Approximate ops completed by time fraction.
        let ops_done = (total_ops as f64 * *tt as f64 / runtime as f64) as u64;
        let true_wss = ground.wss_at(ops_done.min(total_ops - 1)) * 4096;
        let pf = r
            .pf_series
            .iter()
            .filter(|(pt, _)| *pt <= *tt)
            .next_back()
            .map(|(_, v)| *v)
            .unwrap_or(0.0);
        t.row(vec![
            (tt / MS).to_string(),
            format!("{:.1}", true_wss as f64 / 1e6),
            format!("{:.1}", usage / 1e6),
            format!("{pf:.0}"),
        ]);
        let _ = i;
    }
    vec![t]
}

/// Fig 9: the eight cloud workloads: relative performance + memory saved.
pub fn fig9(scale: Scale) -> Vec<Table> {
    let wl_scale = scale.f(0.4, 1.0);
    let mut t = Table::new(
        "cloud workloads: relative perf and memory saved",
        &[
            "workload",
            "perf_2M",
            "perf_4k",
            "saved_2M_pct",
            "saved_4k_pct",
            "pf_ratio_4k_over_2M",
        ],
    );
    for name in crate::workloads::CLOUD_NAMES {
        let base = fig9_one(name, wl_scale, PageSize::Huge, false);
        let r2m = fig9_one(name, wl_scale, PageSize::Huge, true);
        let r4k = fig9_one(name, wl_scale, PageSize::Small, true);
        let perf = |r: &FigNine| base.runtime as f64 / r.runtime as f64;
        let saved =
            |r: &FigNine| (1.0 - r.avg_usage / base.avg_usage.max(1.0)) * 100.0;
        t.row(vec![
            name.into(),
            format!("{:.2}", perf(&r2m)),
            format!("{:.2}", perf(&r4k)),
            format!("{:.0}", saved(&r2m)),
            format!("{:.0}", saved(&r4k)),
            format!("{:.0}", r4k.major_faults as f64 / r2m.major_faults.max(1) as f64),
        ]);
    }
    vec![t]
}

struct FigNine {
    runtime: Time,
    avg_usage: f64,
    major_faults: u64,
}

fn fig9_one(name: &str, wl_scale: f64, mode: PageSize, reclaim: bool) -> FigNine {
    let spec = cloud_preset(name, wl_scale);
    let frames = spec.pages + spec.pages / 8 + 1024;
    let mut m = Machine::new(HostConfig::paper());
    let mm = MmConfig {
        scan_interval: if reclaim { 80 * MS } else { 3600 * SEC },
        history: 16,
        target_promotion_rate: 0.02,
        ..Default::default()
    };
    m.sys_vm(
        vm_cfg(frames, mode, 1),
        &mm,
        vec![Box::new(CloudWorkload::new(spec))],
    );
    let res = m.run();
    FigNine {
        runtime: res[0].runtime,
        avg_usage: res[0].avg_usage_bytes,
        major_faults: res[0].counters.faults_major.max(1),
    }
}

/// Fig 10: g500 vs enhanced-Linux reclaim; aggressivity sweep + SYS-Agg.
pub fn fig10(scale: Scale) -> Vec<Table> {
    let wl_scale = scale.f(0.08, 0.5);
    let mut t = Table::new(
        "g500: system vs enhanced-Linux reclaim",
        &["config", "rel_perf", "saved_pct", "thp_coverage_pct"],
    );
    let base = fig10_one("none", wl_scale);
    for config in ["2M", "2M-aggressive-rate", "sys-agg", "linux-x0.5", "linux-x1", "linux-x2"] {
        let r = fig10_one(config, wl_scale);
        t.row(vec![
            config.into(),
            format!("{:.2}", base.0 as f64 / r.0 as f64),
            format!("{:.0}", (1.0 - r.1 / base.1.max(1.0)) * 100.0),
            format!("{:.0}", r.2 * 100.0),
        ]);
    }
    vec![t]
}

fn fig10_one(config: &str, wl_scale: f64) -> (Time, f64, f64) {
    let spec = cloud_preset("g500", wl_scale);
    let frames = spec.pages + spec.pages / 8 + 1024;
    let mut m = Machine::new(HostConfig::paper());
    m.set_max_time(15 * SEC); // thrashing baselines: cap, ordering is set
    let w: Vec<Box<dyn Workload>> = vec![Box::new(CloudWorkload::new(spec))];
    match config {
        "none" => {
            m.sys_vm(vm_cfg(frames, PageSize::Huge, 1), &no_reclaim_mm(PageSize::Huge), w);
        }
        "2M" => {
            let mm = MmConfig { scan_interval: 80 * MS, history: 16, ..Default::default() };
            m.sys_vm(vm_cfg(frames, PageSize::Huge, 1), &mm, w);
        }
        "2M-aggressive-rate" => {
            // Tuning the default reclaimer harder (paper: cannot match
            // the dedicated phase policy without hurting perf).
            let mm = MmConfig {
                scan_interval: 30 * MS,
                history: 16,
                target_promotion_rate: 0.10,
                ..Default::default()
            };
            m.sys_vm(vm_cfg(frames, PageSize::Huge, 1), &mm, w);
        }
        "sys-agg" => {
            let mm_cfg = MmConfig { scan_interval: 80 * MS, history: 16, ..Default::default() };
            let units = vm_cfg(frames, PageSize::Huge, 1).units();
            let mut mm = Mm::new(
                &mm_cfg,
                units,
                PageSize::Huge.unit_bytes(),
                &m.host.sw,
                m.host.hw.zero_2m_ns,
            );
            mm.add_policy(Box::new(DtReclaimer::new(
                Box::new(NativeAnalytics::new()),
                mm_cfg.history,
                mm_cfg.target_promotion_rate,
            )));
            mm.add_policy(Box::new(AggressivePolicy::new(80 * MS)));
            mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
            m.add_vm(VmSetup {
                vm_cfg: vm_cfg(frames, PageSize::Huge, 1),
                mech: Mechanism::Sys(Box::new(mm)),
                workloads: w,
                scan_interval: Some(80 * MS),
            });
        }
        lx if lx.starts_with("linux-x") => {
            let agg: f64 = lx.trim_start_matches("linux-x").parse().unwrap();
            let mut e = EnhancedReclaim::new(16, 0.02);
            e.aggressivity = agg;
            m.kernel_vm(
                vm_cfg(frames, PageSize::Small, 1),
                &LinuxConfig::default(),
                w,
                Some(e),
                80 * MS,
            );
        }
        _ => unreachable!(),
    }
    let res = m.run();
    (res[0].runtime, res[0].avg_usage_bytes, res[0].thp_coverage)
}

/// Fig 11: runtime under an 80%-of-WSS memory limit.
pub fn fig11(scale: Scale) -> Vec<Table> {
    let wl_scale = scale.f(0.25, 0.6);
    let mut t = Table::new(
        "runtime under 80% memory limit (normalized to 2M)",
        &["workload", "sys_2M", "sys_4k", "kernel_thp", "sys_R_2M", "sysR_pf_reduction_pct"],
    );
    for name in ["redis", "matmul"] {
        // Measure the WSS with an unlimited dry run.
        let probe = fig9_one(name, wl_scale, PageSize::Huge, false);
        let limit = (probe.avg_usage * 0.8) as u64;
        let t2m = fig11_one(name, wl_scale, "2M", limit);
        let t4k = fig11_one(name, wl_scale, "4k", limit);
        let tk = fig11_one(name, wl_scale, "kernel", limit);
        let tr = fig11_one(name, wl_scale, "sys-r", limit);
        t.row(vec![
            name.into(),
            "1.00".into(),
            format!("{:.2}", t4k.0 as f64 / t2m.0 as f64),
            format!("{:.2}", tk.0 as f64 / t2m.0 as f64),
            format!("{:.2}", tr.0 as f64 / t2m.0 as f64),
            format!("{:.0}", (1.0 - tr.1 as f64 / t2m.1.max(1) as f64) * 100.0),
        ]);
    }
    vec![t]
}

fn fig11_one(name: &str, wl_scale: f64, config: &str, limit: u64) -> (Time, u64) {
    let spec = cloud_preset(name, wl_scale);
    let frames = spec.pages + spec.pages / 8 + 1024;
    let mut m = Machine::new(HostConfig::paper());
    m.set_max_time(60 * SEC);
    let w: Vec<Box<dyn Workload>> = vec![Box::new(CloudWorkload::new(spec))];
    match config {
        "2M" | "4k" | "sys-r" => {
            let mode = if config == "4k" { PageSize::Small } else { PageSize::Huge };
            let mm_cfg = MmConfig {
                scan_interval: 15 * MS,
                history: 16,
                memory_limit: Some(limit),
                ..Default::default()
            };
            let units = vm_cfg(frames, mode, 1).units();
            let mut mm = Mm::new(
                &mm_cfg,
                units,
                mode.unit_bytes(),
                &m.host.sw,
                m.host.hw.zero_2m_ns,
            );
            mm.add_policy(Box::new(DtReclaimer::new(
                Box::new(NativeAnalytics::new()),
                mm_cfg.history,
                mm_cfg.target_promotion_rate,
            )));
            if config == "sys-r" {
                mm.set_limit_reclaimer(Box::new(ReuseDistReclaimer::new(
                    units,
                    Box::new(NativeAnalytics::new()),
                )));
            } else {
                mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
            }
            m.add_vm(VmSetup {
                vm_cfg: vm_cfg(frames, mode, 1),
                mech: Mechanism::Sys(Box::new(mm)),
                workloads: w,
                scan_interval: Some(200 * MS),
            });
        }
        "kernel" => {
            let lx = LinuxConfig {
                thp: true,
                memory_limit: Some(limit),
                ..Default::default()
            };
            m.kernel_vm(vm_cfg(frames, PageSize::Small, 1), &lx, w, None, 15 * MS);
        }
        _ => unreachable!(),
    }
    let res = m.run();
    (res[0].runtime, res[0].counters.faults_major)
}

/// §6.6: LinearPF GVA vs HVA under a 75%-of-WSS limit.
pub fn fig_pf(scale: Scale) -> Vec<Table> {
    let pages = scale.u(12_000, 48_000);
    let iters = scale.u(4, 10);
    let mut t = Table::new(
        "LinearPF: sequential workload under 75% limit",
        &["config", "runtime_ms", "rel_improvement_pct", "timely_pf_pct"],
    );
    let base = fig_pf_one(pages, iters, None);
    for (label, mode) in
        [("no-prefetch", None), ("linear-pf-hva", Some(PfMode::Hva)), ("linear-pf-gva", Some(PfMode::Gva))]
    {
        let r = fig_pf_one(pages, iters, mode);
        t.row(vec![
            label.into(),
            format!("{:.1}", r.0 as f64 / 1e6),
            format!("{:.0}", (1.0 - r.0 as f64 / base.0 as f64) * 100.0),
            format!("{:.0}", r.1),
        ]);
    }
    vec![t]
}

fn fig_pf_one(pages: u64, iters: u64, pf: Option<PfMode>) -> (Time, f64) {
    let frames = pages + 2048;
    let limit = pages * 4096 * 3 / 4;
    let mut m = Machine::new(HostConfig::paper());
    let mode = PageSize::Small;
    let mm_cfg = MmConfig {
        scan_interval: 500 * MS,
        history: 16,
        memory_limit: Some(limit),
        ..Default::default()
    };
    let units = vm_cfg(frames, mode, 1).units();
    let mut mm = Mm::new(&mm_cfg, units, mode.unit_bytes(), &m.host.sw, m.host.hw.zero_2m_ns);
    if let Some(mode_pf) = pf {
        mm.add_policy(Box::new(LinearPf::new(mode_pf)));
    }
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    // Aged VM (paper warms up with a random-access process first).
    m.add_vm(VmSetup {
        vm_cfg: VmConfig { scramble: 1.0, ..vm_cfg(frames, mode, 1) },
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: vec![Box::new(SeqScan::new(pages, iters, 300_000))],
        scan_interval: Some(500 * MS),
    });
    let res = m.run();
    let c = &res[0].counters;
    let timely = c.prefetch_timely as f64
        / (c.prefetch_timely + c.faults_major).max(1) as f64
        * 100.0;
    (res[0].runtime, timely)
}

/// Storage tiers (PR 2 extension, beyond the paper): the same
/// reclaim-heavy workload against the flat NVMe backend vs the tiered
/// backend (compressed pool + batched writeback). The tiered run must
/// issue fewer NVMe requests and serve fault hits from the pool.
pub fn fig_tiers(scale: Scale) -> Vec<Table> {
    let pages = scale.u(6_000, 24_000);
    let ops = scale.u(150_000, 600_000);
    let mut t = Table::new(
        "storage tiers: flat NVMe vs compressed pool + writeback",
        &[
            "config",
            "runtime_ms",
            "nvme_reqs",
            "nvme_mb_written",
            "pool_hit_pct",
            "compression_x",
            "pool_peak_mb",
        ],
    );
    for (label, host) in [("flat", HostConfig::paper()), ("tiered", HostConfig::default())] {
        let (rt, bm) = fig_tiers_one(host, pages, ops);
        let cr = bm.compression_ratio();
        t.row(vec![
            label.into(),
            format!("{:.1}", rt as f64 / 1e6),
            bm.nvme_io_reqs().to_string(),
            format!("{:.1}", bm.nvme_bytes_written as f64 / 1e6),
            format!("{:.0}", bm.pool_hit_rate() * 100.0),
            if cr.is_finite() { format!("{cr:.1}") } else { "inf".into() },
            format!("{:.1}", bm.pool_peak_bytes as f64 / 1e6),
        ]);
    }
    vec![t]
}

fn fig_tiers_one(host: HostConfig, pages: u64, ops: u64) -> (Time, TierMetrics) {
    let frames = pages + 2048;
    // Half the working set fits: sustained reclaim + fault-back traffic.
    let limit = pages * 4096 / 2;
    let mut m = Machine::new(host);
    let mm_cfg = MmConfig {
        scan_interval: 50 * MS,
        history: 16,
        memory_limit: Some(limit),
        ..Default::default()
    };
    m.sys_vm(
        vm_cfg(frames, PageSize::Small, 1),
        &mm_cfg,
        vec![Box::new(UniformRandom::new(0, pages, ops))],
    );
    let res = m.run();
    (res[0].runtime, m.backend_metrics().clone())
}

/// Fig 12: g500 memory usage over time, default vs aggressive policy.
pub fn fig12(scale: Scale) -> Vec<Table> {
    let wl_scale = scale.f(0.25, 0.8);
    let mut t = Table::new(
        "g500 memory usage over time",
        &["t_pct", "default_mb", "sys_agg_mb"],
    );
    let d = fig12_series("2M", wl_scale);
    let a = fig12_series("sys-agg", wl_scale);
    for i in 0..20 {
        let pick = |s: &Vec<(Time, f64)>| {
            if s.is_empty() {
                return 0.0;
            }
            let idx = (i * s.len() / 20).min(s.len() - 1);
            s[idx].1 / 1e6
        };
        t.row(vec![
            format!("{}", i * 5),
            format!("{:.0}", pick(&d)),
            format!("{:.0}", pick(&a)),
        ]);
    }
    vec![t]
}

fn fig12_series(config: &str, wl_scale: f64) -> Vec<(Time, f64)> {
    let spec = cloud_preset("g500", wl_scale);
    let frames = spec.pages + spec.pages / 8 + 1024;
    let mut m = Machine::new(HostConfig::paper());
    let w: Vec<Box<dyn Workload>> = vec![Box::new(CloudWorkload::new(spec))];
    let mm_cfg = MmConfig { scan_interval: 80 * MS, history: 16, ..Default::default() };
    let units = vm_cfg(frames, PageSize::Huge, 1).units();
    let mut mm = Mm::new(
        &mm_cfg,
        units,
        PageSize::Huge.unit_bytes(),
        &m.host.sw,
        m.host.hw.zero_2m_ns,
    );
    mm.add_policy(Box::new(DtReclaimer::new(
        Box::new(NativeAnalytics::new()),
        mm_cfg.history,
        mm_cfg.target_promotion_rate,
    )));
    if config == "sys-agg" {
        mm.add_policy(Box::new(AggressivePolicy::new(80 * MS)));
    }
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    m.add_vm(VmSetup {
        vm_cfg: vm_cfg(frames, PageSize::Huge, 1),
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: w,
        scan_interval: Some(150 * MS),
    });
    let res = m.run();
    res[0].usage_series.clone()
}

/// Fig 13: recovery time after a memory-limit lift.
pub fn fig13(scale: Scale) -> Vec<Table> {
    let pages = scale.u(16_000, 64_000);
    let ops = scale.u(600_000, 2_400_000);
    let mut t = Table::new(
        "recovery after limit lift",
        &["config", "runtime_ms", "recovery_ms", "major_faults_after_lift"],
    );
    for config in ["sys-2M", "sys-4k", "sys-4k-wsr", "kernel"] {
        let r = fig13_one(config, pages, ops);
        t.row(vec![
            config.into(),
            format!("{:.0}", r.0 as f64 / 1e6),
            format!("{:.0}", r.1 as f64 / 1e6),
            r.2.to_string(),
        ]);
    }
    vec![t]
}

fn fig13_one(config: &str, pages: u64, ops: u64) -> (Time, Time, u64) {
    let frames = pages + 2048;
    // (thrash-then-recover: bounded below by construction)
    let tight = pages * 4096 * 3 / 10; // 30% of WSS: thrashing
    let lift_at = 2 * SEC;
    let mut m = Machine::new(HostConfig::paper());
    let w: Vec<Box<dyn Workload>> =
        vec![Box::new(UniformRandom::new(0, pages, ops))];
    let vmid = match config {
        "kernel" => {
            let lx = LinuxConfig {
                thp: true,
                memory_limit: Some(tight),
                ..Default::default()
            };
            m.kernel_vm(vm_cfg(frames, PageSize::Small, 1), &lx, w, None, 30 * MS)
        }
        _ => {
            let mode = if config == "sys-2M" { PageSize::Huge } else { PageSize::Small };
            let mm_cfg = MmConfig {
                scan_interval: 30 * MS,
                history: 16,
                memory_limit: Some(tight),
                ..Default::default()
            };
            let units = vm_cfg(frames, mode, 1).units();
            let mut mm = Mm::new(&mm_cfg, units, mode.unit_bytes(), &m.host.sw, m.host.hw.zero_2m_ns);
            mm.add_policy(Box::new(DtReclaimer::new(
                Box::new(NativeAnalytics::new()),
                mm_cfg.history,
                mm_cfg.target_promotion_rate,
            )));
            if config == "sys-4k-wsr" {
                mm.add_policy(Box::new(WsrPolicy::new(units)));
            }
            mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
            m.add_vm(VmSetup {
                vm_cfg: vm_cfg(frames, mode, 1),
                mech: Mechanism::Sys(Box::new(mm)),
                workloads: w,
                scan_interval: Some(30 * MS),
            })
        }
    };
    // One-shot release through the in-loop control plane (the old
    // external plan_limit_change path, migrated in PR 3).
    m.schedule_limit(vmid, lift_at, None);
    let res = m.run();
    let r = &res[0];
    // Recovery: time after the lift until the PF rate falls below 5% of
    // its pre-lift peak.
    let peak = r
        .pf_series
        .iter()
        .filter(|(t, _)| *t <= lift_at)
        .map(|(_, v)| *v)
        .fold(0.0f64, f64::max);
    let recovered_at = r
        .pf_series
        .iter()
        .find(|(t, v)| *t > lift_at + 200 * MS && *v < peak * 0.05)
        .map(|(t, _)| *t)
        .unwrap_or(r.runtime);
    let majors_after = 0; // counters are cumulative; report via hist below
    (
        r.runtime,
        recovered_at.saturating_sub(lift_at),
        majors_after + r.counters.faults_major,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_quick_shape() {
        let t = &fig6(Scale::Quick)[0];
        // kernel-4k row: total below sys-4k.
        let k: f64 = t.rows[0][2].parse().unwrap();
        let s4: f64 = t.rows[1][2].parse().unwrap();
        let s2: f64 = t.rows[2][2].parse().unwrap();
        assert!(s4 > k, "sys-4k {s4} vs kernel {k}");
        // sys-4k within ~25% of kernel (paper: +13%).
        assert!(s4 / k < 1.35, "ratio {}", s4 / k);
        // 2M an order of magnitude above kernel-4k (paper: 11x).
        assert!(s2 / k > 6.0 && s2 / k < 16.0, "2M ratio {}", s2 / k);
        // VMEXIT share small for 2M.
        let share2: f64 = t.rows[2][3].parse().unwrap();
        assert!(share2 < 10.0, "share {share2}");
    }

    #[test]
    fn fig7_quick_2m_saturates() {
        let t = &fig7(Scale::Quick)[0];
        // At 8 vCPUs the 2M config approaches the 2.6 GB/s bus.
        let bw2m: f64 = t.rows[3][3].parse().unwrap();
        assert!(bw2m > 1.8, "2M bw {bw2m}");
        // 4k sys and kernel in the same ballpark.
        let bwk: f64 = t.rows[3][1].parse().unwrap();
        let bw4: f64 = t.rows[3][2].parse().unwrap();
        assert!(bw4 / bwk > 0.4 && bw4 / bwk < 2.5, "4k {bw4} vs kernel {bwk}");
        // 2M >> 4k.
        assert!(bw2m > bw4 * 3.0);
    }

    #[test]
    fn figpf_quick_gva_beats_hva() {
        let t = &fig_pf(Scale::Quick)[0];
        let hva_timely: f64 = t.rows[1][3].parse().unwrap();
        let gva_timely: f64 = t.rows[2][3].parse().unwrap();
        assert!(gva_timely > 60.0, "gva timely {gva_timely}");
        assert!(hva_timely < 20.0, "hva timely {hva_timely}");
        let gva_impr: f64 = t.rows[2][2].parse().unwrap();
        assert!(gva_impr > 5.0, "gva improvement {gva_impr}");
    }

    #[test]
    fn fmt_helper_reachable() {
        assert_eq!(fmt_bytes(4096), "4KiB");
    }

    #[test]
    fn tiers_quick_tiered_beats_flat_on_requests() {
        let pages = 4_000;
        let ops = 120_000;
        let (_, flat) = fig_tiers_one(HostConfig::paper(), pages, ops);
        let (_, tiered) = fig_tiers_one(HostConfig::default(), pages, ops);
        assert_eq!(flat.pool_hits, 0);
        assert!(flat.nvme_io_reqs() > 0);
        assert!(
            tiered.nvme_io_reqs() < flat.nvme_io_reqs(),
            "tiered {} vs flat {}",
            tiered.nvme_io_reqs(),
            flat.nvme_io_reqs()
        );
        assert!(tiered.pool_hit_rate() > 0.3, "hit rate {}", tiered.pool_hit_rate());
        assert!(tiered.compression_ratio() > 1.5);
    }
}
