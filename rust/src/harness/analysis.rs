//! §3 analysis experiments: Fig 1 (hugepage swap trade-off), Fig 2
//! (virtualization scrambles access patterns), Fig 3 (EPT scan costs).

use crate::config::{HostConfig, HwConfig, MmConfig, SwCost, VmConfig};
use crate::coordinator::Machine;
use crate::metrics::Table;
use crate::scanner::EptScanner;
use crate::sim::Rng;
use crate::types::{PageSize, MS, SEC, US};
use crate::vm::{AccessResult, Vm};
use crate::workloads::{ColdRatio, SeqScan, UniformRandom, Workload};

use super::Scale;

/// Fig 1: average access latency vs cold-page access ratio.
pub fn fig1(scale: Scale) -> Vec<Table> {
    let ratios = [0.0, 1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 1e-2];
    let ops = scale.u(40_000, 200_000);
    let mut t = Table::new(
        "avg access latency (ns) vs cold-access ratio",
        &["cold_ratio", "strict_4k_ns", "strict_2M_ns", "winner"],
    );
    let mut crossover: Option<f64> = None;
    let mut prev_winner = None;
    for &r in &ratios {
        let lat4k = fig1_one(PageSize::Small, r, ops);
        let lat2m = fig1_one(PageSize::Huge, r, ops);
        let winner = if lat2m <= lat4k { "2M" } else { "4k" };
        if prev_winner == Some("2M") && winner == "4k" && crossover.is_none() {
            crossover = Some(r);
        }
        prev_winner = Some(winner);
        t.row(vec![
            format!("{r:.0e}"),
            format!("{lat4k:.0}"),
            format!("{lat2m:.0}"),
            winner.to_string(),
        ]);
    }
    t.row(vec![
        "break-even".into(),
        "-".into(),
        "-".into(),
        crossover.map(|r| format!("~{r:.0e}")).unwrap_or("none<=1e-2".into()),
    ]);
    vec![t]
}

fn fig1_one(mode: PageSize, cold_ratio: f64, ops: u64) -> f64 {
    let mut m = Machine::new(HostConfig::paper());
    // Hot region resident, cold region swapped out; near-100% TLB miss
    // (hot region much larger than TLB reach).
    let frames = 96_000u64;
    let hot_pages = 64_000u64;
    let cold_pages = 16_000u64;
    let cfg = VmConfig {
        frames,
        vcpus: 1,
        page_size: mode,
        scramble: 0.0,
        guest_thp_coverage: 1.0,
    };
    // A memory limit just above the hot set keeps the cold region
    // swapped out in steady state (the paper sizes the swap region so
    // cold accesses always miss).
    let slack = 4 * mode.unit_frames();
    let mm_cfg = MmConfig {
        scan_interval: 3600 * SEC, // no proactive reclamation
        memory_limit: Some((hot_pages + slack) * 4096),
        ..Default::default()
    };
    let vmid = m.sys_vm(
        cfg,
        &mm_cfg,
        vec![Box::new(ColdRatio::new(hot_pages, cold_pages, cold_ratio, ops))],
    );
    // Pre-state: hot region resident + mapped, cold region swapped out.
    m.prime_resident(vmid, hot_pages);
    m.prime_swapped(vmid, hot_pages, hot_pages + cold_pages);
    let res = m.run();
    let r = &res[0];
    (r.runtime as f64) / (r.work_ops.max(1) as f64)
}

/// Fig 2: the same workload seen in GVA (in-guest scan) vs GPA
/// (hypervisor EPT scan) space. We report a locality score: the fraction
/// of accessed-page pairs that are neighbours in each address space.
pub fn fig2(scale: Scale) -> Vec<Table> {
    let pages = scale.u(8_192, 32_768);
    let phase_ops = scale.u(40_000, 160_000);
    let host = HostConfig::default();
    let mut rng = Rng::new(7);
    let cfg = VmConfig {
        frames: pages + 1024,
        vcpus: 1,
        page_size: PageSize::Small,
        scramble: 1.0, // aged guest (the paper warms up with random churn)
        guest_thp_coverage: 1.0,
    };
    let mut vm = Vm::new(&cfg, &host.hw, &host.sw, &mut rng);
    let p = vm.spawn_process(pages);
    for u in 0..vm.units() {
        vm.ept.map(u);
    }
    let mut w = crate::workloads::AlternatingHalves::new(pages, phase_ops);
    let mut scanner = EptScanner::new(&host.hw);

    let mut t = Table::new(
        "phase locality: GVA vs GPA view",
        &["phase", "space", "accessed_pages", "low_half_frac", "neighbour_frac"],
    );
    for phase in 0..2 {
        // Drive one phase of accesses.
        for _ in 0..phase_ops {
            if let crate::workloads::Op::Access { gva_page, write, ip, .. } =
                w.next(&mut rng)
            {
                let _ = vm.access(0, p, gva_page, write, ip, 0, &mut rng);
            }
        }
        // Guest-side (direct) view.
        let gva_bits = vm.processes[p].pt.scan_and_clear();
        // Hypervisor (EPT) view.
        let out = scanner.scan(&mut vm, None, phase as u64 * SEC);
        for (space, bits, len) in [
            ("GVA", &gva_bits, pages as usize),
            ("GPA", &out.bitmap, vm.units() as usize),
        ] {
            let ones: Vec<usize> = bits.iter_ones().collect();
            let low = ones.iter().filter(|&&i| i < len / 2).count();
            let mut neigh = 0usize;
            for w2 in ones.windows(2) {
                if w2[1] == w2[0] + 1 {
                    neigh += 1;
                }
            }
            t.row(vec![
                format!("{}", phase + 1),
                space.to_string(),
                ones.len().to_string(),
                format!("{:.2}", low as f64 / ones.len().max(1) as f64),
                format!("{:.2}", neigh as f64 / ones.len().max(1) as f64),
            ]);
        }
    }
    vec![t]
}

/// Fig 3: direct (%CPU) and indirect (runtime) cost vs scan interval,
/// for 4k and 2M EPT leaves.
pub fn fig3(scale: Scale) -> Vec<Table> {
    let intervals = [100 * MS, 50 * MS, 20 * MS, 10 * MS, 5 * MS];
    let ops = scale.u(600_000, 2_400_000);
    let mut t = Table::new(
        "EPT scan cost vs interval",
        &["interval_ms", "mode", "direct_cpu_pct", "runtime_ms", "slowdown_pct"],
    );
    for mode in [PageSize::Small, PageSize::Huge] {
        let base = fig3_one(mode, 3600 * SEC, ops); // no scanning
        for &iv in &intervals {
            let (runtime, scan_cpu) = fig3_one_full(mode, iv, ops);
            let direct = scan_cpu as f64 / runtime as f64 * 100.0;
            let slow = (runtime as f64 / base as f64 - 1.0) * 100.0;
            t.row(vec![
                format!("{}", iv / MS),
                mode.label().to_string(),
                format!("{direct:.2}"),
                format!("{:.1}", runtime as f64 / 1e6),
                format!("{slow:.1}"),
            ]);
        }
    }
    vec![t]
}

fn fig3_one(mode: PageSize, interval: u64, ops: u64) -> u64 {
    fig3_one_full(mode, interval, ops).0
}

fn fig3_one_full(mode: PageSize, interval: u64, ops: u64) -> (u64, u64) {
    let mut m = Machine::new(HostConfig::paper());
    let frames = 16_384;
    let cfg = VmConfig {
        frames,
        vcpus: 1,
        page_size: mode,
        scramble: 0.0,
        guest_thp_coverage: 1.0,
    };
    let mm_cfg = MmConfig { scan_interval: interval, ..Default::default() };
    let vmid = m.sys_vm(
        cfg,
        &mm_cfg,
        // Sequential read scan over memory (paper's workload).
        vec![Box::new(SeqScan::new(frames - 1024, (ops / (frames - 1024)).max(1), 0))],
    );
    m.prime_resident(vmid, frames - 1024);
    let res = m.run();
    (res[0].runtime, res[0].scan_cpu_ns)
}

/// Warm-start helper used across harness experiments: shared by the
/// uniform microbenchmarks. (Re-exported for the eval module.)
pub fn uniform_vm(
    m: &mut Machine,
    mode: PageSize,
    frames: u64,
    pages: u64,
    ops: u64,
    mm_cfg: &MmConfig,
) -> usize {
    let cfg = VmConfig {
        frames,
        vcpus: 1,
        page_size: mode,
        scramble: 0.5,
        guest_thp_coverage: 1.0,
    };
    m.sys_vm(cfg, mm_cfg, vec![Box::new(UniformRandom::new(0, pages, ops))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_quick_produces_crossover_shape() {
        let tables = fig1(Scale::Quick);
        let t = &tables[0];
        // First data row (ratio 0): 2M must win (shorter walks).
        assert_eq!(t.rows[0][3], "2M");
        // Last data row (1e-2): 4k must win (smaller faults).
        let last = &t.rows[t.rows.len() - 2];
        assert_eq!(last[3], "4k", "{last:?}");
    }

    #[test]
    fn fig2_quick_shows_scrambling() {
        let tables = fig2(Scale::Quick);
        let rows = &tables[0].rows;
        // Phase 1 GVA low-half fraction ~1.0; GPA ~0.5 (scrambled).
        let gva_low: f64 = rows[0][3].parse().unwrap();
        let gpa_low: f64 = rows[1][3].parse().unwrap();
        assert!(gva_low > 0.95, "gva {gva_low}");
        assert!(gpa_low < 0.75, "gpa {gpa_low}");
    }

    #[test]
    fn fig3_quick_scan_costs_grow_with_frequency() {
        let tables = fig3(Scale::Quick);
        let rows = &tables[0].rows;
        // Within the 4k block (first 6 rows), direct cost grows as the
        // interval shrinks.
        let first: f64 = rows[0][2].parse().unwrap();
        let last: f64 = rows[4][2].parse().unwrap();
        assert!(last > first, "direct {first} -> {last}");
        // 2M scanning much cheaper than 4k at the same interval.
        let d4k: f64 = rows[4][2].parse().unwrap();
        let d2m: f64 = rows[9][2].parse().unwrap();
        assert!(d2m < d4k / 10.0, "4k {d4k} vs 2m {d2m}");
        let _ = US;
    }
}
