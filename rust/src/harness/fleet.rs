//! The `fleet` experiment: a 64–128 VM mixed-SLA host driven by the
//! event-driven control plane.
//!
//! Two questions, two tables:
//!
//! 1. **Density/latency** — the same heterogeneous fleet (three SLA
//!    classes × four VM sizes, phase-churning working sets, staggered
//!    boots) under *static* weighted-share limits vs the *closed-loop*
//!    proportional-share arbiter. The closed loop tracks reported WSS,
//!    so it should beat static on memory saved and/or p99 fault stall
//!    while Σ(resident + pool) never exceeds the host budget at any
//!    control tick.
//! 2. **Release recovery** (fig13-style) — a thrashing VM whose hard
//!    limit is released mid-run, with and without the recovery-boost
//!    hint to the prefetchers; recovery with the boost must be no
//!    slower.
//! 3. **Fleet sharding** (PR 4) — the same mixed-SLA population spread
//!    over 4 host shards by the [`FleetScheduler`], with one host's
//!    budget deliberately short of its working-set demand. Static
//!    placement leaves that host thrashing; the fault-rate-delta
//!    rebalancer stages cold-memory migrations from the slackest
//!    shards, so total major faults drop while Σ saved memory holds
//!    (every shard stays limit-bound, and Σ budgets is conserved).
//! 4. **Host failure** (PR 7) — the same state-migration fleet with
//!    host 0 faulted mid-run, hard crash vs graceful drain
//!    (degraded-NVMe). The drain arm evacuates its VMs with their
//!    resident sets through state migration; the crash arm rebuilds
//!    them from salvaged NVMe receipts and refaults everything. Drain
//!    must beat crash on recovered-VM p99 fault stall and SLA
//!    violations, with at least one completed evacuation flip.
//! 5. **Remote marketplace** (PR 9) — the pressured static-placement
//!    fleet with the remote-memory marketplace armed vs NVMe-only.
//!    Donor hosts with empty pools post offers at fleet ticks; the
//!    demand-infeasible host bids, and matched leases stage its
//!    coldest pool entries into donor DRAM behind a modeled network
//!    round trip. Remote-armed must strictly beat NVMe-only on the
//!    pressured host's p99 fault stall while Σ budgets stay exactly
//!    conserved (begin/cancel-only escrow) and every shard holds
//!    Σ(resident + pool) ≤ budget at every tick.
//! 6. **Boot-storm autoscaling** (PR 10, the separate `clone_storm`
//!    experiment in this module) — a burst of VMs clone-admitted from
//!    a shared read-only golden image at fleet-tick barriers: zero
//!    resident memory at implant, boot faults decompressing units out
//!    of the host's dedup'd refcounted pool copy while `LinearPf`
//!    boot-streams ahead. Image-backed clones must beat cold boots
//!    (full NVMe zero-fill per fault) on time-to-first-useful-work
//!    p99, the golden-image dedup ratio must exceed 1, packing must
//!    hold the image on fewer hosts than spreading, and the storm
//!    must preserve every engine-identity and Σ-budget invariant.
//!
//! All arms run through the single unified entry point,
//! [`run_sharded_fleet`], parameterized by [`FleetRunOpts`].

use crate::config::{
    ArbiterKind, CloneConfig, ControlConfig, FleetConfig, HostConfig, HostFault, HostFaultKind,
    MmConfig, PlacementPolicy, RemoteConfig, TierConfig, VmConfig,
};
use crate::coordinator::{Machine, Mechanism, VmSetup};
use crate::daemon::{FleetScheduler, FleetVmSpec, Sla};
use crate::metrics::{LatencyHist, Table};
use crate::mm::Mm;
use crate::policies::{DtReclaimer, LruReclaimer, NativeAnalytics, WsrPolicy};
use crate::sim::Rng;
use crate::types::{GranularityMode, PageSize, Time, FRAME_BYTES, MS, REGION_UNITS, SEC};
use crate::workloads::{BootDelay, PhasedWss, SeqScan, UniformRandom, Workload};

use super::Scale;

/// Aggregate outcome of one fleet run (public: the control-plane tests
/// re-run fleets for determinism and budget-invariant checks).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSummary {
    pub vms: usize,
    pub budget_bytes: u64,
    pub nominal_bytes: u64,
    /// Mean Σ(resident + pool) over all control ticks.
    pub avg_host_bytes: f64,
    pub peak_host_bytes: u64,
    pub budget_exceeded_ticks: u64,
    pub min_headroom_bytes: i64,
    pub limit_changes: u64,
    pub p99_stall_ns: u64,
    pub mean_stall_ns: f64,
    pub majors: u64,
    pub total_ops: u64,
    /// Latest VM finish time.
    pub runtime_ns: Time,
    /// 1 - avg_host/nominal: the host-density win.
    pub saved_frac: f64,
}

/// Shape of one fleet VM: SLA and size class are deliberately
/// decorrelated (period 12) so weight-blind static shares starve some
/// big-WSS Bronze VMs — the misallocation the arbiter fixes.
fn vm_shape(i: usize) -> (Sla, u64) {
    let sla = [Sla::Gold, Sla::Silver, Sla::Bronze][i % 3];
    let frames = [4096u64, 8192, 12288, 16384][(i / 3) % 4];
    (sla, frames)
}

/// Build and run one fleet. Deterministic in `seed`.
pub fn run_fleet(n: usize, ops_per_vm: u64, kind: ArbiterKind, seed: u64) -> FleetSummary {
    let host = HostConfig {
        seed,
        tier: TierConfig { pool_capacity_bytes: 64 * 1024 * 1024, ..Default::default() },
        ..Default::default()
    };

    // Shapes first: the budget and the initial static shares need the
    // whole fleet.
    let shapes: Vec<(Sla, u64)> = (0..n).map(vm_shape).collect();
    let nominal: u64 = shapes.iter().map(|&(_, f)| f * 4096).sum();
    let budget = nominal / 100 * 72;
    let total_weight: u64 = shapes.iter().map(|&(s, _)| s.weight()).sum();
    let inflight: u64 = shapes
        .iter()
        .map(|&(s, _)| swapper_threads(s) as u64 * s.page_size().unit_bytes())
        .sum();
    let usable = budget - host.tier.pool_capacity_bytes - inflight;

    let mut m = Machine::new(host);
    m.set_max_time(30 * SEC);
    m.install_control(ControlConfig {
        interval: 25 * MS,
        host_budget_bytes: Some(budget),
        kind,
        recovery_boost_window: 300 * MS,
        ..Default::default()
    });

    for (i, &(sla, frames)) in shapes.iter().enumerate() {
        let share = usable * sla.weight() / total_weight;
        let mm_cfg = MmConfig {
            swapper_threads: swapper_threads(sla),
            memory_limit: Some(share),
            scan_interval: scan_interval(sla),
            history: 6,
            target_promotion_rate: match sla {
                Sla::Gold => 0.005,
                Sla::Silver => 0.02,
                Sla::Bronze => 0.08,
            },
            ..Default::default()
        };
        let vm_cfg = VmConfig {
            frames,
            vcpus: 1,
            page_size: sla.page_size(),
            scramble: 0.05,
            guest_thp_coverage: 1.0,
        };
        let pages = frames - 1024;
        // Phase churn: half the fleet expands its working set mid-run,
        // half contracts — the time-varying demand the closed loop
        // tracks and static shares cannot.
        let phases = if i % 2 == 0 {
            vec![(pages / 3, ops_per_vm / 2), (pages, ops_per_vm / 2)]
        } else {
            vec![(pages, ops_per_vm / 2), (pages / 3, ops_per_vm / 2)]
        };
        let w: Box<dyn Workload> = Box::new(BootDelay::new(
            (i as u64 % 8) * 10 * MS,
            Box::new(PhasedWss::with_cost(phases, 40_000)),
        ));
        let id = m.sys_vm(vm_cfg, &mm_cfg, vec![w]);
        m.register_control_vm(id, format!("vm{i}"), sla);
    }

    let results = m.run();
    let mut hist = LatencyHist::default();
    let mut majors = 0;
    let mut total_ops = 0;
    let mut runtime = 0;
    for r in &results {
        hist.merge(&r.fault_hist);
        majors += r.counters.faults_major;
        total_ops += r.work_ops;
        runtime = runtime.max(r.runtime);
    }
    let stats = m.control_stats().expect("fleet has a control plane");
    let avg_host = if stats.host_series.is_empty() {
        0.0
    } else {
        stats.host_series.iter().map(|(_, r, p)| r + p).sum::<f64>()
            / stats.host_series.len() as f64
    };
    FleetSummary {
        vms: n,
        budget_bytes: budget,
        nominal_bytes: nominal,
        avg_host_bytes: avg_host,
        peak_host_bytes: stats.peak_host_bytes,
        budget_exceeded_ticks: stats.budget_exceeded_ticks,
        min_headroom_bytes: stats.min_headroom_bytes,
        limit_changes: stats.limit_changes,
        p99_stall_ns: hist.quantile(0.99),
        mean_stall_ns: hist.mean(),
        majors,
        total_ops,
        runtime_ns: runtime,
        saved_frac: 1.0 - avg_host / nominal as f64,
    }
}

fn swapper_threads(sla: Sla) -> usize {
    // Huge-unit VMs get fewer workers: each worker's in-flight unit is
    // 2MB of budget reservation.
    match sla.page_size() {
        PageSize::Huge => 2,
        PageSize::Small => 4,
    }
}

fn scan_interval(sla: Sla) -> Time {
    match sla {
        Sla::Gold => 100 * MS,
        Sla::Silver => 60 * MS,
        Sla::Bronze => 40 * MS,
    }
}

/// Outcome of one release-recovery run (fig13-style).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoverySummary {
    pub runtime_ns: Time,
    /// Work remaining after the release: runtime - lift time. The
    /// recovery metric — lower is faster.
    pub after_lift_ns: Time,
    pub majors: u64,
    pub prefetch_issued: u64,
    pub prefetch_timely: u64,
}

/// One VM thrashing under a 30% hard limit; at 1.2s the control plane
/// releases the limit to 85% of the working set — enough to recover,
/// tight enough that the one-shot WSR restore cannot cover everything
/// (so the boost's re-restores have majors left to convert).
pub fn recovery_release(boost: bool, ops: u64, seed: u64) -> RecoverySummary {
    let pages = 6_000u64;
    let frames = pages + 1024;
    let tight = pages * 4096 * 3 / 10;
    let released = pages * 4096 * 85 / 100;
    let lift_at = 1_200 * MS;

    let mut m = Machine::new(HostConfig { seed, ..Default::default() });
    m.set_max_time(60 * SEC);
    m.install_control(ControlConfig {
        recovery_boost_window: 600 * MS,
        ..Default::default()
    });
    let mm_cfg = MmConfig {
        scan_interval: 30 * MS,
        history: 8,
        memory_limit: Some(tight),
        ..Default::default()
    };
    let vm_cfg = VmConfig {
        frames,
        vcpus: 1,
        page_size: PageSize::Small,
        scramble: 0.05,
        guest_thp_coverage: 1.0,
    };
    let units = vm_cfg.units();
    let mut mm = Mm::new(&mm_cfg, units, 4096, &m.host.sw, m.host.hw.zero_2m_ns);
    mm.add_policy(Box::new(DtReclaimer::new(
        Box::new(NativeAnalytics::new()),
        mm_cfg.history,
        mm_cfg.target_promotion_rate,
    )));
    mm.add_policy(Box::new(WsrPolicy::new(units)));
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    let vmid = m.add_vm(VmSetup {
        vm_cfg,
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: vec![Box::new(UniformRandom::new(0, pages, ops))],
        scan_interval: Some(30 * MS),
    });
    m.schedule_limit_release(vmid, lift_at, Some(released), boost, false);
    let res = m.run();
    let r = &res[0];
    RecoverySummary {
        runtime_ns: r.runtime,
        after_lift_ns: r.runtime.saturating_sub(lift_at),
        majors: r.counters.faults_major,
        prefetch_issued: r.counters.prefetch_issued,
        prefetch_timely: r.counters.prefetch_timely,
    }
}

/// Which rebalancing tools one sharded-fleet arm runs with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Admission-time placement is final: no cross-host rebalancing.
    StaticPlacement,
    /// The PR 4 budget lease only: cold memory's budget moves, the VM
    /// itself never does.
    LeaseOnly,
    /// Full VM state migration, with the lease as fallback when no
    /// shard can absorb a whole VM.
    StateMigration,
}

impl FleetMode {
    fn label(self) -> &'static str {
        match self {
            FleetMode::StaticPlacement => "static-placement",
            FleetMode::LeaseOnly => "lease-only",
            FleetMode::StateMigration => "state-migration",
        }
    }
}

/// Per-host outcome of one sharded fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct HostRow {
    pub host: usize,
    pub vms: usize,
    /// Audited budget at admission / after the run (a lease moves it; a
    /// state migration does not).
    pub budget_start: u64,
    pub budget_end: u64,
    pub avg_host_bytes: f64,
    pub peak_host_bytes: u64,
    pub budget_exceeded_ticks: u64,
    pub min_headroom_bytes: i64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Whole VMs this host received / shipped via state migration.
    pub vms_in: u64,
    pub vms_out: u64,
    pub majors: u64,
}

/// Aggregate outcome of one 4-host sharded fleet run (public: the
/// invariant suite re-runs these for determinism / conservation /
/// rebalancer-beats-static checks).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedSummary {
    pub hosts: usize,
    pub vms: usize,
    pub mode: FleetMode,
    pub per_host: Vec<HostRow>,
    pub total_majors: u64,
    pub total_ops: u64,
    /// Σ over hosts of mean Σ(resident + pool) — fleet occupancy.
    pub avg_fleet_bytes: f64,
    pub nominal_bytes: u64,
    /// 1 - avg_fleet/nominal: the fleet-wide density win.
    pub saved_frac: f64,
    pub migrations_started: u64,
    pub migrations_completed: u64,
    pub migrations_aborted: u64,
    pub migrated_bytes: u64,
    /// VM state-migration ledger (zero outside `StateMigration` mode).
    pub state_migrations_started: u64,
    pub state_migrations_completed: u64,
    pub state_migrations_aborted: u64,
    pub state_precopy_bytes: u64,
    pub state_flip_bytes: u64,
    pub state_stop_ns_max: u64,
    pub handoff_violations: u64,
    pub conservation_violations: u64,
    /// Σ audited budgets after the run (must equal the initial Σ minus
    /// whatever crashes and revocations retired).
    pub budget_total_end: u64,
    pub budget_total_start: u64,
    pub p99_stall_ns: u64,
    pub runtime_ns: Time,
    /// PR 7 fault/recovery ledger (all zero with no faults armed).
    pub faults_injected: u64,
    pub crashes: u64,
    pub degrades: u64,
    pub revocations: u64,
    pub budget_retired_bytes: u64,
    pub vms_rebuilt: u64,
    pub rebuild_salvaged_bytes: u64,
    pub rebuild_lost_bytes: u64,
    pub drains_started: u64,
    pub drains_completed: u64,
    pub drain_deadline_misses: u64,
    pub residency_restored: u64,
    pub residency_restore_ns_max: u64,
    /// Fault-stall stats over the *recovered population*: VMs admitted
    /// to a host the fault plan targets, measured across the whole run
    /// wherever they end up. Empty plan → zero VMs.
    pub recovered_vms: usize,
    pub recovered_p99_stall_ns: u64,
    /// Recovered VMs whose own p99 fault stall exceeds [`FAULT_SLA_NS`].
    pub recovered_sla_violations: u64,
    /// PR 9 remote-marketplace ledger (all zero with remote disarmed).
    pub remote_leases: u64,
    pub remote_leased_bytes: u64,
    pub remote_staged_bytes: u64,
    pub remote_revocations: u64,
    pub remote_recalled_bytes: u64,
    pub remote_dropped_bytes: u64,
    /// Faults across the fleet served from a remote lease instead of
    /// local NVMe.
    pub remote_hits: u64,
    /// p99 fault stall over host 0's VMs only — the deliberately
    /// demand-infeasible shard the marketplace exists to relieve.
    pub pressured_p99_stall_ns: u64,
    /// PR 10 clone-storm ledger (all zero with storms disarmed).
    pub clones_staged: u64,
    pub clones_admitted: u64,
    pub clone_cold_boots: u64,
    /// p99 time-to-first-useful-work measured from each storm VM's
    /// admission tick: image-backed clones vs the cold-boot arm.
    pub clone_first_work_p99_ns: u64,
    pub cold_first_work_p99_ns: u64,
    /// Σ over hosts of golden-image stored / logical bytes at the end
    /// of the run (dedup ratio = logical / stored).
    pub image_stored_bytes: u64,
    pub image_logical_bytes: u64,
    pub image_hits: u64,
    pub image_cow_breaks: u64,
    /// Image-backed clones resident per host at the end of the run —
    /// the spread-vs-pack evidence.
    pub clones_per_host: Vec<usize>,
}

impl ShardedSummary {
    /// Fleet-wide golden-image dedup ratio: logical bytes the clones
    /// would hold privately over bytes actually stored (0 when no
    /// image is installed anywhere).
    pub fn image_dedup_ratio(&self) -> f64 {
        if self.image_stored_bytes == 0 {
            0.0
        } else {
            self.image_logical_bytes as f64 / self.image_stored_bytes as f64
        }
    }
}

/// The per-VM p99 fault-stall bound the failure experiment scores
/// against: a recovered VM above this counts as an SLA violation.
pub const FAULT_SLA_NS: u64 = MS;

/// Fleet-run options — the ONE parameter object the unified
/// [`run_sharded_fleet`] runner takes. PR 10 collapsed the old
/// positional variants (`_exec`, `_faulted`, `_granular`, `_market`)
/// into this: `Default` is the canonical shape (parallel epoch engine
/// on all cores, flat 4k granularity, no faults, no remote
/// marketplace, no clone storm), and the builder-style `with_*`
/// methods override one knob at a time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetRunOpts {
    /// Run the sequential `(time, shard index)` merge oracle instead of
    /// the parallel epoch engine. Output is byte-identical either way.
    pub sequential: bool,
    /// Worker-thread cap for the parallel engine (None: all cores).
    pub workers: Option<usize>,
    /// VMs per host, overriding the scale default (the nightly
    /// `--vms TOTAL` knob, divided by the host count in `main`).
    pub per_host: Option<usize>,
    /// Fault-schedule *plan* for soak runs (`--fault-plan`): each soak
    /// seed derives its own concrete [`FleetRunOpts::faults`] from it.
    pub fault_plan: FaultPlan,
    /// Concrete fault schedule armed on this run (PR 7).
    pub faults: Vec<HostFault>,
    /// Swap granularity: VM `i` gets `granularity[i % len]`, so one
    /// element sets a uniform mode (the `--granularity` CLI path) and
    /// several seed a mixed-granularity fleet (the chaos sweep's PR 8
    /// arm). Empty means flat 4k for everyone — the canonical shape
    /// the acceptance comparisons are pinned to.
    pub granularity: Vec<GranularityMode>,
    /// Arm the PR 9 remote-memory marketplace (`--remote`): leases
    /// matched at fleet ticks, donor budgets sized for spare DRAM.
    pub remote: bool,
    /// Donor budget sizing as % of hot-phase demand. 0 means auto:
    /// 300 with the marketplace armed (donors never reclaim, pools sit
    /// empty, real DRAM headroom hosts staged bytes), 130 otherwise
    /// (donors limit-bound with modest slack).
    pub donor_pct: u64,
    /// Clone-from-image parameters (PR 10). `enabled` is forced on
    /// whenever a storm is staged; `image_units` is rounded up so the
    /// golden image covers a storm VM's whole gpa space.
    pub clone: CloneConfig,
    /// Image-backed storm clones staged before the run (admitted at
    /// fleet ticks, [`CloneConfig::clones_per_tick`] at a time).
    pub storm_clones: usize,
    /// Cold-boot comparison VMs staged interleaved with the clones:
    /// same zero-resident start, no golden image behind the faults.
    pub storm_cold: usize,
    /// Storm-VM memory limit as % of the boot working set (0 = 100).
    /// The balloon arm squeezes it: the guest hands memory back before
    /// host swap gets involved (the arxiv 1411.7344 comparison).
    pub storm_limit_pct: u64,
    /// CLI `--clone-storm` switch: also run the clone-storm tables.
    pub clone_storm: bool,
}

impl FleetRunOpts {
    pub fn with_sequential(mut self, sequential: bool) -> Self {
        self.sequential = sequential;
        self
    }
    pub fn with_workers(mut self, workers: Option<usize>) -> Self {
        self.workers = workers;
        self
    }
    pub fn with_per_host(mut self, per_host: Option<usize>) -> Self {
        self.per_host = per_host;
        self
    }
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = plan;
        self
    }
    pub fn with_faults(mut self, faults: Vec<HostFault>) -> Self {
        self.faults = faults;
        self
    }
    pub fn with_granularity(mut self, granularity: Vec<GranularityMode>) -> Self {
        self.granularity = granularity;
        self
    }
    pub fn with_remote(mut self, remote: bool) -> Self {
        self.remote = remote;
        self
    }
    pub fn with_donor_pct(mut self, pct: u64) -> Self {
        self.donor_pct = pct;
        self
    }
    /// Stage a clone storm: `clones` image-backed + `cold` cold-boot
    /// comparison VMs, interleaved so each admission tick carries both
    /// arms (paired admission times keep the p99 comparison fair).
    pub fn with_storm(mut self, clones: usize, cold: usize) -> Self {
        self.storm_clones = clones;
        self.storm_cold = cold;
        self.clone.enabled = self.clone.enabled || clones + cold > 0;
        self
    }
    pub fn with_clone(mut self, clone: CloneConfig) -> Self {
        self.clone = clone;
        self
    }
    pub fn with_pack(mut self, pack: bool) -> Self {
        self.clone.pack = pack;
        self
    }
    pub fn with_storm_limit_pct(mut self, pct: u64) -> Self {
        self.storm_limit_pct = pct;
        self
    }

    /// Resolved donor budget % (see [`FleetRunOpts::donor_pct`]).
    fn donor_pct_resolved(&self) -> u64 {
        if self.donor_pct != 0 {
            self.donor_pct
        } else if self.remote {
            300
        } else {
            130
        }
    }

    fn storm_total(&self) -> usize {
        self.storm_clones + self.storm_cold
    }
}

/// Which fault schedule a soak run arms (`--fault-plan <none|random>`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum FaultPlan {
    /// No injected faults (the default).
    #[default]
    None,
    /// A seed-derived chaos schedule of crash / degraded-NVMe /
    /// budget-revocation faults ([`random_fault_plan`]).
    Random,
}

/// Deterministic seed-derived chaos schedule: roughly half the hosts
/// take one fault each, timed in the middle half of the fleet's pure
/// compute span (so faults land while VMs are still working), with
/// crashes capped at `hosts - 2` so recovery always has live shards to
/// land on.
pub fn random_fault_plan(hosts: usize, ops_per_vm: u64, seed: u64) -> Vec<HostFault> {
    let mut rng = Rng::new(seed ^ 0x00FA_0175);
    // [`run_sharded_fleet`] workloads cost 20µs of compute per op.
    let work_ns = ops_per_vm * 20_000;
    let (lo, hi) = (work_ns / 4, (work_ns * 3 / 4).max(work_ns / 4 + 1));
    let crash_cap = hosts.saturating_sub(2);
    let mut crashes = 0usize;
    let mut plan = Vec::new();
    for host in 0..hosts {
        let at = rng.range(lo, hi);
        match rng.below(6) {
            0 if crashes < crash_cap => {
                crashes += 1;
                plan.push(HostFault { at, host, kind: HostFaultKind::Crash });
            }
            1 | 2 => plan.push(HostFault { at, host, kind: HostFaultKind::DegradedNvme }),
            3 => plan.push(HostFault { at, host, kind: HostFaultKind::BudgetRevoke }),
            _ => {}
        }
    }
    plan
}

/// Storm-VM gpa size: the golden image covers the clone's *entire*
/// guest-physical space (`frames == image_units` after rounding up),
/// so a scrambled gva→gpa mapping can never step off the image onto
/// the cold path and dilute the clone-vs-cold comparison.
fn storm_frames(clone: &CloneConfig) -> u64 {
    clone.image_units.max(2048)
}

/// Boot working set of one storm VM (the usual 1024-frame guest
/// slack, like every other fleet VM shape).
fn storm_boot_pages(clone: &CloneConfig) -> u64 {
    storm_frames(clone) - 1024
}

/// Guest ops one storm VM performs — two sequential passes over its
/// boot working set. Public because the soak's lost-work audit needs
/// the expected total.
pub fn storm_vm_ops(clone: &CloneConfig) -> u64 {
    storm_boot_pages(clone) * 2
}

/// Storm-VM memory limit in bytes ([`FleetRunOpts::storm_limit_pct`]
/// of the boot working set; 0 = 100%).
fn storm_limit_bytes(opts: &FleetRunOpts) -> u64 {
    let pct = if opts.storm_limit_pct == 0 { 100 } else { opts.storm_limit_pct };
    (storm_boot_pages(&opts.clone) * FRAME_BYTES * pct / 100).max(FRAME_BYTES)
}

/// Build and run one sharded fleet: `hosts` shards × `per_host` VMs,
/// host 0's budget deliberately short of its hot-phase demand (the
/// sustained-pressure host), the rest comfortable. Every VM touches a
/// footprint three times its hot set once, then works in the hot third
/// — so every shard is limit-bound and holds real cold memory the
/// rebalancer can lease or migrate (the regime where moving budget or
/// VMs *moves* occupancy instead of inflating it). All VMs are Bronze:
/// 4k units keep the arbiter's reclaim granularity fine enough that
/// limits bind tightly on every host. `mode` picks the rebalancing
/// tools; everything else — engine, workers, faults, granularity,
/// remote marketplace, clone storm — rides in `opts` (this is the one
/// public sharded-fleet runner; PR 10 folded the old positional
/// variants into [`FleetRunOpts`]). Deterministic in `seed`, and the
/// equivalence suite asserts the summary — and therefore the CSV, a
/// pure function of it — is byte-identical across engines and worker
/// counts, clone storms included.
pub fn run_sharded_fleet(
    hosts: usize,
    per_host: usize,
    ops_per_vm: u64,
    mode: FleetMode,
    seed: u64,
    opts: &FleetRunOpts,
) -> ShardedSummary {
    let parallel = !opts.sequential;
    let workers = opts.workers;
    let granularity = &opts.granularity;
    let faults = &opts.faults;
    let remote = opts.remote;
    let donor_pct = opts.donor_pct_resolved();
    let interval = 50 * MS;
    let n = hosts * per_host;
    let frames = 4096u64;
    let pages = frames - 1024;
    let nominal: u64 = n as u64 * frames * FRAME_BYTES;
    let pool_cap = 8 * 1024 * 1024;

    let template = HostConfig {
        seed,
        tier: TierConfig { pool_capacity_bytes: pool_cap, ..Default::default() },
        ..Default::default()
    };
    let cfg = FleetConfig {
        hosts,
        // Placeholder; real budgets are sized from the admitted mix
        // below via `set_shard_budget`.
        host_budgets: vec![1 << 40],
        placement: PlacementPolicy::SpreadByFaultRate,
        interval,
        migration: mode != FleetMode::StaticPlacement,
        state_migration: mode == FleetMode::StateMigration,
        migrate_pf_delta_min: 16,
        pressure_demand_pct: 104,
        donor_demand_pct: 90,
        migration_max_bytes: 32 * 1024 * 1024,
        migration_min_chunk: 256 * 1024,
        migration_margin_bytes: 128 * 1024,
        migration_stall_ticks: 10,
        max_active_migrations: 1,
        control: ControlConfig {
            interval: 25 * MS,
            kind: ArbiterKind::ProportionalShare,
            recovery_boost_window: 300 * MS,
            ..Default::default()
        },
        max_time: 60 * SEC,
        parallel,
        workers,
        faults: faults.to_vec(),
        remote: RemoteConfig { enabled: remote, ..Default::default() },
        clone: CloneConfig {
            enabled: opts.clone.enabled || opts.storm_total() > 0,
            image_units: storm_frames(&opts.clone),
            ..opts.clone.clone()
        },
        ..Default::default()
    };
    let mut f = FleetScheduler::new(&template, cfg);

    for i in 0..n {
        // Touch the whole footprint once, then work in the hottest
        // third: real cold memory everywhere, hot-set thrash only where
        // the budget is short.
        let phases = vec![(pages, ops_per_vm / 4), (pages / 3, ops_per_vm * 3 / 4)];
        let w: Box<dyn Workload> = Box::new(BootDelay::new(
            (i as u64 % 8) * 10 * MS,
            Box::new(PhasedWss::with_cost(phases, 20_000)),
        ));
        f.admit(FleetVmSpec {
            name: format!("vm{i}"),
            sla: Sla::Bronze,
            frames,
            vcpus: 1,
            workloads: vec![w],
            initial_limit_bytes: None, // set per shard below
            mm: Some(MmConfig {
                swapper_threads: swapper_threads(Sla::Bronze),
                scan_interval: 60 * MS,
                history: 6,
                // Lazy proactive reclaim: cold pages are shed by the
                // *limit* (arbiter pressure), which keeps every shard
                // limit-bound.
                target_promotion_rate: 0.002,
                granularity: if granularity.is_empty() {
                    GranularityMode::Fixed
                } else {
                    granularity[i % granularity.len()]
                },
                ..Default::default()
            }),
        });
    }

    // Clone storm (PR 10): stage the storm before the run; the
    // scheduler drains it at fleet ticks, `clones_per_tick` per tick.
    // The two arms interleave (Bresenham over the staged order), so
    // each tick's batch carries both and admission times pair up. Each
    // storm VM boots with two sequential passes over its boot working
    // set — the pattern the image's boot-stream prefetch is built for.
    let storm_total = opts.storm_total();
    let boot_pages = storm_boot_pages(&opts.clone);
    let limit = storm_limit_bytes(opts);
    for k in 0..storm_total {
        let cold = (k * opts.storm_cold) / storm_total != ((k + 1) * opts.storm_cold) / storm_total;
        let name = format!("{}-{k}", if cold { "cold" } else { "clone" });
        f.stage_clone(
            FleetVmSpec {
                name,
                sla: Sla::Bronze,
                frames: storm_frames(&opts.clone),
                vcpus: 1,
                workloads: vec![Box::new(SeqScan::new(boot_pages, 2, 2_000))],
                initial_limit_bytes: Some(limit),
                mm: Some(MmConfig {
                    swapper_threads: swapper_threads(Sla::Bronze),
                    scan_interval: 60 * MS,
                    history: 6,
                    target_promotion_rate: 0.002,
                    ..Default::default()
                }),
            },
            cold,
        );
    }

    // Size each shard's budget from its actually admitted members: the
    // arbiter's own hot-phase demand (WSS + WSS/8) plus the pool
    // reservation and in-flight slack. Host 0: usable ≈ 78% of demand
    // (sustained pressure); the rest: ≈ `donor_pct`% — 130 in the
    // canonical comparison (feasible with enough spare under the 90%
    // donor-eligibility line both to lease from and to absorb one
    // whole migrated VM), 300 in remote scenarios (never limit-bound,
    // pools empty, real DRAM headroom for staged remote bytes).
    let hot_demand = {
        let wss = pages / 3 * FRAME_BYTES;
        wss + wss / 8
    };
    // Storm headroom, charged to every host up front: clones spread by
    // committed pressure (⌈total/hosts⌉ per host), but pack piles every
    // image-backed clone onto one host, and a chaos crash re-lands a
    // dead host's clones on the survivors — both size for the whole
    // storm. Per clone: its memory limit plus swapper in-flight slack;
    // per host: one shared golden image (stored ≤ raw, charged once).
    let storm_extra = if storm_total > 0 {
        let per_host_storm = if opts.clone.pack
            || !opts.faults.is_empty()
            || opts.fault_plan == FaultPlan::Random
        {
            storm_total as u64
        } else {
            (storm_total as u64).div_ceil(hosts as u64)
        };
        let storm_inflight = swapper_threads(Sla::Bronze) as u64 * FRAME_BYTES;
        per_host_storm * (limit + storm_inflight) + storm_frames(&opts.clone) * FRAME_BYTES
    } else {
        0
    };
    let mut budgets = vec![0u64; hosts];
    for h in 0..hosts {
        let members: Vec<usize> = f
            .placements
            .iter()
            .filter(|p| p.shard == h)
            .map(|p| p.vm)
            .collect();
        let inflight: u64 = members
            .iter()
            .map(|&v| {
                let mm = f.shards[h].machine.mm(v).expect("sys VM");
                // A huge-granularity VM's in-flight swap-in is a whole
                // 2MB region, not one unit — slack must cover it or
                // demand-fault overshoot trips the budget audit.
                let span = if mm.core.granularity_mode == GranularityMode::Fixed {
                    1
                } else {
                    REGION_UNITS
                };
                mm.swapper.threads() as u64 * mm.core.unit_bytes * span
            })
            .sum();
        let demand = hot_demand * members.len() as u64;
        let pct = if h == 0 { 78 } else { donor_pct };
        let budget = demand * pct / 100 + pool_cap + inflight + storm_extra;
        budgets[h] = budget;
        f.set_shard_budget(h, budget);
        // Everyone starts at an equal share of its shard's usable
        // budget, so Σ(resident + pool) ≤ budget holds from t = 0.
        // Storm headroom is reserved for the storm: base shares match
        // the storm-free run exactly.
        let usable = budget - pool_cap - inflight - storm_extra;
        let share = usable / members.len().max(1) as u64;
        for &v in &members {
            let mm = f.shards[h].machine.mm_mut(v).expect("sys VM");
            mm.core.limit_units = Some((share / mm.core.unit_bytes).max(1));
        }
    }
    let budget_total_start: u64 = budgets.iter().sum();

    // The recovered population: every VM admitted to a host the fault
    // plan targets. Captured as placement-log indices — the log is
    // append-only and follows each VM across crashes and drains.
    let faulted_hosts: std::collections::BTreeSet<usize> =
        faults.iter().map(|f| f.host).collect();
    let recovered_pidx: Vec<usize> = f
        .placements
        .iter()
        .enumerate()
        .filter(|(_, p)| faulted_hosts.contains(&p.shard))
        .map(|(i, _)| i)
        .collect();

    let results = f.run();
    let mut hist = LatencyHist::default();
    let mut per_host = Vec::with_capacity(hosts);
    let mut total_majors = 0;
    let mut total_ops = 0;
    let mut runtime = 0;
    let mut avg_fleet = 0.0;
    for (h, rs) in results.iter().enumerate() {
        let mut majors = 0;
        for r in rs {
            hist.merge(&r.fault_hist);
            majors += r.counters.faults_major;
            total_ops += r.work_ops;
            runtime = runtime.max(r.runtime);
        }
        total_majors += majors;
        let cs = f.shards[h]
            .machine
            .control_stats()
            .expect("shard has a control plane");
        let avg = if cs.host_series.is_empty() {
            0.0
        } else {
            cs.host_series.iter().map(|(_, r, p)| r + p).sum::<f64>()
                / cs.host_series.len() as f64
        };
        avg_fleet += avg;
        per_host.push(HostRow {
            host: h,
            vms: rs.len(),
            budget_start: budgets[h],
            budget_end: f.shard_budget(h),
            avg_host_bytes: avg,
            peak_host_bytes: cs.peak_host_bytes,
            budget_exceeded_ticks: cs.budget_exceeded_ticks,
            min_headroom_bytes: cs.min_headroom_bytes,
            bytes_in: f.stats.bytes_in[h],
            bytes_out: f.stats.bytes_out[h],
            vms_in: f.stats.vms_migrated_in[h],
            vms_out: f.stats.vms_migrated_out[h],
            majors,
        });
    }
    // Per-VM recovered stats: a shard's result rows flatten its
    // occupied slots in slot-id order, so a VM's row index is the count
    // of occupied lower slots on its final shard.
    // Pressured-shard stall: host 0's VMs only — where the marketplace
    // (or any other relief channel) must show up to matter.
    let mut pressured_hist = LatencyHist::default();
    for r in &results[0] {
        pressured_hist.merge(&r.fault_hist);
    }
    let remote_hits: u64 = results
        .iter()
        .flatten()
        .map(|r| r.counters.swapin_remote_hits)
        .sum();
    let mut rec_hist = LatencyHist::default();
    let mut rec_viol = 0u64;
    for &pidx in &recovered_pidx {
        let p = &f.placements[pidx];
        let row = (0..p.vm)
            .filter(|&u| f.shards[p.shard].machine.mm(u).is_some())
            .count();
        let r = &results[p.shard][row];
        rec_hist.merge(&r.fault_hist);
        if r.fault_hist.quantile(0.99) > FAULT_SLA_NS {
            rec_viol += 1;
        }
    }
    // PR 10 storm ledger: per-arm time-to-first-useful-work, measured
    // from each storm VM's admission tick. Staged index k is admitted
    // at the (k / clones_per_tick + 1)-th tick — the queue is FIFO and
    // every tick drains exactly one batch while any remain, so the
    // admission time is exact, not estimated.
    let batch = opts.clone.clones_per_tick.max(1) as u64;
    let mut clone_hist = LatencyHist::default();
    let mut cold_hist = LatencyHist::default();
    let mut clones_per_host = vec![0usize; hosts];
    for p in &f.placements {
        let (arm_cold, k) = if let Some(k) = p.name.strip_prefix("clone-") {
            (false, k)
        } else if let Some(k) = p.name.strip_prefix("cold-") {
            (true, k)
        } else {
            continue;
        };
        let Ok(k) = k.parse::<u64>() else { continue };
        let admit_at = (k / batch + 1) * interval;
        let row = (0..p.vm)
            .filter(|&u| f.shards[p.shard].machine.mm(u).is_some())
            .count();
        let r = &results[p.shard][row];
        let rel = r.first_work_ns.saturating_sub(admit_at);
        if arm_cold {
            cold_hist.record(rel);
        } else {
            clone_hist.record(rel);
            clones_per_host[p.shard] += 1;
        }
    }
    let (mut image_stored, mut image_logical) = (0u64, 0u64);
    let (mut image_hits, mut image_cow_breaks) = (0u64, 0u64);
    for s in f.shards.iter() {
        let tm = s.machine.backend.metrics();
        image_stored += tm.image_stored_bytes;
        image_logical += tm.image_logical_bytes;
        image_hits += tm.image_hits;
        image_cow_breaks += tm.image_cow_breaks;
    }
    ShardedSummary {
        hosts,
        vms: n,
        mode,
        per_host,
        total_majors,
        total_ops,
        avg_fleet_bytes: avg_fleet,
        nominal_bytes: nominal,
        saved_frac: 1.0 - avg_fleet / nominal as f64,
        migrations_started: f.stats.migrations_started,
        migrations_completed: f.stats.migrations_completed,
        migrations_aborted: f.stats.migrations_aborted,
        migrated_bytes: f.stats.migrated_bytes,
        state_migrations_started: f.stats.state_migrations_started,
        state_migrations_completed: f.stats.state_migrations_completed,
        state_migrations_aborted: f.stats.state_migrations_aborted,
        state_precopy_bytes: f.stats.state_precopy_bytes,
        state_flip_bytes: f.stats.state_flip_bytes,
        state_stop_ns_max: f.stats.state_stop_ns_max,
        handoff_violations: f.stats.handoff_violations,
        conservation_violations: f.stats.conservation_violations,
        budget_total_end: (0..hosts).map(|i| f.shard_budget(i)).sum(),
        budget_total_start,
        p99_stall_ns: hist.quantile(0.99),
        runtime_ns: runtime,
        faults_injected: f.stats.faults_injected,
        crashes: f.stats.crashes,
        degrades: f.stats.degrades,
        revocations: f.stats.revocations,
        budget_retired_bytes: f.stats.budget_retired_bytes,
        vms_rebuilt: f.stats.vms_rebuilt,
        rebuild_salvaged_bytes: f.stats.rebuild_salvaged_bytes,
        rebuild_lost_bytes: f.stats.rebuild_lost_bytes,
        drains_started: f.stats.drains_started,
        drains_completed: f.stats.drains_completed,
        drain_deadline_misses: f.stats.drain_deadline_misses,
        residency_restored: f.stats.residency_restored,
        residency_restore_ns_max: f.stats.residency_restore_ns_max,
        recovered_vms: recovered_pidx.len(),
        recovered_p99_stall_ns: rec_hist.quantile(0.99),
        recovered_sla_violations: rec_viol,
        remote_leases: f.stats.remote_leases,
        remote_leased_bytes: f.stats.remote_leased_bytes,
        remote_staged_bytes: f.stats.remote_staged_bytes,
        remote_revocations: f.stats.remote_revocations,
        remote_recalled_bytes: f.stats.remote_recalled_bytes,
        remote_dropped_bytes: f.stats.remote_dropped_bytes,
        remote_hits,
        pressured_p99_stall_ns: pressured_hist.quantile(0.99),
        clones_staged: f.stats.clones_staged,
        clones_admitted: f.stats.clones_admitted,
        clone_cold_boots: f.stats.clone_cold_boots,
        clone_first_work_p99_ns: clone_hist.quantile(0.99),
        cold_first_work_p99_ns: cold_hist.quantile(0.99),
        image_stored_bytes: image_stored,
        image_logical_bytes: image_logical,
        image_hits,
        image_cow_breaks,
        clones_per_host,
    }
}

/// The registered experiment driver (4 host shards by default; the CLI
/// overrides via `flexswap fleet --hosts N`).
pub fn fleet(scale: Scale) -> Vec<Table> {
    fleet_with_hosts(scale, 4, FleetRunOpts::default())
}

/// The nightly soak: the sharded lease-vs-state comparison swept over
/// many seeds at larger scale (`flexswap fleet --hosts 64 --vms 4096
/// --seeds N`), optionally as a chaos soak with a seed-derived fault
/// schedule armed (`--fault-plan random`) and/or with the remote
/// marketplace armed (`--remote`, which also re-sizes donor budgets
/// for spare DRAM). Kept out of the PR-gating
/// CI path — the `schedule:`-triggered workflow runs it and uploads
/// the per-seed CSV. Every run must hold the budget / conservation /
/// atomic-hand-off invariants — with faults, the conservation baseline
/// steps down by exactly the retired budgets — and no VM may lose work
/// to a fault; migration and recovery activity is reported, not
/// asserted (a seed whose plan injects nothing is data, not a
/// failure).
pub fn fleet_soak(scale: Scale, hosts: usize, seeds: u64, opts: FleetRunOpts) -> Vec<Table> {
    let per_host = opts.per_host.unwrap_or(scale.u(8, 16) as usize);
    let ops = scale.u(16_000, 48_000);
    let mut t = Table::new(
        "fleet soak: per-seed sharded comparison (lease-only vs state-migration)",
        &[
            "seed",
            "config",
            "hosts",
            "vms",
            "major_faults",
            "saved_pct",
            "migrations",
            "state_migrations",
            "precopy_mb",
            "flip_mb",
            "stop_max_us",
            "p99_stall_us",
            "runtime_ms",
            "faults",
            "vms_rebuilt",
            "retired_mb",
            "restored",
            "restore_max_ms",
            "drain_misses",
            "remote_leases/staged_mb/hits",
            "clones(adm/cold)",
        ],
    );
    for seed in 0..seeds {
        let plan = match opts.fault_plan {
            FaultPlan::None => vec![],
            FaultPlan::Random => random_fault_plan(hosts, ops, seed),
        };
        for mode in [FleetMode::LeaseOnly, FleetMode::StateMigration] {
            let label = mode.label();
            let arm = opts.clone().with_faults(plan.clone());
            let s = run_sharded_fleet(hosts, per_host, ops, mode, seed, &arm);
            let storm_ops = (arm.storm_clones + arm.storm_cold) as u64 * storm_vm_ops(&arm.clone);
            assert_eq!(
                s.total_ops,
                s.vms as u64 * ops + storm_ops,
                "soak seed {seed} {label}: fleet lost work"
            );
            assert_eq!(
                (s.clones_admitted + s.clone_cold_boots) as usize,
                arm.storm_clones + arm.storm_cold,
                "soak seed {seed} {label}: staged storm VMs never admitted"
            );
            assert_eq!(
                s.conservation_violations, 0,
                "soak seed {seed} {label}: budgets drifted"
            );
            assert_eq!(
                s.budget_total_end + s.budget_retired_bytes,
                s.budget_total_start,
                "soak seed {seed} {label}: Σ budgets ≠ start − retired"
            );
            assert_eq!(
                s.handoff_violations, 0,
                "soak seed {seed} {label}: non-atomic hand-off"
            );
            for h in &s.per_host {
                assert_eq!(
                    h.budget_exceeded_ticks, 0,
                    "soak seed {seed} {label}: host {} over budget",
                    h.host
                );
            }
            t.row(vec![
                seed.to_string(),
                label.into(),
                s.hosts.to_string(),
                s.vms.to_string(),
                s.total_majors.to_string(),
                format!("{:.1}", s.saved_frac * 100.0),
                format!(
                    "{}/{}/{}",
                    s.migrations_started, s.migrations_completed, s.migrations_aborted
                ),
                format!(
                    "{}/{}/{}",
                    s.state_migrations_started,
                    s.state_migrations_completed,
                    s.state_migrations_aborted
                ),
                format!("{:.1}", s.state_precopy_bytes as f64 / 1e6),
                format!("{:.1}", s.state_flip_bytes as f64 / 1e6),
                format!("{:.0}", s.state_stop_ns_max as f64 / 1e3),
                format!("{:.0}", s.p99_stall_ns as f64 / 1e3),
                format!("{:.0}", s.runtime_ns as f64 / 1e6),
                format!(
                    "{}c/{}d/{}r",
                    s.crashes, s.degrades, s.revocations
                ),
                s.vms_rebuilt.to_string(),
                format!("{:.1}", s.budget_retired_bytes as f64 / 1e6),
                s.residency_restored.to_string(),
                format!("{:.0}", s.residency_restore_ns_max as f64 / 1e6),
                s.drain_deadline_misses.to_string(),
                format!(
                    "{}/{:.1}/{}",
                    s.remote_leases,
                    s.remote_staged_bytes as f64 / 1e6,
                    s.remote_hits
                ),
                format!("{}/{}", s.clones_admitted, s.clone_cold_boots),
            ]);
        }
    }
    vec![t]
}

pub fn fleet_with_hosts(scale: Scale, hosts: usize, opts: FleetRunOpts) -> Vec<Table> {
    let n = scale.u(64, 128) as usize;
    let ops = scale.u(12_000, 40_000);
    let mut t = Table::new(
        "fleet density: closed-loop arbitration vs static limits",
        &[
            "config",
            "vms",
            "budget_mb",
            "avg_host_mb",
            "peak_host_mb",
            "budget_exceeded_ticks",
            "saved_pct",
            "p99_stall_us",
            "mean_stall_us",
            "major_faults",
            "limit_changes",
            "runtime_ms",
        ],
    );
    for (label, kind) in
        [("static", ArbiterKind::Static), ("closed-loop", ArbiterKind::ProportionalShare)]
    {
        let s = run_fleet(n, ops, kind, 7);
        assert_eq!(
            s.total_ops,
            n as u64 * ops,
            "{label}: fleet did not complete its work"
        );
        assert_eq!(
            s.budget_exceeded_ticks, 0,
            "{label}: host budget exceeded ({} min headroom)",
            s.min_headroom_bytes
        );
        t.row(vec![
            label.into(),
            s.vms.to_string(),
            format!("{:.0}", s.budget_bytes as f64 / 1e6),
            format!("{:.0}", s.avg_host_bytes / 1e6),
            format!("{:.0}", s.peak_host_bytes as f64 / 1e6),
            s.budget_exceeded_ticks.to_string(),
            format!("{:.1}", s.saved_frac * 100.0),
            format!("{:.0}", s.p99_stall_ns as f64 / 1e3),
            format!("{:.1}", s.mean_stall_ns / 1e3),
            s.majors.to_string(),
            s.limit_changes.to_string(),
            format!("{:.0}", s.runtime_ns as f64 / 1e6),
        ]);
    }

    let rec_ops = scale.u(150_000, 400_000);
    let mut t2 = Table::new(
        "release recovery: boost hint on vs off",
        &[
            "config",
            "runtime_ms",
            "post_release_ms",
            "major_faults",
            "prefetch_issued",
            "prefetch_timely",
        ],
    );
    for (label, boost) in [("no-boost", false), ("boost", true)] {
        let r = recovery_release(boost, rec_ops, 11);
        t2.row(vec![
            label.into(),
            format!("{:.0}", r.runtime_ns as f64 / 1e6),
            format!("{:.0}", r.after_lift_ns as f64 / 1e6),
            r.majors.to_string(),
            r.prefetch_issued.to_string(),
            r.prefetch_timely.to_string(),
        ]);
    }

    // Sharded fleet: static placement vs the budget-lease rebalancer
    // vs full VM state migration, one host budget-starved (PR 4/5
    // extension). The state-migration arm must beat lease-only on
    // major faults or on saved memory — moving the whole VM removes
    // its entire demand from the pressured host, where a lease can
    // only move as much budget as donors can prove free.
    let per_host = opts.per_host.unwrap_or(scale.u(8, 32) as usize);
    let shard_ops = scale.u(16_000, 28_000);
    // The t3–t5 comparison arms run storm-free even when
    // `--clone-storm` is set (the storm gets its own tables below):
    // their lost-work audits and acceptance pins are calibrated to the
    // base population.
    let base = FleetRunOpts {
        faults: vec![],
        fault_plan: FaultPlan::None,
        remote: false,
        donor_pct: 0,
        clone: CloneConfig::default(),
        storm_clones: 0,
        storm_cold: 0,
        storm_limit_pct: 0,
        clone_storm: false,
        ..opts.clone()
    };
    let mut t3 = Table::new(
        "fleet sharding: lease-only vs full VM state migration vs static placement",
        &[
            "config",
            "host",
            "vms",
            "budget_start_mb",
            "budget_end_mb",
            "avg_host_mb",
            "budget_exceeded_ticks",
            "migr_in_mb",
            "migr_out_mb",
            "vms_in/out",
            "major_faults",
            "migrations",
            "state_migrations",
            "stop_max_us",
            "saved_pct",
            "p99_stall_us",
        ],
    );
    let mut lease: Option<ShardedSummary> = None;
    for mode in [
        FleetMode::StaticPlacement,
        FleetMode::LeaseOnly,
        FleetMode::StateMigration,
    ] {
        let label = mode.label();
        let s = run_sharded_fleet(hosts, per_host, shard_ops, mode, 7, &base);
        assert_eq!(
            s.total_ops,
            s.vms as u64 * shard_ops,
            "{label}: sharded fleet did not complete its work"
        );
        assert_eq!(
            s.conservation_violations, 0,
            "{label}: fleet budget not conserved"
        );
        assert_eq!(
            s.budget_total_end, s.budget_total_start,
            "{label}: Σ budgets drifted"
        );
        assert_eq!(s.handoff_violations, 0, "{label}: non-atomic VM hand-off");
        for h in &s.per_host {
            assert_eq!(
                h.budget_exceeded_ticks, 0,
                "{label}: host {} exceeded its budget ({} min headroom)",
                h.host, h.min_headroom_bytes
            );
        }
        // The acceptance comparison is pinned to the canonical 4-host
        // topology at its default population (the CI smoke and the test
        // suite's `state_migration_beats_lease_only` both run it
        // there). Other `--hosts` values — and `--vms` overrides — are
        // exploratory: a shape where no flip can even occur (e.g.
        // `--hosts 1`) must report, not abort.
        if mode == FleetMode::StateMigration
            && hosts == 4
            && opts.per_host.is_none()
            && opts.granularity.is_empty()
        {
            let l = lease.as_ref().expect("lease arm ran first");
            assert!(
                s.state_migrations_completed >= 1,
                "{label}: no VM ever migrated: {s:?}"
            );
            assert!(
                s.total_majors < l.total_majors
                    || s.avg_fleet_bytes < l.avg_fleet_bytes,
                "{label}: full migration beat lease-only on neither majors \
                 ({} vs {}) nor occupancy ({:.0} vs {:.0})",
                s.total_majors,
                l.total_majors,
                s.avg_fleet_bytes,
                l.avg_fleet_bytes
            );
        }
        for h in &s.per_host {
            t3.row(vec![
                label.into(),
                h.host.to_string(),
                h.vms.to_string(),
                format!("{:.0}", h.budget_start as f64 / 1e6),
                format!("{:.0}", h.budget_end as f64 / 1e6),
                format!("{:.0}", h.avg_host_bytes / 1e6),
                h.budget_exceeded_ticks.to_string(),
                format!("{:.1}", h.bytes_in as f64 / 1e6),
                format!("{:.1}", h.bytes_out as f64 / 1e6),
                format!("{}/{}", h.vms_in, h.vms_out),
                h.majors.to_string(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
        t3.row(vec![
            label.into(),
            "all".into(),
            s.vms.to_string(),
            format!("{:.0}", s.budget_total_start as f64 / 1e6),
            format!("{:.0}", s.budget_total_end as f64 / 1e6),
            format!("{:.0}", s.avg_fleet_bytes / 1e6),
            s.per_host
                .iter()
                .map(|h| h.budget_exceeded_ticks)
                .sum::<u64>()
                .to_string(),
            format!("{:.1}", s.migrated_bytes as f64 / 1e6),
            format!("{:.1}", s.migrated_bytes as f64 / 1e6),
            format!(
                "{}/{}",
                s.per_host.iter().map(|h| h.vms_in).sum::<u64>(),
                s.per_host.iter().map(|h| h.vms_out).sum::<u64>()
            ),
            s.total_majors.to_string(),
            format!(
                "{}/{}/{}",
                s.migrations_started, s.migrations_completed, s.migrations_aborted
            ),
            format!(
                "{}/{}/{}",
                s.state_migrations_started,
                s.state_migrations_completed,
                s.state_migrations_aborted
            ),
            format!("{:.0}", s.state_stop_ns_max as f64 / 1e3),
            format!("{:.1}", s.saved_frac * 100.0),
            format!("{:.0}", s.p99_stall_ns as f64 / 1e3),
        ]);
        if mode == FleetMode::LeaseOnly {
            lease = Some(s);
        }
    }

    // Host failure: hard crash vs graceful drain on the same pressured
    // state-migration fleet (PR 7). One fault hits host 0 halfway
    // through the fleet's pure compute span — both arms share the same
    // seed, so their schedules are identical up to the fault tick and
    // the comparison isolates the recovery path. The crash arm rebuilds
    // host 0's VMs from salvaged NVMe receipts and refaults their
    // residency cold on the survivors; the drain arm evacuates them
    // with their resident sets via stop-and-copy flips. Drain must be
    // no worse on recovered-VM p99 fault stall and SLA violations, and
    // strictly better on at least one.
    let fault_at = shard_ops * 20_000 / 2;
    let mut t4 = Table::new(
        "host failure: hard crash at T vs graceful drain (state-migration fleet)",
        &[
            "config",
            "faults",
            "recovered_vms",
            "recovered_p99_us",
            "sla_violations",
            "restored",
            "restore_max_ms",
            "vms_rebuilt",
            "salvaged_mb",
            "lost_mb",
            "drain_misses",
            "evac_flips",
            "stop_max_us",
            "major_faults",
            "runtime_ms",
        ],
    );
    let mut crash_arm: Option<ShardedSummary> = None;
    for (label, kind) in [
        ("hard-crash", HostFaultKind::Crash),
        ("graceful-drain", HostFaultKind::DegradedNvme),
    ] {
        let faults = vec![HostFault { at: fault_at, host: 0, kind }];
        let arm = base.clone().with_faults(faults);
        let s = run_sharded_fleet(hosts, per_host, shard_ops, FleetMode::StateMigration, 7, &arm);
        assert_eq!(
            s.total_ops,
            s.vms as u64 * shard_ops,
            "{label}: fleet lost work to the fault"
        );
        assert_eq!(s.conservation_violations, 0, "{label}: budgets drifted");
        assert_eq!(
            s.budget_total_end + s.budget_retired_bytes,
            s.budget_total_start,
            "{label}: Σ budgets ≠ start − retired"
        );
        assert_eq!(s.handoff_violations, 0, "{label}: non-atomic hand-off");
        for h in &s.per_host {
            assert_eq!(
                h.budget_exceeded_ticks, 0,
                "{label}: host {} exceeded its budget ({} min headroom)",
                h.host, h.min_headroom_bytes
            );
        }
        // Pinned on the canonical topology, like the t3 acceptance.
        if hosts == 4 && opts.per_host.is_none() && opts.granularity.is_empty() {
            if kind == HostFaultKind::Crash {
                assert!(s.vms_rebuilt > 0, "{label}: the crash rebuilt nothing");
            } else {
                assert!(
                    s.state_migrations_completed >= 1,
                    "{label}: no evacuation flip completed: {s:?}"
                );
                let c = crash_arm.as_ref().expect("crash arm ran first");
                assert!(
                    s.recovered_p99_stall_ns <= c.recovered_p99_stall_ns
                        && s.recovered_sla_violations <= c.recovered_sla_violations
                        && (s.recovered_p99_stall_ns < c.recovered_p99_stall_ns
                            || s.recovered_sla_violations < c.recovered_sla_violations),
                    "{label}: drain did not beat the crash — p99 {} vs {} ns, \
                     violations {} vs {}",
                    s.recovered_p99_stall_ns,
                    c.recovered_p99_stall_ns,
                    s.recovered_sla_violations,
                    c.recovered_sla_violations
                );
            }
        }
        t4.row(vec![
            label.into(),
            format!("{}c/{}d/{}r", s.crashes, s.degrades, s.revocations),
            s.recovered_vms.to_string(),
            format!("{:.0}", s.recovered_p99_stall_ns as f64 / 1e3),
            s.recovered_sla_violations.to_string(),
            s.residency_restored.to_string(),
            format!("{:.0}", s.residency_restore_ns_max as f64 / 1e6),
            s.vms_rebuilt.to_string(),
            format!("{:.1}", s.rebuild_salvaged_bytes as f64 / 1e6),
            format!("{:.1}", s.rebuild_lost_bytes as f64 / 1e6),
            s.drain_deadline_misses.to_string(),
            s.state_migrations_completed.to_string(),
            format!("{:.0}", s.state_stop_ns_max as f64 / 1e3),
            s.total_majors.to_string(),
            format!("{:.0}", s.runtime_ns as f64 / 1e6),
        ]);
        if kind == HostFaultKind::Crash {
            crash_arm = Some(s);
        }
    }

    // Remote marketplace: the static-placement fleet (so the
    // marketplace is the only relief channel) with donor budgets at
    // 300% of demand — donors never reclaim, their pools sit empty
    // below the low watermark, and once their phase-2 working sets
    // contract they post offers the pressured host 0 bids on. The
    // NVMe-only arm runs the identical shape with matching disarmed:
    // the comparison isolates the tier. Remote-armed must strictly
    // beat NVMe-only on the pressured host's p99 fault stall (pool
    // ~6.5µs < remote ~20µs < NVMe ~75µs on its overflow faults),
    // with Σ budgets exactly conserved — remote escrow is
    // begin/cancel-only, audited budgets never move permanently.
    let mut t5 = Table::new(
        "remote marketplace: remote-armed vs nvme-only (static placement)",
        &[
            "config",
            "leases",
            "leased_mb",
            "staged_mb",
            "revocations",
            "recalled_mb",
            "dropped_mb",
            "remote_hits",
            "pressured_p99_us",
            "p99_stall_us",
            "major_faults",
            "budget_start_mb",
            "budget_end_mb",
            "runtime_ms",
        ],
    );
    let mut nvme_only: Option<ShardedSummary> = None;
    for (label, remote) in [("nvme-only", false), ("remote-armed", true)] {
        let arm = base.clone().with_remote(remote).with_donor_pct(300);
        let s = run_sharded_fleet(hosts, per_host, shard_ops, FleetMode::StaticPlacement, 7, &arm);
        assert_eq!(
            s.total_ops,
            s.vms as u64 * shard_ops,
            "{label}: marketplace fleet did not complete its work"
        );
        assert_eq!(
            s.conservation_violations, 0,
            "{label}: fleet budget not conserved"
        );
        assert_eq!(
            s.budget_total_end, s.budget_total_start,
            "{label}: Σ budgets drifted — remote escrow must be begin/cancel-only"
        );
        for h in &s.per_host {
            assert_eq!(
                h.budget_exceeded_ticks, 0,
                "{label}: host {} exceeded its budget ({} min headroom)",
                h.host, h.min_headroom_bytes
            );
        }
        if !remote {
            assert_eq!(
                s.remote_leases, 0,
                "{label}: leases formed with the marketplace disarmed"
            );
            assert_eq!(s.remote_hits, 0, "{label}: remote hits without leases");
        }
        // Pinned on the canonical topology, like the t3/t4 acceptance.
        if remote
            && hosts == 4
            && opts.per_host.is_none()
            && opts.granularity.is_empty()
        {
            let base = nvme_only.as_ref().expect("nvme-only arm ran first");
            assert!(s.remote_leases >= 1, "{label}: no lease ever matched: {s:?}");
            assert!(s.remote_staged_bytes > 0, "{label}: leases staged nothing");
            assert!(
                s.remote_hits > 0,
                "{label}: no fault ever hit the remote tier"
            );
            assert!(
                s.pressured_p99_stall_ns < base.pressured_p99_stall_ns,
                "{label}: remote did not beat nvme-only on the pressured \
                 host's p99 stall ({} vs {} ns)",
                s.pressured_p99_stall_ns,
                base.pressured_p99_stall_ns
            );
        }
        t5.row(vec![
            label.into(),
            s.remote_leases.to_string(),
            format!("{:.1}", s.remote_leased_bytes as f64 / 1e6),
            format!("{:.1}", s.remote_staged_bytes as f64 / 1e6),
            s.remote_revocations.to_string(),
            format!("{:.1}", s.remote_recalled_bytes as f64 / 1e6),
            format!("{:.1}", s.remote_dropped_bytes as f64 / 1e6),
            s.remote_hits.to_string(),
            format!("{:.0}", s.pressured_p99_stall_ns as f64 / 1e3),
            format!("{:.0}", s.p99_stall_ns as f64 / 1e3),
            s.total_majors.to_string(),
            format!("{:.0}", s.budget_total_start as f64 / 1e6),
            format!("{:.0}", s.budget_total_end as f64 / 1e6),
            format!("{:.0}", s.runtime_ns as f64 / 1e6),
        ]);
        if !remote {
            nvme_only = Some(s);
        }
    }
    let mut tables = vec![t, t2, t3, t4, t5];
    if opts.clone_storm {
        tables.extend(clone_storm_with_hosts(scale, hosts, opts));
    }
    tables
}

/// Shape-independent invariants every storm run must hold: no lost
/// work (base or storm), every staged storm VM admitted, Σ budgets
/// exactly conserved at the audit and end-to-end, atomic hand-offs.
fn assert_storm_invariants(label: &str, s: &ShardedSummary, arm: &FleetRunOpts, base_ops: u64) {
    let storm_ops = arm.storm_total() as u64 * storm_vm_ops(&arm.clone);
    assert_eq!(
        s.total_ops,
        s.vms as u64 * base_ops + storm_ops,
        "{label}: storm fleet lost work"
    );
    assert_eq!(
        s.clones_staged as usize,
        arm.storm_total(),
        "{label}: staging miscounted"
    );
    assert_eq!(
        s.clones_admitted as usize, arm.storm_clones,
        "{label}: not every image-backed clone was admitted"
    );
    assert_eq!(
        s.clone_cold_boots as usize, arm.storm_cold,
        "{label}: not every cold-boot VM was admitted"
    );
    assert_eq!(
        s.conservation_violations, 0,
        "{label}: budgets drifted under the storm"
    );
    assert_eq!(
        s.budget_total_end, s.budget_total_start,
        "{label}: Σ budgets not conserved with the storm armed"
    );
    assert_eq!(s.handoff_violations, 0, "{label}: non-atomic hand-off");
}

/// The registered `clone_storm` experiment driver (8 host shards by
/// default; the CLI reaches it via `flexswap fleet --hosts N
/// --clone-storm`).
pub fn clone_storm(scale: Scale) -> Vec<Table> {
    clone_storm_with_hosts(scale, 8, FleetRunOpts::default())
}

/// Boot-storm autoscaling (PR 10): a storm of image-backed clones —
/// 256 over at most 100 fleet ticks at Full scale — lands on a busy
/// 8-host fleet, with an interleaved cold-boot comparison arm. Three
/// tables:
///
/// 1. **Clone vs cold boot** — time-to-first-useful-work p99 per arm,
///    measured from each storm VM's admission tick. Image-backed
///    clones must strictly beat cold boots: their boot faults
///    decompress shared pool entries (and boot-streaming runs ahead)
///    where a cold boot pays the full NVMe path per fault. Also
///    asserts the golden-image dedup ratio exceeds 1, Σ budgets hold
///    exactly, and the summary is byte-identical across engines and
///    worker counts with the storm armed.
/// 2. **Spread vs pack** — placement policy for image-sharing clones.
///    Spread installs the image once per host; pack rides one host's
///    copy, so it must hold the image on fewer hosts and store fewer
///    image bytes fleet-wide.
/// 3. **Balloon vs swap vs balloon+swap** (arxiv 1411.7344) — the same
///    storm under three reclaim renderings: a squeezed guest memory
///    limit (balloon), host swap with the full boot set resident
///    (swap), and the middle path.
pub fn clone_storm_with_hosts(scale: Scale, hosts: usize, opts: FleetRunOpts) -> Vec<Table> {
    let per_host = opts.per_host.unwrap_or(scale.u(2, 4) as usize);
    let ops = scale.u(4_000, 12_000);
    let clones = if opts.storm_clones > 0 { opts.storm_clones } else { scale.u(48, 256) as usize };
    let cold = if opts.storm_cold > 0 { opts.storm_cold } else { scale.u(16, 64) as usize };
    let base = FleetRunOpts {
        faults: vec![],
        fault_plan: FaultPlan::None,
        remote: false,
        donor_pct: 0,
        clone: CloneConfig::default(),
        storm_clones: 0,
        storm_cold: 0,
        storm_limit_pct: 0,
        clone_storm: false,
        ..opts.clone()
    };
    let storm = base.with_storm(clones, cold);

    let mut t = Table::new(
        "clone storm: image-backed admission vs cold boot",
        &[
            "config",
            "hosts",
            "clones",
            "cold",
            "admit_ticks",
            "clone_first_work_p99_us",
            "cold_first_work_p99_us",
            "dedup_ratio",
            "image_stored_mb",
            "image_hits",
            "cow_breaks",
            "major_faults",
            "runtime_ms",
        ],
    );
    let s = run_sharded_fleet(hosts, per_host, ops, FleetMode::StaticPlacement, 7, &storm);
    assert_storm_invariants("clone-storm", &s, &storm, ops);
    let batch = storm.clone.clones_per_tick.max(1);
    let admit_ticks = storm.storm_total().div_ceil(batch);
    if opts.storm_clones == 0 {
        assert!(
            admit_ticks <= 100,
            "clone-storm: default storm needs {admit_ticks} ticks (> 100) to admit"
        );
    }
    if storm.storm_clones > 0 && storm.storm_cold > 0 {
        assert!(
            s.clone_first_work_p99_ns < s.cold_first_work_p99_ns,
            "clone-storm: image-backed admission did not beat cold boot on \
             time-to-first-useful-work p99 ({} vs {} ns)",
            s.clone_first_work_p99_ns,
            s.cold_first_work_p99_ns
        );
    }
    if clones >= 2 * hosts {
        assert!(
            s.image_dedup_ratio() > 1.0,
            "clone-storm: golden image did not dedup (ratio {:.2})",
            s.image_dedup_ratio()
        );
    }
    // Engine equivalence with the storm armed: the sequential merge
    // oracle and a pinned worker count must reproduce the parallel
    // summary byte-for-byte (clone admission happens only at the
    // fleet-tick barrier, so nothing engine-dependent can leak in).
    let seq = run_sharded_fleet(
        hosts,
        per_host,
        ops,
        FleetMode::StaticPlacement,
        7,
        &storm.clone().with_sequential(true),
    );
    assert_eq!(s, seq, "clone-storm: summary differs between engines");
    let w3 = run_sharded_fleet(
        hosts,
        per_host,
        ops,
        FleetMode::StaticPlacement,
        7,
        &storm.clone().with_workers(Some(3)),
    );
    assert_eq!(s, w3, "clone-storm: summary differs at a pinned worker count");
    t.row(vec![
        "storm".into(),
        hosts.to_string(),
        clones.to_string(),
        cold.to_string(),
        admit_ticks.to_string(),
        format!("{:.0}", s.clone_first_work_p99_ns as f64 / 1e3),
        format!("{:.0}", s.cold_first_work_p99_ns as f64 / 1e3),
        format!("{:.1}", s.image_dedup_ratio()),
        format!("{:.1}", s.image_stored_bytes as f64 / 1e6),
        s.image_hits.to_string(),
        s.image_cow_breaks.to_string(),
        s.total_majors.to_string(),
        format!("{:.0}", s.runtime_ns as f64 / 1e6),
    ]);

    // Spread vs pack: clone-only storms (no cold arm) so the placement
    // comparison is pure.
    let mut t2 = Table::new(
        "clone storm: spread vs pack placement (image-sharing clones)",
        &[
            "config",
            "host",
            "clones",
            "image_stored_mb",
            "dedup_ratio",
            "clone_first_work_p99_us",
            "major_faults",
        ],
    );
    let holding = |x: &ShardedSummary| x.clones_per_host.iter().filter(|&&c| c > 0).count();
    let mut spread_arm: Option<ShardedSummary> = None;
    for (label, pack) in [("spread", false), ("pack", true)] {
        let arm = storm.clone().with_pack(pack).with_storm(clones, 0);
        let sp = run_sharded_fleet(hosts, per_host, ops, FleetMode::StaticPlacement, 7, &arm);
        assert_storm_invariants(label, &sp, &arm, ops);
        for (h, &c) in sp.clones_per_host.iter().enumerate() {
            t2.row(vec![
                label.into(),
                h.to_string(),
                c.to_string(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
        t2.row(vec![
            label.into(),
            "all".into(),
            sp.clones_admitted.to_string(),
            format!("{:.1}", sp.image_stored_bytes as f64 / 1e6),
            format!("{:.1}", sp.image_dedup_ratio()),
            format!("{:.0}", sp.clone_first_work_p99_ns as f64 / 1e3),
            sp.total_majors.to_string(),
        ]);
        if pack {
            let spread = spread_arm.as_ref().expect("spread arm ran first");
            if hosts > 1 && clones >= 2 * hosts {
                assert!(
                    holding(&sp) < holding(spread),
                    "{label}: packing spread the image anyway ({} vs {} hosts)",
                    holding(&sp),
                    holding(spread)
                );
                assert!(
                    sp.image_stored_bytes < spread.image_stored_bytes,
                    "{label}: packing stored no fewer image bytes ({} vs {})",
                    sp.image_stored_bytes,
                    spread.image_stored_bytes
                );
            }
        } else {
            spread_arm = Some(sp);
        }
    }

    // Balloon vs swap vs balloon+swap under the same storm: ballooning
    // is rendered as a squeezed per-VM memory limit (the guest hands
    // pages back before host swap is involved), swap as the full boot
    // working set resident with overflow on the image/swap path. The
    // swap arm *is* the main storm run above (limit = 100%).
    let mut t3 = Table::new(
        "clone storm: balloon vs swap vs balloon+swap",
        &[
            "config",
            "limit_pct",
            "clone_first_work_p99_us",
            "cold_first_work_p99_us",
            "major_faults",
            "p99_stall_us",
            "runtime_ms",
        ],
    );
    for (label, limit_pct) in [("balloon", 55), ("balloon+swap", 80), ("swap", 100)] {
        let sb;
        let arm_summary = if limit_pct == 100 {
            &s
        } else {
            let arm = storm.clone().with_storm_limit_pct(limit_pct);
            sb = run_sharded_fleet(hosts, per_host, ops, FleetMode::StaticPlacement, 7, &arm);
            assert_storm_invariants(label, &sb, &arm, ops);
            &sb
        };
        t3.row(vec![
            label.into(),
            limit_pct.to_string(),
            format!("{:.0}", arm_summary.clone_first_work_p99_ns as f64 / 1e3),
            format!("{:.0}", arm_summary.cold_first_work_p99_ns as f64 / 1e3),
            arm_summary.total_majors.to_string(),
            format!("{:.0}", arm_summary.p99_stall_ns as f64 / 1e3),
            format!("{:.0}", arm_summary.runtime_ns as f64 / 1e6),
        ]);
    }
    vec![t, t2, t3]
}
