//! PR 8 paper-figure-style table: swap granularity (strict-4k vs huge
//! vs auto) on a uniform-cold sequential sweep under a memory limit.
//!
//! The workload writes every page of a buffer twice its memory limit,
//! so the reclaimer runs continuously and every revisit is a cold
//! refault. Huge granularity moves one 2MB region per fault/reclaim:
//! strictly fewer major faults per byte reclaimed, strictly fewer NVMe
//! requests (one naturally-aligned 2MB write instead of 512 × 4kB), and
//! a region-level EPT scan (one summary bit per region). `auto` starts
//! huge and lets the dt-reclaimer split refault-heavy regions.

use crate::config::{HostConfig, MmConfig, VmConfig};
use crate::coordinator::Machine;
use crate::metrics::{Counters, Table};
use crate::storage::TierMetrics;
use crate::types::{GranularityMode, PageSize, Time, MS};
use crate::workloads::{SeqScan, Workload};

use super::Scale;

struct ArmResult {
    runtime: Time,
    counters: Counters,
    tiers: TierMetrics,
}

/// One granularity arm: a strict-4k guest under `mode`, sequential
/// writes over `pages` with a limit of half that, flat NVMe backend
/// (the paper's testbed shape, so every reclaim is a device request).
fn run_arm(mode: GranularityMode, pages: u64, iterations: u64) -> ArmResult {
    let mut m = Machine::new(HostConfig::paper());
    let mm_cfg = MmConfig {
        scan_interval: 50 * MS,
        history: 16,
        memory_limit: Some(pages * 4096 / 2),
        granularity: mode,
        ..Default::default()
    };
    let vm_cfg = VmConfig {
        frames: pages + 2048,
        vcpus: 1,
        page_size: PageSize::Small,
        // Freshly-booted THP-backed guest: granularity regions line up
        // with the guest's own layout.
        scramble: 0.0,
        guest_thp_coverage: 1.0,
    };
    let w: Vec<Box<dyn Workload>> = vec![Box::new(SeqScan::new(pages, iterations, 0))];
    m.sys_vm(vm_cfg, &mm_cfg, w);
    let res = m.run();
    ArmResult {
        runtime: res[0].runtime,
        counters: res[0].counters.clone(),
        tiers: m.backend_metrics().clone(),
    }
}

/// Major faults per GB actually written back by reclaim — the paper's
/// "reclaim efficiency" figure of merit.
fn faults_per_gb(c: &Counters) -> f64 {
    c.faults_major as f64 / (c.swapout_bytes.max(1) as f64 / 1e9)
}

fn arm_row(label: &str, a: &ArmResult) -> Vec<String> {
    vec![
        label.into(),
        format!("{:.1}", a.runtime as f64 / 1e6),
        a.counters.faults_major.to_string(),
        format!("{:.2}", a.counters.swapout_bytes as f64 / 1e9),
        format!("{:.0}", faults_per_gb(&a.counters)),
        format!("{:.2}", a.counters.scan_cpu_ns as f64 / 1e6),
        (a.tiers.nvme_write_reqs + a.tiers.nvme_reads).to_string(),
        a.tiers.nvme_huge_write_reqs.to_string(),
        a.counters.region_splits.to_string(),
    ]
}

fn table_columns() -> [&'static str; 9] {
    [
        "config",
        "runtime_ms",
        "major_faults",
        "reclaimed_gb",
        "faults_per_gb",
        "scan_ms",
        "nvme_reqs",
        "nvme_2m_writes",
        "region_splits",
    ]
}

pub fn granularity(scale: Scale) -> Vec<Table> {
    let pages = scale.u(8_192, 32_768);
    let iterations = scale.u(3, 5);
    let mut t = Table::new(
        "swap granularity: uniform-cold sweep under a 50% memory limit",
        &table_columns(),
    );
    for (label, mode) in [
        ("strict-4k", GranularityMode::Fixed),
        ("huge", GranularityMode::Huge),
        ("auto", GranularityMode::Auto),
    ] {
        let a = run_arm(mode, pages, iterations);
        t.row(arm_row(label, &a));
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::REGION_UNITS;

    /// The PR 8 acceptance shape: on a uniform-cold sweep, huge
    /// granularity needs strictly fewer major faults per byte reclaimed
    /// AND strictly fewer NVMe requests than strict-4k, and the
    /// region-level scan burns strictly less CPU.
    #[test]
    fn granularity_huge_beats_4k_on_uniform_cold() {
        let a4 = run_arm(GranularityMode::Fixed, 4_096, 2);
        let ah = run_arm(GranularityMode::Huge, 4_096, 2);
        assert!(a4.counters.swapout_bytes > 0, "4k arm never reclaimed");
        assert!(ah.counters.swapout_bytes > 0, "huge arm never reclaimed");
        assert!(
            faults_per_gb(&ah.counters) < faults_per_gb(&a4.counters),
            "huge {:.0} !< 4k {:.0} faults/GB",
            faults_per_gb(&ah.counters),
            faults_per_gb(&a4.counters),
        );
        let reqs = |a: &ArmResult| a.tiers.nvme_write_reqs + a.tiers.nvme_reads;
        assert!(
            reqs(&ah) < reqs(&a4),
            "huge {} !< 4k {} NVMe requests",
            reqs(&ah),
            reqs(&a4),
        );
        assert!(ah.tiers.nvme_huge_write_reqs > 0);
        assert_eq!(a4.tiers.nvme_huge_write_reqs, 0);
        assert!(ah.counters.scan_cpu_ns < a4.counters.scan_cpu_ns);
        assert!(ah.counters.huge_swapins > 0);
        assert!(ah.counters.huge_swapouts > 0);
    }

    /// Split-always oracle: `SplitAll` demotes every region to per-4k
    /// tracking at boot, so the whole run — timing, counters, CSV —
    /// must be byte-identical to the flat 4k baseline (only the
    /// `region_splits` bookkeeping column differs, by construction).
    #[test]
    fn granularity_splitall_oracle_matches_4k_csv() {
        let pages = 4_096;
        let a4 = run_arm(GranularityMode::Fixed, pages, 2);
        let ao = run_arm(GranularityMode::SplitAll, pages, 2);
        assert_eq!(ao.counters.region_splits, (pages + 2048).div_ceil(REGION_UNITS));
        let strip_splits = |mut row: Vec<String>| {
            row.pop();
            row
        };
        let csv_of = |a: &ArmResult| {
            let mut t = Table::new("oracle", &table_columns()[..8]);
            t.row(strip_splits(arm_row("oracle-arm", a)));
            t.csv()
        };
        assert_eq!(csv_of(&a4), csv_of(&ao));
    }
}
