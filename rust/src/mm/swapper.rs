//! Swapper worker pool (paper §4.1 step 7, §5.3).
//!
//! Workers dequeue units from the Swapper queue, derive the required
//! action from the unit's *current* state (the conflation design), hand
//! I/O to the storage backend, and sleep on a semaphore until the
//! backend wakes them. A worker is therefore occupied for the whole
//! duration of its operation — which is exactly why 2MB swapping
//! saturates the device with only two workers (Fig 7).

use crate::storage::TierHint;
use crate::types::{Time, UnitId};

/// What a worker must do for the unit it picked up. Produced by
/// [`super::engine::EngineCore::pick_work`]; executed by the machine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkOutcome {
    /// First touch: take a zero page and map it (no I/O).
    MapZero { unit: UnitId, cost: Time },
    /// Load unit content from the backing store, then map. The backend
    /// resolves the tier (compressed pool first, then NVMe).
    SwapIn { unit: UnitId, bytes: u64 },
    /// Map an already-staged (prefetched) unit — no I/O.
    MapStaged { unit: UnitId, cost: Time },
    /// Unmapped + dirty: write content out, then punch the hole.
    /// `hint` carries the requesting policy's tier routing (Auto unless
    /// the policy called `reclaim_to`).
    SwapOutWrite { unit: UnitId, bytes: u64, pre_cost: Time, hint: TierHint },
    /// Unmapped + clean copy already on disk: just punch the hole.
    Drop { unit: UnitId, cost: Time },
}

/// Worker-pool occupancy tracking. Idle workers sit on a free list so
/// `claim` is O(1) (the machine claims a worker per queued work item —
/// a linear occupancy scan would sit right behind the fault path).
#[derive(Debug)]
pub struct Swapper {
    busy: Vec<bool>,
    /// Idle worker stack; top is the most recently released.
    free: Vec<usize>,
    pub jobs_done: u64,
}

impl Swapper {
    pub fn new(threads: usize) -> Self {
        let n = threads.max(1);
        // Reverse so the first claims hand out workers 0, 1, 2, ...
        Swapper { busy: vec![false; n], free: (0..n).rev().collect(), jobs_done: 0 }
    }

    pub fn threads(&self) -> usize {
        self.busy.len()
    }

    /// Claim an idle worker, if any.
    pub fn claim(&mut self) -> Option<usize> {
        let idx = self.free.pop()?;
        debug_assert!(!self.busy[idx]);
        self.busy[idx] = true;
        Some(idx)
    }

    /// Release a worker after its chain completes. Idempotent: a
    /// double release must not put a duplicate on the free list (the
    /// old occupancy-scan implementation tolerated this, so degrade
    /// gracefully in release builds too).
    pub fn release(&mut self, worker: usize) {
        debug_assert!(self.busy[worker], "double release of worker {worker}");
        if !self.busy[worker] {
            return;
        }
        self.busy[worker] = false;
        self.free.push(worker);
        self.jobs_done += 1;
    }

    pub fn idle_workers(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_release_cycle() {
        let mut s = Swapper::new(2);
        let a = s.claim().unwrap();
        let b = s.claim().unwrap();
        assert_ne!(a, b);
        assert_eq!(s.claim(), None);
        s.release(a);
        assert_eq!(s.idle_workers(), 1);
        assert_eq!(s.claim(), Some(a));
    }

    #[test]
    fn at_least_one_worker() {
        let s = Swapper::new(0);
        assert_eq!(s.threads(), 1);
    }
}
