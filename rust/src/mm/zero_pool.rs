//! Zero-page pool (paper §5.1): pre-zeroed 2MB pages so first-touch
//! faults don't pay the ~100µs zeroing cost on the critical path; idle
//! time refills the pool.

use crate::types::Time;

#[derive(Debug)]
pub struct ZeroPool {
    level: usize,
    cap: usize,
    zero_cost: Time,
    pub hits: u64,
    pub misses: u64,
}

impl ZeroPool {
    pub fn new(cap: usize, zero_cost: Time) -> Self {
        // Pool starts full (populated at MM startup).
        ZeroPool { level: cap, cap, zero_cost, hits: 0, misses: 0 }
    }

    /// Take a pre-zeroed page for a first-touch mapping. Returns the
    /// zeroing cost paid on the critical path (0 on pool hit).
    pub fn take(&mut self) -> Time {
        if self.level > 0 {
            self.level -= 1;
            self.hits += 1;
            0
        } else {
            self.misses += 1;
            self.zero_cost
        }
    }

    /// Idle-time refill: add up to `n` pages.
    pub fn refill(&mut self, n: usize) {
        self.level = (self.level + n).min(self.cap);
    }

    pub fn level(&self) -> usize {
        self.level
    }
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_is_free_miss_pays_zeroing() {
        let mut p = ZeroPool::new(2, 100_000);
        assert_eq!(p.take(), 0);
        assert_eq!(p.take(), 0);
        assert_eq!(p.take(), 100_000);
        assert_eq!(p.hits, 2);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn refill_caps_at_capacity() {
        let mut p = ZeroPool::new(4, 1);
        p.take();
        p.take();
        p.refill(10);
        assert_eq!(p.level(), 4);
    }
}
