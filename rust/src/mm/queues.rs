//! Swapper queues (paper §4.2): a conflating priority-queue pair.
//!
//! The key design point: the queue holds *pages needing attention*, not
//! explicit operations. The Swapper dequeues a unit, looks at its
//! current state and the engine's intent, and derives the action — so a
//! reclaim raced by a fault (or vice versa) collapses into a no-op
//! instead of a redundant I/O round trip.
//!
//! Priority order: page faults > swap-outs (limit pressure) > prefetch.
//!
//! Every operation is O(1) amortized. Membership is a per-unit class
//! tag; a fault upgrade retags the unit and appends a fresh entry to the
//! fault queue, leaving the old entry behind as a *tombstone* (its
//! per-unit stamp no longer matches) that `pop` skips lazily. Each push
//! creates at most one physical entry and each entry is popped at most
//! once, so tombstone skipping is covered by the push that created it —
//! no `iter().position()` scans anywhere on the fault path.

use std::collections::VecDeque;

use crate::types::UnitId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueClass {
    Fault,
    Reclaim,
    Prefetch,
}

/// `class_of` value for "not queued".
const TAG_NONE: u8 = 0;

#[inline]
fn tag(class: QueueClass) -> u8 {
    match class {
        QueueClass::Fault => 1,
        QueueClass::Reclaim => 2,
        QueueClass::Prefetch => 3,
    }
}

#[derive(Debug)]
pub struct SwapperQueue {
    fault_q: VecDeque<(UnitId, u32)>,
    reclaim_q: VecDeque<(UnitId, u32)>,
    prefetch_q: VecDeque<(UnitId, u32)>,
    /// Per-unit queue membership: TAG_NONE or tag(class).
    class_of: Vec<u8>,
    /// Per-unit push generation; a queue entry is live iff its stamp
    /// matches (tombstones do not).
    stamp: Vec<u32>,
    /// Logical (tombstone-free) membership count per class.
    counts: [usize; 3],
    /// Outstanding tombstones per class queue. Only fault upgrades
    /// create tombstones (in the reclaim/prefetch queues); when a
    /// queue's dead entries outnumber its live ones, it is compacted so
    /// physical size stays O(live) even under sustained upgrade churn.
    dead: [usize; 3],
    pub enqueued: u64,
    pub conflated_enqueues: u64,
}

impl SwapperQueue {
    pub fn new(units: u64) -> Self {
        SwapperQueue {
            fault_q: VecDeque::new(),
            reclaim_q: VecDeque::new(),
            prefetch_q: VecDeque::new(),
            class_of: vec![TAG_NONE; units as usize],
            stamp: vec![0; units as usize],
            counts: [0; 3],
            dead: [0; 3],
            enqueued: 0,
            conflated_enqueues: 0,
        }
    }

    /// Drop dead entries from one class queue when they outnumber live
    /// ones. Amortized O(1): each retained pass is paid for by the
    /// upgrades that created the tombstones.
    fn maybe_compact(&mut self, cur: u8) {
        let ci = (cur - 1) as usize;
        if self.dead[ci] <= self.counts[ci] + 8 {
            return;
        }
        let (class_of, stamp) = (&self.class_of, &self.stamp);
        let live = |&(u, s): &(UnitId, u32)| {
            class_of[u as usize] == cur && stamp[u as usize] == s
        };
        match cur {
            1 => self.fault_q.retain(live),
            2 => self.reclaim_q.retain(live),
            _ => self.prefetch_q.retain(live),
        }
        self.dead[ci] = 0;
    }

    /// Enqueue a unit for attention. Re-enqueueing an already-queued unit
    /// is the conflation case: the entry stays where it is (the swapper
    /// will re-derive the correct action anyway), unless the new class is
    /// `Fault`, which upgrades the unit into the fault queue in O(1) by
    /// retagging it and tombstoning the old entry.
    pub fn push(&mut self, unit: UnitId, class: QueueClass) {
        let ui = unit as usize;
        let t = tag(class);
        let cur = self.class_of[ui];
        if cur != TAG_NONE {
            self.conflated_enqueues += 1;
            if class == QueueClass::Fault && cur != t {
                self.counts[(cur - 1) as usize] -= 1;
                self.counts[0] += 1;
                self.dead[(cur - 1) as usize] += 1;
                self.class_of[ui] = t;
                self.stamp[ui] = self.stamp[ui].wrapping_add(1);
                self.fault_q.push_back((unit, self.stamp[ui]));
                self.maybe_compact(cur);
            }
            return;
        }
        self.class_of[ui] = t;
        self.stamp[ui] = self.stamp[ui].wrapping_add(1);
        self.counts[(t - 1) as usize] += 1;
        self.enqueued += 1;
        let s = self.stamp[ui];
        match class {
            QueueClass::Fault => self.fault_q.push_back((unit, s)),
            QueueClass::Reclaim => self.reclaim_q.push_back((unit, s)),
            QueueClass::Prefetch => self.prefetch_q.push_back((unit, s)),
        }
    }

    /// Dequeue the highest-priority unit. `prefer_out` flips faults and
    /// reclaims (used when the engine is at the memory limit and must
    /// drain swap-outs before admitting more swap-ins).
    pub fn pop(&mut self, prefer_out: bool) -> Option<(UnitId, QueueClass)> {
        let order: [QueueClass; 3] = if prefer_out {
            [QueueClass::Reclaim, QueueClass::Fault, QueueClass::Prefetch]
        } else {
            [QueueClass::Fault, QueueClass::Reclaim, QueueClass::Prefetch]
        };
        for class in order {
            let t = tag(class);
            loop {
                let q = match class {
                    QueueClass::Fault => &mut self.fault_q,
                    QueueClass::Reclaim => &mut self.reclaim_q,
                    QueueClass::Prefetch => &mut self.prefetch_q,
                };
                let Some((unit, s)) = q.pop_front() else { break };
                let ui = unit as usize;
                if self.class_of[ui] == t && self.stamp[ui] == s {
                    self.class_of[ui] = TAG_NONE;
                    self.counts[(t - 1) as usize] -= 1;
                    return Some((unit, class));
                }
                // Tombstone (upgraded or re-pushed since): skip.
                self.dead[(t - 1) as usize] = self.dead[(t - 1) as usize].saturating_sub(1);
            }
        }
        None
    }

    pub fn contains(&self, unit: UnitId) -> bool {
        self.class_of[unit as usize] != TAG_NONE
    }

    /// Logical length: units currently queued (tombstones excluded).
    pub fn len(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pending_reclaims(&self) -> usize {
        self.counts[1]
    }
    pub fn pending_faults(&self) -> usize {
        self.counts[0]
    }

    /// Physical entries including tombstones (compaction bound checks).
    #[cfg(test)]
    fn physical_len(&self) -> usize {
        self.fault_q.len() + self.reclaim_q.len() + self.prefetch_q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_order() {
        let mut q = SwapperQueue::new(16);
        q.push(1, QueueClass::Prefetch);
        q.push(2, QueueClass::Reclaim);
        q.push(3, QueueClass::Fault);
        assert_eq!(q.pop(false), Some((3, QueueClass::Fault)));
        assert_eq!(q.pop(false), Some((2, QueueClass::Reclaim)));
        assert_eq!(q.pop(false), Some((1, QueueClass::Prefetch)));
        assert_eq!(q.pop(false), None);
    }

    #[test]
    fn prefer_out_flips_order() {
        let mut q = SwapperQueue::new(16);
        q.push(3, QueueClass::Fault);
        q.push(2, QueueClass::Reclaim);
        assert_eq!(q.pop(true), Some((2, QueueClass::Reclaim)));
        assert_eq!(q.pop(true), Some((3, QueueClass::Fault)));
    }

    #[test]
    fn conflation_no_duplicates() {
        let mut q = SwapperQueue::new(16);
        q.push(5, QueueClass::Reclaim);
        q.push(5, QueueClass::Reclaim);
        q.push(5, QueueClass::Prefetch);
        assert_eq!(q.len(), 1);
        assert_eq!(q.conflated_enqueues, 2);
    }

    #[test]
    fn fault_upgrades_queued_reclaim() {
        let mut q = SwapperQueue::new(16);
        q.push(5, QueueClass::Reclaim);
        q.push(6, QueueClass::Reclaim);
        q.push(5, QueueClass::Fault); // upgrade
        assert_eq!(q.pop(false), Some((5, QueueClass::Fault)));
        assert_eq!(q.pop(false), Some((6, QueueClass::Reclaim)));
    }

    #[test]
    fn contains_tracks_membership() {
        let mut q = SwapperQueue::new(8);
        q.push(1, QueueClass::Fault);
        assert!(q.contains(1));
        q.pop(false);
        assert!(!q.contains(1));
    }

    #[test]
    fn tombstone_does_not_resurrect_after_requeue() {
        let mut q = SwapperQueue::new(8);
        // reclaim -> fault upgrade -> pop -> fresh reclaim: the stale
        // reclaim entry must not surface for the fresh membership.
        q.push(3, QueueClass::Reclaim);
        q.push(3, QueueClass::Fault);
        assert_eq!(q.pop(false), Some((3, QueueClass::Fault)));
        q.push(4, QueueClass::Reclaim);
        q.push(3, QueueClass::Reclaim);
        // FIFO among live entries: 4 was pushed before 3's re-push; the
        // tombstone ahead of it must be skipped, not returned.
        assert_eq!(q.pop(false), Some((4, QueueClass::Reclaim)));
        assert_eq!(q.pop(false), Some((3, QueueClass::Reclaim)));
        assert_eq!(q.pop(false), None);
        assert!(q.is_empty());
    }

    #[test]
    fn upgrade_churn_does_not_accumulate_tombstones() {
        let mut q = SwapperQueue::new(1024);
        for round in 0..10_000u64 {
            let u = round % 1024;
            q.push(u, QueueClass::Reclaim);
            q.push(u, QueueClass::Fault); // upgrade -> reclaim_q tombstone
            assert_eq!(q.pop(false), Some((u, QueueClass::Fault)));
        }
        // Dead entries are compacted away: physical size stays O(live),
        // not O(upgrades) (10k churn rounds here).
        assert!(q.physical_len() <= 64, "physical {}", q.physical_len());
        assert!(q.is_empty());
    }

    /// Reference model: the original three-queue implementation with
    /// eager linear-scan removal. The tombstone queue must be
    /// observationally identical under arbitrary op sequences.
    struct RefModel {
        f: Vec<UnitId>,
        r: Vec<UnitId>,
        p: Vec<UnitId>,
    }

    impl RefModel {
        fn new() -> Self {
            RefModel { f: vec![], r: vec![], p: vec![] }
        }
        fn contains(&self, u: UnitId) -> bool {
            self.f.contains(&u) || self.r.contains(&u) || self.p.contains(&u)
        }
        fn push(&mut self, u: UnitId, c: QueueClass) {
            if self.contains(u) {
                if c == QueueClass::Fault && !self.f.contains(&u) {
                    self.r.retain(|&x| x != u);
                    self.p.retain(|&x| x != u);
                    self.f.push(u);
                }
                return;
            }
            match c {
                QueueClass::Fault => self.f.push(u),
                QueueClass::Reclaim => self.r.push(u),
                QueueClass::Prefetch => self.p.push(u),
            }
        }
        fn pop(&mut self, prefer_out: bool) -> Option<(UnitId, QueueClass)> {
            let order = if prefer_out {
                [QueueClass::Reclaim, QueueClass::Fault, QueueClass::Prefetch]
            } else {
                [QueueClass::Fault, QueueClass::Reclaim, QueueClass::Prefetch]
            };
            for c in order {
                let q = match c {
                    QueueClass::Fault => &mut self.f,
                    QueueClass::Reclaim => &mut self.r,
                    QueueClass::Prefetch => &mut self.p,
                };
                if !q.is_empty() {
                    return Some((q.remove(0), c));
                }
            }
            None
        }
        fn len(&self) -> usize {
            self.f.len() + self.r.len() + self.p.len()
        }
    }

    #[test]
    fn randomized_ops_match_reference_model() {
        use crate::sim::Rng;
        let units = 64u64;
        let mut rng = Rng::new(99);
        let mut q = SwapperQueue::new(units);
        let mut m = RefModel::new();
        for step in 0..20_000 {
            if rng.below(10) < 6 {
                let u = rng.below(units);
                let c = match rng.below(3) {
                    0 => QueueClass::Fault,
                    1 => QueueClass::Reclaim,
                    _ => QueueClass::Prefetch,
                };
                q.push(u, c);
                m.push(u, c);
            } else {
                let prefer_out = rng.chance(0.3);
                assert_eq!(q.pop(prefer_out), m.pop(prefer_out), "step {step}");
            }
            // Membership invariant: a unit appears at most once across
            // all queues, and both implementations agree on membership.
            assert_eq!(q.len(), m.len(), "step {step}");
            for u in 0..units {
                assert_eq!(q.contains(u), m.contains(u), "unit {u} step {step}");
            }
        }
        // Drain: the remaining pop sequences must match exactly.
        loop {
            let (a, b) = (q.pop(false), m.pop(false));
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert!(q.is_empty() && q.pending_faults() == 0 && q.pending_reclaims() == 0);
    }
}
