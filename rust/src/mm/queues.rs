//! Swapper queues (paper §4.2): a conflating priority-queue pair.
//!
//! The key design point: the queue holds *pages needing attention*, not
//! explicit operations. The Swapper dequeues a unit, looks at its
//! current state and the engine's intent, and derives the action — so a
//! reclaim raced by a fault (or vice versa) collapses into a no-op
//! instead of a redundant I/O round trip.
//!
//! Priority order: page faults > swap-outs (limit pressure) > prefetch.

use std::collections::VecDeque;

use crate::types::{Bitmap, UnitId};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueClass {
    Fault,
    Reclaim,
    Prefetch,
}

#[derive(Debug)]
pub struct SwapperQueue {
    fault_q: VecDeque<UnitId>,
    reclaim_q: VecDeque<UnitId>,
    prefetch_q: VecDeque<UnitId>,
    /// Membership bitmap: a unit appears at most once across all queues.
    queued: Bitmap,
    pub enqueued: u64,
    pub conflated_enqueues: u64,
}

impl SwapperQueue {
    pub fn new(units: u64) -> Self {
        SwapperQueue {
            fault_q: VecDeque::new(),
            reclaim_q: VecDeque::new(),
            prefetch_q: VecDeque::new(),
            queued: Bitmap::new(units as usize),
            enqueued: 0,
            conflated_enqueues: 0,
        }
    }

    /// Enqueue a unit for attention. Re-enqueueing an already-queued unit
    /// is the conflation case: the entry stays where it is (the swapper
    /// will re-derive the correct action anyway), unless the new class is
    /// `Fault`, which upgrades the unit into the fault queue.
    pub fn push(&mut self, unit: UnitId, class: QueueClass) {
        if self.queued.get(unit as usize) {
            self.conflated_enqueues += 1;
            if class == QueueClass::Fault {
                // Upgrade: remove from lower-priority queues if present.
                if let Some(p) = self.reclaim_q.iter().position(|&u| u == unit) {
                    self.reclaim_q.remove(p);
                    self.fault_q.push_back(unit);
                } else if let Some(p) =
                    self.prefetch_q.iter().position(|&u| u == unit)
                {
                    self.prefetch_q.remove(p);
                    self.fault_q.push_back(unit);
                }
            }
            return;
        }
        self.queued.set(unit as usize);
        self.enqueued += 1;
        match class {
            QueueClass::Fault => self.fault_q.push_back(unit),
            QueueClass::Reclaim => self.reclaim_q.push_back(unit),
            QueueClass::Prefetch => self.prefetch_q.push_back(unit),
        }
    }

    /// Dequeue the highest-priority unit. `prefer_out` flips faults and
    /// reclaims (used when the engine is at the memory limit and must
    /// drain swap-outs before admitting more swap-ins).
    pub fn pop(&mut self, prefer_out: bool) -> Option<(UnitId, QueueClass)> {
        let order: [(QueueClass, bool); 3] = if prefer_out {
            [(QueueClass::Reclaim, true), (QueueClass::Fault, true), (QueueClass::Prefetch, true)]
        } else {
            [(QueueClass::Fault, true), (QueueClass::Reclaim, true), (QueueClass::Prefetch, true)]
        };
        for (class, _) in order {
            let q = match class {
                QueueClass::Fault => &mut self.fault_q,
                QueueClass::Reclaim => &mut self.reclaim_q,
                QueueClass::Prefetch => &mut self.prefetch_q,
            };
            if let Some(u) = q.pop_front() {
                self.queued.clear(u as usize);
                return Some((u, class));
            }
        }
        None
    }

    pub fn contains(&self, unit: UnitId) -> bool {
        self.queued.get(unit as usize)
    }

    pub fn len(&self) -> usize {
        self.fault_q.len() + self.reclaim_q.len() + self.prefetch_q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pending_reclaims(&self) -> usize {
        self.reclaim_q.len()
    }
    pub fn pending_faults(&self) -> usize {
        self.fault_q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_order() {
        let mut q = SwapperQueue::new(16);
        q.push(1, QueueClass::Prefetch);
        q.push(2, QueueClass::Reclaim);
        q.push(3, QueueClass::Fault);
        assert_eq!(q.pop(false), Some((3, QueueClass::Fault)));
        assert_eq!(q.pop(false), Some((2, QueueClass::Reclaim)));
        assert_eq!(q.pop(false), Some((1, QueueClass::Prefetch)));
        assert_eq!(q.pop(false), None);
    }

    #[test]
    fn prefer_out_flips_order() {
        let mut q = SwapperQueue::new(16);
        q.push(3, QueueClass::Fault);
        q.push(2, QueueClass::Reclaim);
        assert_eq!(q.pop(true), Some((2, QueueClass::Reclaim)));
        assert_eq!(q.pop(true), Some((3, QueueClass::Fault)));
    }

    #[test]
    fn conflation_no_duplicates() {
        let mut q = SwapperQueue::new(16);
        q.push(5, QueueClass::Reclaim);
        q.push(5, QueueClass::Reclaim);
        q.push(5, QueueClass::Prefetch);
        assert_eq!(q.len(), 1);
        assert_eq!(q.conflated_enqueues, 2);
    }

    #[test]
    fn fault_upgrades_queued_reclaim() {
        let mut q = SwapperQueue::new(16);
        q.push(5, QueueClass::Reclaim);
        q.push(6, QueueClass::Reclaim);
        q.push(5, QueueClass::Fault); // upgrade
        assert_eq!(q.pop(false), Some((5, QueueClass::Fault)));
        assert_eq!(q.pop(false), Some((6, QueueClass::Reclaim)));
    }

    #[test]
    fn contains_tracks_membership() {
        let mut q = SwapperQueue::new(8);
        q.push(1, QueueClass::Fault);
        assert!(q.contains(1));
        q.pop(false);
        assert!(!q.contains(1));
    }
}
