//! The Memory Manager (paper §4.2): one userspace process per VM hosting
//! the Policy Engine, the Swapper (queues + worker threads), the memory
//! limit accounting, the zero-page pool and the MM-API parameter
//! registry.

pub mod engine;
pub mod queues;
pub mod swapper;
pub mod zero_pool;

pub use engine::{
    EngineCore, LimitReclaimer, Mm, MmStats, Policy, PolicyApi, PolicyEvent, WaiterMap,
};
pub use queues::SwapperQueue;
pub use swapper::{Swapper, WorkOutcome};
pub use zero_pool::ZeroPool;
