//! The Memory Manager (paper §4.2): one userspace process per VM hosting
//! the Policy Engine, the Swapper (queues + worker threads), the memory
//! limit accounting, the zero-page pool and the MM-API parameter
//! registry.
//!
//! Swap I/O leaves this layer through [`crate::storage::SwapBackend`]:
//! swap-out pickups carry a policy tier hint
//! ([`WorkOutcome::SwapOutWrite`]), and the engine mirrors backend
//! receipts into a per-unit tier map so policies can query
//! [`PolicyApi::swap_tier`] without ever touching the backend on the
//! fault path.

pub mod engine;
pub mod queues;
pub mod swapper;
pub mod zero_pool;

pub use engine::{
    EngineCore, LimitReclaimer, Mm, MmStats, Policy, PolicyApi, PolicyEvent, WaiterMap,
};
pub use queues::SwapperQueue;
pub use swapper::{Swapper, WorkOutcome};
pub use zero_pool::ZeroPool;
