//! The Policy Engine (paper §4.3): synchronizes page faults from the
//! UFFD poller with requests from policies, enforces the memory limit,
//! schedules work into the Swapper queue and notifies policies.
//!
//! Safety property (paper Table 1 discussion): a policy driving the
//! [`PolicyApi`] cannot corrupt guest memory or violate the memory
//! limit — reclaim/prefetch requests are validated against the unit
//! state machine and the limit accounting before any work is queued.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::{MmConfig, SwCost};
use crate::introspect::{FaultCtx, GvaWalker, VmcsRing};
use crate::metrics::Counters;
use crate::mm::queues::{QueueClass, SwapperQueue};
use crate::mm::swapper::{Swapper, WorkOutcome};
use crate::mm::zero_pool::ZeroPool;
use crate::storage::{LockBitmap, SwapTier, TierHint};
use crate::types::{Bitmap, Granularity, GranularityMode, Time, UnitId, UnitState, REGION_UNITS};
use crate::uffd::{Uffd, UffdEvent};
use crate::vm::Vm;

/// Events delivered to policies (paper Table 1 `on_event`).
#[derive(Debug)]
pub enum PolicyEvent<'a> {
    PageFault {
        unit: UnitId,
        /// VMCS context from the introspection ring (may be absent).
        ctx: Option<FaultCtx>,
        /// true = required backing-store I/O.
        major: bool,
        now: Time,
    },
    ScanBitmap { bitmap: &'a Bitmap, now: Time },
    SwapIn { unit: UnitId, now: Time },
    SwapOut { unit: UnitId, now: Time },
    LimitChanged { old: Option<u64>, new: Option<u64>, now: Time },
    Timer { now: Time },
}

/// The policy-facing API (paper Table 1). Wraps the engine core plus a
/// read-only view of the VM for introspection.
pub struct PolicyApi<'a> {
    pub core: &'a mut EngineCore,
    pub vm: &'a Vm,
    pub walker: &'a mut GvaWalker,
    pub now: Time,
}

impl<'a> PolicyApi<'a> {
    /// `reclaim(addr)`: request a unit be swapped out. Validated; no-op
    /// for non-resident or DMA-locked units.
    pub fn reclaim(&mut self, unit: UnitId) {
        self.core.request_reclaim(unit);
    }

    /// `reclaim(addr, tier)`: like [`PolicyApi::reclaim`] but routes the
    /// write to a specific storage tier — e.g. the dt-reclaimer sends
    /// maximally-cold units straight to NVMe so they don't churn the
    /// compressed pool.
    pub fn reclaim_to(&mut self, unit: UnitId, hint: TierHint) {
        self.core.request_reclaim_to(unit, hint);
    }

    /// `get_swap_tier(addr)`: which storage tier holds the unit's swap
    /// copy (None while resident with no backing copy). Maintained by
    /// the machine from backend receipts, so policies can target tiers
    /// without touching the backend.
    pub fn swap_tier(&self, unit: UnitId) -> Option<SwapTier> {
        self.core.swap_tier(unit)
    }

    /// `prefetch(addr)`: request a swap-in. Dropped if it would violate
    /// the memory limit (paper §4.3) or the unit isn't swapped out.
    pub fn prefetch(&mut self, unit: UnitId) {
        self.core.request_prefetch(unit);
    }

    /// `gva_to_hva(gva, cr3)`: guest-page-table walk via the QEMU helper.
    /// Returns the host frame (HVA page) on success.
    pub fn gva_to_hva(&mut self, gva_page: u64, cr3: u64) -> Option<u64> {
        self.walker.gva_to_hva(self.vm, cr3, gva_page)
    }

    /// Unit covering a host frame.
    pub fn unit_of_frame(&self, hva_frame: u64) -> UnitId {
        hva_frame / self.vm.unit_frames()
    }

    /// `get_page_state(addr)`.
    pub fn page_state(&self, unit: UnitId) -> UnitState {
        self.core.states[unit as usize]
    }

    /// `get_memory_limit()` in units.
    pub fn memory_limit(&self) -> Option<u64> {
        self.core.limit_units
    }

    /// `get_memory_usage()` in units.
    pub fn memory_usage(&self) -> u64 {
        self.core.usage_units
    }

    /// `get_pf_count()`.
    pub fn pf_count(&self) -> u64 {
        self.core.pf_count
    }

    pub fn units(&self) -> u64 {
        self.core.states.len() as u64
    }

    /// `register_parameter(name, ...)`: expose a runtime-tunable knob
    /// through the MM-API.
    pub fn register_parameter(&mut self, name: &str, value: f64) {
        self.core.params.insert(name.to_string(), value);
    }

    /// Read a parameter (control-plane side uses the same registry).
    pub fn parameter(&self, name: &str) -> Option<f64> {
        self.core.params.get(name).copied()
    }

    /// Request a different EPT scan interval (the §6.7 aggressive policy
    /// tightens this during reclaim mode).
    pub fn set_scan_interval(&mut self, interval: Time) {
        self.core.requested_scan_interval = Some(interval);
    }

    /// `recovery_mode()`: true while the control plane's recovery-boost
    /// window after a hard-limit release is open. Prefetchers use this
    /// hint to restore the working set more aggressively (§6.8); it is
    /// advisory — the engine still validates every request.
    pub fn recovery_mode(&self) -> bool {
        self.now < self.core.recovery_until
    }

    /// `split_region(r)` (PR 8): ask that 2MB-backed region `r` be
    /// demoted to per-4k tracking. Queued and applied by the machine at
    /// the next scan tick (the VM's EPT mirror must change in the same
    /// step), and validated there — a region with in-flight or swapped
    /// state stays huge until it settles.
    pub fn split_region(&mut self, r: u64) {
        if r < self.core.regions() && self.core.region_huge(r) {
            self.core.pending_splits.push(r);
        }
    }

    /// `collapse_region(r)` (PR 8): ask that split region `r` be
    /// promoted back to one 2MB-backed unit. Applied at the next scan
    /// tick if the whole span is uniformly resident and idle.
    pub fn collapse_region(&mut self, r: u64) {
        if r < self.core.regions() && !self.core.region_huge(r) {
            self.core.pending_collapses.push(r);
        }
    }

    /// Number of granularity regions over the unit space.
    pub fn regions(&self) -> u64 {
        self.core.regions()
    }

    /// Is region `r` currently 2MB-backed?
    pub fn region_huge(&self, r: u64) -> bool {
        r < self.core.regions() && self.core.region_huge(r)
    }

    /// Granularity tag of the op a fault/reclaim on `unit` would be.
    pub fn granularity_of(&self, unit: UnitId) -> Granularity {
        if self.core.huge_unit(unit) {
            Granularity::Region
        } else {
            Granularity::Page
        }
    }

    /// The VM's configured granularity mode.
    pub fn granularity_mode(&self) -> GranularityMode {
        self.core.granularity_mode
    }

    /// Retune the tiered backend's pool-admission threshold (satellite
    /// of PR 8: the dt-reclaimer drives this from its age histogram).
    /// Forwarded to the backend by the machine at the next scan tick.
    pub fn set_pool_admission(&mut self, reject_pct: u8) {
        self.core.pending_admission = Some(reject_pct.min(100));
    }
}

/// A policy module (optional, paper §4.3). Policies only see
/// [`PolicyEvent`]s and the [`PolicyApi`]. `Send` because the MM (and
/// so its policies) rides its machine onto a fleet worker thread.
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi);
    /// Periodic timer, if the policy wants one.
    fn timer_interval(&self) -> Option<Time> {
        None
    }
}

/// The *memory-limit reclaimer* (paper §4.3 "Forced memory reclamation"):
/// invoked synchronously on the fault path, must answer fast. `Send`
/// for the same reason as [`Policy`].
pub trait LimitReclaimer: Send {
    fn name(&self) -> &'static str;
    /// Observe events to train victim selection.
    fn note(&mut self, ev: &PolicyEvent);
    /// O(1) recency notification: the engine calls this on *every*
    /// `last_touch` update (faults, swap-in completions, scan hits) so
    /// incremental reclaimers can maintain their structures without a
    /// per-fault event allocation or hash lookup. Default: ignore.
    fn touch(&mut self, _unit: UnitId, _now: Time) {}
    /// Choose a victim among resident units; never a locked/queued unit
    /// (the engine re-validates anyway).
    fn victim(&mut self, core: &EngineCore, now: Time) -> Option<UnitId>;
}

/// Index-based waiter table: per-unit lists of vCPUs blocked on a fault,
/// preallocated per unit so the fault path never hashes. Replaces the
/// old `HashMap<UnitId, Vec<usize>>` (a hash + probe per fault, per
/// pickup and per completion).
#[derive(Clone)]
pub struct WaiterMap {
    lists: Vec<Vec<usize>>,
    nonempty: usize,
}

impl WaiterMap {
    pub fn new(units: u64) -> Self {
        WaiterMap { lists: vec![Vec::new(); units as usize], nonempty: 0 }
    }

    /// Append a waiting vCPU to the unit's list.
    #[inline]
    pub fn push(&mut self, unit: UnitId, vcpu: usize) {
        let l = &mut self.lists[unit as usize];
        if l.is_empty() {
            self.nonempty += 1;
        }
        l.push(vcpu);
    }

    /// Any vCPU waiting on this unit?
    #[inline]
    pub fn has(&self, unit: UnitId) -> bool {
        !self.lists[unit as usize].is_empty()
    }

    /// Remove and return the unit's waiters (empty vec if none). The
    /// buffer moves out with its capacity; the slot restarts empty, so
    /// the next fault on the same unit re-allocates (one small alloc
    /// per fault *burst*, not per fault — piggybacking waiters append).
    pub fn take(&mut self, unit: UnitId) -> Vec<usize> {
        let l = &mut self.lists[unit as usize];
        if l.is_empty() {
            return Vec::new();
        }
        self.nonempty -= 1;
        std::mem::take(l)
    }

    /// Waiters for one unit (None if empty) — kept HashMap-call-shaped
    /// for tests.
    pub fn get(&self, unit: &UnitId) -> Option<&Vec<usize>> {
        let l = &self.lists[*unit as usize];
        if l.is_empty() {
            None
        } else {
            Some(l)
        }
    }

    /// True when no unit has waiters.
    pub fn is_empty(&self) -> bool {
        self.nonempty == 0
    }

    /// Number of units with at least one waiter.
    pub fn waiting_units(&self) -> usize {
        self.nonempty
    }
}

impl fmt::Debug for WaiterMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_map()
            .entries(
                self.lists
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| !l.is_empty())
                    .map(|(u, l)| (u, l)),
            )
            .finish()
    }
}

/// Shared engine state: unit state machine, queues, accounting.
pub struct EngineCore {
    pub states: Vec<UnitState>,
    /// Reclaim intent (set by policies, consumed at pickup).
    pub want_out: Bitmap,
    /// Queued-as-prefetch marker for stats.
    prefetch_intent: Bitmap,
    /// Unit content exists on the backing store and is unmodified.
    clean_on_disk: Bitmap,
    pub queue: SwapperQueue,
    pub waiters: WaiterMap,
    /// Units in DRAM (Resident + in-flight transitions holding DRAM).
    pub usage_units: u64,
    pub limit_units: Option<u64>,
    /// Queued/in-flight swap-ins not yet counted in usage.
    pub planned_in: u64,
    /// Queued/in-flight swap-outs not yet subtracted from usage.
    pub planned_out: u64,
    pub pf_count: u64,
    pub unit_bytes: u64,
    pub huge: bool,
    pub counters: Counters,
    pub locks: LockBitmap,
    pub params: BTreeMap<String, f64>,
    /// Last touch time per unit (faults + scan hits) — shared LRU info.
    pub last_touch: Vec<Time>,
    /// Units brought in by prefetch and not yet touched.
    pub prefetched_untouched: Bitmap,
    /// When each prefetched unit was staged (timeliness window).
    pub staged_at: Vec<Time>,
    /// Set when a policy asks for a different scan cadence.
    pub requested_scan_interval: Option<Time>,
    /// Recovery-boost window: [`PolicyApi::recovery_mode`] reads true
    /// until this virtual time (set by boost-flagged limit releases).
    pub recovery_until: Time,
    /// Per-unit reclaim tier routing (encoded [`TierHint`]), set by
    /// `reclaim_to`, consumed at swap-out pickup.
    tier_hint: Vec<u8>,
    /// Which backend tier holds each unit's swap copy (encoded
    /// `Option<SwapTier>`): mirror of backend receipts, kept by the
    /// machine so the fault path / policies never query the backend.
    backend_tier: Vec<u8>,
    clock_hand: usize,
    /// Granularity overlay (PR 8): bit r set = region r is 2MB-backed.
    /// All state for a huge region lives at its *base unit* (r *
    /// [`REGION_UNITS`]); the other units stay `Untouched` and are never
    /// queued, waited on or LRU-tracked, so one huge fault/reclaim is
    /// one O(1) op through every existing structure.
    pub region_huge: Bitmap,
    /// Count of set bits in `region_huge` (fast path: 0 = flat 4k).
    huge_region_count: u64,
    pub granularity_mode: GranularityMode,
    /// Region ops requested by policies this tick, validated + applied
    /// by the machine at the next scan tick (EPT mirror moves with it).
    pub pending_splits: Vec<u64>,
    pub pending_collapses: Vec<u64>,
    /// Pool-admission retune requested by a policy (reject_pct).
    pub pending_admission: Option<u8>,
}

#[inline]
fn hint_code(h: TierHint) -> u8 {
    match h {
        TierHint::Auto => 0,
        TierHint::Pool => 1,
        TierHint::Nvme => 2,
    }
}

#[inline]
fn hint_from(c: u8) -> TierHint {
    match c {
        1 => TierHint::Pool,
        2 => TierHint::Nvme,
        _ => TierHint::Auto,
    }
}

impl EngineCore {
    pub fn new(units: u64, unit_bytes: u64, limit_units: Option<u64>) -> Self {
        EngineCore {
            states: vec![UnitState::Untouched; units as usize],
            want_out: Bitmap::new(units as usize),
            prefetch_intent: Bitmap::new(units as usize),
            clean_on_disk: Bitmap::new(units as usize),
            queue: SwapperQueue::new(units),
            waiters: WaiterMap::new(units),
            usage_units: 0,
            limit_units,
            planned_in: 0,
            planned_out: 0,
            pf_count: 0,
            unit_bytes,
            huge: unit_bytes > crate::types::FRAME_BYTES,
            counters: Counters::default(),
            locks: LockBitmap::new(units),
            params: BTreeMap::new(),
            last_touch: vec![0; units as usize],
            prefetched_untouched: Bitmap::new(units as usize),
            staged_at: vec![0; units as usize],
            requested_scan_interval: None,
            recovery_until: 0,
            tier_hint: vec![0; units as usize],
            backend_tier: vec![0; units as usize],
            clock_hand: 0,
            region_huge: Bitmap::new(units.div_ceil(REGION_UNITS) as usize),
            huge_region_count: 0,
            granularity_mode: GranularityMode::Fixed,
            pending_splits: Vec::new(),
            pending_collapses: Vec::new(),
            pending_admission: None,
        }
    }

    /// Install the granularity mode at admission time (before any
    /// fault). Strict-2MB VMs force `Fixed`: their unit is already 2MB.
    pub fn set_granularity(&mut self, mode: GranularityMode) {
        if self.huge {
            self.granularity_mode = GranularityMode::Fixed;
            return;
        }
        self.granularity_mode = mode;
        match mode {
            GranularityMode::Fixed => {}
            GranularityMode::Huge | GranularityMode::Auto => {
                for r in 0..self.regions() {
                    self.region_huge.set(r as usize);
                }
                self.huge_region_count = self.regions();
            }
            GranularityMode::SplitAll => {
                // Oracle: admit huge, then split every region while it
                // is still untouched — structurally identical to Fixed.
                for r in 0..self.regions() {
                    self.region_huge.set(r as usize);
                    self.huge_region_count += 1;
                    let ok = self.split_region(r);
                    debug_assert!(ok);
                }
            }
        }
    }

    /// Number of granularity regions ([`REGION_UNITS`] units each, last
    /// one possibly short).
    #[inline]
    pub fn regions(&self) -> u64 {
        (self.states.len() as u64).div_ceil(REGION_UNITS)
    }

    /// Is region `r` 2MB-backed? (`r` must be in bounds.)
    #[inline]
    pub fn region_huge(&self, r: u64) -> bool {
        self.huge_region_count > 0 && self.region_huge.get(r as usize)
    }

    /// First unit of region `r`.
    #[inline]
    pub fn region_base(&self, r: u64) -> UnitId {
        r * REGION_UNITS
    }

    /// Units covered by region `r` (the last region may be short).
    #[inline]
    pub fn region_span(&self, r: u64) -> u64 {
        (self.states.len() as u64 - self.region_base(r)).min(REGION_UNITS)
    }

    /// The unit carrying a unit's swap state: the region base inside a
    /// huge region, the unit itself otherwise.
    #[inline]
    pub fn canonical_unit(&self, unit: UnitId) -> UnitId {
        if self.huge_region_count > 0 && self.region_huge.get((unit / REGION_UNITS) as usize) {
            unit - unit % REGION_UNITS
        } else {
            unit
        }
    }

    /// Units one swap op on `unit` moves (1, or the whole region span
    /// for the base of a huge region).
    #[inline]
    pub fn span_units(&self, unit: UnitId) -> u64 {
        if self.huge_region_count > 0 && self.region_huge.get((unit / REGION_UNITS) as usize) {
            self.region_span(unit / REGION_UNITS)
        } else {
            1
        }
    }

    /// Does an op on this unit move a 2MB mapping (strict-2MB unit or
    /// 2MB-backed granularity region)?
    #[inline]
    pub fn huge_unit(&self, unit: UnitId) -> bool {
        self.huge
            || (self.huge_region_count > 0
                && self.region_huge.get((unit / REGION_UNITS) as usize))
    }

    /// Demote region `r` to per-4k tracking. Only settled regions split:
    /// the base must be `Resident` or `Untouched` with nothing queued,
    /// wanted, locked or waited-on — in particular a `Swapped` base
    /// never splits, so a 2MB backing-store image is never torn into 4k
    /// reads. Returns true on success; the caller (machine) mirrors the
    /// transition into the VM's EPT and discards the stale base receipt.
    pub fn split_region(&mut self, r: u64) -> bool {
        if !self.region_huge(r) {
            return false;
        }
        let base = self.region_base(r);
        let bi = base as usize;
        let span = self.region_span(r) as usize;
        match self.states[bi] {
            UnitState::Resident | UnitState::Untouched => {}
            _ => return false,
        }
        if self.queue.contains(base)
            || self.want_out.get(bi)
            || self.prefetch_intent.get(bi)
            || self.locks.is_locked(base)
            || self.waiters.has(base)
        {
            return false;
        }
        self.region_huge.clear(r as usize);
        self.huge_region_count -= 1;
        if self.states[bi] == UnitState::Resident {
            // Fan the resident base out over the span: usage_units
            // already counts the full span, so accounting is unchanged.
            let t = self.last_touch[bi];
            for u in bi + 1..bi + span {
                self.states[u] = UnitState::Resident;
                self.last_touch[u] = t;
            }
        }
        // Any 2MB backing-store copy can no longer serve per-4k reads:
        // forget the clean copy (the machine discards the receipt).
        self.clean_on_disk.clear(bi);
        self.tier_hint[bi] = 0;
        self.backend_tier[bi] = 0;
        self.prefetched_untouched.clear(bi);
        self.counters.region_splits += 1;
        true
    }

    /// Promote split region `r` back to one 2MB-backed unit. Requires
    /// the whole span uniformly `Resident` and idle (nothing queued,
    /// wanted, locked or waited-on anywhere in it). Returns true on
    /// success; the caller mirrors the EPT and discards the span's
    /// stale per-4k receipts.
    pub fn collapse_region(&mut self, r: u64) -> bool {
        if self.huge || r >= self.regions() || self.region_huge(r) {
            return false;
        }
        let base = self.region_base(r);
        let bi = base as usize;
        let span = self.region_span(r) as usize;
        for u in bi..bi + span {
            if self.states[u] != UnitState::Resident
                || self.want_out.get(u)
                || self.prefetch_intent.get(u)
                || self.queue.contains(u as UnitId)
                || self.locks.is_locked(u as UnitId)
                || self.waiters.has(u as UnitId)
            {
                return false;
            }
        }
        let mut newest = 0;
        for u in bi..bi + span {
            newest = newest.max(self.last_touch[u]);
            // Per-4k disk copies can't back a 2MB unit: drop them.
            self.clean_on_disk.clear(u);
            self.tier_hint[u] = 0;
            self.backend_tier[u] = 0;
            self.prefetched_untouched.clear(u);
            if u != bi {
                self.states[u] = UnitState::Untouched;
                self.last_touch[u] = 0;
            }
        }
        self.last_touch[bi] = newest;
        self.region_huge.set(r as usize);
        self.huge_region_count += 1;
        self.counters.region_collapses += 1;
        true
    }

    /// Record where the backend put this unit's swap copy (machine-side
    /// bookkeeping from [`crate::storage::IoReceipt`]s).
    pub fn set_backend_tier(&mut self, unit: UnitId, tier: Option<SwapTier>) {
        self.backend_tier[unit as usize] = match tier {
            None => 0,
            Some(SwapTier::Pool) => 1,
            Some(SwapTier::Nvme) => 2,
            Some(SwapTier::Remote) => 3,
        };
    }

    /// Storage tier holding the unit's swap copy, if any.
    pub fn swap_tier(&self, unit: UnitId) -> Option<SwapTier> {
        match self.backend_tier[unit as usize] {
            1 => Some(SwapTier::Pool),
            2 => Some(SwapTier::Nvme),
            3 => Some(SwapTier::Remote),
            _ => None,
        }
    }

    /// Rebuild the whole backend-tier mirror from an authoritative
    /// probe (VM state migration: after the implant, the target
    /// backend is the authority — imported pool copies may have been
    /// demoted to NVMe on arrival, and policies must not keep routing
    /// on the donor's stale map).
    pub fn resync_backend_tiers(&mut self, tier_of: impl Fn(UnitId) -> Option<SwapTier>) {
        for u in 0..self.backend_tier.len() as UnitId {
            self.set_backend_tier(u, tier_of(u));
        }
    }

    /// Crash demotion: the host under this VM died, so its DRAM —
    /// every resident unit — is gone. Residents become Swapped (their
    /// next touch refaults cold against the rebuild shard's backend)
    /// and every clean-on-disk bit drops: those bits vouched for the
    /// *dead* host's backend, so no future reclaim may elide its
    /// write-back against the new one. In-flight transitions and
    /// queued intents are left alone — the conflating pickup settles
    /// their planned counts when the stale entries pop. Returns the
    /// demoted bytes. Callers unmap the EPT themselves.
    pub fn crash_demote_all(&mut self) -> u64 {
        let mut demoted = 0u64;
        for ui in 0..self.states.len() {
            if self.states[ui] == UnitState::Resident {
                // A huge region's base carries the whole span's DRAM.
                let span = self.span_units(ui as UnitId);
                self.states[ui] = UnitState::Swapped;
                self.usage_units -= span;
                demoted += self.unit_bytes * span;
            }
            self.clean_on_disk.clear(ui);
        }
        demoted
    }

    /// Planned usage if every queued request were processed: the paper's
    /// "correct ratio of swap-in and swap-out requests" invariant.
    pub fn planned_usage(&self) -> i64 {
        self.usage_units as i64 + self.planned_in as i64 - self.planned_out as i64
    }

    pub fn over_limit(&self) -> bool {
        self.limit_units
            .is_some_and(|l| self.planned_usage() > l as i64)
    }

    pub fn at_limit(&self) -> bool {
        self.limit_units
            .is_some_and(|l| self.planned_usage() >= l as i64)
    }

    /// Policy request: reclaim. Validated (paper: cannot corrupt, cannot
    /// break the fault path).
    pub fn request_reclaim(&mut self, unit: UnitId) {
        self.request_reclaim_to(unit, TierHint::Auto);
    }

    /// Reclaim with an explicit storage-tier routing hint (consumed at
    /// swap-out pickup; the last request's hint wins).
    pub fn request_reclaim_to(&mut self, unit: UnitId, hint: TierHint) {
        if self.states[unit as usize] != UnitState::Resident {
            return;
        }
        if self.locks.deny_if_locked(unit) {
            return;
        }
        self.tier_hint[unit as usize] = hint_code(hint);
        if self.want_out.get(unit as usize) {
            return; // already requested
        }
        self.want_out.set(unit as usize);
        self.planned_out += self.span_units(unit);
        self.queue.push(unit, QueueClass::Reclaim);
    }

    /// Policy request: prefetch. Dropped when at the memory limit.
    /// A prefetch racing an in-flight swap-out of the same unit is
    /// queued as intent — the conflating pickup re-derives the swap-in
    /// once the swap-out completes (paper §4.2).
    pub fn request_prefetch(&mut self, unit: UnitId) {
        let st = self.states[unit as usize];
        if st != UnitState::Swapped && st != UnitState::SwappingOut {
            return;
        }
        if self.queue.contains(unit) {
            return;
        }
        let span = self.span_units(unit);
        if self
            .limit_units
            .is_some_and(|l| self.planned_usage() + span as i64 > l as i64)
        {
            return; // would violate limit: drop (paper §4.3)
        }
        self.planned_in += span;
        self.prefetch_intent.set(unit as usize);
        self.counters.prefetch_issued += 1;
        self.queue.push(unit, QueueClass::Prefetch);
    }

    /// Derive the next work item (conflating pickup; paper §4.2).
    pub fn pick_work(&mut self, zero_pool: &mut ZeroPool, sw: &SwCost, now: Time) -> Option<WorkOutcome> {
        let prefer_out = self.at_limit();
        loop {
            let (unit, class) = self.queue.pop(prefer_out)?;
            let ui = unit as usize;
            match self.states[ui] {
                UnitState::Untouched => {
                    if self.waiters.has(unit) {
                        self.states[ui] = UnitState::SwappingIn;
                        let huge_op = self.huge_unit(unit);
                        let cost = sw.queue_handoff_ns
                            + if huge_op { zero_pool.take() } else { 0 }
                            + Uffd::continue_cost(sw, huge_op);
                        return Some(WorkOutcome::MapZero { unit, cost });
                    }
                    // Prefetch/reclaim of an untouched unit: nothing to do.
                    self.cancel_intents(unit);
                    self.counters.conflated_ops += 1;
                }
                UnitState::Swapped => {
                    let wanted = self.waiters.has(unit)
                        || self.prefetch_intent.get(ui);
                    if wanted {
                        self.states[ui] = UnitState::SwappingIn;
                        if self.prefetch_intent.get(ui)
                            && !self.waiters.has(unit)
                        {
                            self.prefetched_untouched.set(ui);
                        }
                        self.prefetch_intent.clear(ui);
                        return Some(WorkOutcome::SwapIn {
                            unit,
                            bytes: self.unit_bytes * self.span_units(unit),
                        });
                    }
                    self.cancel_intents(unit);
                    self.counters.conflated_ops += 1;
                }
                UnitState::Resident => {
                    if self.want_out.get(ui) && !self.locks.is_locked(unit) {
                        self.want_out.clear(ui);
                        self.states[ui] = UnitState::SwappingOut;
                        if self.prefetched_untouched.get(ui) {
                            self.prefetched_untouched.clear(ui);
                            self.counters.prefetch_wasted += 1;
                        }
                        let pre = sw.queue_handoff_ns + sw.madvise_ns;
                        // Consume the routing hint either way so a Drop
                        // elision can't leak it into a later reclaim.
                        let hint = hint_from(std::mem::take(&mut self.tier_hint[ui]));
                        if self.clean_on_disk.get(ui) {
                            // Clean copy on disk: no write-back needed.
                            return Some(WorkOutcome::Drop {
                                unit,
                                cost: pre + sw.punch_hole_ns,
                            });
                        }
                        return Some(WorkOutcome::SwapOutWrite {
                            unit,
                            bytes: self.unit_bytes * self.span_units(unit),
                            pre_cost: pre,
                            hint,
                        });
                    }
                    // Fault/prefetch raced a completed map, or the unit
                    // got locked: conflated no-op.
                    self.cancel_intents(unit);
                    self.counters.conflated_ops += 1;
                }
                UnitState::Staged => {
                    if self.waiters.has(unit) {
                        self.states[ui] = UnitState::SwappingIn;
                        let cost = sw.queue_handoff_ns
                            + Uffd::continue_cost(sw, self.huge_unit(unit));
                        return Some(WorkOutcome::MapStaged { unit, cost });
                    }
                    if self.want_out.get(ui) && !self.locks.is_locked(unit) {
                        // Reclaiming an untouched prefetch: content is a
                        // clean disk copy — just punch the hole.
                        self.want_out.clear(ui);
                        self.tier_hint[ui] = 0;
                        self.states[ui] = UnitState::SwappingOut;
                        self.prefetched_untouched.clear(ui);
                        self.counters.prefetch_wasted += 1;
                        return Some(WorkOutcome::Drop {
                            unit,
                            cost: sw.queue_handoff_ns + sw.punch_hole_ns,
                        });
                    }
                    self.cancel_intents(unit);
                    self.counters.conflated_ops += 1;
                }
                UnitState::SwappingIn | UnitState::SwappingOut => {
                    // In-flight: the completion handler re-queues the
                    // unit if intents remain (conflation).
                    self.counters.conflated_ops += 1;
                }
            }
            let _ = now;
            let _ = class;
        }
    }

    fn cancel_intents(&mut self, unit: UnitId) {
        let ui = unit as usize;
        let span = self.span_units(unit);
        if self.want_out.get(ui) {
            self.want_out.clear(ui);
            self.planned_out = self.planned_out.saturating_sub(span);
            self.tier_hint[ui] = 0;
        }
        if self.prefetch_intent.get(ui) {
            self.prefetch_intent.clear(ui);
            self.planned_in = self.planned_in.saturating_sub(span);
        }
        // A fault whose unit became resident: its planned_in is settled
        // by the waiter wake path instead.
    }

    /// Default clock-style victim scan used when the limit reclaimer
    /// abstains: oldest last_touch among resident, unlocked units.
    pub fn clock_victim(&mut self, now: Time) -> Option<UnitId> {
        let n = self.states.len();
        let mut best: Option<(Time, UnitId)> = None;
        let mut scanned = 0;
        let mut hand = self.clock_hand;
        while scanned < n {
            let u = hand as u64;
            hand = (hand + 1) % n;
            scanned += 1;
            if self.states[u as usize] == UnitState::Resident
                && !self.want_out.get(u as usize)
                && !self.locks.is_locked(u)
            {
                let t = self.last_touch[u as usize];
                if t + 1_000_000 < now {
                    // Cold enough: take it and remember the hand.
                    self.clock_hand = hand;
                    return Some(u);
                }
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, u));
                }
            }
        }
        self.clock_hand = hand;
        best.map(|(_, u)| u)
    }

    /// Resident bytes.
    pub fn usage_bytes(&self) -> u64 {
        self.usage_units * self.unit_bytes
    }
}

/// Aggregate MM statistics snapshot.
#[derive(Debug, Clone)]
pub struct MmStats {
    pub usage_units: u64,
    pub limit_units: Option<u64>,
    pub pf_count: u64,
    pub queue_len: usize,
    pub counters: Counters,
}

/// The Memory Manager: engine core + mandatory modules + policies.
pub struct Mm {
    pub cfg: MmConfig,
    pub core: EngineCore,
    pub swapper: Swapper,
    pub zero_pool: ZeroPool,
    pub ring: VmcsRing,
    pub uffd: Uffd,
    pub walker: GvaWalker,
    pub policies: Vec<Box<dyn Policy>>,
    pub limit_reclaimer: Option<Box<dyn LimitReclaimer>>,
    sw: SwCost,
}

impl Mm {
    pub fn new(cfg: &MmConfig, units: u64, unit_bytes: u64, sw: &SwCost, zero_2m_ns: Time) -> Self {
        let limit_units = cfg.memory_limit.map(|b| b / unit_bytes);
        let mut core = EngineCore::new(units, unit_bytes, limit_units);
        core.set_granularity(cfg.granularity);
        Mm {
            cfg: cfg.clone(),
            core,
            swapper: Swapper::new(cfg.swapper_threads),
            zero_pool: ZeroPool::new(cfg.zero_pool, zero_2m_ns),
            ring: VmcsRing::new(cfg.vmcs_ring),
            uffd: Uffd::new(),
            walker: GvaWalker::new(),
            policies: vec![],
            limit_reclaimer: None,
            sw: sw.clone(),
        }
    }

    pub fn add_policy(&mut self, p: Box<dyn Policy>) {
        self.policies.push(p);
    }

    pub fn set_limit_reclaimer(&mut self, r: Box<dyn LimitReclaimer>) {
        self.limit_reclaimer = Some(r);
    }

    /// Change the memory limit at runtime (control-plane action).
    pub fn set_memory_limit(&mut self, vm: &Vm, bytes: Option<u64>, now: Time) {
        self.set_memory_limit_with_boost(vm, bytes, now, 0);
    }

    /// Limit change with an optional recovery boost: when the change is
    /// a *release* (raise or lift) and `boost_window > 0`, the engine's
    /// recovery window opens for that long, so prefetchers observing
    /// [`PolicyApi::recovery_mode`] can restore the working set harder.
    pub fn set_memory_limit_with_boost(
        &mut self,
        vm: &Vm,
        bytes: Option<u64>,
        now: Time,
        boost_window: Time,
    ) {
        let old = self.core.limit_units;
        let new = bytes.map(|b| b / self.core.unit_bytes);
        self.core.limit_units = new;
        let released = match (old, new) {
            (Some(_), None) => true,
            (Some(o), Some(n)) => n > o,
            _ => false,
        };
        if released && boost_window > 0 {
            // Open before LimitChanged dispatches, so policies already
            // see recovery_mode() while handling the release itself.
            self.core.recovery_until = now + boost_window;
        }
        self.dispatch_event(vm, &|now2| PolicyEvent::LimitChanged { old, new, now: now2 }, now);
        // Under a tightened limit, force reclamation down to the limit.
        if let Some(l) = new {
            while self.core.planned_usage() > l as i64 {
                if !self.force_reclaim_one(now) {
                    break;
                }
            }
        }
    }

    fn force_reclaim_one(&mut self, now: Time) -> bool {
        let victim = self
            .limit_reclaimer
            .as_mut()
            .and_then(|r| r.victim(&self.core, now))
            .filter(|&u| {
                self.core.states[u as usize] == UnitState::Resident
                    && !self.core.want_out.get(u as usize)
                    && !self.core.locks.is_locked(u)
            })
            .or_else(|| self.core.clock_victim(now));
        match victim {
            Some(u) => {
                self.core.counters.limit_forced_reclaims += 1;
                self.core.request_reclaim(u);
                true
            }
            None => false,
        }
    }

    /// Record a touch (fault, swap-in completion or scan hit): updates
    /// the shared `last_touch` LRU info and notifies the limit
    /// reclaimer's incremental recency structure — O(1), no event
    /// construction, no hash lookup.
    pub fn note_touch(&mut self, unit: UnitId, now: Time) {
        self.core.last_touch[unit as usize] = now;
        if let Some(r) = self.limit_reclaimer.as_mut() {
            r.touch(unit, now);
        }
    }

    /// Deliver one UFFD fault event to the engine (paper §4.1 steps 5-6).
    /// Returns true if the fault needs swapper work (the machine should
    /// dispatch workers).
    pub fn on_fault(&mut self, vm: &Vm, ev: &UffdEvent, now: Time) -> bool {
        let unit = ev.fault.unit;
        let ui = unit as usize;
        self.core.pf_count += 1;
        self.note_touch(unit, now);

        let ctx = self.ring.take(ev.fault.gpa_frame);
        let state = self.core.states[ui];
        let major = state == UnitState::Swapped;
        if major {
            self.core.counters.faults_major += 1;
        } else {
            self.core.counters.faults_minor += 1;
        }
        if self.core.prefetched_untouched.get(ui) {
            self.core.prefetched_untouched.clear(ui);
            // A prefetch is *timely* only if the access follows soon
            // after staging — a hit a full pass later is luck, not
            // prediction (the paper's HVA prefetcher scores <2%).
            if now.saturating_sub(self.core.staged_at[ui]) < 50_000_000 {
                self.core.counters.prefetch_timely += 1;
            }
        }

        // Notify policies (async in the real system; accounted off the
        // critical path here as well).
        self.dispatch_event(
            vm,
            &move |n| PolicyEvent::PageFault { unit, ctx, major, now: n },
            now,
        );

        let needs_work = match self.core.states[ui] {
            UnitState::Resident => {
                // Raced with a completing map: nothing to do.
                false
            }
            UnitState::Staged => {
                // Prefetched content already in DRAM: minor fault, map
                // only (usage already accounted at stage time).
                self.core.waiters.push(unit, ev.fault.vcpu);
                self.core.queue.push(unit, QueueClass::Fault);
                true
            }
            UnitState::SwappingIn => {
                self.core.waiters.push(unit, ev.fault.vcpu);
                false
            }
            UnitState::SwappingOut => {
                // Fault on a page being swapped out: queue it; the
                // swap-out completion re-queues a swap-in (conflation).
                let first = !self.core.waiters.has(unit);
                self.core.waiters.push(unit, ev.fault.vcpu);
                if first {
                    self.core.planned_in += self.core.span_units(unit);
                }
                self.core.queue.push(unit, QueueClass::Fault);
                true
            }
            UnitState::Untouched | UnitState::Swapped => {
                let first = !self.core.waiters.has(unit);
                self.core.waiters.push(unit, ev.fault.vcpu);
                if first {
                    if self.core.prefetch_intent.get(ui) {
                        // A queued prefetch is upgraded into this fault;
                        // its swap-in is already planned.
                        self.core.prefetch_intent.clear(ui);
                    } else {
                        self.core.planned_in += self.core.span_units(unit);
                    }
                    // Limit check (paper §4.1 step 6): forced reclamation.
                    // Like kswapd, reclaim down to a low watermark below
                    // the limit so prefetchers have headroom (§6.6 works
                    // under a memory limit because of this slack).
                    if self.core.over_limit() {
                        let limit = self.core.limit_units.unwrap_or(0) as i64;
                        let slack = (limit / 64).clamp(2, 1024);
                        let mut guard = 0;
                        while self.core.planned_usage() > limit - slack && guard < 4096 {
                            if !self.force_reclaim_one(now) {
                                break;
                            }
                            guard += 1;
                        }
                    }
                }
                self.core.queue.push(unit, QueueClass::Fault);
                true
            }
        };
        needs_work
    }

    /// Swap-in I/O (or zero-map) finished: map the unit, wake waiters.
    /// `from_disk` distinguishes a real swap-in (leaves a clean disk
    /// copy behind, enabling write-back elision) from a zero-page map.
    /// Returns (map_cost, woken vcpus).
    pub fn finish_swapin(&mut self, vm: &mut Vm, unit: UnitId, from_disk: bool, now: Time) -> (Time, Vec<usize>) {
        let ui = unit as usize;
        debug_assert_eq!(self.core.states[ui], UnitState::SwappingIn);
        let span = self.core.span_units(unit);
        self.core.usage_units += span;
        self.core.planned_in = self.core.planned_in.saturating_sub(span);
        if from_disk {
            self.core.clean_on_disk.set(ui); // disk copy valid until dirtied
        } else {
            self.core.clean_on_disk.clear(ui);
        }
        self.core.counters.swapin_ops += 1;
        self.core.counters.swapin_bytes += self.core.unit_bytes * span;
        if span > 1 {
            self.core.counters.huge_swapins += 1;
        }
        self.note_touch(unit, now);
        let wake = self.core.waiters.take(unit);
        if wake.is_empty() && self.core.prefetched_untouched.get(ui) {
            // Pure prefetch: stage without mapping (the next fault turns
            // minor — no I/O on its path; paper §6.6/§6.8 behaviour).
            self.core.states[ui] = UnitState::Staged;
            self.core.staged_at[ui] = now;
            self.dispatch_event_vm(vm, &|n| PolicyEvent::SwapIn { unit, now: n }, now);
            return (0, wake);
        }
        self.core.states[ui] = UnitState::Resident;
        vm.ept.map(unit);
        vm.ept.clear_dirty(unit);
        if self.core.want_out.get(ui) && !self.core.queue.contains(unit) {
            // A reclaim raced this swap-in: re-queue it.
            self.core.queue.push(unit, QueueClass::Reclaim);
        }
        let cost = Uffd::continue_cost(&self.sw, self.core.huge_unit(unit));
        self.dispatch_event_vm(vm, &|n| PolicyEvent::SwapIn { unit, now: n }, now);
        (cost, wake)
    }

    /// A fault hit a staged (prefetched) unit: map it without I/O.
    /// Returns (map_cost, woken vcpus).
    pub fn finish_map_staged(&mut self, vm: &mut Vm, unit: UnitId, now: Time) -> (Time, Vec<usize>) {
        let ui = unit as usize;
        debug_assert_eq!(self.core.states[ui], UnitState::SwappingIn);
        self.core.states[ui] = UnitState::Resident;
        self.note_touch(unit, now);
        vm.ept.map(unit);
        vm.ept.clear_dirty(unit);
        let wake = self.core.waiters.take(unit);
        let cost = Uffd::continue_cost(&self.sw, self.core.huge_unit(unit));
        (cost, wake)
    }

    /// Swap-out pickup already unmapped the unit; this is the I/O-done +
    /// punch-hole step. Returns true if a fault arrived meanwhile and the
    /// machine should dispatch workers again (conflated swap-in).
    pub fn finish_swapout(&mut self, vm: &mut Vm, unit: UnitId, dirty_written: bool, now: Time) -> bool {
        let ui = unit as usize;
        debug_assert_eq!(self.core.states[ui], UnitState::SwappingOut);
        let span = self.core.span_units(unit);
        self.core.states[ui] = UnitState::Swapped;
        self.core.usage_units = self.core.usage_units.saturating_sub(span);
        self.core.planned_out = self.core.planned_out.saturating_sub(span);
        self.core.clean_on_disk.set(ui);
        self.core.counters.swapout_ops += 1;
        if dirty_written {
            self.core.counters.swapout_bytes += self.core.unit_bytes * span;
        }
        if span > 1 {
            self.core.counters.huge_swapouts += 1;
        }
        self.dispatch_event_vm(vm, &|n| PolicyEvent::SwapOut { unit, now: n }, now);
        // A vCPU may have faulted on this unit while the write was in
        // flight; its entry may have been conflated away while the unit
        // was in flight, so re-queue it for a swap-in.
        let ui2 = unit as usize;
        if self.core.waiters.has(unit) {
            if !self.core.queue.contains(unit) {
                self.core.queue.push(unit, QueueClass::Fault);
            }
            true
        } else if self.core.prefetch_intent.get(ui2) {
            if !self.core.queue.contains(unit) {
                self.core.queue.push(unit, QueueClass::Prefetch);
            }
            true
        } else {
            false
        }
    }

    /// Unmap step of a swap-out (executed at pickup time).
    pub fn unmap_for_swapout(&mut self, vm: &mut Vm, unit: UnitId) {
        let dirty = vm.ept.dirty(unit);
        if dirty {
            self.core.clean_on_disk.clear(unit as usize);
        }
        vm.ept.unmap(unit);
    }

    /// Record guest writes (dirty tracking for write-back elision): the
    /// machine calls this before unmap decisions when the EPT D bit is
    /// set.
    pub fn note_dirty(&mut self, unit: UnitId) {
        self.core.clean_on_disk.clear(unit as usize);
    }

    /// Deliver a scan bitmap to policies + update shared LRU info.
    pub fn on_scan(&mut self, vm: &Vm, bitmap: &Bitmap, now: Time) {
        // Ascending-unit order matters: equal-timestamp scan hits enter
        // the reclaimer's recency structure in unit order, matching the
        // (last_touch, unit) sort the rank-based reclaimers use.
        for u in bitmap.iter_ones() {
            self.note_touch(u as UnitId, now);
            if self.core.prefetched_untouched.get(u) {
                self.core.prefetched_untouched.clear(u);
                self.core.counters.prefetch_timely += 1;
            }
        }
        let mut policies = std::mem::take(&mut self.policies);
        let mut api = PolicyApi {
            core: &mut self.core,
            vm,
            walker: &mut self.walker,
            now,
        };
        let ev = PolicyEvent::ScanBitmap { bitmap, now };
        for p in &mut policies {
            p.on_event(&ev, &mut api);
        }
        if let Some(r) = self.limit_reclaimer.as_mut() {
            r.note(&ev);
        }
        self.policies = policies;
    }

    /// Policy timer tick.
    pub fn on_timer(&mut self, vm: &Vm, now: Time) {
        self.dispatch_event(vm, &|n| PolicyEvent::Timer { now: n }, now);
    }

    fn dispatch_event(
        &mut self,
        vm: &Vm,
        make: &dyn Fn(Time) -> PolicyEvent<'static>,
        now: Time,
    ) {
        let mut policies = std::mem::take(&mut self.policies);
        {
            let mut api = PolicyApi {
                core: &mut self.core,
                vm,
                walker: &mut self.walker,
                now,
            };
            let ev = make(now);
            for p in &mut policies {
                p.on_event(&ev, &mut api);
            }
            if let Some(r) = self.limit_reclaimer.as_mut() {
                r.note(&ev);
            }
        }
        self.policies = policies;
    }

    fn dispatch_event_vm(
        &mut self,
        vm: &Vm,
        make: &dyn Fn(Time) -> PolicyEvent<'static>,
        now: Time,
    ) {
        self.dispatch_event(vm, make, now)
    }

    /// Machine-facing wrapper for [`Mm::finish_map_staged`].
    pub fn core_map_staged(&mut self, vm: &mut Vm, unit: UnitId, now: Time) -> (Time, Vec<usize>) {
        self.finish_map_staged(vm, unit, now)
    }

    /// Next work item for an idle worker.
    pub fn pick_work(&mut self, now: Time) -> Option<WorkOutcome> {
        let sw = self.sw.clone();
        self.core.pick_work(&mut self.zero_pool, &sw, now)
    }

    /// Apply region-granularity requests queued by policies via
    /// [`PolicyApi::split_region`] / [`PolicyApi::collapse_region`].
    /// Returns the region ids actually applied (validation may refuse a
    /// request whose base is in flight) so the machine can mirror the
    /// change into the VM's EPT and discard stale backend receipts.
    pub fn drain_region_ops(&mut self) -> (Vec<u64>, Vec<u64>) {
        let split_req = std::mem::take(&mut self.core.pending_splits);
        let collapse_req = std::mem::take(&mut self.core.pending_collapses);
        let mut splits = Vec::new();
        let mut collapses = Vec::new();
        for r in split_req {
            if self.core.split_region(r) {
                // Fanned-out resident units enter the limit reclaimer's
                // recency structure at the base's timestamp so they are
                // individually reclaimable right away.
                let base = self.core.region_base(r);
                let span = self.core.region_span(r);
                for u in base..base + span {
                    if self.core.states[u as usize] == UnitState::Resident {
                        let t = self.core.last_touch[u as usize];
                        if let Some(rec) = self.limit_reclaimer.as_mut() {
                            rec.touch(u, t);
                        }
                    }
                }
                splits.push(r);
            }
        }
        for r in collapse_req {
            if self.core.collapse_region(r) {
                let base = self.core.region_base(r);
                let t = self.core.last_touch[base as usize];
                if let Some(rec) = self.limit_reclaimer.as_mut() {
                    rec.touch(base, t);
                }
                collapses.push(r);
            }
        }
        (splits, collapses)
    }

    /// Take a pending pool-admission retune requested by a policy
    /// through [`PolicyApi::set_pool_admission`].
    pub fn take_pool_admission(&mut self) -> Option<u8> {
        self.core.pending_admission.take()
    }

    pub fn stats(&self) -> MmStats {
        MmStats {
            usage_units: self.core.usage_units,
            limit_units: self.core.limit_units,
            pf_count: self.core.pf_count,
            queue_len: self.core.queue.len(),
            counters: self.core.counters.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    fn mm(units: u64, limit: Option<u64>) -> Mm {
        let mut cfg = MmConfig::default();
        cfg.memory_limit = limit.map(|u| u * 4096);
        Mm::new(&cfg, units, 4096, &SwCost::default(), HwConfig::default().zero_2m_ns)
    }

    fn vm_for(units: u64) -> (Vm, crate::sim::Rng) {
        let cfg = crate::config::VmConfig {
            frames: units,
            vcpus: 1,
            page_size: crate::types::PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = crate::sim::Rng::new(1);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (vm, rng)
    }

    fn fault_ev(unit: UnitId) -> UffdEvent {
        UffdEvent {
            fault: crate::vm::FaultInfo {
                unit,
                gpa_frame: unit,
                gva_page: unit,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        }
    }

    #[test]
    fn first_touch_maps_zero_page() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        assert!(m.on_fault(&vm, &fault_ev(3), 100));
        match m.pick_work(100) {
            Some(WorkOutcome::MapZero { unit: 3, .. }) => {}
            other => panic!("{other:?}"),
        }
        let (_, wake) = m.finish_swapin(&mut vm, 3, false, 200);
        assert_eq!(wake, vec![0]);
        assert_eq!(m.core.usage_units, 1);
        assert_eq!(m.core.states[3], UnitState::Resident);
        assert!(vm.ept.present(3));
    }

    #[test]
    fn fault_on_swapped_unit_is_major_swapin() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        // Bring in, then reclaim, then fault again.
        m.on_fault(&vm, &fault_ev(1), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 1, false, 1);
        m.core.request_reclaim(1);
        match m.pick_work(2) {
            // First swap-out of a freshly zero-mapped page must write.
            Some(WorkOutcome::SwapOutWrite { unit: 1, .. }) => {}
            other => panic!("{other:?}"),
        }
        m.unmap_for_swapout(&mut vm, 1);
        m.finish_swapout(&mut vm, 1, true, 3);
        assert_eq!(m.core.states[1], UnitState::Swapped);
        assert_eq!(m.core.usage_units, 0);

        assert!(m.on_fault(&vm, &fault_ev(1), 10));
        assert_eq!(m.core.counters.faults_major, 1);
        match m.pick_work(10) {
            Some(WorkOutcome::SwapIn { unit: 1, bytes: 4096 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn reclaim_to_routes_tier_hint_and_consumes_it() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        m.on_fault(&vm, &fault_ev(1), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 1, false, 1);
        m.core.request_reclaim_to(1, TierHint::Nvme);
        match m.pick_work(2) {
            Some(WorkOutcome::SwapOutWrite { unit: 1, hint: TierHint::Nvme, .. }) => {}
            other => panic!("{other:?}"),
        }
        m.unmap_for_swapout(&mut vm, 1);
        m.finish_swapout(&mut vm, 1, true, 3);
        // Machine mirrors the backend receipt into the tier map.
        m.core.set_backend_tier(1, Some(SwapTier::Nvme));
        assert_eq!(m.core.swap_tier(1), Some(SwapTier::Nvme));
        // Hint was consumed: the next reclaim defaults to Auto.
        m.on_fault(&vm, &fault_ev(1), 4);
        m.pick_work(4).unwrap();
        m.finish_swapin(&mut vm, 1, false, 5);
        m.core.request_reclaim(1);
        match m.pick_work(6) {
            Some(WorkOutcome::SwapOutWrite { unit: 1, hint: TierHint::Auto, .. }) => {}
            Some(WorkOutcome::Drop { .. }) => {} // clean elision also fine
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn resync_backend_tiers_overwrites_stale_mirror() {
        let mut m = mm(4, None);
        m.core.set_backend_tier(0, Some(SwapTier::Pool));
        m.core.set_backend_tier(1, Some(SwapTier::Nvme));
        // Authority: unit 0 was demoted on import, unit 2 appeared,
        // unit 1 vanished.
        m.core.resync_backend_tiers(|u| match u {
            0 => Some(SwapTier::Nvme),
            2 => Some(SwapTier::Pool),
            _ => None,
        });
        assert_eq!(m.core.swap_tier(0), Some(SwapTier::Nvme));
        assert_eq!(m.core.swap_tier(1), None);
        assert_eq!(m.core.swap_tier(2), Some(SwapTier::Pool));
        assert_eq!(m.core.swap_tier(3), None);
    }

    #[test]
    fn clean_unit_swapout_skips_write() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        // Fault in from disk (clean copy exists after swap-in).
        m.core.states[2] = UnitState::Swapped;
        m.on_fault(&vm, &fault_ev(2), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 2, true, 1);
        // Not dirtied: reclaim should be a Drop (no write I/O).
        vm.ept.clear_dirty(2);
        m.core.request_reclaim(2);
        match m.pick_work(2) {
            Some(WorkOutcome::Drop { unit: 2, .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn conflation_fault_cancels_queued_reclaim() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        m.on_fault(&vm, &fault_ev(4), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 4, false, 1);
        // Queue a reclaim, then fault the same unit before pickup: the
        // reclaim entry must resolve to a no-op... but since the unit is
        // resident the fault itself is also a no-op. Simulate the race:
        m.core.request_reclaim(4);
        // Fault arrives (unit still resident — minor, no work).
        assert!(!m.on_fault(&vm, &fault_ev(4), 2));
        // Reclaim still queued; it fires (unit is resident + wanted out).
        assert!(m.pick_work(3).is_some());
    }

    #[test]
    fn prefetch_dropped_at_limit() {
        let mut m = mm(8, Some(2));
        let (mut vm, _) = vm_for(8);
        for u in 0..2 {
            m.on_fault(&vm, &fault_ev(u), 0);
            m.pick_work(0).unwrap();
            m.finish_swapin(&mut vm, u, false, 1);
        }
        m.core.states[5] = UnitState::Swapped;
        m.core.request_prefetch(5);
        assert_eq!(m.core.counters.prefetch_issued, 0);
        assert!(m.core.queue.is_empty());
    }

    #[test]
    fn fault_at_limit_forces_reclaim() {
        let mut m = mm(8, Some(2));
        let (mut vm, _) = vm_for(8);
        for u in 0..2 {
            m.on_fault(&vm, &fault_ev(u), u);
            m.pick_work(0).unwrap();
            m.finish_swapin(&mut vm, u, false, 1);
        }
        assert!(m.on_fault(&vm, &fault_ev(7), 10));
        assert!(m.core.counters.limit_forced_reclaims >= 1);
        // Queue must hold a reclaim to pair with the incoming swap-in.
        assert!(m.core.queue.pending_reclaims() >= 1);
        assert!(m.core.planned_usage() <= 2);
    }

    #[test]
    fn limit_decrease_reclaims_down() {
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        for u in 0..4 {
            m.on_fault(&vm, &fault_ev(u), u);
            m.pick_work(0).unwrap();
            m.finish_swapin(&mut vm, u, false, 1);
        }
        assert_eq!(m.core.usage_units, 4);
        m.set_memory_limit(&vm, Some(2 * 4096), 100);
        assert!(m.core.planned_usage() <= 2);
        assert_eq!(m.core.queue.pending_reclaims(), 2);
    }

    #[test]
    fn waiters_accumulate_on_inflight_unit() {
        let mut m = mm(8, None);
        let (_vm2, _) = vm_for(8);
        let vm = _vm2;
        let mut ev0 = fault_ev(6);
        ev0.fault.vcpu = 0;
        let mut ev1 = fault_ev(6);
        ev1.fault.vcpu = 1;
        assert!(m.on_fault(&vm, &ev0, 0));
        m.pick_work(0).unwrap(); // now SwappingIn
        assert!(!m.on_fault(&vm, &ev1, 1)); // piggybacks
        assert_eq!(m.core.waiters.get(&6).unwrap().len(), 2);
    }

    #[test]
    fn waiter_map_push_take_counts() {
        let mut w = WaiterMap::new(8);
        assert!(w.is_empty());
        w.push(3, 0);
        w.push(3, 1);
        w.push(5, 2);
        assert!(w.has(3) && w.has(5) && !w.has(4));
        assert_eq!(w.waiting_units(), 2);
        assert_eq!(w.get(&3).unwrap().len(), 2);
        assert_eq!(w.take(3), vec![0, 1]);
        assert!(!w.has(3));
        assert_eq!(w.take(3), Vec::<usize>::new());
        assert_eq!(w.waiting_units(), 1);
        assert_eq!(w.take(5), vec![2]);
        assert!(w.is_empty());
        // Debug prints only non-empty entries.
        w.push(2, 7);
        assert_eq!(format!("{w:?}"), "{2: [7]}");
    }

    #[test]
    fn touches_flow_to_limit_reclaimer() {
        use std::sync::{Arc, Mutex};

        // Arc<Mutex<_>>, not Rc<RefCell<_>>: `LimitReclaimer: Send`.
        struct Recorder(Arc<Mutex<Vec<(UnitId, Time)>>>);
        impl LimitReclaimer for Recorder {
            fn name(&self) -> &'static str {
                "recorder"
            }
            fn note(&mut self, _ev: &PolicyEvent) {}
            fn touch(&mut self, unit: UnitId, now: Time) {
                self.0.lock().unwrap().push((unit, now));
            }
            fn victim(&mut self, _core: &EngineCore, _now: Time) -> Option<UnitId> {
                None
            }
        }

        let touches = Arc::new(Mutex::new(vec![]));
        let mut m = mm(8, None);
        let (mut vm, _) = vm_for(8);
        m.set_limit_reclaimer(Box::new(Recorder(touches.clone())));
        // Fault -> touch; swap-in completion -> touch; scan hit -> touch.
        m.on_fault(&vm, &fault_ev(3), 100);
        m.pick_work(100).unwrap();
        m.finish_swapin(&mut vm, 3, false, 200);
        let mut bm = Bitmap::new(8);
        bm.set(1);
        bm.set(3);
        m.on_scan(&vm, &bm, 300);
        assert_eq!(
            touches.lock().unwrap().as_slice(),
            &[(3, 100), (3, 200), (1, 300), (3, 300)]
        );
        assert_eq!(m.core.last_touch[3], 300);
    }

    fn mm_mode(units: u64, limit: Option<u64>, mode: crate::types::GranularityMode) -> Mm {
        let mut cfg = MmConfig::default();
        cfg.memory_limit = limit.map(|u| u * 4096);
        cfg.granularity = mode;
        Mm::new(&cfg, units, 4096, &SwCost::default(), HwConfig::default().zero_2m_ns)
    }

    #[test]
    fn granularity_huge_fault_is_one_op_with_region_bytes() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let mut m = mm_mode(2 * REGION_UNITS, None, GranularityMode::Huge);
        let (mut vm, _) = vm_for(2 * REGION_UNITS);
        assert_eq!(m.core.span_units(0), REGION_UNITS);
        // First touch: one MapZero covering the whole region.
        assert!(m.on_fault(&vm, &fault_ev(0), 0));
        assert_eq!(m.core.planned_in, REGION_UNITS);
        match m.pick_work(0) {
            Some(WorkOutcome::MapZero { unit: 0, .. }) => {}
            other => panic!("{other:?}"),
        }
        m.finish_swapin(&mut vm, 0, false, 1);
        assert_eq!(m.core.usage_units, REGION_UNITS);
        assert_eq!(m.core.planned_in, 0);
        assert_eq!(m.core.counters.huge_swapins, 1);
        assert_eq!(m.core.counters.swapin_bytes, REGION_UNITS * 4096);
        // One reclaim moves the whole 2MB in one write.
        m.core.request_reclaim(0);
        assert_eq!(m.core.planned_out, REGION_UNITS);
        match m.pick_work(2) {
            Some(WorkOutcome::SwapOutWrite { unit: 0, bytes, .. }) => {
                assert_eq!(bytes, REGION_UNITS * 4096);
            }
            other => panic!("{other:?}"),
        }
        m.finish_swapout(&mut vm, 0, true, 3);
        assert_eq!(m.core.usage_units, 0);
        assert_eq!(m.core.counters.huge_swapouts, 1);
        // Refault: one major fault, one 2MB swap-in.
        assert!(m.on_fault(&vm, &fault_ev(0), 4));
        assert_eq!(m.core.counters.faults_major, 1);
        match m.pick_work(4) {
            Some(WorkOutcome::SwapIn { unit: 0, bytes }) => {
                assert_eq!(bytes, REGION_UNITS * 4096);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn granularity_split_fans_resident_and_collapse_folds_back() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let mut m = mm_mode(2 * REGION_UNITS, None, GranularityMode::Auto);
        let (mut vm, _) = vm_for(2 * REGION_UNITS);
        m.on_fault(&vm, &fault_ev(0), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 0, false, 1);
        assert_eq!(m.core.usage_units, REGION_UNITS);
        // Split: every unit of the span becomes individually resident,
        // accounting unchanged.
        m.core.pending_splits.push(0);
        let (splits, collapses) = m.drain_region_ops();
        assert_eq!(splits, vec![0]);
        assert!(collapses.is_empty());
        assert!(!m.core.region_huge(0));
        assert_eq!(m.core.span_units(0), 1);
        for u in 0..REGION_UNITS {
            assert_eq!(m.core.states[u as usize], UnitState::Resident);
        }
        assert_eq!(m.core.usage_units, REGION_UNITS);
        // Now a single-unit reclaim works at 4k granularity.
        m.core.request_reclaim(7);
        assert_eq!(m.core.planned_out, 1);
        m.pick_work(2).unwrap();
        m.finish_swapout(&mut vm, 7, true, 3);
        assert_eq!(m.core.usage_units, REGION_UNITS - 1);
        // Collapse refused while the span is not uniformly resident.
        m.core.pending_collapses.push(0);
        assert!(m.drain_region_ops().1.is_empty());
        // Bring unit 7 back; collapse then folds the span to the base.
        m.on_fault(&vm, &fault_ev(7), 4);
        m.pick_work(4).unwrap();
        m.finish_swapin(&mut vm, 7, true, 5);
        m.core.pending_collapses.push(0);
        assert_eq!(m.drain_region_ops().1, vec![0]);
        assert!(m.core.region_huge(0));
        assert_eq!(m.core.states[0], UnitState::Resident);
        for u in 1..REGION_UNITS {
            assert_eq!(m.core.states[u as usize], UnitState::Untouched);
        }
        assert_eq!(m.core.usage_units, REGION_UNITS);
        assert_eq!(m.core.counters.region_splits, 1);
        assert_eq!(m.core.counters.region_collapses, 1);
    }

    #[test]
    fn granularity_split_refused_for_swapped_base() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let mut m = mm_mode(REGION_UNITS, None, GranularityMode::Huge);
        let (mut vm, _) = vm_for(REGION_UNITS);
        m.on_fault(&vm, &fault_ev(0), 0);
        m.pick_work(0).unwrap();
        m.finish_swapin(&mut vm, 0, false, 1);
        m.core.request_reclaim(0);
        m.pick_work(2).unwrap();
        m.finish_swapout(&mut vm, 0, true, 3);
        assert_eq!(m.core.states[0], UnitState::Swapped);
        // A swapped base never splits: the 2MB backing-store image
        // would otherwise be torn into per-4k reads.
        m.core.pending_splits.push(0);
        assert!(m.drain_region_ops().0.is_empty());
        assert!(m.core.region_huge(0));
    }

    #[test]
    fn granularity_splitall_is_structurally_fixed() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let units = 2 * REGION_UNITS;
        let mut fixed = mm_mode(units, None, GranularityMode::Fixed);
        let mut oracle = mm_mode(units, None, GranularityMode::SplitAll);
        assert_eq!(oracle.core.counters.region_splits, 2);
        let (mut vf, _) = vm_for(units);
        let (mut vo, _) = vm_for(units);
        for (m, vm) in [(&mut fixed, &mut vf), (&mut oracle, &mut vo)] {
            for u in [0u64, 3, 700] {
                m.on_fault(vm, &fault_ev(u), u);
                m.pick_work(u).unwrap();
                m.finish_swapin(vm, u, false, u + 1);
            }
            m.core.request_reclaim(3);
            m.pick_work(10).unwrap();
            m.finish_swapout(vm, 3, true, 11);
        }
        assert_eq!(fixed.core.usage_units, oracle.core.usage_units);
        assert_eq!(fixed.core.states, oracle.core.states);
        let (cf, co) = (&fixed.core.counters, &oracle.core.counters);
        assert_eq!(cf.faults_major, co.faults_major);
        assert_eq!(cf.swapin_bytes, co.swapin_bytes);
        assert_eq!(cf.swapout_bytes, co.swapout_bytes);
        assert_eq!(co.huge_swapins, 0);
        assert_eq!(co.huge_swapouts, 0);
    }

    #[test]
    fn granularity_strict_2m_vm_forces_fixed() {
        use crate::types::GranularityMode;
        let cfg = MmConfig { granularity: GranularityMode::Huge, ..Default::default() };
        let zero_2m = HwConfig::default().zero_2m_ns;
        let m = Mm::new(&cfg, 64, 2 * 1024 * 1024, &SwCost::default(), zero_2m);
        assert_eq!(m.core.granularity_mode, GranularityMode::Fixed);
        assert_eq!(m.core.span_units(0), 1);
        assert!(m.core.huge_unit(0)); // the unit itself is 2MB
    }

    #[test]
    fn granularity_pool_admission_handoff() {
        let mut m = mm(8, None);
        assert_eq!(m.take_pool_admission(), None);
        m.core.pending_admission = Some(80);
        assert_eq!(m.take_pool_admission(), Some(80));
        assert_eq!(m.take_pool_admission(), None);
    }
}
