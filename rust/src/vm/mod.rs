//! The simulated VM: guest processes + nested translation (guest PT ->
//! EPT) + per-vCPU TLBs. This is the component that *raises* EPT
//! violations; everything above it (UFFD, MM, policies) is the system
//! under test.

use crate::config::{HwConfig, SwCost, VmConfig};
use crate::guest::{GuestAllocator, GuestProcess};
use crate::hw::{Ept, Tlb, WalkModel};
use crate::sim::Rng;
use crate::types::{PageSize, Time, UnitId, REGION_UNITS};

/// Outcome of one guest memory access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AccessResult {
    /// Completed in `cost` ns of guest time.
    Hit { cost: Time },
    /// EPT violation: the vCPU is stalled until the unit is mapped.
    /// `cost` is guest time consumed before the exit.
    Fault(FaultInfo),
}

/// Everything the hypervisor knows at EPT-violation time. The VMCS
/// fields (cr3/ip/gva) flow to policies through the introspection ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultInfo {
    pub unit: UnitId,
    pub gpa_frame: u64,
    pub gva_page: u64,
    pub cr3: u64,
    pub ip: u64,
    pub write: bool,
    pub vcpu: usize,
    pub pre_cost: Time,
}

#[derive(Debug)]
pub struct Vm {
    pub cfg: VmConfig,
    pub allocator: GuestAllocator,
    pub processes: Vec<GuestProcess>,
    pub ept: Ept,
    /// Per-vCPU TLBs: [ (4k, 2M) ].
    tlbs: Vec<(Tlb, Tlb)>,
    pub walk: WalkModel,
    guest_alloc_ns: Time,
    mem_ns: Time,
    unit_frames: u64,
    thp_coverage: f64,
    /// Host-side THP map over 2MB GPA regions (kernel-baseline mode: the
    /// kernel starts with THP everywhere and splits on swap-out, which
    /// permanently shrinks TLB reach). `None` = derive from `unit_frames`
    /// (strict mode).
    host_thp: Option<crate::types::Bitmap>,
    /// Guest first-touch (minor fault) count.
    pub guest_minor_faults: u64,
}

impl Vm {
    pub fn new(cfg: &VmConfig, hw: &HwConfig, sw: &SwCost, rng: &mut Rng) -> Self {
        let mut allocator = GuestAllocator::new(cfg.frames);
        allocator.age(cfg.scramble, rng);
        let tlbs = (0..cfg.vcpus)
            .map(|_| (Tlb::new(hw.tlb_entries_4k), Tlb::new(hw.tlb_entries_2m)))
            .collect();
        Vm {
            allocator,
            processes: vec![],
            ept: Ept::new(cfg.units()),
            tlbs,
            walk: WalkModel::new(hw),
            guest_alloc_ns: sw.guest_alloc_ns,
            mem_ns: hw.mem_ns,
            unit_frames: cfg.page_size.unit_frames(),
            thp_coverage: cfg.guest_thp_coverage,
            host_thp: None,
            guest_minor_faults: 0,
            cfg: cfg.clone(),
        }
    }

    /// Kernel-baseline mode: host memory is THP-backed per 2MB region
    /// until the kernel splits it on swap-out.
    pub fn enable_host_thp(&mut self) {
        let regions = self.cfg.frames.div_ceil(512) as usize;
        let mut bm = crate::types::Bitmap::new(regions);
        for r in 0..regions {
            bm.set(r);
        }
        self.host_thp = Some(bm);
    }

    pub fn host_thp_mut(&mut self) -> Option<&mut crate::types::Bitmap> {
        self.host_thp.as_mut()
    }

    /// Ensure a guest mapping exists for `gva_page` (warm-start helper);
    /// returns the backing frame.
    pub fn ensure_mapped(&mut self, proc_idx: usize, gva_page: u64) -> Option<u32> {
        let proc = &mut self.processes[proc_idx];
        match proc.pt.walk(gva_page) {
            Some(f) => Some(f),
            None => proc.pt.map_on_fault(gva_page, &mut self.allocator),
        }
    }

    /// Spawn a guest process with a `gva_pages`-page address space.
    pub fn spawn_process(&mut self, gva_pages: u64) -> usize {
        let idx = self.processes.len();
        self.processes.push(GuestProcess::new(idx, gva_pages));
        idx
    }

    pub fn unit_frames(&self) -> u64 {
        self.unit_frames
    }

    pub fn units(&self) -> u64 {
        self.ept.units()
    }

    /// Whether the guest backs this gva region with a THP (deterministic
    /// pseudo-random per 2MB region, with `thp_coverage` probability).
    #[inline]
    fn guest_thp(&self, proc_idx: usize, gva_page: u64) -> bool {
        let region = gva_page / 512;
        let h = (region ^ (proc_idx as u64) << 40)
            .wrapping_mul(0x9E3779B97F4A7C15)
            >> 40;
        (h as f64 / (1u64 << 24) as f64) < self.thp_coverage
    }

    /// Execute one guest memory access on `vcpu` at virtual time `now`.
    ///
    /// Models, in order: guest demand paging (first touch), TLB lookup,
    /// nested page walk on miss, EPT presence check (violation -> fault).
    pub fn access(
        &mut self,
        vcpu: usize,
        proc_idx: usize,
        gva_page: u64,
        write: bool,
        ip: u64,
        now: Time,
        rng: &mut Rng,
    ) -> AccessResult {
        let mut cost = 0;
        let proc = &mut self.processes[proc_idx];

        // Guest-side translation (+ demand paging on first touch).
        let frame = match proc.pt.walk(gva_page) {
            Some(f) => f,
            None => {
                self.guest_minor_faults += 1;
                cost += self.guest_alloc_ns;
                match proc.pt.map_on_fault(gva_page, &mut self.allocator) {
                    Some(f) => f,
                    // Guest OOM: model as access to frame 0 (guest would
                    // reclaim; irrelevant to host swap behaviour).
                    None => 0,
                }
            }
        };
        proc.pt.touch(gva_page);
        let asid = proc.asid;
        let cr3 = proc.cr3;

        let gpa_frame = frame as u64;
        // A unit inside a 2MB-backed granularity region canonicalizes to
        // the region base: the whole region faults/maps as one op.
        let unit = self.ept.canonical_unit(gpa_frame / self.unit_frames);

        // TLB: hugepage entries only where both host mode and the guest's
        // THP policy give a 2MB leaf on both levels. A huge granularity
        // region is host-side 2MB-backed exactly like strict-2MB mode.
        let host_huge = match &self.host_thp {
            Some(bm) => bm.get((gpa_frame / 512) as usize),
            None => self.unit_frames > 1 || self.ept.region_huge(unit / REGION_UNITS),
        };
        let huge_leaf = host_huge && self.guest_thp(proc_idx, gva_page);
        let (tlb4k, tlb2m) = &mut self.tlbs[vcpu];
        let hit = if huge_leaf {
            tlb2m.access(asid, gva_page / 512, rng)
        } else {
            tlb4k.access(asid, gva_page, rng)
        };

        if hit {
            // A TLB entry can only exist for a mapped unit; unmap is
            // modeled as invalidating (we verify against the EPT).
            if self.ept.touch(unit, write) {
                return AccessResult::Hit { cost: cost + self.mem_ns };
            }
        }

        // TLB miss (or stale entry): nested page walk.
        let leaf = if huge_leaf { PageSize::Huge } else { PageSize::Small };
        cost += self.walk.walk_cost(now, leaf) + self.mem_ns;

        if self.ept.touch(unit, write) {
            return AccessResult::Hit { cost };
        }

        // EPT violation.
        AccessResult::Fault(FaultInfo {
            unit,
            gpa_frame,
            gva_page,
            cr3,
            ip,
            write,
            vcpu,
            pre_cost: cost,
        })
    }

    /// TLB statistics aggregated over vCPUs: (hits, misses).
    pub fn tlb_stats(&self) -> (u64, u64) {
        self.tlbs.iter().fold((0, 0), |(h, m), (a, b)| {
            (h + a.hits + b.hits, m + a.misses + b.misses)
        })
    }

    /// Flush all vCPU TLBs (e.g. after bulk unmap).
    pub fn flush_tlbs(&mut self) {
        for (a, b) in &mut self.tlbs {
            a.flush();
            b.flush();
        }
    }

    /// Resident bytes according to the EPT.
    pub fn resident_bytes(&self) -> u64 {
        self.ept.resident_units() * self.unit_frames * crate::types::FRAME_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_vm(mode: PageSize) -> (Vm, Rng) {
        let cfg = VmConfig {
            frames: 2048,
            vcpus: 1,
            page_size: mode,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(1);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (vm, rng)
    }

    #[test]
    fn unmapped_access_faults() {
        let (mut vm, mut rng) = small_vm(PageSize::Small);
        let p = vm.spawn_process(2048);
        match vm.access(0, p, 0, false, 0x400000, 0, &mut rng) {
            AccessResult::Fault(f) => {
                assert_eq!(f.gva_page, 0);
                assert_eq!(f.unit, 0); // unscrambled boot allocator
                assert!(f.cr3 != 0);
            }
            other => panic!("expected fault, got {other:?}"),
        }
        assert_eq!(vm.guest_minor_faults, 1);
    }

    #[test]
    fn mapped_access_hits() {
        let (mut vm, mut rng) = small_vm(PageSize::Small);
        let p = vm.spawn_process(2048);
        // Map every unit.
        for u in 0..vm.units() {
            vm.ept.map(u);
        }
        match vm.access(0, p, 5, true, 0, 0, &mut rng) {
            AccessResult::Hit { cost } => assert!(cost > 0),
            other => panic!("expected hit, got {other:?}"),
        }
    }

    #[test]
    fn huge_mode_unit_covers_512_frames() {
        let (mut vm, mut rng) = small_vm(PageSize::Huge);
        let p = vm.spawn_process(2048);
        assert_eq!(vm.units(), 4);
        // Touch frame 0 and frame 511: same unit (sequential allocator).
        let f1 = match vm.access(0, p, 0, false, 0, 0, &mut rng) {
            AccessResult::Fault(f) => f.unit,
            _ => panic!(),
        };
        vm.ept.map(f1);
        match vm.access(0, p, 511, false, 0, 0, &mut rng) {
            AccessResult::Hit { .. } => {}
            other => panic!("expected hit in same 2M unit, got {other:?}"),
        }
    }

    #[test]
    fn granularity_access_canonicalizes_to_region_base() {
        let (mut vm, mut rng) = small_vm(PageSize::Small);
        let p = vm.spawn_process(2048);
        vm.ept.set_region_huge(1);
        // Unscrambled boot allocator: gva 700 -> frame 700, region 1.
        let f = match vm.access(0, p, 700, false, 0, 0, &mut rng) {
            AccessResult::Fault(f) => f,
            other => panic!("expected fault, got {other:?}"),
        };
        assert_eq!(f.gpa_frame, 700);
        assert_eq!(f.unit, 512, "fault canonicalizes to the region base");
        // Mapping the base maps the whole region: any frame in it hits.
        vm.ept.map(f.unit);
        match vm.access(0, p, 1000, true, 0, 0, &mut rng) {
            AccessResult::Hit { .. } => {}
            other => panic!("expected hit in huge region, got {other:?}"),
        }
        assert!(vm.ept.dirty(512));
        assert_eq!(vm.resident_bytes(), 512 * 4096);
    }

    #[test]
    fn repeated_access_warms_tlb() {
        let (mut vm, mut rng) = small_vm(PageSize::Small);
        let p = vm.spawn_process(2048);
        for u in 0..vm.units() {
            vm.ept.map(u);
        }
        for _ in 0..50 {
            vm.access(0, p, 9, false, 0, 0, &mut rng);
        }
        let (h, m) = vm.tlb_stats();
        assert!(h > 40, "hits {h} misses {m}");
    }

    #[test]
    fn scrambled_allocator_decorrelates_gva_gpa() {
        let cfg = VmConfig {
            frames: 4096,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 1.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(3);
        let mut vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        let p = vm.spawn_process(4096);
        let mut units = vec![];
        for g in 0..256 {
            if let AccessResult::Fault(f) = vm.access(0, p, g, false, 0, 0, &mut rng) {
                units.push(f.unit);
                vm.ept.map(f.unit);
            }
        }
        let seq = units.windows(2).filter(|w| w[1] == w[0] + 1).count();
        assert!(seq < 32, "gva->gpa still sequential: {seq}");
    }
}
