//! In-kernel Linux swap model: the baseline the paper compares against.
//!
//! Behaviours reproduced (paper §2, §6 benchmark setup):
//! * faults handled in-kernel: 6µs VMEXIT (vs 22µs userspace), plus the
//!   kernel software swap path;
//! * readahead: `vm.page-cluster = 3` reads a cluster of 8 pages per
//!   major fault in *swap-slot order* (≈ GPA order — which is exactly
//!   what degrades under virtualization, §3.2);
//! * THP: guest memory starts 2MB-backed; swap-out *splits* THPs into
//!   4kB pages, permanently degrading TLB reach (§6.4's "only 40% of
//!   memory covered by hugepages by the end");
//! * cgroup memory limit with direct reclaim on the fault path and a
//!   2-list-LRU-like clock eviction;
//! * reactive only: the kernel does not reclaim without pressure.

use crate::config::{HwConfig, LinuxConfig, SwCost};
use crate::hw::{IoKind, Nvme};
use crate::metrics::Counters;
use crate::sim::Rng;
use crate::types::{Time, UnitState, FRAME_BYTES};
use crate::vm::Vm;

#[derive(Debug)]
pub struct LinuxSwap {
    pub cfg: LinuxConfig,
    /// Per-4kB-frame state.
    pub states: Vec<UnitState>,
    last_touch: Vec<Time>,
    pub usage_frames: u64,
    pub limit_frames: Option<u64>,
    clock_hand: usize,
    sw: SwCost,
    pub counters: Counters,
    /// THP splits performed (coverage telemetry).
    pub thp_splits: u64,
    total_2m_regions: u64,
}

/// Result of handling a kernel fault: when the vCPU resumes.
#[derive(Debug, Clone, Copy)]
pub struct KernelFault {
    pub resume_at: Time,
    pub major: bool,
}

impl LinuxSwap {
    pub fn new(cfg: &LinuxConfig, frames: u64, sw: &SwCost) -> Self {
        LinuxSwap {
            cfg: cfg.clone(),
            states: vec![UnitState::Untouched; frames as usize],
            last_touch: vec![0; frames as usize],
            usage_frames: 0,
            limit_frames: cfg.memory_limit.map(|b| b / FRAME_BYTES),
            clock_hand: 0,
            sw: sw.clone(),
            counters: Counters::default(),
            thp_splits: 0,
            total_2m_regions: frames.div_ceil(512),
        }
    }

    pub fn set_limit(&mut self, bytes: Option<u64>) {
        self.limit_frames = bytes.map(|b| b / FRAME_BYTES);
    }

    /// Fraction of 2MB regions still THP-backed.
    pub fn thp_coverage(&self) -> f64 {
        if self.total_2m_regions == 0 {
            return 1.0;
        }
        1.0 - self.thp_splits as f64 / self.total_2m_regions as f64
    }

    /// Mark guest accesses young (called from scan bitmaps / fault path)
    /// so the LRU sees recency.
    pub fn touch(&mut self, frame: u64, now: Time) {
        self.last_touch[frame as usize] = now;
    }

    fn evict_one(&mut self, vm: &mut Vm, now: Time, nvme: &mut Nvme, io_end: &mut Time) -> bool {
        let n = self.states.len();
        let mut oldest: Option<(Time, usize)> = None;
        let start = self.clock_hand;
        let mut victim = None;
        for step in 0..n {
            let f = (start + step) % n;
            if self.states[f] != UnitState::Resident {
                continue;
            }
            let t = self.last_touch[f];
            if t + 50_000_000 < now {
                victim = Some(f);
                self.clock_hand = (f + 1) % n;
                break;
            }
            if oldest.map_or(true, |(bt, _)| t < bt) {
                oldest = Some((t, f));
            }
        }
        let Some(f) = victim.or(oldest.map(|(_, f)| f)) else {
            return false;
        };
        // Splitting a THP on swap-out (THP cannot be swapped as a unit).
        let region = f / 512;
        if self.cfg.thp {
            if let Some(bm) = vm.host_thp_mut() {
                if bm.get(region) {
                    bm.clear(region);
                    self.thp_splits += 1;
                }
            }
        }
        self.states[f] = UnitState::Swapped;
        self.usage_frames -= 1;
        vm.ept.unmap(f as u64);
        let done = nvme.submit(now, FRAME_BYTES, IoKind::Write);
        *io_end = (*io_end).max(done);
        self.counters.swapout_ops += 1;
        self.counters.swapout_bytes += FRAME_BYTES;
        true
    }

    /// Handle an EPT violation in-kernel at `now`.
    pub fn fault(
        &mut self,
        vm: &mut Vm,
        frame: u64,
        now: Time,
        nvme: &mut Nvme,
        _rng: &mut Rng,
    ) -> KernelFault {
        let fi = frame as usize;
        let mut t = now + self.sw.vmexit_kernel_ns + self.sw.kernel_swap_sw_ns;
        self.last_touch[fi] = now;

        // Direct reclaim under the cgroup limit.
        let mut incoming = match self.states[fi] {
            UnitState::Untouched if self.cfg.thp => {
                // THP fault maps a whole 2MB region if fully untouched.
                let region = frame / 512;
                let lo = (region * 512) as usize;
                let hi = (lo + 512).min(self.states.len());
                if self.states[lo..hi].iter().all(|s| *s == UnitState::Untouched)
                    && vm.host_thp_mut().map_or(false, |bm| bm.get(region as usize))
                {
                    (hi - lo) as u64
                } else {
                    1
                }
            }
            _ => 1,
        };
        // Readahead cluster for major faults.
        let major = self.states[fi] == UnitState::Swapped;
        let mut cluster: Vec<usize> = vec![];
        if major {
            let ra = 1usize << self.cfg.page_cluster;
            for k in 0..ra {
                let g = fi + k;
                if g < self.states.len() && self.states[g] == UnitState::Swapped {
                    cluster.push(g);
                } else if k > 0 {
                    break;
                }
            }
            incoming = cluster.len() as u64;
        }

        let mut io_end = t;
        if let Some(limit) = self.limit_frames {
            while self.usage_frames + incoming > limit {
                if !self.evict_one(vm, t, nvme, &mut io_end) {
                    break;
                }
                self.counters.limit_forced_reclaims += 1;
            }
        }

        match self.states[fi] {
            UnitState::Untouched => {
                // Minor fault: map (THP region or single page), zero cost
                // folded into kernel_swap_sw.
                self.counters.faults_minor += 1;
                if incoming > 1 {
                    let region = frame / 512;
                    let lo = (region * 512) as usize;
                    for g in lo..lo + incoming as usize {
                        self.states[g] = UnitState::Resident;
                        self.last_touch[g] = now;
                        vm.ept.map(g as u64);
                    }
                } else {
                    self.states[fi] = UnitState::Resident;
                    vm.ept.map(frame);
                }
                self.usage_frames += incoming;
                KernelFault { resume_at: t.max(io_end), major: false }
            }
            UnitState::Swapped => {
                self.counters.faults_major += 1;
                // One clustered read.
                let bytes = (cluster.len() as u64) * FRAME_BYTES;
                let done = nvme.submit(t, bytes, IoKind::Read);
                self.counters.swapin_ops += 1;
                self.counters.swapin_bytes += bytes;
                for &g in &cluster {
                    self.states[g] = UnitState::Resident;
                    self.last_touch[g] = now;
                    vm.ept.map(g as u64);
                    // Refaulting 4kB into a split THP region keeps the
                    // region split (TLB reach stays degraded).
                }
                self.usage_frames += cluster.len() as u64;
                t = done.max(io_end) + self.sw.kernel_swap_sw_ns;
                KernelFault { resume_at: t, major: true }
            }
            UnitState::Resident => {
                // Spurious (already mapped by readahead): minor.
                self.counters.faults_minor += 1;
                vm.ept.map(frame);
                KernelFault { resume_at: t, major: false }
            }
            other => {
                debug_assert!(false, "kernel fault in state {other:?}");
                KernelFault { resume_at: t, major: false }
            }
        }
    }

    /// kswapd-style background reclaim towards the limit watermark.
    pub fn kswapd_tick(&mut self, vm: &mut Vm, now: Time, nvme: &mut Nvme) {
        let Some(limit) = self.limit_frames else { return };
        let high = limit - limit / 16; // high watermark
        let mut io_end = now;
        let mut budget = 4096;
        while self.usage_frames > high && budget > 0 {
            if !self.evict_one(vm, now, nvme, &mut io_end) {
                break;
            }
            budget -= 1;
        }
    }

    pub fn usage_bytes(&self) -> u64 {
        self.usage_frames * FRAME_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VmConfig;
    use crate::types::PageSize;

    fn setup(frames: u64, limit: Option<u64>, thp: bool) -> (LinuxSwap, Vm, Nvme, Rng) {
        let cfg = LinuxConfig {
            thp,
            memory_limit: limit.map(|f| f * FRAME_BYTES),
            ..Default::default()
        };
        let mut rng = Rng::new(6);
        let vm_cfg = VmConfig {
            frames,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut vm = Vm::new(&vm_cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        if thp {
            vm.enable_host_thp();
        }
        (
            LinuxSwap::new(&cfg, frames, &SwCost::default()),
            vm,
            Nvme::new(&HwConfig::default()),
            rng,
        )
    }

    #[test]
    fn thp_first_touch_maps_whole_region() {
        let (mut k, mut vm, mut nvme, mut rng) = setup(1024, None, true);
        let r = k.fault(&mut vm, 5, 0, &mut nvme, &mut rng);
        assert!(!r.major);
        assert_eq!(k.usage_frames, 512);
        assert!(vm.ept.present(0) && vm.ept.present(511));
        assert!(!vm.ept.present(512));
    }

    #[test]
    fn readahead_clusters_major_faults() {
        let (mut k, mut vm, mut nvme, mut rng) = setup(64, None, false);
        for f in 0..16 {
            k.states[f] = UnitState::Swapped;
        }
        let r = k.fault(&mut vm, 4, 0, &mut nvme, &mut rng);
        assert!(r.major);
        // page-cluster=3 => 8 pages in one read.
        assert_eq!(k.counters.swapin_bytes, 8 * FRAME_BYTES);
        assert_eq!(k.usage_frames, 8);
        assert!(vm.ept.present(4) && vm.ept.present(11));
    }

    #[test]
    fn limit_forces_eviction_and_splits_thp() {
        let (mut k, mut vm, mut nvme, mut rng) = setup(2048, Some(600), true);
        // First THP fault maps 512 frames.
        k.fault(&mut vm, 0, 0, &mut nvme, &mut rng);
        assert_eq!(k.thp_coverage(), 1.0);
        // Second THP region would exceed 600: direct reclaim evicts old
        // 4k frames and splits their region.
        k.fault(&mut vm, 600, 1_000_000_000, &mut nvme, &mut rng);
        assert!(k.usage_frames <= 600 + 512);
        assert!(k.thp_splits > 0);
        assert!(k.thp_coverage() < 1.0);
        assert!(k.counters.limit_forced_reclaims > 0);
    }

    #[test]
    fn kernel_fault_is_cheaper_than_uffd() {
        let (mut k, mut vm, mut nvme, mut rng) = setup(64, None, false);
        k.states[3] = UnitState::Swapped;
        let r = k.fault(&mut vm, 3, 0, &mut nvme, &mut rng);
        // 6us exit + sw + ~80us io for 8-page cluster: well under 200us.
        assert!(r.resume_at < 250_000, "{}", r.resume_at);
    }
}
