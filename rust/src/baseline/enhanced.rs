//! Enhanced Linux reclaim (paper §6.4): the paper's own reclamation
//! algorithm ported to drive *cgroup limits* on top of kernel swap,
//! with flexswap removed from the data path.
//!
//! The EPT scanner informs the kernel of young pages; the dt-style
//! analytics derive a cold-page count; the cgroup limit is tightened to
//! `usage - cold`, letting the kernel's own LRU evict. Two handicaps the
//! paper identifies are inherent and reproduced here:
//!
//! 1. faults are invisible to the bitmap history (the kernel-side port
//!    has no UFFD feedback), making the reclaimer over-aggressive;
//! 2. the kernel swaps 4kB pages and splits THPs, so hugepage coverage
//!    decays over the run.

use std::collections::VecDeque;

use crate::baseline::LinuxSwap;
use crate::policies::analytics::{ColdAnalytics, NativeAnalytics};
use crate::types::{Bitmap, Time, FRAME_BYTES};

pub struct EnhancedReclaim {
    history: usize,
    target_rate: f32,
    threshold: f32,
    ring: VecDeque<Bitmap>,
    /// Shared zero pad row (window borrows, no per-tick clones).
    zero_pad: Bitmap,
    backend: NativeAnalytics,
    /// Aggressivity scale on the derived cold set (for the Fig 10 sweep).
    pub aggressivity: f64,
    pub limit_updates: u64,
}

impl EnhancedReclaim {
    pub fn new(history: usize, target_rate: f64) -> Self {
        EnhancedReclaim {
            history: history.max(2),
            target_rate: target_rate as f32,
            threshold: history as f32,
            ring: VecDeque::new(),
            zero_pad: Bitmap::default(),
            backend: NativeAnalytics::new(),
            aggressivity: 1.0,
            limit_updates: 0,
        }
    }

    /// Feed one scan bitmap (frame granularity); adjusts the cgroup
    /// limit on the kernel swap instance.
    pub fn on_scan(&mut self, kernel: &mut LinuxSwap, bitmap: &Bitmap, now: Time) {
        // NOTE: unlike the flexswap dt-reclaimer, faulted pages are NOT
        // merged in — the kernel port has no visibility (§6.4).
        self.ring.push_back(bitmap.clone());
        while self.ring.len() > self.history {
            self.ring.pop_front();
        }
        if self.ring.len() < self.history.min(4) {
            return;
        }
        let n = bitmap.len();
        let window = crate::policies::analytics::window_refs(
            &mut self.zero_pad,
            &self.ring,
            self.history,
            n,
        );
        let out = self.backend.dt_reclaim(&window, self.target_rate, self.threshold);
        self.threshold = out.smoothed;
        let cold = out
            .age
            .iter()
            .filter(|&&a| a >= self.threshold)
            .count() as f64
            * self.aggressivity;
        let usage = kernel.usage_frames;
        let new_limit_frames = usage.saturating_sub(cold as u64).max(64);
        kernel.set_limit(Some(new_limit_frames * FRAME_BYTES));
        self.limit_updates += 1;
        let _ = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LinuxConfig, SwCost};

    #[test]
    fn tightens_limit_when_cold_pages_exist() {
        let mut k = LinuxSwap::new(&LinuxConfig::default(), 1024, &SwCost::default());
        k.usage_frames = 1024;
        for s in &mut k.states {
            *s = crate::types::UnitState::Resident;
        }
        let mut e = EnhancedReclaim::new(8, 0.02);
        // 8 scans where only frames 0..100 are hot.
        for i in 0..8u64 {
            let mut bm = Bitmap::new(1024);
            for f in 0..100 {
                bm.set(f);
            }
            e.on_scan(&mut k, &bm, i);
        }
        let limit = k.limit_frames.unwrap();
        assert!(limit < 1024, "limit {limit}");
        assert!(limit >= 100, "limit {limit} below hot set");
    }

    #[test]
    fn no_action_during_warmup() {
        let mut k = LinuxSwap::new(&LinuxConfig::default(), 256, &SwCost::default());
        let mut e = EnhancedReclaim::new(8, 0.02);
        e.on_scan(&mut k, &Bitmap::new(256), 0);
        assert!(k.limit_frames.is_none());
    }
}
