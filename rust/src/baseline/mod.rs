//! Linux kernel swap baseline (paper §6 "Comparing to Linux swapping")
//! and the enhanced-Linux reclaim baseline of §6.4.

pub mod enhanced;
pub mod linux_swap;

pub use enhanced::EnhancedReclaim;
pub use linux_swap::LinuxSwap;
