//! EPT scanner (paper §5.4): kernel module + userspace aggregator.
//!
//! Reads + clears EPT access bits on a dedicated core and forwards the
//! bitmap to subscribed policies. Costs are the §3.3 pair: *direct* CPU
//! time on the scanning core (∝ present PTEs) and *indirect* slowdown of
//! the guest from flushed partial-walk caches (applied to the VM's walk
//! model). Per the paper we do NOT do hierarchical/sampled scanning —
//! policies adjust the interval instead.

use crate::config::HwConfig;
use crate::types::{Bitmap, Time};
use crate::vm::Vm;

#[derive(Debug, Clone)]
pub struct ScanOutput {
    /// Access bitmap over swap units (1 = accessed since last scan).
    pub bitmap: Bitmap,
    /// Present leaves visited (scan cost scales with this).
    pub visited: u64,
    /// CPU time burnt on the scanning core.
    pub cpu_ns: Time,
    pub at: Time,
}

#[derive(Debug)]
pub struct EptScanner {
    scan_pte_ns: Time,
    /// Also scan the QEMU process page table (VIRTIO case, §5.4): bits
    /// set by host-side clients (e.g. vhost touching guest buffers) are
    /// OR-ed into the result so policies don't reclaim I/O-hot pages.
    pub scan_qemu: bool,
    pub scans: u64,
    pub total_cpu_ns: Time,
}

impl EptScanner {
    pub fn new(hw: &HwConfig) -> Self {
        EptScanner { scan_pte_ns: hw.scan_pte_ns, scan_qemu: true, scans: 0, total_cpu_ns: 0 }
    }

    /// One scan pass at `now`. `qemu_bits` is the host-client access
    /// bitmap maintained by the machine (None when no VIRTIO clients).
    pub fn scan(
        &mut self,
        vm: &mut Vm,
        qemu_bits: Option<&Bitmap>,
        now: Time,
    ) -> ScanOutput {
        let mut bitmap = Bitmap::new(vm.units() as usize);
        let visited = vm.ept.scan_and_clear(&mut bitmap);
        // Clearing A-bits flushes partial-walk caches (indirect cost).
        vm.walk.on_abit_clear(now);

        let mut cpu_ns = visited * self.scan_pte_ns;
        if self.scan_qemu {
            if let Some(q) = qemu_bits {
                bitmap.or_assign(q);
                cpu_ns += q.len() as u64 * self.scan_pte_ns;
            }
        }
        self.scans += 1;
        self.total_cpu_ns += cpu_ns;
        ScanOutput { bitmap, visited, cpu_ns, at: now }
    }

    /// Direct cost (fraction of one core) of scanning `visited` PTEs
    /// every `interval` ns — the Fig 3 "direct (% CPU)" series.
    pub fn direct_cpu_fraction(&self, visited: u64, interval: Time) -> f64 {
        if interval == 0 {
            return 1.0;
        }
        ((visited * self.scan_pte_ns) as f64 / interval as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SwCost, VmConfig};
    use crate::sim::Rng;
    use crate::types::PageSize;

    fn vm(mode: PageSize) -> (Vm, Rng) {
        let cfg = VmConfig {
            frames: 2048,
            vcpus: 1,
            page_size: mode,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(5);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (vm, rng)
    }

    #[test]
    fn scan_reports_accessed_units_and_clears() {
        let (mut v, _) = vm(PageSize::Small);
        v.ept.map(3);
        v.ept.map(4);
        v.ept.touch(3, false);
        let mut s = EptScanner::new(&HwConfig::default());
        let out = s.scan(&mut v, None, 1000);
        assert!(out.bitmap.get(3) && out.bitmap.get(4)); // map sets A
        assert_eq!(out.visited, 2);
        let out2 = s.scan(&mut v, None, 2000);
        assert_eq!(out2.bitmap.count_ones(), 0);
    }

    #[test]
    fn huge_mode_scans_512x_fewer_ptes() {
        let (mut v4, _) = vm(PageSize::Small);
        let (mut v2, _) = vm(PageSize::Huge);
        for u in 0..v4.units() {
            v4.ept.map(u);
        }
        for u in 0..v2.units() {
            v2.ept.map(u);
        }
        let mut s = EptScanner::new(&HwConfig::default());
        let c4 = s.scan(&mut v4, None, 0).cpu_ns;
        let c2 = s.scan(&mut v2, None, 0).cpu_ns;
        assert_eq!(c4, c2 * 512);
    }

    #[test]
    fn scan_sets_pwc_penalty() {
        let (mut v, _) = vm(PageSize::Small);
        let mut s = EptScanner::new(&HwConfig::default());
        assert!(!v.walk.penalized(100));
        s.scan(&mut v, None, 100);
        assert!(v.walk.penalized(101));
    }

    #[test]
    fn qemu_bits_are_merged() {
        let (mut v, _) = vm(PageSize::Small);
        let mut q = Bitmap::new(v.units() as usize);
        q.set(7);
        let mut s = EptScanner::new(&HwConfig::default());
        let out = s.scan(&mut v, Some(&q), 0);
        assert!(out.bitmap.get(7));
    }

    #[test]
    fn direct_fraction() {
        let s = EptScanner::new(&HwConfig::default());
        // 1M PTEs * 5ns = 5ms per scan; at 1s interval = 0.5%.
        let f = s.direct_cpu_fraction(1_000_000, 1_000_000_000);
        assert!((f - 0.005).abs() < 1e-9);
    }
}
