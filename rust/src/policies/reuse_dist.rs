//! SYS-R (paper §6.5): reuse-distance based memory-limit reclaimer
//! approximating Bélády's optimal policy.
//!
//! Trained on page-fault events: an IP-indexed predictor learns the
//! reuse distance (in faults) of the faulting page w.r.t. historical
//! faults; the page's Estimated Reuse Time — stored as the predicted
//! *expiry* fault-sequence — enters the ERT table. On a victim request,
//! the entry with the largest remaining |ERT| is victimized, either via
//! the AOT `ert_victim` artifact (L2 JAX) or the native scorer.

use std::collections::HashMap;

use crate::mm::{EngineCore, LimitReclaimer, PolicyEvent};
use crate::policies::analytics::ErtScorer;
use crate::types::{Time, UnitId, UnitState};

const EMA_ALPHA: f64 = 0.3;
/// Re-rank after this many victims from one scoring pass.
const RANK_BATCH: usize = 32;

pub struct ReuseDistReclaimer {
    scorer: Box<dyn ErtScorer>,
    /// Fault sequence counter (the "clock" ERTs count against).
    seq: u64,
    /// Last fault sequence per unit (0 = never).
    last_fault: Vec<u64>,
    /// Predicted expiry sequence per unit (f32 table fed to the scorer).
    expiry: Vec<f32>,
    valid: Vec<f32>,
    /// IP -> EMA of observed reuse distance.
    ip_table: HashMap<u64, f64>,
    global_ema: f64,
    /// Cached victim ranking (descending score).
    ranked: Vec<UnitId>,
    ranked_at_seq: u64,
    pub victims: u64,
    pub trained_faults: u64,
}

impl ReuseDistReclaimer {
    pub fn new(units: u64, scorer: Box<dyn ErtScorer>) -> Self {
        ReuseDistReclaimer {
            scorer,
            seq: 0,
            last_fault: vec![0; units as usize],
            expiry: vec![0.0; units as usize],
            valid: vec![0.0; units as usize],
            ip_table: HashMap::new(),
            global_ema: 64.0,
            ranked: vec![],
            ranked_at_seq: 0,
            victims: 0,
            trained_faults: 0,
        }
    }

    fn predict(&self, ip: Option<u64>) -> f64 {
        ip.and_then(|ip| self.ip_table.get(&ip).copied())
            .unwrap_or(self.global_ema)
    }

    fn train(&mut self, unit: UnitId, ip: Option<u64>) {
        self.seq += 1;
        self.trained_faults += 1;
        let ui = unit as usize;
        if self.last_fault[ui] != 0 {
            let dist = (self.seq - self.last_fault[ui]) as f64;
            self.global_ema = (1.0 - EMA_ALPHA) * self.global_ema + EMA_ALPHA * dist;
            if let Some(ip) = ip {
                let e = self.ip_table.entry(ip).or_insert(dist);
                *e = (1.0 - EMA_ALPHA) * *e + EMA_ALPHA * dist;
            }
        }
        self.last_fault[ui] = self.seq;
        self.expiry[ui] = (self.seq as f64 + self.predict(ip)) as f32;
        self.valid[ui] = 1.0;
        // Faults invalidate the cached ranking lazily (see victim()).
    }

    /// Run the scorer over remaining-ERT values and cache a ranking.
    fn rank(&mut self, core: &EngineCore) {
        let n = self.expiry.len();
        // Remaining = expiry - seq; invalid for non-resident units.
        let mut rem: Vec<f32> = (0..n)
            .map(|u| self.expiry[u] - self.seq as f32)
            .collect();
        let valid: Vec<f32> = (0..n)
            .map(|u| {
                if self.valid[u] > 0.0
                    && core.states[u] == UnitState::Resident
                    && !core.want_out.get(u)
                    && !core.locks.is_locked(u as UnitId)
                {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        // Pull the top RANK_BATCH victims by repeated scorer calls (the
        // artifact returns one argmax per invocation).
        let mut valid_mut = valid;
        self.ranked.clear();
        for _ in 0..RANK_BATCH.min(n) {
            let (idx, score) = self.scorer.victim(&mut rem, &valid_mut, 0.0);
            if score == f32::NEG_INFINITY || valid_mut[idx] == 0.0 {
                break;
            }
            valid_mut[idx] = 0.0;
            self.ranked.push(idx as UnitId);
        }
        self.ranked.reverse(); // pop() yields highest score first
        self.ranked_at_seq = self.seq;
    }
}

impl LimitReclaimer for ReuseDistReclaimer {
    fn name(&self) -> &'static str {
        "sys-r"
    }

    fn note(&mut self, ev: &PolicyEvent) {
        if let PolicyEvent::PageFault { unit, ctx, major, .. } = ev {
            if *major {
                self.train(*unit, ctx.map(|c| c.ip));
            }
        }
    }

    fn victim(&mut self, core: &EngineCore, _now: Time) -> Option<UnitId> {
        // Refresh the ranking when exhausted or stale.
        if self.ranked.is_empty() || self.seq.saturating_sub(self.ranked_at_seq) > 512 {
            self.rank(core);
        }
        while let Some(u) = self.ranked.pop() {
            if core.states[u as usize] == UnitState::Resident
                && !core.want_out.get(u as usize)
                && !core.locks.is_locked(u)
            {
                self.victims += 1;
                return Some(u);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::introspect::FaultCtx;
    use crate::policies::analytics::NativeAnalytics;

    fn fault_ev(unit: UnitId, ip: u64) -> PolicyEvent<'static> {
        PolicyEvent::PageFault {
            unit,
            ctx: Some(FaultCtx { cr3: 1, ip, gva: unit * 4096, gpa_frame: unit }),
            major: true,
            now: 0,
        }
    }

    fn resident_core(n: u64) -> EngineCore {
        let mut c = EngineCore::new(n, 4096, None);
        for u in 0..n as usize {
            c.states[u] = UnitState::Resident;
        }
        c
    }

    #[test]
    fn learns_ip_distances() {
        let mut r = ReuseDistReclaimer::new(16, Box::new(NativeAnalytics::new()));
        // IP 0xA faults unit 1 every 2 faults; IP 0xB unit 2 every 8.
        for i in 0..32 {
            r.note(&fault_ev(1, 0xA));
            if i % 4 == 0 {
                r.note(&fault_ev(2, 0xB));
            }
        }
        let a = r.ip_table[&0xA];
        let b = r.ip_table[&0xB];
        assert!(a < b, "short-reuse ip must predict shorter: {a} vs {b}");
    }

    #[test]
    fn victimizes_largest_remaining_ert() {
        let core = resident_core(8);
        let mut r = ReuseDistReclaimer::new(8, Box::new(NativeAnalytics::new()));
        // Train: unit 1 reused every ~2 faults (hot), unit 5 once with a
        // long-reuse IP.
        for _ in 0..16 {
            r.note(&fault_ev(1, 0xA));
        }
        // Give 0xB a long learned distance by spacing its faults.
        r.note(&fault_ev(5, 0xB));
        for _ in 0..30 {
            r.note(&fault_ev(1, 0xA));
        }
        r.note(&fault_ev(5, 0xB));
        let v = r.victim(&core, 0).unwrap();
        assert_eq!(v, 5, "far-future-reuse unit should be victimized");
    }

    #[test]
    fn skips_nonresident() {
        let mut core = resident_core(4);
        core.states[2] = UnitState::Swapped;
        let mut r = ReuseDistReclaimer::new(4, Box::new(NativeAnalytics::new()));
        for u in [1u64, 2, 3] {
            r.note(&fault_ev(u, 0x1));
        }
        for _ in 0..4 {
            if let Some(v) = r.victim(&core, 0) {
                assert_ne!(v, 2);
            }
        }
    }

    #[test]
    fn random_ips_fall_back_to_global_ema() {
        let mut r = ReuseDistReclaimer::new(8, Box::new(NativeAnalytics::new()));
        r.note(&fault_ev(1, 0x1));
        // Unknown ip: predicted = global ema.
        assert!((r.predict(Some(0x999)) - r.global_ema).abs() < 1e-9);
        assert!((r.predict(None) - r.global_ema).abs() < 1e-9);
    }
}
