//! 4k-WSR, working-set restore (paper §6.8).
//!
//! While running under a memory limit, the policy records the working
//! set (units seen in recent scan bitmaps / faults, with recency). When
//! the control plane *lifts* the limit, the recorded set is prefetched
//! in LRU order (most recently used first), turning the recovery's major
//! faults into minor ones — the paper's "removes I/O from the page fault
//! path".

use crate::mm::{Policy, PolicyApi, PolicyEvent};
use crate::types::{Time, UnitId, UnitState};

pub struct WsrPolicy {
    /// last seen (scan/fault) time per unit while limited.
    seen: Vec<Time>,
    pub restored: u64,
    pub recordings: u64,
    /// Prefetches re-issued under the recovery-boost hint.
    pub boost_restored: u64,
}

impl WsrPolicy {
    pub fn new(units: u64) -> Self {
        WsrPolicy { seen: vec![0; units as usize], restored: 0, recordings: 0, boost_restored: 0 }
    }

    /// Prefetch the recorded working set, most recently used first.
    /// Returns how many prefetches were issued.
    fn restore(&mut self, api: &mut PolicyApi) -> u64 {
        let mut order: Vec<(Time, UnitId)> = self
            .seen
            .iter()
            .enumerate()
            .filter(|(_, &t)| t > 0)
            .map(|(u, &t)| (t, u as UnitId))
            .collect();
        order.sort_unstable_by(|a, b| b.cmp(a));
        let mut issued = 0;
        for (_, u) in order {
            if api.page_state(u) == UnitState::Swapped {
                api.prefetch(u);
                issued += 1;
            }
        }
        issued
    }
}

impl Policy for WsrPolicy {
    fn name(&self) -> &'static str {
        "4k-wsr"
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        match ev {
            PolicyEvent::ScanBitmap { bitmap, now } => {
                if api.memory_limit().is_some() {
                    for u in bitmap.iter_ones() {
                        self.seen[u] = *now;
                        self.recordings += 1;
                    }
                }
                // Recovery boost: while the control plane's release
                // window is open, keep re-issuing the restore each
                // scan — prefetches dropped at the (still finite)
                // limit or conflated away get another chance, so the
                // remaining recovery majors turn minor.
                if api.recovery_mode() {
                    let n = self.restore(api);
                    self.boost_restored += n;
                }
            }
            PolicyEvent::PageFault { unit, now, .. } => {
                if api.memory_limit().is_some() {
                    self.seen[*unit as usize] = *now;
                }
            }
            PolicyEvent::LimitChanged { old, new, .. } => {
                let lifted = match (old, new) {
                    (Some(_), None) => true,
                    (Some(o), Some(n)) => n > o,
                    _ => false,
                };
                if !lifted {
                    return;
                }
                // Prefetch the recorded WS, most recently used first.
                let n = self.restore(api);
                self.restored += n;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, MmConfig, SwCost, VmConfig};
    use crate::mm::Mm;
    use crate::sim::Rng;
    use crate::types::{Bitmap, PageSize, SEC};
    use crate::vm::Vm;

    fn setup(units: u64, limit_units: u64) -> (Mm, Vm) {
        let cfg = MmConfig {
            memory_limit: Some(limit_units * 4096),
            ..Default::default()
        };
        let mut mm = Mm::new(&cfg, units, 4096, &SwCost::default(), 0);
        mm.add_policy(Box::new(WsrPolicy::new(units)));
        let vm_cfg = VmConfig {
            frames: units,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(8);
        let vm = Vm::new(&vm_cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm)
    }

    #[test]
    fn restores_recorded_ws_on_limit_lift() {
        let (mut mm, vm) = setup(32, 8);
        // Record a working set of units 0..12 under the limit.
        let mut bm = Bitmap::new(32);
        for u in 0..12 {
            bm.set(u);
        }
        mm.on_scan(&vm, &bm, SEC);
        // They all get swapped out (thrashing).
        for u in 0..12 {
            mm.core.states[u] = UnitState::Swapped;
        }
        // Lift the limit.
        mm.set_memory_limit(&vm, None, 2 * SEC);
        // The WS should be queued as prefetches.
        let queued = (0..12u64).filter(|&u| mm.core.queue.contains(u)).count();
        assert_eq!(queued, 12);
        assert_eq!(mm.core.counters.prefetch_issued, 12);
    }

    #[test]
    fn recovery_boost_reissues_restore_on_scans() {
        let (mut mm, vm) = setup(32, 8);
        let mut bm = Bitmap::new(32);
        for u in 0..6 {
            bm.set(u);
        }
        mm.on_scan(&vm, &bm, SEC);
        for u in 0..6 {
            mm.core.states[u] = UnitState::Swapped;
        }
        // Boost-flagged release: recovery window opens.
        mm.set_memory_limit_with_boost(&vm, None, 2 * SEC, SEC);
        assert!(mm.core.recovery_until > 2 * SEC);
        let first_issued = mm.core.counters.prefetch_issued;
        assert_eq!(first_issued, 6);
        // Drain the queue, then swap one WS unit back out: without the
        // boost it would fault major; the in-window scan re-restores it.
        while mm.pick_work(2 * SEC + 1).is_some() {}
        mm.core.states[3] = UnitState::Swapped;
        mm.on_scan(&vm, &Bitmap::new(32), 2 * SEC + 100);
        assert!(mm.core.queue.contains(3), "boost did not re-restore");
        // Window closed: no further re-restores.
        while mm.pick_work(2 * SEC + 200).is_some() {}
        mm.core.states[3] = UnitState::Swapped;
        mm.on_scan(&vm, &Bitmap::new(32), 4 * SEC);
        assert!(!mm.core.queue.contains(3), "restored outside the window");
    }

    #[test]
    fn no_restore_on_tighten() {
        let (mut mm, vm) = setup(32, 16);
        let mut bm = Bitmap::new(32);
        bm.set(1);
        mm.on_scan(&vm, &bm, SEC);
        mm.core.states[1] = UnitState::Swapped;
        mm.set_memory_limit(&vm, Some(4 * 4096), 2 * SEC);
        assert_eq!(mm.core.counters.prefetch_issued, 0);
    }

    #[test]
    fn lru_order_most_recent_first() {
        let (mut mm, vm) = setup(16, 4);
        let mut bm1 = Bitmap::new(16);
        bm1.set(1);
        mm.on_scan(&vm, &bm1, SEC);
        let mut bm2 = Bitmap::new(16);
        bm2.set(2);
        mm.on_scan(&vm, &bm2, 2 * SEC);
        mm.core.states[1] = UnitState::Swapped;
        mm.core.states[2] = UnitState::Swapped;
        mm.set_memory_limit(&vm, None, 3 * SEC);
        // Both prefetched; unit 2 (more recent) first in the queue.
        let mut popped = vec![];
        while let Some(w) = mm.pick_work(4 * SEC) {
            if let crate::mm::WorkOutcome::SwapIn { unit, .. } = w {
                popped.push(unit);
            }
        }
        assert_eq!(popped, vec![2, 1]);
    }
}
