//! LinearPF (paper §6.6): the simple next-page prefetcher, in two
//! flavours — HVA (next page in host/guest-physical space) and GVA
//! (next page in the *guest application's* address space, via the
//! introspection ring + gva_to_hva walker).
//!
//! This is the paper's flagship demonstration of why introspection
//! matters: after the guest allocator ages, HVA-neighbourhood no longer
//! predicts GVA-neighbourhood, so the HVA version prefetches garbage
//! (<2% timely) while the GVA version covers >98% of faults.

use crate::mm::{Policy, PolicyApi, PolicyEvent};
use crate::storage::SwapTier;
use crate::types::UnitId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PfMode {
    /// Use the fault's host address directly (physical neighbourhood).
    Hva,
    /// Look up the faulting GVA and prefetch its GVA-successor
    /// (application-aware; the paper's §4.3 example policy).
    Gva,
}

pub struct LinearPf {
    mode: PfMode,
    /// Tier-aware mode: only prefetch units whose swap copy sits on
    /// NVMe. A compressed-pool hit is already cheap on the fault path
    /// (decompress, no device I/O), so prefetching it mostly burns
    /// Swapper-queue slots. Off by default (paper §6.6 behavior).
    pub nvme_only: bool,
    /// How many successor units each fault streams while the recovery
    /// boost window is open. The stock policy streams 2 (one successor
    /// plus one deeper in-window, §6.8); clone-from-image admission
    /// (PR 10) raises it so the boot working set pulls ahead of the
    /// guest out of the shared golden image.
    pub depth: u64,
    pub issued: u64,
    pub ctx_missing: u64,
    pub translation_failed: u64,
    /// Prefetches suppressed because the target was pool-resident.
    pub skipped_pool_resident: u64,
}

impl LinearPf {
    pub fn new(mode: PfMode) -> Self {
        LinearPf {
            mode,
            nvme_only: false,
            depth: 2,
            issued: 0,
            ctx_missing: 0,
            translation_failed: 0,
            skipped_pool_resident: 0,
        }
    }

    /// Tier-aware variant: see [`LinearPf::nvme_only`].
    pub fn tier_aware(mode: PfMode) -> Self {
        LinearPf { nvme_only: true, ..Self::new(mode) }
    }

    /// Boot-streaming variant (PR 10): while the clone's post-implant
    /// recovery window is open, each fault streams `depth` successor
    /// units ahead. `depth == 2` is exactly the stock policy.
    pub fn boot_stream(mode: PfMode, depth: u64) -> Self {
        LinearPf { depth: depth.max(1), ..Self::new(mode) }
    }

    /// Issue (or tier-skip) one prefetch.
    fn emit(&mut self, next: UnitId, api: &mut PolicyApi) {
        if self.nvme_only && api.swap_tier(next) == Some(SwapTier::Pool) {
            self.skipped_pool_resident += 1;
            return;
        }
        api.prefetch(next);
        self.issued += 1;
    }
}

impl Policy for LinearPf {
    fn name(&self) -> &'static str {
        match self.mode {
            PfMode::Hva => "linear-pf-hva",
            PfMode::Gva => "linear-pf-gva",
        }
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        let PolicyEvent::PageFault { unit, ctx, .. } = ev else {
            return;
        };
        match self.mode {
            PfMode::Hva => {
                let next = unit + 1;
                if next < api.units() {
                    self.emit(next, api);
                }
                // Recovery boost: stream deeper while the post-release
                // window is open (the working set is coming back
                // wholesale — §6.8; clone boot streaming raises
                // `depth`, PR 10).
                if api.recovery_mode() {
                    for d in 2..=self.depth {
                        if unit + d < api.units() {
                            self.emit(unit + d, api);
                        }
                    }
                }
            }
            PfMode::Gva => {
                // Paper §4.3 example, verbatim logic:
                //   if (!cr3 || !gva) return;
                //   next_gva = gva + page.size();
                //   next_hva = SYS.gva_to_hva(next_gva, cr3);
                //   if (!next_hva) return;
                //   SYS.prefetch(next_hva);
                let Some(ctx) = ctx else {
                    self.ctx_missing += 1;
                    return;
                };
                let unit_frames = api.vm.unit_frames();
                let next_gva_page = ctx.gva / crate::types::FRAME_BYTES + unit_frames;
                match api.gva_to_hva(next_gva_page, ctx.cr3) {
                    Some(hva_frame) => {
                        let next_unit: UnitId = api.unit_of_frame(hva_frame);
                        self.emit(next_unit, api);
                    }
                    None => self.translation_failed += 1,
                }
                // Recovery boost: stream GVA-successors deeper
                // in-window (`depth` of them for clone boot streaming).
                if api.recovery_mode() {
                    let mut gva_page = next_gva_page;
                    for _ in 2..=self.depth {
                        gva_page += unit_frames;
                        if let Some(hva_frame) = api.gva_to_hva(gva_page, ctx.cr3) {
                            let u2: UnitId = api.unit_of_frame(hva_frame);
                            self.emit(u2, api);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, MmConfig, SwCost, VmConfig};
    use crate::introspect::FaultCtx;
    use crate::mm::Mm;
    use crate::sim::Rng;
    use crate::types::{PageSize, UnitState};
    use crate::vm::{AccessResult, Vm};

    fn setup(scramble: f64) -> (Mm, Vm, Rng) {
        let mm = Mm::new(&MmConfig::default(), 256, 4096, &SwCost::default(), 0);
        let cfg = VmConfig {
            frames: 256,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(7);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm, rng)
    }

    #[test]
    fn gva_mode_prefetches_gva_successor() {
        let (mut mm, mut vm, mut rng) = setup(1.0);
        let p = vm.spawn_process(256);
        mm.add_policy(Box::new(LinearPf::new(PfMode::Gva)));
        // Touch gva pages 10 and 11 so guest mappings exist; find units.
        let u10 = match vm.access(0, p, 10, false, 0, 0, &mut rng) {
            AccessResult::Fault(f) => f.unit,
            _ => panic!(),
        };
        let u11 = match vm.access(0, p, 11, false, 0, 0, &mut rng) {
            AccessResult::Fault(f) => f.unit,
            _ => panic!(),
        };
        // Both swapped out.
        mm.core.states[u10 as usize] = UnitState::Swapped;
        mm.core.states[u11 as usize] = UnitState::Swapped;
        let cr3 = vm.processes[p].cr3;
        mm.ring.push(FaultCtx { cr3, ip: 0x40, gva: 10 * 4096, gpa_frame: u10 });
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit: u10,
                gpa_frame: u10,
                gva_page: 10,
                cr3,
                ip: 0x40,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        };
        mm.on_fault(&vm, &ev, 0);
        // The GVA successor's *unit* (u11, scrambled != u10+1) is queued.
        assert!(mm.core.queue.contains(u11), "gva successor not prefetched");
        assert_eq!(mm.core.counters.prefetch_issued, 1);
    }

    #[test]
    fn hva_mode_prefetches_physical_successor() {
        let (mut mm, vm, _) = setup(1.0);
        mm.add_policy(Box::new(LinearPf::new(PfMode::Hva)));
        mm.core.states[20] = UnitState::Swapped;
        mm.core.states[21] = UnitState::Swapped;
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit: 20,
                gpa_frame: 20,
                gva_page: 99,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        };
        mm.on_fault(&vm, &ev, 0);
        assert!(mm.core.queue.contains(21));
    }

    #[test]
    fn tier_aware_mode_skips_pool_resident_targets() {
        let (mut mm, vm, _) = setup(1.0);
        mm.add_policy(Box::new(LinearPf::tier_aware(PfMode::Hva)));
        mm.core.states[20] = UnitState::Swapped;
        mm.core.states[21] = UnitState::Swapped;
        // Unit 21's swap copy sits in the compressed pool: a fault on it
        // is already I/O-free, so the prefetch is suppressed.
        mm.core.set_backend_tier(21, Some(crate::storage::SwapTier::Pool));
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit: 20,
                gpa_frame: 20,
                gva_page: 99,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        };
        mm.on_fault(&vm, &ev, 0);
        assert_eq!(mm.core.counters.prefetch_issued, 0);
        // But an NVMe-resident target is still prefetched.
        mm.core.states[30] = UnitState::Swapped;
        mm.core.states[31] = UnitState::Swapped;
        mm.core.set_backend_tier(31, Some(crate::storage::SwapTier::Nvme));
        let mut ev2 = ev;
        ev2.fault.unit = 30;
        ev2.fault.gpa_frame = 30;
        mm.on_fault(&vm, &ev2, 1);
        assert!(mm.core.queue.contains(31));
    }

    #[test]
    fn boot_stream_depth_streams_ahead_only_in_recovery_window() {
        let (mut mm, vm, _) = setup(1.0);
        mm.add_policy(Box::new(LinearPf::boot_stream(PfMode::Hva, 4)));
        for u in 20..=24 {
            mm.core.states[u] = UnitState::Swapped;
        }
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit: 20,
                gpa_frame: 20,
                gva_page: 99,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        };
        // Outside the window: just the single successor.
        mm.on_fault(&vm, &ev, 0);
        assert!(mm.core.queue.contains(21));
        assert_eq!(mm.core.counters.prefetch_issued, 1);
        // Inside the window: depth successors stream ahead (21 is
        // already queued, so 22..24 are the new issues).
        mm.core.recovery_until = 1_000;
        mm.on_fault(&vm, &ev, 10);
        for u in 21..=24 {
            assert!(mm.core.queue.contains(u), "unit {u} not streamed");
        }
        assert_eq!(mm.core.counters.prefetch_issued, 1 + 3);
    }

    #[test]
    fn gva_mode_tolerates_missing_context() {
        let (mut mm, vm, _) = setup(1.0);
        mm.add_policy(Box::new(LinearPf::new(PfMode::Gva)));
        mm.core.states[5] = UnitState::Swapped;
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit: 5,
                gpa_frame: 5,
                gva_page: 5,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: 0,
            delivered_at: 0,
        };
        // No ring entry pushed: ctx is None; must not panic or prefetch.
        mm.on_fault(&vm, &ev, 0);
        assert_eq!(mm.core.counters.prefetch_issued, 0);
    }
}
