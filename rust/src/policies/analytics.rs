//! Reclaimer analytics: the contract between the dt-reclaimer / SYS-R
//! policies and their compute backends.
//!
//! Two implementations exist:
//! * [`NativeAnalytics`] — plain Rust, mirrors `python/compile/kernels/
//!   ref.py` exactly (differential-tested against the artifact).
//! * [`crate::runtime::XlaAnalytics`] — executes the AOT artifacts
//!   (`artifacts/dt_reclaim.hlo.txt`, `artifacts/ert_victim.hlo.txt`)
//!   lowered from the L2 JAX pipeline + L1 Pallas kernel via PJRT.
//!
//! Both run *off* the page-fault critical path (paper §4.3).

use std::collections::VecDeque;

use crate::types::Bitmap;

/// Build an H-row borrowed analytics window from a history ring:
/// missing old rows are padded with a shared zero row (resized to `n`
/// on demand), newer rows are borrowed from the ring. No bitmap is
/// cloned — the ring-of-references fix for the ROADMAP-flagged
/// per-scan-tick `window()` clones, shared by the dt-reclaimer and the
/// §6.4 enhanced-Linux baseline.
pub fn window_refs<'a>(
    zero_pad: &'a mut Bitmap,
    ring: &'a VecDeque<Bitmap>,
    history: usize,
    n: usize,
) -> Vec<&'a Bitmap> {
    if zero_pad.len() != n {
        *zero_pad = Bitmap::new(n);
    }
    let missing = history.saturating_sub(ring.len());
    std::iter::repeat(&*zero_pad)
        .take(missing)
        .chain(ring.iter())
        .collect()
}

/// Output of one dt-reclaim analytics pass.
#[derive(Debug, Clone)]
pub struct DtOutput {
    /// Scans since last access per unit (H = never in window).
    pub age: Vec<f32>,
    /// Accesses in window per unit.
    pub count: Vec<f32>,
    /// Access-distance histogram, buckets 0..=H.
    pub histogram: Vec<f32>,
    pub proposed: f32,
    pub smoothed: f32,
}

/// dt-reclaimer analytics backend (L2 `dt_reclaim` graph). `Send`
/// because the owning policy rides its machine onto a fleet worker
/// thread between fleet ticks.
pub trait ColdAnalytics: Send {
    /// `hist` is the window of access bitmaps, oldest first, all of the
    /// same length; `hist.len() == H`. Rows are borrowed (`&Bitmap`) so
    /// callers keeping a history ring pass references instead of
    /// cloning H bitmaps per scan tick (the PR 1 ROADMAP flagged that
    /// clone; see ARCHITECTURE.md "dt-reclaimer window").
    fn dt_reclaim(
        &mut self,
        hist: &[&Bitmap],
        target_rate: f32,
        prev_threshold: f32,
    ) -> DtOutput;

    fn backend_name(&self) -> &'static str;
}

/// SYS-R victim scorer backend (L2 `ert_victim` graph). `Send` for the
/// same reason as [`ColdAnalytics`].
pub trait ErtScorer: Send {
    /// Pick argmax |ert - dt| over valid entries; returns (index, score)
    /// and applies the countdown to `ert` in place.
    fn victim(&mut self, ert: &mut [f32], valid: &[f32], dt: f32) -> (usize, f32);

    fn backend_name(&self) -> &'static str;
}

/// Threshold smoothing factor — must match `python/compile/model.py`.
pub const SMOOTHING: f32 = 0.5;

/// Pure-Rust analytics, the reference implementation.
#[derive(Debug, Default)]
pub struct NativeAnalytics;

impl NativeAnalytics {
    pub fn new() -> Self {
        NativeAnalytics
    }

    /// (age, count, distance) per unit — mirrors `coldstats_ref`.
    pub fn coldstats(hist: &[&Bitmap]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let h = hist.len();
        let n = hist.first().map(|b| b.len()).unwrap_or(0);
        let mut age = vec![h as f32; n];
        let mut count = vec![0f32; n];
        let mut dist = vec![h as f32; n];
        let mut last = vec![-1i64; n];
        let mut last2 = vec![-1i64; n];
        for (row, bm) in hist.iter().enumerate() {
            for u in bm.iter_ones() {
                count[u] += 1.0;
                last2[u] = last[u];
                last[u] = row as i64;
            }
        }
        for u in 0..n {
            if last[u] >= 0 {
                age[u] = (h as i64 - 1 - last[u]) as f32;
            }
            if last2[u] >= 0 {
                dist[u] = (last[u] - last2[u]) as f32;
            }
        }
        (age, count, dist)
    }

    /// Histogram + threshold — mirrors `dt_reclaim_ref`.
    pub fn pipeline(
        hist: &[&Bitmap],
        target_rate: f32,
        prev_threshold: f32,
    ) -> DtOutput {
        let h = hist.len();
        let (age, count, dist) = Self::coldstats(hist);
        let mut histogram = vec![0f32; h + 1];
        for u in 0..age.len() {
            if count[u] >= 1.0 {
                histogram[dist[u] as usize] += 1.0;
            }
        }
        // Bucket H (seen < 2 times: unknown distance) and bucket 0 are
        // excluded from the rate — see python/compile/model.py.
        let mut measured = histogram.clone();
        measured[h] = 0.0;
        measured[0] = 0.0;
        let total: f32 = measured.iter().sum();
        let proposed = if total <= 0.0 {
            h as f32
        } else {
            let mut tail = vec![0f32; h + 2];
            for t in (0..=h).rev() {
                tail[t] = tail[t + 1] + measured[t];
            }
            (1..=h)
                .find(|&t| tail[t] / total <= target_rate)
                .unwrap_or(h) as f32
        };
        let smoothed = SMOOTHING * prev_threshold + (1.0 - SMOOTHING) * proposed;
        DtOutput { age, count, histogram, proposed, smoothed }
    }
}

impl ColdAnalytics for NativeAnalytics {
    fn dt_reclaim(
        &mut self,
        hist: &[&Bitmap],
        target_rate: f32,
        prev_threshold: f32,
    ) -> DtOutput {
        Self::pipeline(hist, target_rate, prev_threshold)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

impl ErtScorer for NativeAnalytics {
    fn victim(&mut self, ert: &mut [f32], valid: &[f32], dt: f32) -> (usize, f32) {
        let mut best = (0usize, f32::NEG_INFINITY);
        for i in 0..ert.len() {
            if valid[i] > 0.0 {
                ert[i] -= dt;
                let s = ert[i].abs();
                if s > best.1 {
                    best = (i, s);
                }
            }
        }
        best
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bm(n: usize, ones: &[usize]) -> Bitmap {
        let mut b = Bitmap::new(n);
        for &i in ones {
            b.set(i);
        }
        b
    }

    fn refs(hist: &[Bitmap]) -> Vec<&Bitmap> {
        hist.iter().collect()
    }

    #[test]
    fn coldstats_matches_python_ref_semantics() {
        // H=4, N=3: unit0 accessed rows {0,2}, unit1 row {3}, unit2 never.
        let hist = vec![
            bm(3, &[0]),
            bm(3, &[]),
            bm(3, &[0]),
            bm(3, &[1]),
        ];
        let (age, count, dist) = NativeAnalytics::coldstats(&refs(&hist));
        assert_eq!(age, vec![1.0, 0.0, 4.0]);
        assert_eq!(count, vec![2.0, 1.0, 0.0]);
        assert_eq!(dist, vec![2.0, 4.0, 4.0]);
    }

    #[test]
    fn threshold_semantics() {
        // All distances = 1 (hot): with any target, threshold proposes 2+
        // (tail(2) = 0 <= target).
        let hist = vec![bm(4, &[0, 1]); 8];
        let out = NativeAnalytics::pipeline(&refs(&hist), 0.02, 8.0);
        assert_eq!(out.proposed, 2.0);
        assert_eq!(out.smoothed, 0.5 * 8.0 + 0.5 * 2.0);
    }

    #[test]
    fn empty_history_proposes_max() {
        let hist = vec![bm(4, &[]); 6];
        let out = NativeAnalytics::pipeline(&refs(&hist), 0.02, 3.0);
        assert_eq!(out.proposed, 6.0);
    }

    #[test]
    fn ert_victim_native() {
        let mut n = NativeAnalytics::new();
        let mut ert = vec![3.0, -10.0, 5.0];
        let valid = vec![1.0, 0.0, 1.0];
        let (idx, score) = n.victim(&mut ert, &valid, 1.0);
        assert_eq!(idx, 2);
        assert_eq!(score, 4.0);
        assert_eq!(ert, vec![2.0, -10.0, 4.0]); // countdown only valid
    }
}
