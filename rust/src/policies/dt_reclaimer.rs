//! The dt-reclaimer (paper §5.4): the default proactive reclaimer.
//!
//! Maintains a ring of the last `H` access bitmaps from the EPT scanner
//! and, each interval, runs the access-distance analytics (L1 Pallas +
//! L2 JAX pipeline, or the native fallback) to derive a reclamation
//! threshold such that at most `target_promotion_rate` of the working
//! set is predicted to fault next interval. Units whose age reaches the
//! (smoothed) threshold are requested for reclaim.
//!
//! Paper §6.4 detail reproduced here: pages that *faulted* since the
//! last scan are OR-ed into the next bitmap — the kernel baseline cannot
//! see those accesses, which makes it over-aggressive.

use std::collections::VecDeque;

use crate::mm::{Policy, PolicyApi, PolicyEvent};
use crate::policies::analytics::ColdAnalytics;
use crate::storage::TierHint;
use crate::types::{Bitmap, GranularityMode, Time, UnitId, UnitState, REGION_UNITS};

pub struct DtReclaimer {
    backend: Box<dyn ColdAnalytics>,
    history: usize,
    target_rate: f32,
    threshold: f32,
    ring: VecDeque<Bitmap>,
    /// Shared all-zero pad row for a not-yet-full ring, so the window
    /// borrows H references instead of cloning H bitmaps per scan tick
    /// (the ROADMAP-flagged `window()` inefficiency, fixed in PR 2).
    zero_pad: Bitmap,
    /// Units faulted since the last scan (folded into the next bitmap).
    faulted: Option<Bitmap>,
    /// Last computed per-unit ages (for WSS estimation).
    pub last_ages: Vec<f32>,
    pub reclaims_requested: u64,
    /// Reclaims routed straight to NVMe (maximally cold: age == H).
    pub nvme_routed: u64,
    pub analytics_runs: u64,
    /// WSS estimate: units with age < threshold at the last run.
    pub wss_estimate_units: u64,
    /// Major refaults per granularity region since the last analytics
    /// run (PR 8, `--granularity auto`): a 2MB-backed region that keeps
    /// refaulting wastes a whole region of DRAM per touch — split it.
    region_refaults: Vec<u16>,
    /// Split requests issued under `--granularity auto`.
    pub splits_requested: u64,
    /// Collapse requests issued under `--granularity auto`.
    pub collapses_requested: u64,
    /// Straggler prefetches issued to unblock a collapse (PR 9 bugfix):
    /// a split region that turned uniformly hot keeps Swapped stragglers
    /// from split time, so the fully-resident collapse gate alone never
    /// fires again.
    pub promotions_requested: u64,
    /// Drive the tiered backend's pool-admission threshold from the
    /// age histogram instead of the fixed config value (PR 8 satellite).
    adaptive_admission: bool,
    /// Last admission percentage sent (avoid re-sending every run).
    last_admission: Option<u8>,
}

impl DtReclaimer {
    pub fn new(backend: Box<dyn ColdAnalytics>, history: usize, target_rate: f64) -> Self {
        DtReclaimer {
            backend,
            history: history.max(2),
            target_rate: target_rate as f32,
            threshold: history as f32, // start maximally conservative
            ring: VecDeque::new(),
            zero_pad: Bitmap::default(),
            faulted: None,
            last_ages: vec![],
            reclaims_requested: 0,
            nvme_routed: 0,
            analytics_runs: 0,
            wss_estimate_units: 0,
            region_refaults: vec![],
            splits_requested: 0,
            collapses_requested: 0,
            promotions_requested: 0,
            adaptive_admission: false,
            last_admission: None,
        }
    }

    /// Enable histogram-driven pool admission (PR 8 satellite): the
    /// reclaimer retunes the backend's compressibility threshold from
    /// the warm/cold mix of each reclaim batch.
    pub fn with_adaptive_admission(mut self, on: bool) -> Self {
        self.adaptive_admission = on;
        self
    }

    fn note_fault(&mut self, unit: UnitId, units: usize) {
        let bm = self
            .faulted
            .get_or_insert_with(|| Bitmap::new(units));
        bm.set(unit as usize);
    }
}

impl Policy for DtReclaimer {
    fn name(&self) -> &'static str {
        "dt-reclaimer"
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        match ev {
            PolicyEvent::PageFault { unit, major, .. } => {
                self.note_fault(*unit, api.units() as usize);
                // Auto granularity: a major fault on a 2MB-backed base
                // re-pulled a whole region from the backing store.
                if *major
                    && api.granularity_mode() == GranularityMode::Auto
                    && api.region_huge(*unit / REGION_UNITS)
                {
                    let r = (*unit / REGION_UNITS) as usize;
                    if self.region_refaults.len() <= r {
                        self.region_refaults.resize(r + 1, 0);
                    }
                    self.region_refaults[r] = self.region_refaults[r].saturating_add(1);
                }
            }
            PolicyEvent::ScanBitmap { bitmap, now } => {
                let n = bitmap.len();
                let mut merged = (*bitmap).clone();
                if let Some(f) = self.faulted.take() {
                    if f.len() == n {
                        merged.or_assign(&f);
                    }
                }
                self.ring.push_back(merged);
                while self.ring.len() > self.history {
                    self.ring.pop_front();
                }
                // Need some real history before acting.
                if self.ring.len() < self.history.min(4) {
                    return;
                }
                // Ring-of-references window: a unit not seen since the
                // window began is genuinely cold (age saturates at H).
                let window = crate::policies::analytics::window_refs(
                    &mut self.zero_pad,
                    &self.ring,
                    self.history,
                    n,
                );
                let out = self.backend.dt_reclaim(
                    &window,
                    self.target_rate,
                    self.threshold,
                );
                self.analytics_runs += 1;
                self.threshold = out.smoothed;
                let cut = self.threshold;
                let h_max = self.history as f32;
                // Auto granularity (PR 8): manage the region overlay
                // *before* issuing reclaims, so a region we are about to
                // collapse isn't shredded into per-4k reclaims first.
                // `region_op` marks regions with a pending split or
                // collapse this run; the reclaim loop leaves them alone.
                let regions = n.div_ceil(REGION_UNITS as usize);
                let mut region_op: Vec<bool> = Vec::new();
                if api.granularity_mode() == GranularityMode::Auto {
                    region_op = vec![false; regions];
                    self.region_refaults.resize(regions, 0);
                    for r in 0..regions as u64 {
                        let refaults = self.region_refaults[r as usize];
                        self.region_refaults[r as usize] = 0;
                        let base = (r * REGION_UNITS) as usize;
                        let span = (n - base).min(REGION_UNITS as usize);
                        if api.region_huge(r) {
                            // Repeated refaults mean the region mixes
                            // hot and cold at sub-2MB grain: each touch
                            // re-pulls 512 units. Split it.
                            if refaults >= 2 {
                                api.split_region(r);
                                self.splits_requested += 1;
                                region_op[r as usize] = true;
                            }
                        } else if refaults == 0 {
                            // Collapse a quiet split region back to 2MB
                            // once the whole span is resident and sits
                            // on one side of the cut (uniformly hot, or
                            // uniformly cold = one future queue entry
                            // and one receipt instead of 512).
                            let mut resident = 0usize;
                            let mut hot = 0usize;
                            let mut stragglers: Vec<UnitId> = vec![];
                            for u in base..base + span {
                                match api.page_state(u as UnitId) {
                                    UnitState::Resident => resident += 1,
                                    UnitState::Swapped => stragglers.push(u as UnitId),
                                    _ => {}
                                }
                                if out.age[u] < cut {
                                    hot += 1;
                                }
                            }
                            if resident == span && (hot == 0 || hot == span) {
                                api.collapse_region(r);
                                self.collapses_requested += 1;
                                region_op[r as usize] = true;
                            } else if !stragglers.is_empty()
                                && resident + stragglers.len() == span
                                && hot * 8 >= span * 7
                            {
                                // Dense-touch promotion (PR 9 bugfix): a
                                // region split while it mixed hot and
                                // cold can turn uniformly hot later, but
                                // the cold minority swapped out around
                                // split time stays Swapped forever — no
                                // access ever lands on it — so the
                                // fully-resident gate above can never
                                // fire and the region pays 512 per-unit
                                // scan bits indefinitely. Pull the
                                // stragglers back in; once they land the
                                // span is resident and uniformly hot and
                                // a later run collapses it.
                                for &u in &stragglers {
                                    api.prefetch(u);
                                }
                                self.promotions_requested += stragglers.len() as u64;
                                region_op[r as usize] = true;
                            }
                        }
                    }
                }
                let mut wss = 0u64;
                let mut cold_reclaims = 0u64;
                let mut warm_reclaims = 0u64;
                for u in 0..n {
                    if out.age[u] < cut {
                        // A 2MB-backed base stands for its whole span in
                        // the WSS estimate.
                        wss += if u as u64 % REGION_UNITS == 0
                            && api.region_huge(u as u64 / REGION_UNITS)
                        {
                            (n - u).min(REGION_UNITS as usize) as u64
                        } else {
                            1
                        };
                    }
                    if !region_op.is_empty() && region_op[u / REGION_UNITS as usize] {
                        continue; // pending split/collapse owns this region
                    }
                    if out.age[u] >= cut
                        && api.page_state(u as UnitId) == UnitState::Resident
                    {
                        if out.age[u] >= h_max {
                            // Never seen in the whole window: predicted
                            // to stay cold — bypass the compressed pool
                            // so it doesn't churn capacity.
                            api.reclaim_to(u as UnitId, TierHint::Nvme);
                            self.nvme_routed += 1;
                            cold_reclaims += 1;
                        } else {
                            api.reclaim(u as UnitId);
                            warm_reclaims += 1;
                        }
                        self.reclaims_requested += 1;
                    }
                }
                // Histogram-driven pool admission (PR 8 satellite): a
                // warm-dominated reclaim batch is likely to refault, so
                // open the compressed pool up; a cold-dominated batch
                // heads to NVMe anyway, so keep the pool selective.
                if self.adaptive_admission && cold_reclaims + warm_reclaims > 0 {
                    let pct = (50 + warm_reclaims * 50 / (cold_reclaims + warm_reclaims)) as u8;
                    if self.last_admission != Some(pct) {
                        api.set_pool_admission(pct);
                        self.last_admission = Some(pct);
                    }
                }
                self.wss_estimate_units = wss;
                self.last_ages = out.age;
                api.register_parameter("dt.threshold", self.threshold as f64);
                api.register_parameter("dt.wss_units", wss as f64);
                let _ = now;
            }
            _ => {}
        }
    }

    fn timer_interval(&self) -> Option<Time> {
        None // driven by scan events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, MmConfig, SwCost, VmConfig};
    use crate::mm::Mm;
    use crate::policies::analytics::NativeAnalytics;
    use crate::sim::Rng;
    use crate::types::PageSize;
    use crate::vm::Vm;

    fn setup(units: u64) -> (Mm, Vm) {
        let mm_cfg = MmConfig { history: 8, ..Default::default() };
        let mut mm = Mm::new(&mm_cfg, units, 4096, &SwCost::default(), 100_000);
        mm.add_policy(Box::new(DtReclaimer::new(
            Box::new(NativeAnalytics::new()),
            8,
            0.02,
        )));
        let cfg = VmConfig {
            frames: units,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(2);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm)
    }

    #[test]
    fn cold_units_get_reclaimed_hot_stay() {
        let (mut mm, vm) = setup(64);
        // Make all units resident.
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        // 8 scans: units 0..8 accessed every scan, rest never.
        for s in 0..8 {
            let mut bm = Bitmap::new(64);
            for u in 0..8 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        // Cold units must be queued for reclaim, hot must not.
        assert!(mm.core.queue.pending_reclaims() > 40);
        for u in 0..8u64 {
            assert!(!mm.core.want_out.get(u as usize), "hot unit {u} reclaimed");
        }
    }

    #[test]
    fn maximally_cold_units_routed_to_nvme() {
        use crate::mm::WorkOutcome;
        use crate::storage::TierHint;
        let (mut mm, vm) = setup(64);
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        for s in 0..8 {
            let mut bm = Bitmap::new(64);
            for u in 0..8 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        // Units never seen in the window have age == H: their swap-outs
        // carry the NVMe bypass hint at pickup.
        let mut nvme_hints = 0;
        while let Some(w) = mm.pick_work(9_000_000_000) {
            if let WorkOutcome::SwapOutWrite { hint, .. } = w {
                assert_eq!(hint, TierHint::Nvme);
                nvme_hints += 1;
            }
        }
        assert!(nvme_hints > 40, "nvme-routed {nvme_hints}");
    }

    #[test]
    fn wss_estimate_tracks_hot_set() {
        let (mut mm, vm) = setup(128);
        for u in 0..128 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 128;
        for s in 0..8 {
            let mut bm = Bitmap::new(128);
            for u in 0..32 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        let wss = mm.core.params.get("dt.wss_units").copied().unwrap();
        assert!((wss - 32.0).abs() <= 4.0, "wss {wss}");
    }

    #[test]
    fn faulted_pages_count_as_accessed() {
        let (mut mm, vm) = setup(32);
        for u in 0..32 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 32;
        // Unit 5 never appears in scan bitmaps but faults continuously.
        for s in 0..8 {
            let ev = crate::uffd::UffdEvent {
                fault: crate::vm::FaultInfo {
                    unit: 5,
                    gpa_frame: 5,
                    gva_page: 5,
                    cr3: 0,
                    ip: 0,
                    write: false,
                    vcpu: 0,
                    pre_cost: 0,
                },
                raised_at: 0,
                delivered_at: 0,
            };
            mm.on_fault(&vm, &ev, s * 1_000_000_000);
            mm.on_scan(&vm, &Bitmap::new(32), s * 1_000_000_000 + 1);
        }
        assert!(
            !mm.core.want_out.get(5),
            "faulting unit must not be reclaimed (paper §6.4)"
        );
    }

    fn setup_mode(units: u64, mode: crate::types::GranularityMode, adaptive: bool) -> (Mm, Vm) {
        let mm_cfg = MmConfig { history: 8, granularity: mode, ..Default::default() };
        let mut mm = Mm::new(&mm_cfg, units, 4096, &SwCost::default(), 100_000);
        mm.add_policy(Box::new(
            DtReclaimer::new(Box::new(NativeAnalytics::new()), 8, 0.02)
                .with_adaptive_admission(adaptive),
        ));
        let cfg = VmConfig {
            frames: units,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(2);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm)
    }

    fn major_fault(mm: &mut Mm, vm: &Vm, unit: u64, now: u64) {
        let ev = crate::uffd::UffdEvent {
            fault: crate::vm::FaultInfo {
                unit,
                gpa_frame: unit,
                gva_page: unit,
                cr3: 0,
                ip: 0,
                write: false,
                vcpu: 0,
                pre_cost: 0,
            },
            raised_at: now,
            delivered_at: now,
        };
        mm.on_fault(vm, &ev, now);
    }

    #[test]
    fn granularity_auto_splits_refaulting_huge_region() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let (mut mm, mut vm) = setup_mode(2 * REGION_UNITS, GranularityMode::Auto, false);
        mm.core.states[0] = UnitState::Swapped;
        // Two refault cycles on region 0's base: swap in, kick out,
        // swap in again — a huge region churning whole-2MB I/O.
        for t in 0..2u64 {
            major_fault(&mut mm, &vm, 0, t * 1000);
            mm.pick_work(t * 1000).unwrap();
            mm.finish_swapin(&mut vm, 0, true, t * 1000 + 1);
            if t == 0 {
                mm.core.request_reclaim(0);
                mm.pick_work(500).unwrap();
                mm.finish_swapout(&mut vm, 0, true, 600);
            }
        }
        for s in 0..4u64 {
            mm.on_scan(&vm, &Bitmap::new(2 * REGION_UNITS as usize), 10_000 + s);
        }
        // The analytics run asked for the split, and the engine applied
        // it (base resident and idle): per-4k tracking from here on.
        let (splits, _) = mm.drain_region_ops();
        assert_eq!(splits, vec![0]);
        assert!(!mm.core.region_huge(0));
        assert_eq!(mm.core.states[1], UnitState::Resident); // fanned out
    }

    #[test]
    fn granularity_auto_collapses_uniform_split_region() {
        use crate::types::{GranularityMode, REGION_UNITS};
        let (mut mm, vm) = setup_mode(2 * REGION_UNITS, GranularityMode::Auto, false);
        // Split region 0 while untouched (trivial), then hand-build a
        // uniformly-resident span.
        mm.core.pending_splits.push(0);
        assert_eq!(mm.drain_region_ops().0, vec![0]);
        for u in 0..REGION_UNITS as usize {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = REGION_UNITS;
        for s in 0..4u64 {
            mm.on_scan(&vm, &Bitmap::new(2 * REGION_UNITS as usize), 10_000 + s);
        }
        // Uniformly cold + resident: the reclaimer asked to collapse it
        // back to one 2MB unit instead of issuing 512 reclaims.
        let (_, collapses) = mm.drain_region_ops();
        assert_eq!(collapses, vec![0]);
        assert!(mm.core.region_huge(0));
        assert_eq!(mm.core.states[0], UnitState::Resident);
        assert_eq!(mm.core.usage_units, REGION_UNITS);
    }

    #[test]
    fn granularity_auto_promotes_dense_hot_region_then_collapses() {
        use crate::mm::WorkOutcome;
        use crate::types::{GranularityMode, REGION_UNITS};
        let (mut mm, mut vm) = setup_mode(2 * REGION_UNITS, GranularityMode::Auto, false);
        // Split region 0 while untouched (trivial), then hand-build the
        // stuck shape: the span turned uniformly hot except for two cold
        // stragglers swapped out around split time. Nothing ever touches
        // a Swapped unit, so the fully-resident collapse gate alone can
        // never fire — the pre-fix reclaimer leaves this split forever.
        mm.core.pending_splits.push(0);
        assert_eq!(mm.drain_region_ops().0, vec![0]);
        let span = REGION_UNITS as usize;
        for u in 0..span {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.states[3] = UnitState::Swapped;
        mm.core.states[7] = UnitState::Swapped;
        mm.core.usage_units = REGION_UNITS - 2;
        for s in 0..4u64 {
            let mut bm = Bitmap::new(2 * REGION_UNITS as usize);
            for u in 0..span {
                if mm.core.states[u] == UnitState::Resident {
                    bm.set(u);
                }
            }
            mm.on_scan(&vm, &bm, 10_000 + s);
        }
        // The dense-touch promotion path prefetched the stragglers
        // instead of collapsing early or giving up.
        assert_eq!(mm.drain_region_ops(), (vec![], vec![]));
        assert_eq!(mm.core.counters.prefetch_issued, 2);
        let mut pulled = vec![];
        while let Some(w) = mm.pick_work(20_000) {
            if let WorkOutcome::SwapIn { unit, .. } = w {
                pulled.push(unit);
            }
        }
        pulled.sort_unstable();
        assert_eq!(pulled, vec![3, 7]);
        for &u in &pulled {
            mm.finish_swapin(&mut vm, u, true, 20_001);
        }
        // Stragglers landed and get touched with the rest of the hot
        // span: the next analytics run sees a fully-resident uniformly
        // hot region and collapses it back to 2MB.
        let mut bm = Bitmap::new(2 * REGION_UNITS as usize);
        for u in 0..span {
            bm.set(u);
        }
        mm.on_scan(&vm, &bm, 30_000);
        let (_, collapses) = mm.drain_region_ops();
        assert_eq!(collapses, vec![0]);
        assert!(mm.core.region_huge(0));
        assert_eq!(mm.core.states[0], UnitState::Resident);
        assert_eq!(mm.core.usage_units, REGION_UNITS);
    }

    #[test]
    fn granularity_adaptive_admission_tracks_reclaim_mix() {
        let (mut mm, vm) = setup_mode(64, crate::types::GranularityMode::Fixed, true);
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        for s in 0..8 {
            let mut bm = Bitmap::new(64);
            for u in 0..8 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        // Every reclaimed unit was maximally cold: the batch is
        // cold-dominated, so the pool stays selective (50%).
        assert_eq!(mm.take_pool_admission(), Some(50));
        assert_eq!(mm.take_pool_admission(), None);
    }
}
