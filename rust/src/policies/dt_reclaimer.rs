//! The dt-reclaimer (paper §5.4): the default proactive reclaimer.
//!
//! Maintains a ring of the last `H` access bitmaps from the EPT scanner
//! and, each interval, runs the access-distance analytics (L1 Pallas +
//! L2 JAX pipeline, or the native fallback) to derive a reclamation
//! threshold such that at most `target_promotion_rate` of the working
//! set is predicted to fault next interval. Units whose age reaches the
//! (smoothed) threshold are requested for reclaim.
//!
//! Paper §6.4 detail reproduced here: pages that *faulted* since the
//! last scan are OR-ed into the next bitmap — the kernel baseline cannot
//! see those accesses, which makes it over-aggressive.

use std::collections::VecDeque;

use crate::mm::{Policy, PolicyApi, PolicyEvent};
use crate::policies::analytics::ColdAnalytics;
use crate::storage::TierHint;
use crate::types::{Bitmap, Time, UnitId, UnitState};

pub struct DtReclaimer {
    backend: Box<dyn ColdAnalytics>,
    history: usize,
    target_rate: f32,
    threshold: f32,
    ring: VecDeque<Bitmap>,
    /// Shared all-zero pad row for a not-yet-full ring, so the window
    /// borrows H references instead of cloning H bitmaps per scan tick
    /// (the ROADMAP-flagged `window()` inefficiency, fixed in PR 2).
    zero_pad: Bitmap,
    /// Units faulted since the last scan (folded into the next bitmap).
    faulted: Option<Bitmap>,
    /// Last computed per-unit ages (for WSS estimation).
    pub last_ages: Vec<f32>,
    pub reclaims_requested: u64,
    /// Reclaims routed straight to NVMe (maximally cold: age == H).
    pub nvme_routed: u64,
    pub analytics_runs: u64,
    /// WSS estimate: units with age < threshold at the last run.
    pub wss_estimate_units: u64,
}

impl DtReclaimer {
    pub fn new(backend: Box<dyn ColdAnalytics>, history: usize, target_rate: f64) -> Self {
        DtReclaimer {
            backend,
            history: history.max(2),
            target_rate: target_rate as f32,
            threshold: history as f32, // start maximally conservative
            ring: VecDeque::new(),
            zero_pad: Bitmap::default(),
            faulted: None,
            last_ages: vec![],
            reclaims_requested: 0,
            nvme_routed: 0,
            analytics_runs: 0,
            wss_estimate_units: 0,
        }
    }

    fn note_fault(&mut self, unit: UnitId, units: usize) {
        let bm = self
            .faulted
            .get_or_insert_with(|| Bitmap::new(units));
        bm.set(unit as usize);
    }
}

impl Policy for DtReclaimer {
    fn name(&self) -> &'static str {
        "dt-reclaimer"
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        match ev {
            PolicyEvent::PageFault { unit, .. } => {
                self.note_fault(*unit, api.units() as usize);
            }
            PolicyEvent::ScanBitmap { bitmap, now } => {
                let n = bitmap.len();
                let mut merged = (*bitmap).clone();
                if let Some(f) = self.faulted.take() {
                    if f.len() == n {
                        merged.or_assign(&f);
                    }
                }
                self.ring.push_back(merged);
                while self.ring.len() > self.history {
                    self.ring.pop_front();
                }
                // Need some real history before acting.
                if self.ring.len() < self.history.min(4) {
                    return;
                }
                // Ring-of-references window: a unit not seen since the
                // window began is genuinely cold (age saturates at H).
                let window = crate::policies::analytics::window_refs(
                    &mut self.zero_pad,
                    &self.ring,
                    self.history,
                    n,
                );
                let out = self.backend.dt_reclaim(
                    &window,
                    self.target_rate,
                    self.threshold,
                );
                self.analytics_runs += 1;
                self.threshold = out.smoothed;
                let cut = self.threshold;
                let h_max = self.history as f32;
                let mut wss = 0u64;
                for u in 0..n {
                    if out.age[u] < cut {
                        wss += 1;
                    }
                    if out.age[u] >= cut
                        && api.page_state(u as UnitId) == UnitState::Resident
                    {
                        if out.age[u] >= h_max {
                            // Never seen in the whole window: predicted
                            // to stay cold — bypass the compressed pool
                            // so it doesn't churn capacity.
                            api.reclaim_to(u as UnitId, TierHint::Nvme);
                            self.nvme_routed += 1;
                        } else {
                            api.reclaim(u as UnitId);
                        }
                        self.reclaims_requested += 1;
                    }
                }
                self.wss_estimate_units = wss;
                self.last_ages = out.age;
                api.register_parameter("dt.threshold", self.threshold as f64);
                api.register_parameter("dt.wss_units", wss as f64);
                let _ = now;
            }
            _ => {}
        }
    }

    fn timer_interval(&self) -> Option<Time> {
        None // driven by scan events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, MmConfig, SwCost, VmConfig};
    use crate::mm::Mm;
    use crate::policies::analytics::NativeAnalytics;
    use crate::sim::Rng;
    use crate::types::PageSize;
    use crate::vm::Vm;

    fn setup(units: u64) -> (Mm, Vm) {
        let mm_cfg = MmConfig { history: 8, ..Default::default() };
        let mut mm = Mm::new(&mm_cfg, units, 4096, &SwCost::default(), 100_000);
        mm.add_policy(Box::new(DtReclaimer::new(
            Box::new(NativeAnalytics::new()),
            8,
            0.02,
        )));
        let cfg = VmConfig {
            frames: units,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(2);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm)
    }

    #[test]
    fn cold_units_get_reclaimed_hot_stay() {
        let (mut mm, vm) = setup(64);
        // Make all units resident.
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        // 8 scans: units 0..8 accessed every scan, rest never.
        for s in 0..8 {
            let mut bm = Bitmap::new(64);
            for u in 0..8 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        // Cold units must be queued for reclaim, hot must not.
        assert!(mm.core.queue.pending_reclaims() > 40);
        for u in 0..8u64 {
            assert!(!mm.core.want_out.get(u as usize), "hot unit {u} reclaimed");
        }
    }

    #[test]
    fn maximally_cold_units_routed_to_nvme() {
        use crate::mm::WorkOutcome;
        use crate::storage::TierHint;
        let (mut mm, vm) = setup(64);
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        for s in 0..8 {
            let mut bm = Bitmap::new(64);
            for u in 0..8 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        // Units never seen in the window have age == H: their swap-outs
        // carry the NVMe bypass hint at pickup.
        let mut nvme_hints = 0;
        while let Some(w) = mm.pick_work(9_000_000_000) {
            if let WorkOutcome::SwapOutWrite { hint, .. } = w {
                assert_eq!(hint, TierHint::Nvme);
                nvme_hints += 1;
            }
        }
        assert!(nvme_hints > 40, "nvme-routed {nvme_hints}");
    }

    #[test]
    fn wss_estimate_tracks_hot_set() {
        let (mut mm, vm) = setup(128);
        for u in 0..128 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 128;
        for s in 0..8 {
            let mut bm = Bitmap::new(128);
            for u in 0..32 {
                bm.set(u);
            }
            mm.on_scan(&vm, &bm, s * 1_000_000_000);
        }
        let wss = mm.core.params.get("dt.wss_units").copied().unwrap();
        assert!((wss - 32.0).abs() <= 4.0, "wss {wss}");
    }

    #[test]
    fn faulted_pages_count_as_accessed() {
        let (mut mm, vm) = setup(32);
        for u in 0..32 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 32;
        // Unit 5 never appears in scan bitmaps but faults continuously.
        for s in 0..8 {
            let ev = crate::uffd::UffdEvent {
                fault: crate::vm::FaultInfo {
                    unit: 5,
                    gpa_frame: 5,
                    gva_page: 5,
                    cr3: 0,
                    ip: 0,
                    write: false,
                    vcpu: 0,
                    pre_cost: 0,
                },
                raised_at: 0,
                delivered_at: 0,
            };
            mm.on_fault(&vm, &ev, s * 1_000_000_000);
            mm.on_scan(&vm, &Bitmap::new(32), s * 1_000_000_000 + 1);
        }
        assert!(
            !mm.core.want_out.get(5),
            "faulting unit must not be reclaimed (paper §6.4)"
        );
    }
}
