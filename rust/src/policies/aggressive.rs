//! SYS-Agg (paper §6.7): phase-detecting aggressive reclaimer.
//!
//! Workloads like graph500 run in phases with disjoint working sets.
//! When a phase change happens, the page-fault rate spikes (part of the
//! new working set is swapped out). The policy detects the uptick and
//! enters *reclaim mode*: every resident page joins an "old page set";
//! the EPT is scanned every second, accessed pages leave the set, and up
//! to `per_tick_bytes` of the remaining set is reclaimed per tick until
//! the set drains.

use crate::mm::{Policy, PolicyApi, PolicyEvent};
use crate::types::{Bitmap, Time, UnitState, SEC};

pub struct AggressivePolicy {
    /// Fault-rate uptick factor that triggers reclaim mode.
    uptick_factor: f64,
    /// Minimum faults/window to consider an uptick at all.
    min_faults: u64,
    /// Bytes reclaimed per tick in reclaim mode (paper: 2GB/s).
    per_tick_bytes: u64,
    window_faults: u64,
    baseline_rate: f64,
    old_set: Option<Bitmap>,
    normal_scan_interval: Time,
    pub mode_entries: u64,
    pub reclaimed_units: u64,
}

impl AggressivePolicy {
    pub fn new(normal_scan_interval: Time) -> Self {
        AggressivePolicy {
            uptick_factor: 3.0,
            min_faults: 32,
            per_tick_bytes: 2 << 30,
            window_faults: 0,
            baseline_rate: 0.0,
            old_set: None,
            normal_scan_interval,
            mode_entries: 0,
            reclaimed_units: 0,
        }
    }

    pub fn in_reclaim_mode(&self) -> bool {
        self.old_set.is_some()
    }
}

impl Policy for AggressivePolicy {
    fn name(&self) -> &'static str {
        "sys-agg"
    }

    fn timer_interval(&self) -> Option<Time> {
        Some(SEC)
    }

    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        match ev {
            PolicyEvent::PageFault { .. } => {
                self.window_faults += 1;
            }
            PolicyEvent::Timer { .. } => {
                let rate = self.window_faults as f64;
                self.window_faults = 0;
                if self.old_set.is_none() {
                    let uptick = rate
                        > (self.baseline_rate * self.uptick_factor)
                            .max(self.min_faults as f64);
                    // EMA baseline only updates in normal mode.
                    self.baseline_rate = 0.7 * self.baseline_rate + 0.3 * rate;
                    if uptick {
                        // Enter reclaim mode: all resident units are old.
                        let n = api.units() as usize;
                        let mut set = Bitmap::new(n);
                        for u in 0..n {
                            if api.page_state(u as u64) == UnitState::Resident {
                                set.set(u);
                            }
                        }
                        self.old_set = Some(set);
                        self.mode_entries += 1;
                        api.set_scan_interval(SEC);
                        api.register_parameter("agg.reclaim_mode", 1.0);
                    }
                }
            }
            PolicyEvent::ScanBitmap { bitmap, .. } => {
                let Some(mut set) = self.old_set.take() else {
                    return;
                };
                // Accessed units are not old (word-parallel subtraction).
                set.and_not_assign(bitmap);
                // Reclaim up to the per-tick budget from the old set. The
                // victims are a prefix of iter_ones, so the drained span
                // clears as one word-parallel range op instead of
                // per-unit bit clears.
                let budget =
                    (self.per_tick_bytes / api.core.unit_bytes).max(1) as usize;
                let mut drained_to = None;
                for u in set.iter_ones().take(budget) {
                    api.reclaim(u as u64);
                    drained_to = Some(u);
                    self.reclaimed_units += 1;
                }
                if let Some(hi) = drained_to {
                    set.clear_range(0, hi + 1);
                }
                if set.count_ones() == 0 {
                    // Old set drained: leave reclaim mode.
                    api.set_scan_interval(self.normal_scan_interval);
                    api.register_parameter("agg.reclaim_mode", 0.0);
                } else {
                    self.old_set = Some(set);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, MmConfig, SwCost, VmConfig};
    use crate::mm::Mm;
    use crate::sim::Rng;
    use crate::types::PageSize;
    use crate::vm::Vm;

    fn setup(units: u64) -> (Mm, Vm) {
        let mut mm = Mm::new(&MmConfig::default(), units, 4096, &SwCost::default(), 0);
        mm.add_policy(Box::new(AggressivePolicy::new(60 * SEC)));
        let cfg = VmConfig {
            frames: units,
            vcpus: 1,
            page_size: PageSize::Small,
            scramble: 0.0,
            guest_thp_coverage: 1.0,
        };
        let mut rng = Rng::new(4);
        let vm = Vm::new(&cfg, &HwConfig::default(), &SwCost::default(), &mut rng);
        (mm, vm)
    }

    fn burst_faults(mm: &mut Mm, vm: &Vm, n: u64, t: Time) {
        for i in 0..n {
            let ev = crate::uffd::UffdEvent {
                fault: crate::vm::FaultInfo {
                    unit: i % 4,
                    gpa_frame: i % 4,
                    gva_page: i % 4,
                    cr3: 0,
                    ip: 0,
                    write: false,
                    vcpu: 0,
                    pre_cost: 0,
                },
                raised_at: t,
                delivered_at: t,
            };
            mm.on_fault(vm, &ev, t);
        }
    }

    #[test]
    fn uptick_enters_reclaim_mode_and_drains_old_set() {
        let (mut mm, vm) = setup(64);
        for u in 0..64 {
            mm.core.states[u] = UnitState::Resident;
        }
        mm.core.usage_units = 64;
        // Quiet windows to establish the baseline.
        for k in 0..3 {
            mm.on_timer(&vm, k * SEC);
        }
        // Fault burst -> uptick.
        burst_faults(&mut mm, &vm, 100, 3 * SEC);
        mm.on_timer(&vm, 4 * SEC);
        assert_eq!(mm.core.params.get("agg.reclaim_mode"), Some(&1.0));
        assert_eq!(mm.core.requested_scan_interval, Some(SEC));
        // Scan: units 0..8 hot; everything else drains over ticks.
        let mut hot = Bitmap::new(64);
        for u in 0..8 {
            hot.set(u);
        }
        mm.on_scan(&vm, &hot, 5 * SEC);
        // Budget is huge (2GB / 4kB), so one tick drains the whole set.
        assert_eq!(mm.core.params.get("agg.reclaim_mode"), Some(&0.0));
        assert!(mm.core.queue.pending_reclaims() >= 48);
        for u in 0..8u64 {
            assert!(!mm.core.want_out.get(u as usize), "hot {u} reclaimed");
        }
    }

    #[test]
    fn no_uptick_no_mode() {
        let (mut mm, vm) = setup(16);
        for k in 0..5 {
            burst_faults(&mut mm, &vm, 4, k * SEC);
            mm.on_timer(&vm, k * SEC);
        }
        assert_eq!(mm.core.params.get("agg.reclaim_mode"), None);
    }
}
