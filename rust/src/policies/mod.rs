//! The policy zoo (paper §4.3, §5.4, §6.5-§6.8).
//!
//! Policies are optional modules subscribed to engine events; they can
//! only act through the safe [`crate::mm::PolicyApi`]. This module
//! provides every policy the paper evaluates:
//!
//! * [`dt_reclaimer`] — the default proactive reclaimer (§5.4), built on
//!   the access-distance analytics pipeline that runs as an AOT-compiled
//!   XLA artifact (L1 Pallas + L2 JAX) or a native Rust fallback.
//! * [`lru`] — the default LRU memory-limit reclaimer (§4.3).
//! * [`reuse_dist`] — SYS-R, the reuse-distance (ERT) limit reclaimer
//!   approximating Bélády (§6.5).
//! * [`linear_pf`] — LinearPF next-page prefetcher, GVA vs HVA (§6.6).
//! * [`aggressive`] — SYS-Agg phase-detecting fast reclaimer (§6.7).
//! * [`wsr`] — 4k-WSR working-set restore after a limit lift (§6.8).

pub mod aggressive;
pub mod analytics;
pub mod dt_reclaimer;
pub mod linear_pf;
pub mod lru;
pub mod reuse_dist;
pub mod wsr;

pub use aggressive::AggressivePolicy;
pub use analytics::{ColdAnalytics, DtOutput, ErtScorer, NativeAnalytics};
pub use dt_reclaimer::DtReclaimer;
pub use linear_pf::{LinearPf, PfMode};
pub use lru::LruReclaimer;
pub use reuse_dist::ReuseDistReclaimer;
pub use wsr::WsrPolicy;
