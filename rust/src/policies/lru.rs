//! Default memory-limit reclaimer (paper §4.3): LRU-based, invoked
//! synchronously on the fault path, so victim selection must be fast.
//!
//! True-LRU order matters (e.g. §6.6/§6.8 depend on eviction following
//! recency), but a full scan per victim would sit on the fault path.
//! We amortize: when the victim cache drains, rank resident units by
//! the engine's shared `last_touch` and keep the oldest `BATCH`; each
//! `victim()` call then pops in O(1), re-validating against touches
//! that happened after ranking.

use crate::mm::{EngineCore, LimitReclaimer, PolicyEvent};
use crate::types::{Time, UnitId, UnitState};

const BATCH: usize = 64;

pub struct LruReclaimer {
    /// Victim cache: (last_touch at ranking time, unit), oldest last.
    cache: Vec<(Time, UnitId)>,
    pub victims: u64,
    pub rankings: u64,
}

impl Default for LruReclaimer {
    fn default() -> Self {
        Self::new()
    }
}

impl LruReclaimer {
    pub fn new() -> Self {
        LruReclaimer { cache: vec![], victims: 0, rankings: 0 }
    }

    fn eligible(core: &EngineCore, u: usize) -> bool {
        core.states[u] == UnitState::Resident
            && !core.want_out.get(u)
            && !core.locks.is_locked(u as UnitId)
    }

    fn rank(&mut self, core: &EngineCore) {
        self.rankings += 1;
        let mut all: Vec<(Time, UnitId)> = (0..core.states.len())
            .filter(|&u| Self::eligible(core, u))
            .map(|u| (core.last_touch[u], u as UnitId))
            .collect();
        // Oldest first; keep only the front batch, store reversed so
        // pop() yields the oldest.
        all.sort_unstable();
        all.truncate(BATCH);
        all.reverse();
        self.cache = all;
    }
}

impl LimitReclaimer for LruReclaimer {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn note(&mut self, _ev: &PolicyEvent) {}

    fn victim(&mut self, core: &EngineCore, _now: Time) -> Option<UnitId> {
        loop {
            if self.cache.is_empty() {
                self.rank(core);
                if self.cache.is_empty() {
                    return None;
                }
            }
            while let Some((t, u)) = self.cache.pop() {
                // Re-validate: still resident, not re-touched since
                // ranking, not locked.
                if Self::eligible(core, u as usize) && core.last_touch[u as usize] == t {
                    self.victims += 1;
                    return Some(u);
                }
            }
            // Whole cache was stale: re-rank once more; if that yields
            // nothing eligible, give up.
            self.rank(core);
            if self.cache.is_empty() {
                return None;
            }
            let (t, u) = self.cache.pop().unwrap();
            if Self::eligible(core, u as usize) && core.last_touch[u as usize] == t {
                self.victims += 1;
                return Some(u);
            }
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SEC;

    fn core_with(resident: &[(usize, Time)]) -> EngineCore {
        let n = resident.iter().map(|(u, _)| *u).max().unwrap_or(0) + 1;
        let mut c = EngineCore::new(n as u64, 4096, None);
        for &(u, t) in resident {
            c.states[u] = UnitState::Resident;
            c.last_touch[u] = t;
        }
        c
    }

    #[test]
    fn picks_globally_oldest() {
        let mut core = core_with(&[(0, 5 * SEC), (1, 0), (2, 3 * SEC)]);
        let mut r = LruReclaimer::new();
        for want in [1u64, 2, 0] {
            let v = r.victim(&core, 6 * SEC).unwrap();
            assert_eq!(v, want);
            core.want_out.set(v as usize); // engine does this on reclaim
        }
        assert_eq!(r.victim(&core, 6 * SEC), None);
    }

    #[test]
    fn skips_locked_and_nonresident() {
        let mut core = core_with(&[(0, 0), (1, 0)]);
        core.locks.lock(0);
        core.states[1] = UnitState::Swapped;
        let mut r = LruReclaimer::new();
        assert_eq!(r.victim(&core, SEC), None);
    }

    #[test]
    fn stale_cache_entries_are_revalidated() {
        let mut core = core_with(&[(0, 0), (1, 1), (2, 2)]);
        let mut r = LruReclaimer::new();
        assert_eq!(r.victim(&core, SEC), Some(0));
        // Unit 1 touched after the ranking: must not be returned with
        // its stale timestamp.
        core.last_touch[1] = 10 * SEC;
        let v = r.victim(&core, SEC).unwrap();
        assert_eq!(v, 2);
    }

    #[test]
    fn eviction_follows_recency_order() {
        // 100 units touched in sequence: eviction order must match.
        let pairs: Vec<(usize, Time)> = (0..100).map(|u| (u, u as Time * 10)).collect();
        let mut core = core_with(&pairs);
        let mut r = LruReclaimer::new();
        let mut got: Vec<UnitId> = vec![];
        for _ in 0..100 {
            let v = r.victim(&core, SEC).unwrap();
            core.want_out.set(v as usize); // engine does this on reclaim
            got.push(v);
        }
        let want: Vec<UnitId> = (0..100).collect();
        assert_eq!(got, want);
    }
}
