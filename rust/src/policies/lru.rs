//! Default memory-limit reclaimer (paper §4.3): LRU-based, invoked
//! synchronously on the fault path, so victim selection must be fast.
//!
//! True-LRU order matters (e.g. §6.6/§6.8 depend on eviction following
//! recency), but the old implementation re-sorted every resident unit
//! (O(N log N)) each time a 64-victim cache drained — squarely on the
//! fault path. This version maintains recency *incrementally*: an
//! intrusive doubly-linked LRU list over a preallocated node arena,
//! advanced in O(1) by the engine's [`LimitReclaimer::touch`]
//! notifications (faults, swap-in completions and `ScanBitmap` hits all
//! flow through [`crate::mm::Mm::note_touch`]). `victim()` pops the
//! head — O(1) amortized.
//!
//! Units whose `last_touch` is mutated *without* a touch notification
//! (tests poking the core directly, warm-start priming gone stale) are
//! handled by two safety nets: a per-node stamp that detects the
//! mismatch at pop time and re-queues the node as most-recent, and a
//! full rebuild — the old sort, now only a fallback — whenever a walk
//! finds no eligible unit.

use crate::mm::{EngineCore, LimitReclaimer, PolicyEvent};
use crate::types::{Time, UnitId, UnitState};

/// Arena null link.
const NIL: u32 = u32::MAX;

pub struct LruReclaimer {
    /// Oldest (next victim) end of the intrusive list.
    head: u32,
    /// Most-recently-touched end.
    tail: u32,
    /// Node arena: per-unit prev/next links (NIL-terminated).
    prev: Vec<u32>,
    next: Vec<u32>,
    /// `last_touch` value the unit had when (re)linked; a mismatch with
    /// the core means the unit was touched behind our back.
    stamp: Vec<Time>,
    in_list: Vec<bool>,
    pub victims: u64,
    /// Full rebuilds (the old per-batch sort; now only the fallback).
    pub rankings: u64,
}

impl Default for LruReclaimer {
    fn default() -> Self {
        Self::new()
    }
}

impl LruReclaimer {
    pub fn new() -> Self {
        LruReclaimer {
            head: NIL,
            tail: NIL,
            prev: vec![],
            next: vec![],
            stamp: vec![],
            in_list: vec![],
            victims: 0,
            rankings: 0,
        }
    }

    fn eligible(core: &EngineCore, u: usize) -> bool {
        core.states[u] == UnitState::Resident
            && !core.want_out.get(u)
            && !core.locks.is_locked(u as UnitId)
    }

    fn ensure(&mut self, n: usize) {
        if self.prev.len() < n {
            self.prev.resize(n, NIL);
            self.next.resize(n, NIL);
            self.stamp.resize(n, 0);
            self.in_list.resize(n, false);
        }
    }

    fn unlink(&mut self, u: usize) {
        let p = self.prev[u];
        let x = self.next[u];
        if p == NIL {
            self.head = x;
        } else {
            self.next[p as usize] = x;
        }
        if x == NIL {
            self.tail = p;
        } else {
            self.prev[x as usize] = p;
        }
        self.prev[u] = NIL;
        self.next[u] = NIL;
        self.in_list[u] = false;
    }

    fn push_tail(&mut self, u: usize, t: Time) {
        self.stamp[u] = t;
        self.prev[u] = self.tail;
        self.next[u] = NIL;
        if self.tail == NIL {
            self.head = u as u32;
        } else {
            self.next[self.tail as usize] = u as u32;
        }
        self.tail = u as u32;
        self.in_list[u] = true;
    }

    /// Fallback resynchronization: sort eligible units by
    /// `(last_touch, unit)` — exactly the old ranking — and relink the
    /// whole list in that order. Only runs when the incremental list has
    /// no eligible unit (fresh reclaimer, or state mutated out-of-band).
    fn rebuild(&mut self, core: &EngineCore) {
        self.rankings += 1;
        let n = core.states.len();
        self.ensure(n);
        self.head = NIL;
        self.tail = NIL;
        self.prev.fill(NIL);
        self.next.fill(NIL);
        self.in_list.fill(false);
        let mut all: Vec<(Time, UnitId)> = (0..n)
            .filter(|&u| Self::eligible(core, u))
            .map(|u| (core.last_touch[u], u as UnitId))
            .collect();
        all.sort_unstable();
        for (t, u) in all {
            self.push_tail(u as usize, t);
        }
    }
}

impl LimitReclaimer for LruReclaimer {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn note(&mut self, _ev: &PolicyEvent) {}

    /// O(1): move (or insert) the unit at the most-recent end.
    fn touch(&mut self, unit: UnitId, now: Time) {
        let u = unit as usize;
        self.ensure(u + 1);
        if self.in_list[u] {
            self.unlink(u);
        }
        self.push_tail(u, now);
    }

    fn victim(&mut self, core: &EngineCore, _now: Time) -> Option<UnitId> {
        let n = core.states.len();
        self.ensure(n);
        let mut rebuilt = false;
        loop {
            let mut cur = self.head;
            // Each node is visited at most twice per walk: once in place
            // and once more if a stale stamp moved it to the tail.
            let mut budget = 2 * self.prev.len() + 2;
            while cur != NIL && budget > 0 {
                budget -= 1;
                let u = cur as usize;
                let nx = self.next[u];
                if u >= n {
                    // Arena outlived a smaller core (test reuse): drop.
                    self.unlink(u);
                } else if core.last_touch[u] != self.stamp[u] {
                    // Touched without a notification: treat as a fresh
                    // touch and re-queue at the most-recent end.
                    let t = core.last_touch[u];
                    self.unlink(u);
                    self.push_tail(u, t);
                } else if Self::eligible(core, u) {
                    self.unlink(u);
                    self.victims += 1;
                    return Some(u as UnitId);
                } else if core.states[u] != UnitState::Resident {
                    // Swapped/in-flight: re-entry to Resident always goes
                    // through a completion that touches, so drop the node.
                    self.unlink(u);
                }
                // else: locked or want_out but still resident — transient;
                // keep the node in place so the unit keeps its LRU slot.
                cur = nx;
            }
            if rebuilt {
                return None;
            }
            self.rebuild(core);
            if self.head == NIL {
                return None;
            }
            rebuilt = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::types::SEC;

    fn core_with(resident: &[(usize, Time)]) -> EngineCore {
        let n = resident.iter().map(|(u, _)| *u).max().unwrap_or(0) + 1;
        let mut c = EngineCore::new(n as u64, 4096, None);
        for &(u, t) in resident {
            c.states[u] = UnitState::Resident;
            c.last_touch[u] = t;
        }
        c
    }

    /// The old sort-based ranking as a pure function: globally oldest
    /// eligible unit by (last_touch, unit).
    fn oracle_victim(core: &EngineCore) -> Option<UnitId> {
        (0..core.states.len())
            .filter(|&u| LruReclaimer::eligible(core, u))
            .map(|u| (core.last_touch[u], u as UnitId))
            .min()
            .map(|(_, u)| u)
    }

    #[test]
    fn picks_globally_oldest() {
        let mut core = core_with(&[(0, 5 * SEC), (1, 0), (2, 3 * SEC)]);
        let mut r = LruReclaimer::new();
        for want in [1u64, 2, 0] {
            let v = r.victim(&core, 6 * SEC).unwrap();
            assert_eq!(v, want);
            core.want_out.set(v as usize); // engine does this on reclaim
        }
        assert_eq!(r.victim(&core, 6 * SEC), None);
    }

    #[test]
    fn skips_locked_and_nonresident() {
        let mut core = core_with(&[(0, 0), (1, 0)]);
        core.locks.lock(0);
        core.states[1] = UnitState::Swapped;
        let mut r = LruReclaimer::new();
        assert_eq!(r.victim(&core, SEC), None);
    }

    #[test]
    fn stale_cache_entries_are_revalidated() {
        let mut core = core_with(&[(0, 0), (1, 1), (2, 2)]);
        let mut r = LruReclaimer::new();
        assert_eq!(r.victim(&core, SEC), Some(0));
        // Unit 1 touched after the ranking: must not be returned with
        // its stale timestamp.
        core.last_touch[1] = 10 * SEC;
        let v = r.victim(&core, SEC).unwrap();
        assert_eq!(v, 2);
    }

    #[test]
    fn eviction_follows_recency_order() {
        // 100 units touched in sequence: eviction order must match.
        let pairs: Vec<(usize, Time)> = (0..100).map(|u| (u, u as Time * 10)).collect();
        let mut core = core_with(&pairs);
        let mut r = LruReclaimer::new();
        let mut got: Vec<UnitId> = vec![];
        for _ in 0..100 {
            let v = r.victim(&core, SEC).unwrap();
            core.want_out.set(v as usize); // engine does this on reclaim
            got.push(v);
        }
        let want: Vec<UnitId> = (0..100).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn touch_reorders_incrementally() {
        let pairs: Vec<(usize, Time)> = (0..8).map(|u| (u, (u as Time + 1) * 10)).collect();
        let mut core = core_with(&pairs);
        let mut r = LruReclaimer::new();
        // Seed the list through the touch path (as the engine would).
        for &(u, t) in &pairs {
            r.touch(u as UnitId, t);
        }
        // Re-touch unit 0: it becomes the most recent.
        core.last_touch[0] = 1000;
        r.touch(0, 1000);
        let mut got = vec![];
        while let Some(v) = r.victim(&core, 2000) {
            core.want_out.set(v as usize);
            got.push(v);
        }
        assert_eq!(got, vec![1, 2, 3, 4, 5, 6, 7, 0]);
        // All served from the incremental list: no fallback rebuild until
        // the final drained call found nothing eligible.
        assert_eq!(r.rankings, 1);
    }

    /// Regression for the old double-re-rank tail: after a fresh re-rank
    /// the old `victim()` could return None while eligible victims
    /// remained. Drain well past 2x the old BATCH=64 with touches
    /// interleaved between pops; every call must produce the oracle
    /// victim, and the drain must reach every unit.
    #[test]
    fn drains_beyond_two_batches_with_interleaved_touches() {
        let n = 200usize;
        let pairs: Vec<(usize, Time)> = (0..n).map(|u| (u, (u as Time + 1) * 10)).collect();
        let mut core = core_with(&pairs);
        let mut r = LruReclaimer::new();
        for &(u, t) in &pairs {
            r.touch(u as UnitId, t);
        }
        let mut t = (n as Time + 1) * 10;
        let mut evicted = 0usize;
        let mut step = 0usize;
        while let Some(expect) = oracle_victim(&core) {
            step += 1;
            t += 10;
            if step % 5 == 0 {
                // Touch the would-be victim: it must move to the back.
                core.last_touch[expect as usize] = t;
                r.touch(expect, t);
                continue;
            }
            let v = r
                .victim(&core, t)
                .unwrap_or_else(|| panic!("None with eligible victims left after {evicted}"));
            assert_eq!(v, expect, "eviction diverged at step {step}");
            core.want_out.set(v as usize);
            evicted += 1;
        }
        assert_eq!(evicted, n, "drain did not reach every unit");
    }

    /// Pins the documented out-of-band nuance (see module docs and the
    /// ROADMAP note): a unit made Resident *without* a touch
    /// notification is invisible to the incremental list — even when it
    /// is the globally oldest — and only re-enters eviction order at
    /// the rebuild fallback, once the list has no eligible unit left.
    /// Every engine path routes through `Mm::note_touch`, so this can
    /// only happen to direct state pokes; this test keeps the behavior
    /// from regressing silently in either direction.
    #[test]
    fn out_of_band_resident_units_only_reenter_at_rebuild_fallback() {
        let mut core = EngineCore::new(3, 4096, None);
        let mut r = LruReclaimer::new();
        for (u, t) in [(0usize, 10u64), (1, 20)] {
            core.states[u] = UnitState::Resident;
            core.last_touch[u] = t;
            r.touch(u as UnitId, t);
        }
        // Out-of-band poke: Resident and globally oldest, no touch.
        core.states[2] = UnitState::Resident;
        core.last_touch[2] = 5;
        // The incremental list serves its known units first; unit 2
        // stays invisible despite being the LRU-oldest.
        assert_eq!(r.victim(&core, 100), Some(0));
        core.want_out.set(0);
        assert_eq!(r.victim(&core, 100), Some(1));
        core.want_out.set(1);
        assert_eq!(r.rankings, 0, "rebuilt while the list still had units");
        // Only the rebuild fallback discovers it.
        assert_eq!(r.victim(&core, 100), Some(2));
        assert_eq!(r.rankings, 1, "unit 2 re-entered without a rebuild");
        core.want_out.set(2);
        assert_eq!(r.victim(&core, 100), None);
    }

    /// Randomized oracle: 10k mixed touch/reclaim/lock/swap events; the
    /// incremental list must produce exactly the old sort-based victim
    /// order. Event times are strictly increasing (as simulation time
    /// is), so the order is fully determined.
    #[test]
    fn randomized_events_match_sort_based_oracle() {
        let n = 512u64;
        let mut core = EngineCore::new(n, 4096, None);
        let mut r = LruReclaimer::new();
        let mut rng = Rng::new(2024);
        let mut t: Time = 0;
        fn touch(core: &mut EngineCore, r: &mut LruReclaimer, u: u64, t: Time) {
            core.last_touch[u as usize] = t;
            r.touch(u, t);
        }
        // Fault in an initial population.
        for u in 0..n / 2 {
            t += 1;
            core.states[u as usize] = UnitState::Resident;
            touch(&mut core, &mut r, u, t);
        }
        let mut victim_calls = 0u64;
        for _ in 0..10_000 {
            t += 1;
            let roll = rng.below(100);
            let u = rng.below(n);
            let ui = u as usize;
            if roll < 45 {
                // Guest touch on a resident unit.
                if core.states[ui] == UnitState::Resident {
                    touch(&mut core, &mut r, u, t);
                }
            } else if roll < 60 {
                // Fault-in: swapped/untouched unit becomes resident.
                if matches!(core.states[ui], UnitState::Swapped | UnitState::Untouched) {
                    core.states[ui] = UnitState::Resident;
                    touch(&mut core, &mut r, u, t);
                }
            } else if roll < 80 {
                // Limit reclaimer asked for a victim.
                victim_calls += 1;
                let expect = oracle_victim(&core);
                let got = r.victim(&core, t);
                assert_eq!(got, expect, "victim diverged at t={t}");
                if let Some(v) = got {
                    core.want_out.set(v as usize);
                }
            } else if roll < 90 {
                // A queued swap-out completed.
                if core.states[ui] == UnitState::Resident && core.want_out.get(ui) {
                    core.states[ui] = UnitState::Swapped;
                    core.want_out.clear(ui);
                }
            } else if roll < 95 {
                core.locks.lock(u);
            } else {
                core.locks.unlock(u);
            }
        }
        assert!(victim_calls > 1000);
        // Full drain must follow oracle order to the end.
        loop {
            t += 1;
            let expect = oracle_victim(&core);
            let got = r.victim(&core, t);
            assert_eq!(got, expect);
            match got {
                Some(v) => core.want_out.set(v as usize),
                None => break,
            }
        }
    }
}
