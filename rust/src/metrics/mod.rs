//! Metrics: counters, log-bucketed latency histograms, virtual-time
//! series, and markdown/CSV table emission for the experiment harness.

use std::fmt::Write as _;

use crate::types::Time;

/// Per-VM counters maintained by the Machine and the MM.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    /// Major faults (required backing-store I/O).
    pub faults_major: u64,
    /// Minor faults (first touch / already-in-flight / zero page).
    pub faults_minor: u64,
    pub swapin_ops: u64,
    pub swapin_bytes: u64,
    pub swapout_ops: u64,
    pub swapout_bytes: u64,
    /// Swap-ins served from the compressed pool (no NVMe I/O).
    pub swapin_pool_hits: u64,
    /// Swap-ins served from a remote-memory lease (network fetch, no
    /// NVMe I/O; latency sits between pool hit and flash read).
    pub swapin_remote_hits: u64,
    /// Swap-outs absorbed by the compressed pool (no NVMe I/O).
    pub swapout_pool_stores: u64,
    pub prefetch_issued: u64,
    /// Prefetches that removed I/O from a later fault (timely).
    pub prefetch_timely: u64,
    /// Prefetched units reclaimed without ever being touched.
    pub prefetch_wasted: u64,
    /// vCPU time spent stalled on faults.
    pub stall_ns: Time,
    /// vCPU time spent doing useful work.
    pub work_ns: Time,
    /// CPU time burnt by EPT scanning (direct cost, §3.3).
    pub scan_cpu_ns: Time,
    /// Redundant operations cancelled by swapper-queue conflation.
    pub conflated_ops: u64,
    /// Swap-ins denied / delayed by the memory limit.
    pub limit_forced_reclaims: u64,
    /// TLB statistics.
    pub tlb_hits: u64,
    pub tlb_misses: u64,
    /// Swap-ins that moved a whole 2MB granularity region in one op.
    pub huge_swapins: u64,
    /// Swap-outs that moved a whole 2MB granularity region in one op.
    pub huge_swapouts: u64,
    /// Granularity regions demoted to per-4k tracking (PR 8).
    pub region_splits: u64,
    /// Split regions promoted back to 2MB backing (PR 8).
    pub region_collapses: u64,
}

/// Log-bucketed latency histogram (ns), 2 buckets per octave.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: vec![0; 128], count: 0, sum: 0, max: 0 }
    }
}

impl LatencyHist {
    fn index(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let lz = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let half = (v >> lz.saturating_sub(1)) & 1; // next bit => half octave
        (lz * 2 + half as usize).min(127)
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (fleet-wide percentiles).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let lz = i / 2;
                let half = i % 2;
                let lo = 1u64 << lz;
                return if half == 1 { lo + lo / 2 } else { lo };
            }
        }
        self.max
    }
}

/// Host-level control-plane gauges, maintained at every control tick by
/// [`crate::daemon::ControlPlane`]: budget headroom, per-SLA splits and
/// limit-change counts (the paper's §4.1 daemon telemetry).
#[derive(Debug, Clone, Default)]
pub struct ControlStats {
    /// Periodic control ticks executed.
    pub ticks: u64,
    /// Limit changes applied (arbitration + scheduled + staged).
    pub limit_changes: u64,
    /// Staged hard-limit releases started.
    pub staged_releases: u64,
    /// Configured host budget (0 = accounting only).
    pub budget_bytes: u64,
    /// Peak Σ(resident + pool) observed at any tick.
    pub peak_host_bytes: u64,
    /// Ticks at which Σ(resident + pool) exceeded the budget (must stay
    /// 0 — the fleet acceptance invariant).
    pub budget_exceeded_ticks: u64,
    /// Smallest budget headroom seen at a tick (bytes; negative means
    /// the invariant broke).
    pub min_headroom_bytes: i64,
    /// (t, Σ resident bytes, pool bytes) per tick.
    pub host_series: Vec<(Time, f64, f64)>,
    /// Resident bytes per SLA class (Gold/Silver/Bronze) at the last
    /// tick.
    pub resident_by_class: [u64; 3],
    /// Compressed-pool bytes per SLA class at the last tick.
    pub pool_by_class: [u64; 3],
}

impl ControlStats {
    pub fn new(budget_bytes: u64) -> Self {
        ControlStats {
            budget_bytes,
            min_headroom_bytes: i64::MAX,
            ..Default::default()
        }
    }

    /// Record one tick's host occupancy.
    pub fn observe(&mut self, t: Time, resident: u64, pool: u64) {
        self.ticks += 1;
        let occupied = resident + pool;
        self.peak_host_bytes = self.peak_host_bytes.max(occupied);
        self.host_series.push((t, resident as f64, pool as f64));
        if self.budget_bytes > 0 {
            let headroom = self.budget_bytes as i64 - occupied as i64;
            self.min_headroom_bytes = self.min_headroom_bytes.min(headroom);
            if headroom < 0 {
                self.budget_exceeded_ticks += 1;
            }
        }
    }
}

/// Fleet-wide accounting gauges, maintained by
/// [`crate::daemon::FleetScheduler`] across all host shards: the
/// budget-conservation audit, migration counts/bytes and the per-shard
/// invariant tallies the test suite asserts on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetStats {
    pub hosts: usize,
    /// Fleet ticks executed (migration decision points).
    pub fleet_ticks: u64,
    /// Σ audited per-host budgets at fleet construction. Migration
    /// moves budget between shards but never creates or destroys it,
    /// so the per-tick audit below must always see exactly this.
    pub total_budget_bytes: u64,
    /// Fleet ticks at which Σ per-host budgets differed from
    /// `total_budget_bytes` (must stay 0 — the conservation invariant).
    pub conservation_violations: u64,
    pub migrations_started: u64,
    pub migrations_completed: u64,
    /// Migrations cancelled after stalling (their undelivered remainder
    /// was returned to the donor's lease, not lost).
    pub migrations_aborted: u64,
    /// Total bytes actually handed between shards (Σ over chunks).
    pub migrated_bytes: u64,
    /// Per-shard bytes received from / donated to other shards.
    /// Σ `bytes_in` == Σ `bytes_out` == `migrated_bytes`.
    pub bytes_in: Vec<u64>,
    pub bytes_out: Vec<u64>,
    /// Per-shard `budget_exceeded_ticks`, copied out of each shard's
    /// [`ControlStats`] when the run finishes (must all stay 0).
    pub budget_exceeded_ticks: Vec<u64>,

    // ---- VM state-migration ledger (full VM moves, not budget) ----
    /// Full VM state migrations started / flipped / aborted.
    pub state_migrations_started: u64,
    pub state_migrations_completed: u64,
    pub state_migrations_aborted: u64,
    /// Raw bytes staged cold-first (pool entries + NVMe receipts copied
    /// while the VM kept running on the donor).
    pub state_precopy_bytes: u64,
    /// Raw bytes moved inside the stop-and-copy window (hot resident
    /// set + entries re-dirtied after their pre-copy).
    pub state_flip_bytes: u64,
    /// Portion of `state_flip_bytes` that was the resident set.
    pub state_flip_resident_bytes: u64,
    /// Σ and max modeled stop-and-copy pause observed by migrated VMs.
    pub state_stop_ns_total: Time,
    pub state_stop_ns_max: Time,
    /// Flips after which the donor still held state for the VM (must
    /// stay 0 — the atomic-handoff invariant).
    pub handoff_violations: u64,
    /// Per-shard whole-VM arrivals / departures.
    pub vms_migrated_in: Vec<u64>,
    pub vms_migrated_out: Vec<u64>,

    // ---- Fault / recovery ledger (the PR 7 failure model) ----
    /// [`crate::config::HostFault`] events injected (all kinds).
    pub faults_injected: u64,
    pub crashes: u64,
    pub degrades: u64,
    pub revocations: u64,
    /// Bytes taken back by budget revocations (chunked, as they land).
    pub revoked_bytes: u64,
    /// Total budget permanently removed from the Σ-budget baseline:
    /// dead hosts' full budgets plus delivered revocations. The audit
    /// holds `Σ shard budgets == total_budget_bytes` where the baseline
    /// has already been stepped down by exactly this amount.
    pub budget_retired_bytes: u64,
    /// Graceful drains started / fully evacuated before their deadline.
    pub drains_started: u64,
    pub drains_completed: u64,
    /// VMs still on a draining shard when its deadline expired (they
    /// fell back to the lease-only rebalancer).
    pub drain_deadline_misses: u64,
    /// VMs rebuilt on surviving shards after a host crash.
    pub vms_rebuilt: u64,
    /// NVMe receipts salvaged into rebuilt VMs (units / raw bytes) —
    /// swap state that survived its host's death.
    pub rebuild_salvaged_units: u64,
    pub rebuild_salvaged_bytes: u64,
    /// Pool-resident-only units lost with the host (units / raw bytes);
    /// their content is re-synthesized as cold faults on first touch.
    pub rebuild_lost_units: u64,
    pub rebuild_lost_bytes: u64,
    /// Per-shard liveness: false once the host crashed.
    pub alive: Vec<bool>,
    /// Per-shard fault-latency EWMA (ns), updated each fleet tick from
    /// the shard's merged per-VM fault histograms (health gauge).
    pub fault_ewma_ns: Vec<u64>,
    /// Per-shard fleet ticks missed while dead (health gauge).
    pub missed_ticks: Vec<u64>,
    /// Recovered VMs (crash-rebuilt or drain-migrated) that re-reached
    /// their pre-fault residency target, and the slowest such recovery.
    pub residency_restored: u64,
    pub residency_restore_ns_max: Time,

    // ---- Remote-memory marketplace ledger (PR 9) ----
    /// Offers posted by pool-slack shards / bids posted by pressured
    /// shards at fleet ticks (counted per tick, matched or not).
    pub remote_offers: u64,
    pub remote_bids: u64,
    /// Leases granted (matched offer/bid pairs) and their Σ granted
    /// bytes. The donor escrows the grant via `begin_lease`; the escrow
    /// is *always* returned via `cancel_lease` (revocation, crash or the
    /// final barrier), never completed — so Σ budgets are untouched by
    /// the marketplace and the conservation audit holds trivially.
    pub remote_leases: u64,
    pub remote_leased_bytes: u64,
    /// Compressed pool bytes retagged to the remote tier (Σ over paced
    /// per-tick staging chunks).
    pub remote_staged_bytes: u64,
    /// Revocations started (donor pressure rose) and remote bytes
    /// written back to the consumer's NVMe under them.
    pub remote_revocations: u64,
    pub remote_recalled_bytes: u64,
    /// Remote entries lost to a donor crash (units / stored bytes); the
    /// consumer re-faults them as cold misses.
    pub remote_dropped_units: u64,
    pub remote_dropped_bytes: u64,
    /// Clone-from-image admission (PR 10): storm VMs staged at the
    /// scheduler, image-backed clones admitted at fleet ticks, and
    /// cold-boot comparison VMs admitted alongside them.
    pub clones_staged: u64,
    pub clones_admitted: u64,
    pub clone_cold_boots: u64,
}

impl FleetStats {
    pub fn new(hosts: usize, total_budget_bytes: u64) -> Self {
        FleetStats {
            hosts,
            total_budget_bytes,
            bytes_in: vec![0; hosts],
            bytes_out: vec![0; hosts],
            budget_exceeded_ticks: vec![0; hosts],
            vms_migrated_in: vec![0; hosts],
            vms_migrated_out: vec![0; hosts],
            alive: vec![true; hosts],
            fault_ewma_ns: vec![0; hosts],
            missed_ticks: vec![0; hosts],
            ..Default::default()
        }
    }

    /// Permanently retire `bytes` from the Σ-budget baseline (a dead
    /// host's budget, or a delivered revocation chunk). Subsequent
    /// [`FleetStats::audit_budgets`] calls compare against the stepped-
    /// down baseline, so conservation means "shrank by *exactly* this".
    pub fn retire_budget(&mut self, bytes: u64) {
        self.total_budget_bytes -= bytes;
        self.budget_retired_bytes += bytes;
    }

    /// Record one completed stop-and-copy flip of a whole VM.
    pub fn record_state_flip(
        &mut self,
        from: usize,
        to: usize,
        flip_bytes: u64,
        resident_bytes: u64,
        stop_ns: Time,
    ) {
        self.state_migrations_completed += 1;
        self.state_flip_bytes += flip_bytes;
        self.state_flip_resident_bytes += resident_bytes;
        self.state_stop_ns_total += stop_ns;
        self.state_stop_ns_max = self.state_stop_ns_max.max(stop_ns);
        self.vms_migrated_out[from] += 1;
        self.vms_migrated_in[to] += 1;
    }

    /// Record one chunk handed from shard `from` to shard `to`.
    pub fn record_transfer(&mut self, from: usize, to: usize, bytes: u64) {
        self.migrated_bytes += bytes;
        self.bytes_out[from] += bytes;
        self.bytes_in[to] += bytes;
    }

    /// Audit budget conservation at a fleet tick.
    pub fn audit_budgets(&mut self, sum: u64) {
        if sum != self.total_budget_bytes {
            self.conservation_violations += 1;
        }
    }
}

/// A (virtual-time, value) series with uniform-bucket downsampling.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<(Time, f64)>,
}

impl Series {
    pub fn push(&mut self, t: Time, v: f64) {
        self.points.push((t, v));
    }

    /// Average value over the whole series, weighting each sample by the
    /// span until the next (time integral / duration).
    pub fn time_weighted_mean(&self) -> f64 {
        if self.points.len() < 2 {
            return self.points.first().map(|p| p.1).unwrap_or(0.0);
        }
        let mut acc = 0.0;
        let mut span = 0.0;
        for w in self.points.windows(2) {
            let dt = (w[1].0 - w[0].0) as f64;
            acc += w[0].1 * dt;
            span += dt;
        }
        if span == 0.0 {
            self.points[0].1
        } else {
            acc / span
        }
    }

    /// Downsample into `n` uniform time buckets (mean per bucket).
    pub fn downsample(&self, n: usize) -> Vec<(Time, f64)> {
        if self.points.is_empty() || n == 0 {
            return vec![];
        }
        let t0 = self.points[0].0;
        let t1 = self.points.last().unwrap().0.max(t0 + 1);
        let w = (t1 - t0).div_ceil(n as u64);
        let mut out: Vec<(Time, f64, u64)> = vec![];
        for &(t, v) in &self.points {
            let b = ((t - t0) / w).min(n as u64 - 1);
            let bt = t0 + b * w;
            match out.last_mut() {
                Some((lt, lv, lc)) if *lt == bt => {
                    *lv += v;
                    *lc += 1;
                }
                _ => out.push((bt, v, 1)),
            }
        }
        out.into_iter().map(|(t, v, c)| (t, v / c as f64)).collect()
    }
}

/// A printable results table (markdown + CSV) for the harness.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.columns.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}

/// Pretty-print nanoseconds.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Pretty-print bytes.
pub fn fmt_bytes(b: u64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    let bf = b as f64;
    if bf >= G {
        format!("{:.2}GiB", bf / G)
    } else if bf >= M {
        format!("{:.1}MiB", bf / M)
    } else {
        format!("{:.0}KiB", bf / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_mean_and_quantiles() {
        let mut h = LatencyHist::default();
        for v in [100u64, 100, 100, 100, 100, 100, 100, 100, 100, 10_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean() - 1090.0).abs() < 1.0);
        assert!(h.quantile(0.5) <= 200);
        assert!(h.quantile(0.99) >= 4000);
        assert_eq!(h.max(), 10_000);
    }

    #[test]
    fn hist_empty() {
        let h = LatencyHist::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn hist_merge_combines_counts() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        for v in [100u64, 200, 300] {
            a.record(v);
        }
        b.record(50_000);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max(), 50_000);
        assert!(a.quantile(0.99) >= 16_384);
    }

    #[test]
    fn control_stats_tracks_headroom_and_violations() {
        let mut s = ControlStats::new(1000);
        s.observe(1, 600, 100); // headroom 300
        s.observe(2, 900, 200); // headroom -100: violation
        assert_eq!(s.ticks, 2);
        assert_eq!(s.peak_host_bytes, 1100);
        assert_eq!(s.budget_exceeded_ticks, 1);
        assert_eq!(s.min_headroom_bytes, -100);
        assert_eq!(s.host_series.len(), 2);
    }

    #[test]
    fn fleet_stats_transfer_and_conservation() {
        let mut s = FleetStats::new(3, 1000);
        s.record_transfer(0, 2, 100);
        s.record_transfer(0, 1, 50);
        assert_eq!(s.migrated_bytes, 150);
        assert_eq!(s.bytes_out, vec![150, 0, 0]);
        assert_eq!(s.bytes_in, vec![0, 50, 100]);
        assert_eq!(s.bytes_in.iter().sum::<u64>(), s.bytes_out.iter().sum());
        s.audit_budgets(1000);
        assert_eq!(s.conservation_violations, 0);
        s.audit_budgets(999);
        assert_eq!(s.conservation_violations, 1);
    }

    #[test]
    fn fleet_stats_state_flip_ledger() {
        let mut s = FleetStats::new(2, 1000);
        s.record_state_flip(0, 1, 500, 300, 2_000);
        s.record_state_flip(1, 0, 100, 100, 5_000);
        assert_eq!(s.state_migrations_completed, 2);
        assert_eq!(s.state_flip_bytes, 600);
        assert_eq!(s.state_flip_resident_bytes, 400);
        assert_eq!(s.state_stop_ns_total, 7_000);
        assert_eq!(s.state_stop_ns_max, 5_000);
        assert_eq!(s.vms_migrated_out, vec![1, 1]);
        assert_eq!(s.vms_migrated_in, vec![1, 1]);
        assert_eq!(s.handoff_violations, 0);
    }

    #[test]
    fn fleet_stats_budget_retirement_steps_down_baseline() {
        let mut s = FleetStats::new(2, 1000);
        s.audit_budgets(1000);
        assert_eq!(s.conservation_violations, 0);
        // A crash retires the dead host's budget: the audit baseline
        // steps down by exactly that amount, so only the stepped-down
        // sum passes from here on.
        s.retire_budget(400);
        assert_eq!(s.total_budget_bytes, 600);
        assert_eq!(s.budget_retired_bytes, 400);
        s.audit_budgets(600);
        assert_eq!(s.conservation_violations, 0);
        s.audit_budgets(1000);
        assert_eq!(s.conservation_violations, 1);
        assert_eq!(s.alive, vec![true, true]);
        assert_eq!(s.missed_ticks, vec![0, 0]);
    }

    #[test]
    fn series_time_weighted() {
        let mut s = Series::default();
        s.push(0, 0.0);
        s.push(10, 10.0); // value 0 held for 10
        s.push(20, 10.0); // value 10 held for 10
        assert!((s.time_weighted_mean() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn series_downsample() {
        let mut s = Series::default();
        for t in 0..100u64 {
            s.push(t, t as f64);
        }
        let d = s.downsample(10);
        assert!(d.len() <= 10 && d.len() >= 9);
        assert!(d[0].1 < d.last().unwrap().1);
    }

    #[test]
    fn table_emit() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert!(t.markdown().contains("| 1 | 2 |"));
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1500), "1.5us");
        assert_eq!(fmt_bytes(2 * 1024 * 1024), "2.0MiB");
    }
}
