//! The eight cloud workloads of §6.3, as parameterized generators.
//!
//! We do not have the real applications (repro band 0/5); each preset
//! encodes the properties the evaluation depends on — working-set size,
//! spatial locality (how many 4kB chunks of a 2MB page get reused, the
//! paper's ~500 page-fault ratio), phase structure and hot/cold split —
//! taken from the paper's own description of each workload.

use super::{Op, Workload};
use crate::sim::{Rng, Zipf};
use crate::types::Time;

/// One phase of a cloud workload.
#[derive(Debug, Clone)]
pub enum PhaseKind {
    /// Sequential read sweep over a fraction range of the space.
    SeqRead(f64, f64),
    /// Sequential write sweep (initialization, matrix output...).
    SeqWrite(f64, f64),
    /// Uniform random over a range.
    Uniform(f64, f64),
    /// Gaussian around the range's center.
    Gauss(f64, f64),
    /// Zipf-skewed over a range (hot head).
    ZipfRead(f64, f64, f64),
    /// Pick a random 2MB-aligned block in range, touch `inner` pages
    /// inside it (high 2M locality, random at large scale).
    BlockedRandom { lo: f64, hi: f64, block_pages: u64, inner: u64 },
    /// Log append: write at a growing head, read mostly the recent tail.
    AppendLog { tail_frac: f64, old_prob: f64 },
    /// Host-side DMA touches (VIRTIO/OVS serving path): the page is
    /// accessed by QEMU/OVS, not the guest — visible only to the QEMU
    /// page-table scan (§5.4).
    HostServe { lo: f64, hi: f64, zipf_s: f64 },
}

#[derive(Debug, Clone)]
pub struct PhaseSpec {
    pub kind: PhaseKind,
    pub ops: u64,
    /// Base instruction pointer for this phase (IP-indexed predictors).
    pub ip: u64,
}

#[derive(Debug, Clone)]
pub struct CloudSpec {
    pub name: &'static str,
    /// Guest-virtual pages the workload addresses.
    pub pages: u64,
    pub write_ratio: f64,
    pub phases: Vec<PhaseSpec>,
    /// Repeat the phase list this many times (steady-state workloads).
    pub repeats: u32,
}

impl CloudSpec {
    pub fn total_ops(&self) -> u64 {
        self.phases.iter().map(|p| p.ops).sum::<u64>() * self.repeats as u64
    }
}

pub struct CloudWorkload {
    spec: CloudSpec,
    phase: usize,
    rep: u32,
    done_in_phase: u64,
    seq_cursor: u64,
    log_head: u64,
    zipf: Option<Zipf>,
    zipf_key: (u64, u64),
}

impl CloudWorkload {
    pub fn new(spec: CloudSpec) -> Self {
        CloudWorkload {
            spec,
            phase: 0,
            rep: 0,
            done_in_phase: 0,
            seq_cursor: 0,
            log_head: 1,
            zipf: None,
            zipf_key: (u64::MAX, u64::MAX),
        }
    }

    pub fn spec(&self) -> &CloudSpec {
        &self.spec
    }

    fn range(&self, lo: f64, hi: f64) -> (u64, u64) {
        let n = self.spec.pages as f64;
        let a = (lo * n) as u64;
        let b = ((hi * n) as u64).max(a + 1).min(self.spec.pages);
        (a, b)
    }
}

impl Workload for CloudWorkload {
    fn next(&mut self, rng: &mut Rng) -> Op {
        loop {
            if self.phase >= self.spec.phases.len() {
                self.rep += 1;
                if self.rep >= self.spec.repeats {
                    return Op::Done;
                }
                self.phase = 0;
                self.done_in_phase = 0;
            }
            let spec_ops = self.spec.phases[self.phase].ops;
            if self.done_in_phase >= spec_ops {
                self.phase += 1;
                self.done_in_phase = 0;
                self.seq_cursor = 0;
                continue;
            }
            self.done_in_phase += 1;
            let ip = self.spec.phases[self.phase].ip;
            let kind = self.spec.phases[self.phase].kind.clone();
            let write_ratio = self.spec.write_ratio;
            let (page, write, host) = match kind {
                PhaseKind::SeqRead(lo, hi) => {
                    let (a, b) = self.range(lo, hi);
                    let p = a + self.seq_cursor % (b - a);
                    self.seq_cursor += 1;
                    (p, false, false)
                }
                PhaseKind::SeqWrite(lo, hi) => {
                    let (a, b) = self.range(lo, hi);
                    let p = a + self.seq_cursor % (b - a);
                    self.seq_cursor += 1;
                    (p, true, false)
                }
                PhaseKind::Uniform(lo, hi) => {
                    let (a, b) = self.range(lo, hi);
                    (rng.range(a, b), rng.chance(write_ratio), false)
                }
                PhaseKind::Gauss(lo, hi) => {
                    let (a, b) = self.range(lo, hi);
                    let span = (b - a) as f64;
                    let mid = a as f64 + span / 2.0;
                    let x = (mid + rng.gauss() * span / 6.0)
                        .clamp(a as f64, (b - 1) as f64);
                    (x as u64, rng.chance(write_ratio), false)
                }
                PhaseKind::ZipfRead(lo, hi, s) => {
                    let (a, b) = self.range(lo, hi);
                    if self.zipf_key != (a, b) {
                        self.zipf = Some(Zipf::new(b - a, s));
                        self.zipf_key = (a, b);
                    }
                    let k = self.zipf.as_ref().unwrap().sample(rng);
                    // Spread the zipf rank over the range so the hot head
                    // isn't artificially GVA-contiguous.
                    let p = a + (k * 2_654_435_761 % (b - a));
                    (p, false, false)
                }
                PhaseKind::BlockedRandom { lo, hi, block_pages, inner } => {
                    let (a, b) = self.range(lo, hi);
                    let blocks = ((b - a) / block_pages).max(1);
                    // Stay in one block for `inner` consecutive accesses.
                    let seq_in_block = self.seq_cursor % inner;
                    if seq_in_block == 0 {
                        self.log_head = rng.below(blocks); // reuse as block idx
                    }
                    self.seq_cursor += 1;
                    let off = rng.below(block_pages);
                    (a + self.log_head * block_pages + off, rng.chance(write_ratio), false)
                }
                PhaseKind::AppendLog { tail_frac, old_prob } => {
                    let max = self.spec.pages;
                    let r = rng.f64();
                    if r < old_prob && self.log_head > 64 {
                        // Rare read of old, cold log segments.
                        (rng.below(self.log_head * 4 / 5), false, false)
                    } else if r < old_prob + 0.1 {
                        // Append: advance the head.
                        self.log_head = (self.log_head + 1).min(max - 1);
                        (self.log_head, true, false)
                    } else {
                        // Hot tail: producers + consumers trail the head;
                        // bounded so the hot set stays small vs the log.
                        let tail = ((self.log_head as f64 * tail_frac) as u64)
                            .clamp(1, 2048);
                        let lo = self.log_head.saturating_sub(tail);
                        (rng.range(lo, self.log_head + 1), rng.chance(0.5), false)
                    }
                }
                PhaseKind::HostServe { lo, hi, zipf_s } => {
                    let (a, b) = self.range(lo, hi);
                    if self.zipf_key != (a, b) {
                        self.zipf = Some(Zipf::new(b - a, zipf_s));
                        self.zipf_key = (a, b);
                    }
                    let k = self.zipf.as_ref().unwrap().sample(rng);
                    (a + (k * 2_654_435_761 % (b - a)), false, true)
                }
            };
            // Cloud workloads do real work between page-granularity
            // touches; 2us/touch keeps reclamation dynamics (seconds)
            // and access dynamics on the same simulated clock.
            let cost: Time = 2_000;
            if host {
                // Host-side access: machine routes it to the OVS/vhost
                // path (page locking + QEMU bitmap), guest not involved.
                return Op::Access { proc: usize::MAX, gva_page: page, write, ip, cost_ns: cost };
            }
            return Op::Access { proc: 0, gva_page: page, write, ip, cost_ns: cost };
        }
    }

    fn label(&self) -> &'static str {
        self.spec.name
    }

    fn total_ops(&self) -> u64 {
        self.spec.total_ops()
    }
}

pub const CLOUD_NAMES: [&str; 8] =
    ["bert", "xsbench", "elastic", "g500", "kafka", "matmul", "nginx", "redis"];

/// Build a named cloud workload preset. `scale` multiplies page counts
/// (1.0 ≈ a 200-350MB guest working set, fast to simulate; raise it to
/// stress larger VMs).
pub fn cloud_preset(name: &str, scale: f64) -> CloudSpec {
    let pg = |p: u64| ((p as f64 * scale) as u64).max(64);
    let op = |o: u64| ((o as f64 * scale) as u64).max(1000);
    match name {
        // BERT inference: weights streamed sequentially per query; a
        // cold tail of rarely-used buffers. High 2M locality.
        "bert" => CloudSpec {
            name: "bert",
            pages: pg(320_000),
            write_ratio: 0.05,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(320_000), ip: 0x10 },
                PhaseSpec { kind: PhaseKind::SeqRead(0.0, 0.62), ops: op(300_000), ip: 0x11 },
            ],
            repeats: 1,
        },
        // XSBench: huge read-only cross-section tables; each lookup
        // lands in a random table region but reads many entries there.
        "xsbench" => CloudSpec {
            name: "xsbench",
            pages: pg(480_000),
            write_ratio: 0.02,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(480_000), ip: 0x20 },
                PhaseSpec {
                    kind: PhaseKind::BlockedRandom { lo: 0.0, hi: 0.55, block_pages: 512, inner: 384 },
                    ops: op(300_000),
                    ip: 0x21,
                },
            ],
            repeats: 1,
        },
        // Elasticsearch/Rally: hot index + large cold segment store.
        "elastic" => CloudSpec {
            name: "elastic",
            pages: pg(400_000),
            write_ratio: 0.15,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(400_000), ip: 0x30 },
                PhaseSpec { kind: PhaseKind::ZipfRead(0.0, 0.45, 1.05), ops: op(260_000), ip: 0x31 },
            ],
            repeats: 1,
        },
        // graph500: construction sweep, then BFS/SSSP phases over
        // (different) subsets — the paper's phase-change workload.
        "g500" => CloudSpec {
            name: "g500",
            pages: pg(640_000),
            write_ratio: 0.3,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(640_000), ip: 0x40 },
                PhaseSpec { kind: PhaseKind::Uniform(0.0, 0.55), ops: op(140_000), ip: 0x41 },
                PhaseSpec { kind: PhaseKind::Uniform(0.0, 0.55), ops: op(140_000), ip: 0x42 },
                PhaseSpec { kind: PhaseKind::Uniform(0.35, 0.95), ops: op(140_000), ip: 0x43 },
                PhaseSpec { kind: PhaseKind::Uniform(0.35, 0.95), ops: op(140_000), ip: 0x44 },
            ],
            repeats: 1,
        },
        // Kafka: append-only log, hot head, cold history (the paper's
        // 71%-reclaimable champion).
        "kafka" => CloudSpec {
            name: "kafka",
            pages: pg(1_280_000),
            write_ratio: 0.5,
            phases: vec![PhaseSpec {
                kind: PhaseKind::AppendLog { tail_frac: 0.08, old_prob: 0.0005 },
                ops: op(700_000),
                ip: 0x50,
            }],
            repeats: 1,
        },
        // OpenBLAS dgemm: repeated sequential panel sweeps, very high
        // spatial locality, WSS = the three matrices.
        "matmul" => CloudSpec {
            name: "matmul",
            pages: pg(240_000),
            write_ratio: 0.2,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(240_000), ip: 0x60 },
                PhaseSpec { kind: PhaseKind::SeqRead(0.0, 0.66), ops: op(120_000), ip: 0x61 },
                PhaseSpec { kind: PhaseKind::SeqWrite(0.66, 1.0), ops: op(90_000), ip: 0x62 },
            ],
            repeats: 3,
        },
        // nginx static files: zipf over the page cache, with ~50% of the
        // working set touched by the host (OVS/vhost) serving path.
        "nginx" => CloudSpec {
            name: "nginx",
            pages: pg(160_000),
            write_ratio: 0.05,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(160_000), ip: 0x70 },
                PhaseSpec { kind: PhaseKind::ZipfRead(0.0, 1.0, 0.9), ops: op(130_000), ip: 0x71 },
                PhaseSpec { kind: PhaseKind::HostServe { lo: 0.0, hi: 1.0, zipf_s: 0.9 }, ops: op(130_000), ip: 0x72 },
            ],
            repeats: 1,
        },
        // Redis + memtier: gauss / random / sequential key sweeps over
        // the whole dataset — touches everything, ~nothing reclaimable.
        "redis" => CloudSpec {
            name: "redis",
            pages: pg(100_000),
            write_ratio: 0.3,
            phases: vec![
                PhaseSpec { kind: PhaseKind::SeqWrite(0.0, 1.0), ops: op(100_000), ip: 0x80 },
                PhaseSpec { kind: PhaseKind::Gauss(0.0, 1.0), ops: op(150_000), ip: 0x81 },
                PhaseSpec { kind: PhaseKind::Uniform(0.0, 1.0), ops: op(120_000), ip: 0x82 },
                PhaseSpec { kind: PhaseKind::SeqRead(0.0, 1.0), ops: op(120_000), ip: 0x83 },
            ],
            repeats: 1,
        },
        other => panic!("unknown cloud workload {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_build_and_run() {
        let mut rng = Rng::new(1);
        for name in CLOUD_NAMES {
            let spec = cloud_preset(name, 0.02);
            let mut w = CloudWorkload::new(spec);
            let mut accesses = 0u64;
            loop {
                match w.next(&mut rng) {
                    Op::Access { gva_page, .. } => {
                        assert!(gva_page < w.spec().pages, "{name}");
                        accesses += 1;
                    }
                    Op::Done => break,
                    _ => {}
                }
                assert!(accesses < 10_000_000, "{name} runaway");
            }
            assert!(accesses > 1000, "{name} too few ops");
        }
    }

    #[test]
    fn kafka_keeps_old_log_cold() {
        let mut rng = Rng::new(2);
        let mut w = CloudWorkload::new(cloud_preset("kafka", 0.1));
        let pages = w.spec().pages;
        let total = w.total_ops();
        let mut last_touch = vec![0u64; pages as usize];
        let mut op_idx = 0u64;
        loop {
            match w.next(&mut rng) {
                Op::Access { gva_page, .. } => {
                    op_idx += 1;
                    last_touch[gva_page as usize] = op_idx;
                }
                Op::Done => break,
                _ => {}
            }
        }
        // Old log segments go cold: a large share of touched pages see
        // no access in the second half of the run (reclaimable).
        let cold = last_touch
            .iter()
            .filter(|&&t| t > 0 && t < total / 2)
            .count();
        let touched = last_touch.iter().filter(|&&t| t > 0).count();
        assert!(
            cold as f64 > touched as f64 * 0.35,
            "kafka cold fraction too small: {cold}/{touched}"
        );
    }

    #[test]
    fn redis_touches_nearly_everything() {
        let mut rng = Rng::new(3);
        let mut w = CloudWorkload::new(cloud_preset("redis", 0.05));
        let pages = w.spec().pages;
        let mut touched = vec![false; pages as usize];
        loop {
            match w.next(&mut rng) {
                Op::Access { gva_page, .. } => touched[gva_page as usize] = true,
                Op::Done => break,
                _ => {}
            }
        }
        let frac = touched.iter().filter(|&&t| t).count() as f64 / pages as f64;
        assert!(frac > 0.95, "redis coverage {frac}");
    }

    #[test]
    fn nginx_has_host_side_accesses() {
        let mut rng = Rng::new(4);
        let mut w = CloudWorkload::new(cloud_preset("nginx", 0.05));
        let mut host = 0;
        loop {
            match w.next(&mut rng) {
                Op::Access { proc, .. } => {
                    if proc == usize::MAX {
                        host += 1;
                    }
                }
                Op::Done => break,
                _ => {}
            }
        }
        assert!(host > 1000, "host accesses {host}");
    }
}
