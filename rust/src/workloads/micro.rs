//! Microbenchmark workloads (paper §3.1, §3.2, §6.1, §6.2, §6.6).

use super::{Op, Workload, OP_COST};
use crate::sim::Rng;
use crate::types::Time;

/// Uniform random page accesses over [base, base+pages).
pub struct UniformRandom {
    base: u64,
    pages: u64,
    remaining: u64,
    total: u64,
    pub ip: u64,
}

impl UniformRandom {
    pub fn new(base: u64, pages: u64, ops: u64) -> Self {
        UniformRandom { base, pages, remaining: ops, total: ops, ip: 0x401000 }
    }
}

impl Workload for UniformRandom {
    fn next(&mut self, rng: &mut Rng) -> Op {
        if self.remaining == 0 {
            return Op::Done;
        }
        self.remaining -= 1;
        Op::Access {
            proc: 0,
            gva_page: self.base + rng.below(self.pages),
            write: rng.chance(0.3),
            ip: self.ip,
            cost_ns: OP_COST,
        }
    }
    fn label(&self) -> &'static str {
        "uniform"
    }
    fn total_ops(&self) -> u64 {
        self.total
    }
}

/// Fig 1 microbenchmark: accesses split between a resident region and a
/// swapped-out region with probability `cold_ratio`.
pub struct ColdRatio {
    pub resident_pages: u64,
    pub cold_pages: u64,
    pub cold_ratio: f64,
    remaining: u64,
    total: u64,
}

impl ColdRatio {
    pub fn new(resident_pages: u64, cold_pages: u64, cold_ratio: f64, ops: u64) -> Self {
        ColdRatio { resident_pages, cold_pages, cold_ratio, remaining: ops, total: ops }
    }
}

impl Workload for ColdRatio {
    fn next(&mut self, rng: &mut Rng) -> Op {
        if self.remaining == 0 {
            return Op::Done;
        }
        self.remaining -= 1;
        let (base, span) = if rng.chance(self.cold_ratio) {
            (self.resident_pages, self.cold_pages) // cold region after hot
        } else {
            (0, self.resident_pages)
        };
        Op::Access {
            proc: 0,
            gva_page: base + rng.below(span),
            write: false,
            ip: 0x402000,
            cost_ns: OP_COST,
        }
    }
    fn label(&self) -> &'static str {
        "cold-ratio"
    }
    fn total_ops(&self) -> u64 {
        self.total
    }
}

/// Fig 2 microbenchmark: access the first half of a buffer for one
/// phase, then switch to the second half.
pub struct AlternatingHalves {
    pages: u64,
    phase_ops: u64,
    done_ops: u64,
    total: u64,
}

impl AlternatingHalves {
    pub fn new(pages: u64, phase_ops: u64) -> Self {
        AlternatingHalves { pages, phase_ops, done_ops: 0, total: phase_ops * 2 }
    }
}

impl Workload for AlternatingHalves {
    fn next(&mut self, rng: &mut Rng) -> Op {
        if self.done_ops >= self.total {
            return Op::Done;
        }
        let half = self.pages / 2;
        let base = if self.done_ops < self.phase_ops { 0 } else { half };
        self.done_ops += 1;
        Op::Access {
            proc: 0,
            gva_page: base + rng.below(half),
            write: true,
            ip: 0x403000,
            cost_ns: OP_COST,
        }
    }
    fn label(&self) -> &'static str {
        "alternating-halves"
    }
    fn total_ops(&self) -> u64 {
        self.total
    }
}

/// §6.6 workload: strictly sequential page writes, with enough think
/// time between accesses for a prefetcher to stay ahead.
pub struct SeqScan {
    pages: u64,
    iterations: u64,
    cursor: u64,
    think: Time,
    emitted_think: bool,
}

impl SeqScan {
    pub fn new(pages: u64, iterations: u64, think: Time) -> Self {
        SeqScan { pages, iterations, cursor: 0, think, emitted_think: false }
    }
}

impl Workload for SeqScan {
    fn next(&mut self, _rng: &mut Rng) -> Op {
        let total = self.pages * self.iterations;
        if self.cursor >= total {
            return Op::Done;
        }
        if self.think > 0 && !self.emitted_think {
            self.emitted_think = true;
            return Op::Think(self.think);
        }
        self.emitted_think = false;
        let page = self.cursor % self.pages;
        self.cursor += 1;
        Op::Access { proc: 0, gva_page: page, write: true, ip: 0x404000, cost_ns: OP_COST }
    }
    fn label(&self) -> &'static str {
        "seq-scan"
    }
    fn total_ops(&self) -> u64 {
        self.pages * self.iterations
    }
}

/// §6.2 workload: a working set that varies over time in known steps, so
/// the reclaimer's WSS estimate can be compared against ground truth.
pub struct PhasedWss {
    /// (wss_pages, ops) per phase.
    pub phases: Vec<(u64, u64)>,
    phase: usize,
    done_in_phase: u64,
    total: u64,
    cost_ns: Time,
}

impl PhasedWss {
    pub fn new(phases: Vec<(u64, u64)>) -> Self {
        // 500ns/touch: slow enough that WSS dynamics are visible.
        Self::with_cost(phases, 500)
    }

    /// Same phase structure with an explicit per-touch cost — the fleet
    /// experiment stretches virtual time so reclamation and control
    /// ticks see many rounds within few simulated ops.
    pub fn with_cost(phases: Vec<(u64, u64)>, cost_ns: Time) -> Self {
        let total = phases.iter().map(|p| p.1).sum();
        PhasedWss { phases, phase: 0, done_in_phase: 0, total, cost_ns }
    }

    /// Ground-truth WSS for the phase active after `ops_done` accesses.
    pub fn wss_at(&self, mut ops_done: u64) -> u64 {
        for &(wss, ops) in &self.phases {
            if ops_done < ops {
                return wss;
            }
            ops_done -= ops;
        }
        self.phases.last().map(|p| p.0).unwrap_or(0)
    }
}

impl Workload for PhasedWss {
    fn next(&mut self, rng: &mut Rng) -> Op {
        loop {
            let Some(&(wss, ops)) = self.phases.get(self.phase) else {
                return Op::Done;
            };
            if self.done_in_phase >= ops {
                self.phase += 1;
                self.done_in_phase = 0;
                continue;
            }
            self.done_in_phase += 1;
            return Op::Access {
                proc: 0,
                gva_page: rng.below(wss),
                write: rng.chance(0.5),
                ip: 0x405000 + self.phase as u64,
                cost_ns: self.cost_ns,
            };
        }
    }
    fn label(&self) -> &'static str {
        "phased-wss"
    }
    fn total_ops(&self) -> u64 {
        self.total
    }
}

/// Boot-churn wrapper: the VM "boots" `delay` ns into the run (one big
/// think), then runs the wrapped workload — the fleet experiment
/// staggers VM start times with this.
pub struct BootDelay {
    delay: Time,
    emitted: bool,
    inner: Box<dyn Workload>,
}

impl BootDelay {
    pub fn new(delay: Time, inner: Box<dyn Workload>) -> Self {
        BootDelay { delay, emitted: delay == 0, inner }
    }
}

impl Workload for BootDelay {
    fn next(&mut self, rng: &mut Rng) -> Op {
        if !self.emitted {
            self.emitted = true;
            return Op::Think(self.delay);
        }
        self.inner.next(rng)
    }
    fn label(&self) -> &'static str {
        self.inner.label()
    }
    fn total_ops(&self) -> u64 {
        self.inner.total_ops()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_delay_thinks_once_then_delegates() {
        let mut rng = Rng::new(5);
        let mut w = BootDelay::new(1000, Box::new(UniformRandom::new(0, 10, 3)));
        assert_eq!(w.next(&mut rng), Op::Think(1000));
        let mut n = 0;
        while let Op::Access { .. } = w.next(&mut rng) {
            n += 1;
        }
        assert_eq!(n, 3);
        assert_eq!(w.total_ops(), 3);
        assert_eq!(w.label(), "uniform");
    }

    #[test]
    fn cold_ratio_splits_regions() {
        let mut rng = Rng::new(2);
        let mut w = ColdRatio::new(100, 1000, 0.5, 10_000);
        let (mut hot, mut cold) = (0u64, 0u64);
        while let Op::Access { gva_page, .. } = w.next(&mut rng) {
            if gva_page < 100 {
                hot += 1;
            } else {
                cold += 1;
            }
        }
        assert!(hot > 4500 && cold > 4500, "{hot}/{cold}");
    }

    #[test]
    fn alternating_switches_halves() {
        let mut rng = Rng::new(3);
        let mut w = AlternatingHalves::new(100, 10);
        let mut first = vec![];
        loop {
            match w.next(&mut rng) {
                Op::Access { gva_page, .. } => first.push(gva_page),
                Op::Done => break,
                _ => {}
            }
        }
        assert!(first[..10].iter().all(|&p| p < 50));
        assert!(first[10..].iter().all(|&p| p >= 50));
    }

    #[test]
    fn seq_scan_is_sequential_with_think() {
        let mut rng = Rng::new(4);
        let mut w = SeqScan::new(5, 2, 100);
        let mut pages = vec![];
        loop {
            match w.next(&mut rng) {
                Op::Access { gva_page, .. } => pages.push(gva_page),
                Op::Done => break,
                Op::Think(t) => assert_eq!(t, 100),
            }
        }
        assert_eq!(pages, vec![0, 1, 2, 3, 4, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn phased_wss_ground_truth() {
        let w = PhasedWss::new(vec![(100, 10), (500, 10), (50, 10)]);
        assert_eq!(w.wss_at(0), 100);
        assert_eq!(w.wss_at(10), 500);
        assert_eq!(w.wss_at(25), 50);
    }
}
