//! Workload generators: the microbenchmarks of §3/§6.1-§6.2 and the
//! eight cloud workloads of §6.3 (modeled on the paper's reported
//! working-set sizes, locality and phase structure — see DESIGN.md §2
//! for why generator-based substitution preserves the evaluation).

pub mod cloud;
pub mod micro;

pub use cloud::{cloud_preset, CloudSpec, CloudWorkload, CLOUD_NAMES};
pub use micro::{AlternatingHalves, BootDelay, ColdRatio, PhasedWss, SeqScan, UniformRandom};

use crate::sim::Rng;
use crate::types::Time;

/// One step of a guest workload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Touch a guest-virtual page.
    Access { proc: usize, gva_page: u64, write: bool, ip: u64, cost_ns: Time },
    /// Compute without memory traffic.
    Think(Time),
    /// The workload finished its fixed amount of work.
    Done,
}

/// A guest workload: a deterministic stream of operations.
///
/// `Send` because workloads live inside [`crate::coordinator::Machine`]
/// slots, and the fleet scheduler runs whole machines on worker threads
/// between fleet ticks (ARCHITECTURE.md "Parallel fleet execution").
pub trait Workload: Send {
    fn next(&mut self, rng: &mut Rng) -> Op;
    fn label(&self) -> &'static str;
    /// Total accesses this workload will issue (for progress metrics).
    fn total_ops(&self) -> u64;
}

/// Convenience: per-access base cost used by all generators (accounts
/// for the non-memory instructions around each touch).
pub const OP_COST: Time = 40;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_terminates_and_stays_in_range() {
        let mut rng = Rng::new(1);
        let mut w = UniformRandom::new(0, 100, 1000);
        let mut n = 0;
        loop {
            match w.next(&mut rng) {
                Op::Access { gva_page, .. } => {
                    assert!(gva_page < 100);
                    n += 1;
                }
                Op::Done => break,
                _ => {}
            }
        }
        assert_eq!(n, 1000);
    }
}
