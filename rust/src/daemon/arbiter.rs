//! Host-wide pressure arbitration (the control-plane feedback loop the
//! paper's daemon closes in §4.1/§6.8, informed by Memtrade-style
//! per-consumer harvesting limits).
//!
//! Every control tick the [`crate::daemon::ControlPlane`] hands the
//! arbiter one [`VmReport`] per managed VM plus a [`HostView`] of the
//! physical-memory budget; the arbiter answers with per-VM limit
//! actions. Three policies ([`crate::config::ArbiterKind`]):
//!
//! * **Static** — never re-arbitrates; limits stay as registered.
//! * **Proportional share** — re-divides the usable budget every tick:
//!   each VM is floored at its *demand* (reported WSS + headroom) when
//!   feasible, and surplus is distributed by SLA weight. Under
//!   infeasible demand, cold slack is squeezed class by class —
//!   Bronze first, Gold last — so a Gold VM is never pushed below its
//!   reported WSS while a Bronze VM still has reclaimable slack.
//! * **Watermark** — leaves the fleet alone inside the band; squeezes
//!   to proportional targets when Σ(resident+pool) crosses the high
//!   watermark and releases limits in boost-flagged stages below the
//!   low one.

use crate::config::{ArbiterKind, ControlConfig};

use super::Sla;

/// Control-plane view of one VM at a tick (paper: "inform the control
/// plane about the number of cold memory pages"). Built into a reused
/// buffer — no per-tick allocation.
#[derive(Debug, Clone, Copy)]
pub struct VmReport {
    /// Machine slot id (name lookup via [`super::Daemon::vm_name`]).
    pub vm: usize,
    pub sla: Sla,
    /// Resident bytes.
    pub usage_bytes: u64,
    /// dt-reclaimer working-set estimate (bytes; `dt.wss_units`).
    pub wss_bytes: u64,
    /// Reported cold memory: usage minus the WSS estimate.
    pub cold_estimate_bytes: u64,
    /// Cumulative fault count.
    pub pf_count: u64,
    /// Faults since the previous control tick.
    pub pf_delta: u64,
    /// Current memory limit (None = unlimited).
    pub limit_bytes: Option<u64>,
    /// Reclaim granularity (4k or 2M).
    pub unit_bytes: u64,
    /// In-flight slack the engine may transiently hold above its limit
    /// (one unit per swapper worker).
    pub inflight_allowance: u64,
}

/// Host-wide physical-memory accounting at a tick.
#[derive(Debug, Clone, Copy)]
pub struct HostView {
    pub budget_bytes: u64,
    /// Σ resident bytes over all managed VMs.
    pub resident_bytes: u64,
    /// Compressed-pool occupancy (bytes actually stored).
    pub pool_bytes: u64,
    /// Pool *capacity*, reserved off the top of the budget so pool
    /// growth between ticks can never break the budget invariant.
    pub pool_reserved_bytes: u64,
}

impl HostView {
    /// Budget headroom right now (negative = invariant violated).
    pub fn headroom(&self) -> i64 {
        self.budget_bytes as i64 - self.resident_bytes as i64 - self.pool_bytes as i64
    }

    /// Σ(resident + pool): the occupancy the budget invariant bounds.
    pub fn occupied(&self) -> u64 {
        self.resident_bytes + self.pool_bytes
    }
}

/// One arbitration decision: set `vm`'s limit to `bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LimitAction {
    pub vm: usize,
    pub bytes: Option<u64>,
    /// Raise the recovery-mode hint for the prefetchers.
    pub boost: bool,
}

/// Pluggable arbitration engine (one per [`crate::daemon::ControlPlane`]).
#[derive(Debug)]
pub struct Arbiter {
    pub kind: ArbiterKind,
    /// Watermark policy: currently squeezing (true between a
    /// high-watermark crossing and the staged release completing).
    engaged: bool,
    /// Scratch for the proportional solver, reused across ticks.
    limits: Vec<u64>,
    floors: Vec<u64>,
}

impl Arbiter {
    pub fn new(kind: ArbiterKind) -> Self {
        Arbiter { kind, engaged: false, limits: vec![], floors: vec![] }
    }

    /// Minimum viable limit for a VM: a handful of units plus the
    /// in-flight allowance, so the engine can always make progress.
    pub fn floor_of(r: &VmReport) -> u64 {
        8 * r.unit_bytes + r.inflight_allowance
    }

    /// Demand: the reported WSS plus fault headroom. Keeping every VM
    /// at demand (not usage) is what converts reported cold memory
    /// into host density.
    pub fn demand_of(r: &VmReport) -> u64 {
        let headroom = (r.wss_bytes / 8).max(4 * r.unit_bytes);
        (r.wss_bytes + headroom).max(Self::floor_of(r))
    }

    /// Bytes of the budget the fleet may actually occupy as resident
    /// memory: budget minus the reserved pool capacity minus every
    /// VM's in-flight slack.
    pub fn usable_budget(reports: &[VmReport], host: &HostView) -> u64 {
        let inflight: u64 = reports.iter().map(|r| r.inflight_allowance).sum();
        host.budget_bytes
            .saturating_sub(host.pool_reserved_bytes)
            .saturating_sub(inflight)
    }

    /// Proportional-share solve: per-VM limits with Σ ≤ `usable`.
    /// Exposed for the arbitration property tests.
    pub fn proportional_limits(&mut self, reports: &[VmReport], usable: u64) -> &[u64] {
        let n = reports.len();
        self.limits.clear();
        self.floors.clear();
        self.limits.extend(reports.iter().map(Self::demand_of));
        self.floors.extend(reports.iter().map(Self::floor_of));
        let total_demand: u64 = self.limits.iter().sum();
        if total_demand <= usable {
            // Feasible: everyone gets demand; surplus by SLA weight.
            let surplus = usable - total_demand;
            let total_w: u64 = reports.iter().map(|r| r.sla.weight()).sum();
            if total_w > 0 {
                for (l, r) in self.limits.iter_mut().zip(reports) {
                    *l += (surplus as u128 * r.sla.weight() as u128 / total_w as u128) as u64;
                }
            }
            return &self.limits;
        }
        // Infeasible: squeeze below demand class by class, Bronze
        // first, proportionally to each VM's reducible span.
        let mut deficit = total_demand - usable;
        for class in [Sla::Bronze, Sla::Silver, Sla::Gold] {
            if deficit == 0 {
                break;
            }
            let reducible: u64 = (0..n)
                .filter(|&i| reports[i].sla == class)
                .map(|i| self.limits[i].saturating_sub(self.floors[i]))
                .sum();
            if reducible == 0 {
                continue;
            }
            let take = deficit.min(reducible);
            let mut taken = 0u64;
            for i in 0..n {
                if reports[i].sla != class {
                    continue;
                }
                let span = self.limits[i].saturating_sub(self.floors[i]);
                let cut = (take as u128 * span as u128 / reducible as u128) as u64;
                self.limits[i] -= cut;
                taken += cut;
            }
            // Flooring under-takes by < #VMs bytes; settle the residue
            // from the first reducible VM so Σ limits ≤ usable holds.
            let mut residue = take - taken;
            for i in 0..n {
                if residue == 0 {
                    break;
                }
                if reports[i].sla != class {
                    continue;
                }
                let span = self.limits[i].saturating_sub(self.floors[i]);
                let cut = residue.min(span);
                self.limits[i] -= cut;
                residue -= cut;
            }
            deficit -= take;
        }
        &self.limits
    }

    /// One arbitration round: append limit actions to `out`. `cfg`
    /// supplies the watermark band; staged releases are expanded by the
    /// control plane, not here.
    pub fn arbitrate(
        &mut self,
        reports: &[VmReport],
        host: &HostView,
        cfg: &ControlConfig,
        out: &mut Vec<LimitAction>,
    ) {
        if reports.is_empty() {
            return;
        }
        match self.kind {
            ArbiterKind::Static => {}
            ArbiterKind::ProportionalShare => {
                let usable = Self::usable_budget(reports, host);
                self.proportional_limits(reports, usable);
                // Transition safety: a tightened VM sheds memory only as
                // its swap-outs complete, so until then it *holds* up to
                // min(usage, old limit). Raises are therefore granted
                // only from measured headroom — Σ(transient holds) +
                // Σ(raised limits) stays ≤ usable at every instant, and
                // the loop self-paces: as squeezed VMs shed, the next
                // tick's reserve shrinks and the raises complete.
                let mut reserved: u64 = 0;
                for (i, r) in reports.iter().enumerate() {
                    let t = self.limits[i];
                    let cur = r.limit_bytes.unwrap_or(r.usage_bytes.max(t));
                    if t <= cur {
                        reserved += t.max(r.usage_bytes.min(cur));
                    }
                }
                let mut avail = usable.saturating_sub(reserved);
                for (i, r) in reports.iter().enumerate() {
                    let t = self.limits[i];
                    if let Some(cur) = r.limit_bytes {
                        if t > cur {
                            // Raised VMs keep holding up to their old
                            // limit regardless of the grant.
                            avail = avail.saturating_sub(cur);
                        }
                    }
                }
                for (i, r) in reports.iter().enumerate() {
                    let t = self.limits[i];
                    let Some(cur) = r.limit_bytes else {
                        // Unlimited VM entering arbitration: always cap.
                        out.push(LimitAction { vm: r.vm, bytes: Some(t), boost: false });
                        continue;
                    };
                    if t < cur {
                        // Tightenings always apply — skipping one would
                        // let per-VM drift accumulate past the budget.
                        out.push(LimitAction { vm: r.vm, bytes: Some(t), boost: false });
                    } else if t > cur {
                        let grant = (t - cur).min(avail);
                        // Hysteresis on raises only: a withheld raise
                        // leaves the VM below target, which is safe.
                        if grant >= r.unit_bytes {
                            avail -= grant;
                            out.push(LimitAction {
                                vm: r.vm,
                                bytes: Some(cur + grant),
                                boost: true,
                            });
                        }
                    }
                }
            }
            ArbiterKind::Watermark => {
                let occupied = host.occupied();
                let high = host.budget_bytes / 100 * cfg.high_watermark_pct as u64;
                let low = host.budget_bytes / 100 * cfg.low_watermark_pct as u64;
                if occupied > high {
                    self.engaged = true;
                    let usable = Self::usable_budget(reports, host)
                        .min(low.saturating_sub(host.pool_bytes));
                    self.proportional_limits(reports, usable);
                    for (i, r) in reports.iter().enumerate() {
                        out.push(LimitAction { vm: r.vm, bytes: Some(self.limits[i]), boost: false });
                    }
                } else if self.engaged && occupied < low {
                    // Staged release: raise every squeezed limit by 25%
                    // per tick (boost-flagged) until the band clears.
                    let usable = Self::usable_budget(reports, host);
                    let mut total: u64 = reports.iter().filter_map(|r| r.limit_bytes).sum();
                    let mut any = false;
                    for r in reports {
                        let Some(cur) = r.limit_bytes else { continue };
                        let step = (cur / 4).max(r.unit_bytes);
                        if total + step > usable {
                            continue;
                        }
                        total += step;
                        any = true;
                        out.push(LimitAction { vm: r.vm, bytes: Some(cur + step), boost: true });
                    }
                    if !any {
                        self.engaged = false;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(vm: usize, sla: Sla, usage_mb: u64, wss_mb: u64) -> VmReport {
        const MB: u64 = 1024 * 1024;
        VmReport {
            vm,
            sla,
            usage_bytes: usage_mb * MB,
            wss_bytes: wss_mb * MB,
            cold_estimate_bytes: (usage_mb - wss_mb) * MB,
            pf_count: 0,
            pf_delta: 0,
            limit_bytes: Some(usage_mb * MB),
            unit_bytes: 4096,
            inflight_allowance: 4 * 4096,
        }
    }

    #[test]
    fn feasible_demand_gets_floor_plus_weighted_surplus() {
        const MB: u64 = 1024 * 1024;
        let reports = vec![
            report(0, Sla::Gold, 100, 50),
            report(1, Sla::Bronze, 100, 50),
        ];
        let mut a = Arbiter::new(ArbiterKind::ProportionalShare);
        let limits = a.proportional_limits(&reports, 400 * MB).to_vec();
        // Both above demand; Gold's surplus 4x Bronze's.
        for (l, r) in limits.iter().zip(&reports) {
            assert!(*l >= r.wss_bytes, "limit below WSS");
        }
        let (g, b) = (limits[0] - 57 * MB, limits[1] - 57 * MB); // demand ≈ 56.25MB
        assert!(g > 3 * b, "gold surplus {g} vs bronze {b}");
    }

    #[test]
    fn infeasible_squeezes_bronze_before_gold() {
        const MB: u64 = 1024 * 1024;
        let reports = vec![
            report(0, Sla::Gold, 100, 80),
            report(1, Sla::Bronze, 100, 80),
        ];
        let mut a = Arbiter::new(ArbiterKind::ProportionalShare);
        // Usable covers Gold's demand plus a little: Bronze absorbs the
        // whole squeeze, Gold stays at (or above) its WSS.
        let usable = 120 * MB;
        let limits = a.proportional_limits(&reports, usable).to_vec();
        assert!(limits.iter().sum::<u64>() <= usable);
        assert!(limits[0] >= reports[0].wss_bytes, "gold below wss");
        assert!(limits[1] < reports[1].wss_bytes, "bronze not squeezed");
    }

    #[test]
    fn sum_never_exceeds_usable() {
        const MB: u64 = 1024 * 1024;
        let mut a = Arbiter::new(ArbiterKind::ProportionalShare);
        for usable_mb in [10u64, 50, 150, 400, 1000] {
            let reports = vec![
                report(0, Sla::Gold, 120, 90),
                report(1, Sla::Silver, 80, 40),
                report(2, Sla::Bronze, 200, 30),
            ];
            let limits = a.proportional_limits(&reports, usable_mb * MB);
            assert!(
                limits.iter().sum::<u64>() <= usable_mb * MB,
                "sum over budget at usable {usable_mb}MB"
            );
        }
    }

    #[test]
    fn watermark_squeezes_then_releases_in_stages() {
        const MB: u64 = 1024 * 1024;
        let cfg = ControlConfig::default(); // band: high 90%, low 75%
        let mut a = Arbiter::new(ArbiterKind::Watermark);
        let mut reports = vec![report(0, Sla::Bronze, 950, 100)];
        let host = |resident_mb: u64| HostView {
            budget_bytes: 1000 * MB,
            resident_bytes: resident_mb * MB,
            pool_bytes: 0,
            pool_reserved_bytes: 0,
        };
        let mut out = vec![];
        // Inside the band: leave the fleet alone.
        a.arbitrate(&reports, &host(800), &cfg, &mut out);
        assert!(out.is_empty());
        // Above the 900MB high watermark: squeeze to ≤ low watermark.
        a.arbitrate(&reports, &host(950), &cfg, &mut out);
        assert!(!out.is_empty(), "no squeeze above high watermark");
        let squeezed = out.last().unwrap().bytes.unwrap();
        assert!(squeezed <= 750 * MB, "squeeze target {squeezed}");
        // Back below the low watermark: staged, boost-flagged release.
        out.clear();
        reports[0].limit_bytes = Some(squeezed);
        a.arbitrate(&reports, &host(600), &cfg, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].boost, "release not boost-flagged");
        assert!(out[0].bytes.unwrap() > squeezed, "limit not raised");
    }

    #[test]
    fn static_kind_emits_nothing() {
        let reports = vec![report(0, Sla::Gold, 100, 50)];
        let host = HostView {
            budget_bytes: 1 << 30,
            resident_bytes: 100 << 20,
            pool_bytes: 0,
            pool_reserved_bytes: 0,
        };
        let mut out = vec![];
        Arbiter::new(ArbiterKind::Static).arbitrate(
            &reports,
            &host,
            &ControlConfig::default(),
            &mut out,
        );
        assert!(out.is_empty());
    }
}
