//! The daemon (paper §4.1): launched at host startup; spawns and
//! configures one MM per VM according to the VM's registration (desired
//! page size + SLA), and runs the control-plane feedback loop — per-VM
//! cold-memory reports, host-wide physical-memory accounting and
//! SLA-weighted limit arbitration — as a scheduled `ControlTick` actor
//! *inside* the machine's event loop.
//!
//! Layer split:
//! * [`arbiter`] — the pure arbitration engine: [`VmReport`]s +
//!   [`HostView`] in, [`LimitAction`]s out (static / proportional-share
//!   / watermark policies).
//! * [`control`] — the [`ControlPlane`] actor state: fleet bookkeeping,
//!   scheduled one-shots, staged hard-limit releases with the
//!   recovery-boost hint, and the host gauges
//!   ([`crate::metrics::ControlStats`]).
//! * [`Daemon`] — the boot-time registration facade the CLI, examples
//!   and harness drive.

pub mod arbiter;
pub mod control;
pub mod scheduler;

pub use arbiter::{Arbiter, HostView, LimitAction, VmReport};
pub use control::{ControlPlane, ManagedVm};
pub use scheduler::{FleetRun, FleetScheduler, FleetVmSpec, HostShard, Placement};

use crate::config::{ControlConfig, HostConfig, MmConfig, VmConfig};
use crate::coordinator::Machine;
use crate::types::{PageSize, Time, MS, SEC};
use crate::workloads::Workload;

/// SLA class a VM registers with at boot (paper step ①).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sla {
    /// Latency-critical: huge pages, conservative reclamation.
    Gold,
    /// Balanced (default).
    Silver,
    /// Best-effort: aggressive reclamation to maximize density.
    Bronze,
}

impl Sla {
    /// The daemon's MM configuration policy (paper step ②).
    pub fn mm_config(self) -> MmConfig {
        match self {
            Sla::Gold => MmConfig {
                scan_interval: SEC,
                target_promotion_rate: 0.005,
                swapper_threads: 8,
                ..Default::default()
            },
            Sla::Silver => MmConfig {
                scan_interval: 500 * MS,
                target_promotion_rate: 0.02,
                ..Default::default()
            },
            Sla::Bronze => MmConfig {
                scan_interval: 200 * MS,
                target_promotion_rate: 0.08,
                swapper_threads: 2,
                ..Default::default()
            },
        }
    }

    pub fn page_size(self) -> PageSize {
        match self {
            Sla::Gold | Sla::Silver => PageSize::Huge,
            Sla::Bronze => PageSize::Small,
        }
    }

    /// Arbitration weight: how much of the budget surplus (and how
    /// little of the squeeze) this class attracts.
    pub fn weight(self) -> u64 {
        match self {
            Sla::Gold => 4,
            Sla::Silver => 2,
            Sla::Bronze => 1,
        }
    }

    /// Index into per-class arrays (pool partitions, gauge splits).
    pub fn class_index(self) -> usize {
        match self {
            Sla::Gold => 0,
            Sla::Silver => 1,
            Sla::Bronze => 2,
        }
    }
}

/// A VM registration request (QEMU boot-time handshake).
pub struct VmRegistration {
    pub name: String,
    pub frames: u64,
    pub vcpus: usize,
    pub sla: Sla,
    pub workloads: Vec<Box<dyn Workload>>,
    /// Boot-time memory limit (None: unlimited until the arbiter — if
    /// any — places one). With a host budget, registrations should
    /// carry limits so the budget invariant holds from t = 0.
    pub initial_limit_bytes: Option<u64>,
}

/// The daemon: registration facade over the machine-resident control
/// plane.
pub struct Daemon {
    pub machine: Machine,
}

impl Daemon {
    /// Daemon with the default (static, accounting-only) control plane.
    pub fn new(host: HostConfig) -> Self {
        Self::with_control(host, ControlConfig::default())
    }

    /// Daemon with an explicit control-plane configuration (budget,
    /// arbitration policy, tick cadence, pool split).
    pub fn with_control(host: HostConfig, ctrl: ControlConfig) -> Self {
        let mut machine = Machine::new(host);
        machine.install_control(ctrl);
        Daemon { machine }
    }

    /// Boot-time registration: spawn + configure an MM for the VM and
    /// enroll it with the control plane (SLA pool class included).
    pub fn register(&mut self, reg: VmRegistration) -> usize {
        let mm_base = reg.sla.mm_config();
        register_vm_on(
            &mut self.machine,
            reg.name,
            reg.sla,
            reg.frames,
            reg.vcpus,
            reg.workloads,
            reg.initial_limit_bytes,
            mm_base,
        )
    }

    /// Control-plane report for every VM: rebuilt into the plane's
    /// reused buffer — no per-call `String`/`Vec` allocation. Names
    /// stay owned by the plane; look them up with [`Daemon::vm_name`].
    pub fn report(&mut self) -> &[VmReport] {
        self.machine.control_reports()
    }

    pub fn vm_name(&self, vm: usize) -> &str {
        self.machine
            .control()
            .and_then(|c| c.vm_name(vm))
            .unwrap_or("?")
    }

    /// Fleet control-plane gauges shortcut.
    pub fn control_stats(&self) -> Option<&crate::metrics::ControlStats> {
        self.machine.control_stats()
    }

    /// Schedule a one-shot control-plane limit change (applied from a
    /// control tick inside the event loop; replaces the old external
    /// `plan_limit` path). `boost` opens the recovery window on a
    /// release; `staged` spreads the release over several ticks.
    pub fn schedule_limit(
        &mut self,
        vm: usize,
        at: Time,
        bytes: Option<u64>,
        boost: bool,
        staged: bool,
    ) {
        self.machine.schedule_limit_release(vm, at, bytes, boost, staged);
    }
}

/// Spawn + configure one VM on `machine` per its registration (the
/// paper's boot handshake: desired page size + SLA → MM config) and
/// enroll it with the machine's control plane. Shared by the
/// single-host [`Daemon`] and the fleet scheduler's shard admission.
#[allow(clippy::too_many_arguments)]
pub(crate) fn register_vm_on(
    machine: &mut Machine,
    name: String,
    sla: Sla,
    frames: u64,
    vcpus: usize,
    workloads: Vec<Box<dyn Workload>>,
    initial_limit_bytes: Option<u64>,
    mm_base: MmConfig,
) -> usize {
    let mm_cfg = MmConfig { memory_limit: initial_limit_bytes, ..mm_base };
    let vm_cfg = VmConfig {
        frames,
        vcpus,
        page_size: sla.page_size(),
        scramble: 0.05,
        guest_thp_coverage: 1.0,
    };
    let id = machine.sys_vm(vm_cfg, &mm_cfg, workloads);
    machine.register_control_vm(id, name, sla);
    id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::UniformRandom;

    #[test]
    fn daemon_runs_a_small_fleet() {
        let mut d = Daemon::new(HostConfig::default());
        for (i, sla) in [Sla::Gold, Sla::Silver, Sla::Bronze].iter().enumerate() {
            d.register(VmRegistration {
                name: format!("vm{i}"),
                frames: 4096,
                vcpus: 1,
                sla: *sla,
                workloads: vec![Box::new(UniformRandom::new(0, 2048, 20_000))],
                initial_limit_bytes: None,
            });
        }
        let res = d.machine.run();
        assert_eq!(res.len(), 3);
        for r in &res {
            assert_eq!(r.work_ops, 20_000);
        }
        let reports = d.report().to_vec();
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.pf_count > 0));
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(d.vm_name(r.vm), format!("vm{i}"));
        }
    }

    #[test]
    fn sla_maps_to_config() {
        assert_eq!(Sla::Gold.page_size(), PageSize::Huge);
        assert_eq!(Sla::Bronze.page_size(), PageSize::Small);
        assert!(Sla::Bronze.mm_config().target_promotion_rate
            > Sla::Gold.mm_config().target_promotion_rate);
        assert!(Sla::Gold.weight() > Sla::Silver.weight());
        assert_ne!(Sla::Gold.class_index(), Sla::Bronze.class_index());
    }

    #[test]
    fn registration_applies_initial_limit_and_pool_class() {
        let mut d = Daemon::new(HostConfig::default());
        let id = d.register(VmRegistration {
            name: "capped".into(),
            frames: 4096,
            vcpus: 1,
            sla: Sla::Bronze,
            workloads: vec![Box::new(UniformRandom::new(0, 2048, 5_000))],
            initial_limit_bytes: Some(1024 * 4096),
        });
        let mm = d.machine.mm(id).unwrap();
        assert_eq!(mm.core.limit_units, Some(1024));
        assert_eq!(d.machine.control().unwrap().vms.len(), 1);
    }
}
