//! The daemon (paper §4.1): launched at host startup; spawns and
//! configures one MM per VM according to the VM's registration (desired
//! page size + SLA), and exposes the control-plane feedback loop
//! (per-VM cold-memory estimates, runtime-tunable parameters).

use crate::config::{HostConfig, MmConfig, VmConfig};
use crate::coordinator::Machine;
use crate::types::{PageSize, Time, MS, SEC};
use crate::workloads::Workload;

/// SLA class a VM registers with at boot (paper step ①).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sla {
    /// Latency-critical: huge pages, conservative reclamation.
    Gold,
    /// Balanced (default).
    Silver,
    /// Best-effort: aggressive reclamation to maximize density.
    Bronze,
}

impl Sla {
    /// The daemon's MM configuration policy (paper step ②).
    pub fn mm_config(self) -> MmConfig {
        match self {
            Sla::Gold => MmConfig {
                scan_interval: SEC,
                target_promotion_rate: 0.005,
                swapper_threads: 8,
                ..Default::default()
            },
            Sla::Silver => MmConfig {
                scan_interval: 500 * MS,
                target_promotion_rate: 0.02,
                ..Default::default()
            },
            Sla::Bronze => MmConfig {
                scan_interval: 200 * MS,
                target_promotion_rate: 0.08,
                swapper_threads: 2,
                ..Default::default()
            },
        }
    }

    pub fn page_size(self) -> PageSize {
        match self {
            Sla::Gold | Sla::Silver => PageSize::Huge,
            Sla::Bronze => PageSize::Small,
        }
    }
}

/// A VM registration request (QEMU boot-time handshake).
pub struct VmRegistration {
    pub name: String,
    pub frames: u64,
    pub vcpus: usize,
    pub sla: Sla,
    pub workloads: Vec<Box<dyn Workload>>,
}

/// The daemon: owns the machine and the fleet bookkeeping.
pub struct Daemon {
    pub machine: Machine,
    names: Vec<String>,
}

/// Control-plane view of one VM (paper: "inform the control plane about
/// the number of cold memory pages").
#[derive(Debug, Clone)]
pub struct VmReport {
    pub name: String,
    pub usage_bytes: u64,
    pub cold_estimate_bytes: u64,
    pub pf_count: u64,
}

impl Daemon {
    pub fn new(host: HostConfig) -> Self {
        Daemon { machine: Machine::new(host), names: vec![] }
    }

    /// Boot-time registration: spawn + configure an MM for the VM.
    pub fn register(&mut self, reg: VmRegistration) -> usize {
        let mm_cfg = reg.sla.mm_config();
        let vm_cfg = VmConfig {
            frames: reg.frames,
            vcpus: reg.vcpus,
            page_size: reg.sla.page_size(),
            scramble: 0.05,
            guest_thp_coverage: 1.0,
        };
        let id = self.machine.sys_vm(vm_cfg, &mm_cfg, reg.workloads);
        self.names.push(reg.name);
        id
    }

    /// Control-plane report for every VM.
    pub fn report(&self) -> Vec<VmReport> {
        (0..self.names.len())
            .map(|i| {
                let mm = self.machine.mm(i).expect("daemon VMs are sys VMs");
                let wss_units =
                    mm.core.params.get("dt.wss_units").copied().unwrap_or(0.0);
                let usage = mm.core.usage_bytes();
                let cold = usage
                    .saturating_sub((wss_units as u64) * mm.core.unit_bytes);
                VmReport {
                    name: self.names[i].clone(),
                    usage_bytes: usage,
                    cold_estimate_bytes: cold,
                    pf_count: mm.core.pf_count,
                }
            })
            .collect()
    }

    /// Control-plane action: set a VM's memory limit at time `at`.
    pub fn plan_limit(&mut self, vm: usize, at: Time, bytes: Option<u64>) {
        self.machine.plan_limit_change(vm, at, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::UniformRandom;

    #[test]
    fn daemon_runs_a_small_fleet() {
        let mut d = Daemon::new(HostConfig::default());
        for (i, sla) in [Sla::Gold, Sla::Silver, Sla::Bronze].iter().enumerate() {
            d.register(VmRegistration {
                name: format!("vm{i}"),
                frames: 4096,
                vcpus: 1,
                sla: *sla,
                workloads: vec![Box::new(UniformRandom::new(0, 2048, 20_000))],
            });
        }
        let res = d.machine.run();
        assert_eq!(res.len(), 3);
        for r in &res {
            assert_eq!(r.work_ops, 20_000);
        }
        let reports = d.report();
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.pf_count > 0));
    }

    #[test]
    fn sla_maps_to_config() {
        assert_eq!(Sla::Gold.page_size(), PageSize::Huge);
        assert_eq!(Sla::Bronze.page_size(), PageSize::Small);
        assert!(Sla::Bronze.mm_config().target_promotion_rate
            > Sla::Gold.mm_config().target_promotion_rate);
    }
}
