//! The fleet scheduler: the sharding layer *above* per-host control
//! planes. One [`HostShard`] per simulated host — each an independent
//! [`Machine`] carrying its own [`super::Arbiter`] +
//! [`super::ControlPlane`] + tiered storage backend — plus the two
//! things only a fleet-level view can do:
//!
//! 1. **Placement** ([`crate::config::PlacementPolicy`]): VM admission
//!    picks a shard — first-fit by SLA-weighted demand (pack shards in
//!    order) or spread by projected fault pressure (balance it). A VM
//!    is placed exactly once and never split across shards.
//! 2. **Cross-host rebalancing**: every fleet tick the scheduler reads
//!    the per-shard [`super::VmReport`]s (the fault-rate deltas the control
//!    plane already carries) and, when a VM's fault rate spikes on a
//!    shard whose Σ demand exceeds its usable budget, stages a
//!    **cold-memory migration** from the slackest shard — modeled
//!    Memtrade-style as a budget lease. The donor's control plane
//!    reserves the leased bytes out of its *arbitration* budget
//!    ([`super::ControlPlane::begin_lease`]): its proportional-share
//!    arbiter squeezes cold slack out of the fleet, and as headroom
//!    actually materializes, chunks are handed over
//!    ([`super::ControlPlane::complete_lease`] +
//!    [`super::ControlPlane::grow_budget`]) — the same
//!    shed-first-then-release pacing as the staged hard-limit release
//!    machinery, applied across hosts. The audited per-shard budget
//!    therefore only ever drops *after* the occupancy is below it, so
//!    Σ(resident + pool) ≤ budget holds on every shard at every tick,
//!    and Σ budgets over the fleet is exactly conserved (bytes leaving
//!    a shard equal bytes arriving — no unit lost or duplicated).
//!
//! 3. **VM state migration** ([`crate::config::FleetConfig::state_migration`]):
//!    when a whole VM is worth moving, the rebalancer migrates *the VM
//!    itself* instead of leasing budget toward it — engine/MM state,
//!    policy state, the per-unit tier map, compressed-pool entries and
//!    NVMe receipts. The transfer is staged **cold-first**, post-copy
//!    style: while the VM keeps running on the donor, pre-copy ticks
//!    stage its swapped-out state to the target (NVMe receipts first —
//!    the coldest bytes — then pool entries, which land in the target's
//!    SLA partition or demote to NVMe when it is full). Each staged
//!    unit carries the backend's replacement stamp; a unit rewritten
//!    after its pre-copy is detected by the stamp mismatch and re-sent.
//!    When the not-yet-copied remainder is small (or pre-copy stops
//!    converging), a brief **stop-and-copy flip** moves the hot
//!    resident set and every stale unit at once: the donor machine
//!    extracts the VM (slot, pending events, control registration,
//!    backend copies — [`Machine::extract_vm`]), the target implants it
//!    with the modeled pause added to its event times
//!    ([`Machine::implant_vm`]), and the target's control plane /
//!    arbiter / pool partition adopt it while the donor forgets it —
//!    the hand-off is atomic at the flip. The PR 4 budget lease is
//!    reused as the **headroom escrow**: the target's arbitration
//!    budget is docked by the VM's expected resident arrival
//!    ([`super::ControlPlane::begin_lease`]) so its fleet sheds ahead
//!    of the flip, the flip itself is gated on *measured* headroom, and
//!    the escrow is returned once the VM has landed (budgets never move
//!    — Σ budgets is trivially conserved and still audited every tick).
//!
//! 4. **Failure injection and self-healing**
//!    ([`crate::config::HostFault`]): a deterministic fault stream,
//!    sorted `(at, host)`, is applied at fleet ticks — the only point
//!    where shards interact, so injection is identical under both
//!    engines and any worker count. A **degraded-NVMe** fault inflates
//!    the shard's flash latency and starts a graceful drain: every VM
//!    is evacuated through the state-migration path under a deadline
//!    ([`FleetConfig::drain_deadline_ticks`]); whatever is still
//!    waiting when it expires falls back to lease-only relief and is
//!    counted as a deadline miss. A **crash** is immediate: in-flight
//!    migrations touching the dead shard abort (escrows and lease
//!    remainders return to their *surviving* counterparties), each
//!    lost VM is rebuilt on a surviving shard from its NVMe receipts
//!    ([`SwapBackend::salvage_vm`]) — pool-resident units died with
//!    the host's DRAM and are re-synthesized as cold faults on next
//!    touch, measured — and the dead shard's budget retires from the
//!    fleet ([`super::ControlPlane::retire_host_budget`]), so the
//!    conservation audit's Σ steps down by exactly that budget at the
//!    crash tick. A **budget revocation** returns part of a healthy
//!    shard's budget to the provider through the lease machinery —
//!    shed first, retire after, never below measured occupancy. Health
//!    gauges (per-shard liveness, fault-latency EWMA, missed ticks)
//!    and the fault/recovery ledger live in
//!    [`FleetStats`](crate::metrics::FleetStats).
//!
//! 5. **Remote-memory marketplace** ([`crate::config::RemoteConfig`],
//!    PR 9): at fleet ticks, shards with pool slack post offers,
//!    pressured shards bid, and a matched pair forms a lease — the
//!    donor escrows the grant out of its arbitration budget
//!    ([`super::ControlPlane::begin_lease`]) and the consumer's coldest
//!    compressed-pool entries retag to [`SwapTier::Remote`] in paced,
//!    donor-headroom-gated chunks. A remote fault hit pays a modeled
//!    network latency between a pool hit and an NVMe read. When the
//!    donor's own pressure rises the lease revokes: remote bytes write
//!    back to the consumer's NVMe chunk by chunk, returning escrow as
//!    they land. The escrow is only ever cancelled, never completed, so
//!    audited budgets don't move and Σ-budget conservation is trivial.
//!
//! Multi-machine stepping is deterministic: the scheduler merges the
//! shards' event queues by (virtual time, shard index) — a stable
//! round-robin interleave in which equal timestamps always resolve
//! lowest-shard-first — and fires fleet ticks at fixed virtual times
//! before any shard steps past them. Because a fleet tick at `now`
//! precedes every pending event (≥ `now`), the flip can move a VM's
//! queued events between machines without ever reordering the past.
//!
//! Shards interact **only** at fleet ticks, so the tick boundary is
//! also a parallelism barrier: the default engine runs every live
//! shard's inter-tick events on its own worker thread
//! ([`Machine::run_until`] under `std::thread::scope`), joins at the
//! tick, and produces byte-identical output to the sequential merge —
//! see ARCHITECTURE.md "Parallel fleet execution" and the gated
//! equivalence tests in `tests/fleet_scheduler.rs`.

use std::collections::{BTreeMap, VecDeque};

use crate::config::{
    ArbiterKind, ControlConfig, FleetConfig, HostConfig, HostFault, HostFaultKind, MmConfig,
};
use crate::coordinator::{Machine, RunResult};
use crate::metrics::FleetStats;
use crate::storage::{SwapBackend, SwapTier};
use crate::types::{Time, FRAME_BYTES, SEC};
use crate::workloads::Workload;

use super::arbiter::{Arbiter, HostView};
use super::Sla;

/// One host shard: an independent machine (control plane, arbiter,
/// backend, NVMe) plus the scheduler's admission bookkeeping.
pub struct HostShard {
    pub id: usize,
    pub machine: Machine,
    /// Σ nominal bytes of VMs placed here.
    pub committed_bytes: u64,
    /// SLA-weighted committed demand: nominal bytes scaled by
    /// `max_weight / weight`, so a Bronze byte (squeezed first, faults
    /// most under pressure) counts heavier than a Gold byte.
    pub committed_pressure: u64,
}

/// Where one admitted VM lives. The invariant suite asserts every VM
/// appears in exactly one shard's control plane (never split).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub name: String,
    pub sla: Sla,
    pub shard: usize,
    /// Machine slot id inside the shard.
    pub vm: usize,
}

/// A VM admission request to the fleet (the multi-host analogue of
/// [`super::VmRegistration`], plus an optional MM-config override the
/// experiments use for tighter scan cadences).
pub struct FleetVmSpec {
    pub name: String,
    pub sla: Sla,
    pub frames: u64,
    pub vcpus: usize,
    pub workloads: Vec<Box<dyn Workload>>,
    pub initial_limit_bytes: Option<u64>,
    /// MM configuration base; None uses the SLA default
    /// ([`Sla::mm_config`]), exactly like single-host registration.
    pub mm: Option<MmConfig>,
}

/// An in-flight staged cold-memory migration (budget lease).
#[derive(Debug, Clone, Copy)]
struct Migration {
    from: usize,
    to: usize,
    /// Machine slot id of the pressured VM on `to`.
    vm: usize,
    total: u64,
    moved: u64,
    /// Consecutive fleet ticks that transferred nothing.
    stalled: u32,
    /// Static-receiver path: the VM's limit when the first chunk
    /// landed. Later chunks target `base + moved` so an in-flight
    /// staged raise from a previous chunk is never clobbered by a
    /// re-read of the intermediate limit.
    base_limit: Option<u64>,
}

/// An in-flight **VM state migration** (see module docs): the whole VM
/// moves from the pressured shard `from` to the slack shard `to`,
/// cold-first, with an atomic stop-and-copy flip at the end.
#[derive(Debug)]
struct StateMigration {
    from: usize,
    to: usize,
    /// Donor-side machine slot of the migrating VM.
    vm: usize,
    /// Target-side slot reserved for the arrival (never reused; left
    /// empty forever if the migration aborts).
    reserved: usize,
    /// Headroom escrow taken on the target's arbitration budget
    /// (returned at flip or abort; the audited budget never moves).
    escrow: u64,
    /// Pre-copied units and the backend stamp each was copied at; a
    /// donor rewrite bumps the stamp and re-queues the unit.
    copied: BTreeMap<crate::types::UnitId, u32>,
    precopy_ticks: u32,
    /// Consecutive flip attempts blocked on target headroom.
    stalled: u32,
    /// Set when this migration is a graceful-drain evacuation: the
    /// virtual time the fault was injected. The flip arms a recovery
    /// probe measuring from it.
    drain_since: Option<Time>,
}

/// An in-flight remote-memory lease (the PR 9 Memtrade-style
/// marketplace): `donor` escrows `granted` bytes of its *arbitration*
/// budget ([`super::ControlPlane::begin_lease`]) — its arbiter squeezes,
/// so real DRAM headroom materializes to host the `consumer`'s coldest
/// compressed-pool entries, which retag to [`SwapTier::Remote`] in
/// paced, headroom-gated chunks. Unlike a budget-lease migration the
/// escrow is only ever *cancelled* (revocation, crash, final barrier),
/// never completed: audited budgets are untouched by the marketplace,
/// so Σ-budget conservation holds trivially and Σ(resident + pool) ≤
/// budget is unaffected on both sides (staged bytes leave the
/// consumer's pool; the donor's occupancy only ever shrinks under the
/// squeeze).
#[derive(Debug, Clone, Copy)]
struct RemoteLease {
    donor: usize,
    consumer: usize,
    /// Bytes granted at the match: staging never exceeds this.
    granted: u64,
    /// Escrow still held on the donor (granted minus what revocation
    /// already returned chunk by chunk).
    reserved: u64,
    /// The donor turned pressured (or either side started draining):
    /// each tick recalls a chunk of remote bytes to the consumer's NVMe
    /// and returns that much escrow, until the lease dissolves.
    revoking: bool,
}

/// A host marked for graceful drain (degraded NVMe): every VM placed
/// there is evacuated via state migration before the deadline; VMs
/// still waiting when it expires fall back to lease-only relief and
/// count as deadline misses.
#[derive(Debug, Clone, Copy)]
struct Drain {
    host: usize,
    /// Fleet ticks left before the evacuation deadline.
    ticks_left: u32,
    /// Deadline expired and the misses were already counted.
    missed: bool,
    /// Virtual time the fault was injected.
    t0: Time,
}

/// An in-flight budget revocation (Memtrade-style): the lease is taken
/// up front so the shard sheds immediately, then the budget retires
/// from the fleet chunk by chunk as measured headroom materializes —
/// the audited budget never drops below occupancy.
#[derive(Debug, Clone, Copy)]
struct Revocation {
    host: usize,
    remaining: u64,
    /// Consecutive fleet ticks that retired nothing.
    stalled: u32,
}

/// Tracks one recovered VM until its resident set is back to half its
/// pre-fault size (the ledger's time-to-restored-residency gauge).
#[derive(Debug, Clone, Copy)]
struct RecoveryProbe {
    /// Index into `placements` — stable (the log is append-only) and
    /// it follows the VM across shards.
    placement: usize,
    target_bytes: u64,
    t0: Time,
}

/// Everything a finished fleet run returns: per-shard per-VM results in
/// shard order (stats stay on the scheduler). A VM that migrated
/// mid-run is reported by the shard that owned it at the end.
pub type FleetRun = Vec<Vec<RunResult>>;

/// The fleet's one golden boot image (PR 10). A single id suffices:
/// every storm clone shares the same content-addressed image, installed
/// at most once per host backend.
pub const GOLDEN_IMAGE_ID: u32 = 1;

/// A storm VM waiting at the admission queue. Clone decisions happen
/// only at fleet ticks (the parallelism barrier), paced by
/// [`crate::config::CloneConfig::clones_per_tick`], so storms are
/// deterministic under both engines and any worker count.
struct PendingClone {
    spec: FleetVmSpec,
    /// Cold-boot comparison arm: admitted with zero resident memory
    /// but *no* golden image — every boot fault pays the cold NVMe
    /// path instead of a shared-image pool hit.
    cold: bool,
}

/// The fleet scheduler (see module docs).
pub struct FleetScheduler {
    pub cfg: FleetConfig,
    pub shards: Vec<HostShard>,
    /// Admission log, in admission order. A state migration updates the
    /// moved VM's record at the flip, so the log always names the one
    /// shard owning each VM.
    pub placements: Vec<Placement>,
    migrations: Vec<Migration>,
    state_migrations: Vec<StateMigration>,
    /// The fault schedule, sorted `(at, host)`, plus the injection
    /// cursor: everything before the cursor has fired.
    faults: Vec<HostFault>,
    fault_cursor: usize,
    drains: Vec<Drain>,
    revocations: Vec<Revocation>,
    remote_leases: Vec<RemoteLease>,
    probes: Vec<RecoveryProbe>,
    /// Storm VMs staged for clone-from-image admission (PR 10); drained
    /// at fleet ticks, `clones_per_tick` at a time.
    clone_queue: VecDeque<PendingClone>,
    /// Image-backed clones by placement name — crash rebuilds and
    /// state-migration flips re-attach the VM to the new host's copy of
    /// its golden image.
    clone_images: BTreeMap<String, u32>,
    pub stats: FleetStats,
}

impl FleetScheduler {
    /// Build the fleet: one machine per shard from the host template
    /// (per-shard seeds derived deterministically), each with its own
    /// control plane carrying that shard's budget.
    pub fn new(template: &HostConfig, cfg: FleetConfig) -> Self {
        assert!(cfg.hosts > 0, "fleet needs at least one host");
        assert!(cfg.interval > 0, "fleet tick interval must be positive");
        assert!(
            !cfg.host_budgets.is_empty(),
            "fleet needs at least one host budget (they cycle per host)"
        );
        let mut shards = Vec::with_capacity(cfg.hosts);
        let mut total_budget = 0u64;
        for i in 0..cfg.hosts {
            let budget = cfg.budget_of(i);
            total_budget += budget;
            let host = HostConfig {
                seed: template
                    .seed
                    .wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15)),
                ..template.clone()
            };
            let mut machine = Machine::new(host);
            machine.set_max_time(cfg.max_time);
            machine.install_control(ControlConfig {
                host_budget_bytes: Some(budget),
                ..cfg.control.clone()
            });
            shards.push(HostShard {
                id: i,
                machine,
                committed_bytes: 0,
                committed_pressure: 0,
            });
        }
        let mut faults = cfg.faults.clone();
        faults.sort_by_key(|f| (f.at, f.host));
        for f in &faults {
            assert!(
                f.host < cfg.hosts,
                "fault targets host {} but the fleet has {}",
                f.host,
                cfg.hosts
            );
        }
        FleetScheduler {
            stats: FleetStats::new(cfg.hosts, total_budget),
            cfg,
            shards,
            placements: vec![],
            migrations: vec![],
            state_migrations: vec![],
            faults,
            fault_cursor: 0,
            drains: vec![],
            revocations: vec![],
            remote_leases: vec![],
            probes: vec![],
            clone_queue: VecDeque::new(),
            clone_images: BTreeMap::new(),
        }
    }

    /// Admit one VM: pick a shard per the placement policy, spawn +
    /// register it there. Returns (shard, machine slot id).
    pub fn admit(&mut self, spec: FleetVmSpec) -> (usize, usize) {
        let nominal = spec.frames * FRAME_BYTES;
        let pressure = nominal * Sla::Gold.weight() / spec.sla.weight();
        let shard = self.place(pressure);
        let mm_base = spec.mm.unwrap_or_else(|| spec.sla.mm_config());
        let s = &mut self.shards[shard];
        let vm = super::register_vm_on(
            &mut s.machine,
            spec.name.clone(),
            spec.sla,
            spec.frames,
            spec.vcpus,
            spec.workloads,
            spec.initial_limit_bytes,
            mm_base,
        );
        s.committed_bytes += nominal;
        s.committed_pressure += pressure;
        self.placements.push(Placement { name: spec.name, sla: spec.sla, shard, vm });
        (shard, vm)
    }

    /// Stage one storm VM for clone-from-image admission (PR 10).
    /// Nothing happens until a fleet tick drains the queue
    /// ([`Self::admit_clones`]): every clone decision sits at the
    /// parallelism barrier, so storms are byte-identical under both
    /// engines and at any worker count. `cold` marks the comparison
    /// arm: admitted identically but with no golden image behind it.
    pub fn stage_clone(&mut self, spec: FleetVmSpec, cold: bool) {
        self.stats.clones_staged += 1;
        self.clone_queue.push_back(PendingClone { spec, cold });
    }

    /// Drain up to [`crate::config::CloneConfig::clones_per_tick`]
    /// staged clones into the fleet at this tick. An image-backed clone
    /// implants with *zero* resident memory: every frame starts Swapped
    /// against the shared golden image, boot faults decompress units
    /// out of the host's dedup'd pool copy, and boot streaming pulls
    /// the working set ahead inside the recovery window. A cold-boot
    /// arm VM gets the same zero-resident start but no image — its
    /// faults pay the never-written NVMe zero-fill path instead.
    fn admit_clones(&mut self, now: Time) {
        if self.clone_queue.is_empty() {
            return;
        }
        let batch = self.cfg.clone.clones_per_tick.max(1);
        // Bytes this tick's batch has already granted per shard — the
        // occupancy gauge cannot see limits that have not faulted in
        // yet, so stacked same-tick admissions must be tracked by hand
        // (same bookkeeping as the crash-rebuild re-land).
        let mut granted: BTreeMap<usize, u64> = BTreeMap::new();
        for _ in 0..batch {
            let Some(PendingClone { spec, cold }) = self.clone_queue.pop_front() else {
                break;
            };
            let nominal = spec.frames * FRAME_BYTES;
            let pressure = nominal * Sla::Gold.weight() / spec.sla.weight();
            let shard = self.place_clone(cold, GOLDEN_IMAGE_ID);
            let mm_base = spec.mm.unwrap_or_else(|| spec.sla.mm_config());
            let name = spec.name;
            let s = &mut self.shards[shard];
            let vm = super::register_vm_on(
                &mut s.machine,
                name.clone(),
                spec.sla,
                spec.frames,
                spec.vcpus,
                spec.workloads,
                spec.initial_limit_bytes,
                mm_base,
            );
            s.committed_bytes += nominal;
            s.committed_pressure += pressure;
            if cold {
                s.machine.prime_cold_boot(vm);
                self.stats.clone_cold_boots += 1;
            } else {
                let unit_bytes = s.machine.mm(vm).map_or(FRAME_BYTES, |m| m.core.unit_bytes);
                s.machine.ensure_golden_image(
                    GOLDEN_IMAGE_ID,
                    self.cfg.clone.image_seed,
                    self.cfg.clone.image_units,
                    unit_bytes,
                );
                s.machine.attach_clone(
                    vm,
                    GOLDEN_IMAGE_ID,
                    self.cfg.clone.boot_stream_depth,
                    self.cfg.clone.boost_window,
                    now,
                );
                self.clone_images.insert(name.clone(), GOLDEN_IMAGE_ID);
                self.stats.clones_admitted += 1;
            }
            // Like a crash rebuild, mid-run admission cannot wait for
            // the arbiter: clamp the clone's initial limit under the
            // target's measured spare so Σ(resident + pool) ≤ budget
            // keeps holding until the next control tick re-plans
            // around the new tenant (which then grows the clone as its
            // measured WSS rises).
            let already = granted.get(&shard).copied().unwrap_or(0);
            let spare = self
                .shard_budget(shard)
                .saturating_sub(self.shards[shard].machine.host_occupied_bytes())
                .saturating_sub(already);
            let grant = (spare / 2).max(FRAME_BYTES);
            if let Some(mm) = self.shards[shard].machine.mm_mut(vm) {
                let units = (grant / mm.core.unit_bytes).max(1);
                let clamped = mm.core.limit_units.map_or(units, |c| c.min(units));
                mm.core.limit_units = Some(clamped);
                granted.insert(shard, already + clamped * mm.core.unit_bytes);
            }
            self.shards[shard].machine.activate_vm(vm, now);
            self.placements.push(Placement { name, sla: spec.sla, shard, vm });
        }
    }

    /// Placement for storm clones. Spread (the default) picks the
    /// least-pressured live, non-draining shard — clones land
    /// everywhere, each host installs its own image copy once. Pack
    /// prefers shards that *already hold* the golden image, so later
    /// clones ride the existing dedup'd copy instead of installing a
    /// new one (the clone_storm experiment tables both). Ties always
    /// break on the lowest shard id, keeping admission deterministic.
    fn place_clone(&self, cold: bool, image: u32) -> usize {
        let live = |s: &&HostShard| self.stats.alive[s.id] && !self.draining(s.id);
        if !cold && self.cfg.clone.pack {
            if let Some(s) = self
                .shards
                .iter()
                .filter(live)
                .filter(|s| s.machine.backend.image_units(image) > 0)
                .min_by_key(|s| (s.committed_pressure + self.inbound_escrow(s.id), s.id))
            {
                return s.id;
            }
        }
        self.shards
            .iter()
            .filter(live)
            .min_by_key(|s| (s.committed_pressure + self.inbound_escrow(s.id), s.id))
            .map(|s| s.id)
            .expect("clone admission needs a live shard")
    }

    /// Σ in-flight state-migration escrow reserved on shard `i`:
    /// resident sets headed there that have not landed yet. Admission
    /// must treat these bytes as spoken for, or a new tenant squeezes
    /// the target below its escrowed headroom and the flip gate stalls
    /// the migration into an avoidable abort.
    fn inbound_escrow(&self, i: usize) -> u64 {
        self.state_migrations.iter().filter(|m| m.to == i).map(|m| m.escrow).sum()
    }

    /// Shard `i` is a party to any in-flight migration (budget lease or
    /// VM state move, either direction).
    fn migrating(&self, i: usize) -> bool {
        self.migrations.iter().any(|m| m.from == i || m.to == i)
            || self.state_migrations.iter().any(|m| m.from == i || m.to == i)
    }

    /// Placement decision (pure; ties always break on the lowest shard
    /// id so admission is deterministic). Migration-aware: in-flight
    /// state-migration escrow counts against a shard's capacity, and a
    /// migration-free shard is preferred over an equally fitting party
    /// to one. With no migrations in flight both passes reduce to the
    /// original policies exactly.
    fn place(&self, pressure: u64) -> usize {
        match self.cfg.placement {
            crate::config::PlacementPolicy::FirstFitBySla => {
                let fits = |s: &HostShard| {
                    let cap = self.cfg.budget_of(s.id) as u128
                        * self.cfg.fit_overcommit_pct as u128
                        / 100;
                    (s.committed_pressure + self.inbound_escrow(s.id) + pressure) as u128
                        <= cap
                };
                // First pass: migration-free shards only; second pass
                // admits onto a migration party over overflowing.
                for s in self.shards.iter().filter(|s| !self.migrating(s.id)) {
                    if fits(s) {
                        return s.id;
                    }
                }
                for s in &self.shards {
                    if fits(s) {
                        return s.id;
                    }
                }
                // Nothing fits under the overcommit cap: least loaded.
                self.least_pressured()
            }
            crate::config::PlacementPolicy::SpreadByFaultRate => self.least_pressured(),
        }
    }

    fn least_pressured(&self) -> usize {
        self.shards
            .iter()
            .min_by_key(|s| {
                (
                    self.migrating(s.id),
                    s.committed_pressure + self.inbound_escrow(s.id),
                    s.id,
                )
            })
            .map(|s| s.id)
            .expect("fleet has shards")
    }

    /// Run the whole fleet to completion (or the horizon). Two engines
    /// produce byte-identical output (a gated equivalence test):
    ///
    /// * **Parallel epochs** ([`FleetConfig::parallel`], the default) —
    ///   between consecutive fleet ticks every live shard drains its
    ///   own queue up to the tick bound ([`Machine::run_until`]) on a
    ///   scoped worker thread; the threads join at the barrier, then
    ///   the tick runs sequentially in shard-id order. Sound because
    ///   shards share *no* mutable state between ticks — every
    ///   cross-shard effect (lease chunk, pre-copy, flip, audit) is
    ///   applied inside `fleet_tick`, single-threaded.
    /// * **Sequential merge** (the PR 4 oracle, `--sequential`) — one
    ///   global `(time, shard index)` merge of the shards' queues,
    ///   firing fleet ticks at fixed virtual times before any shard
    ///   steps past them.
    ///
    /// Both end at the same **final barrier**: in-flight state
    /// migrations abort cleanly and the per-shard tallies are copied
    /// out, one shared code path.
    pub fn run(&mut self) -> FleetRun {
        for s in &mut self.shards {
            s.machine.start();
        }
        if self.cfg.parallel {
            self.run_epochs();
        } else {
            self.run_merge();
        }
        self.final_barrier();
        self.shards.iter_mut().map(|s| s.machine.finish()).collect()
    }

    /// The sequential `(time, shard index)` merge loop — the
    /// correctness oracle the parallel engine is gated against.
    fn run_merge(&mut self) {
        let mut next_tick = self.cfg.interval;
        loop {
            let next = self
                .shards
                .iter()
                .filter(|s| !s.machine.done())
                .filter_map(|s| s.machine.peek_time().map(|t| (t, s.id)))
                .min();
            // Storm liveness: a fleet whose admitted VMs are all done
            // (or that started empty) has no pending events, but staged
            // clones still need fleet ticks to enter it. Storms off ⇒
            // the queue is empty and both arms reduce to the originals.
            let Some((t, idx)) = next else {
                if !self.clone_queue.is_empty() && next_tick <= self.cfg.max_time {
                    let now = next_tick;
                    self.fleet_tick(now);
                    next_tick += self.cfg.interval;
                    continue;
                }
                break;
            };
            if t > self.cfg.max_time {
                if !self.clone_queue.is_empty() && next_tick <= self.cfg.max_time {
                    let now = next_tick;
                    self.fleet_tick(now);
                    next_tick += self.cfg.interval;
                    continue;
                }
                break;
            }
            while next_tick <= t {
                let now = next_tick;
                self.fleet_tick(now);
                next_tick += self.cfg.interval;
            }
            self.shards[idx].machine.step_one();
        }
    }

    /// The parallel epoch loop. Each iteration: find the earliest
    /// pending event over live shards (exactly the merge loop's key,
    /// minus the shard index — only the time gates anything here), fire
    /// every fleet tick due at or before it, then drain all shards up
    /// to the next unfired tick bound concurrently. After an epoch no
    /// live shard holds an event below the bound, so the next iteration
    /// fires the tick at that bound before anything at or past it runs
    /// — the same tick/event interleave the merge loop produces.
    fn run_epochs(&mut self) {
        let mut next_tick = self.cfg.interval;
        loop {
            let next = self
                .shards
                .iter()
                .filter(|s| !s.machine.done())
                .filter_map(|s| s.machine.peek_time())
                .min();
            // Storm liveness (mirrors `run_merge` exactly — the gate is
            // single-threaded in both engines, so tick times and order
            // stay byte-identical).
            let Some(t) = next else {
                if !self.clone_queue.is_empty() && next_tick <= self.cfg.max_time {
                    let now = next_tick;
                    self.fleet_tick(now);
                    next_tick += self.cfg.interval;
                    continue;
                }
                break;
            };
            if t > self.cfg.max_time {
                if !self.clone_queue.is_empty() && next_tick <= self.cfg.max_time {
                    let now = next_tick;
                    self.fleet_tick(now);
                    next_tick += self.cfg.interval;
                    continue;
                }
                break;
            }
            while next_tick <= t {
                let now = next_tick;
                self.fleet_tick(now);
                next_tick += self.cfg.interval;
            }
            self.run_epoch(next_tick);
        }
    }

    /// Drain every shard's queue up to `bound` (exclusive), each shard
    /// on its own worker. Shard state is disjoint between ticks, so the
    /// partition of shards onto workers — and the worker count itself —
    /// cannot affect any shard's state at the barrier.
    fn run_epoch(&mut self, bound: Time) {
        let workers = self.worker_count().min(self.shards.len());
        if workers <= 1 {
            for s in &mut self.shards {
                s.machine.run_until(bound);
            }
            return;
        }
        let per = self.shards.len().div_ceil(workers);
        std::thread::scope(|scope| {
            for chunk in self.shards.chunks_mut(per) {
                scope.spawn(move || {
                    for s in chunk {
                        s.machine.run_until(bound);
                    }
                });
            }
        });
    }

    /// Worker threads for the parallel engine ([`FleetConfig::workers`];
    /// default: all cores).
    fn worker_count(&self) -> usize {
        self.cfg.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        })
    }

    /// The final barrier, shared by both engines. A state migration
    /// still in flight at the horizon aborts cleanly: the VM never left
    /// its donor, the staged copies are dropped and the escrow returns
    /// — end-of-run audits see no half-moved VM. Abort order is
    /// irrelevant to the audited totals (each abort touches only its
    /// own migration's target shard, and the fleet's `busy()` admission
    /// keeps in-flight targets disjoint — pinned by a test), so aborts
    /// run in plain ascending index order.
    fn final_barrier(&mut self) {
        for idx in 0..self.state_migrations.len() {
            self.abort_state_migration(idx);
        }
        self.state_migrations.clear();
        // A revocation still converging at the horizon returns its
        // unretired remainder to the shard's arbitration budget (the
        // retired part stays retired — the audit baseline moved with
        // it).
        for r in std::mem::take(&mut self.revocations) {
            self.shards[r.host]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .cancel_lease(r.remaining);
        }
        // Remote leases dissolve at the horizon: every escrow returns
        // to its donor's arbitration budget (audited budgets never
        // moved, so the conservation audit saw nothing either way).
        // Staged entries stay on the remote tier — their reads already
        // paid the modeled network latency, and no one is left to
        // fault them back.
        for l in std::mem::take(&mut self.remote_leases) {
            self.shards[l.donor]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .cancel_lease(l.reserved);
        }
        // Copy the per-shard invariant tallies out for the test suite.
        for (i, s) in self.shards.iter().enumerate() {
            if let Some(cs) = s.machine.control_stats() {
                self.stats.budget_exceeded_ticks[i] = cs.budget_exceeded_ticks;
            }
        }
    }

    /// Σ events handled across all shards (the fleet_scale bench's
    /// events/sec numerator; engine-independent for the same seed).
    pub fn events_handled(&self) -> u64 {
        self.shards.iter().map(|s| s.machine.events_handled).sum()
    }

    /// Re-shape shard `i`'s budget before the run starts (experiments
    /// size budgets from the actually admitted mix). Re-baselines the
    /// conservation audit to the new Σ.
    pub fn set_shard_budget(&mut self, i: usize, bytes: u64) {
        let cp = self.shards[i]
            .machine
            .control_mut()
            .expect("shard has a control plane");
        cp.cfg.host_budget_bytes = Some(bytes);
        cp.stats.budget_bytes = bytes;
        self.stats.total_budget_bytes =
            (0..self.shards.len()).map(|j| self.shard_budget(j)).sum();
    }

    /// Audited budget of shard `i` right now (migrations move it).
    pub fn shard_budget(&self, i: usize) -> u64 {
        self.shards[i]
            .machine
            .control()
            .and_then(|c| c.cfg.host_budget_bytes)
            .unwrap_or(0)
    }

    /// One fleet tick: inject due faults, advance drains/revocations
    /// and in-flight migrations chunk by chunk (budget leases and VM
    /// state migrations), consider starting a new one, refresh the
    /// health gauges, audit budget conservation.
    fn fleet_tick(&mut self, now: Time) {
        self.stats.fleet_ticks += 1;
        self.inject_faults(now);
        self.admit_clones(now);
        self.advance_drains(now);
        self.advance_revocations();
        self.advance_migrations(now);
        self.advance_state_migrations(now);
        let active = self.migrations.len() + self.state_migrations.len();
        if self.cfg.migration && active < self.cfg.max_active_migrations {
            self.consider_migration();
        }
        if self.cfg.remote.enabled {
            self.advance_remote(now);
            self.match_remote();
        }
        self.check_probes(now);
        self.update_health();
        let sum: u64 = (0..self.shards.len()).map(|i| self.shard_budget(i)).sum();
        self.stats.audit_budgets(sum);
    }

    /// Fire every scheduled fault due at or before `now`, in `(at,
    /// host)` order. Fleet ticks are single-threaded under both
    /// engines, so injection is deterministic at any worker count. A
    /// fault aimed at an already-dead host is dropped.
    fn inject_faults(&mut self, now: Time) {
        while self.fault_cursor < self.faults.len() && self.faults[self.fault_cursor].at <= now {
            let f = self.faults[self.fault_cursor];
            self.fault_cursor += 1;
            if !self.stats.alive[f.host] {
                continue;
            }
            self.stats.faults_injected += 1;
            match f.kind {
                HostFaultKind::Crash => self.crash_host(f.host, now),
                HostFaultKind::DegradedNvme => self.begin_drain(f.host, now),
                HostFaultKind::BudgetRevoke => self.begin_revocation(f.host),
            }
        }
    }

    fn draining(&self, host: usize) -> bool {
        self.drains.iter().any(|d| d.host == host)
    }

    /// Hard host crash. Everything DRAM-resident on the shard is gone;
    /// NVMe receipts survive. In order: abort migrations touching the
    /// dead shard (remainders and escrows return to their *surviving*
    /// counterparties — the dead side's lease state is wiped with its
    /// budget), rebuild every placed VM on a surviving shard from its
    /// salvaged receipts, then retire the dead budget so the
    /// conservation Σ steps down by exactly that amount this tick.
    fn crash_host(&mut self, host: usize, now: Time) {
        self.stats.crashes += 1;
        self.stats.alive[host] = false;
        self.drains.retain(|d| d.host != host);
        // An in-flight revocation's lease dies with the host's control
        // plane; the not-yet-revoked remainder is part of the audited
        // budget the retirement below removes.
        self.revocations.retain(|r| r.host != host);
        let mut i = 0;
        while i < self.migrations.len() {
            let m = self.migrations[i];
            if m.from == host || m.to == host {
                if m.from != host {
                    // Receiver died; the surviving donor takes its
                    // undelivered remainder back into arbitration.
                    self.shards[m.from]
                        .machine
                        .control_mut()
                        .expect("shard has a control plane")
                        .cancel_lease(m.total - m.moved);
                }
                self.stats.migrations_aborted += 1;
                self.migrations.remove(i);
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.state_migrations.len() {
            let m = &self.state_migrations[i];
            if m.from == host || m.to == host {
                self.abort_state_migration(i);
                self.state_migrations.remove(i);
            } else {
                i += 1;
            }
        }
        // Remote leases touching the dead shard dissolve now, before
        // the rebuilds measure survivor occupancy. Donor died: its DRAM
        // — and every remote entry it hosted — is gone; the surviving
        // consumer drops them and re-faults each as a measured cold
        // miss (no escrow to return — the dead budget retires whole
        // below). Consumer died: the surviving donor takes its full
        // escrow back into arbitration; the dead consumer's remote
        // entries are salvage-counted as lost with the rest of its
        // DRAM-resident state.
        let mut i = 0;
        while i < self.remote_leases.len() {
            let l = self.remote_leases[i];
            if l.donor == host {
                let (units, bytes) =
                    self.shards[l.consumer].machine.backend.remote_drop();
                self.stats.remote_dropped_units += units;
                self.stats.remote_dropped_bytes += bytes;
                self.remote_leases.remove(i);
            } else if l.consumer == host {
                self.shards[l.donor]
                    .machine
                    .control_mut()
                    .expect("shard has a control plane")
                    .cancel_lease(l.reserved);
                self.remote_leases.remove(i);
            } else {
                i += 1;
            }
        }
        // Rebuild the lost VMs, in placement (admission) order.
        let victims: Vec<usize> = (0..self.placements.len())
            .filter(|&i| self.placements[i].shard == host)
            .collect();
        let mut granted: BTreeMap<usize, u64> = BTreeMap::new();
        for pidx in victims {
            let vm = self.placements[pidx].vm;
            let sla = self.placements[pidx].sla;
            let pre_resident = self.shards[host].machine.vm_resident_bytes(vm);
            let salvage = self.shards[host].machine.backend.salvage_vm(vm);
            self.shards[host].machine.crash_demote_residency(vm);
            let image = self.shards[host]
                .machine
                .extract_vm(vm)
                .expect("crashed VM occupies its slot");
            let nominal = image.nominal_bytes();
            let survivor = self.rebuild_target(host);
            let reserved = self.shards[survivor].machine.reserve_slot();
            self.shards[survivor].machine.prepare_adoption(reserved, sla);
            self.stats.vms_rebuilt += 1;
            self.stats.rebuild_salvaged_units += salvage.units.len() as u64;
            self.stats.rebuild_salvaged_bytes += salvage.salvaged_bytes;
            self.stats.rebuild_lost_units += salvage.lost_units;
            self.stats.rebuild_lost_bytes += salvage.lost_bytes;
            for u in salvage.units {
                self.shards[survivor].machine.backend.import_unit(reserved, u);
            }
            self.shards[survivor]
                .machine
                .implant_vm(reserved, image, self.cfg.crash_rebuild_stop_ns);
            // Unlike a flip, a crash rebuild cannot wait for headroom:
            // clamp the arrival's limit under the survivor's measured
            // spare (tracking what this crash already granted it) so
            // Σ(resident + pool) ≤ budget holds until the arbiter
            // re-plans around the new tenant.
            let already = granted.get(&survivor).copied().unwrap_or(0);
            let spare = self
                .shard_budget(survivor)
                .saturating_sub(self.shards[survivor].machine.host_occupied_bytes())
                .saturating_sub(already);
            let grant = (spare / 2).max(FRAME_BYTES);
            if let Some(mm) = self.shards[survivor].machine.mm_mut(reserved) {
                let units = (grant / mm.core.unit_bytes).max(1);
                let clamped = mm.core.limit_units.map_or(units, |c| c.min(units));
                mm.core.limit_units = Some(clamped);
                granted.insert(survivor, already + clamped * mm.core.unit_bytes);
            }
            // An image-backed clone re-attaches to the survivor's copy
            // of its golden image: `extract_vm` → `forget_vm` dropped
            // the dead host's reference, and the implant resynced tiers
            // *before* the image existed here. Salvaged private (CoW)
            // entries imported above still win over the image on reads.
            if let Some(&img) = self.clone_images.get(&self.placements[pidx].name) {
                let unit_bytes = self.shards[survivor]
                    .machine
                    .mm(reserved)
                    .map_or(FRAME_BYTES, |m| m.core.unit_bytes);
                let m = &mut self.shards[survivor].machine;
                m.ensure_golden_image(
                    img,
                    self.cfg.clone.image_seed,
                    self.cfg.clone.image_units,
                    unit_bytes,
                );
                m.backend.attach_image(reserved, img);
                m.resync_vm_tiers(reserved);
            }
            let pressure = nominal * Sla::Gold.weight() / sla.weight();
            self.shards[host].committed_bytes -= nominal;
            self.shards[host].committed_pressure -= pressure;
            self.shards[survivor].committed_bytes += nominal;
            self.shards[survivor].committed_pressure += pressure;
            self.placements[pidx].shard = survivor;
            self.placements[pidx].vm = reserved;
            self.probes.push(RecoveryProbe {
                placement: pidx,
                target_bytes: pre_resident / 2,
                t0: now,
            });
        }
        let lost = self.shards[host]
            .machine
            .control_mut()
            .expect("shard has a control plane")
            .retire_host_budget();
        self.stats.retire_budget(lost);
    }

    /// Where a crash rebuild lands: the least-pressured live shard,
    /// preferring ones that are not draining (falling back to a
    /// draining one over losing the VM).
    fn rebuild_target(&self, dead: usize) -> usize {
        let candidate = |draining_ok: bool| {
            self.shards
                .iter()
                .filter(|s| s.id != dead && self.stats.alive[s.id])
                .filter(|s| draining_ok || !self.draining(s.id))
                .min_by_key(|s| (s.committed_pressure, s.id))
                .map(|s| s.id)
        };
        candidate(false)
            .or_else(|| candidate(true))
            .expect("fault plan left no live shard to rebuild on")
    }

    /// Degraded-NVMe fault: inflate the shard's flash latency and start
    /// the graceful drain (it stays degraded; the drain entry is what
    /// expires or completes).
    fn begin_drain(&mut self, host: usize, now: Time) {
        self.stats.degrades += 1;
        self.shards[host]
            .machine
            .nvme
            .set_degrade_factor(self.cfg.nvme_degrade_factor);
        if self.draining(host) {
            return;
        }
        self.stats.drains_started += 1;
        self.drains.push(Drain {
            host,
            ticks_left: self.cfg.drain_deadline_ticks,
            missed: false,
            t0: now,
        });
    }

    /// Advance every drain one fleet tick: evacuate waiting VMs to the
    /// sparest live shards via the state-migration path (bypassing the
    /// rebalancer's single-migration budget — this is a mass drain),
    /// count deadline misses once when the clock runs out, and retire
    /// the drain when the shard holds no more VMs.
    fn advance_drains(&mut self, now: Time) {
        if self.drains.is_empty() {
            return;
        }
        let n = self.shards.len();
        let snaps: Vec<ShardSnap> = (0..n).map(|i| self.snapshot(i)).collect();
        let mut spare: Vec<u64> = (0..n)
            .map(|i| {
                (snaps[i].usable as u128 * self.cfg.donor_demand_pct as u128 / 100)
                    .saturating_sub(snaps[i].demand as u128) as u64
            })
            .collect();
        let mut d = 0;
        while d < self.drains.len() {
            let host = self.drains[d].host;
            let vms_here: Vec<usize> = self
                .placements
                .iter()
                .filter(|p| p.shard == host)
                .map(|p| p.vm)
                .collect();
            if vms_here.is_empty() {
                self.stats.drains_completed += 1;
                self.drains.remove(d);
                continue;
            }
            let waiting: Vec<usize> = vms_here
                .into_iter()
                .filter(|&vm| {
                    !self
                        .state_migrations
                        .iter()
                        .any(|m| m.from == host && m.vm == vm)
                })
                .collect();
            if self.drains[d].ticks_left == 0 {
                if !self.drains[d].missed {
                    self.drains[d].missed = true;
                    self.stats.drain_deadline_misses += waiting.len() as u64;
                }
                d += 1;
                continue;
            }
            self.drains[d].ticks_left -= 1;
            let t0 = self.drains[d].t0;
            let hots: Vec<HotVm> = {
                let reports = self.shards[host].machine.control_reports();
                waiting
                    .iter()
                    .filter_map(|&vm| reports.iter().find(|r| r.vm == vm))
                    .map(|r| {
                        let cur = r.limit_bytes.unwrap_or(r.usage_bytes);
                        HotVm {
                            vm: r.vm,
                            deficit: Arbiter::demand_of(r).saturating_sub(cur),
                            demand: Arbiter::demand_of(r),
                            usage: r.usage_bytes,
                            limit: r.limit_bytes,
                            inflight: r.inflight_allowance,
                        }
                    })
                    .collect()
            };
            for hot in hots {
                let target = (0..n)
                    .filter(|&i| i != host && self.stats.alive[i] && !self.draining(i))
                    .filter(|&i| spare[i] >= hot.demand)
                    .max_by_key(|&i| (spare[i], std::cmp::Reverse(i)));
                let Some(dst) = target else { continue };
                spare[dst] = spare[dst].saturating_sub(hot.demand.max(1));
                self.start_state_migration(host, dst, hot, Some(t0));
            }
            d += 1;
        }
    }

    /// Budget-revocation fault: the provider wants `revoke_pct` of the
    /// shard's budget back. Take the lease up front (the shard starts
    /// shedding now); the retirement itself is paced by measured
    /// headroom in [`Self::advance_revocations`].
    fn begin_revocation(&mut self, host: usize) {
        self.stats.revocations += 1;
        let want = self.shard_budget(host) * self.cfg.revoke_pct as u64 / 100;
        let cp = self.shards[host]
            .machine
            .control_mut()
            .expect("shard has a control plane");
        // Never lease past what is arbitrable: an escrow or an earlier
        // revocation may already hold part of the budget.
        let take = cp.arbitration_budget().unwrap_or(0).min(want);
        if take == 0 {
            return;
        }
        cp.begin_lease(take);
        self.revocations.push(Revocation { host, remaining: take, stalled: 0 });
    }

    /// Retire each revocation's next chunk — bounded by measured
    /// headroom minus the margin, exactly the lease-migration pacing —
    /// stepping the conservation baseline down in the same tick. A
    /// revocation that stops converging cancels its remainder.
    fn advance_revocations(&mut self) {
        let mut i = 0;
        while i < self.revocations.len() {
            let host = self.revocations[i].host;
            let budget = self.shard_budget(host);
            let occupied = self.shards[host].machine.host_occupied_bytes();
            let avail = budget
                .saturating_sub(occupied)
                .saturating_sub(self.cfg.migration_margin_bytes);
            let remaining = self.revocations[i].remaining;
            let chunk = remaining.min(avail);
            if chunk == 0 || chunk < self.cfg.migration_min_chunk.min(remaining) {
                self.revocations[i].stalled += 1;
                if self.revocations[i].stalled > self.cfg.migration_stall_ticks {
                    self.shards[host]
                        .machine
                        .control_mut()
                        .expect("shard has a control plane")
                        .cancel_lease(remaining);
                    self.revocations.remove(i);
                } else {
                    i += 1;
                }
                continue;
            }
            self.shards[host]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .complete_lease(chunk);
            self.stats.retire_budget(chunk);
            self.stats.revoked_bytes += chunk;
            self.revocations[i].remaining -= chunk;
            self.revocations[i].stalled = 0;
            if self.revocations[i].remaining == 0 {
                self.revocations.remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Advance every remote-memory lease one fleet tick (single-
    /// threaded at the barrier, like all marketplace decisions). A
    /// healthy lease stages the consumer's coldest pool entries toward
    /// its grant — paced per tick and gated on the donor's *measured*
    /// free DRAM (budget − occupancy − already-hosted bytes − margin),
    /// so hosting never pushes the donor over its own budget. When the
    /// donor turns pressured (or either side starts draining), the
    /// lease flips to revoking: each tick a chunk of remote bytes
    /// writes back to the consumer's local NVMe and exactly that much
    /// escrow returns to the donor's arbitration budget, until no
    /// remote bytes remain and the lease dissolves.
    fn advance_remote(&mut self, now: Time) {
        let mut i = 0;
        while i < self.remote_leases.len() {
            let lease = self.remote_leases[i];
            let (donor, consumer) = (lease.donor, lease.consumer);
            if !lease.revoking {
                let snap = self.snapshot(donor);
                let pressured = snap.demand as u128 * 100
                    > snap.usable as u128 * self.cfg.donor_demand_pct as u128;
                if pressured || self.draining(donor) || self.draining(consumer) {
                    self.remote_leases[i].revoking = true;
                    self.stats.remote_revocations += 1;
                }
            }
            if self.remote_leases[i].revoking {
                let chunk = self.cfg.remote.recall_chunk_bytes;
                let m = &mut self.shards[consumer].machine;
                let recalled = m.backend.remote_recall(chunk, now, &mut m.nvme);
                if recalled > 0 {
                    self.stats.remote_recalled_bytes += recalled;
                    self.shards[donor]
                        .machine
                        .control_mut()
                        .expect("shard has a control plane")
                        .cancel_lease(recalled);
                    let l = &mut self.remote_leases[i];
                    l.reserved = l.reserved.saturating_sub(recalled);
                }
                if self.shards[consumer].machine.backend.remote_bytes() == 0 {
                    // Everything recalled (or rewritten/migrated away
                    // in the meantime): return the escrow remainder and
                    // dissolve.
                    let remainder = self.remote_leases[i].reserved;
                    if remainder > 0 {
                        self.shards[donor]
                            .machine
                            .control_mut()
                            .expect("shard has a control plane")
                            .cancel_lease(remainder);
                    }
                    self.remote_leases.remove(i);
                    continue;
                }
            } else {
                let staged = self.shards[consumer].machine.backend.remote_bytes();
                let want = self.remote_leases[i]
                    .granted
                    .saturating_sub(staged)
                    .min(self.cfg.remote.stage_chunk_bytes);
                let donor_free = self
                    .shard_budget(donor)
                    .saturating_sub(self.shards[donor].machine.host_occupied_bytes())
                    .saturating_sub(staged)
                    .saturating_sub(self.cfg.migration_margin_bytes);
                let chunk = want.min(donor_free);
                if chunk > 0 {
                    let got =
                        self.shards[consumer].machine.backend.remote_stage(chunk);
                    self.stats.remote_staged_bytes += got;
                }
            }
            i += 1;
        }
    }

    /// Match new remote leases at the tick barrier. An **offer** comes
    /// from a live, non-draining shard that is not already party to a
    /// lease, sits comfortably under the donor line, and has pool slack
    /// (pool occupancy below its own low watermark — it is not even
    /// draining to NVMe). A **bid** comes from a pressured shard (the
    /// arbiter's own infeasibility criterion) with pool entries to
    /// stage. The worst-pressured bid matches the most-spare offer,
    /// ties breaking on the lowest shard id, until either side runs out
    /// — one lease per donor and per consumer, so matching is a simple
    /// deterministic zip.
    fn match_remote(&mut self) {
        let n = self.shards.len();
        if n < 2 {
            return;
        }
        let snaps: Vec<ShardSnap> = (0..n).map(|i| self.snapshot(i)).collect();
        let leased = |i: usize| {
            self.remote_leases.iter().any(|l| l.donor == i || l.consumer == i)
        };
        let eligible = |i: usize| self.stats.alive[i] && !self.draining(i) && !leased(i);
        let spare_of = |i: usize| -> u64 {
            (snaps[i].usable as u128 * self.cfg.donor_demand_pct as u128 / 100)
                .saturating_sub(snaps[i].demand as u128) as u64
        };
        let mut offers: Vec<(usize, u64)> = (0..n)
            .filter(|&i| eligible(i))
            .filter(|&i| {
                let m = &self.shards[i].machine;
                m.backend_metrics().pool_bytes < m.host.tier.low_watermark_bytes()
            })
            .map(|i| (i, spare_of(i).min(self.cfg.remote.max_lease_bytes)))
            .filter(|&(_, sz)| sz >= self.cfg.remote.min_lease_bytes)
            .collect();
        let mut bids: Vec<usize> = (0..n)
            .filter(|&i| eligible(i))
            .filter(|&i| {
                snaps[i].demand as u128 * 100
                    > snaps[i].usable as u128 * self.cfg.pressure_demand_pct as u128
            })
            .filter(|&i| self.shards[i].machine.backend_metrics().pool_bytes > 0)
            .collect();
        self.stats.remote_offers += offers.len() as u64;
        self.stats.remote_bids += bids.len() as u64;
        bids.sort_by_key(|&i| {
            let ratio = if snaps[i].usable == 0 {
                u128::MAX
            } else {
                snaps[i].demand as u128 * 1_000_000 / snaps[i].usable as u128
            };
            (std::cmp::Reverse(ratio), i)
        });
        offers.sort_by_key(|&(i, sz)| (std::cmp::Reverse(sz), i));
        for (consumer, (donor, sz)) in bids.into_iter().zip(offers) {
            self.shards[donor]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .begin_lease(sz);
            self.remote_leases.push(RemoteLease {
                donor,
                consumer,
                granted: sz,
                reserved: sz,
                revoking: false,
            });
            self.stats.remote_leases += 1;
            self.stats.remote_leased_bytes += sz;
        }
    }

    /// Resolve recovery probes: a recovered VM counts as restored once
    /// its resident set is back to the probe's target (half its
    /// pre-fault size).
    fn check_probes(&mut self, now: Time) {
        let mut i = 0;
        while i < self.probes.len() {
            let p = self.probes[i];
            let pl = &self.placements[p.placement];
            let resident = self.shards[pl.shard].machine.vm_resident_bytes(pl.vm);
            if resident >= p.target_bytes {
                self.stats.residency_restored += 1;
                self.stats.residency_restore_ns_max =
                    self.stats.residency_restore_ns_max.max(now - p.t0);
                self.probes.remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Health-check gauges: a live shard's fault-latency EWMA (α=1/8
    /// over its current mean guest fault latency), a dead shard's
    /// missed-tick counter.
    fn update_health(&mut self) {
        for (i, s) in self.shards.iter().enumerate() {
            if self.stats.alive[i] {
                let sample = s.machine.host_fault_mean_ns();
                let e = self.stats.fault_ewma_ns[i];
                self.stats.fault_ewma_ns[i] = e - e / 8 + sample / 8;
            } else {
                self.stats.missed_ticks[i] += 1;
            }
        }
    }

    /// Move what each migration's donor can *prove* free: a chunk is
    /// bounded by the donor's measured headroom minus the margin, so
    /// the audited budget never drops below current occupancy. The
    /// squeeze that frees the memory is the arbiter's, planning around
    /// `budget - lease` since `begin_lease` — this is the staged
    /// shed-then-release pacing, fleet edition.
    fn advance_migrations(&mut self, now: Time) {
        for m in self.migrations.iter_mut() {
            let donor = &self.shards[m.from];
            let budget = donor
                .machine
                .control()
                .and_then(|c| c.cfg.host_budget_bytes)
                .unwrap_or(0);
            let headroom = budget.saturating_sub(donor.machine.host_occupied_bytes());
            let avail = headroom.saturating_sub(self.cfg.migration_margin_bytes);
            let remaining = m.total - m.moved;
            let chunk = remaining.min(avail);
            if chunk == 0 || chunk < self.cfg.migration_min_chunk.min(remaining) {
                m.stalled += 1;
                continue;
            }
            self.shards[m.from]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .complete_lease(chunk);
            self.shards[m.to]
                .machine
                .control_mut()
                .expect("shard has a control plane")
                .grow_budget(chunk);
            // A proportional-share receiver converts the new headroom
            // into a boost-flagged raise on its own; a static one needs
            // the explicit staged release to act at all. Targets are
            // cumulative off the limit seen at the first chunk — a
            // later chunk must not re-read a mid-staging intermediate
            // limit and drop the unfinished part of the prior raise.
            let receiver = &self.shards[m.to].machine;
            if receiver.control().map(|c| c.cfg.kind) == Some(ArbiterKind::Static) {
                let cur = receiver
                    .mm(m.vm)
                    .and_then(|mm| mm.core.limit_units.map(|l| l * mm.core.unit_bytes));
                if let Some(cur) = cur {
                    let base = *m.base_limit.get_or_insert(cur);
                    self.shards[m.to].machine.schedule_limit_release(
                        m.vm,
                        now,
                        Some(base + m.moved + chunk),
                        true,
                        true,
                    );
                }
            }
            m.moved += chunk;
            m.stalled = 0;
            self.stats.record_transfer(m.from, m.to, chunk);
        }
        // Retire completed migrations; abort stalled ones (their
        // undelivered remainder returns to the donor's arbitration
        // budget — never lost, never duplicated).
        let mut i = 0;
        while i < self.migrations.len() {
            let m = self.migrations[i];
            if m.moved == m.total {
                self.stats.migrations_completed += 1;
                self.migrations.remove(i);
            } else if m.stalled > self.cfg.migration_stall_ticks {
                self.shards[m.from]
                    .machine
                    .control_mut()
                    .expect("shard has a control plane")
                    .cancel_lease(m.total - m.moved);
                self.stats.migrations_aborted += 1;
                self.migrations.remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Per-shard pressure snapshot for one migration decision. Works on
    /// the control plane's reused report buffer in place — only four
    /// scalars leave this function, nothing is allocated per tick.
    fn snapshot(&mut self, i: usize) -> ShardSnap {
        let pf_delta_min = self.cfg.migrate_pf_delta_min;
        let s = &mut self.shards[i];
        // Host-view inputs first (immutable probes), then the report
        // rebuild borrow, consumed before this function returns.
        let cp = s.machine.control().expect("shard has a control plane");
        let arb_budget = cp.arbitration_budget().unwrap_or(0);
        let pool_reserved = if cp.cfg.host_budget_bytes.is_some() {
            s.machine.host.tier.pool_capacity_bytes
        } else {
            0
        };
        let host = HostView {
            budget_bytes: arb_budget,
            resident_bytes: s.machine.host_resident_bytes(),
            pool_bytes: s.machine.backend_metrics().pool_bytes,
            pool_reserved_bytes: pool_reserved,
        };
        let reports = s.machine.control_reports();
        let usable = Arbiter::usable_budget(reports, &host);
        let demand: u64 = reports.iter().map(Arbiter::demand_of).sum();
        let cold: u64 = reports.iter().map(|r| r.cold_estimate_bytes).sum();
        // Hottest eligible VM: max fault-rate delta, ties to the lowest
        // slot id; `deficit` is its demand shortfall vs its current
        // limit, the rest sizes a potential whole-VM move.
        let hot = reports
            .iter()
            .filter(|r| r.pf_delta >= pf_delta_min)
            .max_by_key(|r| (r.pf_delta, std::cmp::Reverse(r.vm)))
            .map(|r| {
                let cur = r.limit_bytes.unwrap_or(r.usage_bytes);
                HotVm {
                    vm: r.vm,
                    deficit: Arbiter::demand_of(r).saturating_sub(cur),
                    demand: Arbiter::demand_of(r),
                    usage: r.usage_bytes,
                    limit: r.limit_bytes,
                    inflight: r.inflight_allowance,
                }
            });
        ShardSnap { usable, demand, cold, hot }
    }

    /// Start at most one new migration: the most demand-overloaded
    /// shard with a fault-spiking VM either ships that VM to the
    /// slackest shard that can absorb it whole (full state migration,
    /// when enabled) or leases cold memory from the slackest feasible
    /// shard (the PR 4 budget lease).
    fn consider_migration(&mut self) {
        let n = self.shards.len();
        if n < 2 {
            return;
        }
        let snaps: Vec<ShardSnap> = (0..n).map(|i| self.snapshot(i)).collect();
        let busy = |i: usize| {
            self.migrations.iter().any(|m| m.from == i || m.to == i)
                || self
                    .state_migrations
                    .iter()
                    .any(|m| m.from == i || m.to == i)
        };
        // Dead shards hold nothing to move; draining shards are being
        // mass-evacuated already and must not also join the regular
        // rebalance (as source, target or donor).
        let eligible = |i: usize| self.stats.alive[i] && !self.draining(i);
        // Pressured: Σ demand above the trigger fraction of usable,
        // with an eligible hot VM. Pick the worst ratio, ties low id.
        let pressured = (0..n)
            .filter(|&i| eligible(i) && !busy(i) && snaps[i].hot.is_some())
            .filter(|&i| {
                snaps[i].demand as u128 * 100
                    > snaps[i].usable as u128 * self.cfg.pressure_demand_pct as u128
            })
            .max_by_key(|&i| {
                let ratio = if snaps[i].usable == 0 {
                    u128::MAX
                } else {
                    snaps[i].demand as u128 * 1_000_000 / snaps[i].usable as u128
                };
                (ratio, std::cmp::Reverse(i))
            });
        let Some(src) = pressured else { return };
        // Spare capacity: how far a shard sits below the donor line.
        let spare_of = |i: usize| -> u64 {
            (snaps[i].usable as u128 * self.cfg.donor_demand_pct as u128 / 100)
                .saturating_sub(snaps[i].demand as u128) as u64
        };
        let hot = snaps[src].hot.expect("pressured shard has a hot VM");

        // Full state migration first (when enabled): the slackest shard
        // that can absorb the VM's *whole* demand and still sit under
        // the donor line. Moving the VM removes its entire demand from
        // the pressured shard — strictly stronger relief than any lease
        // — so it is preferred whenever feasible.
        if self.cfg.state_migration {
            let target = (0..n)
                .filter(|&i| i != src && eligible(i) && !busy(i))
                .filter(|&i| spare_of(i) >= hot.demand)
                .max_by_key(|&i| (spare_of(i), std::cmp::Reverse(i)));
            if let Some(dst) = target {
                self.start_state_migration(src, dst, hot, None);
                return;
            }
        }

        // Budget lease fallback: a donor stays comfortably feasible
        // after the lease and has cold slack to shed. Most spare wins,
        // ties low id.
        let donor = (0..n)
            .filter(|&i| i != src && eligible(i) && !busy(i))
            .filter(|&i| spare_of(i) > 0 && snaps[i].cold > 0)
            .max_by_key(|&i| (spare_of(i), std::cmp::Reverse(i)));
        let Some(dst) = donor else { return };
        let want = hot
            .deficit
            .min(self.cfg.migration_max_bytes)
            .min(spare_of(dst))
            .min(snaps[dst].cold);
        if want < self.cfg.migration_min_chunk {
            return;
        }
        self.shards[dst]
            .machine
            .control_mut()
            .expect("shard has a control plane")
            .begin_lease(want);
        self.migrations.push(Migration {
            from: dst,
            to: src,
            vm: hot.vm,
            total: want,
            moved: 0,
            stalled: 0,
            base_limit: None,
        });
        self.stats.migrations_started += 1;
    }

    /// Begin a full VM state migration `src → dst`: reserve the target
    /// slot, take the headroom escrow on the target's arbitration
    /// budget (the resident set that will arrive at the flip, plus the
    /// configured margin — its fleet starts shedding immediately), and
    /// enter the pre-copy phase.
    fn start_state_migration(
        &mut self,
        src: usize,
        dst: usize,
        hot: HotVm,
        drain_since: Option<Time>,
    ) {
        // Expected resident arrival: capped by the limit the donor's
        // arbiter enforces (plus in-flight slack), or current usage for
        // an unlimited VM. The escrow also covers the flip threshold —
        // the pool bytes a converged flip may still have to import —
        // plus a double margin, so the measured-headroom gate is
        // *strictly* implied by the escrow once the target's fleet has
        // shed to its escrowed limits: a converged migration cannot
        // stall indefinitely.
        let expect_resident = hot.limit.unwrap_or(hot.usage).max(hot.usage) + hot.inflight;
        let escrow = expect_resident
            + self.cfg.state_flip_threshold_bytes
            + 2 * self.cfg.migration_margin_bytes;
        self.shards[dst]
            .machine
            .control_mut()
            .expect("shard has a control plane")
            .begin_lease(escrow);
        let reserved = self.shards[dst].machine.reserve_slot();
        // Pre-copied pool entries must land in the VM's SLA partition
        // from the first chunk, not in class 0's — and an empty target
        // shard's pool must be partitioned *now*, not at the flip.
        let sla = self
            .placements
            .iter()
            .find(|p| p.shard == src && p.vm == hot.vm)
            .map(|p| p.sla)
            .unwrap_or(Sla::Silver);
        self.shards[dst].machine.prepare_adoption(reserved, sla);
        self.state_migrations.push(StateMigration {
            from: src,
            to: dst,
            vm: hot.vm,
            reserved,
            escrow,
            copied: BTreeMap::new(),
            precopy_ticks: 0,
            stalled: 0,
            drain_since,
        });
        self.stats.state_migrations_started += 1;
    }

    /// Advance every in-flight state migration by one fleet tick:
    /// stage a cold chunk, and once the un-copied remainder is small
    /// (or pre-copy stops converging), attempt the stop-and-copy flip —
    /// gated on *measured* target headroom, so Σ(resident + pool) ≤
    /// budget holds on the target through the hand-off by construction.
    fn advance_state_migrations(&mut self, now: Time) {
        let mut i = 0;
        while i < self.state_migrations.len() {
            match self.step_state_migration(i, now) {
                StateStep::InFlight => i += 1,
                StateStep::Done | StateStep::Aborted => {
                    self.state_migrations.remove(i);
                }
            }
        }
    }

    fn step_state_migration(&mut self, idx: usize, now: Time) -> StateStep {
        let (from, to, vm, reserved) = {
            let m = &self.state_migrations[idx];
            (m.from, m.to, m.vm, m.reserved)
        };
        // Snapshot the donor's stored units (ascending by unit id).
        // Nothing steps between here and the flip below, so the listing
        // stays exact for the whole tick.
        let listing = self.shards[from].machine.backend.list_units(vm);

        // Pre-copy one chunk: coldest first — NVMe receipts, then pool
        // entries — skipping units whose copied stamp still matches.
        let mut chunk = self.cfg.state_chunk_bytes;
        let mut staged: Vec<crate::types::UnitId> = Vec::new();
        let mut precopied = 0u64;
        {
            let m = &self.state_migrations[idx];
            let mut pending: Vec<_> = listing
                .iter()
                .filter(|s| m.copied.get(&s.unit) != Some(&s.stamp))
                .collect();
            // Coldest tier first: NVMe receipts, then remote-leased
            // entries (already evicted from the local pool, and a
            // remote copy always demotes to NVMe on import anyway),
            // then local pool entries. Without remote entries this is
            // exactly the old `tier == Pool` boolean key.
            pending.sort_by_key(|s| {
                let rank = match s.tier {
                    SwapTier::Nvme => 0u8,
                    SwapTier::Remote => 1,
                    SwapTier::Pool => 2,
                };
                (rank, s.unit)
            });
            for s in pending {
                if s.raw_bytes > chunk {
                    break;
                }
                chunk -= s.raw_bytes;
                precopied += s.raw_bytes;
                staged.push(s.unit);
            }
        }
        for &unit in &staged {
            let u = self.shards[from]
                .machine
                .backend
                .export_unit(vm, unit)
                .expect("listed unit exports");
            let stamp = u.stamp;
            self.shards[to].machine.backend.import_unit(reserved, u);
            self.state_migrations[idx].copied.insert(unit, stamp);
        }
        if precopied > 0 {
            self.stats.state_precopy_bytes += precopied;
            self.stats.record_transfer(from, to, precopied);
        }
        self.state_migrations[idx].precopy_ticks += 1;

        // Remaining un-copied swapped bytes after this tick's staging.
        let m = &self.state_migrations[idx];
        let remaining: u64 = listing
            .iter()
            .filter(|s| m.copied.get(&s.unit) != Some(&s.stamp))
            .map(|s| s.raw_bytes)
            .sum();
        let converged = remaining <= self.cfg.state_flip_threshold_bytes
            || m.precopy_ticks >= self.cfg.state_max_precopy_ticks;
        if !converged {
            return StateStep::InFlight;
        }

        // Flip gate: measured target headroom must cover the arriving
        // resident set plus the pool bytes still to import.
        let resident = self.shards[from].machine.vm_resident_bytes(vm);
        let pending_pool: u64 = listing
            .iter()
            .filter(|s| m.copied.get(&s.unit) != Some(&s.stamp))
            .map(|s| s.stored_bytes)
            .sum();
        let headroom = self
            .shard_budget(to)
            .saturating_sub(self.shards[to].machine.host_occupied_bytes());
        if headroom < resident + pending_pool + self.cfg.migration_margin_bytes {
            let m = &mut self.state_migrations[idx];
            m.stalled += 1;
            if m.stalled > self.cfg.migration_stall_ticks {
                return self.abort_state_migration(idx);
            }
            return StateStep::InFlight;
        }

        self.flip_state_migration(idx, listing, resident, now)
    }

    /// The stop-and-copy flip: final copy of every stale unit, atomic
    /// hand-off of the VM (slot + events + control registration), tier
    /// map re-sync, escrow return, ledger update.
    fn flip_state_migration(
        &mut self,
        idx: usize,
        listing: Vec<crate::storage::UnitSummary>,
        resident: u64,
        now: Time,
    ) -> StateStep {
        let (from, to, vm, reserved, escrow, drain_since) = {
            let m = &self.state_migrations[idx];
            (m.from, m.to, m.vm, m.reserved, m.escrow, m.drain_since)
        };
        // Final copy: units never staged or rewritten since staging.
        let mut flip_bytes = 0u64;
        let stale: Vec<_> = {
            let m = &self.state_migrations[idx];
            listing
                .iter()
                .filter(|s| m.copied.get(&s.unit) != Some(&s.stamp))
                .map(|s| (s.unit, s.raw_bytes))
                .collect()
        };
        for &(unit, raw) in &stale {
            let u = self.shards[from]
                .machine
                .backend
                .export_unit(vm, unit)
                .expect("listed unit exports");
            self.shards[to].machine.backend.import_unit(reserved, u);
            flip_bytes += raw;
        }
        // Drop target copies of units the donor no longer stores (the
        // guest faulted them back in and dirtied them after pre-copy).
        {
            let live: std::collections::BTreeSet<_> =
                listing.iter().map(|s| s.unit).collect();
            let dead: Vec<_> = self.state_migrations[idx]
                .copied
                .keys()
                .filter(|u| !live.contains(*u))
                .copied()
                .collect();
            for unit in dead {
                self.shards[to].machine.backend.discard(reserved, unit);
            }
        }
        flip_bytes += resident;

        // The brief pause the VM observes: fixed hand-off overhead plus
        // the stop-and-copy bytes over the modeled transfer bandwidth.
        let stop_ns = self.cfg.state_stop_fixed_ns
            + (flip_bytes as u128 * SEC as u128
                / self.cfg.state_stop_bytes_per_sec.max(1) as u128) as Time;

        let image = self.shards[from]
            .machine
            .extract_vm(vm)
            .expect("migrating VM occupies its donor slot");
        // Atomic-handoff audit: the donor must hold nothing of the VM.
        if !self.shards[from].machine.backend.list_units(vm).is_empty()
            || self.shards[from].machine.mm(vm).is_some()
        {
            self.stats.handoff_violations += 1;
        }
        let nominal = image.nominal_bytes();
        let sla = image.sla().unwrap_or(Sla::Silver);
        self.shards[to].machine.implant_vm(reserved, image, stop_ns);
        self.shards[to]
            .machine
            .control_mut()
            .expect("shard has a control plane")
            .cancel_lease(escrow);

        // Admission bookkeeping and the placement log follow the VM.
        let pressure = nominal * Sla::Gold.weight() / sla.weight();
        self.shards[from].committed_bytes -= nominal;
        self.shards[from].committed_pressure -= pressure;
        self.shards[to].committed_bytes += nominal;
        self.shards[to].committed_pressure += pressure;
        for p in self.placements.iter_mut() {
            if p.shard == from && p.vm == vm {
                p.shard = to;
                p.vm = reserved;
            }
        }
        // An image-backed clone re-attaches on the target: the donor's
        // `forget_vm` dropped its image reference and the implant's
        // tier re-sync saw only the exported private entries, so the
        // target needs its own image copy wired up (then a second
        // re-sync so still-shared units report the Pool tier again).
        let img = self
            .placements
            .iter()
            .find(|p| p.shard == to && p.vm == reserved)
            .and_then(|p| self.clone_images.get(&p.name).copied());
        if let Some(img) = img {
            let unit_bytes = self.shards[to]
                .machine
                .mm(reserved)
                .map_or(FRAME_BYTES, |m| m.core.unit_bytes);
            let m = &mut self.shards[to].machine;
            m.ensure_golden_image(
                img,
                self.cfg.clone.image_seed,
                self.cfg.clone.image_units,
                unit_bytes,
            );
            m.backend.attach_image(reserved, img);
            m.resync_vm_tiers(reserved);
        }
        // A drain evacuation's flip arms a recovery probe: stop-and-copy
        // carries the resident set, so restoration is measured from the
        // fault, not from the flip.
        if let Some(t0) = drain_since {
            if let Some(pidx) = self
                .placements
                .iter()
                .position(|p| p.shard == to && p.vm == reserved)
            {
                self.probes.push(RecoveryProbe {
                    placement: pidx,
                    target_bytes: resident / 2,
                    t0,
                });
            }
        }
        self.stats.record_transfer(from, to, flip_bytes);
        self.stats.record_state_flip(from, to, flip_bytes, resident, stop_ns);
        StateStep::Done
    }

    /// Abort a state migration that cannot land: the target forgets the
    /// staged copies and returns the escrow; the VM never stopped
    /// running on the donor, so nothing else changes.
    fn abort_state_migration(&mut self, idx: usize) -> StateStep {
        let m = &self.state_migrations[idx];
        let (to, reserved, escrow) = (m.to, m.reserved, m.escrow);
        self.shards[to].machine.backend.forget_vm(reserved);
        self.shards[to]
            .machine
            .control_mut()
            .expect("shard has a control plane")
            .cancel_lease(escrow);
        self.stats.state_migrations_aborted += 1;
        StateStep::Aborted
    }
}

/// Outcome of stepping one state migration at a fleet tick.
enum StateStep {
    InFlight,
    Done,
    Aborted,
}

/// Decision inputs for one shard at a fleet tick.
struct ShardSnap {
    usable: u64,
    demand: u64,
    cold: u64,
    /// The hottest migration-eligible VM (max fault-rate delta).
    hot: Option<HotVm>,
}

/// The fault-spiking VM one migration decision is about: enough of its
/// report to size either a lease (deficit) or a whole-VM move (demand +
/// expected resident arrival).
#[derive(Debug, Clone, Copy)]
struct HotVm {
    /// Machine slot id on the pressured shard.
    vm: usize,
    /// Demand shortfall vs its current limit (lease sizing).
    deficit: u64,
    demand: u64,
    usage: u64,
    limit: Option<u64>,
    inflight: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlacementPolicy;
    use crate::workloads::UniformRandom;

    fn spec(i: usize, sla: Sla, frames: u64, ops: u64) -> FleetVmSpec {
        FleetVmSpec {
            name: format!("vm{i}"),
            sla,
            frames,
            vcpus: 1,
            workloads: vec![Box::new(UniformRandom::new(0, frames / 2, ops))],
            initial_limit_bytes: None,
            mm: None,
        }
    }

    fn cfg(hosts: usize, placement: PlacementPolicy) -> FleetConfig {
        FleetConfig {
            hosts,
            host_budgets: vec![64 << 20],
            placement,
            ..Default::default()
        }
    }

    #[test]
    fn spread_placement_round_robins_equal_vms() {
        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(3, PlacementPolicy::SpreadByFaultRate),
        );
        for i in 0..6 {
            f.admit(spec(i, Sla::Silver, 4096, 10));
        }
        let shards: Vec<usize> = f.placements.iter().map(|p| p.shard).collect();
        assert_eq!(shards, vec![0, 1, 2, 0, 1, 2]);
        for s in &f.shards {
            assert_eq!(s.machine.control().unwrap().vms.len(), 2);
        }
    }

    #[test]
    fn first_fit_packs_in_order_and_overflows() {
        // Budget 64MB x 140% fit cap; Bronze 16MB VMs weigh 4x = 64MB
        // of pressure each: one per shard fits, the second overflows to
        // the next shard.
        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(2, PlacementPolicy::FirstFitBySla),
        );
        for i in 0..3 {
            f.admit(spec(i, Sla::Bronze, 4096, 10));
        }
        let shards: Vec<usize> = f.placements.iter().map(|p| p.shard).collect();
        assert_eq!(shards, vec![0, 1, 0], "fallback goes least-loaded");
    }

    #[test]
    fn admission_never_splits_and_bookkeeps() {
        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(4, PlacementPolicy::SpreadByFaultRate),
        );
        for i in 0..8 {
            f.admit(spec(i, [Sla::Gold, Sla::Bronze][i % 2], 4096, 10));
        }
        let total_vms: usize = f
            .shards
            .iter()
            .map(|s| s.machine.control().unwrap().vms.len())
            .sum();
        assert_eq!(total_vms, f.placements.len());
        for p in &f.placements {
            // The placement's shard really owns that VM under its name.
            let cp = f.shards[p.shard].machine.control().unwrap();
            assert_eq!(cp.vm_name(p.vm), Some(p.name.as_str()));
            // ... and no *other* shard knows the name.
            for s in &f.shards {
                if s.id != p.shard {
                    assert!(s
                        .machine
                        .control()
                        .unwrap()
                        .vms
                        .iter()
                        .all(|m| m.name != p.name));
                }
            }
        }
        let committed: u64 = f.shards.iter().map(|s| s.committed_bytes).sum();
        assert_eq!(committed, 8 * 4096 * FRAME_BYTES);
    }

    #[test]
    fn two_shard_fleet_runs_to_completion_conserving_budget() {
        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            FleetConfig {
                hosts: 2,
                host_budgets: vec![32 << 20],
                placement: PlacementPolicy::SpreadByFaultRate,
                interval: crate::types::MS * 5,
                ..Default::default()
            },
        );
        for i in 0..4 {
            f.admit(spec(i, Sla::Bronze, 2048, 4_000));
        }
        let results = f.run();
        assert_eq!(results.len(), 2);
        let ops: u64 = results
            .iter()
            .flatten()
            .map(|r| r.work_ops)
            .sum();
        assert_eq!(ops, 4 * 4_000, "fleet did not complete its work");
        assert!(f.stats.fleet_ticks > 0, "fleet ticks never fired");
        assert_eq!(f.stats.conservation_violations, 0);
        assert_eq!(
            f.shard_budget(0) + f.shard_budget(1),
            f.stats.total_budget_bytes
        );
    }

    /// The two engines step identical schedules: same event counts,
    /// same fleet-tick count, same budgets — on the small in-module
    /// fleet (the byte-level `ShardedSummary` equivalence sweep lives
    /// in `tests/fleet_scheduler.rs`).
    #[test]
    fn epoch_engine_matches_merge_on_small_fleet() {
        let build = |parallel: bool| {
            let mut f = FleetScheduler::new(
                &HostConfig::default(),
                FleetConfig {
                    hosts: 3,
                    host_budgets: vec![32 << 20],
                    placement: PlacementPolicy::SpreadByFaultRate,
                    interval: crate::types::MS * 5,
                    parallel,
                    ..Default::default()
                },
            );
            for i in 0..6 {
                f.admit(spec(i, Sla::Bronze, 2048, 3_000));
            }
            f
        };
        let mut seq = build(false);
        let rs = seq.run();
        let mut par = build(true);
        let rp = par.run();
        assert_eq!(seq.events_handled(), par.events_handled());
        assert_eq!(seq.stats.fleet_ticks, par.stats.fleet_ticks);
        for i in 0..3 {
            assert_eq!(seq.shard_budget(i), par.shard_budget(i));
            assert_eq!(
                seq.shards[i].machine.events_handled,
                par.shards[i].machine.events_handled,
                "shard {i} stepped a different schedule"
            );
        }
        assert_eq!(format!("{rs:?}"), format!("{rp:?}"), "results diverged");
    }

    /// The final barrier aborts in-flight state migrations in ascending
    /// index order; the pre-parallel engine aborted in descending
    /// order. Both must leave identical audited totals — each abort
    /// touches only its own migration's disjoint target shard — so the
    /// shared final barrier cannot have changed any outcome.
    #[test]
    fn abort_order_cannot_affect_audited_totals() {
        use crate::storage::TierHint;

        let build = || {
            let mut f = FleetScheduler::new(
                &HostConfig::default(),
                cfg(3, PlacementPolicy::SpreadByFaultRate),
            );
            // Two in-flight migrations with disjoint targets (exactly
            // what the rebalancer's busy() admission guarantees):
            // 0 → 1 and 0 → 2, each with a staged pre-copy and an
            // escrow the abort must return.
            for to in [1usize, 2] {
                let escrow = (4 + to as u64) << 20;
                f.shards[to]
                    .machine
                    .control_mut()
                    .unwrap()
                    .begin_lease(escrow);
                let reserved = f.shards[to].machine.reserve_slot();
                let m = &mut f.shards[to].machine;
                let mut rng = crate::sim::Rng::new(to as u64);
                m.backend.write(
                    reserved,
                    7,
                    &[1u8; 4096],
                    TierHint::Pool,
                    0,
                    &mut m.nvme,
                    &mut rng,
                );
                f.state_migrations.push(StateMigration {
                    from: 0,
                    to,
                    vm: 0,
                    reserved,
                    escrow,
                    copied: BTreeMap::new(),
                    precopy_ticks: 1,
                    stalled: 0,
                    drain_since: None,
                });
            }
            f
        };
        let audit = |f: &FleetScheduler| {
            let budgets: Vec<u64> = (0..3).map(|i| f.shard_budget(i)).collect();
            let arb: Vec<Option<u64>> = f
                .shards
                .iter()
                .map(|s| s.machine.control().unwrap().arbitration_budget())
                .collect();
            (budgets, arb, f.stats.state_migrations_aborted)
        };

        // Ascending (the shared final barrier) ...
        let mut asc = build();
        asc.final_barrier();
        // ... vs descending (the order run() used before the barrier
        // was shared).
        let mut desc = build();
        for idx in (0..desc.state_migrations.len()).rev() {
            desc.abort_state_migration(idx);
        }
        desc.state_migrations.clear();

        assert_eq!(audit(&asc), audit(&desc), "abort order changed the audit");
        assert_eq!(asc.stats.state_migrations_aborted, 2);
        for f in [&asc, &desc] {
            for to in [1usize, 2] {
                assert!(
                    f.shards[to].machine.backend.list_units(0).is_empty(),
                    "staged copies survived the abort on shard {to}"
                );
                // Escrow fully returned: arbitration budget == audited.
                let cp = f.shards[to].machine.control().unwrap();
                assert_eq!(
                    cp.arbitration_budget(),
                    cp.cfg.host_budget_bytes,
                    "escrow leaked on shard {to}"
                );
            }
        }
    }

    /// PR 7 regression: the donor of an in-flight state migration
    /// crashes mid-pre-copy. The migration must abort cleanly — the
    /// target's escrow lease returns in full and its staged copies are
    /// forgotten — and the VM is rebuilt elsewhere from its NVMe
    /// receipts, with the audited totals pinned: Σ budgets steps down
    /// by exactly the dead shard's budget.
    #[test]
    fn donor_crash_mid_precopy_returns_escrow_and_rebuilds_from_receipts() {
        use crate::storage::TierHint;
        use crate::types::MS;

        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(3, PlacementPolicy::SpreadByFaultRate),
        );
        f.admit(spec(0, Sla::Silver, 2048, 10));
        for s in &mut f.shards {
            s.machine.start();
        }
        let vm = f.placements[0].vm;
        assert_eq!(f.placements[0].shard, 0, "spread places the first VM on shard 0");
        // Durable state on the donor: one NVMe receipt (salvageable)
        // and one pool-resident unit (dies with the host's DRAM).
        {
            let m = &mut f.shards[0].machine;
            let mut rng = crate::sim::Rng::new(7);
            m.backend
                .write(vm, 3, &[9u8; 4096], TierHint::Nvme, 0, &mut m.nvme, &mut rng);
            m.backend
                .write(vm, 5, &[0u8; 4096], TierHint::Pool, 0, &mut m.nvme, &mut rng);
        }
        // An in-flight state migration 0 → 1, mid-pre-copy: escrow
        // taken on the target, one unit already staged there.
        let escrow = 8u64 << 20;
        f.shards[1].machine.control_mut().unwrap().begin_lease(escrow);
        let reserved = f.shards[1].machine.reserve_slot();
        let staged = f.shards[0].machine.backend.export_unit(vm, 3).unwrap();
        f.shards[1].machine.backend.import_unit(reserved, staged);
        f.state_migrations.push(StateMigration {
            from: 0,
            to: 1,
            vm,
            reserved,
            escrow,
            copied: BTreeMap::new(),
            precopy_ticks: 1,
            stalled: 0,
            drain_since: None,
        });

        let budget0 = f.shard_budget(0);
        let total_before = f.stats.total_budget_bytes;
        f.crash_host(0, MS);

        // The migration aborted cleanly.
        assert!(f.state_migrations.is_empty());
        assert_eq!(f.stats.state_migrations_aborted, 1);
        let cp = f.shards[1].machine.control().unwrap();
        assert_eq!(cp.arbitration_budget(), cp.cfg.host_budget_bytes, "escrow leaked");
        assert!(
            f.shards[1].machine.backend.list_units(reserved).is_empty(),
            "staged copies survived the abort"
        );

        // The VM rebuilt on a live shard from exactly its NVMe receipt;
        // the pool unit is accounted as genuinely lost.
        let (ps, pv) = (f.placements[0].shard, f.placements[0].vm);
        assert_ne!(ps, 0);
        assert!(f.stats.alive[ps]);
        assert!(!f.stats.alive[0]);
        let units = f.shards[ps].machine.backend.list_units(pv);
        assert_eq!(units.len(), 1, "exactly the NVMe receipt was salvaged");
        assert_eq!(units[0].unit, 3);
        assert_eq!(units[0].tier, SwapTier::Nvme);
        assert_eq!(f.stats.vms_rebuilt, 1);
        assert_eq!(f.stats.rebuild_salvaged_units, 1);
        assert_eq!(f.stats.rebuild_salvaged_bytes, 4096);
        assert_eq!(f.stats.rebuild_lost_units, 1);
        assert_eq!(f.stats.rebuild_lost_bytes, 4096);
        assert!(
            f.shards[0].machine.backend.list_units(vm).is_empty(),
            "the dead shard still lists the VM's units"
        );

        // Audited totals pinned: Σ stepped down by the dead budget.
        assert_eq!(f.stats.budget_retired_bytes, budget0);
        assert_eq!(f.stats.total_budget_bytes, total_before - budget0);
        assert_eq!(f.shard_budget(0), 0);
        let sum: u64 = (0..3).map(|i| f.shard_budget(i)).sum();
        f.stats.audit_budgets(sum);
        assert_eq!(f.stats.conservation_violations, 0);
    }

    /// PR 9 satellite: admission is migration-aware. A shard targeted
    /// by an in-flight state migration has its headroom spoken for by
    /// the escrow; admitting a new tenant there squeezes the arrival
    /// below the flip gate and stalls the migration into an avoidable
    /// abort. Both policies must count in-flight escrow against
    /// capacity and prefer migration-free shards. (With no migration
    /// in flight the behavior is unchanged — pinned by the placement
    /// tests above.)
    #[test]
    fn admission_avoids_shard_with_inflight_migration_escrow() {
        for placement in
            [PlacementPolicy::SpreadByFaultRate, PlacementPolicy::FirstFitBySla]
        {
            let mut f = FleetScheduler::new(&HostConfig::default(), cfg(3, placement));
            // In-flight migration 2 → 0 whose escrow holds most of
            // shard 0's 64MB budget.
            let escrow = 60u64 << 20;
            f.shards[0].machine.control_mut().unwrap().begin_lease(escrow);
            let reserved = f.shards[0].machine.reserve_slot();
            f.state_migrations.push(StateMigration {
                from: 2,
                to: 0,
                vm: 0,
                reserved,
                escrow,
                copied: BTreeMap::new(),
                precopy_ticks: 0,
                stalled: 0,
                drain_since: None,
            });
            let (shard, _) = f.admit(spec(0, Sla::Silver, 4096, 10));
            assert_eq!(shard, 1, "{placement:?} admitted onto a migration party");
            // The escrowed headroom the flip gate will measure stays
            // intact: nothing was committed onto the target.
            assert_eq!(f.shards[0].committed_bytes, 0);
        }
    }

    /// PR 9: donor crash mid-remote-lease. The surviving consumer's
    /// remote entries lived in the dead host's DRAM — they are dropped
    /// and re-fault as measured cold misses; no escrow returns (the
    /// dead shard's whole budget retires) and the audit stays clean.
    #[test]
    fn remote_donor_crash_drops_entries_and_audits_clean() {
        use crate::storage::TierHint;
        use crate::types::MS;

        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(3, PlacementPolicy::SpreadByFaultRate),
        );
        f.admit(spec(0, Sla::Silver, 2048, 10)); // shard 0 = consumer
        let vm = f.placements[0].vm;
        {
            let m = &mut f.shards[0].machine;
            let mut rng = crate::sim::Rng::new(11);
            m.backend
                .write(vm, 4, &[3u8; 4096], TierHint::Pool, 0, &mut m.nvme, &mut rng);
            let staged = m.backend.remote_stage(1 << 30);
            assert!(staged > 0, "nothing staged to the remote tier");
        }
        let granted = 4u64 << 20;
        f.shards[1].machine.control_mut().unwrap().begin_lease(granted);
        f.remote_leases.push(RemoteLease {
            donor: 1,
            consumer: 0,
            granted,
            reserved: granted,
            revoking: false,
        });

        let budget1 = f.shard_budget(1);
        let total_before = f.stats.total_budget_bytes;
        f.crash_host(1, MS);

        assert!(f.remote_leases.is_empty(), "lease survived its donor");
        assert_eq!(f.shards[0].machine.backend.remote_bytes(), 0);
        assert_eq!(f.stats.remote_dropped_units, 1);
        assert!(f.stats.remote_dropped_bytes > 0);
        // The dropped unit re-faults as a never-written cold miss.
        {
            let m = &mut f.shards[0].machine;
            let mut rng = crate::sim::Rng::new(12);
            let mut out = Vec::new();
            let r = m.backend.read(vm, 4, 4096, &mut out, 2 * MS, &mut m.nvme, &mut rng);
            assert_eq!(r.tier, SwapTier::Nvme);
            assert_eq!(out, vec![0u8; 4096], "dropped remote entry kept content");
        }
        // Σ budgets stepped down by exactly the dead donor's budget.
        assert_eq!(f.stats.budget_retired_bytes, budget1);
        assert_eq!(f.stats.total_budget_bytes, total_before - budget1);
        let sum: u64 = (0..3).map(|i| f.shard_budget(i)).sum();
        f.stats.audit_budgets(sum);
        assert_eq!(f.stats.conservation_violations, 0);
    }

    /// PR 9: consumer crash mid-remote-lease. The surviving donor takes
    /// its full escrow back into arbitration — nothing leaks, audited
    /// budgets never moved.
    #[test]
    fn remote_consumer_crash_returns_full_escrow_to_donor() {
        use crate::types::MS;

        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(3, PlacementPolicy::SpreadByFaultRate),
        );
        let granted = 4u64 << 20;
        f.shards[1].machine.control_mut().unwrap().begin_lease(granted);
        f.remote_leases.push(RemoteLease {
            donor: 1,
            consumer: 0,
            granted,
            reserved: granted,
            revoking: false,
        });
        f.crash_host(0, MS);
        assert!(f.remote_leases.is_empty(), "lease survived its consumer");
        let cp = f.shards[1].machine.control().unwrap();
        assert_eq!(cp.arbitration_budget(), cp.cfg.host_budget_bytes, "escrow leaked");
        let sum: u64 = (0..3).map(|i| f.shard_budget(i)).sum();
        f.stats.audit_budgets(sum);
        assert_eq!(f.stats.conservation_violations, 0);
    }

    /// PR 9: revocation is paced by `recall_chunk_bytes` and returns
    /// escrow exactly as remote bytes land on the consumer's NVMe;
    /// when the remote tier is empty the lease dissolves with its full
    /// remainder back in the donor's arbitration budget.
    #[test]
    fn remote_revocation_paces_recalls_and_returns_escrow() {
        use crate::storage::TierHint;
        use crate::types::MS;

        let mut f = FleetScheduler::new(
            &HostConfig::default(),
            cfg(3, PlacementPolicy::SpreadByFaultRate),
        );
        f.admit(spec(0, Sla::Silver, 2048, 10)); // shard 0 = consumer
        let vm = f.placements[0].vm;
        let staged = {
            let m = &mut f.shards[0].machine;
            let mut rng = crate::sim::Rng::new(21);
            for u in 0..3u64 {
                m.backend.write(
                    vm,
                    u,
                    &[5u8; 4096],
                    TierHint::Pool,
                    u,
                    &mut m.nvme,
                    &mut rng,
                );
            }
            m.backend.remote_stage(1 << 30)
        };
        assert!(staged > 0);
        let granted = 4u64 << 20;
        f.shards[1].machine.control_mut().unwrap().begin_lease(granted);
        f.remote_leases.push(RemoteLease {
            donor: 1,
            consumer: 0,
            granted,
            reserved: granted,
            revoking: true,
        });
        // Tiny recall chunks: one entry per tick, so pacing is visible.
        f.cfg.remote.recall_chunk_bytes = 1;
        let mut ticks = 0u64;
        while !f.remote_leases.is_empty() {
            f.advance_remote((ticks + 1) * MS);
            ticks += 1;
            assert!(ticks <= 4, "revocation failed to converge");
        }
        // One entry per tick; the lease dissolves in the same tick the
        // last entry lands (remote tier empty → remainder cancelled).
        assert_eq!(ticks, 3, "recalls were not paced one entry per tick");
        assert_eq!(f.shards[0].machine.backend.remote_bytes(), 0);
        assert_eq!(f.shards[0].machine.backend_metrics().remote_recalls, 3);
        let cp = f.shards[1].machine.control().unwrap();
        assert_eq!(cp.arbitration_budget(), cp.cfg.host_budget_bytes, "escrow leaked");
        assert_eq!(f.stats.remote_recalled_bytes, staged);
    }
}
