//! The in-simulation control plane: the daemon's feedback loop as a
//! scheduled `ControlTick` actor inside the event-driven machine.
//!
//! Each tick the [`crate::coordinator::Machine`] rebuilds the per-VM
//! [`VmReport`]s into this plane's reused buffer, snapshots host-wide
//! accounting (Σ resident + compressed-pool bytes vs the configured
//! budget), and asks the plane for limit actions:
//!
//! 1. **Scheduled one-shots** — `schedule()`d limit changes due at or
//!    before this tick (the migration target for the old external
//!    `Machine::plan_limit_change` path). A change flagged *staged*
//!    becomes a staged release that doubles the limit per periodic
//!    tick instead of jumping, and *boost*-flagged raises arm the
//!    [`crate::mm::PolicyApi::recovery_mode`] prefetcher hint.
//! 2. **Arbitration** — the pluggable [`Arbiter`]
//!    (static / proportional-share / watermark) closes the loop from
//!    the reports.
//!
//! Host gauges ([`ControlStats`]) are recorded before actions apply, so
//! `budget_exceeded_ticks` audits the state the previous decisions
//! actually produced.

use crate::config::ControlConfig;
use crate::metrics::ControlStats;
use crate::types::Time;

use super::arbiter::{Arbiter, HostView, LimitAction, VmReport};
use super::Sla;

/// Per-VM control metadata held by the plane (names owned once here;
/// reports borrow them by slot id — nothing per tick).
#[derive(Debug)]
pub struct ManagedVm {
    pub vm: usize,
    pub name: String,
    pub sla: Sla,
    /// Fault count at the previous tick (for pf_delta).
    last_pf: u64,
}

/// A one-shot limit change scheduled at a virtual time.
#[derive(Debug, Clone, Copy)]
struct ScheduledLimit {
    vm: usize,
    at: Time,
    bytes: Option<u64>,
    boost: bool,
    staged: bool,
    fired: bool,
}

/// An in-progress staged hard-limit release.
#[derive(Debug, Clone, Copy)]
struct StagedRelease {
    vm: usize,
    target: Option<u64>,
    steps_left: u32,
    boost: bool,
}

/// The control plane: fleet bookkeeping + arbitration + gauges.
#[derive(Debug)]
pub struct ControlPlane {
    pub cfg: ControlConfig,
    pub vms: Vec<ManagedVm>,
    sched: Vec<ScheduledLimit>,
    staging: Vec<StagedRelease>,
    pub arbiter: Arbiter,
    /// Reused per-tick report buffer (one entry per managed VM, in
    /// registration order).
    pub reports: Vec<VmReport>,
    /// Reused action buffer.
    pub actions: Vec<LimitAction>,
    pub stats: ControlStats,
    /// Bytes this host is migrating away (a fleet-scheduler cold-memory
    /// lease in flight): subtracted from the budget the *arbiter*
    /// divides — squeezing the fleet makes the leased memory free —
    /// while the *audited* budget (`cfg.host_budget_bytes`, the
    /// invariant the stats check) follows only as chunks are actually
    /// handed over via [`ControlPlane::complete_lease`].
    lease_reserved: u64,
}

impl ControlPlane {
    pub fn new(cfg: ControlConfig) -> Self {
        ControlPlane {
            arbiter: Arbiter::new(cfg.kind),
            stats: ControlStats::new(cfg.host_budget_bytes.unwrap_or(0)),
            cfg,
            vms: vec![],
            sched: vec![],
            staging: vec![],
            reports: vec![],
            actions: vec![],
            lease_reserved: 0,
        }
    }

    /// The budget the arbiter divides this tick: the audited budget
    /// minus any in-flight outbound migration lease.
    pub fn arbitration_budget(&self) -> Option<u64> {
        self.cfg
            .host_budget_bytes
            .map(|b| b.saturating_sub(self.lease_reserved))
    }

    /// Start leasing `bytes` away: the arbiter immediately plans around
    /// the smaller budget (tightenings apply next tick and the fleet
    /// sheds), but the audited budget is untouched until the memory is
    /// actually free and handed over.
    pub fn begin_lease(&mut self, bytes: u64) {
        self.lease_reserved += bytes;
    }

    /// Return an undelivered lease remainder (migration aborted).
    pub fn cancel_lease(&mut self, bytes: u64) {
        self.lease_reserved = self.lease_reserved.saturating_sub(bytes);
    }

    /// Hand over `bytes` of a lease: the audited budget drops by
    /// exactly the amount the reservation already excluded from
    /// arbitration, so the bound the arbiter enforces
    /// (Σ limits ≤ usable) is unchanged and the budget invariant holds
    /// through the transfer.
    pub fn complete_lease(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.lease_reserved, "lease underflow");
        self.lease_reserved = self.lease_reserved.saturating_sub(bytes);
        if let Some(b) = self.cfg.host_budget_bytes.as_mut() {
            *b = b.saturating_sub(bytes);
            self.stats.budget_bytes = *b;
        }
    }

    /// Receive `bytes` of budget migrated from another shard.
    pub fn grow_budget(&mut self, bytes: u64) {
        if let Some(b) = self.cfg.host_budget_bytes.as_mut() {
            *b += bytes;
            self.stats.budget_bytes = *b;
        }
    }

    /// The host died: zero the audited budget in one step and return
    /// what it held, so the fleet's Σ-budget baseline can step down by
    /// exactly that amount at the crash tick. Unlike a lease hand-over,
    /// nothing is transferred — the budget is gone with the host.
    pub fn retire_host_budget(&mut self) -> u64 {
        let old = self.cfg.host_budget_bytes.unwrap_or(0);
        self.cfg.host_budget_bytes = Some(0);
        self.stats.budget_bytes = 0;
        self.lease_reserved = 0;
        old
    }

    /// Register a VM with the plane (called at daemon registration).
    pub fn register(&mut self, vm: usize, name: String, sla: Sla) {
        self.vms.push(ManagedVm { vm, name, sla, last_pf: 0 });
    }

    /// Adopt a VM migrated in from another shard: like
    /// [`ControlPlane::register`], but the fault-delta baseline carries
    /// over so the first post-flip tick does not see the VM's whole
    /// fault history as one spike (which would immediately re-trigger
    /// the rebalancer against the fresh arrival).
    pub fn adopt(&mut self, vm: usize, name: String, sla: Sla, last_pf: u64) {
        self.vms.push(ManagedVm { vm, name, sla, last_pf });
    }

    /// Forget a VM migrated away (the donor side of the flip): drops
    /// its management record plus any scheduled one-shots and in-flight
    /// staged releases — the target shard's arbiter owns the VM's limit
    /// from here on. Returns `(name, sla, pf_baseline)` for the adopt.
    pub fn deregister(&mut self, vm: usize) -> Option<(String, Sla, u64)> {
        let idx = self.vms.iter().position(|m| m.vm == vm)?;
        let m = self.vms.remove(idx);
        self.sched.retain(|s| s.vm != vm);
        self.staging.retain(|s| s.vm != vm);
        Some((m.name, m.sla, m.last_pf))
    }

    pub fn vm_name(&self, vm: usize) -> Option<&str> {
        self.vms.iter().find(|m| m.vm == vm).map(|m| m.name.as_str())
    }

    /// Schedule a one-shot limit change at virtual time `at`.
    pub fn schedule(&mut self, vm: usize, at: Time, bytes: Option<u64>, boost: bool, staged: bool) {
        self.sched.push(ScheduledLimit { vm, at, bytes, boost, staged, fired: false });
    }

    /// Times the machine must fire extra (non-periodic) control ticks
    /// at, so scheduled changes land exactly on time.
    pub fn scheduled_times(&self) -> impl Iterator<Item = Time> + '_ {
        self.sched.iter().filter(|s| !s.fired).map(|s| s.at)
    }

    /// Whether the plane needs the periodic tick chain at all: pure
    /// one-shot plans (the legacy `plan_limit_change` migration) run
    /// without it, keeping those event sequences byte-identical.
    pub fn needs_periodic(&self) -> bool {
        self.cfg.host_budget_bytes.is_some()
            || self.arbiter.kind != crate::config::ArbiterKind::Static
            || self.sched.iter().any(|s| s.staged)
    }

    /// Start a report rebuild; the machine pushes one raw report per
    /// managed VM in registration order via [`ControlPlane::push_report`].
    pub fn begin_reports(&mut self) {
        self.reports.clear();
    }

    /// Finalize one VM's report: pf_delta is derived here from the
    /// previous *tick*'s count. `advance_baseline` is true only on real
    /// control ticks — an external `Daemon::report()` refresh must not
    /// move the baseline, or the next tick's delta would under-report.
    pub fn push_report(&mut self, mut r: VmReport, idx: usize, advance_baseline: bool) {
        let mv = &mut self.vms[idx];
        debug_assert_eq!(mv.vm, r.vm);
        r.pf_delta = r.pf_count - mv.last_pf;
        if advance_baseline {
            mv.last_pf = r.pf_count;
        }
        self.reports.push(r);
    }

    /// One control tick: record gauges, expand due one-shots and staged
    /// releases, then arbitrate. Actions are appended to `out`.
    pub fn collect_actions(
        &mut self,
        now: Time,
        periodic: bool,
        host: HostView,
        pool_by_class: [u64; 3],
        out: &mut Vec<LimitAction>,
    ) {
        let out_before = out.len();
        // Gauges on periodic ticks only (they are unique per interval;
        // one-shot ticks would double-sample the host series): audit
        // the state the *previous* actions produced.
        if periodic {
            self.stats.observe(now, host.resident_bytes, host.pool_bytes);
            self.stats.pool_by_class = pool_by_class;
            self.stats.resident_by_class = [0; 3];
            for r in &self.reports {
                self.stats.resident_by_class[r.sla.class_index()] += r.usage_bytes;
            }
        }

        // Due one-shots (exact-time ticks are scheduled for these).
        for s in self.sched.iter_mut() {
            if s.fired || s.at > now {
                continue;
            }
            s.fired = true;
            if s.staged {
                self.stats.staged_releases += 1;
                self.staging.push(StagedRelease {
                    vm: s.vm,
                    target: s.bytes,
                    steps_left: self.cfg.release_stages.max(1),
                    boost: s.boost,
                });
            } else {
                out.push(LimitAction { vm: s.vm, bytes: s.bytes, boost: s.boost });
            }
        }

        // Staged releases advance on periodic ticks: double the limit
        // each step, landing on the target in the final one.
        if periodic && !self.staging.is_empty() {
            let reports = &self.reports;
            self.staging.retain_mut(|st| {
                let cur = reports
                    .iter()
                    .find(|r| r.vm == st.vm)
                    .and_then(|r| r.limit_bytes);
                let Some(cur) = cur else {
                    return false; // already unlimited: nothing to stage
                };
                st.steps_left -= 1;
                let next = match st.target {
                    Some(t) => {
                        if st.steps_left == 0 {
                            Some(t)
                        } else {
                            Some(t.min(cur.saturating_mul(2)))
                        }
                    }
                    None => {
                        if st.steps_left == 0 {
                            None
                        } else {
                            Some(cur.saturating_mul(2))
                        }
                    }
                };
                out.push(LimitAction { vm: st.vm, bytes: next, boost: st.boost });
                st.steps_left > 0 && next != st.target
            });
        }

        // Closed-loop arbitration: periodic ticks only, and only with a
        // configured budget — `host_budget_bytes: None` is documented
        // as accounting-only, and arbitrating against a zero budget
        // would squeeze every VM to its floor.
        if periodic && self.cfg.host_budget_bytes.is_some() {
            self.arbiter.arbitrate(&self.reports, &host, &self.cfg, out);
        }
        self.stats.limit_changes += (out.len() - out_before) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArbiterKind;

    fn plane(kind: ArbiterKind, budget: Option<u64>) -> ControlPlane {
        let cfg = ControlConfig { kind, host_budget_bytes: budget, ..Default::default() };
        let mut cp = ControlPlane::new(cfg);
        cp.register(0, "vm0".into(), Sla::Gold);
        cp
    }

    fn report(vm: usize, limit: Option<u64>) -> VmReport {
        VmReport {
            vm,
            sla: Sla::Gold,
            usage_bytes: 64 << 20,
            wss_bytes: 32 << 20,
            cold_estimate_bytes: 32 << 20,
            pf_count: 10,
            pf_delta: 0,
            limit_bytes: limit,
            unit_bytes: 4096,
            inflight_allowance: 16384,
        }
    }

    fn host() -> HostView {
        HostView {
            budget_bytes: 1 << 30,
            resident_bytes: 64 << 20,
            pool_bytes: 0,
            pool_reserved_bytes: 0,
        }
    }

    #[test]
    fn one_shot_fires_once_at_its_time() {
        let mut cp = plane(ArbiterKind::Static, None);
        cp.schedule(0, 100, None, false, false);
        assert!(!cp.needs_periodic());
        let mut out = vec![];
        cp.begin_reports();
        cp.push_report(report(0, Some(1 << 20)), 0, true);
        cp.collect_actions(50, false, host(), [0; 3], &mut out);
        assert!(out.is_empty(), "fired early");
        cp.collect_actions(100, false, host(), [0; 3], &mut out);
        assert_eq!(out, vec![LimitAction { vm: 0, bytes: None, boost: false }]);
        out.clear();
        cp.collect_actions(200, false, host(), [0; 3], &mut out);
        assert!(out.is_empty(), "fired twice");
    }

    #[test]
    fn staged_release_doubles_then_lands_on_target() {
        let mut cp = plane(ArbiterKind::Static, None);
        cp.cfg.release_stages = 3;
        cp.schedule(0, 100, Some(100 << 20), true, true);
        assert!(cp.needs_periodic());
        let mut out = vec![];
        let mut limit = Some(10u64 << 20);
        for step in 0..4 {
            cp.begin_reports();
            cp.push_report(report(0, limit), 0, true);
            cp.collect_actions(100 + step * 10, true, host(), [0; 3], &mut out);
            if let Some(a) = out.last() {
                limit = a.bytes;
                assert!(a.boost);
            }
        }
        // 10 -> 20 -> 40 -> 100 (final step lands on target).
        assert_eq!(limit, Some(100 << 20));
        out.clear();
        cp.begin_reports();
        cp.push_report(report(0, limit), 0, true);
        cp.collect_actions(200, true, host(), [0; 3], &mut out);
        assert!(out.is_empty(), "staging did not terminate");
        assert_eq!(cp.stats.staged_releases, 1);
    }

    #[test]
    fn lease_squeezes_arbitration_before_the_audited_budget_moves() {
        let mut cp = plane(ArbiterKind::ProportionalShare, Some(1 << 30));
        assert_eq!(cp.arbitration_budget(), Some(1 << 30));
        // Begin: arbiter plans around the smaller budget, audit as-is.
        cp.begin_lease(256 << 20);
        assert_eq!(cp.arbitration_budget(), Some((1 << 30) - (256 << 20)));
        assert_eq!(cp.cfg.host_budget_bytes, Some(1 << 30));
        // Complete half: audited budget follows, arbitration unchanged
        // (reservation and budget drop by the same amount).
        cp.complete_lease(128 << 20);
        assert_eq!(cp.cfg.host_budget_bytes, Some((1 << 30) - (128 << 20)));
        assert_eq!(cp.stats.budget_bytes, (1 << 30) - (128 << 20));
        assert_eq!(cp.arbitration_budget(), Some((1 << 30) - (256 << 20)));
        // Abort the rest: arbitration returns to the audited budget.
        cp.cancel_lease(128 << 20);
        assert_eq!(cp.arbitration_budget(), cp.cfg.host_budget_bytes);
        // Inbound migration grows both views together.
        cp.grow_budget(128 << 20);
        assert_eq!(cp.cfg.host_budget_bytes, Some(1 << 30));
        assert_eq!(cp.arbitration_budget(), Some(1 << 30));
    }

    #[test]
    fn retire_host_budget_zeroes_audit_and_any_lease() {
        let mut cp = plane(ArbiterKind::ProportionalShare, Some(1 << 30));
        cp.begin_lease(256 << 20);
        let old = cp.retire_host_budget();
        assert_eq!(old, 1 << 30, "retire returns the full audited budget");
        assert_eq!(cp.cfg.host_budget_bytes, Some(0));
        assert_eq!(cp.stats.budget_bytes, 0);
        // The in-flight lease died with the host: arbitration sees zero,
        // not a negative-saturated remainder.
        assert_eq!(cp.arbitration_budget(), Some(0));
        assert_eq!(cp.retire_host_budget(), 0, "double retire yields nothing");
    }

    #[test]
    fn deregister_purges_schedule_and_adopt_carries_pf_baseline() {
        let mut cp = plane(ArbiterKind::Static, None);
        cp.schedule(0, 100, Some(1 << 20), false, false);
        cp.schedule(0, 200, Some(2 << 20), true, true);
        // Advance the baseline so there is something to carry.
        cp.begin_reports();
        cp.push_report(report(0, Some(1 << 20)), 0, true);
        let (name, sla, last_pf) = cp.deregister(0).expect("vm 0 managed");
        assert_eq!(name, "vm0");
        assert_eq!(sla, Sla::Gold);
        assert_eq!(last_pf, 10);
        assert!(cp.vms.is_empty());
        assert_eq!(cp.scheduled_times().count(), 0, "one-shots survived");
        assert!(cp.deregister(0).is_none(), "double deregister");

        // Adoption on another plane: the first tick's delta counts only
        // faults since the donor's last tick, not the whole history.
        let mut target = plane(ArbiterKind::Static, None);
        target.vms.clear();
        target.adopt(7, name, sla, last_pf);
        target.begin_reports();
        let mut r = report(7, None);
        r.pf_count = 25;
        target.push_report(r, 0, true);
        assert_eq!(target.reports[0].pf_delta, 15);
    }

    #[test]
    fn pf_delta_derived_from_previous_tick() {
        let mut cp = plane(ArbiterKind::Static, None);
        cp.begin_reports();
        cp.push_report(report(0, None), 0, true);
        assert_eq!(cp.reports[0].pf_delta, 10);
        cp.begin_reports();
        let mut r = report(0, None);
        r.pf_count = 25;
        cp.push_report(r, 0, true);
        assert_eq!(cp.reports[0].pf_delta, 15);
    }
}
