//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust coordinator.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax
//! >= 0.5 emits serialized protos with 64-bit instruction ids that the
//! pinned xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs at simulation time — the artifacts are compiled
//! once by `make artifacts`, and this module is the only consumer.
//!
//! The PJRT path needs the `xla` and `anyhow` crates, which are not in
//! the offline crate set, so it is gated behind the `xla` cargo
//! feature. The default build ships a stub whose `from_artifacts`
//! always fails with [`XlaUnavailable`]; every caller already falls
//! back to [`crate::policies::NativeAnalytics`] on error, so the
//! system degrades to the native backend transparently.

/// Minimal extraction of the integer fields we need from manifest.json
/// (no JSON dependency in the offline build).
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn manifest_field(text: &str, section: &str, key: &str) -> Option<usize> {
    let sec = text.find(&format!("\"{section}\""))?;
    let rest = &text[sec..];
    let k = rest.find(&format!("\"{key}\""))?;
    let after = &rest[k + key.len() + 2..];
    let colon = after.find(':')?;
    let digits: String = after[colon + 1..]
        .chars()
        .skip_while(|c| c.is_whitespace())
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

/// Error returned by the stub: the crate was built without the `xla`
/// feature, so PJRT execution is unavailable.
#[cfg(not(feature = "xla"))]
#[derive(Debug, Clone, Copy)]
pub struct XlaUnavailable;

#[cfg(not(feature = "xla"))]
impl std::fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "built without the `xla` feature; PJRT artifacts cannot be \
             executed (the native analytics backend is the fallback)"
        )
    }
}

#[cfg(not(feature = "xla"))]
impl std::error::Error for XlaUnavailable {}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use super::XlaUnavailable;
    use crate::policies::analytics::{ColdAnalytics, DtOutput, ErtScorer};
    use crate::types::Bitmap;

    /// Offline stand-in for the PJRT executor. Unconstructible:
    /// `from_artifacts` always errs, so the trait impls are never
    /// reached at runtime — they exist only to keep call sites
    /// (`Box<dyn ColdAnalytics>` from either backend) type-checking.
    pub struct XlaAnalytics {
        pub history: usize,
        pub pages: usize,
        pub ert_entries: usize,
        pub dt_calls: u64,
        pub ert_calls: u64,
    }

    impl XlaAnalytics {
        pub fn from_artifacts<P: AsRef<Path>>(dir: P) -> Result<Self, XlaUnavailable> {
            let _ = dir;
            Err(XlaUnavailable)
        }

        pub fn platform(&self) -> String {
            unreachable!("XlaAnalytics stub cannot be constructed")
        }
    }

    impl ColdAnalytics for XlaAnalytics {
        fn dt_reclaim(
            &mut self,
            _hist: &[&Bitmap],
            _target_rate: f32,
            _prev_threshold: f32,
        ) -> DtOutput {
            unreachable!("XlaAnalytics stub cannot be constructed")
        }

        fn backend_name(&self) -> &'static str {
            "xla-unavailable"
        }
    }

    impl ErtScorer for XlaAnalytics {
        fn victim(&mut self, _ert: &mut [f32], _valid: &[f32], _dt: f32) -> (usize, f32) {
            unreachable!("XlaAnalytics stub cannot be constructed")
        }

        fn backend_name(&self) -> &'static str {
            "xla-unavailable"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaAnalytics;

#[cfg(feature = "xla")]
mod pjrt {
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    use super::manifest_field;
    use crate::policies::analytics::{ColdAnalytics, DtOutput, ErtScorer};
    use crate::types::Bitmap;

    /// Executes the `dt_reclaim` and `ert_victim` artifacts on the PJRT CPU
    /// client, tiling inputs to the artifact's static shapes.
    pub struct XlaAnalytics {
        client: xla::PjRtClient,
        dt_exe: xla::PjRtLoadedExecutable,
        ert_exe: xla::PjRtLoadedExecutable,
        /// Artifact shapes from manifest.json.
        pub history: usize,
        pub pages: usize,
        pub ert_entries: usize,
        pub dt_calls: u64,
        pub ert_calls: u64,
    }

    impl XlaAnalytics {
        /// Load artifacts from `dir` (expects dt_reclaim.hlo.txt,
        /// ert_victim.hlo.txt, manifest.json).
        pub fn from_artifacts<P: AsRef<Path>>(dir: P) -> Result<Self> {
            let dir = dir.as_ref();
            let manifest = std::fs::read_to_string(dir.join("manifest.json"))
                .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
            let history = manifest_field(&manifest, "dt_reclaim", "history")
                .context("manifest: dt_reclaim.history")?;
            let pages = manifest_field(&manifest, "dt_reclaim", "pages")
                .context("manifest: dt_reclaim.pages")?;
            let ert_entries = manifest_field(&manifest, "ert_victim", "entries")
                .context("manifest: ert_victim.entries")?;

            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("path utf8")?,
                )
                .with_context(|| format!("parsing {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client.compile(&comp).with_context(|| format!("compiling {name}"))
            };
            Ok(XlaAnalytics {
                dt_exe: load("dt_reclaim.hlo.txt")?,
                ert_exe: load("ert_victim.hlo.txt")?,
                client,
                history,
                pages,
                ert_entries,
                dt_calls: 0,
                ert_calls: 0,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute the dt_reclaim artifact on one [H, pages] tile.
        fn dt_tile(
            &mut self,
            hist_rows: &[Vec<f32>],
            target_rate: f32,
            prev_threshold: f32,
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
            let h = self.history;
            let n = self.pages;
            let mut flat = Vec::with_capacity(h * n);
            for row in hist_rows {
                debug_assert_eq!(row.len(), n);
                flat.extend_from_slice(row);
            }
            let hist = xla::Literal::vec1(&flat).reshape(&[h as i64, n as i64])?;
            let tr = xla::Literal::scalar(target_rate);
            let pt = xla::Literal::scalar(prev_threshold);
            let result = self.dt_exe.execute::<xla::Literal>(&[hist, tr, pt])?[0][0]
                .to_literal_sync()?;
            // Lowered with return_tuple=True: 5-tuple.
            let elems = result.to_tuple()?;
            if elems.len() != 5 {
                bail!("dt_reclaim returned {} outputs, expected 5", elems.len());
            }
            let age = elems[0].to_vec::<f32>()?;
            let cnt = elems[1].to_vec::<f32>()?;
            let histo = elems[2].to_vec::<f32>()?;
            let proposed = elems[3].to_vec::<f32>()?[0];
            let smoothed = elems[4].to_vec::<f32>()?[0];
            self.dt_calls += 1;
            Ok((age, cnt, histo, proposed, smoothed))
        }

        /// Recompute threshold natively from a merged histogram (used when a
        /// VM spans multiple tiles; same formula as the artifact).
        fn threshold_from_histogram(histogram: &[f32], target_rate: f32) -> f32 {
            let h = histogram.len() - 1;
            let mut measured = histogram.to_vec();
            measured[h] = 0.0; // unknown-distance bucket excluded
            measured[0] = 0.0;
            let total: f32 = measured.iter().sum();
            if total <= 0.0 {
                return h as f32;
            }
            let mut tail = vec![0f32; h + 2];
            for t in (0..=h).rev() {
                tail[t] = tail[t + 1] + measured[t];
            }
            (1..=h)
                .find(|&t| tail[t] / total <= target_rate)
                .unwrap_or(h) as f32
        }
    }

    impl ColdAnalytics for XlaAnalytics {
        fn dt_reclaim(
            &mut self,
            hist: &[&Bitmap],
            target_rate: f32,
            prev_threshold: f32,
        ) -> DtOutput {
            let n_units = hist.first().map(|b| b.len()).unwrap_or(0);
            let h_in = hist.len();
            let h = self.history;
            let n = self.pages;

            // Adapt the window to the artifact's H: truncate older rows or
            // pad older rows with zeros (same convention as the policies).
            let mut rows: Vec<Vec<f32>> = Vec::with_capacity(h);
            if h_in >= h {
                for bm in &hist[h_in - h..] {
                    let mut r = vec![0f32; n_units];
                    for u in bm.iter_ones() {
                        r[u] = 1.0;
                    }
                    rows.push(r);
                }
            } else {
                for _ in 0..h - h_in {
                    rows.push(vec![0f32; n_units]);
                }
                for bm in hist {
                    let mut r = vec![0f32; n_units];
                    for u in bm.iter_ones() {
                        r[u] = 1.0;
                    }
                    rows.push(r);
                }
            }

            // Tile over N.
            let mut age = Vec::with_capacity(n_units);
            let mut count = Vec::with_capacity(n_units);
            let mut histogram = vec![0f32; h + 1];
            let tiles = n_units.div_ceil(n).max(1);
            let mut last_prop = h as f32;
            let mut last_smooth = prev_threshold;
            for t in 0..tiles {
                let lo = t * n;
                let hi = ((t + 1) * n).min(n_units);
                let tile_rows: Vec<Vec<f32>> = rows
                    .iter()
                    .map(|r| {
                        let mut v = vec![0f32; n];
                        if lo < n_units {
                            v[..hi - lo].copy_from_slice(&r[lo..hi]);
                        }
                        v
                    })
                    .collect();
                match self.dt_tile(&tile_rows, target_rate, prev_threshold) {
                    Ok((a, c, hg, prop, smooth)) => {
                        age.extend_from_slice(&a[..hi - lo]);
                        count.extend_from_slice(&c[..hi - lo]);
                        // Padding columns are all-zero -> they land in the
                        // "seen < 2 times" bucket only if counted; they have
                        // count 0, so they don't pollute the histogram.
                        for (b, v) in histogram.iter_mut().zip(hg.iter()) {
                            *b += v;
                        }
                        last_prop = prop;
                        last_smooth = smooth;
                    }
                    Err(e) => {
                        // Fail loudly in debug; degrade to native in release.
                        debug_assert!(false, "xla dt_reclaim failed: {e}");
                        return crate::policies::NativeAnalytics::pipeline(
                            hist,
                            target_rate,
                            prev_threshold,
                        );
                    }
                }
            }
            let (proposed, smoothed) = if tiles == 1 {
                (last_prop, last_smooth)
            } else {
                let p = Self::threshold_from_histogram(&histogram, target_rate);
                (
                    p,
                    crate::policies::analytics::SMOOTHING * prev_threshold
                        + (1.0 - crate::policies::analytics::SMOOTHING) * p,
                )
            };
            DtOutput { age, count, histogram, proposed, smoothed }
        }

        fn backend_name(&self) -> &'static str {
            "xla-pjrt"
        }
    }

    impl ErtScorer for XlaAnalytics {
        fn victim(&mut self, ert: &mut [f32], valid: &[f32], dt: f32) -> (usize, f32) {
            let m = self.ert_entries;
            let mut best = (0usize, f32::NEG_INFINITY);
            let tiles = ert.len().div_ceil(m).max(1);
            for tile_idx in 0..tiles {
                let lo = tile_idx * m;
                let hi = ((tile_idx + 1) * m).min(ert.len());
                let chunk_len = hi - lo;
                let mut e = vec![0f32; m];
                e[..chunk_len].copy_from_slice(&ert[lo..hi]);
                let mut v = vec![0f32; m];
                v[..chunk_len].copy_from_slice(&valid[lo..hi]);
                let run = || -> Result<(f32, f32, Vec<f32>)> {
                    let el = xla::Literal::vec1(&e);
                    let vl = xla::Literal::vec1(&v);
                    let dl = xla::Literal::scalar(dt);
                    let out = self.ert_exe.execute::<xla::Literal>(&[el, vl, dl])?[0][0]
                        .to_literal_sync()?;
                    let elems = out.to_tuple()?;
                    Ok((
                        elems[0].to_vec::<f32>()?[0],
                        elems[1].to_vec::<f32>()?[0],
                        elems[2].to_vec::<f32>()?,
                    ))
                };
                match run() {
                    Ok((idx, score, new)) => {
                        self.ert_calls += 1;
                        for (dst, src) in ert[lo..hi]
                            .iter_mut()
                            .zip(new.iter())
                        {
                            *dst = *src;
                        }
                        if score > best.1 {
                            best = (lo + idx as usize, score);
                        }
                    }
                    Err(e) => {
                        debug_assert!(false, "xla ert_victim failed: {e}");
                        // Native fallback for this tile.
                        for i in lo..hi {
                            if valid[i] > 0.0 {
                                ert[i] -= dt;
                                if ert[i].abs() > best.1 {
                                    best = (i, ert[i].abs());
                                }
                            }
                        }
                    }
                }
            }
            best
        }

        fn backend_name(&self) -> &'static str {
            "xla-pjrt"
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::policies::analytics::NativeAnalytics;
        use crate::sim::Rng;

        fn artifacts_available() -> bool {
            std::path::Path::new("artifacts/dt_reclaim.hlo.txt").exists()
        }

        fn random_hist(rng: &mut Rng, h: usize, n: usize, p: f64) -> Vec<Bitmap> {
            (0..h)
                .map(|_| {
                    let mut b = Bitmap::new(n);
                    for i in 0..n {
                        if rng.chance(p) {
                            b.set(i);
                        }
                    }
                    b
                })
                .collect()
        }

        #[test]
        fn xla_matches_native_dt() {
            if !artifacts_available() {
                eprintln!("skipping: artifacts not built");
                return;
            }
            let mut x = XlaAnalytics::from_artifacts("artifacts").unwrap();
            let mut rng = Rng::new(10);
            // Window matching the artifact H, small N (padded to tile).
            let hist = random_hist(&mut rng, x.history, 500, 0.3);
            let refs: Vec<&Bitmap> = hist.iter().collect();
            let xo = x.dt_reclaim(&refs, 0.02, 5.0);
            let no = NativeAnalytics::pipeline(&refs, 0.02, 5.0);
            assert_eq!(xo.age.len(), 500);
            for u in 0..500 {
                assert_eq!(xo.age[u], no.age[u], "age mismatch at {u}");
                assert_eq!(xo.count[u], no.count[u], "count mismatch at {u}");
            }
            assert_eq!(xo.proposed, no.proposed);
            assert!((xo.smoothed - no.smoothed).abs() < 1e-5);
        }

        #[test]
        fn xla_matches_native_ert() {
            if !artifacts_available() {
                eprintln!("skipping: artifacts not built");
                return;
            }
            let mut x = XlaAnalytics::from_artifacts("artifacts").unwrap();
            let mut rng = Rng::new(11);
            let n = 300;
            let mut ert_x: Vec<f32> = (0..n).map(|_| (rng.f64() * 100.0 - 50.0) as f32).collect();
            let valid: Vec<f32> = (0..n).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
            let mut ert_n = ert_x.clone();
            let (xi, xs) = ErtScorer::victim(&mut x, &mut ert_x, &valid, 3.0);
            let mut nat = NativeAnalytics::new();
            let (ni, ns) = nat.victim(&mut ert_n, &valid, 3.0);
            assert_eq!(ert_x, ert_n);
            assert!((xs - ns).abs() < 1e-5, "{xs} vs {ns}");
            // Ties may pick different indices; scores must match.
            assert_eq!(valid[xi], 1.0);
            let _ = ni;
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::XlaAnalytics;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parser() {
        let text = r#"{ "dt_reclaim": { "history": 32, "pages": 65536 },
                        "ert_victim": { "entries": 65536 } }"#;
        assert_eq!(manifest_field(text, "dt_reclaim", "history"), Some(32));
        assert_eq!(manifest_field(text, "dt_reclaim", "pages"), Some(65536));
        assert_eq!(manifest_field(text, "ert_victim", "entries"), Some(65536));
        assert_eq!(manifest_field(text, "nope", "x"), None);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable() {
        let err = XlaAnalytics::from_artifacts("artifacts").err().unwrap();
        assert!(format!("{err}").contains("xla"));
    }
}
