//! flexswap CLI: run paper experiments or individual figures.
//!
//! Usage:
//!   flexswap list                 # list experiments
//!   flexswap fig9 [--full]        # run one experiment
//!   flexswap fleet [--full]       # control-plane fleet (incl. 4-host shards)
//!   flexswap fleet --hosts 4      # sharded fleet with an explicit shard count
//!   flexswap fleet --hosts 8 --seeds 6   # nightly soak: many seeds, CSV per seed
//!   flexswap fleet --hosts 64 --vms 4096 # explicit total VM population
//!   flexswap fleet --hosts 4 --sequential # merge-loop oracle (no worker threads)
//!   flexswap fleet --hosts 4 --workers 2  # pin the epoch engine's thread count
//!   flexswap fleet --hosts 8 --seeds 6 --fault-plan random  # chaos soak
//!   flexswap fleet --hosts 8 --granularity auto  # PR 8 swap-granularity mode
//!   flexswap fleet --hosts 8 --seeds 4 --remote  # PR 9 remote-marketplace soak
//!   flexswap fleet --hosts 8 --clone-storm  # PR 10 boot-storm tables (and soak arm)
//!   flexswap fleet --seeds 2 --out-dir results/chaos  # per-arm CSV directory
//!   flexswap all [--full]         # run every experiment (EXPERIMENTS.md input)
//!   flexswap selfcheck            # artifacts + PJRT smoke test

use flexswap::harness::fleet::{FaultPlan, FleetRunOpts};
use flexswap::harness::{registry, run_by_id, run_fleet_soak, run_fleet_with_hosts, Scale};
use flexswap::types::GranularityMode;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("list");
    // `--hosts N`: shard-count override for the fleet experiment. A
    // malformed or missing value is an error, not a silent fallback.
    let hosts = args.iter().position(|a| a == "--hosts").map(|i| {
        match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            Some(h) if h > 0 => h,
            _ => {
                eprintln!("--hosts needs a positive integer (e.g. `flexswap fleet --hosts 4`)");
                std::process::exit(2);
            }
        }
    });
    // `--seeds K`: run the fleet soak (per-seed sharded comparison, the
    // nightly job's entry point) instead of the single-seed experiment.
    let seeds = args.iter().position(|a| a == "--seeds").map(|i| {
        match args.get(i + 1).and_then(|v| v.parse::<u64>().ok()) {
            Some(s) if s > 0 => s,
            _ => {
                eprintln!(
                    "--seeds needs a positive integer (e.g. `flexswap fleet --hosts 8 --seeds 6`)"
                );
                std::process::exit(2);
            }
        }
    });

    // `--workers N`: pin the epoch engine's worker-thread count (the
    // default is `available_parallelism`). Output is byte-identical at
    // any value — this is a throughput knob, not a semantics knob.
    let workers = args.iter().position(|a| a == "--workers").map(|i| {
        match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            Some(w) if w > 0 => w,
            _ => {
                eprintln!("--workers needs a positive integer (e.g. `flexswap fleet --workers 2`)");
                std::process::exit(2);
            }
        }
    });
    // `--vms N`: total VM population, split evenly across host shards
    // (rounded up so every shard gets at least one VM). Without it the
    // per-host population comes from the scale knob.
    let vms = args.iter().position(|a| a == "--vms").map(|i| {
        match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            Some(v) if v > 0 => v,
            _ => {
                eprintln!(
                    "--vms needs a positive integer (e.g. `flexswap fleet --hosts 64 --vms 4096`)"
                );
                std::process::exit(2);
            }
        }
    });

    // `--fault-plan <none|random>`: arm a deterministic host-fault
    // schedule (crash / degraded-NVMe / budget-revocation, derived from
    // each run's seed) in the fleet soak.
    let fault_plan = args.iter().position(|a| a == "--fault-plan").map(|i| {
        match args.get(i + 1).map(|v| v.as_str()) {
            Some("none") => FaultPlan::None,
            Some("random") => FaultPlan::Random,
            _ => {
                eprintln!(
                    "--fault-plan needs `none` or `random` (e.g. `flexswap fleet --fault-plan random`)"
                );
                std::process::exit(2);
            }
        }
    });

    // `--granularity <4k|huge|auto>`: swap granularity for every fleet
    // VM (PR 8). `4k` is the flat default; `huge` moves whole 2MB
    // regions; `auto` starts huge and lets the dt-reclaimer split
    // refault-heavy regions.
    let granularity = args.iter().position(|a| a == "--granularity").map(|i| {
        match args.get(i + 1).map(|v| v.as_str()) {
            Some("4k") => GranularityMode::Fixed,
            Some("huge") => GranularityMode::Huge,
            Some("auto") => GranularityMode::Auto,
            _ => {
                eprintln!(
                    "--granularity needs `4k`, `huge`, or `auto` (e.g. `flexswap fleet --granularity auto`)"
                );
                std::process::exit(2);
            }
        }
    });

    // `--out-dir DIR`: CSV output directory for the fleet soak (the
    // default `results` matches the PR-gating path). Nightly arms pass
    // distinct directories so their per-arm CSVs — which share the
    // `fleet_soak_*` file names — don't clobber each other.
    let out_dir = args.iter().position(|a| a == "--out-dir").map(|i| {
        match args.get(i + 1) {
            Some(d) if !d.is_empty() && !d.starts_with("--") => d.clone(),
            _ => {
                eprintln!(
                    "--out-dir needs a directory (e.g. `flexswap fleet --seeds 2 --out-dir results/chaos`)"
                );
                std::process::exit(2);
            }
        }
    });

    if cmd == "fleet" {
        let h = hosts.unwrap_or(4);
        let mut opts = FleetRunOpts::default()
            .with_sequential(args.iter().any(|a| a == "--sequential"))
            .with_workers(workers)
            .with_per_host(vms.map(|v| v.div_ceil(h)))
            .with_fault_plan(fault_plan.unwrap_or_default())
            .with_granularity(granularity.map(|g| vec![g]).unwrap_or_default())
            .with_remote(args.iter().any(|a| a == "--remote"));
        // `--clone-storm`: append the PR 10 boot-storm tables (and arm
        // the storm in the soak). Storm size follows the scale knob —
        // 256 clones + 64 cold boots at --full, admitted 4 per tick.
        if args.iter().any(|a| a == "--clone-storm") {
            opts.clone_storm = true;
            opts = opts.with_storm(scale.u(48, 256) as usize, scale.u(16, 64) as usize);
        }
        if let Some(k) = seeds {
            let dir = out_dir.as_deref().unwrap_or("results");
            println!("{}", run_fleet_soak(scale, h, k, opts, dir));
            return;
        }
        if hosts.is_some() || opts != FleetRunOpts::default() {
            println!("{}", run_fleet_with_hosts(scale, h, opts));
            return;
        }
    }

    match cmd {
        "list" => {
            println!("experiments:");
            for e in registry() {
                println!("  {:7} {}", e.id, e.title);
            }
            println!("\nrun one with `flexswap <id>`; add --full for paper-scale runs");
        }
        "all" => {
            for e in registry() {
                eprintln!("running {} ...", e.id);
                match run_by_id(e.id, scale) {
                    Some(md) => println!("{md}"),
                    None => eprintln!("  failed to run {}", e.id),
                }
            }
        }
        "selfcheck" => selfcheck(),
        id => match run_by_id(id, scale) {
            Some(md) => println!("{md}"),
            None => {
                eprintln!("unknown experiment '{id}'; try `flexswap list`");
                std::process::exit(2);
            }
        },
    }
}

/// Verify the AOT artifacts load and agree with the native analytics.
fn selfcheck() {
    use flexswap::policies::{ColdAnalytics, NativeAnalytics};
    use flexswap::runtime::XlaAnalytics;
    use flexswap::sim::Rng;
    use flexswap::types::Bitmap;

    match XlaAnalytics::from_artifacts("artifacts") {
        Err(e) => {
            eprintln!("FAIL: {e:#}");
            std::process::exit(1);
        }
        Ok(mut x) => {
            println!("PJRT platform: {}", x.platform());
            println!(
                "artifacts: dt_reclaim[H={},N={}] ert_victim[M={}]",
                x.history, x.pages, x.ert_entries
            );
            let mut rng = Rng::new(1);
            let hist: Vec<Bitmap> = (0..x.history)
                .map(|_| {
                    let mut b = Bitmap::new(1000);
                    for i in 0..1000 {
                        if rng.chance(0.3) {
                            b.set(i);
                        }
                    }
                    b
                })
                .collect();
            let refs: Vec<&Bitmap> = hist.iter().collect();
            let xo = x.dt_reclaim(&refs, 0.02, 5.0);
            let no = NativeAnalytics::pipeline(&refs, 0.02, 5.0);
            assert_eq!(xo.age, no.age, "age mismatch");
            assert_eq!(xo.proposed, no.proposed, "threshold mismatch");
            println!(
                "xla == native over 1000 units (threshold {}), {} dt calls",
                xo.proposed, x.dt_calls
            );
            println!("selfcheck OK");
        }
    }
}
