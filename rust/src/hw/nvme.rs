//! NVMe swap-device model: flash-channel parallelism + a shared PCIe bus
//! bandwidth cap.
//!
//! The paper's testbed tops out at ~2.6 GB/s (PCIe v3 x4), which the 2MB
//! configuration saturates with two swapper threads (Fig 7). The model:
//! each op picks the earliest-free flash channel (base latency depends
//! on size + direction), then its payload is serialized over a shared
//! bus cursor — giving both per-op latency and aggregate bandwidth
//! saturation without simulating the device internals.

use crate::config::HwConfig;
use crate::types::{Time, FRAME_BYTES};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Read,
    Write,
}

#[derive(Debug, Clone)]
pub struct Nvme {
    channel_free: Vec<Time>,
    bus_free: Time,
    bus_ns_per_byte_num: u64, // ns = bytes * num / den
    bus_ns_per_byte_den: u64,
    lat_4k_ns: Time,
    lat_2m_extra_ns: Time,
    /// Flash-latency multiplier; 1 = healthy. Raised by the fleet's
    /// degraded-NVMe fault injection (transfer time is unchanged: the
    /// bus is fine, the flash is dying).
    degrade_factor: u32,
    pub ops: u64,
    pub bytes: u64,
    /// Busy time of the bus (for utilization reporting).
    pub bus_busy_ns: Time,
}

impl Nvme {
    pub fn new(hw: &HwConfig) -> Self {
        Nvme {
            channel_free: vec![0; hw.nvme_channels],
            bus_free: 0,
            bus_ns_per_byte_num: 1_000_000_000,
            bus_ns_per_byte_den: hw.nvme_bus_bytes_per_sec,
            lat_4k_ns: hw.nvme_lat_4k_ns,
            lat_2m_extra_ns: hw.nvme_lat_2m_extra_ns,
            degrade_factor: 1,
            ops: 0,
            bytes: 0,
            bus_busy_ns: 0,
        }
    }

    #[inline]
    fn transfer_ns(&self, bytes: u64) -> Time {
        bytes * self.bus_ns_per_byte_num / self.bus_ns_per_byte_den
    }

    /// Submit an op at `now`; returns its completion time.
    pub fn submit(&mut self, now: Time, bytes: u64, kind: IoKind) -> Time {
        self.ops += 1;
        self.bytes += bytes;

        // Earliest-free channel (idle channels rewind to `now`).
        let (ci, _) = self
            .channel_free
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| **t)
            .expect("nvme has channels");
        let start = now.max(self.channel_free[ci]);

        // Flash latency: base 4k cost + extra fixed cost for large ops;
        // writes are modestly cheaper (DRAM-buffered on this class of SSD).
        let mut flash = self.lat_4k_ns;
        if bytes > FRAME_BYTES {
            flash += self.lat_2m_extra_ns;
        }
        if kind == IoKind::Write {
            flash = flash * 7 / 10;
        }
        flash *= self.degrade_factor.max(1) as Time;

        // Serialize payload on the shared PCIe bus.
        let xfer = self.transfer_ns(bytes);
        let bus_start = self.bus_free.max(start + flash - xfer.min(flash));
        let bus_done = bus_start + xfer;
        self.bus_free = bus_done;
        self.bus_busy_ns += xfer;

        let done = (start + flash).max(bus_done);
        self.channel_free[ci] = done;
        done
    }

    /// Degrade (or heal) the device: every subsequent op's flash
    /// latency is multiplied by `factor` (clamped to ≥ 1). In-flight
    /// completions are unaffected — degradation is prospective, which
    /// keeps fault injection deterministic at any worker count.
    pub fn set_degrade_factor(&mut self, factor: u32) {
        self.degrade_factor = factor.max(1);
    }

    pub fn degrade_factor(&self) -> u32 {
        self.degrade_factor
    }

    /// Aggregate achieved bandwidth over an interval.
    pub fn achieved_bw(&self, elapsed: Time) -> f64 {
        if elapsed == 0 {
            0.0
        } else {
            self.bytes as f64 / (elapsed as f64 / 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{HUGE_BYTES, MS, US};

    fn dev() -> Nvme {
        Nvme::new(&HwConfig::default())
    }

    #[test]
    fn single_4k_latency_near_base() {
        let mut d = dev();
        let done = d.submit(0, FRAME_BYTES, IoKind::Read);
        assert!(done >= 75 * US && done < 90 * US, "done {done}");
    }

    #[test]
    fn single_2m_latency_dominated_by_transfer() {
        let mut d = dev();
        let done = d.submit(0, HUGE_BYTES, IoKind::Read);
        // ~806us transfer + ~195us flash
        assert!(done > 800 * US && done < 1100 * US, "done {done}");
    }

    #[test]
    fn bus_saturation_2m() {
        let mut d = dev();
        let mut t = 0;
        let mut last = 0;
        // 100 sequential-submitted 2MB reads from many queues saturate the bus.
        for _ in 0..100 {
            last = d.submit(t, HUGE_BYTES, IoKind::Read);
            t += 1; // submitted back-to-back
        }
        let bw = d.bytes as f64 / (last as f64 / 1e9);
        assert!(bw > 2.3e9 && bw < 2.7e9, "bw {bw}");
    }

    #[test]
    fn channels_give_4k_parallelism() {
        let mut d = dev();
        let mut completions = vec![];
        for _ in 0..32 {
            completions.push(d.submit(0, FRAME_BYTES, IoKind::Read));
        }
        // 32 channels: all finish around base latency, not serialized.
        let max = *completions.iter().max().unwrap();
        assert!(max < 200 * US, "max {max}");
        // 33rd op queues behind a channel.
        let d33 = d.submit(0, FRAME_BYTES, IoKind::Read);
        assert!(d33 > max, "d33 {d33} max {max}");
        let _ = MS;
    }

    #[test]
    fn degraded_flash_inflates_latency_but_not_transfer() {
        let mut healthy = dev();
        let mut sick = dev();
        sick.set_degrade_factor(8);
        assert_eq!(sick.degrade_factor(), 8);
        let h = healthy.submit(0, FRAME_BYTES, IoKind::Read);
        let s = sick.submit(0, FRAME_BYTES, IoKind::Read);
        // 4k ops are flash-dominated: ~8x slower end to end.
        assert!(s >= 7 * h, "sick {s} healthy {h}");
        // Clamp: a zero factor means healthy, not free I/O.
        let mut z = dev();
        z.set_degrade_factor(0);
        assert_eq!(z.degrade_factor(), 1);
        assert_eq!(z.submit(0, FRAME_BYTES, IoKind::Read), h);
    }

    #[test]
    fn writes_cheaper_than_reads() {
        let mut d1 = dev();
        let mut d2 = dev();
        let r = d1.submit(0, FRAME_BYTES, IoKind::Read);
        let w = d2.submit(0, FRAME_BYTES, IoKind::Write);
        assert!(w < r);
    }
}
