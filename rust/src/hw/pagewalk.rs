//! Nested page-walk cost model with partial-walk-cache (PWC) state.
//!
//! §3.3: clearing EPT access bits flushes the partial-walk caches, so an
//! EPT scan has an *indirect* cost — every TLB miss walks slower for a
//! window after the scan. We model that with a decaying penalty window.

use crate::config::HwConfig;
use crate::types::{PageSize, Time};

#[derive(Debug, Clone)]
pub struct WalkModel {
    walk_4k_ns: Time,
    walk_2m_ns: Time,
    pwc_penalty_ns: Time,
    pwc_penalty_window: Time,
    /// Walks cost extra until this virtual time (set by A-bit clears).
    penalty_until: Time,
}

impl WalkModel {
    pub fn new(hw: &HwConfig) -> Self {
        WalkModel {
            walk_4k_ns: hw.walk_4k_ns,
            walk_2m_ns: hw.walk_2m_ns,
            pwc_penalty_ns: hw.pwc_penalty_ns,
            pwc_penalty_window: hw.pwc_penalty_window,
            penalty_until: 0,
        }
    }

    /// Cost of one full nested walk at `now` for the given leaf size.
    #[inline]
    pub fn walk_cost(&self, now: Time, leaf: PageSize) -> Time {
        let base = match leaf {
            PageSize::Small => self.walk_4k_ns,
            PageSize::Huge => self.walk_2m_ns,
        };
        if now < self.penalty_until {
            base + self.pwc_penalty_ns
        } else {
            base
        }
    }

    /// Called when an EPT scan cleared access bits (flushes PWCs).
    pub fn on_abit_clear(&mut self, now: Time) {
        self.penalty_until = now + self.pwc_penalty_window;
    }

    /// True while the PWC penalty window is active.
    pub fn penalized(&self, now: Time) -> bool {
        now < self.penalty_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> WalkModel {
        WalkModel::new(&HwConfig::default())
    }

    #[test]
    fn huge_walks_shorter() {
        let m = model();
        assert!(m.walk_cost(0, PageSize::Huge) < m.walk_cost(0, PageSize::Small));
    }

    #[test]
    fn penalty_window_applies_and_expires() {
        let mut m = model();
        let base = m.walk_cost(0, PageSize::Small);
        m.on_abit_clear(1000);
        assert!(m.penalized(1000));
        assert_eq!(m.walk_cost(1000, PageSize::Small), base + 60);
        let after = 1000 + HwConfig::default().pwc_penalty_window;
        assert!(!m.penalized(after));
        assert_eq!(m.walk_cost(after, PageSize::Small), base);
    }
}
