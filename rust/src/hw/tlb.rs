//! Single-level TLB model with random replacement.
//!
//! A direct sim of a set-associative TLB is overkill for the figures we
//! reproduce; what matters is *reach* (entries x page size) and the cost
//! asymmetry of 4kB vs 2MB walks (Fig 1). We model a fully-associative
//! TLB of `capacity` entries with random replacement via a fixed-size
//! open-addressed table — O(1), allocation-free on the access path.

use crate::sim::Rng;

/// TLB over page numbers (caller picks granularity: 4kB VPN or 2MB VPN).
#[derive(Debug, Clone)]
pub struct Tlb {
    /// Slot tags; u64::MAX = empty. Tag = (asid << 48) | vpn.
    slots: Vec<u64>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(capacity: usize) -> Self {
        // 2x capacity slots keeps the open-addressed table fast while the
        // resident entry count is bounded by `capacity` via random eviction.
        Tlb { slots: vec![u64::MAX; (capacity * 2).next_power_of_two()], capacity, hits: 0, misses: 0 }
    }

    #[inline]
    fn tag(asid: u16, vpn: u64) -> u64 {
        ((asid as u64) << 48) | (vpn & 0xFFFF_FFFF_FFFF)
    }

    #[inline]
    fn slot_of(&self, tag: u64) -> usize {
        // Fibonacci hash.
        (tag.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize & (self.slots.len() - 1)
    }

    /// Look up; on miss the caller pays a walk and we install the entry.
    #[inline]
    pub fn access(&mut self, asid: u16, vpn: u64, rng: &mut Rng) -> bool {
        let tag = Self::tag(asid, vpn);
        let base = self.slot_of(tag);
        let mask = self.slots.len() - 1;
        // Probe a short window (models limited associativity).
        for i in 0..4 {
            let s = (base + i) & mask;
            if self.slots[s] == tag {
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        // Install: pick a probe slot; random within window models random
        // replacement. Bounded occupancy: with window insertions the table
        // holds at most slots.len() entries; reach is controlled by
        // capacity-scaled table size.
        let victim = (base + rng.below(4) as usize) & mask;
        self.slots[victim] = tag;
        true_miss()
    }

    /// Drop every entry (context switch / PWC flush companion).
    pub fn flush(&mut self) {
        self.slots.fill(u64::MAX);
    }

    /// Effective capacity this TLB was built for.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[inline]
fn true_miss() -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_working_set_hits() {
        let mut tlb = Tlb::new(64);
        let mut rng = Rng::new(1);
        // Warm 8 pages, then re-access: should be ~all hits.
        for p in 0..8 {
            tlb.access(0, p, &mut rng);
        }
        let before = tlb.hits;
        for _ in 0..100 {
            for p in 0..8 {
                tlb.access(0, p, &mut rng);
            }
        }
        assert!(tlb.hits - before >= 780, "hits {}", tlb.hits - before);
    }

    #[test]
    fn large_working_set_misses() {
        let mut tlb = Tlb::new(64);
        let mut rng = Rng::new(2);
        let mut misses = 0;
        for i in 0..100_000u64 {
            if !tlb.access(0, rng.below(1 << 20), &mut rng) {
                misses += 1;
            }
            let _ = i;
        }
        // Random accesses over 1M pages with 64-entry reach: ~100% miss.
        assert!(misses > 95_000, "misses {misses}");
    }

    #[test]
    fn asid_separates_contexts() {
        let mut tlb = Tlb::new(64);
        let mut rng = Rng::new(3);
        tlb.access(1, 42, &mut rng); // install
        let h = tlb.hits;
        tlb.access(1, 42, &mut rng); // same asid: hit
        assert_eq!(tlb.hits, h + 1);
        tlb.access(2, 42, &mut rng); // different asid: miss
        assert_eq!(tlb.hits, h + 1);
    }

    #[test]
    fn flush_clears() {
        let mut tlb = Tlb::new(64);
        let mut rng = Rng::new(4);
        tlb.access(0, 7, &mut rng);
        tlb.flush();
        let h = tlb.hits;
        tlb.access(0, 7, &mut rng);
        assert_eq!(tlb.hits, h);
    }
}
