//! Hardware substrate models: TLB, nested page walk (+ partial-walk
//! caches), EPT access/dirty bits, and the NVMe swap device.
//!
//! These stand in for the paper's Cascade Lake + Intel D7-P5510 testbed
//! (repro band 0/5 — see DESIGN.md §2). Each model is parameterized by
//! [`crate::config::HwConfig`] constants calibrated from the paper.

pub mod ept;
pub mod nvme;
pub mod pagewalk;
pub mod tlb;

pub use ept::Ept;
pub use nvme::{IoKind, Nvme};
pub use pagewalk::WalkModel;
pub use tlb::Tlb;
