//! Extended Page Table model: per-swap-unit presence + access/dirty bits.
//!
//! The hypervisor's second-stage translation (GPA -> HPA). In strict-2MB
//! mode every leaf covers one 2MB unit; in strict-4kB mode one 4kB frame.
//! We only track what the paper's mechanisms consume: presence (an EPT
//! violation is raised on non-present access), and A/D bits (read +
//! cleared by the EPT scanner, §5.4).
//!
//! The bits live in three parallel [`Bitmap`]s rather than a per-unit
//! flag byte so [`Ept::scan_and_clear`] — the direct CPU cost that
//! bounds how aggressively policies can scan (§3.3, Fig 3) — operates
//! on 64 units per AND/clear instead of one unit per branch.

use crate::types::{Bitmap, UnitId};

/// EPT over `units` swap units.
#[derive(Debug, Clone)]
pub struct Ept {
    present: Bitmap,
    accessed: Bitmap,
    dirty: Bitmap,
}

impl Ept {
    pub fn new(units: u64) -> Self {
        Ept {
            present: Bitmap::new(units as usize),
            accessed: Bitmap::new(units as usize),
            dirty: Bitmap::new(units as usize),
        }
    }

    pub fn units(&self) -> u64 {
        self.present.len() as u64
    }

    /// True if the unit is mapped (no EPT violation on access).
    #[inline]
    pub fn present(&self, unit: UnitId) -> bool {
        self.present.get(unit as usize)
    }

    /// Record a guest access; returns false if it raises an EPT violation.
    #[inline]
    pub fn touch(&mut self, unit: UnitId, write: bool) -> bool {
        let ui = unit as usize;
        if !self.present.get(ui) {
            return false;
        }
        self.accessed.set(ui);
        if write {
            self.dirty.set(ui);
        }
        true
    }

    /// Install a leaf mapping (UFFDIO_CONTINUE resolved the violation).
    pub fn map(&mut self, unit: UnitId) {
        // Mapping implies an immediate access by the faulting instruction.
        self.present.set(unit as usize);
        self.accessed.set(unit as usize);
    }

    /// Remove a leaf (MADV_DONTNEED on swap-out).
    pub fn unmap(&mut self, unit: UnitId) {
        self.present.clear(unit as usize);
        self.accessed.clear(unit as usize);
        self.dirty.clear(unit as usize);
    }

    pub fn accessed(&self, unit: UnitId) -> bool {
        self.accessed.get(unit as usize)
    }

    pub fn dirty(&self, unit: UnitId) -> bool {
        self.dirty.get(unit as usize)
    }

    pub fn clear_dirty(&mut self, unit: UnitId) {
        self.dirty.clear(unit as usize);
    }

    /// Scan: copy A-bits into a bitmap and clear them (the kernel-module
    /// behaviour the userspace EPT scanner drives). Returns the number of
    /// *present* leaves visited (scan cost scales with PTE count).
    ///
    /// Word-parallel: each 64-unit word costs one popcount plus, only
    /// when some present unit was accessed, one OR into `out` and one
    /// AND-NOT to clear — no per-unit branching.
    pub fn scan_and_clear(&mut self, out: &mut Bitmap) -> u64 {
        assert_eq!(out.len() as u64, self.units());
        let mut visited = 0u64;
        let pw = self.present.as_words();
        let aw = self.accessed.as_words_mut();
        let ow = out.as_words_mut();
        for ((&p, a), o) in pw.iter().zip(aw.iter_mut()).zip(ow.iter_mut()) {
            if p == 0 {
                continue;
            }
            visited += p.count_ones() as u64;
            // `accessed` is a subset of `present` by construction (touch
            // requires presence, unmap clears both), but mask anyway so a
            // stray bit can never leak into the scan output.
            let hit = *a & p;
            if hit != 0 {
                *o |= hit;
                *a &= !hit;
            }
        }
        visited
    }

    /// Present-unit count (resident memory in units).
    pub fn resident_units(&self) -> u64 {
        self.present.count_ones() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_requires_present() {
        let mut e = Ept::new(4);
        assert!(!e.touch(0, false)); // violation
        e.map(0);
        assert!(e.touch(0, true));
        assert!(e.accessed(0) && e.dirty(0));
    }

    #[test]
    fn scan_clears_abits() {
        let mut e = Ept::new(8);
        e.map(1);
        e.map(2);
        e.touch(1, false);
        let mut bm = Bitmap::new(8);
        let visited = e.scan_and_clear(&mut bm);
        assert_eq!(visited, 2);
        // map() sets ACCESSED too, so both 1 and 2 read as accessed.
        assert!(bm.get(1) && bm.get(2));
        // Second scan: A-bits cleared, nothing accessed.
        let mut bm2 = Bitmap::new(8);
        e.scan_and_clear(&mut bm2);
        assert_eq!(bm2.count_ones(), 0);
    }

    #[test]
    fn unmap_clears_everything() {
        let mut e = Ept::new(2);
        e.map(0);
        e.touch(0, true);
        e.unmap(0);
        assert!(!e.present(0));
        assert!(!e.touch(0, false));
        assert_eq!(e.resident_units(), 0);
    }

    #[test]
    fn scan_across_word_boundaries() {
        // Units straddling the 64-bit word edges must scan correctly.
        let mut e = Ept::new(130);
        for u in [0u64, 63, 64, 65, 128, 129] {
            e.map(u);
        }
        e.unmap(65); // present gap inside the second word
        let mut bm = Bitmap::new(130);
        let visited = e.scan_and_clear(&mut bm);
        assert_eq!(visited, 5);
        let ones: Vec<_> = bm.iter_ones().collect();
        assert_eq!(ones, vec![0, 63, 64, 128, 129]);
        // A-bits cleared, presence retained.
        assert_eq!(e.resident_units(), 5);
        let mut bm2 = Bitmap::new(130);
        assert_eq!(e.scan_and_clear(&mut bm2), 5);
        assert_eq!(bm2.count_ones(), 0);
    }

    #[test]
    fn dirty_tracking_survives_scan() {
        let mut e = Ept::new(4);
        e.map(1);
        e.touch(1, true);
        let mut bm = Bitmap::new(4);
        e.scan_and_clear(&mut bm);
        // Scanning clears A, never D (write-back elision depends on it).
        assert!(e.dirty(1) && !e.accessed(1));
        e.clear_dirty(1);
        assert!(!e.dirty(1));
    }
}
