//! Extended Page Table model: per-swap-unit presence + access/dirty bits.
//!
//! The hypervisor's second-stage translation (GPA -> HPA). In strict-2MB
//! mode every leaf covers one 2MB unit; in strict-4kB mode one 4kB frame.
//! We only track what the paper's mechanisms consume: presence (an EPT
//! violation is raised on non-present access), and A/D bits (read +
//! cleared by the EPT scanner, §5.4).

use crate::types::{Bitmap, UnitId};

const PRESENT: u8 = 1;
const ACCESSED: u8 = 2;
const DIRTY: u8 = 4;

/// EPT over `units` swap units.
#[derive(Debug, Clone)]
pub struct Ept {
    flags: Vec<u8>,
}

impl Ept {
    pub fn new(units: u64) -> Self {
        Ept { flags: vec![0; units as usize] }
    }

    pub fn units(&self) -> u64 {
        self.flags.len() as u64
    }

    /// True if the unit is mapped (no EPT violation on access).
    #[inline]
    pub fn present(&self, unit: UnitId) -> bool {
        self.flags[unit as usize] & PRESENT != 0
    }

    /// Record a guest access; returns false if it raises an EPT violation.
    #[inline]
    pub fn touch(&mut self, unit: UnitId, write: bool) -> bool {
        let f = &mut self.flags[unit as usize];
        if *f & PRESENT == 0 {
            return false;
        }
        *f |= ACCESSED | if write { DIRTY } else { 0 };
        true
    }

    /// Install a leaf mapping (UFFDIO_CONTINUE resolved the violation).
    pub fn map(&mut self, unit: UnitId) {
        // Mapping implies an immediate access by the faulting instruction.
        self.flags[unit as usize] |= PRESENT | ACCESSED;
    }

    /// Remove a leaf (MADV_DONTNEED on swap-out).
    pub fn unmap(&mut self, unit: UnitId) {
        self.flags[unit as usize] = 0;
    }

    pub fn accessed(&self, unit: UnitId) -> bool {
        self.flags[unit as usize] & ACCESSED != 0
    }

    pub fn dirty(&self, unit: UnitId) -> bool {
        self.flags[unit as usize] & DIRTY != 0
    }

    pub fn clear_dirty(&mut self, unit: UnitId) {
        self.flags[unit as usize] &= !DIRTY;
    }

    /// Scan: copy A-bits into a bitmap and clear them (the kernel-module
    /// behaviour the userspace EPT scanner drives). Returns the number of
    /// *present* leaves visited (scan cost scales with PTE count).
    pub fn scan_and_clear(&mut self, out: &mut Bitmap) -> u64 {
        assert_eq!(out.len() as u64, self.units());
        let mut visited = 0;
        for (i, f) in self.flags.iter_mut().enumerate() {
            if *f & PRESENT != 0 {
                visited += 1;
                if *f & ACCESSED != 0 {
                    out.set(i);
                    *f &= !ACCESSED;
                }
            }
        }
        visited
    }

    /// Present-unit count (resident memory in units).
    pub fn resident_units(&self) -> u64 {
        self.flags.iter().filter(|f| **f & PRESENT != 0).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_requires_present() {
        let mut e = Ept::new(4);
        assert!(!e.touch(0, false)); // violation
        e.map(0);
        assert!(e.touch(0, true));
        assert!(e.accessed(0) && e.dirty(0));
    }

    #[test]
    fn scan_clears_abits() {
        let mut e = Ept::new(8);
        e.map(1);
        e.map(2);
        e.touch(1, false);
        let mut bm = Bitmap::new(8);
        let visited = e.scan_and_clear(&mut bm);
        assert_eq!(visited, 2);
        // map() sets ACCESSED too, so both 1 and 2 read as accessed.
        assert!(bm.get(1) && bm.get(2));
        // Second scan: A-bits cleared, nothing accessed.
        let mut bm2 = Bitmap::new(8);
        e.scan_and_clear(&mut bm2);
        assert_eq!(bm2.count_ones(), 0);
    }

    #[test]
    fn unmap_clears_everything() {
        let mut e = Ept::new(2);
        e.map(0);
        e.touch(0, true);
        e.unmap(0);
        assert!(!e.present(0));
        assert!(!e.touch(0, false));
        assert_eq!(e.resident_units(), 0);
    }
}
