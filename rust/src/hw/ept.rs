//! Extended Page Table model: per-swap-unit presence + access/dirty bits.
//!
//! The hypervisor's second-stage translation (GPA -> HPA). In strict-2MB
//! mode every leaf covers one 2MB unit; in strict-4kB mode one 4kB frame.
//! We only track what the paper's mechanisms consume: presence (an EPT
//! violation is raised on non-present access), and A/D bits (read +
//! cleared by the EPT scanner, §5.4).
//!
//! The bits live in three parallel [`Bitmap`]s rather than a per-unit
//! flag byte so [`Ept::scan_and_clear`] — the direct CPU cost that
//! bounds how aggressively policies can scan (§3.3, Fig 3) — operates
//! on 64 units per AND/clear instead of one unit per branch.
//!
//! # Two-level granularity (PR 8)
//!
//! A 4kB-unit EPT can overlay 2MB-backed *regions* of [`REGION_UNITS`]
//! units. A huge region keeps its presence/A/D state in one bit of the
//! region-level summary bitmaps (`r_present`/`r_accessed`/`r_dirty`)
//! and its unit-level bits all-zero; a split region is the inverse.
//! State lives at exactly one level, so the word-parallel 4k scan loop
//! runs unchanged (huge spans contribute zero words) and a second,
//! regions/64-sized loop visits one bit per live huge region — a 2M
//! A-bit check covers 512 units in one test. With no huge regions every
//! path short-circuits to the flat pre-PR-8 behaviour.

use crate::types::{Bitmap, UnitId, REGION_UNITS};

/// EPT over `units` swap units.
#[derive(Debug, Clone)]
pub struct Ept {
    present: Bitmap,
    accessed: Bitmap,
    dirty: Bitmap,
    /// Bit r set: region r is 2MB-backed (state in `r_*`, unit bits 0).
    huge: Bitmap,
    r_present: Bitmap,
    r_accessed: Bitmap,
    r_dirty: Bitmap,
    /// Count of set bits in `huge` (fast path: 0 = flat 4k EPT).
    huge_regions: u64,
}

impl Ept {
    pub fn new(units: u64) -> Self {
        let regions = units.div_ceil(REGION_UNITS) as usize;
        Ept {
            present: Bitmap::new(units as usize),
            accessed: Bitmap::new(units as usize),
            dirty: Bitmap::new(units as usize),
            huge: Bitmap::new(regions),
            r_present: Bitmap::new(regions),
            r_accessed: Bitmap::new(regions),
            r_dirty: Bitmap::new(regions),
            huge_regions: 0,
        }
    }

    pub fn units(&self) -> u64 {
        self.present.len() as u64
    }

    /// Number of granularity regions ([`REGION_UNITS`] units each; the
    /// last one may be short).
    pub fn regions(&self) -> u64 {
        self.huge.len() as u64
    }

    /// Count of 2MB-backed regions.
    pub fn huge_region_count(&self) -> u64 {
        self.huge_regions
    }

    /// Is region `r` 2MB-backed?
    #[inline]
    pub fn region_huge(&self, r: u64) -> bool {
        self.huge_regions > 0 && self.huge.get(r as usize)
    }

    /// Unit range `[lo, hi)` covered by region `r`.
    #[inline]
    fn span(&self, r: usize) -> (usize, usize) {
        let lo = r * REGION_UNITS as usize;
        (lo, (lo + REGION_UNITS as usize).min(self.present.len()))
    }

    /// The unit that carries a unit's state: the region base when its
    /// region is huge, the unit itself otherwise.
    #[inline]
    pub fn canonical_unit(&self, unit: UnitId) -> UnitId {
        if self.huge_regions > 0 && self.huge.get((unit / REGION_UNITS) as usize) {
            unit - unit % REGION_UNITS
        } else {
            unit
        }
    }

    /// True if the unit is mapped (no EPT violation on access).
    #[inline]
    pub fn present(&self, unit: UnitId) -> bool {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                return self.r_present.get(r);
            }
        }
        self.present.get(unit as usize)
    }

    /// Record a guest access; returns false if it raises an EPT violation.
    #[inline]
    pub fn touch(&mut self, unit: UnitId, write: bool) -> bool {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                if !self.r_present.get(r) {
                    return false;
                }
                self.r_accessed.set(r);
                if write {
                    self.r_dirty.set(r);
                }
                return true;
            }
        }
        let ui = unit as usize;
        if !self.present.get(ui) {
            return false;
        }
        self.accessed.set(ui);
        if write {
            self.dirty.set(ui);
        }
        true
    }

    /// Install a leaf mapping (UFFDIO_CONTINUE resolved the violation).
    pub fn map(&mut self, unit: UnitId) {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                // Mapping implies an immediate access by the faulter.
                self.r_present.set(r);
                self.r_accessed.set(r);
                return;
            }
        }
        self.present.set(unit as usize);
        self.accessed.set(unit as usize);
    }

    /// Remove a leaf (MADV_DONTNEED on swap-out). For a unit inside a
    /// huge region this drops the whole region's 2MB leaf.
    pub fn unmap(&mut self, unit: UnitId) {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                self.r_present.clear(r);
                self.r_accessed.clear(r);
                self.r_dirty.clear(r);
                return;
            }
        }
        self.present.clear(unit as usize);
        self.accessed.clear(unit as usize);
        self.dirty.clear(unit as usize);
    }

    pub fn accessed(&self, unit: UnitId) -> bool {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                return self.r_accessed.get(r);
            }
        }
        self.accessed.get(unit as usize)
    }

    pub fn dirty(&self, unit: UnitId) -> bool {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                return self.r_dirty.get(r);
            }
        }
        self.dirty.get(unit as usize)
    }

    pub fn clear_dirty(&mut self, unit: UnitId) {
        if self.huge_regions > 0 {
            let r = (unit / REGION_UNITS) as usize;
            if self.huge.get(r) {
                self.r_dirty.clear(r);
                return;
            }
        }
        self.dirty.clear(unit as usize);
    }

    /// Promote region `r` to a 2MB leaf, folding any unit-level state up
    /// into the region summary (callers collapse uniformly-populated
    /// regions, so "any unit present" and "all present" coincide there).
    pub fn set_region_huge(&mut self, r: u64) {
        let ri = r as usize;
        if self.huge.get(ri) {
            return;
        }
        let (lo, hi) = self.span(ri);
        if self.present.any_in_range(lo, hi) {
            self.r_present.set(ri);
        }
        if self.accessed.any_in_range(lo, hi) {
            self.r_accessed.set(ri);
        }
        if self.dirty.any_in_range(lo, hi) {
            self.r_dirty.set(ri);
        }
        self.present.clear_range(lo, hi);
        self.accessed.clear_range(lo, hi);
        self.dirty.clear_range(lo, hi);
        self.huge.set(ri);
        self.huge_regions += 1;
    }

    /// Demote region `r` back to per-4k leaves, fanning the region
    /// summary down over the whole span.
    pub fn split_region(&mut self, r: u64) {
        let ri = r as usize;
        if !self.huge.get(ri) {
            return;
        }
        let (lo, hi) = self.span(ri);
        if self.r_present.get(ri) {
            self.present.set_range(lo, hi);
        }
        if self.r_accessed.get(ri) {
            self.accessed.set_range(lo, hi);
        }
        if self.r_dirty.get(ri) {
            self.dirty.set_range(lo, hi);
        }
        self.r_present.clear(ri);
        self.r_accessed.clear(ri);
        self.r_dirty.clear(ri);
        self.huge.clear(ri);
        self.huge_regions -= 1;
    }

    /// Scan: copy A-bits into a bitmap and clear them (the kernel-module
    /// behaviour the userspace EPT scanner drives). Returns the number of
    /// *present* leaves visited (scan cost scales with PTE count) — one
    /// leaf per live 2MB region, one per present 4k unit.
    ///
    /// Word-parallel: each 64-unit word costs one popcount plus, only
    /// when some present unit was accessed, one OR into `out` and one
    /// AND-NOT to clear — no per-unit branching. Huge regions never
    /// contribute unit-level words; a second regions/64-sized loop tests
    /// one bit per live region and reports hits at the region base unit.
    pub fn scan_and_clear(&mut self, out: &mut Bitmap) -> u64 {
        assert_eq!(out.len() as u64, self.units());
        let mut visited = 0u64;
        let pw = self.present.as_words();
        let aw = self.accessed.as_words_mut();
        let ow = out.as_words_mut();
        for ((&p, a), o) in pw.iter().zip(aw.iter_mut()).zip(ow.iter_mut()) {
            if p == 0 {
                continue;
            }
            visited += p.count_ones() as u64;
            // `accessed` is a subset of `present` by construction (touch
            // requires presence, unmap clears both), but mask anyway so a
            // stray bit can never leak into the scan output.
            let hit = *a & p;
            if hit != 0 {
                *o |= hit;
                *a &= !hit;
            }
        }
        if self.huge_regions > 0 {
            let hw = self.huge.as_words();
            let rp = self.r_present.as_words();
            let ra = self.r_accessed.as_words_mut();
            for (wi, ((&h, &p), a)) in hw.iter().zip(rp.iter()).zip(ra.iter_mut()).enumerate() {
                let live = h & p;
                if live == 0 {
                    continue;
                }
                visited += live.count_ones() as u64;
                let mut hit = *a & live;
                if hit != 0 {
                    *a &= !hit;
                    while hit != 0 {
                        let b = hit.trailing_zeros() as usize;
                        hit &= hit - 1;
                        out.set((wi * 64 + b) * REGION_UNITS as usize);
                    }
                }
            }
        }
        visited
    }

    /// Present-unit count (resident memory in units): per-4k presents
    /// plus the full span of every live 2MB region.
    pub fn resident_units(&self) -> u64 {
        let mut n = self.present.count_ones() as u64;
        if self.huge_regions > 0 {
            let hw = self.huge.as_words();
            let rp = self.r_present.as_words();
            for (wi, (&h, &p)) in hw.iter().zip(rp.iter()).enumerate() {
                let mut live = h & p;
                while live != 0 {
                    let b = live.trailing_zeros() as usize;
                    live &= live - 1;
                    let (lo, hi) = self.span(wi * 64 + b);
                    n += (hi - lo) as u64;
                }
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_requires_present() {
        let mut e = Ept::new(4);
        assert!(!e.touch(0, false)); // violation
        e.map(0);
        assert!(e.touch(0, true));
        assert!(e.accessed(0) && e.dirty(0));
    }

    #[test]
    fn scan_clears_abits() {
        let mut e = Ept::new(8);
        e.map(1);
        e.map(2);
        e.touch(1, false);
        let mut bm = Bitmap::new(8);
        let visited = e.scan_and_clear(&mut bm);
        assert_eq!(visited, 2);
        // map() sets ACCESSED too, so both 1 and 2 read as accessed.
        assert!(bm.get(1) && bm.get(2));
        // Second scan: A-bits cleared, nothing accessed.
        let mut bm2 = Bitmap::new(8);
        e.scan_and_clear(&mut bm2);
        assert_eq!(bm2.count_ones(), 0);
    }

    #[test]
    fn unmap_clears_everything() {
        let mut e = Ept::new(2);
        e.map(0);
        e.touch(0, true);
        e.unmap(0);
        assert!(!e.present(0));
        assert!(!e.touch(0, false));
        assert_eq!(e.resident_units(), 0);
    }

    #[test]
    fn scan_across_word_boundaries() {
        // Units straddling the 64-bit word edges must scan correctly.
        let mut e = Ept::new(130);
        for u in [0u64, 63, 64, 65, 128, 129] {
            e.map(u);
        }
        e.unmap(65); // present gap inside the second word
        let mut bm = Bitmap::new(130);
        let visited = e.scan_and_clear(&mut bm);
        assert_eq!(visited, 5);
        let ones: Vec<_> = bm.iter_ones().collect();
        assert_eq!(ones, vec![0, 63, 64, 128, 129]);
        // A-bits cleared, presence retained.
        assert_eq!(e.resident_units(), 5);
        let mut bm2 = Bitmap::new(130);
        assert_eq!(e.scan_and_clear(&mut bm2), 5);
        assert_eq!(bm2.count_ones(), 0);
    }

    #[test]
    fn dirty_tracking_survives_scan() {
        let mut e = Ept::new(4);
        e.map(1);
        e.touch(1, true);
        let mut bm = Bitmap::new(4);
        e.scan_and_clear(&mut bm);
        // Scanning clears A, never D (write-back elision depends on it).
        assert!(e.dirty(1) && !e.accessed(1));
        e.clear_dirty(1);
        assert!(!e.dirty(1));
    }

    #[test]
    fn granularity_huge_region_state_lives_at_one_level() {
        // 3 regions, last one short (1536 + 100 units).
        let mut e = Ept::new(2 * REGION_UNITS + 100);
        assert_eq!(e.regions(), 3);
        e.set_region_huge(1);
        assert_eq!(e.huge_region_count(), 1);
        assert!(e.region_huge(1) && !e.region_huge(0));
        // Any unit in the region canonicalizes to the base.
        assert_eq!(e.canonical_unit(REGION_UNITS + 77), REGION_UNITS);
        assert_eq!(e.canonical_unit(5), 5);
        // Map via a non-base unit: the whole region becomes present.
        e.map(REGION_UNITS + 77);
        assert!(e.present(REGION_UNITS) && e.present(2 * REGION_UNITS - 1));
        assert_eq!(e.resident_units(), REGION_UNITS);
        assert!(e.touch(REGION_UNITS + 3, true));
        assert!(e.dirty(REGION_UNITS + 9));
        // Unit-level bitmaps stay empty: state is region-level only.
        assert_eq!(e.present.count_ones(), 0);
        e.unmap(REGION_UNITS + 500);
        assert_eq!(e.resident_units(), 0);
        assert!(!e.present(REGION_UNITS));
    }

    #[test]
    fn granularity_scan_visits_one_leaf_per_huge_region() {
        let mut e = Ept::new(4 * REGION_UNITS);
        for r in 0..4 {
            e.set_region_huge(r);
        }
        e.map(0); // region 0
        e.map(2 * REGION_UNITS + 9); // region 2
        let mut bm = Bitmap::new(4 * REGION_UNITS as usize);
        // Two live 2MB leaves: visited = 2, not 1024.
        assert_eq!(e.scan_and_clear(&mut bm), 2);
        // Hits reported at the region base units.
        let ones: Vec<_> = bm.iter_ones().collect();
        assert_eq!(ones, vec![0, 2 * REGION_UNITS as usize]);
        // A-bits cleared, presence retained.
        let mut bm2 = Bitmap::new(4 * REGION_UNITS as usize);
        assert_eq!(e.scan_and_clear(&mut bm2), 2);
        assert_eq!(bm2.count_ones(), 0);
        assert_eq!(e.resident_units(), 2 * REGION_UNITS);
    }

    #[test]
    fn granularity_mixed_scan_sums_levels() {
        // Region 0 huge + live, region 1 split with 3 present units.
        let mut e = Ept::new(2 * REGION_UNITS);
        e.set_region_huge(0);
        e.map(7); // canonicalized into region 0's summary
        for u in [REGION_UNITS, REGION_UNITS + 64, 2 * REGION_UNITS - 1] {
            e.map(u);
        }
        let mut bm = Bitmap::new(2 * REGION_UNITS as usize);
        assert_eq!(e.scan_and_clear(&mut bm), 4);
        let ones: Vec<_> = bm.iter_ones().collect();
        assert_eq!(
            ones,
            vec![
                0,
                REGION_UNITS as usize,
                REGION_UNITS as usize + 64,
                2 * REGION_UNITS as usize - 1
            ]
        );
        assert_eq!(e.resident_units(), REGION_UNITS + 3);
    }

    #[test]
    fn granularity_split_fans_state_down_and_collapse_folds_up() {
        let mut e = Ept::new(2 * REGION_UNITS);
        e.set_region_huge(0);
        e.map(0);
        e.touch(3, true); // region-level dirty
        e.split_region(0);
        assert_eq!(e.huge_region_count(), 0);
        // Every unit of the span is now individually present + dirty.
        assert!(e.present(0) && e.present(REGION_UNITS - 1));
        assert!(e.dirty(0) && e.dirty(REGION_UNITS - 1));
        assert!(!e.present(REGION_UNITS));
        assert_eq!(e.resident_units(), REGION_UNITS);
        // Collapse folds it back up into one summary bit.
        e.set_region_huge(0);
        assert!(e.present(5) && e.dirty(5));
        assert_eq!(e.resident_units(), REGION_UNITS);
        assert_eq!(e.present.count_ones(), 0);
        // Split of an untouched huge region yields an empty span.
        e.set_region_huge(1);
        e.split_region(1);
        assert!(!e.present(REGION_UNITS + 1));
        // Idempotence: split of a split region / collapse twice no-op.
        e.split_region(1);
        e.set_region_huge(0);
        assert_eq!(e.huge_region_count(), 1);
    }

    #[test]
    fn granularity_flat_ept_is_untouched_by_region_code() {
        // huge_regions == 0: scan output identical to the flat loop.
        let mut e = Ept::new(130);
        e.map(129);
        assert_eq!(e.canonical_unit(129), 129);
        assert!(!e.region_huge(0));
        let mut bm = Bitmap::new(130);
        assert_eq!(e.scan_and_clear(&mut bm), 1);
        assert_eq!(bm.iter_ones().collect::<Vec<_>>(), vec![129]);
    }
}
