//! Fleet-scheduler invariant/property suite (PR 4, extended in PR 5):
//! across ≥40 seeds the sharded control plane must (a) hold every
//! shard's budget at every control tick — including mid-migration,
//! (b) never split a VM across shards outside an in-flight migration
//! window (atomic hand-off at the flip), (c) be bit-identical for the
//! same seed, and (d) conserve migrated bytes — bytes leaving a shard
//! equal bytes arriving, Σ budgets constant. PR 5 extends the sweep to
//! runs with completed **VM state migrations** (the whole VM moves,
//! cold-first, stop-and-copy flip). Plus: the proportional-share
//! arbiter against a brute-force reference solver (the PR 1
//! LRU-oracle pattern), the recovery-mode window regression, the
//! rebalancer-beats-static acceptance and the full-migration-beats-
//! lease acceptance. PR 6 adds the parallel-execution gate: the epoch
//! engine (per-shard worker threads, fleet tick as barrier) must be
//! byte-identical to the sequential merge loop at any worker count.
//! PR 7 adds the chaos sweep: randomized host-fault schedules (crash,
//! degraded NVMe, budget revocation) under which every invariant must
//! still hold — Σ budgets stepping down by exactly each dead host's
//! budget — with no VM lost and the same worker-count byte-identity.
//! PR 9 adds the remote-memory marketplace gates: lease formation on
//! the pressured static-placement fleet, chaos seeds with leases armed
//! (donor crashes drop staged entries, consumer crashes return the
//! full escrow), and seq/par byte-identity with the marketplace and
//! random fault plans armed together. PR 10 adds the clone-storm
//! gates: image-backed clones admitted at the fleet-tick barrier must
//! all land and finish, beat the cold-boot arm on
//! time-to-first-useful-work, dedup the golden image, survive chaos
//! schedules (including the crash of an image-holding host), and stay
//! byte-identical across engines and worker counts — every run through
//! the ONE unified `run_sharded_fleet(…, &FleetRunOpts)` entry point.

use std::sync::{Arc, Mutex};

use flexswap::config::{
    ArbiterKind, ControlConfig, FleetConfig, HostConfig, HostFault, HostFaultKind, MmConfig,
    PlacementPolicy, TierConfig, VmConfig,
};
use flexswap::coordinator::{Machine, Mechanism, VmSetup};
use flexswap::daemon::{Arbiter, FleetScheduler, FleetVmSpec, Sla, VmReport};
use flexswap::harness::fleet::{
    random_fault_plan, run_sharded_fleet, storm_vm_ops, FleetMode, FleetRunOpts, ShardedSummary,
};
use flexswap::mm::{Mm, Policy, PolicyApi, PolicyEvent};
use flexswap::policies::{DtReclaimer, LruReclaimer, NativeAnalytics};
use flexswap::sim::Rng;
use flexswap::types::{GranularityMode, PageSize, MS, SEC};
use flexswap::workloads::{PhasedWss, UniformRandom, Workload};

// ---------------------------------------------------------------------
// Shared invariant checks
// ---------------------------------------------------------------------

/// Engine-selection opts: the old `_exec` positional pair, spelled in
/// the unified builder API.
fn exec_opts(parallel: bool, workers: Option<usize>) -> FleetRunOpts {
    FleetRunOpts::default().with_sequential(!parallel).with_workers(workers)
}

/// (a) Per-shard budget held at every tick, (b) no VM split across
/// shards, (d) migration byte-conservation.
fn assert_fleet_invariants(f: &FleetScheduler, label: &str) {
    // (a) Σ(resident + pool) ≤ budget on every shard at every tick.
    for s in &f.shards {
        let cs = s.machine.control_stats().expect("shard has a control plane");
        assert_eq!(
            cs.budget_exceeded_ticks, 0,
            "{label}: shard {} exceeded its budget",
            s.id
        );
        assert!(
            cs.ticks == 0 || cs.min_headroom_bytes >= 0,
            "{label}: shard {} saw negative headroom {}",
            s.id,
            cs.min_headroom_bytes
        );
    }
    // (b) every admitted VM lives in exactly one shard's control plane.
    let mut names = std::collections::BTreeSet::new();
    for p in &f.placements {
        assert!(names.insert(p.name.clone()), "{label}: duplicate admission {}", p.name);
        let cp = f.shards[p.shard].machine.control().expect("control plane");
        assert_eq!(
            cp.vm_name(p.vm),
            Some(p.name.as_str()),
            "{label}: placement record does not match shard {}",
            p.shard
        );
        for s in &f.shards {
            if s.id != p.shard {
                assert!(
                    s.machine
                        .control()
                        .expect("control plane")
                        .vms
                        .iter()
                        .all(|m| m.name != p.name),
                    "{label}: VM {} split across shards {} and {}",
                    p.name,
                    p.shard,
                    s.id
                );
            }
        }
    }
    let managed: usize = f
        .shards
        .iter()
        .map(|s| s.machine.control().expect("control plane").vms.len())
        .sum();
    assert_eq!(managed, f.placements.len(), "{label}: managed-VM count mismatch");
    // (d) conservation: Σ budgets audited equal at every fleet tick,
    // and migration bytes balance exactly.
    assert_eq!(
        f.stats.conservation_violations, 0,
        "{label}: Σ budgets drifted during the run"
    );
    let total_now: u64 = (0..f.shards.len()).map(|i| f.shard_budget(i)).sum();
    assert_eq!(
        total_now, f.stats.total_budget_bytes,
        "{label}: final Σ budgets differs from the baseline"
    );
    let bytes_in: u64 = f.stats.bytes_in.iter().sum();
    let bytes_out: u64 = f.stats.bytes_out.iter().sum();
    assert_eq!(bytes_in, bytes_out, "{label}: migration bytes not conserved");
    assert_eq!(bytes_in, f.stats.migrated_bytes, "{label}: transfer ledger drift");
    // Atomic hand-off: no flip ever left VM state behind on the donor,
    // and whole-VM arrivals balance departures.
    assert_eq!(f.stats.handoff_violations, 0, "{label}: non-atomic hand-off");
    assert_eq!(
        f.stats.vms_migrated_in.iter().sum::<u64>(),
        f.stats.vms_migrated_out.iter().sum::<u64>(),
        "{label}: whole-VM ledger drift"
    );
    assert_eq!(
        f.stats.vms_migrated_in.iter().sum::<u64>(),
        f.stats.state_migrations_completed,
        "{label}: state-migration count drift"
    );
}

/// The summary-level version of the same checks (harness scenarios).
fn assert_summary_invariants(s: &ShardedSummary, label: &str) {
    assert_eq!(s.conservation_violations, 0, "{label}: budgets drifted");
    assert_eq!(
        s.budget_total_end, s.budget_total_start,
        "{label}: Σ budgets changed"
    );
    assert_eq!(s.handoff_violations, 0, "{label}: non-atomic hand-off");
    for h in &s.per_host {
        assert_eq!(
            h.budget_exceeded_ticks, 0,
            "{label}: host {} exceeded its budget ({} min headroom)",
            h.host, h.min_headroom_bytes
        );
    }
    let b_in: u64 = s.per_host.iter().map(|h| h.bytes_in).sum();
    let b_out: u64 = s.per_host.iter().map(|h| h.bytes_out).sum();
    assert_eq!(b_in, b_out, "{label}: migration bytes not conserved");
    assert_eq!(b_in, s.migrated_bytes, "{label}: transfer ledger drift");
    let v_in: u64 = s.per_host.iter().map(|h| h.vms_in).sum();
    let v_out: u64 = s.per_host.iter().map(|h| h.vms_out).sum();
    assert_eq!(v_in, v_out, "{label}: whole-VM ledger drift");
    assert_eq!(v_in, s.state_migrations_completed, "{label}: flip count drift");
}

// ---------------------------------------------------------------------
// Randomized invariant suite (≥40 seeds)
// ---------------------------------------------------------------------

/// A randomized small fleet: 4 hosts, Bronze VMs with contraction-phase
/// workloads, budget-derived initial limits, arbiter kind and placement
/// cycling with the seed. Returns the scheduler (stats + shards) plus
/// total completed ops and the expected total.
fn run_random_fleet(seed: u64) -> (FleetScheduler, u64, u64) {
    let hosts = 4;
    let mut rng = Rng::new(seed.wrapping_mul(31).wrapping_add(7));
    let kind = [
        ArbiterKind::ProportionalShare,
        ArbiterKind::Watermark,
        ArbiterKind::Static,
    ][(seed % 3) as usize];
    let placement = if seed % 2 == 0 {
        PlacementPolicy::SpreadByFaultRate
    } else {
        PlacementPolicy::FirstFitBySla
    };
    let pool_cap = 2 * 1024 * 1024;
    let template = HostConfig {
        seed,
        tier: TierConfig { pool_capacity_bytes: pool_cap, ..Default::default() },
        ..Default::default()
    };
    let budgets: Vec<u64> = (0..hosts).map(|_| (8 + rng.below(10)) << 20).collect();
    let cfg = FleetConfig {
        hosts,
        host_budgets: budgets.clone(),
        placement,
        interval: 20 * MS,
        migration: true,
        // A quarter of the random fleets also arm full VM migration:
        // their tight random budgets mostly exercise the infeasible /
        // abort paths, which must hold the invariants too.
        state_migration: seed % 4 == 3,
        migrate_pf_delta_min: 8,
        pressure_demand_pct: 102,
        donor_demand_pct: 90,
        migration_max_bytes: 8 << 20,
        migration_min_chunk: 128 << 10,
        migration_margin_bytes: 64 << 10,
        migration_stall_ticks: 5,
        max_active_migrations: 2,
        control: ControlConfig { interval: 10 * MS, kind, ..Default::default() },
        max_time: 30 * SEC,
        ..Default::default()
    };
    let mut f = FleetScheduler::new(&template, cfg);
    let n = 8 + rng.below(5) as usize;
    let mut expected_ops = 0u64;
    for i in 0..n {
        let frames = 1024u64 << rng.below(2); // 4 or 8 MB VMs
        let pages = frames - 256;
        // Even, so the two phases sum to exactly `ops`.
        let ops = 2 * (1_250 + rng.below(1_250));
        expected_ops += ops;
        let w: Box<dyn Workload> = Box::new(PhasedWss::with_cost(
            vec![(pages, ops / 2), (pages / 4, ops / 2)],
            15_000,
        ));
        f.admit(FleetVmSpec {
            name: format!("vm{i}"),
            sla: Sla::Bronze,
            frames,
            vcpus: 1,
            workloads: vec![w],
            initial_limit_bytes: None, // budget-safe fix-up below
            mm: Some(MmConfig {
                swapper_threads: 4,
                scan_interval: 40 * MS,
                history: 6,
                target_promotion_rate: 0.002,
                ..Default::default()
            }),
        });
    }
    // Budget-derived initial limits: Σ limits ≤ usable per shard, so
    // invariant (a) holds from t = 0 under every arbiter kind.
    let by_shard: Vec<(usize, usize)> =
        f.placements.iter().map(|p| (p.shard, p.vm)).collect();
    for h in 0..hosts {
        let members: Vec<usize> =
            by_shard.iter().filter(|&&(s, _)| s == h).map(|&(_, v)| v).collect();
        if members.is_empty() {
            continue;
        }
        let inflight: u64 = members
            .iter()
            .map(|&v| {
                let mm = f.shards[h].machine.mm(v).expect("sys VM");
                mm.swapper.threads() as u64 * mm.core.unit_bytes
            })
            .sum();
        let usable = budgets[h].saturating_sub(pool_cap).saturating_sub(inflight);
        let share = usable / members.len() as u64;
        for &v in &members {
            let mm = f.shards[h].machine.mm_mut(v).expect("sys VM");
            mm.core.limit_units = Some((share / mm.core.unit_bytes).max(1));
        }
    }
    let results = f.run();
    let done_ops: u64 = results.iter().flatten().map(|r| r.work_ops).sum();
    (f, done_ops, expected_ops)
}

/// The ≥40-seed sweep: odd seeds run the randomized fleets with
/// arbiter-kind / placement / state-migration cycling; even seeds run
/// the pressure-skewed harness scenario — `seed % 8 == 0` at the scale
/// where full VM state migration triggers (every such run must
/// complete ≥ 1 flip), the rest alternating lease-only and static.
/// Invariants (a), (b) and (d) must hold on every one, mid-migration
/// ticks included.
#[test]
fn invariants_hold_across_forty_seeds() {
    for seed in 0..40u64 {
        if seed % 8 == 0 {
            // Full state migration at trigger scale: 4 hosts × 8 VMs,
            // host 0 pressure-starved.
            let s = run_sharded_fleet(
                4,
                8,
                12_000,
                FleetMode::StateMigration,
                seed,
                &FleetRunOpts::default(),
            );
            assert_eq!(
                s.total_ops,
                s.vms as u64 * 12_000,
                "seed {seed}: sharded fleet incomplete"
            );
            assert_summary_invariants(&s, &format!("seed {seed} (state)"));
            assert!(
                s.state_migrations_completed >= 1,
                "seed {seed}: no state migration completed: {s:?}"
            );
            assert!(
                s.state_stop_ns_max > 0,
                "seed {seed}: flip recorded no stop time"
            );
        } else if seed % 2 == 0 {
            // Harness scenario, shrunk: 4 hosts × 3 VMs, lease/static.
            let mode = if seed % 8 == 2 {
                FleetMode::LeaseOnly
            } else {
                FleetMode::StaticPlacement
            };
            let s = run_sharded_fleet(4, 3, 6_000, mode, seed, &FleetRunOpts::default());
            assert_eq!(
                s.total_ops,
                s.vms as u64 * 6_000,
                "seed {seed}: sharded fleet incomplete"
            );
            assert_summary_invariants(&s, &format!("seed {seed}"));
            if mode == FleetMode::StaticPlacement {
                assert_eq!(s.migrated_bytes, 0, "seed {seed}: static arm migrated");
            }
            assert_eq!(
                s.state_migrations_started, 0,
                "seed {seed}: lease arm moved a VM"
            );
        } else {
            let (f, done, expected) = run_random_fleet(seed);
            assert_eq!(done, expected, "seed {seed}: random fleet incomplete");
            assert_fleet_invariants(&f, &format!("seed {seed}"));
        }
    }
}

/// (c) Determinism: the same-seed 4-host fleet is bit-identical — the
/// whole summary (per-host occupancy averages, migration ledger, fault
/// counts, stall percentiles) compares equal, and since the experiment
/// CSV is a pure function of the summary, the CSV is identical too.
#[test]
fn same_seed_four_host_fleet_is_bit_identical() {
    let opts = FleetRunOpts::default();
    let a = run_sharded_fleet(4, 8, 10_000, FleetMode::LeaseOnly, 42, &opts);
    let b = run_sharded_fleet(4, 8, 10_000, FleetMode::LeaseOnly, 42, &opts);
    assert_eq!(a, b, "same-seed sharded fleet runs diverged");
    assert_eq!(a.hosts, 4);
    assert_eq!(a.vms, 32);
    // And a second seed on the static arm, for the no-migration path.
    let c = run_sharded_fleet(4, 4, 6_000, FleetMode::StaticPlacement, 9, &opts);
    let d = run_sharded_fleet(4, 4, 6_000, FleetMode::StaticPlacement, 9, &opts);
    assert_eq!(c, d, "same-seed static-placement runs diverged");
    // The full state-migration path — pre-copy staging, stop-and-copy
    // flip, event hand-off — must be bit-identical too: the whole
    // summary (including the stop-time and byte ledgers) compares
    // equal, so the experiment CSV is identical.
    let e = run_sharded_fleet(4, 8, 12_000, FleetMode::StateMigration, 42, &opts);
    let g = run_sharded_fleet(4, 8, 12_000, FleetMode::StateMigration, 42, &opts);
    assert_eq!(e, g, "same-seed state-migration runs diverged");
    assert!(e.state_migrations_completed >= 1, "nothing migrated: {e:?}");
}

/// Acceptance: on the pressure-skewed fleet, the fault-rate-delta
/// rebalancer completes real migrations and yields fewer total major
/// faults than static placement, with no loss in Σ saved memory
/// (occupancy tracks the conserved Σ budgets because every shard stays
/// limit-bound; 0.5% covers measurement noise).
#[test]
fn rebalancer_beats_static_placement() {
    let opts = FleetRunOpts::default();
    let st = run_sharded_fleet(4, 8, 16_000, FleetMode::StaticPlacement, 7, &opts);
    let rb = run_sharded_fleet(4, 8, 16_000, FleetMode::LeaseOnly, 7, &opts);
    assert_eq!(st.total_ops, rb.total_ops, "arms did different work");
    assert_eq!(st.migrated_bytes, 0);
    assert!(
        rb.migrations_completed >= 1 && rb.migrated_bytes > 0,
        "rebalancer never migrated: {rb:?}"
    );
    assert!(
        rb.total_majors < st.total_majors,
        "rebalancer did not cut major faults: {} vs {}",
        rb.total_majors,
        st.total_majors
    );
    assert!(
        rb.avg_fleet_bytes <= st.avg_fleet_bytes * 1.005,
        "rebalancer lost saved memory: {:.0} vs {:.0}",
        rb.avg_fleet_bytes,
        st.avg_fleet_bytes
    );
    // The pressured host is where the migrated budget landed.
    assert!(
        rb.per_host[0].budget_end > rb.per_host[0].budget_start,
        "host 0 received no budget: {:?}",
        rb.per_host[0]
    );
}

/// Acceptance (PR 5): on the same pressure-skewed fleet, **full VM
/// state migration** completes at least one flip and beats the
/// lease-only rebalancer on total major faults or on fleet occupancy —
/// moving the whole VM removes its entire demand from the starved
/// host, where a lease can only move what donors prove free. Both arms
/// must hold every invariant; the state arm's budgets only move if its
/// lease *fallback* fired (Σ is conserved either way).
#[test]
fn state_migration_beats_lease_only() {
    let opts = FleetRunOpts::default();
    let lease = run_sharded_fleet(4, 8, 16_000, FleetMode::LeaseOnly, 7, &opts);
    let state = run_sharded_fleet(4, 8, 16_000, FleetMode::StateMigration, 7, &opts);
    assert_eq!(lease.total_ops, state.total_ops, "arms did different work");
    assert_summary_invariants(&lease, "lease arm");
    assert_summary_invariants(&state, "state arm");
    assert!(
        state.state_migrations_completed >= 1 && state.state_flip_bytes > 0,
        "no VM ever moved: {state:?}"
    );
    // The flip pause is the brief stop-and-copy, not a stall epoch:
    // bounded by the fixed overhead plus the whole VM over the modeled
    // link (64MB at 10GB/s ≈ 6.4ms ≫ any real flip here).
    assert!(
        state.state_stop_ns_max > 0 && state.state_stop_ns_max < 50_000_000,
        "implausible stop time: {}",
        state.state_stop_ns_max
    );
    // The pressured host shipped at least one VM away.
    assert!(
        state.per_host[0].vms_out >= 1,
        "host 0 kept all its VMs: {:?}",
        state.per_host[0]
    );
    assert!(
        state.total_majors < lease.total_majors
            || state.avg_fleet_bytes < lease.avg_fleet_bytes,
        "full migration beat lease-only on neither majors ({} vs {}) nor \
         occupancy ({:.0} vs {:.0})",
        state.total_majors,
        lease.total_majors,
        state.avg_fleet_bytes,
        lease.avg_fleet_bytes
    );
}

// ---------------------------------------------------------------------
// Chaos sweep: randomized host-fault schedules (PR 7 tentpole gate)
// ---------------------------------------------------------------------

/// The fault-run version of [`assert_summary_invariants`]: Σ budgets
/// may legitimately shrink, but only by exactly what crashes and
/// revocations retired — never by drift.
fn assert_chaos_summary_invariants(s: &ShardedSummary, label: &str) {
    assert_eq!(s.conservation_violations, 0, "{label}: budgets drifted");
    assert_eq!(
        s.budget_total_end + s.budget_retired_bytes,
        s.budget_total_start,
        "{label}: Σ budgets did not step down by exactly the retired amount"
    );
    assert_eq!(s.handoff_violations, 0, "{label}: non-atomic hand-off");
    for h in &s.per_host {
        assert_eq!(
            h.budget_exceeded_ticks, 0,
            "{label}: host {} exceeded its budget ({} min headroom)",
            h.host, h.min_headroom_bytes
        );
    }
    assert_eq!(
        s.crashes + s.degrades + s.revocations,
        s.faults_injected,
        "{label}: fault ledger drift"
    );
}

/// The chaos sweep: ≥40 seeds, each with its own randomized host-fault
/// schedule (up to one crash / degraded-NVMe / budget-revocation per
/// host, timed inside the run's compute span), alternating the
/// state-migration and lease-only recovery paths. Every seed must (a)
/// hold each shard's budget at every tick — mid-evacuation and
/// mid-rebuild included, (b) finish every VM's work (a VM whose pages
/// reached NVMe is never lost to a crash), and (c) conserve Σ budgets
/// less exactly the retired dead-host/revoked amounts.
#[test]
fn chaos_invariants_hold_across_forty_random_fault_seeds() {
    let (hosts, per_host, ops) = (4usize, 3usize, 6_000u64);
    let (mut crashes, mut degrades, mut revocations) = (0u64, 0u64, 0u64);
    for seed in 0..44u64 {
        let plan = random_fault_plan(hosts, ops, seed);
        let mode = if seed % 2 == 0 {
            FleetMode::StateMigration
        } else {
            FleetMode::LeaseOnly
        };
        let label = format!("chaos seed {seed} ({mode:?})");
        let s = run_sharded_fleet(
            hosts,
            per_host,
            ops,
            mode,
            seed,
            &FleetRunOpts::default().with_faults(plan.clone()),
        );
        assert_eq!(s.vms, hosts * per_host, "{label}: admission lost a VM");
        assert_eq!(
            s.total_ops,
            s.vms as u64 * ops,
            "{label}: a VM lost work to a fault"
        );
        assert_chaos_summary_invariants(&s, &label);
        // Every planned fault fired (the plan targets each host at most
        // once, so none is ever skipped as already-dead).
        assert_eq!(
            s.faults_injected,
            plan.len() as u64,
            "{label}: schedule not fully injected"
        );
        let planned_crashes =
            plan.iter().filter(|f| f.kind == HostFaultKind::Crash).count() as u64;
        assert_eq!(s.crashes, planned_crashes, "{label}: crash count drift");
        if s.crashes == 0 {
            assert_eq!(s.vms_rebuilt, 0, "{label}: rebuild without a crash");
            if s.revocations == 0 {
                // Only crashes and revocations may retire budget.
                assert_eq!(
                    s.budget_retired_bytes, 0,
                    "{label}: budget retired without a crash or revocation"
                );
            }
        } else {
            // A dead host's budget reads zero afterwards; something was
            // retired for every crash.
            assert!(
                s.budget_retired_bytes > 0,
                "{label}: crash retired no budget"
            );
        }
        if s.degrades == 0 {
            assert_eq!(s.drains_started, 0, "{label}: drain without a degrade");
        }
        crashes += s.crashes;
        degrades += s.degrades;
        revocations += s.revocations;
    }
    // The sweep as a whole exercised every fault kind.
    assert!(
        crashes > 0 && degrades > 0 && revocations > 0,
        "sweep never exercised all fault kinds: {crashes}c/{degrades}d/{revocations}r"
    );
}

/// Worker-count byte-identity with faults armed: a fixed three-kind
/// schedule (drain host 1, then crash host 2 mid-drain, then revoke
/// host 3) on the pressure-skewed state-migration fleet must produce
/// the same bytes from the sequential merge oracle and the epoch
/// engine at 1, 2, and `available_parallelism` workers. Fault
/// injection, evacuation, and crash rebuild all happen at fleet ticks
/// — single-threaded barriers in both engines — so the shard set
/// changing size mid-run must not perturb determinism.
#[test]
fn chaos_same_seed_bit_identical_across_worker_counts() {
    let faults = vec![
        HostFault { at: 60 * MS, host: 1, kind: HostFaultKind::DegradedNvme },
        HostFault { at: 100 * MS, host: 2, kind: HostFaultKind::Crash },
        HostFault { at: 150 * MS, host: 3, kind: HostFaultKind::BudgetRevoke },
    ];
    let base = run_sharded_fleet(
        4,
        8,
        12_000,
        FleetMode::StateMigration,
        0,
        &exec_opts(false, None).with_faults(faults.clone()),
    );
    assert_eq!(
        (base.crashes, base.degrades, base.revocations),
        (1, 1, 1),
        "schedule did not inject all three kinds: {base:?}"
    );
    assert!(base.vms_rebuilt >= 1, "the crash rebuilt nothing: {base:?}");
    assert_eq!(base.total_ops, base.vms as u64 * 12_000, "fleet lost work");
    assert_chaos_summary_invariants(&base, "chaos oracle");
    for workers in [Some(1), Some(2), None] {
        let par = run_sharded_fleet(
            4,
            8,
            12_000,
            FleetMode::StateMigration,
            0,
            &exec_opts(true, workers).with_faults(faults.clone()),
        );
        assert_eq!(base, par, "workers {workers:?} changed the faulted output");
        assert_eq!(
            format!("{base:?}"),
            format!("{par:?}"),
            "workers {workers:?}: debug render differs despite Eq — float bit drift"
        );
    }
    // And the same engine equivalence under randomized schedules, at
    // the smaller sweep scale.
    let mut injected = 0u64;
    for seed in [3u64, 11, 27] {
        let plan = random_fault_plan(4, 6_000, seed);
        let seq = run_sharded_fleet(
            4,
            4,
            6_000,
            FleetMode::StateMigration,
            seed,
            &exec_opts(false, None).with_faults(plan.clone()),
        );
        let par = run_sharded_fleet(
            4,
            4,
            6_000,
            FleetMode::StateMigration,
            seed,
            &exec_opts(true, Some(2)).with_faults(plan.clone()),
        );
        assert_eq!(seq, par, "chaos seed {seed}: engines diverged under faults");
        assert_chaos_summary_invariants(&seq, &format!("chaos seed {seed}"));
        injected += seq.faults_injected;
    }
    assert!(injected > 0, "all three random plans were empty");
}

/// Mixed-granularity chaos seeds (PR 8 satellite): VMs cycling through
/// strict-4k, huge, and auto granularity share each shard while the
/// randomized fault schedule crashes/drains/revokes hosts around them.
/// Salvage and rebuild must preserve per-VM granularity state (a split
/// region's per-4k receipts stay per-4k across a crash), every VM must
/// finish its work, the chaos budget/conservation invariants must hold,
/// and the seq/par engines must stay bit-identical.
#[test]
fn chaos_mixed_granularity_seeds_hold_invariants() {
    let (hosts, per_host, ops) = (4usize, 3usize, 6_000u64);
    let mix = [
        GranularityMode::Fixed,
        GranularityMode::Huge,
        GranularityMode::Auto,
    ];
    for seed in [5u64, 13, 29] {
        let plan = random_fault_plan(hosts, ops, seed);
        let label = format!("chaos mixed-granularity seed {seed}");
        let s = run_sharded_fleet(
            hosts,
            per_host,
            ops,
            FleetMode::StateMigration,
            seed,
            &FleetRunOpts::default()
                .with_granularity(mix.to_vec())
                .with_faults(plan.clone()),
        );
        assert_eq!(s.vms, hosts * per_host, "{label}: admission lost a VM");
        assert_eq!(
            s.total_ops,
            s.vms as u64 * ops,
            "{label}: a VM lost work to a fault"
        );
        assert_chaos_summary_invariants(&s, &label);
        let seq = run_sharded_fleet(
            hosts,
            per_host,
            ops,
            FleetMode::StateMigration,
            seed,
            &exec_opts(false, None)
                .with_granularity(mix.to_vec())
                .with_faults(plan.clone()),
        );
        assert_eq!(s, seq, "{label}: engines diverged");
    }
}

// ---------------------------------------------------------------------
// Remote-memory marketplace (PR 9 tentpole gates)
// ---------------------------------------------------------------------

/// Lease formation and conservation on the canonical marketplace
/// shape: static placement (the marketplace is the only relief
/// channel), host 0 demand-infeasible, donors at 300% of demand so
/// their pools sit empty below the low watermark and real DRAM
/// headroom backs the escrow. Leases must form, staged entries must
/// serve faults from the remote tier, and — because remote escrow is
/// begin/cancel-only — Σ audited budgets must end exactly where they
/// started. Same seed twice must be bit-identical (lease matching is
/// deterministic at the fleet-tick barrier).
#[test]
fn remote_marketplace_forms_leases_and_conserves_budgets() {
    let label = "remote marketplace";
    let run = || {
        run_sharded_fleet(
            4,
            8,
            16_000,
            FleetMode::StaticPlacement,
            7,
            &FleetRunOpts::default().with_remote(true).with_donor_pct(300),
        )
    };
    let s = run();
    assert_eq!(s.total_ops, s.vms as u64 * 16_000, "{label}: fleet lost work");
    assert!(s.remote_leases >= 1, "{label}: no lease ever matched: {s:?}");
    assert!(s.remote_staged_bytes > 0, "{label}: leases staged nothing");
    assert!(
        s.remote_hits > 0,
        "{label}: no fault was ever served from the remote tier"
    );
    assert!(
        s.remote_staged_bytes <= s.remote_leased_bytes,
        "{label}: staged more than the granted leases"
    );
    // No faults armed: nothing may be dropped, and every invariant of
    // the fault-free suite (including exact Σ-budget equality) holds
    // with leases in flight and dissolved at the final barrier.
    assert_eq!(s.remote_dropped_bytes, 0, "{label}: drops without a crash");
    assert_summary_invariants(&s, label);
    let again = run();
    assert_eq!(s, again, "{label}: same seed diverged");
}

/// Chaos seeds with remote leases armed: randomized fault schedules
/// over the marketplace fleet. A donor crash drops the staged entries
/// (the consumer re-faults them as cold NVMe misses — reported in the
/// dropped ledger) and returns the escrow; a consumer crash dissolves
/// the lease donor-side. Either way the budget audit must stay clean:
/// Σ budgets step down by exactly the retired amounts, nothing more.
#[test]
fn remote_marketplace_chaos_seeds_hold_invariants() {
    let (hosts, per_host, ops) = (4usize, 4usize, 12_000u64);
    let mut leases = 0u64;
    for seed in 0..10u64 {
        let plan = random_fault_plan(hosts, ops, seed);
        let mode = if seed % 2 == 0 {
            FleetMode::StateMigration
        } else {
            FleetMode::LeaseOnly
        };
        let label = format!("remote chaos seed {seed} ({mode:?})");
        let s = run_sharded_fleet(
            hosts,
            per_host,
            ops,
            mode,
            seed,
            &FleetRunOpts::default()
                .with_remote(true)
                .with_donor_pct(300)
                .with_faults(plan.clone()),
        );
        assert_eq!(s.vms, hosts * per_host, "{label}: admission lost a VM");
        assert_eq!(
            s.total_ops,
            s.vms as u64 * ops,
            "{label}: a VM lost work to a fault"
        );
        assert_chaos_summary_invariants(&s, &label);
        if s.crashes == 0 {
            assert_eq!(
                s.remote_dropped_bytes, 0,
                "{label}: remote drops without a crash"
            );
        }
        leases += s.remote_leases;
    }
    assert!(leases > 0, "the remote chaos sweep never formed a lease");
}

/// Seq/par byte-identity with the marketplace AND random fault plans
/// armed together: lease matching, paced revocation, crash-time drops,
/// and the final-barrier cancellation all run at the fleet tick — a
/// single-threaded barrier in both engines — so the output must be
/// bit-identical from the merge oracle and the epoch engine at 1, 2,
/// and `available_parallelism` workers.
#[test]
fn remote_marketplace_seq_par_byte_identical_across_worker_counts() {
    for seed in [2u64, 9] {
        let plan = random_fault_plan(4, 12_000, seed);
        let base = run_sharded_fleet(
            4,
            4,
            12_000,
            FleetMode::StateMigration,
            seed,
            &exec_opts(false, None)
                .with_remote(true)
                .with_donor_pct(300)
                .with_faults(plan.clone()),
        );
        assert_chaos_summary_invariants(&base, &format!("remote seq seed {seed}"));
        for workers in [Some(1), Some(2), None] {
            let par = run_sharded_fleet(
                4,
                4,
                12_000,
                FleetMode::StateMigration,
                seed,
                &exec_opts(true, workers)
                    .with_remote(true)
                    .with_donor_pct(300)
                    .with_faults(plan.clone()),
            );
            assert_eq!(
                base, par,
                "remote seed {seed} workers {workers:?}: engines diverged"
            );
            assert_eq!(
                format!("{base:?}"),
                format!("{par:?}"),
                "remote seed {seed} workers {workers:?}: debug render differs \
                 despite Eq — float bit drift"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Clone-from-image boot storms (PR 10 tentpole gates)
// ---------------------------------------------------------------------

/// A small storm: `clones` image-backed + `cold` cold-boot VMs staged
/// on top of the base fleet, admitted at fleet ticks.
fn storm_opts(clones: usize, cold: usize) -> FleetRunOpts {
    FleetRunOpts::default().with_storm(clones, cold)
}

/// Tentpole acceptance: every staged storm VM is admitted and finishes
/// its boot workload; image-backed clones strictly beat cold boots on
/// time-to-first-useful-work p99 (their boot faults decompress shared
/// pool entries where a cold boot pays full NVMe zero-fill); the
/// golden image dedups across clones sharing a host; first guest
/// writes break CoW; and Σ budgets are exactly conserved with the
/// storm armed.
#[test]
fn clone_storm_admits_all_beats_cold_and_conserves_budgets() {
    let opts = storm_opts(12, 4);
    let s = run_sharded_fleet(4, 3, 6_000, FleetMode::StaticPlacement, 7, &opts);
    assert_eq!(s.clones_staged, 16, "not every storm VM was staged");
    assert_eq!(s.clones_admitted, 12, "not every clone was admitted");
    assert_eq!(s.clone_cold_boots, 4, "not every cold boot was admitted");
    let storm_ops = 16 * storm_vm_ops(&opts.clone);
    assert_eq!(
        s.total_ops,
        s.vms as u64 * 6_000 + storm_ops,
        "the storm (or the base fleet under it) lost work"
    );
    assert_summary_invariants(&s, "clone storm");
    assert!(
        s.clone_first_work_p99_ns < s.cold_first_work_p99_ns,
        "image-backed clones did not beat cold boots on \
         time-to-first-useful-work p99: {} vs {} ns",
        s.clone_first_work_p99_ns,
        s.cold_first_work_p99_ns
    );
    assert!(
        s.image_dedup_ratio() > 1.0,
        "golden image did not dedup: {:.2}",
        s.image_dedup_ratio()
    );
    assert!(s.image_hits > 0, "no boot fault was served from the image");
    assert!(
        s.image_cow_breaks > 0,
        "guest writes never broke image CoW"
    );
    // The storm landed somewhere, and spread placement lands it on
    // more than one host at this scale.
    let holding = s.clones_per_host.iter().filter(|&&c| c > 0).count();
    assert!(holding > 1, "spread placement packed every clone: {:?}", s.clones_per_host);
}

/// Engine/worker byte-identity with a storm armed (the PR 6 gate
/// extended to PR 10): clone admission happens only at the fleet-tick
/// barrier, so the sequential merge oracle and the epoch engine at 1,
/// 2, and `available_parallelism` workers must produce the same bytes.
#[test]
fn clone_storm_byte_identical_across_engines_and_worker_counts() {
    let base = run_sharded_fleet(
        4,
        2,
        4_000,
        FleetMode::StaticPlacement,
        3,
        &storm_opts(8, 2).with_sequential(true),
    );
    assert_eq!(base.clones_admitted, 8, "oracle run admitted too few clones");
    for workers in [Some(1), Some(2), None] {
        let par = run_sharded_fleet(
            4,
            2,
            4_000,
            FleetMode::StaticPlacement,
            3,
            &storm_opts(8, 2).with_workers(workers),
        );
        assert_eq!(base, par, "workers {workers:?} changed the storm output");
        assert_eq!(
            format!("{base:?}"),
            format!("{par:?}"),
            "workers {workers:?}: debug render differs despite Eq — float bit drift"
        );
    }
}

/// Chaos seeds with storms armed: randomized host-fault schedules over
/// a fleet mid-boot-storm. Crashed hosts' clones re-land on survivors
/// (the golden image re-installs there and salvaged private CoW pages
/// still win on reads), no VM — storm or base — loses work, and Σ
/// budgets step down by exactly the retired amounts. Engines must
/// still agree byte-for-byte.
#[test]
fn clone_storm_chaos_seeds_hold_invariants() {
    for seed in [1u64, 6, 17] {
        let plan = random_fault_plan(4, 6_000, seed);
        let mode = if seed % 2 == 0 {
            FleetMode::StateMigration
        } else {
            FleetMode::LeaseOnly
        };
        let label = format!("storm chaos seed {seed} ({mode:?})");
        let opts = storm_opts(8, 2).with_faults(plan.clone());
        let s = run_sharded_fleet(4, 3, 6_000, mode, seed, &opts);
        assert_eq!(
            s.clones_admitted + s.clone_cold_boots,
            10,
            "{label}: a storm VM was never admitted"
        );
        assert_eq!(
            s.total_ops,
            s.vms as u64 * 6_000 + 10 * storm_vm_ops(&opts.clone),
            "{label}: a VM lost work to a fault"
        );
        assert_chaos_summary_invariants(&s, &label);
        let seq = run_sharded_fleet(4, 3, 6_000, mode, seed, &opts.clone().with_sequential(true));
        assert_eq!(s, seq, "{label}: engines diverged");
    }
}

/// Targeted crash of the image-holding host: pack piles every clone
/// (and the only golden-image copy) onto one host, then that host
/// crashes mid-run. Every clone must re-land on a survivor — which
/// re-installs the image and re-attaches before resuming — and finish
/// its boot work, with the image present somewhere at the end.
#[test]
fn crash_of_image_holding_host_salvages_clones_on_survivors() {
    let faults = vec![HostFault { at: 110 * MS, host: 0, kind: HostFaultKind::Crash }];
    let opts = storm_opts(6, 0).with_pack(true).with_faults(faults);
    let s = run_sharded_fleet(4, 3, 6_000, FleetMode::LeaseOnly, 5, &opts);
    assert_eq!(s.crashes, 1, "the crash never fired");
    assert!(s.vms_rebuilt >= 1, "the crash rebuilt nothing: {s:?}");
    assert_eq!(s.clones_admitted, 6, "not every clone was admitted");
    assert_eq!(
        s.total_ops,
        s.vms as u64 * 6_000 + 6 * storm_vm_ops(&opts.clone),
        "a clone lost work to the crash"
    );
    assert_chaos_summary_invariants(&s, "image-host crash");
    assert!(
        s.image_stored_bytes > 0,
        "no golden image survived the crash"
    );
    // Dead hosts hold nothing: every clone sits on a live survivor.
    assert_eq!(s.clones_per_host[0], 0, "a clone still counts on the dead host");
    assert_eq!(
        s.clones_per_host.iter().sum::<usize>(),
        6,
        "clone placement ledger drift: {:?}",
        s.clones_per_host
    );
}

// ---------------------------------------------------------------------
// Parallel epoch engine ≡ sequential merge loop (PR 6 tentpole gate)
// ---------------------------------------------------------------------

/// One seq/par pair at identical parameters: the summaries must compare
/// equal field-for-field AND render byte-identically (`Debug` covers
/// every float bit pattern; the experiment CSV is a pure function of
/// the summary, so byte-equal summaries mean byte-equal CSV).
fn assert_engines_agree(
    hosts: usize,
    per_host: usize,
    ops: u64,
    mode: FleetMode,
    seed: u64,
    workers: Option<usize>,
) -> ShardedSummary {
    let seq = run_sharded_fleet(hosts, per_host, ops, mode, seed, &exec_opts(false, None));
    let par = run_sharded_fleet(hosts, per_host, ops, mode, seed, &exec_opts(true, workers));
    assert_eq!(
        seq, par,
        "seed {seed} mode {:?} workers {workers:?}: epoch engine diverged from merge loop",
        mode
    );
    assert_eq!(
        format!("{seq:?}"),
        format!("{par:?}"),
        "seed {seed}: debug render differs despite Eq — float bit drift"
    );
    par
}

/// Tentpole acceptance: on lease-only fleets the parallel epoch engine
/// is byte-identical to the sequential merge loop across ten seeds.
#[test]
fn parallel_epoch_engine_matches_merge_lease_only_ten_seeds() {
    for seed in 0..10u64 {
        let s = assert_engines_agree(4, 4, 6_000, FleetMode::LeaseOnly, seed, None);
        assert_eq!(s.total_ops, s.vms as u64 * 6_000, "seed {seed}: incomplete run");
        assert_summary_invariants(&s, &format!("seed {seed} (parallel lease)"));
    }
}

/// Tentpole acceptance: same equivalence with full VM state migration
/// armed. Seeds 0 and 8 run at the pressure-skewed scale where flips
/// are known to complete — pre-copy staging, stop-and-copy, and the
/// end-of-run abort barrier all execute on worker threads and must
/// still match the merge loop bit-for-bit.
#[test]
fn parallel_epoch_engine_matches_merge_state_migration_ten_seeds() {
    for seed in 0..10u64 {
        let (per_host, ops) = if seed % 8 == 0 { (8, 12_000) } else { (4, 6_000) };
        let s = assert_engines_agree(4, per_host, ops, FleetMode::StateMigration, seed, None);
        assert_summary_invariants(&s, &format!("seed {seed} (parallel state)"));
        if seed % 8 == 0 {
            assert!(
                s.state_migrations_completed >= 1,
                "seed {seed}: flip scale completed no migration: {s:?}"
            );
        }
    }
}

/// Thread-count independence: 1 worker, 2 workers, and the default
/// (`available_parallelism`) all produce the same bytes as the
/// sequential oracle. The worker count partitions shards differently
/// (`chunks_mut`), so this also pins partitioning-independence.
#[test]
fn parallel_worker_count_does_not_change_output() {
    let base = run_sharded_fleet(
        4,
        8,
        12_000,
        FleetMode::StateMigration,
        0,
        &exec_opts(false, None),
    );
    assert!(
        base.state_migrations_completed >= 1,
        "baseline completed no migration: {base:?}"
    );
    for workers in [Some(1), Some(2), None] {
        let par = run_sharded_fleet(
            4,
            8,
            12_000,
            FleetMode::StateMigration,
            0,
            &exec_opts(true, workers),
        );
        assert_eq!(base, par, "workers {workers:?} changed the output");
        assert_eq!(
            format!("{base:?}"),
            format!("{par:?}"),
            "workers {workers:?}: debug render differs"
        );
    }
}

// ---------------------------------------------------------------------
// Arbiter oracle (brute-force reference solver, ≤6 VMs)
// ---------------------------------------------------------------------

/// Reference proportional-share solver: the spec recomputed the
/// straightforward way with fresh allocations per call — floors and
/// demands first, weighted surplus when feasible, class-by-class
/// squeeze (Bronze → Silver → Gold) with largest-remainder settling
/// when not. Asserted equal to the incremental solver, which reuses
/// scratch buffers across calls (the bug class this oracle hunts).
fn oracle_proportional(reports: &[VmReport], usable: u64) -> Vec<u64> {
    let n = reports.len();
    let demands: Vec<u64> = reports.iter().map(Arbiter::demand_of).collect();
    let floors: Vec<u64> = reports.iter().map(Arbiter::floor_of).collect();
    let total_demand: u64 = demands.iter().sum();
    if total_demand <= usable {
        let surplus = usable - total_demand;
        let total_w: u64 = reports.iter().map(|r| r.sla.weight()).sum();
        return (0..n)
            .map(|i| {
                let extra = if total_w == 0 {
                    0
                } else {
                    (surplus as u128 * reports[i].sla.weight() as u128
                        / total_w as u128) as u64
                };
                demands[i] + extra
            })
            .collect();
    }
    let mut limits = demands;
    let mut deficit = total_demand - usable;
    for class in [Sla::Bronze, Sla::Silver, Sla::Gold] {
        if deficit == 0 {
            break;
        }
        let idx: Vec<usize> = (0..n).filter(|&i| reports[i].sla == class).collect();
        let reducible: u64 =
            idx.iter().map(|&i| limits[i].saturating_sub(floors[i])).sum();
        if reducible == 0 {
            continue;
        }
        let take = deficit.min(reducible);
        let mut taken = 0u64;
        for &i in &idx {
            let span = limits[i].saturating_sub(floors[i]);
            let cut = (take as u128 * span as u128 / reducible as u128) as u64;
            limits[i] -= cut;
            taken += cut;
        }
        let mut residue = take - taken;
        for &i in &idx {
            if residue == 0 {
                break;
            }
            let span = limits[i].saturating_sub(floors[i]);
            let cut = residue.min(span);
            limits[i] -= cut;
            residue -= cut;
        }
        deficit -= take;
    }
    limits
}

fn random_report(vm: usize, rng: &mut Rng) -> VmReport {
    let sla = [Sla::Gold, Sla::Silver, Sla::Bronze][rng.below(3) as usize];
    let unit_bytes = if rng.chance(0.5) { 4096 } else { 2 << 20 };
    let usage = (1 + rng.below(256)) << 20;
    let wss = usage / (1 + rng.below(4));
    VmReport {
        vm,
        sla,
        usage_bytes: usage,
        wss_bytes: wss,
        cold_estimate_bytes: usage - wss,
        pf_count: rng.below(10_000),
        pf_delta: rng.below(500),
        limit_bytes: if rng.chance(0.8) { Some(usage) } else { None },
        unit_bytes,
        inflight_allowance: (1 + rng.below(8)) * unit_bytes,
    }
}

/// Oracle test (the PR 1 pattern): randomized WSS/SLA mixes on ≤6 VMs,
/// swept from starvation to surplus, against ONE reused arbiter
/// instance — stale scratch state from any previous solve would show up
/// as a mismatch.
#[test]
fn proportional_solver_matches_bruteforce_oracle() {
    let mut arb = Arbiter::new(ArbiterKind::ProportionalShare);
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed.wrapping_mul(97).wrapping_add(3));
        let n = 1 + rng.below(6) as usize;
        let reports: Vec<VmReport> = (0..n).map(|vm| random_report(vm, &mut rng)).collect();
        let total_demand: u64 = reports.iter().map(Arbiter::demand_of).sum();
        for frac in [5u64, 25, 50, 75, 100, 130] {
            let usable = total_demand / 100 * frac;
            let got = arb.proportional_limits(&reports, usable).to_vec();
            let want = oracle_proportional(&reports, usable);
            assert_eq!(
                got, want,
                "seed {seed} frac {frac}: incremental solve diverged from oracle"
            );
            // Reference sanity: the oracle itself obeys the spec.
            assert!(
                want.iter().sum::<u64>() <= usable,
                "seed {seed} frac {frac}: oracle over budget"
            );
            if total_demand <= usable {
                for (i, r) in reports.iter().enumerate() {
                    assert!(
                        want[i] >= Arbiter::demand_of(r),
                        "seed {seed} frac {frac}: feasible solve below demand"
                    );
                }
            } else {
                // Independent closed-form identity: the squeeze removes
                // exactly min(deficit, total reducible slack), so
                // Σ limits == max(usable, Σ floors) — derivable from
                // the spec without mirroring the algorithm.
                let floors_sum: u64 = reports.iter().map(Arbiter::floor_of).sum();
                assert_eq!(
                    want.iter().sum::<u64>(),
                    usable.max(floors_sum),
                    "seed {seed} frac {frac}: squeeze total off the closed form"
                );
                for (i, r) in reports.iter().enumerate() {
                    assert!(
                        want[i] >= Arbiter::floor_of(r),
                        "seed {seed} frac {frac}: VM {i} squeezed below its floor"
                    );
                }
                // Class ordering: a Gold VM below its demand means no
                // Bronze VM retains reducible slack.
                let bronze_slack = reports.iter().enumerate().any(|(i, r)| {
                    r.sla == Sla::Bronze && want[i] > Arbiter::floor_of(r)
                });
                for (i, r) in reports.iter().enumerate() {
                    if r.sla == Sla::Gold && want[i] < Arbiter::demand_of(r) {
                        assert!(
                            !bronze_slack,
                            "seed {seed} frac {frac}: gold squeezed before bronze"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Recovery-mode window regression (PR 3 boost-hint path, end to end)
// ---------------------------------------------------------------------

/// Probe policy: samples `PolicyApi::recovery_mode()` at every scan
/// tick into a shared log (`Arc<Mutex<_>>` because `Policy: Send`).
struct RecoveryProbe {
    log: Arc<Mutex<Vec<(u64, bool)>>>,
}

impl Policy for RecoveryProbe {
    fn name(&self) -> &'static str {
        "recovery-probe"
    }
    fn on_event(&mut self, ev: &PolicyEvent, api: &mut PolicyApi) {
        if let PolicyEvent::ScanBitmap { now, .. } = ev {
            self.log.lock().unwrap().push((*now, api.recovery_mode()));
        }
    }
}

/// `recovery_mode` must read true strictly inside the boost window,
/// false again by the first tick after `recovery_until` expires, and a
/// later non-boost release must NOT re-open the window.
#[test]
fn recovery_window_expires_and_non_boost_release_does_not_reopen() {
    let boost_at = 210 * MS; // off the 20ms scan grid: no tie-order reliance
    let window = 300 * MS;
    let plain_at = 910 * MS;

    let mut m = Machine::new(HostConfig { seed: 5, ..Default::default() });
    m.install_control(ControlConfig {
        recovery_boost_window: window,
        ..Default::default()
    });
    let mm_cfg = MmConfig {
        scan_interval: 20 * MS,
        history: 8,
        memory_limit: Some(1024 * 4096),
        ..Default::default()
    };
    let vm_cfg = VmConfig {
        frames: 4096,
        vcpus: 1,
        page_size: PageSize::Small,
        scramble: 0.0,
        guest_thp_coverage: 1.0,
    };
    let units = vm_cfg.units();
    let mut mm = Mm::new(&mm_cfg, units, 4096, &m.host.sw, m.host.hw.zero_2m_ns);
    mm.add_policy(Box::new(DtReclaimer::new(Box::new(NativeAnalytics::new()), 8, 0.02)));
    let log = Arc::new(Mutex::new(Vec::new()));
    mm.add_policy(Box::new(RecoveryProbe { log: log.clone() }));
    mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
    let vmid = m.add_vm(VmSetup {
        vm_cfg,
        mech: Mechanism::Sys(Box::new(mm)),
        workloads: vec![Box::new(UniformRandom::new(0, 3000, 90_000))],
        scan_interval: Some(20 * MS),
    });
    // Boost-flagged release at 210ms opens (210ms, 510ms); the plain
    // release at 910ms raises the limit again but must not re-open it.
    m.schedule_limit_release(vmid, boost_at, Some(2048 * 4096), true, false);
    m.schedule_limit_release(vmid, plain_at, Some(3000 * 4096), false, false);
    m.run();

    let closes = boost_at + window;
    assert_eq!(
        m.mm(vmid).expect("sys VM").core.recovery_until,
        closes,
        "non-boost release moved the recovery window"
    );
    let samples = log.lock().unwrap().clone();
    assert!(
        samples.iter().any(|&(t, _)| t > boost_at && t < closes),
        "no scan sample inside the boost window"
    );
    assert!(
        samples.iter().any(|&(t, _)| t >= closes),
        "run ended before the window expired"
    );
    assert!(
        samples.iter().any(|&(t, _)| t > plain_at),
        "run ended before the non-boost release"
    );
    for &(t, on) in &samples {
        if t > boost_at && t < closes {
            assert!(on, "recovery_mode false at {t} inside the boost window");
        } else {
            assert!(
                !on,
                "recovery_mode true at {t} outside the ({boost_at}, {closes}) window"
            );
        }
    }
}
