//! Cross-module integration tests + randomized property tests on the
//! coordinator invariants (proptest is not in the offline crate set, so
//! properties are driven by the crate's own deterministic RNG across
//! many seeds — failures print the offending seed).

use flexswap::config::{HostConfig, LinuxConfig, MmConfig, VmConfig};
use flexswap::coordinator::{Machine, Mechanism, VmSetup};
use flexswap::mm::Mm;
use flexswap::policies::{
    DtReclaimer, LinearPf, LruReclaimer, NativeAnalytics, PfMode, WsrPolicy,
};
use flexswap::sim::Rng;
use flexswap::storage::ContentMix;
use flexswap::types::{PageSize, UnitState, MS, SEC};
use flexswap::workloads::{cloud_preset, CloudWorkload, SeqScan, UniformRandom};

fn vm_cfg(frames: u64, mode: PageSize) -> VmConfig {
    VmConfig {
        frames,
        vcpus: 1,
        page_size: mode,
        scramble: 0.05, // fresh-boot allocator (see harness::eval)
        guest_thp_coverage: 1.0,
    }
}

/// Property: under any (seeded) random workload and limit, the MM never
/// exceeds its memory limit by more than the in-flight allowance, all
/// vCPUs finish, and the unit state machine ends consistent with the
/// EPT.
#[test]
fn prop_limit_and_state_consistency() {
    for seed in 0..12u64 {
        let mut outer = Rng::new(seed * 7 + 1);
        let frames = 2048 + outer.below(4096);
        let pages = frames / 2 + outer.below(frames / 3);
        let limit_units = pages / 4 + outer.below(pages / 4) + 8;
        let mode = if outer.chance(0.5) { PageSize::Small } else { PageSize::Huge };
        let limit_bytes = match mode {
            PageSize::Small => limit_units * 4096,
            PageSize::Huge => (limit_units * 4096).max(8 * 2 * 1024 * 1024),
        };
        let mut m = Machine::new(HostConfig { seed, ..Default::default() });
        let mm_cfg = MmConfig {
            memory_limit: Some(limit_bytes),
            scan_interval: 40 * MS,
            history: 8,
            ..Default::default()
        };
        let ops = 20_000 + outer.below(30_000);
        let vmid = m.sys_vm(
            vm_cfg(frames, mode),
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, pages, ops))],
        );
        let res = m.run();
        assert_eq!(res[0].work_ops, ops, "seed {seed}: workload incomplete");

        let mm = m.mm(vmid).unwrap();
        let limit = mm.core.limit_units.unwrap();
        assert!(
            mm.core.usage_units <= limit + mm.swapper.threads() as u64,
            "seed {seed}: usage {} over limit {}",
            mm.core.usage_units,
            limit
        );
        // State machine vs EPT consistency.
        let vm = m.vm_ref(vmid);
        for (u, st) in mm.core.states.iter().enumerate() {
            match st {
                UnitState::Resident => assert!(
                    vm.ept.present(u as u64),
                    "seed {seed}: resident unit {u} not mapped"
                ),
                UnitState::Swapped | UnitState::Untouched | UnitState::Staged => {
                    assert!(
                        !vm.ept.present(u as u64),
                        "seed {seed}: {st:?} unit {u} mapped"
                    )
                }
                _ => {} // in-flight at end of run is fine
            }
        }
        // No stranded waiters (every fault eventually resolved).
        assert!(
            mm.core.waiters.is_empty(),
            "seed {seed}: stranded waiters {:?}",
            mm.core.waiters
        );
    }
}

/// Property: determinism — identical seeds give identical runs across
/// mechanisms and page sizes.
#[test]
fn prop_determinism_across_configs() {
    for seed in [3u64, 17, 91] {
        for mode in [PageSize::Small, PageSize::Huge] {
            let run = || {
                let mut m = Machine::new(HostConfig { seed, ..Default::default() });
                let mm_cfg = MmConfig {
                    scan_interval: 100 * MS,
                    history: 8,
                    memory_limit: Some(4 * 1024 * 1024 * 4),
                    ..Default::default()
                };
                m.sys_vm(
                    vm_cfg(8192, mode),
                    &mm_cfg,
                    vec![Box::new(UniformRandom::new(0, 6000, 40_000))],
                );
                let r = m.run();
                (
                    r[0].runtime,
                    r[0].counters.faults_major,
                    r[0].counters.swapout_ops,
                    r[0].counters.swapin_bytes,
                )
            };
            assert_eq!(run(), run(), "seed {seed} mode {mode:?}");
        }
    }
}

/// The paper's headline: proactive 2M reclamation keeps performance
/// close to no-swapping while saving significant memory on a cold-heavy
/// workload (kafka).
#[test]
fn kafka_2m_saves_memory_without_tanking() {
    let spec = cloud_preset("kafka", 0.5);
    let frames = spec.pages + 1024;
    let run = |reclaim: bool| {
        let mut m = Machine::new(HostConfig::default());
        let mm_cfg = MmConfig {
            scan_interval: if reclaim { 10 * MS } else { 3600 * SEC },
            history: 16,
            ..Default::default()
        };
        let spec = cloud_preset("kafka", 0.5);
        m.sys_vm(
            vm_cfg(frames, PageSize::Huge),
            &mm_cfg,
            vec![Box::new(CloudWorkload::new(spec))],
        );
        let r = m.run();
        (r[0].runtime, r[0].avg_usage_bytes)
    };
    let (rt_base, mem_base) = run(false);
    let (rt_sys, mem_sys) = run(true);
    let perf = rt_base as f64 / rt_sys as f64;
    let saved = 1.0 - mem_sys / mem_base;
    // Scale note (EXPERIMENTS.md): at simulation scale the 2MB unit
    // count is ~1000x smaller than the paper's 128GB VMs, so first-touch
    // scatter into reclaimed hugepages costs relatively more perf than
    // the paper's ~95%; the savings shape (~70%+) holds.
    assert!(perf > 0.20, "perf {perf}");
    assert!(saved > 0.40, "saved {saved}");
}

/// Kernel baseline on the same workload: runs and reclaims under cgroup.
#[test]
fn kernel_baseline_under_cgroup() {
    let mut m = Machine::new(HostConfig::default());
    let lx = LinuxConfig {
        thp: true,
        memory_limit: Some(1024 * 4096),
        ..Default::default()
    };
    m.kernel_vm(
        vm_cfg(8192, PageSize::Small),
        &lx,
        vec![Box::new(UniformRandom::new(0, 4096, 50_000))],
        None,
        200 * MS,
    );
    let res = m.run();
    assert_eq!(res[0].work_ops, 50_000);
    assert!(res[0].counters.swapout_ops > 0);
    // THP coverage degrades when swap splits hugepages (§6.4).
    assert!(res[0].thp_coverage < 1.0);
}

/// WSR end-to-end: recovery after a limit lift is faster with the
/// working-set-restore policy than without (paper Fig 13).
#[test]
fn wsr_speeds_up_recovery() {
    let pages = 6_000u64;
    let run = |wsr: bool| {
        let mut m = Machine::new(HostConfig::default());
        let mm_cfg = MmConfig {
            scan_interval: 100 * MS,
            history: 8,
            memory_limit: Some(pages * 4096 * 3 / 10),
            ..Default::default()
        };
        let cfgv = vm_cfg(pages + 512, PageSize::Small);
        let units = cfgv.units();
        let mut mm = Mm::new(
            &mm_cfg,
            units,
            cfgv.page_size.unit_bytes(),
            &m.host.sw,
            m.host.hw.zero_2m_ns,
        );
        mm.add_policy(Box::new(DtReclaimer::new(
            Box::new(NativeAnalytics::new()),
            8,
            0.02,
        )));
        if wsr {
            mm.add_policy(Box::new(WsrPolicy::new(units)));
        }
        mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
        let vmid = m.add_vm(VmSetup {
            vm_cfg: cfgv,
            mech: Mechanism::Sys(Box::new(mm)),
            workloads: vec![Box::new(UniformRandom::new(0, pages, 400_000))],
            scan_interval: Some(100 * MS),
        });
        m.schedule_limit(vmid, 1 * SEC, None);
        let r = m.run();
        r[0].runtime
    };
    let without = run(false);
    let with = run(true);
    assert!(
        with < without,
        "wsr {with} should beat plain {without}"
    );
}

/// GVA prefetcher end-to-end beats no-prefetch on an aged sequential
/// workload (paper §6.6).
#[test]
fn gva_prefetcher_improves_sequential() {
    let pages = 4_000u64;
    let run = |pf: Option<PfMode>| {
        let mut m = Machine::new(HostConfig::default());
        let mm_cfg = MmConfig {
            scan_interval: 500 * MS,
            memory_limit: Some(pages * 4096 * 3 / 4),
            ..Default::default()
        };
        let cfgv = VmConfig { scramble: 1.0, ..vm_cfg(pages + 512, PageSize::Small) };
        let units = cfgv.units();
        let mut mm = Mm::new(
            &mm_cfg,
            units,
            cfgv.page_size.unit_bytes(),
            &m.host.sw,
            m.host.hw.zero_2m_ns,
        );
        if let Some(mode) = pf {
            mm.add_policy(Box::new(LinearPf::new(mode)));
        }
        mm.set_limit_reclaimer(Box::new(LruReclaimer::new()));
        m.add_vm(VmSetup {
            vm_cfg: cfgv,
            mech: Mechanism::Sys(Box::new(mm)),
            workloads: vec![Box::new(SeqScan::new(pages, 4, 300_000))],
            scan_interval: Some(500 * MS),
        });
        let r = m.run();
        (r[0].runtime, r[0].counters.faults_major)
    };
    let (rt_none, _) = run(None);
    let (rt_gva, majors_gva) = run(Some(PfMode::Gva));
    let (rt_hva, majors_hva) = run(Some(PfMode::Hva));
    assert!(rt_gva < rt_none, "gva {rt_gva} vs none {rt_none}");
    assert!(
        majors_gva * 4 < majors_hva.max(1),
        "gva majors {majors_gva} vs hva {majors_hva}"
    );
    let _ = rt_hva;
}

/// Page locking: DMA-locked units survive aggressive reclamation.
#[test]
fn locked_units_never_swapped() {
    let mut m = Machine::new(HostConfig::default());
    let mm_cfg = MmConfig { scan_interval: 20 * MS, history: 8, ..Default::default() };
    // scramble 0.0: gva == gpa == unit, so we can lock known units.
    let cfgv = VmConfig { scramble: 0.0, ..vm_cfg(4096, PageSize::Small) };
    let vmid = m.sys_vm(
        cfgv,
        &mm_cfg,
        vec![Box::new(UniformRandom::new(0, 1024, 1_500_000))],
    );
    m.prime_resident(vmid, 2048);
    {
        let mm = m.mm_mut(vmid).unwrap();
        for u in 1500..1600u64 {
            mm.core.locks.lock(u);
        }
    }
    let _ = m.run();
    let mm = m.mm(vmid).unwrap();
    for u in 1500..1600usize {
        assert_eq!(
            mm.core.states[u],
            UnitState::Resident,
            "locked unit {u} was reclaimed"
        );
    }
    // Reclamation did happen around the locked range: a cold unlocked
    // unit was swapped while the locked ones survived.
    assert_ne!(mm.core.states[1400], UnitState::Resident, "cold unit kept");
    assert!(mm.core.locks.denied_swapouts > 0, "lock never exercised");
}

/// Tiered storage end to end: a zero-page-only VM under memory pressure
/// swaps entirely through the compressed pool — swap traffic happens,
/// yet the NVMe device never sees a single byte (zero pages store no
/// payload and are never written back).
#[test]
fn zero_heavy_vm_reclaims_without_any_nvme_io() {
    let mut m = Machine::new(HostConfig::default());
    let mm_cfg = MmConfig {
        memory_limit: Some(1024 * 4096),
        scan_interval: 3600 * SEC, // limit-driven reclaim only (Auto hints)
        ..Default::default()
    };
    let vmid = m.sys_vm(
        vm_cfg(8192, PageSize::Small),
        &mm_cfg,
        vec![Box::new(UniformRandom::new(0, 4096, 80_000))],
    );
    m.set_content_mix(vmid, ContentMix::all_zero());
    let res = m.run();
    let c = &res[0].counters;
    assert!(c.swapout_ops > 100, "no reclaim happened: {c:?}");
    assert!(c.faults_major > 100, "no fault-back happened: {c:?}");
    let bm = m.backend_metrics();
    assert_eq!(bm.nvme_bytes_written, 0, "{bm:?}");
    assert_eq!(bm.nvme_reads, 0, "{bm:?}");
    assert_eq!(c.swapin_pool_hits, bm.pool_hits);
    assert!(bm.pool_zero_pages > 0);
}

/// The same pressure with incompressible content degrades gracefully to
/// the NVMe tier (pool rejects), still completing the workload.
#[test]
fn random_content_falls_through_to_nvme() {
    let mut m = Machine::new(HostConfig::default());
    let mm_cfg = MmConfig {
        memory_limit: Some(1024 * 4096),
        scan_interval: 3600 * SEC,
        ..Default::default()
    };
    let vmid = m.sys_vm(
        vm_cfg(8192, PageSize::Small),
        &mm_cfg,
        vec![Box::new(UniformRandom::new(0, 4096, 60_000))],
    );
    m.set_content_mix(vmid, ContentMix::all_random());
    let res = m.run();
    assert_eq!(res[0].work_ops, 60_000);
    let bm = m.backend_metrics();
    assert!(bm.pool_rejects > 0, "{bm:?}");
    assert!(bm.nvme_write_reqs > 0);
    assert_eq!(bm.pool_stores, 0); // nothing compressible to absorb
}

/// Multi-VM fleet shares one device without interference bugs.
#[test]
fn multi_vm_fleet_all_complete() {
    let mut m = Machine::new(HostConfig::default());
    for i in 0..4 {
        let mm_cfg = MmConfig {
            scan_interval: 100 * MS,
            history: 8,
            memory_limit: if i % 2 == 0 { Some(512 * 4096) } else { None },
            ..Default::default()
        };
        m.sys_vm(
            vm_cfg(2048, if i % 2 == 0 { PageSize::Small } else { PageSize::Huge }),
            &mm_cfg,
            vec![Box::new(UniformRandom::new(0, 1500, 25_000))],
        );
    }
    let res = m.run();
    assert_eq!(res.len(), 4);
    for (i, r) in res.iter().enumerate() {
        assert_eq!(r.work_ops, 25_000, "vm {i}");
    }
}
